"""End-to-end behaviour tests for the paper's system: a full streaming
deployment scenario — base-graph forward, update stream, concurrent ODEC
queries, engine/baseline/offload agreement, and counters sanity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    RTECUER,
    RTECEngine,
    RTECFull,
    full_forward,
    make_model,
    odec_query,
)
from repro.graph import make_graph, make_stream
from repro.graph.generators import random_features
from repro.serve.offload import OffloadedRTECEngine


def test_streaming_deployment_scenario():
    """The paper's deployment loop: serve queries per batch (ODEC), commit
    incrementally, verify against from-scratch recomputation at the end."""
    n = 400
    g = make_graph("powerlaw", n, avg_degree=8, seed=0, weighted=True)
    x, _ = random_features(n, 16, seed=0)
    wl = make_stream(g, num_batches=6, batch_edges=15, delete_frac=0.3,
                     feature_dim=16, feature_frac=0.01, seed=1)
    model = make_model("gat")
    params = model.init_layers(jax.random.PRNGKey(0), [16, 16, 16])

    inc = RTECEngine(model, params, wl.base, jnp.asarray(x))
    off = OffloadedRTECEngine(model, params, wl.base, x)
    rng = np.random.default_rng(2)

    g_cur, x_cur = wl.base, np.array(x)
    total_inc_edges = 0
    for b in wl.batches:
        q = rng.choice(n, size=8, replace=False).astype(np.int64)
        emb_q, qstats = odec_query(inc, b, q)
        assert bool(jnp.all(jnp.isfinite(emb_q)))
        st = inc.apply_batch(b)
        off.apply_batch(b)
        total_inc_edges += st.edges_processed
        # the ODEC answer must equal the committed state at those vertices
        np.testing.assert_allclose(
            np.asarray(emb_q), np.asarray(inc.embeddings[jnp.asarray(q)]), atol=1e-4
        )
        g_cur = g_cur.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                                    b.ins_weights, b.ins_etypes)
        if b.feat_vertices is not None:
            x_cur[b.feat_vertices] = b.feat_values

    ref = full_forward(model, params, jnp.asarray(x_cur), g_cur)[-1].h
    assert float(jnp.abs(inc.embeddings - ref).max()) < 5e-4
    np.testing.assert_allclose(off.embeddings, np.asarray(inc.embeddings), atol=1e-4)
    # and it must actually have been incremental
    assert total_inc_edges < 2 * g_cur.num_edges


def test_all_engines_agree_and_order_costs():
    n = 300
    g = make_graph("uniform", n, avg_degree=6, seed=3)
    x, _ = random_features(n, 8, seed=3)
    wl = make_stream(g, num_batches=3, batch_edges=10, seed=4)
    model = make_model("sage")
    params = model.init_layers(jax.random.PRNGKey(1), [8, 8, 8])
    engines = {
        "inc": RTECEngine(model, params, wl.base, jnp.asarray(x)),
        "full": RTECFull(model, params, wl.base, jnp.asarray(x)),
        "uer": RTECUER(model, params, wl.base, jnp.asarray(x)),
    }
    edges = {k: 0 for k in engines}
    for b in wl.batches:
        for k, e in engines.items():
            edges[k] += e.apply_batch(b).edges_processed
    h = {k: np.asarray(e.embeddings) for k, e in engines.items()}
    np.testing.assert_allclose(h["inc"], h["full"], atol=2e-4)
    np.testing.assert_allclose(h["inc"], h["uer"], atol=2e-4)
    assert edges["inc"] < edges["uer"] <= edges["full"]
