"""Residency-backend architecture invariants (ISSUE 4).

One :class:`StreamOrchestrator` drives four interchangeable state
substrates; the engine classes are thin facades.  The acceptance matrix:
all four backends produce embeddings equal to the single-device reference
(and to full recomputation within float tolerance) over a 20-batch gcn AND
gat stream — with the sharded pair additionally verified on a forced
8-host-device mesh in a subprocess — and the sharded-offload hybrid's
device residency is O(per-shard workspace), never O(V).
"""
import inspect
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    RTECEngine,
    ShardedRTECEngine,
    StreamOrchestrator,
    full_forward,
    make_model,
)
from repro.graph import make_graph, make_stream
from repro.graph.generators import random_features
from repro.serve.offload import OffloadedRTECEngine, ShardedOffloadRTECEngine

TOL = 2e-4


def _mk_stream(n=150, num_batches=20, seed=0, feature_dim=8, batch_edges=8):
    g = make_graph("powerlaw", n, avg_degree=5, seed=seed, weighted=True)
    x, _ = random_features(n, 8, seed=seed)
    wl = make_stream(g, num_batches=num_batches, batch_edges=batch_edges,
                     delete_frac=0.35, seed=seed + 1,
                     feature_dim=feature_dim, feature_frac=0.02)
    return x, wl


def _final_reference(model, params, x, wl):
    """From-scratch recomputation over the post-stream snapshot/features."""
    g_cur, x_cur = wl.base, np.array(x)
    for b in wl.batches:
        g_cur = g_cur.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                                    b.ins_weights, b.ins_etypes)
        if b.feat_vertices is not None:
            x_cur[b.feat_vertices] = b.feat_values
    return np.asarray(full_forward(model, params, jnp.asarray(x_cur), g_cur)[-1].h)


# ---------------------------------------------------------------------- #
# architecture: orchestration lives only in StreamOrchestrator
# ---------------------------------------------------------------------- #
def test_engines_are_facades_over_one_orchestrator():
    """No engine class may own a plan/overlap loop: every ``apply_batch`` /
    ``apply_stream`` must be a pure delegation to StreamOrchestrator."""
    from repro.serve.offload import _OffloadFacadeMixin

    for cls in (RTECEngine, ShardedRTECEngine, _OffloadFacadeMixin):
        for meth in ("apply_batch", "apply_stream"):
            src = inspect.getsource(getattr(cls, meth))
            assert f"self._orch.{meth}" in src, (cls, meth)
            # no timing, no dispatching, no per-batch loop in any facade
            assert "perf_counter" not in src, f"{cls.__name__}.{meth} times"
            assert "dispatch" not in src, f"{cls.__name__}.{meth} dispatches"

    x, wl = _mk_stream(n=60, num_batches=1)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    engines = [
        RTECEngine(model, params, wl.base, jnp.asarray(x)),
        ShardedRTECEngine(model, params, wl.base, x, num_shards=1),
        OffloadedRTECEngine(model, params, wl.base, x),
        ShardedOffloadRTECEngine(model, params, wl.base, x, num_shards=1),
    ]
    for eng in engines:
        assert isinstance(eng._orch, StreamOrchestrator)


# ---------------------------------------------------------------------- #
# cross-backend equivalence matrix (in-process; S = local device count)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["gcn", "gat"])  # unconstrained + constrained
def test_cross_backend_matrix_20_batches(name):
    S = jax.device_count()
    x, wl = _mk_stream(n=150, num_batches=20, seed=3)
    model = make_model(name)
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8, 8])
    from repro.serve import ChunkedRTECEngine

    device = RTECEngine(model, params, wl.base, jnp.asarray(x))
    offload = OffloadedRTECEngine(model, params, wl.base, x)
    sharded = ShardedRTECEngine(model, params, wl.base, x, num_shards=S)
    hybrid = ShardedOffloadRTECEngine(model, params, wl.base, x, num_shards=S)
    chunked = ChunkedRTECEngine(model, params, wl.base, x, chunk_size=32)
    for b in wl.batches:
        for eng in (device, offload, sharded, hybrid, chunked):
            eng.apply_batch(b)

    ref = _final_reference(model, params, x, wl)
    embs = {
        "device": np.asarray(device.embeddings),
        "offload": np.asarray(offload.embeddings),
        "sharded": np.asarray(sharded.embeddings),
        "hybrid": np.asarray(hybrid.embeddings),
        "chunked": np.asarray(chunked.embeddings),
    }
    for k, e in embs.items():
        assert float(np.abs(e - ref).max()) < TOL, f"{k} vs full recompute"
    # the hybrid's compact per-shard staging is index-remapped, never
    # re-ordered → bit-identical to the host-resident offload engine
    np.testing.assert_array_equal(embs["hybrid"], embs["offload"])
    if name == "gcn":  # unconstrained path is exact across all substrates
        np.testing.assert_array_equal(embs["device"], embs["sharded"])
        np.testing.assert_array_equal(embs["device"], embs["offload"])
    else:
        assert float(np.abs(embs["device"] - embs["sharded"]).max()) < TOL
        assert float(np.abs(embs["device"] - embs["offload"]).max()) < TOL


# ---------------------------------------------------------------------- #
# hybrid residency: device footprint is O(workspace), not O(V)
# ---------------------------------------------------------------------- #
def test_hybrid_device_residency_is_o_workspace():
    """Grow the graph 7.5× at fixed batch size: the hybrid's peak staged
    bytes (its entire HBM residency) must stay bounded by the affected
    workspace while the host-resident state grows with V.  Uniform graphs
    keep the k-hop affected cone size independent of V (a powerlaw hub's
    fanout would legitimately grow the workspace itself)."""
    peaks, states = {}, {}
    model = make_model("gcn")
    for n in (400, 3000):
        g = make_graph("uniform", n, avg_degree=4, seed=5, weighted=True)
        x, _ = random_features(n, 8, seed=5)
        wl = make_stream(g, num_batches=4, batch_edges=6, delete_frac=0.35,
                         seed=6)
        params = model.init_layers(jax.random.PRNGKey(1), [8, 8, 8])
        hyb = ShardedOffloadRTECEngine(model, params, wl.base, x,
                                       num_shards=jax.device_count())
        for b in wl.batches:
            hyb.apply_batch(b)
        peaks[n] = hyb.peak_device_bytes
        states[n] = hyb.state_bytes()
    # state is O(V): 7.5× more vertices → >5× more state bytes
    assert states[3000] > 5 * states[400]
    # device residency is O(workspace): flat in V (pow-2 caps may wiggle) ...
    assert peaks[3000] <= 1.5 * peaks[400], (peaks, states)
    # ... and at production-shaped V it is a small fraction of the state
    assert peaks[3000] < states[3000] / 4, (peaks, states)


def test_hybrid_per_shard_transfer_accounting():
    """per_shard_rows must sum to the aggregate TransferStats row volume and
    every shard's traffic must be bounded by its own affected subgraph (no
    shard stages the whole plan)."""
    S = jax.device_count()
    x, wl = _mk_stream(n=160, num_batches=6, seed=7)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(2), [8, 8, 8])
    hyb = ShardedOffloadRTECEngine(model, params, wl.base, x, num_shards=S)
    for b in wl.batches:
        hyb.apply_batch(b)
    assert int(hyb.per_shard_rows.sum()) == hyb.transfers.total_rows
    assert hyb.transfers.total_rows > 0
    if S > 1:
        assert int(hyb.per_shard_rows.max()) < hyb.transfers.total_rows


def test_hybrid_apply_stream_matches_apply_batch():
    S = jax.device_count()
    x, wl = _mk_stream(n=120, num_batches=8, seed=11)
    model = make_model("gat")
    params = model.init_layers(jax.random.PRNGKey(3), [8, 8, 8])
    seq = ShardedOffloadRTECEngine(model, params, wl.base, x, num_shards=S)
    pipe = ShardedOffloadRTECEngine(model, params, wl.base, x, num_shards=S)
    for b in wl.batches:
        seq.apply_batch(b)
    ss = pipe.apply_stream(wl.batches)
    np.testing.assert_array_equal(seq.embeddings, pipe.embeddings)
    assert len(ss.batches) == len(wl.batches)
    assert ss.wall_s > 0 and ss.plan_s > 0


def test_hybrid_refresh_keeps_stream_feature_updates():
    S = jax.device_count()
    x, wl = _mk_stream(n=100, num_batches=6, seed=13)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(4), [8, 8, 8])
    ref = RTECEngine(model, params, wl.base, jnp.asarray(x), refresh_every=3)
    hyb = ShardedOffloadRTECEngine(model, params, wl.base, x, num_shards=S,
                                   refresh_every=3)
    for b in wl.batches:
        ref.apply_batch(b)
        hyb.apply_batch(b)
    np.testing.assert_allclose(np.asarray(ref.embeddings), hyb.embeddings,
                               atol=1e-6)


# ---------------------------------------------------------------------- #
# the acceptance invariant under a real 8-shard mesh (subprocess: device
# count must be fixed before jax initializes)
# ---------------------------------------------------------------------- #
_SUBPROCESS_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
"""


def test_cross_backend_matrix_8dev_20_batches_subprocess():
    """ISSUE 4 acceptance: all four backends agree over a 20-batch gcn and
    gat stream with the sharded pair on a forced 8-host-device mesh, and
    the hybrid keeps device residency O(workspace) while sharded 8 ways."""
    code = _SUBPROCESS_PRELUDE + textwrap.dedent("""
    from repro.core import RTECEngine, ShardedRTECEngine, full_forward, make_model
    from repro.serve.offload import OffloadedRTECEngine, ShardedOffloadRTECEngine
    from repro.graph import make_graph, make_stream
    from repro.graph.generators import random_features

    assert jax.device_count() == 8
    g = make_graph("powerlaw", 150, avg_degree=5, seed=3, weighted=True)
    x, _ = random_features(150, 8, seed=3)
    wl = make_stream(g, num_batches=20, batch_edges=8, delete_frac=0.35,
                     seed=4, feature_dim=8, feature_frac=0.02)
    for name in ("gcn", "gat"):
        model = make_model(name)
        params = model.init_layers(jax.random.PRNGKey(0), [8, 8, 8])
        device = RTECEngine(model, params, wl.base, jnp.asarray(x))
        offload = OffloadedRTECEngine(model, params, wl.base, x)
        sharded = ShardedRTECEngine(model, params, wl.base, x, num_shards=8)
        hybrid = ShardedOffloadRTECEngine(model, params, wl.base, x, num_shards=8)
        for b in wl.batches:
            for eng in (device, offload, sharded, hybrid):
                eng.apply_batch(b)
        g_cur, x_cur = wl.base, np.array(x)
        for b in wl.batches:
            g_cur = g_cur.apply_updates(b.ins_src, b.ins_dst, b.del_src,
                                        b.del_dst, b.ins_weights, b.ins_etypes)
            if b.feat_vertices is not None:
                x_cur[b.feat_vertices] = b.feat_values
        ref = np.asarray(full_forward(model, params, jnp.asarray(x_cur), g_cur)[-1].h)
        embs = dict(device=np.asarray(device.embeddings),
                    offload=np.asarray(offload.embeddings),
                    sharded=np.asarray(sharded.embeddings),
                    hybrid=np.asarray(hybrid.embeddings))
        for k, e in embs.items():
            d = float(np.abs(e - ref).max())
            assert d < 2e-4, (name, k, d)
        np.testing.assert_array_equal(embs["hybrid"], embs["offload"])
        if name == "gcn":
            np.testing.assert_array_equal(embs["device"], embs["sharded"])
            np.testing.assert_array_equal(embs["device"], embs["offload"])
        assert sharded.halo_rows_total > 0
        assert hybrid.peak_device_bytes < hybrid.state_bytes() * 8
        assert int(hybrid.per_shard_rows.sum()) == hybrid.transfers.total_rows
        print(name, "ok", {k: float(np.abs(e - ref).max()) for k, e in embs.items()})
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=Path(__file__).resolve().parents[1], timeout=900,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    print(out.stdout)
