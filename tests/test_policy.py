"""Adaptive execution policy (ISSUE 7): cost model, per-batch mode
selection, policy≡forced bitwise equivalence on every backend, the
adversarial decision counts the CI matrix gates, and the serving
front-end's undo-log reset on policy-chosen full-recompute batches.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.core import (
    MODES,
    ExecutionPolicy,
    estimate_plan_cost,
    make_model,
    make_policy,
)
from repro.core.affected import build_plan
from repro.core.backend import (
    ChunkedBackend,
    DeviceBackend,
    OffloadBackend,
    ShardBackend,
    ShardedOffloadBackend,
    StreamOrchestrator,
)
from repro.graph import ADVERSARIAL_REGIMES, make_adversarial_stream
from repro.graph.csr import CSRGraph
from repro.graph.generators import random_features
from repro.serve import EngineConfig, ServingFrontend, StaleVersionError, create_engine

BACKEND_MAKERS = {
    "device": DeviceBackend,
    "offload": OffloadBackend,
    "sharded": ShardBackend,
    "sharded_offload": ShardedOffloadBackend,
    "chunked": ChunkedBackend,
}


def _setup(regime: str, seed: int = 0):
    model = make_model("gcn")
    wl = make_adversarial_stream(regime, seed=seed)
    x, _ = random_features(wl.base.n, 8, seed=seed)
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    return model, wl, x, params


def _graphs_along(wl):
    """(g_old, g_new, batch) triples walking the stream's graph evolution."""
    g = wl.base
    for b in wl.batches:
        g_new = g.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                                b.ins_weights, b.ins_etypes)
        yield g, g_new, b
        g = g_new


# ---------------------------------------------------------------------- #
# cost model
# ---------------------------------------------------------------------- #
def test_estimates_monotone_in_frontier():
    """A burst batch's affected frontier strictly contains a quiet batch's,
    so every incremental/chunked count must grow with it; full recompute
    tracks |E|, which only the structural batches move."""
    model, wl, x, params = _setup("hub_burst")
    ests = []
    for g_old, g_new, b in _graphs_along(wl):
        plan = build_plan(model, g_old, g_new, b, 2)
        ests.append(estimate_plan_cost(plan))
    quiet, burst = ests[0], ests[1]  # b1 is the first hub burst
    assert burst.affected_rows > quiet.affected_rows
    assert burst.frontier_rows > quiet.frontier_rows
    assert burst.inc_edges > quiet.inc_edges
    assert burst.chunked_edges > quiet.chunked_edges
    for est in ests:
        # chunked recomputes a subset of the rows full recomputes, from
        # the same degree table: it can never exceed the dense pass
        assert est.chunked_edges <= est.full_edges
        assert est.affected_rows <= est.n * est.L
        for mode in MODES:
            assert est.edges(mode) >= 0
            assert est.staged_rows(mode) > 0


def test_estimate_row_bytes_scales_staged_bytes():
    model, wl, x, params = _setup("hub_burst")
    g_old, g_new, b = next(_graphs_along(wl))
    plan = build_plan(model, g_old, g_new, b, 2)
    est = estimate_plan_cost(plan, row_bytes=96)
    for mode in MODES:
        assert est.staged_bytes(mode) == est.staged_rows(mode) * 96


def test_policy_costs_and_argmin():
    """costs() weights raw edge-work; decide() takes the argmin with the
    MODES tie-break order."""
    model, wl, x, params = _setup("delete_heavy")
    pol = ExecutionPolicy()
    for g_old, g_new, b in _graphs_along(wl):
        plan = build_plan(model, g_old, g_new, b, 2)
        d = pol.decide(plan)
        assert d.mode in MODES
        assert not d.forced
        assert d.costs[d.mode] == min(d.costs.values())
        assert d.est_edges == d.estimate.edges(d.mode)
    assert sum(pol.decisions.values()) == len(wl.batches)
    assert len(pol.history) == len(wl.batches)


def test_make_policy_resolution():
    assert make_policy(None) is None
    pol = ExecutionPolicy()
    assert make_policy(pol) is pol
    assert make_policy("adaptive").force_mode is None
    assert make_policy("full").force_mode == "full"
    with pytest.raises(ValueError):
        make_policy("warp")
    with pytest.raises(ValueError):
        ExecutionPolicy(force_mode=("incremental", "warp"))


def test_force_mode_schedule_exhausted():
    model, wl, x, params = _setup("hub_burst")
    pol = ExecutionPolicy(force_mode=("incremental",))
    it = _graphs_along(wl)
    g_old, g_new, b = next(it)
    pol.decide(build_plan(model, g_old, g_new, b, 2))
    g_old, g_new, b = next(it)
    with pytest.raises(ValueError, match="schedule exhausted"):
        pol.decide(build_plan(model, g_old, g_new, b, 2))


# ---------------------------------------------------------------------- #
# policy ≡ forced-mode bitwise equivalence (all five backends × regimes)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", sorted(BACKEND_MAKERS))
@pytest.mark.parametrize("regime", ADVERSARIAL_REGIMES)
def test_policy_equals_forced_schedule_bitwise(backend, regime):
    """Replaying an adaptive run's recorded decisions through
    ``force_mode`` must reproduce its embeddings bitwise on every
    substrate: the policy only *selects* between execution shapes, the
    shapes themselves are deterministic."""
    model, wl, x, params = _setup(regime)
    mk = BACKEND_MAKERS[backend]

    be_a = mk(model, params, wl.base, x)
    orch_a = StreamOrchestrator(be_a, wl.base, policy=make_policy("adaptive"))
    orch_a.apply_stream(wl.batches)
    schedule = tuple(d.mode for d in orch_a.policy.history)
    assert len(schedule) == len(wl.batches)
    # the adversarial streams are built so the adaptive schedule mixes
    # modes — an all-incremental schedule would make this test vacuous
    assert len(set(schedule)) > 1

    be_f = mk(model, params, wl.base, x)
    orch_f = StreamOrchestrator(be_f, wl.base,
                                policy=ExecutionPolicy(force_mode=schedule))
    orch_f.apply_stream(wl.batches)
    for d in orch_f.policy.history:
        assert d.forced
    np.testing.assert_array_equal(np.asarray(be_a.embeddings),
                                  np.asarray(be_f.embeddings))


@pytest.mark.parametrize("regime", ADVERSARIAL_REGIMES)
def test_policy_modes_match_reference_embeddings(regime):
    """Every execution shape lands on the same embeddings (to float32
    tolerance — chunked/full recompute vs incremental accumulation), and
    forced-incremental is bitwise-equal to the no-policy path."""
    model, wl, x, params = _setup(regime)
    be_ref = DeviceBackend(model, params, wl.base, x)
    orch_ref = StreamOrchestrator(be_ref, wl.base)
    for b in wl.batches:
        orch_ref.apply_batch(b)
    ref = np.asarray(be_ref.embeddings)
    for spec in ("incremental", "chunked", "full", "adaptive"):
        be = DeviceBackend(model, params, wl.base, x)
        orch = StreamOrchestrator(be, wl.base, policy=make_policy(spec))
        orch.apply_stream(wl.batches)
        got = np.asarray(be.embeddings)
        if spec == "incremental":
            np.testing.assert_array_equal(got, ref)
        else:
            np.testing.assert_allclose(got, ref, atol=5e-6)


# ---------------------------------------------------------------------- #
# the adversarial decision counts the CI matrix gates
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("regime", ADVERSARIAL_REGIMES)
def test_adversarial_decision_counts_match_ci_expectations(regime):
    """The per-regime decision counts are THE blocking CI contract
    (check_regression.ADVERSARIAL_EXPECTED): pin them here too so a
    policy/planner change fails the tier-1 suite before it fails CI."""
    from benchmarks.check_regression import ADVERSARIAL_EXPECTED

    model, wl, x, params = _setup(regime)
    be = DeviceBackend(model, params, wl.base, x)
    orch = StreamOrchestrator(be, wl.base, policy=make_policy("adaptive"))
    ss = orch.apply_stream(wl.batches)
    d = ss.as_dict()
    exp = ADVERSARIAL_EXPECTED[regime]
    for mode in MODES:
        assert d[f"policy_{mode}_batches"] == exp[mode], (regime, mode)
    assert d["policy_edges"] == exp["policy_edges"]
    assert d["policy_cost"] > 0.0
    # the adaptive per-batch argmin over mode-independent plans can never
    # cost more than any fixed mode (the ≤1.1× acceptance bound holds
    # with margin); fixed totals come from the recorded estimates
    for mode in MODES:
        fixed_cost = sum(dec.costs[mode] for dec in orch.policy.history)
        assert d["policy_cost"] <= fixed_cost + 1e-9


def test_adversarial_streams_are_deterministic():
    for regime in ADVERSARIAL_REGIMES:
        a = make_adversarial_stream(regime, seed=3)
        b = make_adversarial_stream(regime, seed=3)
        assert a.base.n == b.base.n
        for ba, bb in zip(a.batches, b.batches):
            np.testing.assert_array_equal(ba.ins_src, bb.ins_src)
            np.testing.assert_array_equal(ba.del_src, bb.del_src)
            if ba.feat_values is not None:
                np.testing.assert_array_equal(ba.feat_values, bb.feat_values)
    with pytest.raises(ValueError, match="unknown adversarial regime"):
        make_adversarial_stream("calm")
    with pytest.raises(ValueError, match="n >= 64"):
        make_adversarial_stream("hub_burst", n=32)


def test_adversarial_stream_live_edge_invariant():
    """Applying every batch in order never inserts a duplicate edge or
    deletes a missing one (CSRGraph.apply_updates raises on both)."""
    for regime in ADVERSARIAL_REGIMES:
        wl = make_adversarial_stream(regime)
        g = wl.base
        assert isinstance(g, CSRGraph)
        for b in wl.batches:
            g = g.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                                b.ins_weights, b.ins_etypes)


# ---------------------------------------------------------------------- #
# serving front-end: policy-chosen full recompute resets the undo log
# ---------------------------------------------------------------------- #
def test_frontend_reset_on_policy_full_recompute():
    """hub_burst batch 1 makes the adaptive policy pick full recompute:
    the frontend must reset its undo history (floor jumps to that
    version) instead of logging a whole-state pre-image; versions
    retained *after* the reset keep serving bitwise reads with no
    StaleVersionError regression."""
    model, wl, x, params = _setup("hub_burst")
    cfg = EngineConfig(model=model, graph=wl.base, x=x, params=params,
                       policy="adaptive")
    eng = create_engine("device", cfg)
    fe = ServingFrontend(eng, max_versions=8)
    rows = np.arange(0, wl.base.n, 7)

    snaps = {0: np.array(eng.snapshot_rows(rows))}
    full_versions = []
    for v, b in enumerate(wl.batches, start=1):
        bs = fe.apply_batch(b)
        snaps[v] = np.array(eng.snapshot_rows(rows))
        if bs.mode == "full":
            full_versions.append(v)
            # policy-chosen full recompute == refresh-style history reset
            assert fe.min_version == v
        assert fe.version == v

    assert full_versions, "hub_burst must trigger at least one full batch"
    last_reset = full_versions[-1]
    # pins below the last reset are unreconstructible → typed rejection
    for stale in range(last_reset):
        with pytest.raises(StaleVersionError):
            fe.read(rows, version=stale)
    # pins at/after the last reset serve bitwise — the reset must not
    # leak into versions retained after it
    for v in range(last_reset, fe.version + 1):
        np.testing.assert_array_equal(fe.read(rows, version=v), snaps[v])
    assert fe.reads_served == fe.version + 1 - last_reset


def test_frontend_bitwise_reads_across_chunked_batches():
    """feature_churn's adaptive schedule mixes incremental and chunked
    batches (never full): the undo log must stay bitwise across both
    write-set shapes, for every retained version."""
    model, wl, x, params = _setup("feature_churn")
    cfg = EngineConfig(model=model, graph=wl.base, x=x, params=params,
                       policy="adaptive")
    eng = create_engine("device", cfg)
    fe = ServingFrontend(eng, max_versions=len(wl.batches) + 1)
    rows = np.arange(0, wl.base.n, 3)

    snaps = {0: np.array(eng.snapshot_rows(rows))}
    modes = set()
    for v, b in enumerate(wl.batches, start=1):
        bs = fe.apply_batch(b)
        modes.add(bs.mode)
        snaps[v] = np.array(eng.snapshot_rows(rows))
    assert modes == {"incremental", "chunked"}
    assert fe.min_version == 0  # no reset: every version stays readable
    for v in range(fe.version + 1):
        np.testing.assert_array_equal(fe.read(rows, version=v), snaps[v])


# ---------------------------------------------------------------------- #
# hysteresis band (ISSUE 8): damp mode flapping, follow genuine shifts
# ---------------------------------------------------------------------- #
def _adaptive_modes(regime: str, hysteresis: float):
    model, wl, x, params = _setup(regime)
    be = DeviceBackend(model, params, wl.base, x)
    orch = StreamOrchestrator(
        be, wl.base, policy=make_policy("adaptive", hysteresis=hysteresis))
    orch.apply_stream(wl.batches)
    return [d.mode for d in orch.policy.history], np.asarray(be.embeddings)


def _flips(modes) -> int:
    return sum(a != b for a, b in zip(modes, modes[1:]))


def test_hysteresis_validation():
    with pytest.raises(ValueError, match="hysteresis"):
        ExecutionPolicy(hysteresis=-0.1)
    with pytest.raises(ValueError, match="hysteresis"):
        ExecutionPolicy(hysteresis=1.0)
    assert make_policy("adaptive", hysteresis=0.25).hysteresis == 0.25
    # default band is 0.0 — the exact adversarial CI gates depend on it
    assert make_policy("adaptive").hysteresis == 0.0


def test_hysteresis_damps_feature_churn_flapping():
    """feature_churn oscillates around the incremental/chunked cost
    crossover (the costs differ by ~20% each way): the 0.0 band flips
    mode every batch, a 0.15 band holds incremental throughout.  The
    damped run's embeddings must still match the flapping run's to
    float32 tolerance — modes only pick the execution shape."""
    modes0, emb0 = _adaptive_modes("feature_churn", 0.0)
    assert _flips(modes0) == 5  # the adversarial construction guarantees it
    modes_h, emb_h = _adaptive_modes("feature_churn", 0.15)
    assert _flips(modes_h) == 0
    assert set(modes_h) == {"incremental"}
    np.testing.assert_allclose(emb_h, emb0, atol=5e-6)


def test_hysteresis_follows_genuine_regime_shift():
    """delete_heavy alternates between regimes whose costs differ by far
    more than the band (full is ~3x cheaper on the delete batches): even
    a 0.3 band must follow every shift — hysteresis damps flapping
    around a crossover, it must not freeze the policy."""
    modes0, _ = _adaptive_modes("delete_heavy", 0.0)
    modes_h, _ = _adaptive_modes("delete_heavy", 0.3)
    assert modes_h == modes0
    assert _flips(modes_h) == 5


def test_hysteresis_zero_is_bitwise_argmin():
    """hysteresis=0.0 must reproduce the plain per-batch argmin decision
    for decision — the adversarial CI gates pin those counts exactly."""
    for regime in ADVERSARIAL_REGIMES:
        modes0, _ = _adaptive_modes(regime, 0.0)
        model, wl, x, params = _setup(regime)
        be = DeviceBackend(model, params, wl.base, x)
        orch = StreamOrchestrator(be, wl.base, policy=make_policy("adaptive"))
        orch.apply_stream(wl.batches)
        assert [d.mode for d in orch.policy.history] == modes0, regime


def test_hysteresis_forced_bypasses_band():
    """force_mode pins decisions regardless of the band (and must not
    seed its previous-mode state)."""
    model, wl, x, params = _setup("hub_burst")
    pol = ExecutionPolicy(force_mode="chunked", hysteresis=0.5)
    for g_old, g_new, b in _graphs_along(wl):
        d = pol.decide(build_plan(model, g_old, g_new, b, 2))
        assert d.forced and d.mode == "chunked"
    assert pol._prev_mode is None


def test_engine_config_policy_hysteresis_threading():
    model, wl, x, params = _setup("hub_burst")
    cfg = EngineConfig(model=model, graph=wl.base, x=x, params=params,
                       policy="adaptive", policy_hysteresis=0.3)
    eng = create_engine("device", cfg)
    assert eng._orch.policy.hysteresis == 0.3


# ---------------------------------------------------------------------- #
# StreamStats accounting and the EngineConfig knob
# ---------------------------------------------------------------------- #
# online cost-weight calibration (ISSUE 9)
# ---------------------------------------------------------------------- #
def test_calibrate_validation_and_passthrough():
    with pytest.raises(ValueError, match="calibrate_blend"):
        ExecutionPolicy(calibrate=True, calibrate_blend=1.5)
    with pytest.raises(ValueError, match="calibrate_alpha"):
        ExecutionPolicy(calibrate=True, calibrate_alpha=0.0)
    assert make_policy("adaptive", calibrate=True).calibrate is True
    assert make_policy("adaptive").calibrate is False
    # forced-mode policies never calibrate (they are the CI baselines)
    assert make_policy("incremental", calibrate=True).calibrate is False


def test_calibrate_off_is_strict_noop():
    """The static decision surface must not move: observe() is a no-op and
    effective_weights() returns the *same dict object* as the weights."""
    model, wl, x, params = _setup("hub_burst")
    pol = ExecutionPolicy()
    for g_old, g_new, b in _graphs_along(wl):
        d = pol.decide(build_plan(model, g_old, g_new, b, 2))
        pol.observe(d, 12.34)
    assert pol.effective_weights() is pol.weights
    assert all(v is None for v in pol._ema.values())


def test_calibrate_ema_update_math():
    """observe() maintains wall-per-work-unit EMAs with the documented
    update rule; effective_weights() blends with the ratio-preserving
    rescale (one measured mode is a fixed point of the blend)."""
    model, wl, x, params = _setup("hub_burst")
    pol = ExecutionPolicy(calibrate=True, calibrate_alpha=0.25)
    g_old, g_new, b = next(_graphs_along(wl))
    d = pol.decide(build_plan(model, g_old, g_new, b, 2))
    units = pol._units(d.estimate, d.mode)
    pol.observe(d, 2.0)
    assert pol._ema[d.mode] == pytest.approx(2.0 / units)
    pol.observe(d, 4.0)
    assert pol._ema[d.mode] == pytest.approx(0.75 * (2.0 / units)
                                             + 0.25 * (4.0 / units))
    # zero/negative walls and calibrate=False feeds are ignored
    pol.observe(d, 0.0)
    assert pol._ema[d.mode] == pytest.approx(0.75 * (2.0 / units)
                                             + 0.25 * (4.0 / units))
    # one measured mode: the rescale pins its blended weight to static
    w = pol.effective_weights()
    assert w is not pol.weights
    assert w[d.mode] == pytest.approx(pol.weights[d.mode])


def test_calibrate_two_modes_shift_ratios():
    """With two measured modes the blend moves the *ratio* toward the
    measured one while preserving the static magnitude scale."""
    model, wl, x, params = _setup("hub_burst")
    pol = ExecutionPolicy(calibrate=True, calibrate_blend=0.5)
    g_old, g_new, b = next(_graphs_along(wl))
    plan = build_plan(model, g_old, g_new, b, 2)
    d = pol.decide(plan)
    est = d.estimate
    # synthesize: incremental measured 4x slower per unit than full
    pol._ema["incremental"] = 4.0e-6
    pol._ema["full"] = 1.0e-6
    w = pol.effective_weights()
    # measured ratio (4.0) exceeds the static 2.0/1.0: incremental's
    # effective weight rises, full's falls, the mean over measured holds
    assert w["incremental"] > pol.weights["incremental"]
    assert w["full"] < pol.weights["full"]
    total_static = pol.weights["incremental"] + pol.weights["full"]
    assert w["incremental"] + w["full"] == pytest.approx(total_static)
    assert w["chunked"] == pol.weights["chunked"]  # unmeasured: static
    # and costs() prices through the blend
    assert pol.costs(est)["incremental"] == pytest.approx(
        w["incremental"] * pol._units(est, "incremental"))


def test_engine_config_policy_calibrate_threading():
    """EngineConfig.policy_calibrate reaches the resolved policy, the
    orchestrator feeds measured walls back, and the run still completes
    with sane accounting (decisions are hardware-dependent under
    calibration, so no exactness is asserted — that is the point of
    keeping the static model as the CI gate)."""
    model, wl, x, params = _setup("hub_burst")
    cfg = EngineConfig(model=model, graph=wl.base, x=x, params=params,
                       policy="adaptive", policy_calibrate=True)
    pol = cfg.resolved_policy()
    assert pol.calibrate is True
    eng = create_engine("offload", EngineConfig(
        model=model, graph=wl.base, x=x, params=params, policy=pol))
    ss = eng.apply_stream(wl.batches)
    assert len(ss.batches) == len(wl.batches)
    assert any(v is not None for v in pol._ema.values())


def test_decide_window_records_only_accepted_windows():
    """A declined fused window must not double-count: the serial fallback
    re-decides each constituent through decide()."""
    model, wl, x, params = _setup("hub_burst")
    g_old, g_new, b = next(_graphs_along(wl))
    plan = build_plan(model, g_old, g_new, b, 2)
    # huge incremental weight → the window prices off-incremental
    pol = ExecutionPolicy(incremental_weight=1e9)
    d = pol.decide_window(plan)
    assert d.mode != "incremental"
    assert len(pol.history) == 0 and sum(pol.decisions.values()) == 0
    # default weights on a small plan → incremental wins → recorded
    pol2 = ExecutionPolicy()
    d2 = pol2.decide_window(plan)
    assert d2.mode == "incremental"
    assert len(pol2.history) == 1 and pol2.decisions["incremental"] == 1


# ---------------------------------------------------------------------- #
def test_stream_stats_policy_keys_default_zero():
    """Without a policy every batch reports mode="incremental" and the
    policy accounting stays zero — pre-policy baselines keep passing."""
    model, wl, x, params = _setup("hub_burst")
    be = DeviceBackend(model, params, wl.base, x)
    ss = StreamOrchestrator(be, wl.base).apply_stream(wl.batches)
    d = ss.as_dict()
    assert d["policy_incremental_batches"] == len(wl.batches)
    assert d["policy_chunked_batches"] == 0
    assert d["policy_full_batches"] == 0
    assert d["policy_edges"] == 0
    assert d["policy_cost"] == 0.0


def test_engine_config_policy_specs_all_backends():
    """EngineConfig.policy drives every factory backend; a forced-mode
    spec string gives each engine its own decision state."""
    model, wl, x, params = _setup("feature_churn")
    for backend in sorted(BACKEND_MAKERS):
        cfg = EngineConfig(model=model, graph=wl.base, x=x, params=params,
                           policy="chunked")
        eng = create_engine(backend, cfg)
        eng.apply_batch(wl.batches[0])
        pol = eng._orch.policy
        assert pol.decisions["chunked"] == 1, backend


# ---------------------------------------------------------------------- #
# check_regression: renamed-cell namespace guard (exit 2, never retried)
# ---------------------------------------------------------------------- #
def test_check_regression_missing_namespace_exits_2(tmp_path):
    """A baseline row in a gated namespace that the candidate artifact no
    longer emits (renamed bench cell) must exit 2 — the retry path may
    not silently pass it."""
    import json
    import subprocess
    import sys
    from pathlib import Path

    from benchmarks.check_regression import (
        EXIT_MISSING,
        EXIT_OK,
        SUITES,
        missing_namespace_rows,
    )

    repo = Path(__file__).resolve().parents[1]
    base = repo / "BENCH_baseline.json"
    good = json.loads(base.read_text())["rows"]

    def run_gate(rows, suite):
        art = tmp_path / "current.json"
        art.write_text(json.dumps({"rows": rows, "wall_s": 1.0}))
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.check_regression",
             "--current", str(art), "--baseline", str(base),
             "--suite", suite],
            capture_output=True, text=True, cwd=repo, timeout=120,
        )
        return proc.returncode, proc.stderr

    code, err = run_gate(good, "adversarial-hub_burst")
    assert code == EXIT_OK, err
    # rename one gated cell's rows: the per-spec loop would flag the
    # specced ones anyway, but the namespace guard also catches renamed
    # *telemetry* rows of a gated cell, which no spec references
    renamed = [r.replace("adversarial/hub_burst/fixed_full_cost",
                         "adversarial/hub_burst/fixed_dense_cost")
               for r in good]
    code, err = run_gate(renamed, "adversarial-hub_burst")
    assert code == EXIT_MISSING
    assert "renamed bench cell" in err
    # unreadable candidate artifact → exit 2 as well, not a traceback
    art = tmp_path / "current.json"
    art.write_text("{not json")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.check_regression",
         "--current", str(art), "--baseline", str(base),
         "--suite", "adversarial-hub_burst"],
        capture_output=True, text=True, cwd=repo, timeout=120,
    )
    assert proc.returncode == EXIT_MISSING
    # the helper ignores rows outside the gated namespaces (the shared
    # baseline carries smoke + sharded + adversarial rows)
    msgs = missing_namespace_rows(str(art), str(base),
                                  SUITES["adversarial-hub_burst"])
    assert msgs and "unreadable" in msgs[0]
    assert missing_namespace_rows(str(base), str(base),
                                  SUITES["adversarial-hub_burst"]) == []
