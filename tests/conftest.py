import os

# Tests run on the single real CPU device; only launch/dryrun (in its own
# process) requests 512 placeholder devices.  Keep compilation deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


# Every compiled XLA executable pins a handful of anonymous mappings for
# its JIT'd code, and jit caches are process-global, so a full-suite run
# accumulates mappings monotonically — by the end of the suite the process
# sits within a few percent of the kernel's vm.max_map_count (65530
# default), and crossing it segfaults *inside* the next LLVM compile.
# Bound the growth: after any module that leaves the map count above the
# threshold, drop the compiled-executable caches (the affected module
# recompiles its shapes on next use; correctness is unaffected).
_MAPS_CLEAR_THRESHOLD = 30_000


def _map_count() -> int:
    try:
        with open("/proc/self/maps") as f:
            return sum(1 for _ in f)
    except OSError:  # non-Linux host: no map limit to bound
        return 0


@pytest.fixture(autouse=True, scope="module")
def _bound_compile_cache_maps():
    yield
    if "jax" in sys.modules and _map_count() > _MAPS_CLEAR_THRESHOLD:
        sys.modules["jax"].clear_caches()
