import os

# Tests run on the single real CPU device; only launch/dryrun (in its own
# process) requests 512 placeholder devices.  Keep compilation deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
