"""Decoupled operators: Theorem-1 condition prober + per-model semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ALL_MODELS, certify, full_forward, make_model, validate_registration
from repro.core.operators import GNNModel
from repro.graph.csr import CSRGraph
from repro.graph.generators import random_features


def _mk(name):
    kw = {"num_relations": 3} if name in ("rgcn", "rgat") else {}
    return make_model(name, **kw)


@pytest.mark.parametrize("name", ALL_MODELS)
def test_conditions_certified(name):
    model = _mk(name)
    rep = validate_registration(model)
    assert rep.incrementalizable


@pytest.mark.parametrize("name", ["gat", "agnn", "ggcn", "rgat"])
def test_dest_dependence_detected(name):
    rep = certify(_mk(name))
    assert not rep.dest_independent, f"{name} should be detected as dest-dependent"


@pytest.mark.parametrize("name", ["gcn", "sage", "gin", "commnet", "monet", "pinsage", "rgcn"])
def test_dest_independence_detected(name):
    rep = certify(_mk(name))
    assert rep.dest_independent


def test_gcn_struct_dependence_detected():
    rep = certify(_mk("gcn"))
    assert not rep.struct_independent


class _BadMean(GNNModel):
    """Undecoupled mean: ms_cbn not distributive (running mean) — must fail."""

    name = "badmean"

    def init_params(self, key, d_in, d_out):
        return {"W": jnp.eye(d_in, d_out)}

    def ms_local(self, p, h_u, h_v, s_u, s_v, ew, et):
        return jnp.ones_like(s_u)

    def edge_term(self, p, mlc, z, et):
        return mlc[:, None] * z

    def ms_cbn(self, p, nct, x):
        # non-distributive: sqrt of aggregated value
        return jnp.sqrt(jnp.abs(x) + 1.0)

    def ms_cbn_inv(self, p, nct, x):
        return x**2 - 1.0

    def update(self, p, h_v, a_v):
        return a_v @ p["W"]


class _UndeclaredGAT(GNNModel):
    """Destination-dependent message WITHOUT the dest_dependent flag — the
    registration gate must reject it (the paper's SMT-check failure mode)."""

    name = "undeclared"

    def init_params(self, key, d_in, d_out):
        return {"W": jnp.eye(d_in, d_out)}

    def ms_local(self, p, h_u, h_v, s_u, s_v, ew, et):
        return jnp.sum(h_u * h_v, -1)

    def edge_term(self, p, mlc, z, et):
        return mlc[:, None] * z

    def update(self, p, h_v, a_v):
        return a_v @ p["W"]


def test_bad_mean_rejected():
    with pytest.raises(ValueError, match="fails Theorem-1"):
        validate_registration(_BadMean())


def test_undeclared_dest_dependence_rejected():
    with pytest.raises(ValueError, match="destination-dependent"):
        validate_registration(_UndeclaredGAT())


@pytest.mark.parametrize("name", ALL_MODELS)
def test_full_forward_shapes_finite(name):
    model = _mk(name)
    g = CSRGraph.from_edges(
        10,
        np.array([0, 1, 2, 3, 4, 5, 6, 7, 8, 9]),
        np.array([1, 2, 3, 4, 5, 6, 7, 8, 9, 0]),
        np.random.default_rng(0).uniform(0.5, 1.5, 10).astype(np.float32),
        np.random.default_rng(0).integers(0, 3, 10).astype(np.int32),
    )
    x, _ = random_features(10, 6, seed=0)
    params = model.init_layers(jax.random.PRNGKey(0), [6, 8, 4])
    states = full_forward(model, params, jnp.asarray(x), g)
    assert states[-1].h.shape == (10, 4)
    assert states[0].h.shape == (10, 8)
    for st in states:
        assert bool(jnp.all(jnp.isfinite(st.h)))
        assert bool(jnp.all(jnp.isfinite(st.a)))


def test_gat_softmax_equals_reference():
    """Decoupled GAT (exp/sum/normalize) == direct softmax attention."""
    model = make_model("gat", heads=2)
    n, d = 12, 8
    rng = np.random.default_rng(0)
    src = np.array([i for i in range(n) for _ in range(3)]) % n
    dst = np.array([(i // 3 + j + 1) % n for i, j in
                    zip(range(3 * n), [0, 1, 2] * n)])
    key = dst * n + src
    _, idx = np.unique(key, return_index=True)
    src, dst = src[idx], dst[idx]
    g = CSRGraph.from_edges(n, src, dst)
    x = rng.normal(size=(n, d)).astype(np.float32)
    params = model.init_layers(jax.random.PRNGKey(3), [d, 8])
    st = full_forward(model, params, jnp.asarray(x), g)[-1]

    # direct dense softmax reference
    p = params[0]
    H, dh = 2, 4
    W = np.array(p["W"])
    wx = (x @ W).reshape(n, H, dh)
    logits = np.full((n, n, H), -np.inf, np.float32)
    for u, v in zip(src, dst):
        lg = (wx[u] * np.array(p["a_src"])).sum(-1) + (wx[v] * np.array(p["a_dst"])).sum(-1)
        lg = np.clip(np.where(lg > 0, lg, 0.2 * lg), -30, 30)
        logits[v, u] = lg
    att = np.exp(logits)
    att = att / np.maximum(att.sum(1, keepdims=True), 1e-10)
    out = np.einsum("vuh,uhd->vhd", np.nan_to_num(att), wx).reshape(n, H * dh)
    ref = np.where(out > 0, out, np.expm1(out))  # elu
    np.testing.assert_allclose(np.array(st.h), ref, atol=1e-4)
