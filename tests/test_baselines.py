"""Baselines: RTEC-Full / RTEC-UER correctness, RTEC-NS behaviour,
MTEC-Period staleness semantics, ODEC query mode, access-volume ordering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    RTECUER,
    MTECPeriod,
    RTECEngine,
    RTECFull,
    RTECSample,
    full_forward,
    make_model,
    odec_query,
)
from repro.graph import make_graph, make_stream
from repro.graph.generators import random_features

TOL = 2e-4


def _setup(name="sage", n=120, num_batches=3, seed=0):
    g = make_graph("powerlaw", n, avg_degree=6, seed=seed)
    x, _ = random_features(n, 8, seed=seed)
    wl = make_stream(g, num_batches=num_batches, batch_edges=10, delete_frac=0.3, seed=seed + 1)
    model = make_model(name)
    params = model.init_layers(jax.random.PRNGKey(seed), [8, 8, 8])
    return g, x, wl, model, params


def _final_ref(model, params, wl, x):
    g_cur = wl.base
    for b in wl.batches:
        g_cur = g_cur.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                                    b.ins_weights, b.ins_etypes)
    return full_forward(model, params, jnp.asarray(x), g_cur)[-1].h, g_cur


@pytest.mark.parametrize("cls", [RTECFull, RTECUER])
@pytest.mark.parametrize("name", ["sage", "gcn", "gat"])
def test_exact_baselines_match_full(cls, name):
    _, x, wl, model, params = _setup(name)
    bl = cls(model, params, wl.base, jnp.asarray(x))
    for b in wl.batches:
        bl.apply_batch(b)
    ref, _ = _final_ref(model, params, wl, x)
    err = float(jnp.abs(bl.embeddings - ref).max())
    assert err < TOL, f"{cls.__name__}/{name}: {err}"


def test_sampling_is_approximate_but_bounded():
    _, x, wl, model, params = _setup("sage")
    bl = RTECSample(model, params, wl.base, jnp.asarray(x), fanout=2, seed=0)
    for b in wl.batches:
        bl.apply_batch(b)
    ref, _ = _final_ref(model, params, wl, x)
    err = float(jnp.abs(bl.embeddings - ref).max())
    assert np.isfinite(err)
    # tiny fanout on a deg-6 graph should visibly deviate somewhere
    assert err > 1e-6


def test_mtec_period_stale_then_fresh():
    _, x, wl, model, params = _setup("sage", num_batches=4)
    bl = MTECPeriod(model, params, wl.base, jnp.asarray(x), period=4)
    ref0 = np.array(bl.embeddings)
    for b in wl.batches[:3]:
        bl.apply_batch(b)
    np.testing.assert_allclose(np.array(bl.embeddings), ref0, atol=1e-6)  # stale
    bl.apply_batch(wl.batches[3])  # period hit → refresh
    ref, _ = _final_ref(model, params, wl, x)
    assert float(jnp.abs(bl.embeddings - ref).max()) < TOL


def test_access_volume_ordering():
    """Paper Figs. 2/8: edges processed should order Inc < UER <= Full."""
    _, x, wl, model, params = _setup("sage", n=300, seed=3)
    inc = RTECEngine(model, params, wl.base, jnp.asarray(x))
    uer = RTECUER(model, params, wl.base, jnp.asarray(x))
    fn = RTECFull(model, params, wl.base, jnp.asarray(x))
    e_inc = e_uer = e_fn = 0
    for b in wl.batches:
        e_inc += inc.apply_batch(b).edges_processed
        e_uer += uer.apply_batch(b).edges_processed
        e_fn += fn.apply_batch(b).edges_processed
    assert e_inc < e_uer <= e_fn, (e_inc, e_uer, e_fn)


def test_odec_matches_committed_engine():
    _, x, wl, model, params = _setup("gcn", n=150, num_batches=1, seed=5)
    eng = RTECEngine(model, params, wl.base, jnp.asarray(x))
    q = np.array([3, 17, 42, 99], np.int64)
    emb_q, stats = odec_query(eng, wl.batches[0], q)
    # committed path
    eng.apply_batch(wl.batches[0])
    np.testing.assert_allclose(
        np.array(emb_q), np.array(eng.embeddings[jnp.asarray(q)]), atol=1e-5
    )
    # ODEC should process no more work than the full commit would
    assert stats.edges_processed <= eng.graph.num_edges


def test_odec_all_affected_reduces_to_rtec():
    _, x, wl, model, params = _setup("sage", n=100, num_batches=1, seed=6)
    eng = RTECEngine(model, params, wl.base, jnp.asarray(x))
    q = np.arange(100, dtype=np.int64)
    emb_q, _ = odec_query(eng, wl.batches[0], q)
    eng.apply_batch(wl.batches[0])
    np.testing.assert_allclose(np.array(emb_q), np.array(eng.embeddings), atol=1e-5)
