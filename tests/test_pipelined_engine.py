"""Pipelined-engine invariants: the packed-plan / fused / donated / overlapped
hot path must be numerically indistinguishable from the unfused seed engine
(and from full recomputation), and the CI perf gate logic must be sound.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.check_regression import (
    EXIT_MISSING,
    EXIT_OK,
    EXIT_REGRESSION,
    SPECS,
    check,
    check_exact,
    check_spec,
    check_volume,
    read_metric,
    read_speedup,
)
from repro.core import RTECEngine, full_forward, make_model
from repro.core.affected import (
    FLT_FIELDS,
    IDX_FIELDS,
    MSK_FIELDS,
    build_plan,
    layout_slices,
    pack_plan,
)
from repro.graph import make_graph, make_stream
from repro.graph.generators import random_features
from repro.graph.streaming import UpdateBatch

TOL = 2e-4


def _mk_stream(n=150, num_batches=20, seed=0, feature_dim=None):
    g = make_graph("powerlaw", n, avg_degree=5, seed=seed, weighted=True)
    x, _ = random_features(n, 8, seed=seed)
    kw = dict(feature_dim=feature_dim, feature_frac=0.02) if feature_dim else {}
    wl = make_stream(g, num_batches=num_batches, batch_edges=8, delete_frac=0.35,
                     seed=seed + 1, **kw)
    return x, wl


# ---------------------------------------------------------------------- #
# packed plans
# ---------------------------------------------------------------------- #
def test_pack_plan_roundtrip():
    """Every LayerPlan field must slice back bit-identically out of the
    three packed buffers via the static offset table."""
    x, wl = _mk_stream(n=100, num_batches=1, seed=3)
    model = make_model("gcn")
    b = wl.batches[0]
    g_new = wl.base.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                                  b.ins_weights, b.ins_etypes)
    plan = build_plan(model, wl.base, g_new, b, 2)
    packed = pack_plan(plan, b.feat_vertices, b.feat_values)
    idx_sl, flt_sl, msk_sl, (ni, nf, nm) = layout_slices(packed.layout)
    assert packed.idx.shape == (ni,) and packed.flt.shape == (nf,)
    assert packed.msk.shape == (nm,)
    n = wl.base.n
    np.testing.assert_array_equal(packed.flt[: n + 1], plan.deg_old)
    np.testing.assert_array_equal(packed.flt[n + 1 : 2 * (n + 1)], plan.deg_new)
    for l, lp in enumerate(plan.layers):
        for name, _ in IDX_FIELDS:
            np.testing.assert_array_equal(packed.idx[idx_sl[l][name]], getattr(lp, name))
        for name, _ in FLT_FIELDS:
            np.testing.assert_array_equal(packed.flt[flt_sl[l][name]], getattr(lp, name))
        for name, _ in MSK_FIELDS:
            np.testing.assert_array_equal(packed.msk[msk_sl[l][name]], getattr(lp, name))


def test_packed_layout_is_static_and_cached():
    x, wl = _mk_stream(n=100, num_batches=1, seed=4)
    model = make_model("gcn")
    b = wl.batches[0]
    g_new = wl.base.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                                  b.ins_weights, b.ins_etypes)
    plan = build_plan(model, wl.base, g_new, b, 2)
    p1 = pack_plan(plan)
    p2 = pack_plan(plan)
    assert p1.layout == p2.layout and hash(p1.layout) == hash(p2.layout)
    assert layout_slices(p1.layout) is layout_slices(p2.layout)  # lru_cache hit


# ---------------------------------------------------------------------- #
# fused engine ≡ unfused seed engine (the PR's acceptance invariant)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["gcn", "gat"])  # unconstrained + constrained
def test_fused_equals_unfused_20_batches(name):
    x, wl = _mk_stream(n=150, num_batches=20, seed=7, feature_dim=8)
    model = make_model(name)
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8, 8])
    fused = RTECEngine(model, params, wl.base, jnp.asarray(x), fused=True)
    seed_eng = RTECEngine(model, params, wl.base, jnp.asarray(x), fused=False)
    for b in wl.batches:
        fused.apply_batch(b)
        seed_eng.apply_batch(b)
    assert float(jnp.abs(fused.embeddings - seed_eng.embeddings).max()) < TOL
    for l in range(2):
        assert float(jnp.abs(fused.a[l] - seed_eng.a[l]).max()) < TOL
        assert float(jnp.abs(fused.nct[l] - seed_eng.nct[l]).max()) < TOL


def test_fused_matches_full_forward():
    x, wl = _mk_stream(n=120, num_batches=6, seed=9)
    model = make_model("sage")
    params = model.init_layers(jax.random.PRNGKey(1), [8, 8, 8])
    eng = RTECEngine(model, params, wl.base, jnp.asarray(x))
    g_cur = wl.base
    for b in wl.batches:
        eng.apply_batch(b)
        g_cur = g_cur.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                                    b.ins_weights, b.ins_etypes)
    ref = full_forward(model, params, jnp.asarray(x), g_cur)
    assert float(jnp.abs(eng.embeddings - ref[-1].h).max()) < TOL


def test_fused_store_h_false():
    """§V-B recompute mode must survive the fused/donated path."""
    x, wl = _mk_stream(n=100, num_batches=5, seed=11)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(2), [8, 8, 8])
    e1 = RTECEngine(model, params, wl.base, jnp.asarray(x), store_h=True)
    e2 = RTECEngine(model, params, wl.base, jnp.asarray(x), store_h=False)
    for b in wl.batches:
        e1.apply_batch(b)
        e2.apply_batch(b)
    assert float(jnp.abs(e1.embeddings - e2.embeddings).max()) < TOL


def test_fused_empty_batch_noop():
    g = make_graph("uniform", 60, avg_degree=4, seed=0)
    x, _ = random_features(60, 6, seed=0)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), [6, 6, 6])
    eng = RTECEngine(model, params, g, jnp.asarray(x))
    before = np.array(eng.embeddings)
    empty = UpdateBatch(
        ins_src=np.zeros(0, np.int64), ins_dst=np.zeros(0, np.int64),
        del_src=np.zeros(0, np.int64), del_dst=np.zeros(0, np.int64),
        ins_weights=np.zeros(0, np.float32), ins_etypes=np.zeros(0, np.int32),
    )
    stats = eng.apply_batch(empty)
    assert stats.edges_processed == 0
    np.testing.assert_allclose(np.array(eng.embeddings), before, atol=1e-6)


# ---------------------------------------------------------------------- #
# Pallas delta-scatter flag (interpret mode on CPU)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["gcn", "gat"])
def test_pallas_delta_flag_equivalence(name):
    """The fused step with the host-planned delta_agg kernel schedule must
    match the XLA segment-sum fallback exactly (CPU: interpret=True)."""
    x, wl = _mk_stream(n=100, num_batches=4, seed=13)
    model = make_model(name)
    params = model.init_layers(jax.random.PRNGKey(3), [8, 8, 8])
    xla = RTECEngine(model, params, wl.base, jnp.asarray(x), use_pallas_delta=False)
    pal = RTECEngine(model, params, wl.base, jnp.asarray(x), use_pallas_delta=True)
    for b in wl.batches:
        xla.apply_batch(b)
        pal.apply_batch(b)
    assert float(jnp.abs(xla.embeddings - pal.embeddings).max()) < TOL
    for l in range(2):
        assert float(jnp.abs(xla.a[l] - pal.a[l]).max()) < TOL


def test_pallas_schedule_shapes_bucketed():
    """The block-CSR schedule must come out in pow-2 block-count buckets —
    data-dependent schedule shapes would force a fused-step recompile on
    nearly every batch (one trace per PackedLayout is the contract)."""
    from repro.kernels.delta_agg import DELTA_BE

    x, wl = _mk_stream(n=150, num_batches=8, seed=31)
    model = make_model("gcn")
    g_cur = wl.base
    shapes = set()
    for b in wl.batches:
        g_new = g_cur.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                                    b.ins_weights, b.ins_etypes)
        plan = build_plan(model, g_cur, g_new, b, 2)
        packed = pack_plan(plan, pallas=True)
        for perm, dloc, brows in packed.pallas:
            assert perm.shape[0] % DELTA_BE == 0
            assert perm.shape[0] & (perm.shape[0] - 1) == 0  # power of two
            assert brows.shape[0] == perm.shape[0] // DELTA_BE
            assert np.all(np.diff(brows) >= 0)
            shapes.add((perm.shape[0], packed.layout.caps))
        g_cur = g_new
    # pow-2 bucketing keeps the distinct (schedule, layout) shape count low
    assert len(shapes) <= 2 * len(wl.batches)


# ---------------------------------------------------------------------- #
# plan/execute overlap
# ---------------------------------------------------------------------- #
def test_apply_stream_equals_apply_batch():
    x, wl = _mk_stream(n=150, num_batches=10, seed=17, feature_dim=8)
    model = make_model("gat")
    params = model.init_layers(jax.random.PRNGKey(4), [8, 8, 8])
    seq = RTECEngine(model, params, wl.base, jnp.asarray(x))
    pipe = RTECEngine(model, params, wl.base, jnp.asarray(x))
    for b in wl.batches:
        seq.apply_batch(b)
    ss = pipe.apply_stream(wl.batches)
    np.testing.assert_allclose(np.array(seq.embeddings), np.array(pipe.embeddings),
                               atol=1e-6)
    assert len(ss.batches) == len(wl.batches)
    assert ss.wall_s > 0 and ss.plan_s > 0
    assert all(b.edges_processed >= 0 for b in ss.batches)
    assert ss.mean_batch_s > 0


def test_apply_stream_with_refresh():
    x, wl = _mk_stream(n=100, num_batches=6, seed=19)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(5), [8, 8, 8])
    seq = RTECEngine(model, params, wl.base, jnp.asarray(x), refresh_every=3)
    pipe = RTECEngine(model, params, wl.base, jnp.asarray(x), refresh_every=3)
    for b in wl.batches:
        seq.apply_batch(b)
    pipe.apply_stream(wl.batches)
    np.testing.assert_allclose(np.array(seq.embeddings), np.array(pipe.embeddings),
                               atol=1e-6)


def test_offload_apply_stream_equivalence():
    """The offload engine's overlapped stream path (deferred final
    write-back) must match both its own sequential path and the in-memory
    engine bit-for-bit."""
    from repro.serve.offload import OffloadedRTECEngine

    x, wl = _mk_stream(n=120, num_batches=5, seed=29, feature_dim=8)
    model = make_model("gat")
    params = model.init_layers(jax.random.PRNGKey(7), [8, 8, 8])
    mem = RTECEngine(model, params, wl.base, jnp.asarray(x))
    off_seq = OffloadedRTECEngine(model, params, wl.base, x)
    off_pipe = OffloadedRTECEngine(model, params, wl.base, x)
    for b in wl.batches:
        mem.apply_batch(b)
        off_seq.apply_batch(b)
    ss = off_pipe.apply_stream(wl.batches)
    # satellite fix (ISSUE 4): the offload engine returns the same
    # StreamStats as every other engine (wall_s / plan_s), not a bare list
    assert len(ss.batches) == len(wl.batches)
    assert ss.wall_s > 0 and ss.plan_s > 0
    np.testing.assert_array_equal(off_seq.embeddings, off_pipe.embeddings)
    np.testing.assert_allclose(np.asarray(mem.embeddings), off_pipe.embeddings,
                               atol=1e-6)


def test_batch_stats_honest_timing():
    """apply_batch(block=True) syncs at the boundary: exec_time_s of a real
    batch must be positive and the stats fields populated."""
    x, wl = _mk_stream(n=100, num_batches=2, seed=23)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(6), [8, 8, 8])
    eng = RTECEngine(model, params, wl.base, jnp.asarray(x))
    st = eng.apply_batch(wl.batches[0])
    assert st.exec_time_s > 0 and st.plan_time_s > 0 and st.graph_time_s > 0
    assert st.out_vertices > 0


# ---------------------------------------------------------------------- #
# CI perf gate logic
# ---------------------------------------------------------------------- #
def test_check_regression_logic():
    assert check(1.5, 1.5, floor=1.2, tolerance=0.2) == []
    assert check(1.3, 1.5, floor=1.2, tolerance=0.2) == []  # within tolerance
    assert len(check(1.0, 1.5, floor=1.2, tolerance=0.2)) == 2  # floor + rel
    assert len(check(1.21, 2.0, floor=1.2, tolerance=0.2)) == 1  # rel only
    assert check(1.3, None, floor=1.2, tolerance=0.2) == []  # no baseline


def test_check_regression_volume_logic():
    """Volume metrics gate in the opposite direction: growth is regression."""
    m = "fig7/smoke/gcn/offload_transfer_rows"
    assert check_volume(3000, 3000, ceiling=20000, tolerance=0.1, metric=m) == []
    assert check_volume(3200, 3000, ceiling=20000, tolerance=0.1, metric=m) == []
    assert len(check_volume(3400, 3000, ceiling=20000, tolerance=0.1, metric=m)) == 1
    assert len(check_volume(25000, 3000, ceiling=20000, tolerance=0.1, metric=m)) == 2
    assert check_volume(3400, None, ceiling=20000, tolerance=0.1, metric=m) == []


def test_check_regression_metric_matrix_specs():
    """Every spec must be internally consistent and dispatch correctly."""
    assert len(SPECS) >= 5  # gcn + gat + offload volume + overlap counters
    for spec in SPECS:
        if spec.kind == "speedup":
            assert spec.floor is not None
            assert check_spec(spec, spec.floor + 1.0, None) == []
            assert check_spec(spec, spec.floor - 0.5, None) != []
        elif spec.kind == "volume":
            assert spec.ceiling is not None
            assert check_spec(spec, spec.ceiling - 1.0, None) == []
            assert check_spec(spec, spec.ceiling + 1.0, None) != []
        else:
            assert spec.kind == "exact"
            assert check_spec(spec, 5.0, None, derived="expect_5") == []
            assert check_spec(spec, 4.0, None, derived="expect_5") != []


def test_check_regression_exact_logic():
    """Exact counters: must match the emitted expectation and the baseline
    bit-for-bit — the overlap gate has zero tolerance by design."""
    m = "fig7/smoke/gcn/offload_prefetch_hits"
    assert check_exact(5.0, "expect_5", 5.0, m) == []
    assert check_exact(5.0, "expect_5", None, m) == []
    assert len(check_exact(4.0, "expect_5", 4.0, m)) == 1  # misses expectation
    assert len(check_exact(5.0, "expect_5", 4.0, m)) == 1  # baseline drifted
    assert len(check_exact(4.0, "expect_5", 5.0, m)) == 2
    # a row emitted without its expectation is an emitting-cell bug
    assert len(check_exact(5.0, "5hits", 5.0, m)) == 1


def test_check_regression_exit_codes(tmp_path):
    """Distinct exit codes (ISSUE 5 noise-retry bugfix): 1 = regression
    (CI may retry once against runner noise), 2 = gated metric never
    emitted (CI must NOT retry — re-measuring can't conjure the metric)."""
    import json
    import subprocess
    import sys
    from pathlib import Path

    repo = Path(__file__).resolve().parents[1]
    base = repo / "BENCH_baseline.json"

    def run_gate(rows):
        art = tmp_path / "current.json"
        art.write_text(json.dumps({"rows": rows, "wall_s": 1.0}))
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.check_regression",
             "--current", str(art), "--baseline", str(base)],
            capture_output=True, text=True, cwd=repo, timeout=120,
        )
        return proc.returncode, proc.stderr

    good = json.loads(base.read_text())["rows"]
    code, _ = run_gate(good)
    assert code == EXIT_OK
    # regress the headline speedup below its floor → exit 1 (retryable)
    rows = [r for r in good if not r.startswith(
        "fig7/smoke/gcn/inc_speedup_vs_full,")]
    rows.append("fig7/smoke/gcn/inc_speedup_vs_full,9999.0,0.50x")
    code, err = run_gate(rows)
    assert code == EXIT_REGRESSION, err
    # drop a gated metric entirely → exit 2 (never retried)
    rows = [r for r in good if not r.startswith(
        "fig7/smoke/gcn/offload_prefetch_hits,")]
    code, err = run_gate(rows)
    assert code == EXIT_MISSING, err
    assert "MISSING" in err
    # an exact row that lost its expect_<v> expectation is a broken
    # emitting cell, not a regression → also exit 2, never retried
    rows = [r for r in good if not r.startswith(
        "fig7/smoke/gcn/offload_prefetch_hits,")]
    rows.append("fig7/smoke/gcn/offload_prefetch_hits,5.0,5hits")
    code, err = run_gate(rows)
    assert code == EXIT_MISSING, err


def test_check_regression_reads_artifact(tmp_path):
    import json

    art = tmp_path / "BENCH_smoke.json"
    art.write_text(json.dumps({
        "rows": [
            "fig7/smoke/gcn/full,5000.0,",
            "fig7/smoke/gcn/inc,2500.0,",
            "fig7/smoke/gcn/inc_speedup_vs_full,2500.0,2.00x",
            "fig7/smoke/gcn/offload_transfer_rows,2970.0,2970rows",
        ],
        "wall_s": 1.0,
    }))
    assert read_speedup(str(art)) == 2.0
    assert read_metric(str(art), "fig7/smoke/gcn/offload_transfer_rows",
                       "volume") == 2970.0
    with pytest.raises(KeyError):
        read_speedup(str(art), metric="missing/metric")


def test_committed_baseline_covers_all_gate_metrics():
    """BENCH_baseline.json must contain every gated metric — of both the
    smoke and the sharded suite — a spec without a committed baseline
    silently degrades to absolute-bound-only."""
    from pathlib import Path

    from benchmarks.check_regression import SUITES

    base = Path(__file__).resolve().parents[1] / "BENCH_baseline.json"
    for suite in SUITES.values():
        for spec in suite:
            read_metric(str(base), spec.name, spec.kind)
