"""Distribution: sharding rule system (unit), pipeline + sharded train step
(subprocess with 8 forced host devices — env must be set pre-jax-init)."""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import ShardingConfig, auto_spec, spec_for_axes


def test_spec_for_axes_rules():
    sh = ShardingConfig(fsdp=True, dp_axes=("data",))
    rules = sh.rules()
    assert spec_for_axes(("embed", "heads"), rules) == P("data", "model")
    assert spec_for_axes(("layers", "embed", "mlp"), rules) == P(None, "data", "model")
    assert spec_for_axes((None,), rules) == P(None)


def test_spec_no_duplicate_mesh_axes():
    sh = ShardingConfig(fsdp=False)
    rules = sh.rules()
    # two logical dims mapping to "model": only the first gets it
    assert spec_for_axes(("heads", "mlp"), rules) == P("model", None)


def test_auto_spec_divisibility(monkeypatch):
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.zeros((4, 8))

    sh = ShardingConfig(dp_axes=("data",))
    assert auto_spec((16, 64), FakeMesh(), sh, batch_dim=0) == P("data", "model")
    # batch not divisible by data=4 → dp moves to another divisible dim
    assert auto_spec((3, 64), FakeMesh(), sh, batch_dim=0)[0] is None
    # nothing divisible → fully replicated
    assert auto_spec((3, 5), FakeMesh(), sh, batch_dim=0) == P(None, None)


_SUBPROCESS_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
"""


def _run_sub(body: str) -> str:
    code = _SUBPROCESS_PRELUDE + textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=Path(__file__).resolve().parents[1], timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_pipeline_matches_sequential_subprocess():
    print(_run_sub("""
    from repro.dist.pipeline import pipeline_apply, sequential_reference
    mesh = jax.make_mesh((4, 2), ("stage", "model"))
    S, D = 4, 16
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (S, D, D)) * 0.3}
    def block(p, x):
        return jnp.tanh(x @ p["w"])
    x = jax.random.normal(jax.random.PRNGKey(1), (16, D))
    ref = sequential_reference(block, params, x)
    out = pipeline_apply(block, params, x, mesh, "stage", num_micro=4)
    err = float(jnp.abs(out - ref).max())
    assert err < 1e-5, err
    print("pipeline ok", err)
    """))


def test_sharded_train_step_subprocess():
    """FSDP+TP train step on a tiny llama over a 2x4 mesh: runs, loss finite,
    and params stay correctly sharded."""
    print(_run_sub("""
    import dataclasses
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_arch, reduced_config
    from repro.configs.base import ShapeConfig
    from repro.launch.steps import make_train_step, shardings_for_cell
    from repro.train.optimizer import OptConfig, adamw_init
    from repro.models import init_model
    from repro.dist.ctx import activation_sharding

    cfg = dataclasses.replace(
        reduced_config(get_arch("llama3.2-1b")),
        num_layers=2, d_model=32, d_ff=64, num_heads=4, num_kv_heads=2,
        head_dim=8, vocab_size=128,
    )
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    shape = ShapeConfig("tiny", 16, 8, "train")
    sh = shardings_for_cell(cfg, shape, mesh)
    step = make_train_step(cfg, OptConfig(warmup_steps=1, stable_steps=10, decay_steps=1))
    with activation_sharding(mesh, sh["shcfg"]):
        jitted = jax.jit(step, in_shardings=(sh["params_sharding"], sh["opt_sharding"], sh["batch_sharding"]))
        params, _ = init_model(jax.random.PRNGKey(0), cfg)
        params = jax.device_put(params, sh["params_sharding"])
        opt = jax.device_put(adamw_init(params), sh["opt_sharding"])
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jax.device_put(jnp.asarray(rng.integers(0, 128, (8, 16))), sh["batch_sharding"]["tokens"]),
            "labels": jax.device_put(jnp.asarray(rng.integers(0, 128, (8, 16))), sh["batch_sharding"]["labels"]),
        }
        p2, o2, m = jitted(params, opt, batch)
    loss = float(m["loss"])
    assert np.isfinite(loss), loss
    # a second step must also run (state shardings round-trip)
    p3, o3, m2 = jitted(p2, o2, batch)
    assert float(m2["loss"]) < loss + 1.0
    emb = p2["embed"]
    assert emb.sharding.spec == P("model", "data"), emb.sharding
    print("sharded train ok", loss, float(m2["loss"]))
    """))


def test_serve_step_sharded_subprocess():
    print(_run_sub("""
    import dataclasses
    from repro.configs import get_arch, reduced_config
    from repro.configs.base import ShapeConfig
    from repro.launch.steps import make_serve_step, shardings_for_cell
    from repro.models import init_model, init_cache

    cfg = dataclasses.replace(
        reduced_config(get_arch("qwen2.5-3b")),
        num_layers=2, d_model=32, d_ff=64, num_heads=4, num_kv_heads=2,
        head_dim=8, vocab_size=128,
    )
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    shape = ShapeConfig("tinydec", 64, 8, "decode")
    sh = shardings_for_cell(cfg, shape, mesh)
    step = make_serve_step(cfg)
    jitted = jax.jit(step, in_shardings=(sh["params_sharding"], sh["cache_sharding"], sh["token_sharding"]))
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    params = jax.device_put(params, sh["params_sharding"])
    cache = jax.device_put(init_cache(cfg, 8, 64), sh["cache_sharding"])
    tok = jax.device_put(jnp.ones((8, 1), jnp.int32), sh["token_sharding"])
    logits, cache2 = jitted(params, cache, tok)
    assert logits.shape == (8, 1, 128)
    assert bool(jnp.all(jnp.isfinite(logits)))
    print("sharded serve ok")
    """))
