"""Device hot-row cache (ISSUE 8) + the redesigned single engine API.

Covers the tentpole and its API front: deterministic admission/eviction
slot mechanics, the plan-time [cached | miss] residency split, bitwise
cached≡uncached equivalence on both host-resident backends × async flag
× gcn/gat over 20-batch streams, the exact hub_burst counters the CI
gates pin (shared table: benchmarks.check_regression.CACHE_EXPECTED),
value-independent invalidation across policy-forced full recompute,
versioned snapshot reads with the cache enabled, the documented
StreamStats key namespace, and factory-vs-deprecated-alias parity for
every backend.
"""
from __future__ import annotations

import warnings

import jax
import numpy as np
import pytest

from repro.core import make_model
from repro.core.affected import split_residency
from repro.core.backend import STREAM_STAT_KEYS, StreamStats
from repro.graph import make_adversarial_stream, make_graph, make_stream
from repro.graph.generators import random_features
from repro.serve import (
    BACKENDS,
    CacheConfig,
    EngineConfig,
    HotRowCache,
    ServingFrontend,
    StagingConfig,
    create_engine,
)


def _mk_stream(n=120, num_batches=20, seed=0, feature_dim=8, batch_edges=8):
    g = make_graph("powerlaw", n, avg_degree=5, seed=seed, weighted=True)
    x, _ = random_features(n, 8, seed=seed)
    wl = make_stream(g, num_batches=num_batches, batch_edges=batch_edges,
                     delete_frac=0.35, seed=seed + 1,
                     feature_dim=feature_dim, feature_frac=0.02)
    return x, wl


def _cfg(model, wl, x, params, **kw) -> EngineConfig:
    return EngineConfig(model=model, graph=wl.base, x=x, params=params, **kw)


# ---------------------------------------------------------------------- #
# CacheConfig + slot-table mechanics (unit)
# ---------------------------------------------------------------------- #
def test_cache_config_validation():
    with pytest.raises(ValueError, match="capacity_rows"):
        CacheConfig(capacity_rows=0)
    with pytest.raises(ValueError, match="admission"):
        CacheConfig(admission="lru")
    with pytest.raises(ValueError, match="prewarm_rows"):
        CacheConfig(prewarm_rows=-1)
    with pytest.raises(ValueError, match="decay"):
        CacheConfig(decay=1.0)
    with pytest.raises(ValueError, match="decay"):
        CacheConfig(decay=-0.1)
    cfg = CacheConfig()
    assert cfg.enabled is True
    assert cfg.prewarm_rows == 0 and cfg.decay == 0.0


def test_lfu_decay_lets_new_hot_rows_evict_stale_hubs():
    """The ISSUE-9 bugfix: without decay, an early hub's frequency count
    is unbeatable forever; with decay it ages below a newly hot row's."""
    def run(decay, ticks):
        cache = HotRowCache(CacheConfig(capacity_rows=1, decay=decay))
        key, n = ("h", 0), 16
        cache.plan_reads(key, n, np.array([5]), np.zeros(1))  # freq[5] = 1
        cache.plan_reads(key, n, np.array([5]), np.zeros(1))  # freq[5] = 2
        for _ in range(ticks):
            cache.decay_tick()
        cache.plan_reads(key, n, np.array([9]), np.zeros(1))  # freq[9] = 1
        return cache
    stale = run(decay=0.0, ticks=3)
    assert stale.stats.evictions == 0  # 1 > 2 never holds: hub pinned
    aged = run(decay=0.5, ticks=3)
    assert aged.stats.evictions == 1  # freq[5] aged to 0.25 < 1
    sp = aged._spaces[("h", 0)]
    assert sp.slot_of[9] >= 0 and sp.slot_of[5] < 0
    # decay=0.0 ticks are strict no-ops: small-integer counters stay exact
    np.testing.assert_array_equal(stale._spaces[("h", 0)].freq[[5, 9]],
                                  [2.0, 1.0])


def test_prewarm_seeds_slots_and_serves_batch0_hits():
    """prewarm() runs the ordinary touch→admit pipeline and fills the
    admitted slots' stores, so the first plan_reads over those rows hits."""
    cache = HotRowCache(CacheConfig(capacity_rows=4, prewarm_rows=4))
    key, n = ("h", 0), 32
    top = np.array([7, 3, 11, 20], np.int64)  # backend's top-degree rows
    vals = np.arange(4 * 8, dtype=np.float32).reshape(4, 8)
    cache.prewarm(key, n, top, np.array([9.0, 8.0, 7.0, 6.0]), {"h": vals})
    assert cache.stats.admitted_rows == 4 and cache.stats.hit_rows == 0
    sp = cache.plan_reads(key, n, np.array([3, 7, 19]), np.zeros(3))
    np.testing.assert_array_equal(sp.miss_rows, [19])
    assert cache.stats.hit_rows == 2
    # the store holds the gathered pre-batch values at the assigned slots
    st = np.asarray(cache.store(key, "h", (8,)))
    space = cache._spaces[key]
    np.testing.assert_array_equal(st[space.slot_of[7]], vals[0])
    np.testing.assert_array_equal(st[space.slot_of[20]], vals[3])


@pytest.mark.parametrize("kind", ["offload", "sharded_offload"])
def test_prewarm_bitwise_equal_with_warmer_counters(kind):
    """Engine-level prewarm (ISSUE 9): seeding the slot table from the
    base graph's top-degree rows changes WHEN rows become resident, never
    what the kernels compute — embeddings stay bitwise while batch-0
    misses turn into hits."""
    x, wl = _mk_stream(n=120, num_batches=10, seed=5)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    shards = {"num_shards": jax.device_count()} if kind != "offload" else {}
    runs = {}
    for pw in (0, 48):
        eng = create_engine(kind, _cfg(
            model, wl, x, params,
            cache=CacheConfig(capacity_rows=64, prewarm_rows=pw), **shards))
        ss = eng.apply_stream(wl.batches)
        runs[pw] = (eng, ss.as_dict())
    cold, d0 = runs[0]
    warm, d1 = runs[48]
    np.testing.assert_array_equal(np.asarray(cold.embeddings),
                                  np.asarray(warm.embeddings))
    assert d1["cache_hit_rows"] > d0["cache_hit_rows"]
    assert d1["cache_miss_rows"] < d0["cache_miss_rows"]
    # prewarm is deterministic: an identical run reproduces the counters
    again = create_engine(kind, _cfg(
        model, wl, x, params,
        cache=CacheConfig(capacity_rows=64, prewarm_rows=48), **shards))
    d2 = again.apply_stream(wl.batches).as_dict()
    for k in ("cache_hit_rows", "cache_miss_rows", "cache_evictions"):
        assert d1[k] == d2[k]


def test_decay_stays_bitwise_on_embeddings():
    """LFU decay reshapes residency (counters move) but the cache stays
    invisible to the math."""
    x, wl = _mk_stream(n=120, num_batches=12, seed=5)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    runs = {}
    for dc in (0.0, 0.5):
        eng = create_engine("offload", _cfg(
            model, wl, x, params,
            cache=CacheConfig(capacity_rows=32, decay=dc)))
        ss = eng.apply_stream(wl.batches)
        runs[dc] = (np.asarray(eng.embeddings), ss.as_dict())
    np.testing.assert_array_equal(runs[0.0][0], runs[0.5][0])
    # decay=0.0 reproduces the default config's counters exactly
    eng = create_engine("offload", _cfg(
        model, wl, x, params, cache=CacheConfig(capacity_rows=32)))
    d_default = eng.apply_stream(wl.batches).as_dict()
    for k in ("cache_hit_rows", "cache_miss_rows", "cache_evictions"):
        assert runs[0.0][1][k] == d_default[k]


def test_split_residency_exclusion():
    slot_of = np.full(10, -1, np.int32)
    slot_of[[2, 5, 7]] = [0, 1, 2]
    rows = np.array([2, 3, 5, 7, 9], np.int64)
    sp = split_residency(rows, slot_of)
    np.testing.assert_array_equal(sp.hit_pos, [0, 2, 3])
    np.testing.assert_array_equal(sp.hit_slots, [0, 1, 2])
    np.testing.assert_array_equal(sp.miss_pos, [1, 4])
    np.testing.assert_array_equal(sp.miss_rows, [3, 9])
    # excluded rows miss even when cached (their slots are stale mid-batch)
    sp = split_residency(rows, slot_of, exclude_rows=np.array([5], np.int64))
    np.testing.assert_array_equal(sp.hit_pos, [0, 3])
    np.testing.assert_array_equal(sp.miss_rows, [3, 5, 9])


def test_admission_fills_hottest_first_then_evicts_strictly_hotter():
    cache = HotRowCache(CacheConfig(capacity_rows=2))
    key, n = ("h", 0), 10
    deg = np.zeros(3)
    # freq becomes 1 for rows {1,2,3}; all miss, 2 slots → the two
    # smallest rows win the tie (equal priority, ties to smallest row)
    sp = cache.plan_reads(key, n, np.array([1, 2, 3]), deg)
    assert sp.hit_pos.size == 0 and sp.miss_pos.size == 3
    np.testing.assert_array_equal(sp.admit_midx, [0, 1])
    assert cache.stats.admitted_rows == 2 and cache.stats.evictions == 0
    # a second touch makes row 3 strictly hotter (freq 2 > 1): it must
    # evict the coldest incumbent (row 1, smallest-row victim tie-break)
    sp = cache.plan_reads(key, n, np.array([3]), np.zeros(1))
    assert cache.stats.evictions == 1
    assert sp.admit_midx.size == 1  # 3 admitted on this read
    # rows 2,3 cached now; 1 misses
    sp = cache.plan_reads(key, n, np.array([1, 2, 3]), deg, admit=False)
    np.testing.assert_array_equal(sp.miss_rows, [1])
    np.testing.assert_array_equal(sp.hit_pos, [1, 2])


def test_degree_weighted_admission_prefers_hubs():
    cache = HotRowCache(CacheConfig(capacity_rows=1, admission="freq_degree"))
    key, n = ("h", 0), 10
    # equal frequency, row 7 has 50x the plan degree → it wins the slot
    sp = cache.plan_reads(key, n, np.array([2, 7]), np.array([1.0, 50.0]))
    np.testing.assert_array_equal(sp.admit_midx, [1])
    sp = cache.plan_reads(key, n, np.array([2, 7]), np.array([1.0, 50.0]),
                          admit=False)
    np.testing.assert_array_equal(sp.hit_pos, [1])
    # pure-freq admission ignores degree: first-touch tie goes to row 2
    cache = HotRowCache(CacheConfig(capacity_rows=1, admission="freq"))
    sp = cache.plan_reads(key, n, np.array([2, 7]), np.array([1.0, 50.0]))
    np.testing.assert_array_equal(sp.admit_midx, [0])


def test_invalidate_frees_slots_and_keeps_free_list_deterministic():
    cache = HotRowCache(CacheConfig(capacity_rows=4))
    key, n = ("s", 1), 16
    cache.plan_reads(key, n, np.arange(4), np.zeros(4))
    assert cache.stats.admitted_rows == 4
    cache.invalidate(key, np.array([1, 3]))
    assert cache.stats.invalidated_rows == 2
    sp = cache.plan_reads(key, n, np.arange(4), np.zeros(4), admit=False)
    np.testing.assert_array_equal(sp.miss_rows, [1, 3])
    # freed slots readmit smallest-slot-first (grow-only determinism)
    sp = cache.plan_reads(key, n, np.array([8, 9]), np.zeros(2))
    np.testing.assert_array_equal(np.sort(sp.admit_slots), [1, 3])
    cache.invalidate_all()
    assert cache._spaces == {}
    assert cache.stats.invalidated_rows == 6  # 2 targeted + 4 occupied


def test_writeback_admits_uncached_written_rows():
    cache = HotRowCache(CacheConfig(capacity_rows=8))
    key, n = ("h", 1), 32
    pos, slots = cache.plan_writeback(key, n, np.array([4, 9]), np.zeros(2))
    np.testing.assert_array_equal(pos, [0, 1])  # both admitted (free slots)
    sp = cache.plan_reads(key, n, np.array([4, 9]), np.zeros(2), admit=False)
    assert sp.miss_pos.size == 0
    np.testing.assert_array_equal(np.sort(sp.hit_slots), np.sort(slots))


# ---------------------------------------------------------------------- #
# cached ≡ uncached, bitwise: backends × async flag × gcn/gat, 20 batches
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("kind", ["offload", "sharded_offload"])
@pytest.mark.parametrize("name", ["gcn", "gat"])  # unconstrained + constrained
@pytest.mark.parametrize("async_staging", [False, True])
def test_cached_bitwise_equals_uncached_20_batches(kind, name, async_staging):
    """The cache must be invisible to the math: identical kernels run over
    cache-assembled workspaces, so embeddings AND per-layer host state
    match bitwise, while the staged-byte volume strictly shrinks."""
    x, wl = _mk_stream(n=120, num_batches=20, seed=5)
    model = make_model(name)
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    shards = {"num_shards": jax.device_count()} if kind != "offload" else {}
    runs = {}
    for cached in (False, True):
        eng = create_engine(kind, _cfg(
            model, wl, x, params,
            staging=StagingConfig(async_enabled=async_staging),
            cache=CacheConfig(capacity_rows=64) if cached else None,
            **shards))
        ss = eng.apply_stream(wl.batches)
        runs[cached] = (eng, ss.as_dict())
    base, d0 = runs[False]
    hot, d1 = runs[True]
    np.testing.assert_array_equal(np.asarray(base.embeddings),
                                  np.asarray(hot.embeddings))
    for hu, hc in zip(base.h, hot.h):
        np.testing.assert_array_equal(np.asarray(hu), np.asarray(hc))
    assert d0["cache_hit_rows"] == 0 and d0["cache_miss_rows"] == 0
    assert d1["cache_hit_rows"] > 0
    assert d1["staged_bytes"] < d0["staged_bytes"]
    snap = hot._backend.cache_snapshot()
    assert snap.hit_rows == d1["cache_hit_rows"]
    assert snap.evictions == d1["cache_evictions"]


def test_cache_counters_deterministic_across_async_modes():
    """Residency is planned host-side from the batch plans only, so the
    counters cannot depend on staging concurrency."""
    x, wl = _mk_stream(n=120, num_batches=12, seed=5)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    counts = []
    for async_staging in (False, True):
        eng = create_engine("offload", _cfg(
            model, wl, x, params,
            staging=StagingConfig(async_enabled=async_staging),
            cache=CacheConfig(capacity_rows=64)))
        d = eng.apply_stream(wl.batches).as_dict()
        counts.append((d["cache_hit_rows"], d["cache_miss_rows"],
                       d["cache_evictions"], d["staged_bytes"]))
    assert counts[0] == counts[1]
    assert counts[0][2] > 0  # capacity 64 on this stream must evict


# ---------------------------------------------------------------------- #
# the exact hub_burst counters the CI gates pin
# ---------------------------------------------------------------------- #
def test_hub_burst_counters_match_ci_expectations():
    """The smoke-cell residency counts are THE blocking CI contract
    (check_regression.CACHE_EXPECTED['smoke']): pin them here too so a
    cache/planner change fails tier-1 before it fails the bench gate."""
    from benchmarks.check_regression import CACHE_EXPECTED

    wl = make_adversarial_stream("hub_burst", num_batches=6)
    x, _ = random_features(wl.base.n, 8, seed=0)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    eng = create_engine("offload", _cfg(
        model, wl, x, params, cache=CacheConfig(capacity_rows=256)))
    d = eng.apply_stream(wl.batches).as_dict()
    exp = CACHE_EXPECTED["smoke"]
    assert d["cache_hit_rows"] == exp["hit_rows"]
    assert d["cache_miss_rows"] == exp["miss_rows"]
    assert d["cache_evictions"] == exp["evictions"]

    hyb = create_engine("sharded_offload", _cfg(
        model, wl, x, params, num_shards=jax.device_count(),
        cache=CacheConfig(capacity_rows=256)))
    dh = hyb.apply_stream(wl.batches).as_dict()
    if jax.device_count() == 8:
        # the sharded expectations are pinned for the CI 8-way mesh
        exp = CACHE_EXPECTED["sharded"]
        assert dh["cache_hit_rows"] == exp["hit_rows"]
        assert dh["cache_miss_rows"] == exp["miss_rows"]
        assert dh["cache_evictions"] == exp["evictions"]
    else:
        assert dh["cache_hit_rows"] > 0


# ---------------------------------------------------------------------- #
# invalidation coherence: feature scatters, policy full recompute, refresh
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("kind", ["offload", "sharded_offload"])
def test_cache_coherent_across_policy_full_recompute(kind):
    """hub_burst's adaptive schedule interleaves full-recompute batches
    (which rewrite the whole host state → invalidate_all) with
    incremental ones: cached vs uncached must stay bitwise through the
    mode changes, and the invalidation counter must show the flushes."""
    wl = make_adversarial_stream("hub_burst", num_batches=6)
    x, _ = random_features(wl.base.n, 8, seed=0)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    shards = ({"num_shards": jax.device_count()}
              if kind != "offload" else {})
    runs = {}
    for cached in (False, True):
        eng = create_engine(kind, _cfg(
            model, wl, x, params, policy="adaptive",
            cache=CacheConfig(capacity_rows=256) if cached else None,
            **shards))
        ss = eng.apply_stream(wl.batches)
        runs[cached] = (eng, ss.as_dict())
    base, d0 = runs[False]
    hot, d1 = runs[True]
    assert d1["policy_full_batches"] > 0  # the regime guarantees it
    assert d1["policy_full_batches"] == d0["policy_full_batches"]
    np.testing.assert_array_equal(np.asarray(base.embeddings),
                                  np.asarray(hot.embeddings))
    assert hot._backend.cache_snapshot().invalidated_rows > 0


def test_refresh_invalidates_cache_and_stays_bitwise():
    x, wl = _mk_stream(n=120, num_batches=10, seed=5)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    runs = {}
    for cached in (False, True):
        eng = create_engine("offload", _cfg(
            model, wl, x, params, refresh_every=4,
            cache=CacheConfig(capacity_rows=64) if cached else None))
        for b in wl.batches:
            eng.apply_batch(b)
        runs[cached] = eng
    np.testing.assert_array_equal(np.asarray(runs[False].embeddings),
                                  np.asarray(runs[True].embeddings))
    # two refreshes over 10 batches flushed every occupied slot
    assert runs[True]._backend.cache_snapshot().invalidated_rows > 0


# ---------------------------------------------------------------------- #
# versioned snapshot reads with the cache enabled
# ---------------------------------------------------------------------- #
def test_snapshot_reads_at_retained_versions_with_cache():
    """The cache only short-circuits H2D staging; the host state and the
    frontend's undo log stay authoritative, so reads pinned at retained
    versions are bitwise identical with and without the cache."""
    x, wl = _mk_stream(n=120, num_batches=8, seed=5)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    rows = np.arange(0, wl.base.n, 7)
    reads = {}
    for cached in (False, True):
        eng = create_engine("offload", _cfg(
            model, wl, x, params,
            cache=CacheConfig(capacity_rows=64) if cached else None))
        fe = ServingFrontend(eng, max_versions=len(wl.batches) + 1)
        for b in wl.batches:
            fe.apply_batch(b)
        reads[cached] = [np.array(fe.read(rows, version=v))
                         for v in range(fe.version + 1)]
    for ru, rc in zip(reads[False], reads[True]):
        np.testing.assert_array_equal(ru, rc)


# ---------------------------------------------------------------------- #
# StreamStats key namespace (documented, CI-consumed)
# ---------------------------------------------------------------------- #
def test_stream_stats_keys_are_pinned_and_documented():
    """`as_dict` is the single result surface benchmarks and
    check_regression consume: its key set is pinned by STREAM_STAT_KEYS
    and every key must appear in the as_dict docstring table."""
    d = StreamStats([], 0.0, 0.0).as_dict()
    assert tuple(d.keys()) == STREAM_STAT_KEYS
    for key in ("cache_hit_rows", "cache_miss_rows", "cache_evictions",
                "fusion_windows", "fused_batches", "fusion_fallbacks"):
        assert key in STREAM_STAT_KEYS
    doc = StreamStats.as_dict.__doc__
    for key in STREAM_STAT_KEYS:
        assert key in doc, f"undocumented StreamStats key {key!r}"


# ---------------------------------------------------------------------- #
# the single public API: factory ≡ deprecated alias, per backend
# ---------------------------------------------------------------------- #
def _alias_ctor(backend):
    from repro.core.engine import RTECEngine
    from repro.core.sharded_engine import ShardedRTECEngine
    from repro.serve.api import ChunkedRTECEngine
    from repro.serve.offload import (
        OffloadedRTECEngine,
        ShardedOffloadRTECEngine,
    )

    return {"device": RTECEngine, "offload": OffloadedRTECEngine,
            "sharded": ShardedRTECEngine,
            "sharded_offload": ShardedOffloadRTECEngine,
            "chunked": ChunkedRTECEngine}[backend]


@pytest.mark.parametrize("backend", sorted(BACKENDS))
def test_factory_matches_deprecated_alias_bitwise(backend):
    """Every legacy ``*RTECEngine`` constructor is a deprecated alias of
    ``create_engine``: it must emit DeprecationWarning and produce an
    engine whose stream output is bitwise equal to the factory's."""
    x, wl = _mk_stream(n=120, num_batches=6, seed=5)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    eng_f = create_engine(backend, _cfg(model, wl, x, params))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        eng_a = _alias_ctor(backend)(model, params, wl.base, x)
    assert any(issubclass(w.category, DeprecationWarning) and
               "create_engine" in str(w.message) for w in caught), backend
    eng_f.apply_stream(wl.batches)
    eng_a.apply_stream(wl.batches)
    np.testing.assert_array_equal(np.asarray(eng_f.embeddings),
                                  np.asarray(eng_a.embeddings))


def test_factory_is_warning_free():
    x, wl = _mk_stream(n=120, num_batches=1, seed=5)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        create_engine("offload", _cfg(model, wl, x, params))


def test_engine_config_cache_resolution():
    x, wl = _mk_stream(n=120, num_batches=1, seed=5)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    # disabled or absent config → no cache on the backend
    for cache in (None, CacheConfig(enabled=False)):
        eng = create_engine("offload", _cfg(model, wl, x, params, cache=cache))
        assert eng._backend._cache is None
        assert eng._backend.cache_snapshot() is None
    # each engine owns a fresh HotRowCache (slot state is engine state)
    cfg = _cfg(model, wl, x, params, cache=CacheConfig(capacity_rows=32))
    a = create_engine("offload", cfg)
    b = create_engine("offload", cfg)
    assert a._backend._cache is not b._backend._cache
    assert a._backend._cache.capacity == 32
    # the cache knob is ignored by backends without host staging
    dev = create_engine("device", cfg)
    assert not hasattr(dev._backend, "_cache") or dev._backend._cache is None
