"""Per-kernel interpret-mode validation vs the pure-jnp oracles in ref.py,
swept across shapes and dtypes (the kernel contract from the brief)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.ops as ops
from repro.kernels import ref as kref
from repro.kernels.segment_spmm import prepare_block_csr


@pytest.fixture(autouse=True)
def _force_interpret():
    old = ops.FORCE_PALLAS_INTERPRET
    ops.FORCE_PALLAS_INTERPRET = True
    yield
    ops.FORCE_PALLAS_INTERPRET = old


def _tol(dtype):
    # bf16: the kernels accumulate in fp32 and round once, the jnp oracle
    # accumulates in bf16 — allow accumulation-order noise ~ eps·sqrt(deg)·|x|
    return dict(atol=0.3, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------- #
# block-aligned CSR layout
# ---------------------------------------------------------------------- #
def test_prepare_block_csr_properties():
    rng = np.random.default_rng(0)
    dst = np.sort(rng.integers(0, 100, 1000))
    perm, dloc, brows, e_pad = prepare_block_csr(dst, 100, tv=8, be=64)
    assert e_pad % 64 == 0
    assert np.all(np.diff(brows) >= 0), "block rows must be non-decreasing"
    # every real edge appears exactly once
    real = perm[perm >= 0]
    assert sorted(real.tolist()) == list(range(1000))
    # local ids consistent with tiles
    for b in range(len(brows)):
        seg = dloc[b * 64 : (b + 1) * 64]
        live = seg[seg >= 0]
        assert np.all(live < 8)
        glob = dst[perm[b * 64 : (b + 1) * 64][seg >= 0]]
        np.testing.assert_array_equal(glob // 8, brows[b])


def test_prepare_block_csr_empty():
    perm, dloc, brows, e_pad = prepare_block_csr(np.full(5, -1), 16, tv=8, be=64)
    assert np.all(perm == -1)


# ---------------------------------------------------------------------- #
# segment_spmm
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "e,d,v,tv,be,bd",
    [
        (700, 96, 40, 8, 128, 32),
        (64, 32, 8, 8, 64, 32),
        (1500, 128, 256, 8, 256, 128),
        (33, 160, 100, 8, 64, 32),  # sparse touch: many empty tiles
    ],
)
def test_segment_spmm_sweep(e, d, v, tv, be, bd, dtype):
    rng = np.random.default_rng(e + d)
    dst = np.sort(rng.integers(0, v, e)).astype(np.int32)
    msg = jnp.asarray(rng.normal(size=(e, d)), dtype)
    out = ops.segment_sum_edges(msg, dst, v, tv=tv, be=be, bd=bd)
    ref = kref.segment_spmm_ref(msg, jnp.asarray(dst), v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_segment_spmm_with_padding_tail():
    rng = np.random.default_rng(3)
    dst = np.concatenate([np.sort(rng.integers(0, 30, 200)), np.full(56, -1)]).astype(np.int32)
    msg = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    out = ops.segment_sum_edges(msg, dst, 30, tv=8, be=64, bd=32)
    ref = kref.segment_spmm_ref(msg, jnp.asarray(dst), 30)
    np.testing.assert_allclose(np.array(out), np.array(ref), atol=2e-5)


# ---------------------------------------------------------------------- #
# delta_agg
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,d,v", [(700, 96, 40), (100, 32, 128), (2000, 64, 64)])
def test_delta_agg_sweep(e, d, v, dtype):
    rng = np.random.default_rng(e + v)
    dst = np.sort(rng.integers(0, v, e)).astype(np.int32)
    sign = rng.choice([-1.0, 1.0], e).astype(np.float32)
    msg = jnp.asarray(rng.normal(size=(e, d)) * sign[:, None], dtype)
    state = jnp.asarray(rng.normal(size=(v, d)), dtype)
    out = ops.delta_agg_update(state, msg, dst, tv=8, be=128, bd=32)
    ref = kref.delta_agg_ref(state, msg, jnp.asarray(dst))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_delta_agg_untouched_rows_identical():
    """Rows outside the affected tiles must be bit-identical (aliased pass-through)."""
    rng = np.random.default_rng(0)
    v, d = 64, 32
    state = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    dst = np.array([3, 3, 5], np.int32)  # only tile 0 touched
    msg = jnp.asarray(rng.normal(size=(3, d)).astype(np.float32))
    out = ops.delta_agg_update(state, msg, dst, tv=8, be=64, bd=32)
    np.testing.assert_array_equal(np.array(out[8:]), np.array(state[8:]))


# ---------------------------------------------------------------------- #
# edge_softmax
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("e,h,v", [(700, 4, 40), (120, 1, 16), (1024, 8, 128)])
def test_edge_softmax_sweep(e, h, v):
    rng = np.random.default_rng(e)
    dst = np.sort(rng.integers(0, v, e)).astype(np.int32)
    sc = jnp.asarray(rng.uniform(0.05, 5.0, size=(e, h)).astype(np.float32))
    n1, s1 = ops.edge_softmax(sc, dst, v, tv=8, be=128, bh=32)
    n2, s2 = kref.edge_softmax_ref(sc, jnp.asarray(dst), v)
    np.testing.assert_allclose(np.array(n1), np.array(n2), atol=1e-5)
    np.testing.assert_allclose(np.array(s1), np.array(s2), atol=1e-4)
    # normalized scores per destination sum to 1
    sums = np.zeros((v, h))
    np.add.at(sums, dst, np.array(n1))
    np.testing.assert_allclose(sums[np.unique(dst)], 1.0, atol=1e-4)


# ---------------------------------------------------------------------- #
# flash attention
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,s,dh,bq,bk,causal,window",
    [
        (2, 4, 2, 256, 64, 128, 128, True, None),
        (1, 2, 2, 128, 32, 64, 64, False, None),
        (2, 4, 1, 256, 64, 128, 64, True, 64),
        (1, 8, 4, 512, 128, 256, 256, True, None),
    ],
)
def test_flash_attention_sweep(b, hq, hkv, s, dh, bq, bk, causal, window, dtype):
    rng = np.random.default_rng(s + dh)
    q = jnp.asarray(rng.normal(size=(b, hq, s, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, dh)), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window, bq=bq, bk=bk)
    ref = kref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **(dict(atol=3e-2, rtol=3e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-3)),
    )


def test_flash_attention_decode_step():
    """q_len=1 with full KV cache (the serve_step lowering shape)."""
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(2, 4, 1, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 2, 256, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 2, 256, 64)).astype(np.float32))
    out = ops.flash_attention(q, k, v, causal=True, q_offset=255, bq=1, bk=128)
    ref = kref.flash_attention_ref(q, k, v, causal=True, q_offset=255)
    np.testing.assert_allclose(np.array(out), np.array(ref), atol=2e-5)


def test_flash_attention_matches_plain_softmax():
    """Independent oracle: direct jnp softmax attention."""
    rng = np.random.default_rng(1)
    b, h, s, d = 1, 2, 128, 32
    q = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
    out = ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(d)
    mask = np.tril(np.ones((s, s), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    ref = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(logits, -1), v)
    np.testing.assert_allclose(np.array(out), np.array(ref), atol=2e-5)
