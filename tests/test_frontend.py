"""Serving front-end (ISSUE 6): versioned snapshot reads, admission
control, the unified `create_engine` factory, and StreamStats as the
single result type.

The bitwise contract under test: a read pinned to version v returns rows
bitwise-equal to the serial post-batch-v state, no matter how many batches
ran between pin and service — on every backend, with async staging both on
and off for the host-resident pair.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    RTECEngine,
    ShardedRTECEngine,
    StreamStats,
    full_forward,
    make_model,
)
from repro.graph import make_graph, make_stream
from repro.graph.generators import random_features
from repro.serve import (
    BACKENDS,
    ChunkedRTECEngine,
    EngineConfig,
    ReadRejectedError,
    ServingFrontend,
    StaleVersionError,
    create_engine,
)
from repro.serve.offload import OffloadedRTECEngine, ShardedOffloadRTECEngine

TOL = 2e-4


def _mk_stream(n=150, num_batches=8, seed=0, feature_dim=8, batch_edges=8):
    g = make_graph("powerlaw", n, avg_degree=5, seed=seed, weighted=True)
    x, _ = random_features(n, 8, seed=seed)
    wl = make_stream(g, num_batches=num_batches, batch_edges=batch_edges,
                     delete_frac=0.35, seed=seed + 1,
                     feature_dim=feature_dim, feature_frac=0.02)
    return x, wl


def _cfg(model, wl, x, **kw) -> EngineConfig:
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    return EngineConfig(model=model, graph=wl.base, x=x, params=params, **kw)


def _serial_reference(backend, cfg, wl, rows):
    """Per-version row snapshots from an identically-constructed engine
    applying the stream serially: refs[v] is the post-batch-v state."""
    eng = create_engine(backend, cfg)
    refs = [np.array(eng.snapshot_rows(rows))]
    for b in wl.batches:
        eng.apply_batch(b)
        refs.append(np.array(eng.snapshot_rows(rows)))
    return refs


# ---------------------------------------------------------------------- #
# the tentpole contract: versioned reads are bitwise (ISSUE 6 acceptance)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("async_staging", [True, False])
@pytest.mark.parametrize("backend", ["offload", "sharded_offload"])
def test_versioned_reads_bitwise_offload_backends(backend, async_staging):
    """Deterministic read/write interleaving on the host-resident pair:
    after every batch, read *every* retained version v0..vk and require
    each bitwise-equal to the serial post-batch state — with the async
    staging worker both on and off."""
    model = make_model("gcn")
    x, wl = _mk_stream()
    cfg = _cfg(model, wl, x, async_staging=async_staging)
    rows = np.arange(0, wl.base.n, 5)
    refs = _serial_reference(backend, cfg, wl, rows)

    fr = ServingFrontend(create_engine(backend, cfg), max_pending_reads=256,
                         max_versions=len(wl.batches) + 1)
    for b in wl.batches:
        fr.apply_batch(b)
        for v in range(fr.version + 1):
            np.testing.assert_array_equal(fr.read(rows, version=v), refs[v])
    ss = fr.stats()
    # after batch i (version i+1) we read versions 0..i+1 → i+2 reads
    assert ss.reads_served == sum(i + 2 for i in range(len(wl.batches)))
    assert ss.reads_rejected == 0
    assert ss.read_p99_s >= ss.read_p50_s > 0.0


@pytest.mark.parametrize("backend", BACKENDS)
def test_versioned_reads_bitwise_every_backend(backend):
    """All five substrates serve pinned reads bitwise-equal to the serial
    post-batch state (current version + two versions back)."""
    model = make_model("gcn")
    x, wl = _mk_stream(num_batches=6)
    cfg = _cfg(model, wl, x)
    rows = np.arange(0, wl.base.n, 7)
    refs = _serial_reference(backend, cfg, wl, rows)

    fr = ServingFrontend(create_engine(backend, cfg), max_versions=4)
    for b in wl.batches:
        fr.apply_batch(b)
        v = fr.version
        np.testing.assert_array_equal(fr.read(rows, version=v), refs[v])
        np.testing.assert_array_equal(fr.read(rows, version=max(0, v - 2)),
                                      refs[max(0, v - 2)])
    assert fr.stats().reads_served == 2 * len(wl.batches)


def test_reads_interleave_with_pending_writes():
    """Reads submitted *before* batches are served at their pinned version
    at the next micro-batch point, and staleness accounts the gap."""
    model = make_model("gcn")
    x, wl = _mk_stream(num_batches=4)
    cfg = _cfg(model, wl, x)
    rows = np.arange(0, wl.base.n, 11)
    refs = _serial_reference("offload", cfg, wl, rows)

    fr = ServingFrontend(create_engine("offload", cfg))
    tickets = []
    for b in wl.batches:
        tickets.append(fr.submit_read(rows))  # pinned at current version
        fr.apply_batch(b)  # serves the read before applying (staleness 0)
    late = fr.submit_read(rows, version=1)  # served 3 batches late
    fr.drain()
    for v, t in enumerate(tickets):
        assert t.version == v and t.staleness == 0
        np.testing.assert_array_equal(t.value(), refs[v])
    np.testing.assert_array_equal(late.value(), refs[1])
    assert late.staleness == len(wl.batches) - 1
    assert fr.stats().staleness_batches == len(wl.batches) - 1


# ---------------------------------------------------------------------- #
# admission control / backpressure
# ---------------------------------------------------------------------- #
def test_backpressure_evicts_oldest_version_with_typed_error():
    model = make_model("gcn")
    x, wl = _mk_stream(num_batches=3)
    fr = ServingFrontend(create_engine("offload", _cfg(model, wl, x)),
                         max_pending_reads=2)
    for b in wl.batches:
        fr.apply_batch(b)
    rows = np.arange(8)
    t0 = fr.submit_read(rows, version=0)
    t1 = fr.submit_read(rows, version=1)
    t2 = fr.submit_read(rows, version=2)  # queue full → t0 (oldest pin) out
    assert t0.done and isinstance(t0.error, ReadRejectedError)
    with pytest.raises(ReadRejectedError):
        t0.value()
    assert not t1.done and not t2.done
    assert fr.drain() == 2
    assert t1.value() is not None and t2.value() is not None
    ss = fr.stats()
    assert ss.reads_rejected == 1 and ss.reads_served == 2


def test_stale_pin_rejected_below_undo_floor():
    model = make_model("gcn")
    x, wl = _mk_stream(num_batches=4)
    fr = ServingFrontend(create_engine("offload", _cfg(model, wl, x)),
                         max_versions=2)
    for b in wl.batches:
        fr.apply_batch(b)
    assert fr.version == 4 and fr.min_version == 2
    with pytest.raises(StaleVersionError):
        fr.submit_read(np.arange(4), version=1)
    assert fr.stats().reads_rejected == 1
    # the floor itself is still servable
    assert fr.read(np.arange(4), version=2).shape == (4, 8)


def test_refresh_clears_undo_history():
    """An orchestrator refresh recomputes state from scratch — older
    versions stop being reconstructible and the floor jumps."""
    model = make_model("gcn")
    x, wl = _mk_stream(num_batches=4)
    cfg = _cfg(model, wl, x, refresh_every=2)
    rows = np.arange(0, wl.base.n, 9)
    refs = _serial_reference("device", cfg, wl, rows)

    fr = ServingFrontend(create_engine("device", cfg), max_versions=8)
    fr.apply_batch(wl.batches[0])
    fr.apply_batch(wl.batches[1])  # refresh fires after this batch
    assert fr.min_version == fr.version == 2
    with pytest.raises(StaleVersionError):
        fr.submit_read(rows, version=1)
    fr.apply_batch(wl.batches[2])
    np.testing.assert_array_equal(fr.read(rows, version=2), refs[2])
    np.testing.assert_array_equal(fr.read(rows, version=3), refs[3])


def test_future_pin_waits_for_version():
    model = make_model("gcn")
    x, wl = _mk_stream(num_batches=3)
    cfg = _cfg(model, wl, x)
    rows = np.arange(0, wl.base.n, 13)
    refs = _serial_reference("offload", cfg, wl, rows)
    fr = ServingFrontend(create_engine("offload", cfg))
    t = fr.submit_read(rows, version=2)
    fr.apply_batch(wl.batches[0])
    assert not t.done  # version 1 < pin
    fr.apply_batch(wl.batches[1])
    fr.apply_batch(wl.batches[2])  # serves at version 2 before batch 3
    assert t.done and t.staleness == 0
    np.testing.assert_array_equal(t.value(), refs[2])


# ---------------------------------------------------------------------- #
# unified factory (API redesign satellite)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
def test_factory_bitwise_parity_with_direct_construction(backend):
    model = make_model("gcn")
    x, wl = _mk_stream(num_batches=4)
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    direct = {
        "device": lambda: RTECEngine(model, params, wl.base, jnp.asarray(x)),
        "offload": lambda: OffloadedRTECEngine(model, params, wl.base, x),
        "sharded": lambda: ShardedRTECEngine(model, params, wl.base, x),
        "sharded_offload": lambda: ShardedOffloadRTECEngine(
            model, params, wl.base, x),
        "chunked": lambda: ChunkedRTECEngine(model, params, wl.base, x),
    }[backend]()
    fact = create_engine(backend, EngineConfig(model=model, graph=wl.base,
                                               x=x, params=params))
    assert type(fact) is type(direct)
    for b in wl.batches:
        direct.apply_batch(b)
        fact.apply_batch(b)
    np.testing.assert_array_equal(np.asarray(fact.embeddings),
                                  np.asarray(direct.embeddings))


def test_engine_config_param_init_and_validation():
    model = make_model("gcn")
    x, wl = _mk_stream(num_batches=1)
    cfg = EngineConfig(model=model, graph=wl.base, x=x, dims=[8, 8, 8],
                       seed=7)
    eng = create_engine("device", cfg)
    assert eng.L == 2
    with pytest.raises(ValueError, match="unknown backend"):
        create_engine("hbm", cfg)
    with pytest.raises(ValueError, match="params or dims"):
        create_engine("device", EngineConfig(model=model, graph=wl.base, x=x))


def test_serving_frontend_helper_on_every_facade():
    model = make_model("gcn")
    x, wl = _mk_stream(num_batches=1)
    for backend in BACKENDS:
        eng = create_engine(backend, _cfg(model, wl, x))
        fr = eng.serving_frontend(max_versions=3)
        assert isinstance(fr, ServingFrontend) and fr.max_versions == 3


# ---------------------------------------------------------------------- #
# chunked substrate wired into the public API (orphan-code satellite)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["gcn", "gat"])
def test_chunked_backend_matches_full_recompute(name):
    """`backend="chunked"` executes real streams correctly with multiple
    chunks per layer (chunk_size < affected-set size forces chunking and
    the inter-chunk staging-reuse path)."""
    model = make_model(name)
    x, wl = _mk_stream(num_batches=8, seed=3)
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    cfg = EngineConfig(model=model, graph=wl.base, x=x, params=params,
                       chunk_size=8)
    eng = create_engine("chunked", cfg)
    for b in wl.batches:
        eng.apply_batch(b)
    g_cur, x_cur = wl.base, np.array(x)
    for b in wl.batches:
        g_cur = g_cur.apply_updates(b.ins_src, b.ins_dst, b.del_src,
                                    b.del_dst, b.ins_weights, b.ins_etypes)
        if b.feat_vertices is not None:
            x_cur[b.feat_vertices] = b.feat_values
    ref = np.asarray(full_forward(model, params, jnp.asarray(x_cur),
                                  g_cur)[-1].h)
    assert float(np.abs(eng.embeddings - ref).max()) < TOL
    assert eng.chunk_stats.chunks > len(wl.batches)  # chunking really ran


# ---------------------------------------------------------------------- #
# StreamStats as the single result type (results satellite)
# ---------------------------------------------------------------------- #
def test_stream_stats_as_dict_defaults_and_read_fields():
    d = StreamStats([], 0.0, 0.0).as_dict()
    # read-side fields default to zero so pre-serving baselines keep passing
    for k in ("reads_served", "reads_rejected", "staleness_batches"):
        assert d[k] == 0
    for k in ("read_p50_s", "read_p99_s"):
        assert d[k] == 0.0
    model = make_model("gcn")
    x, wl = _mk_stream(num_batches=2)
    fr = ServingFrontend(create_engine("offload", _cfg(model, wl, x)))
    ss = fr.run_stream(wl.batches)
    assert isinstance(ss, StreamStats) and len(ss.batches) == 2
    d = ss.as_dict()
    assert d["n_batches"] == 2 and d["wall_s"] == ss.wall_s
    assert set(d) >= {"staged_bytes", "prefetch_hits", "reads_served",
                      "read_p99_s", "staleness_batches"}
