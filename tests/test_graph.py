"""Graph substrate: CSR, PMA dynamic CSR, generators, update streams."""
import numpy as np
import pytest

from repro.graph import CSRGraph, PMAGraph, make_graph, make_stream
from repro.graph.generators import barabasi_albert, erdos_renyi


def test_csr_roundtrip():
    src = np.array([0, 1, 2, 3, 1])
    dst = np.array([2, 2, 3, 4, 4])
    g = CSRGraph.from_edges(5, src, dst)
    assert g.num_edges == 5
    assert set(g.in_neighbors(2).tolist()) == {0, 1}
    assert set(g.out_neighbors(1).tolist()) == {2, 4}
    assert g.has_edge(0, 2) and not g.has_edge(2, 0)
    np.testing.assert_array_equal(g.in_degree(), [0, 0, 2, 1, 2])
    np.testing.assert_array_equal(g.out_degree(), [1, 2, 1, 1, 0])


def test_csr_duplicate_rejected():
    with pytest.raises(ValueError):
        CSRGraph.from_edges(3, np.array([0, 0]), np.array([1, 1]))


def test_csr_apply_updates():
    g = CSRGraph.from_edges(4, np.array([0, 1]), np.array([1, 2]))
    g2 = g.apply_updates(
        np.array([2]), np.array([3]), np.array([0]), np.array([1])
    )
    assert g2.has_edge(2, 3) and not g2.has_edge(0, 1)
    assert g.has_edge(0, 1), "original snapshot must be immutable"
    with pytest.raises(ValueError):
        g.apply_updates(np.array([], np.int64), np.array([], np.int64), np.array([3]), np.array([0]))


def test_csr_edge_data_alignment():
    rng = np.random.default_rng(0)
    src = rng.integers(0, 50, 200)
    dst = rng.integers(0, 50, 200)
    mask = src != dst
    src, dst = src[mask], dst[mask]
    key = dst * 50 + src
    _, idx = np.unique(key, return_index=True)
    src, dst = src[idx], dst[idx]
    w = rng.uniform(0, 1, src.shape[0]).astype(np.float32)
    t = rng.integers(0, 3, src.shape[0]).astype(np.int32)
    g = CSRGraph.from_edges(50, src, dst, w, t)
    # in- and out-views must agree per edge
    for v in range(50):
        nbrs, ws, ts = g.in_edge_data(v)
        for u, wi, ti in zip(nbrs, ws, ts):
            outs, wo, to = g.out_edge_data(int(u))
            j = np.nonzero(outs == v)[0]
            assert j.size == 1
            assert wo[j[0]] == wi and to[j[0]] == ti


def test_pma_insert_delete_snapshot():
    pma = PMAGraph(20, capacity=64, seg=16)
    rng = np.random.default_rng(1)
    edges = set()
    for _ in range(300):
        u, v = int(rng.integers(20)), int(rng.integers(20))
        if (u, v) in edges:
            pma.delete_edge(u, v)
            edges.discard((u, v))
        else:
            pma.insert_edge(u, v, w=0.5, t=1)
            edges.add((u, v))
    snap = pma.snapshot()
    assert snap.num_edges == len(edges)
    for (u, v) in edges:
        assert snap.has_edge(u, v)
    assert pma.num_edges == len(edges)


def test_pma_growth_preserves_edges():
    pma = PMAGraph(5, capacity=8, seg=8)
    edges = [(i % 5, (i * 3 + 1) % 5) for i in range(20)]
    edges = list(dict.fromkeys((u, v) for u, v in edges if u != v))
    for u, v in edges:
        pma.insert_edge(u, v)
    snap = pma.snapshot()
    for u, v in edges:
        assert snap.has_edge(u, v)


def test_pma_errors():
    pma = PMAGraph(4)
    pma.insert_edge(0, 1)
    with pytest.raises(ValueError):
        pma.insert_edge(0, 1)
    with pytest.raises(ValueError):
        pma.delete_edge(1, 0)


def test_generators_shapes():
    g = barabasi_albert(300, m=3, seed=0)
    assert g.n == 300 and g.num_edges > 300
    # power-law-ish: max degree much larger than mean
    deg = g.in_degree()
    assert deg.max() > 4 * deg.mean()
    g2 = erdos_renyi(200, avg_degree=6.0, seed=1)
    assert abs(g2.num_edges / 200 - 6.0) < 2.0


def test_stream_consistency():
    g = make_graph("powerlaw", 200, avg_degree=6, seed=0)
    wl = make_stream(g, num_batches=5, batch_edges=20, delete_frac=0.3, seed=2)
    cur = wl.base
    for b in wl.batches:
        # applying every batch must be legal (no dup inserts / missing deletes)
        cur = cur.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                                b.ins_weights, b.ins_etypes)
        assert b.num_updates > 0
    assert cur.num_edges >= wl.base.num_edges - sum(b.del_src.size for b in wl.batches)
