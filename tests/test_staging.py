"""Async double-buffered host staging (ISSUE 5).

The acceptance matrix: the async staging path is bitwise-identical to the
``async_staging=False`` escape hatch over 20-batch gcn AND gat streams on
both host-resident backends; a staging-worker exception surfaces out of
``flush()`` on the caller thread; and with an artificially slowed host
gather the caller's staging wait stays below the serial staging time
(the overlap is real, not just plumbed).
"""
import time

import jax
import numpy as np
import pytest

from repro.core import make_model
from repro.graph import make_graph, make_stream
from repro.graph.generators import random_features
from repro.serve.offload import OffloadedRTECEngine, ShardedOffloadRTECEngine
from repro.serve.staging import HostStagingPipeline, StagingBuffers


def _mk_stream(n=120, num_batches=20, seed=0, feature_dim=8, batch_edges=8):
    g = make_graph("powerlaw", n, avg_degree=5, seed=seed, weighted=True)
    x, _ = random_features(n, 8, seed=seed)
    wl = make_stream(g, num_batches=num_batches, batch_edges=batch_edges,
                     delete_frac=0.35, seed=seed + 1,
                     feature_dim=feature_dim, feature_frac=0.02)
    return x, wl


def _mk_engine(kind, model, params, base, x, async_staging):
    if kind == "offload":
        return OffloadedRTECEngine(model, params, base, x,
                                   async_staging=async_staging)
    return ShardedOffloadRTECEngine(model, params, base, x,
                                    num_shards=jax.device_count(),
                                    async_staging=async_staging)


# ---------------------------------------------------------------------- #
# acceptance: async ≡ sync, bitwise, 20 batches, gcn + gat, both backends
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("kind", ["offload", "hybrid"])
@pytest.mark.parametrize("name", ["gcn", "gat"])  # unconstrained + constrained
def test_async_staging_bitwise_equals_sync_20_batches(name, kind):
    x, wl = _mk_stream(n=120, num_batches=20, seed=5)
    model = make_model(name)
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    sync = _mk_engine(kind, model, params, wl.base, x, async_staging=False)
    asyn = _mk_engine(kind, model, params, wl.base, x, async_staging=True)
    assert sync.async_staging is False and asyn.async_staging is True
    for b in wl.batches:
        sync.apply_batch(b)
        asyn.apply_batch(b)
    np.testing.assert_array_equal(np.asarray(sync.embeddings),
                                  np.asarray(asyn.embeddings))
    # per-layer state too, not just the final embeddings
    for hs, ha in zip(sync.h, asyn.h):
        np.testing.assert_array_equal(np.asarray(hs), np.asarray(ha))
    # the deterministic counters must not depend on the execution mode
    assert sync.transfers.total_rows == asyn.transfers.total_rows
    assert sync.staging.stats.staged_bytes == asyn.staging.stats.staged_bytes
    assert sync.staging.stats.gather_jobs == asyn.staging.stats.gather_jobs


@pytest.mark.parametrize("kind", ["offload", "hybrid"])
def test_async_staging_stream_path_bitwise(kind):
    """apply_stream (plan overlap + deferred final write-back on the
    worker) matches the sync per-batch path bit-for-bit and reports the
    structural overlap counters."""
    x, wl = _mk_stream(n=120, num_batches=8, seed=9)
    model = make_model("gat")
    params = model.init_layers(jax.random.PRNGKey(1), [8, 8, 8])
    sync = _mk_engine(kind, model, params, wl.base, x, async_staging=False)
    asyn = _mk_engine(kind, model, params, wl.base, x, async_staging=True)
    ss_sync = sync.apply_stream(wl.batches)
    ss = asyn.apply_stream(wl.batches)
    np.testing.assert_array_equal(np.asarray(sync.embeddings),
                                  np.asarray(asyn.embeddings))
    assert ss.prefetch_hits == len(wl.batches) - 1  # deterministic, CI-gated
    # the counter is not tautological: the sync escape hatch flushes in
    # dispatch (a backend barrier per batch), so it must score 0 — a
    # silent regression to synchronous staging fails the CI exact gate
    assert ss_sync.prefetch_hits == 0
    assert ss.staged_bytes == asyn.staging.stats.staged_bytes > 0
    assert ss.sync_wait_s >= 0.0 and ss.compute_s >= 0.0


# ---------------------------------------------------------------------- #
# fault injection: worker exceptions surface out of flush()
# ---------------------------------------------------------------------- #
def test_worker_exception_propagates_out_of_flush():
    x, wl = _mk_stream(n=100, num_batches=2, seed=13)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(2), [8, 8])
    eng = OffloadedRTECEngine(model, params, wl.base, x)
    eng.apply_batch(wl.batches[0])  # healthy batch first

    def boom(tag):
        if tag == "final":
            raise ValueError("injected staging fault")

    eng.staging.writeback_hook = boom
    backend, orch = eng._backend, eng._orch
    b = wl.batches[1]
    g_new = orch._apply_graph(b)
    prep = backend.plan(orch.graph, g_new, b)
    backend.dispatch(prep)  # final write-back fails on the worker thread
    with pytest.raises(RuntimeError, match="staging"):
        backend.flush()


def test_worker_exception_reaches_apply_batch_caller():
    """End-to-end: the orchestrator's flush inside apply_batch re-raises
    the worker failure on the caller thread — async staging can never
    swallow a write-back error."""
    x, wl = _mk_stream(n=100, num_batches=2, seed=17)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(3), [8, 8])
    eng = OffloadedRTECEngine(model, params, wl.base, x)
    eng.apply_batch(wl.batches[0])
    eng.staging.writeback_hook = lambda tag: (_ for _ in ()).throw(
        ValueError("injected staging fault"))
    with pytest.raises(RuntimeError, match="staging"):
        eng.apply_batch(wl.batches[1])


# ---------------------------------------------------------------------- #
# scheduling: slowed host gathers hide behind device compute
# ---------------------------------------------------------------------- #
def test_overlap_hides_slow_gather(monkeypatch):
    """With every host gather slowed by ``delay`` and a compute window
    wider than the delay, the async schedule prefetches layer l+1's gather
    during layer l's compute, so the caller's staging wait must stay well
    below the serial staging time (= the worker's total gather work)."""
    import repro.core.backend as backend_mod

    real_layer = backend_mod.incremental_layer

    def slow_layer(*a, **k):  # widen the per-layer compute window
        time.sleep(0.025)
        return real_layer(*a, **k)

    monkeypatch.setattr(backend_mod, "incremental_layer", slow_layer)
    x, wl = _mk_stream(n=120, num_batches=6, seed=21)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(4), [8, 8, 8])
    eng = OffloadedRTECEngine(model, params, wl.base, x)
    delay = 0.012
    eng.staging.gather_hook = lambda tag: time.sleep(delay)
    ss = eng.apply_stream(wl.batches)

    st = eng.staging.stats
    assert st.gather_jobs == len(wl.batches) * eng.L
    serial = st.work_gather_s  # what inline staging would cost end-to-end
    assert serial >= st.gather_jobs * delay
    # the ISSUE-5 bound: overlapped staging waits < serial staging time
    # (0.6 adds margin over the structural ~1/L exposed fraction: only
    # each batch's first gather has no compute window to hide behind)
    assert ss.sync_wait_s < 0.6 * serial, (ss.sync_wait_s, serial)


# ---------------------------------------------------------------------- #
# pipeline unit behavior
# ---------------------------------------------------------------------- #
def test_pipeline_inorder_execution_and_drain():
    pipe = HostStagingPipeline(num_layers=2, async_mode=True)
    order = []
    tickets = [pipe.submit_gather(lambda i=i: order.append(("g", i)), tag=i)
               for i in range(3)]
    pipe.submit_writeback(lambda: order.append(("wb", 0)), nbytes=16)
    pipe.drain()
    assert order == [("g", 0), ("g", 1), ("g", 2), ("wb", 0)]
    assert all(t.done() for t in tickets)
    assert pipe.stats.gather_jobs == 3 and pipe.stats.writeback_jobs == 1
    assert pipe.stats.staged_bytes == 16  # writeback nbytes; gathers returned None
    pipe.close()


def test_pipeline_sync_mode_runs_inline_and_raises_at_submit():
    pipe = HostStagingPipeline(num_layers=1, async_mode=False)
    seen = []
    t = pipe.submit_gather(lambda: seen.append(1) or np.zeros((2, 4), np.float32))
    assert t.done() and seen == [1]
    assert pipe.wait_gather(t).shape == (2, 4)
    assert pipe.stats.staged_bytes == 32
    with pytest.raises(RuntimeError, match="staging"):
        pipe.submit_writeback(lambda: 1 / 0)
    pipe.drain()  # the sync path raised at submit; drain stays clean


def test_staging_buffers_grow_only_and_double_buffering():
    bufs = StagingBuffers()
    v1 = bufs.take("h", 8, (4,))
    base1 = v1.base
    v2 = bufs.take("h", 6, (4,))  # shrink: same backing buffer
    assert v2.base is base1 and v2.shape == (6, 4)
    v3 = bufs.take("h", 32, (4,))  # growth reallocates (grow-only, ≥2x)
    assert v3.shape == (32, 4) and v3.base is not base1
    assert bufs.take("h", 40, (4,)).base is not None
    # distinct trailing shapes never alias
    assert bufs.take("h", 8, (5,)).base is not v3.base

    pipe = HostStagingPipeline(num_layers=2, async_mode=False)
    a = pipe.buffers(0)
    pipe.begin_batch()
    b = pipe.buffers(0)
    pipe.begin_batch()
    c = pipe.buffers(0)
    assert a is not b and a is c  # two sets per layer, alternated per batch
