"""Per-consumer halo exchange (ISSUE 10): ppermute schedules + CommsConfig.

Multidevice-owned (run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
like tests/test_dist.py); the plan-level schedule tests are pure host
planning and run anywhere.

Covers the tentpole invariants:

* every halo row a consumer shard needs is delivered exactly once per
  (layer, consumer) by the rotation schedules — and never to a shard
  that does not consume it — at S in {1, 3, 4, 8} on delete-heavy
  streams;
* ``halo="ppermute"`` is bitwise-equal to the legacy ``"psum"``
  broadcast through a 20-batch stream on both sharded backends (gcn and
  gat, async staging on and off, fused windows and snapshot reads
  included), while ``comms_halo_rows_sent`` stays strictly below the
  global-frontier broadcast volume;
* the typed :class:`~repro.dist.sharding.CommsConfig` is the one comms
  surface: validation, ``"auto"`` resolution, the deprecated
  ``use_pallas_delta`` kwarg/field folding (warning + bitwise parity);
* the hybrid staging accountant no longer double-counts the derived
  ``h_new`` copy in ``staged_bytes`` (satellite fix).
"""
import warnings

import jax
import numpy as np
import pytest

from repro.core import make_model
from repro.core.affected import (
    FusionConfig,
    build_plan,
    shard_plan,
    sharded_layout_slices,
)
from repro.core.backend import (
    STREAM_STAT_KEYS,
    ShardBackend,
    ShardedOffloadBackend,
    StreamOrchestrator,
)
from repro.dist.sharding import CommsConfig, rotation_perm
from repro.graph import make_graph, make_stream
from repro.graph.generators import random_features
from repro.serve.api import EngineConfig, create_engine


def _mk_stream(n=150, num_batches=20, seed=0, feature_dim=None,
               batch_edges=8, delete_frac=0.35):
    g = make_graph("powerlaw", n, avg_degree=5, seed=seed, weighted=True)
    x, _ = random_features(n, 8, seed=seed)
    kw = dict(feature_dim=feature_dim, feature_frac=0.02) if feature_dim else {}
    wl = make_stream(g, num_batches=num_batches, batch_edges=batch_edges,
                     delete_frac=delete_frac, seed=seed + 1, **kw)
    return x, wl


def _params(model, seed=0):
    return model.init_layers(jax.random.PRNGKey(seed), [8, 8, 8])


def _plan_for(model, wl, b, num_layers=2):
    g_new = wl.base.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                                  b.ins_weights, b.ins_etypes)
    return build_plan(model, wl.base, g_new, b, num_layers)


def _consumer_needs(lp, rows_per, n_shards):
    """Ground truth, re-derived from the *global* plan: the remote source
    rows each consumer shard's live records reference at this layer."""
    live = lp.e_mask
    es = lp.e_src[live].astype(np.int64)
    cons_e = lp.e_dst[live].astype(np.int64) // rows_per
    fe_live = lp.f_emask
    f_cap_old = lp.f_rows.shape[0]
    fe_rowg = lp.f_rows[np.minimum(lp.f_rowidx, f_cap_old - 1)].astype(np.int64)
    fs = lp.f_src[fe_live].astype(np.int64)
    cons_f = fe_rowg[fe_live] // rows_per
    src = np.concatenate([es, fs])
    cons = np.concatenate([cons_e, cons_f])
    remote = src // rows_per != cons
    src, cons = src[remote], cons[remote]
    return [set(src[cons == c].tolist()) for c in range(n_shards)]


# ---------------------------------------------------------------------- #
# schedule invariants: exactly-once, consumers-only, correct pairing
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("S", [1, 3, 4, 8])
def test_ppermute_schedules_deliver_exactly_once(S):
    """Every needed halo row is delivered exactly once per (layer,
    consumer) and never to a non-consumer, under a delete-heavy stream."""
    model = make_model("gcn")
    x, wl = _mk_stream(n=150, num_batches=6, seed=3, delete_frac=0.5)
    for b in wl.batches:
        plan = _plan_for(model, wl, b)
        sp = shard_plan(plan, S, halo_mode="ppermute")
        lay = sp.layout
        assert lay.halo_mode == "ppermute"
        rows_per = lay.rows_per
        _, _, _, halo_sl, _ = sharded_layout_slices(lay)
        assert sp.comms_sh is not None and len(sp.comms_sh) == len(plan.layers)
        for l, lp in enumerate(plan.layers):
            need = _consumer_needs(lp, rows_per, S)
            halo_cap = lay.caps[l][5]
            halo_list = np.sort(np.unique(np.fromiter(
                (r for s in need for r in s), np.int64)))
            send_pos, recv_pos = sp.comms_sh[l]
            assert send_pos.shape == (S, max(S - 1, 0), send_pos.shape[2])
            assert recv_pos.shape == send_pos.shape
            delivered = [set() for _ in range(S)]
            total = 0
            for k in range(1, S):
                for o, c in rotation_perm(S, k):
                    sl = send_pos[o, k - 1]
                    rl = recv_pos[c, k - 1]
                    pad_s = sl == rows_per
                    pad_r = rl == halo_cap
                    # padded send slots pair with the recv dump row
                    assert np.array_equal(pad_s, pad_r)
                    rows = o * rows_per + sl[~pad_s].astype(np.int64)
                    for r, hp in zip(rows.tolist(),
                                     rl[~pad_r].astype(np.int64).tolist()):
                        assert r // rows_per == o, "owner sends only its rows"
                        assert r in need[c], "delivered to a non-consumer"
                        assert halo_list[hp] == r, "recv slot mismatch"
                        assert r not in delivered[c], "duplicate delivery"
                        delivered[c].add(r)
                        total += 1
            for c in range(S):
                assert delivered[c] == need[c], "consumer left short"
            assert sp.comms_rows[l] == total
            # strictly below the broadcast ceiling whenever rows moved
            ceiling = int(halo_list.shape[0]) * S
            assert total <= ceiling
            if S > 1 and halo_list.size:
                assert total < ceiling


def test_halo_mode_is_a_trace_key():
    """psum and ppermute plans must produce unequal layouts — the resolved
    mode is static, so the two paths may never share a compiled trace."""
    model = make_model("gcn")
    x, wl = _mk_stream(n=120, num_batches=1, seed=7)
    plan = _plan_for(model, wl, wl.batches[0])
    lay_psum = shard_plan(plan, 4, halo_mode="psum").layout
    lay_pp = shard_plan(plan, 4, halo_mode="ppermute").layout
    assert lay_psum.halo_mode == "psum" and lay_pp.halo_mode == "ppermute"
    assert lay_psum != lay_pp
    assert lay_psum.pair_caps is None and lay_pp.pair_caps is not None


def test_pair_capacity_hysteresis_pads_caps():
    model = make_model("gcn")
    x, wl = _mk_stream(n=150, num_batches=1, seed=11)
    plan = _plan_for(model, wl, wl.batches[0])
    tight = shard_plan(plan, 4, halo_mode="ppermute").layout.pair_caps
    padded = shard_plan(plan, 4, halo_mode="ppermute",
                        pair_hysteresis=1.0).layout.pair_caps
    assert all(p >= t for p, t in zip(padded, tight))
    assert any(p > t for p, t in zip(padded, tight))


# ---------------------------------------------------------------------- #
# CommsConfig: validation, auto resolution, deprecated-knob folding
# ---------------------------------------------------------------------- #
def test_comms_config_validation():
    with pytest.raises(ValueError):
        CommsConfig(halo="allreduce")
    with pytest.raises(ValueError):
        CommsConfig(pair_capacity_hysteresis=-0.1)
    assert CommsConfig().resolve_halo(1) == "psum"
    assert CommsConfig().resolve_halo(4) == "ppermute"
    assert CommsConfig(halo="psum").resolve_halo(8) == "psum"
    assert CommsConfig(halo="ppermute").resolve_halo(1) == "ppermute"


def test_engine_config_resolves_comms():
    cfg = EngineConfig(model=None, graph=None, x=None)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        assert cfg.resolved_comms() == CommsConfig()
    # an explicit comms config passes through untouched, silently
    cc = CommsConfig(halo="psum", pair_capacity_hysteresis=0.5)
    cfg = EngineConfig(model=None, graph=None, x=None, comms=cc)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        assert cfg.resolved_comms() is cc
    # the loose legacy field folds in with a deprecation warning
    cfg = EngineConfig(model=None, graph=None, x=None, use_pallas_delta=True)
    with pytest.warns(DeprecationWarning, match="CommsConfig"):
        assert cfg.resolved_comms() == CommsConfig(use_pallas_delta=True)


@pytest.mark.parametrize("backend_cls", [ShardBackend])
def test_deprecated_kwarg_warns_and_routes(backend_cls):
    """The old ``use_pallas_delta=`` backend kwarg must warn, point at the
    factory path, and produce a bitwise-identical engine."""
    S = min(jax.device_count(), 4)
    model = make_model("gcn")
    params = _params(model)
    x, wl = _mk_stream(n=120, num_batches=5, seed=2, feature_dim=8)
    with pytest.warns(DeprecationWarning) as rec:
        legacy = backend_cls(model, params, wl.base, x, num_shards=S,
                             use_pallas_delta=False)
    assert any("CommsConfig" in str(w.message)
               and "create_engine" in str(w.message) for w in rec)
    typed = backend_cls(model, params, wl.base, x, num_shards=S,
                        comms=CommsConfig())
    assert legacy.halo_mode == typed.halo_mode
    StreamOrchestrator(legacy, wl.base).apply_stream(wl.batches)
    StreamOrchestrator(typed, wl.base).apply_stream(wl.batches)
    np.testing.assert_array_equal(legacy.embeddings, typed.embeddings)


# ---------------------------------------------------------------------- #
# psum == ppermute, bitwise, cross-backend 20-batch matrix
# ---------------------------------------------------------------------- #
def _run_matrix_cell(backend, name, async_staging, fusion=None):
    S = jax.device_count()
    if S < 2:
        pytest.skip("needs a forced multi-device host platform")
    model = make_model(name)
    params = _params(model)
    x, wl = _mk_stream(n=150, num_batches=20, seed=0, feature_dim=8)
    out = {}
    for mode in ("psum", "ppermute"):
        eng = create_engine(backend, EngineConfig(
            model=model, graph=wl.base, x=x, params=params, num_shards=S,
            async_staging=async_staging, fusion=fusion,
            comms=CommsConfig(halo=mode)))
        ss = eng.apply_stream(wl.batches)
        probe = np.arange(0, wl.base.n, 7)
        out[mode] = (eng._backend.embeddings.copy(),
                     eng.snapshot_rows(probe).copy(), ss)
    emb_p, snap_p, ss_p = out["psum"]
    emb_q, snap_q, ss_q = out["ppermute"]
    np.testing.assert_array_equal(emb_p, emb_q)
    np.testing.assert_array_equal(snap_p, snap_q)
    assert 0 < ss_q.comms_halo_rows_sent < ss_p.comms_halo_rows_sent
    assert 0 < ss_q.comms_halo_bytes < ss_p.comms_halo_bytes
    return ss_p, ss_q


@pytest.mark.parametrize("name", ["gcn", "gat"])
def test_ppermute_matches_psum_sharded(name):
    _run_matrix_cell("sharded", name, async_staging=True)


@pytest.mark.parametrize("name,async_staging", [
    ("gcn", False), ("gcn", True), ("gat", False), ("gat", True),
])
def test_ppermute_matches_psum_hybrid(name, async_staging):
    ss_p, ss_q = _run_matrix_cell("sharded_offload", name, async_staging)
    # satellite fix: the derived h_new copy is no longer charged to
    # staged_bytes, so the two modes stage identical accounted volume
    assert ss_p.staged_bytes == ss_q.staged_bytes


def test_ppermute_matches_psum_under_fusion():
    _run_matrix_cell("sharded", "gcn", async_staging=True,
                     fusion=FusionConfig(window=4, enabled=True))


def test_comms_counters_in_stream_stats():
    assert "comms_halo_rows_sent" in STREAM_STAT_KEYS
    assert "comms_halo_bytes" in STREAM_STAT_KEYS


# ---------------------------------------------------------------------- #
# staging accountant: derived buffers are not staged bytes
# ---------------------------------------------------------------------- #
def test_iter_arrays_skips_derived_entries():
    from repro.serve.staging import _iter_arrays
    payload = {"h_old": np.zeros((4, 8), np.float32),
               "_h_new": np.zeros((4, 8), np.float32),
               "nested": [np.zeros(3), {"_d": np.zeros(5), "k": np.zeros(2)}]}
    counted = sum(a.nbytes for a in _iter_arrays(payload))
    assert counted == payload["h_old"].nbytes + 3 * 8 + 2 * 8


def test_hybrid_staged_bytes_counts_halo_rows_once():
    """Legacy psum-mode hybrid staging builds a host h_new copy of every
    gathered h_old row; ``staged_bytes`` must charge those bytes once.
    Pinned by comparing against the sum of the gather payloads that
    actually read host state (h_old + a + nct + h_cur + write-backs)."""
    S = min(jax.device_count(), 4)
    if S < 2:
        pytest.skip("needs a forced multi-device host platform")
    model = make_model("gcn")
    params = _params(model)
    x, wl = _mk_stream(n=120, num_batches=4, seed=9, feature_dim=8)
    outs = {}
    for mode in ("psum", "ppermute"):
        be = ShardedOffloadBackend(model, params, wl.base, x, num_shards=S,
                                   async_staging=False,
                                   comms=CommsConfig(halo=mode))
        ss = StreamOrchestrator(be, wl.base).apply_stream(wl.batches)
        outs[mode] = ss.staged_bytes
    # ppermute mode never materializes the copy at all; equal accounted
    # volume proves psum mode no longer double-counts it
    assert outs["psum"] == outs["ppermute"] > 0
