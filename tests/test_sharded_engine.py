"""Sharded streaming-engine invariants: per-shard plan partitioning must
cover every live row and record exactly once, capacity hysteresis must damp
mid-stream retraces, and ``ShardedRTECEngine`` on a forced 8-host-device
mesh must match the single-device engine over a long stream (the PR's
acceptance invariant — exact for gcn, allclose for gat; subprocess because
the device count must be set before jax initializes).
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RTECEngine, ShardedRTECEngine, make_model
from repro.core.affected import (
    BucketHysteresis,
    build_plan,
    pack_plan,
    shard_plan,
    shard_rows,
    sharded_layout_slices,
)
from repro.graph import make_graph, make_stream
from repro.graph.generators import random_features


def _mk_stream(n=150, num_batches=20, seed=0, feature_dim=None, batch_edges=8):
    g = make_graph("powerlaw", n, avg_degree=5, seed=seed, weighted=True)
    x, _ = random_features(n, 8, seed=seed)
    kw = dict(feature_dim=feature_dim, feature_frac=0.02) if feature_dim else {}
    wl = make_stream(g, num_batches=num_batches, batch_edges=batch_edges,
                     delete_frac=0.35, seed=seed + 1, **kw)
    return x, wl


def _plan_for(model, wl, b, num_layers=2):
    g_new = wl.base.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                                  b.ins_weights, b.ins_etypes)
    return build_plan(model, wl.base, g_new, b, num_layers)


# ---------------------------------------------------------------------- #
# per-shard plan partitioning
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("name,n_shards", [("gcn", 4), ("gat", 4), ("gat", 8)])
def test_shard_plan_covers_every_row_exactly_once(name, n_shards):
    """Union over shards of the live rows/records in the packed sharded
    buffers must equal the global plan's live sets, with no overlap, and
    every record must land on the shard that owns its destination row."""
    x, wl = _mk_stream(n=150, num_batches=1, seed=5)
    model = make_model(name)
    b = wl.batches[0]
    plan = _plan_for(model, wl, b)
    sp = shard_plan(plan, n_shards)
    lay = sp.layout
    rows_per = lay.rows_per
    assert rows_per == shard_rows(150, n_shards)
    idx_sl, flt_sl, msk_sl, halo_sl, _ = sharded_layout_slices(lay)

    for l, lp in enumerate(plan.layers):
        for field, mask_name in (("touch_rows", "touch_mask"),
                                 ("f_rows", "f_mask"),
                                 ("out_rows", "out_mask")):
            global_live = set(getattr(lp, field)[getattr(lp, mask_name)].tolist())
            seen: list = []
            for s in range(n_shards):
                rows_l = sp.idx_sh[s, idx_sl[l][field]]
                live = sp.msk_sh[s, msk_sl[l][mask_name]]
                glob = rows_l[live].astype(np.int64) + s * rows_per
                # ownership: every live local row index is inside the block
                assert np.all(rows_l[live] < rows_per)
                seen.extend(glob.tolist())
            assert len(seen) == len(set(seen)), f"{field}: row appears twice"
            assert set(seen) == global_live, f"{field}: cover mismatch"
        # record counts are preserved (each record follows its dst's owner)
        n_e_global = int(lp.e_mask.sum())
        n_e_shards = sum(int(sp.msk_sh[s, msk_sl[l]["e_mask"]].sum())
                         for s in range(n_shards))
        assert n_e_shards == n_e_global
        n_fe_global = int(lp.f_emask.sum())
        n_fe_shards = sum(int(sp.msk_sh[s, msk_sl[l]["f_emask"]].sum())
                          for s in range(n_shards))
        assert n_fe_shards == n_fe_global


def test_shard_plan_halo_is_frontier_sources_only():
    """The replicated halo list must contain only live source rows, and a
    single-shard partition must exchange nothing."""
    x, wl = _mk_stream(n=150, num_batches=1, seed=6)
    model = make_model("gat")
    b = wl.batches[0]
    plan = _plan_for(model, wl, b)
    sp = shard_plan(plan, 4)
    _, _, _, halo_sl, _ = sharded_layout_slices(sp.layout)
    for l, lp in enumerate(plan.layers):
        halo = sp.idx_rep[halo_sl[l]]
        halo = halo[halo >= 0].astype(np.int64)
        live_srcs = set(lp.e_src[lp.e_mask].tolist()) | set(
            lp.f_src[lp.f_emask].tolist())
        assert set(halo.tolist()) <= live_srcs
    assert sp.n_halo_rows == sum(
        int((sp.idx_rep[halo_sl[l]] >= 0).sum()) for l in range(2))
    # one shard owns everything → empty frontier
    sp1 = shard_plan(plan, 1)
    assert sp1.n_halo_rows == 0


# ---------------------------------------------------------------------- #
# single-pass fill (ROADMAP): argsort-by-owner once + contiguous-run
# slicing must reproduce the original per-shard re-scan bit-for-bit
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["gcn", "gat"])
def test_shard_plan_single_pass_equals_reference_fill(name):
    x, wl = _mk_stream(n=150, num_batches=6, seed=21, feature_dim=8)
    model = make_model(name)
    g_cur = wl.base
    for b in wl.batches:
        g_new = g_cur.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                                    b.ins_weights, b.ins_etypes)
        plan = build_plan(model, g_cur, g_new, b, 2)
        for S in (1, 3, 4, 8):
            fast = shard_plan(plan, S, b.feat_vertices, b.feat_values,
                              single_pass=True)
            ref = shard_plan(plan, S, b.feat_vertices, b.feat_values,
                             single_pass=False)
            assert fast.layout == ref.layout
            np.testing.assert_array_equal(fast.idx_sh, ref.idx_sh)
            np.testing.assert_array_equal(fast.flt_sh, ref.flt_sh)
            np.testing.assert_array_equal(fast.msk_sh, ref.msk_sh)
            np.testing.assert_array_equal(fast.idx_rep, ref.idx_rep)
            np.testing.assert_array_equal(fast.msk_rep, ref.msk_rep)
            assert fast.n_halo_rows == ref.n_halo_rows
        g_cur = g_new


# ---------------------------------------------------------------------- #
# per-shard Pallas delta scatter (interpret mode on CPU)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["gcn", "gat"])
def test_sharded_pallas_delta_flag_equivalence(name):
    """ShardedRTECEngine with the per-shard block-CSR delta_agg schedule
    must match the XLA segment-sum path exactly (CPU: interpret=True) —
    previously the sharded path silently fell back to XLA."""
    S = jax.device_count()
    x, wl = _mk_stream(n=120, num_batches=5, seed=23, feature_dim=8)
    model = make_model(name)
    params = model.init_layers(jax.random.PRNGKey(9), [8, 8, 8])
    xla = ShardedRTECEngine(model, params, wl.base, x, num_shards=S,
                            use_pallas_delta=False)
    pal = ShardedRTECEngine(model, params, wl.base, x, num_shards=S,
                            use_pallas_delta=True)
    for b in wl.batches:
        xla.apply_batch(b)
        pal.apply_batch(b)
    # the one-hot-MXU matmul sums each tile in blocked order, so the two
    # paths differ only by float summation order (same bound as the
    # single-device flag test)
    np.testing.assert_allclose(xla.embeddings, pal.embeddings, atol=1e-6)
    for l in range(2):
        np.testing.assert_allclose(xla.a[l], pal.a[l], atol=1e-6)


def test_sharded_pallas_schedules_stacked_and_bucketed():
    """Per-shard schedules must stack to one [S, cap] triple per layer with
    a pow-2, DELTA_BE-aligned capacity shared by every shard (one trace per
    ShardedLayout is the contract)."""
    from repro.kernels.delta_agg import DELTA_BE

    x, wl = _mk_stream(n=150, num_batches=4, seed=25)
    model = make_model("gcn")
    b = wl.batches[0]
    plan = _plan_for(model, wl, b)
    sp = shard_plan(plan, 4, pallas=True)
    assert sp.layout.pallas_ecaps is not None
    assert len(sp.pallas_sh) == len(plan.layers)
    for (perm, dloc, brows), cap in zip(sp.pallas_sh, sp.layout.pallas_ecaps):
        assert perm.shape == (4, cap) and dloc.shape == (4, cap)
        assert cap % DELTA_BE == 0 and cap & (cap - 1) == 0
        assert brows.shape == (4, cap // DELTA_BE)
        assert np.all(np.diff(brows, axis=1) >= 0)
    # layouts with and without schedules are distinct trace keys
    assert shard_plan(plan, 4, pallas=False).layout != sp.layout


# ---------------------------------------------------------------------- #
# capacity hysteresis (mid-stream retrace damping)
# ---------------------------------------------------------------------- #
def test_bucket_hysteresis_caps_are_monotone():
    """With a shared BucketHysteresis, packed capacities never shrink over a
    stream, so a shrinking batch reuses the previous PackedLayout instead of
    retracing the fused step."""
    x, wl = _mk_stream(n=150, num_batches=8, seed=7)
    model = make_model("gcn")
    hwm = BucketHysteresis()
    g_cur = wl.base
    prev_caps = None
    layouts = set()
    for b in wl.batches:
        g_new = g_cur.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                                    b.ins_weights, b.ins_etypes)
        plan = build_plan(model, g_cur, g_new, b, 2)
        packed = pack_plan(plan, hwm=hwm)
        if prev_caps is not None:
            for caps, prev in zip(packed.layout.caps, prev_caps):
                assert all(c >= p for c, p in zip(caps, prev)), "cap shrank"
        prev_caps = packed.layout.caps
        layouts.add(packed.layout)
        g_cur = g_new
    # distinct layouts are bounded by growth events, not by batch count
    assert len(layouts) < len(wl.batches)


def test_hysteresis_padding_is_semantically_inert():
    """A plan packed at hysteresis-grown capacities must produce the same
    embeddings as the same stream packed at exact capacities."""
    x, wl = _mk_stream(n=120, num_batches=6, seed=8, feature_dim=8)
    model = make_model("gat")
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8, 8])
    plain = RTECEngine(model, params, wl.base, jnp.asarray(x))
    # seed the hysteresis with a large artificial high-water mark so every
    # subsequent batch runs at grown capacities
    grown = RTECEngine(model, params, wl.base, jnp.asarray(x))
    for l in range(2):
        for kind in range(5):
            grown._hwm.bucket((l, kind), 512)
    for b in wl.batches:
        plain.apply_batch(b)
        grown.apply_batch(b)
    np.testing.assert_allclose(np.asarray(plain.embeddings),
                               np.asarray(grown.embeddings), atol=1e-6)


# ---------------------------------------------------------------------- #
# sharded engine ≡ single-device engine
# ---------------------------------------------------------------------- #
def test_sharded_engine_matches_single_device_inprocess():
    """Adaptive in-process check: uses however many devices this process
    has (1 locally; 8 in the CI suite, which forces host devices)."""
    S = jax.device_count()
    x, wl = _mk_stream(n=120, num_batches=10, seed=9, feature_dim=8)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(1), [8, 8, 8])
    ref = RTECEngine(model, params, wl.base, jnp.asarray(x))
    sh = ShardedRTECEngine(model, params, wl.base, x, num_shards=S)
    for b in wl.batches:
        ref.apply_batch(b)
        sh.apply_batch(b)
    np.testing.assert_array_equal(np.asarray(ref.embeddings), sh.embeddings)


def test_sharded_refresh_keeps_stream_feature_updates():
    """refresh() must recompute from the *current* features (layer-0 updates
    applied mid-stream live in the h[0] blocks, not the construction-time x)
    — matching RTECEngine's refresh semantics."""
    S = jax.device_count()
    x, wl = _mk_stream(n=100, num_batches=6, seed=13, feature_dim=8)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(3), [8, 8, 8])
    ref = RTECEngine(model, params, wl.base, jnp.asarray(x), refresh_every=3)
    sh = ShardedRTECEngine(model, params, wl.base, x, num_shards=S,
                           refresh_every=3)
    for b in wl.batches:
        ref.apply_batch(b)
        sh.apply_batch(b)
    np.testing.assert_allclose(np.asarray(ref.embeddings), sh.embeddings,
                               atol=1e-6)


def test_stream_mesh_rejects_oversubscription():
    from repro.dist import stream_mesh

    with pytest.raises(ValueError, match="num_shards"):
        stream_mesh(jax.device_count() + 1)


def test_multi_axis_dp_config_shards_on_the_mesh_axis():
    """A multi-pod ShardingConfig (dp_axes spanning several mesh axes) must
    still drive the 1-D stream mesh: stream_state_specs restricts the
    graph_rows rule to the axes the mesh actually has."""
    from repro.dist.sharding import ShardingConfig, stream_mesh, stream_state_specs

    shcfg = ShardingConfig(dp_axes=("pod", "data"))
    mesh = stream_mesh(jax.device_count(), shcfg)
    specs = stream_state_specs(mesh, shcfg)
    assert specs["state"].spec == jax.sharding.PartitionSpec("pod", None, None)
    x, wl = _mk_stream(n=80, num_batches=3, seed=17)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(4), [8, 8, 8])
    ref = RTECEngine(model, params, wl.base, jnp.asarray(x))
    sh = ShardedRTECEngine(model, params, wl.base, x,
                           num_shards=jax.device_count(), shcfg=shcfg)
    for b in wl.batches:
        ref.apply_batch(b)
        sh.apply_batch(b)
    np.testing.assert_array_equal(np.asarray(ref.embeddings), sh.embeddings)


def test_sharded_apply_stream_matches_apply_batch():
    S = jax.device_count()
    x, wl = _mk_stream(n=100, num_batches=6, seed=11)
    model = make_model("gat")
    params = model.init_layers(jax.random.PRNGKey(2), [8, 8, 8])
    seq = ShardedRTECEngine(model, params, wl.base, x, num_shards=S)
    pipe = ShardedRTECEngine(model, params, wl.base, x, num_shards=S)
    for b in wl.batches:
        seq.apply_batch(b)
    ss = pipe.apply_stream(wl.batches)
    np.testing.assert_array_equal(seq.embeddings, pipe.embeddings)
    assert len(ss.batches) == len(wl.batches)
    assert ss.wall_s > 0 and ss.plan_s > 0


_SUBPROCESS_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
"""


def test_sharded_equivalence_8dev_20batches_subprocess():
    """The PR's acceptance invariant: ShardedRTECEngine on a forced
    8-host-device mesh matches the single-device RTECEngine over a 20-batch
    stream — exact for gcn, allclose for gat — and actually exchanges a
    nonzero frontier."""
    code = _SUBPROCESS_PRELUDE + textwrap.dedent("""
    from repro.core import RTECEngine, ShardedRTECEngine, make_model
    from repro.graph import make_graph, make_stream
    from repro.graph.generators import random_features

    assert jax.device_count() == 8
    g = make_graph("powerlaw", 120, avg_degree=5, seed=0, weighted=True)
    x, _ = random_features(120, 8, seed=0)
    wl = make_stream(g, num_batches=20, batch_edges=8, delete_frac=0.35,
                     seed=1, feature_dim=8, feature_frac=0.02)
    for name, tol in (("gcn", 0.0), ("gat", 2e-4)):
        model = make_model(name)
        params = model.init_layers(jax.random.PRNGKey(0), [8, 8, 8])
        ref = RTECEngine(model, params, wl.base, jnp.asarray(x))
        sh = ShardedRTECEngine(model, params, wl.base, x, num_shards=8)
        for b in wl.batches:
            ref.apply_batch(b)
            sh.apply_batch(b)
        diff = float(np.abs(np.asarray(ref.embeddings) - sh.embeddings).max())
        assert sh.halo_rows_total > 0, name
        if tol == 0.0:
            assert diff == 0.0, f"{name}: {diff}"
        else:
            assert diff < tol, f"{name}: {diff}"
        print(name, "ok", diff, "halo", sh.halo_rows_total)
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=Path(__file__).resolve().parents[1], timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    print(out.stdout)
