"""Assigned-architecture configs must match the assignment table exactly."""
import pytest

from repro.configs import ARCH_NAMES, SHAPES, get_arch

SPEC = {
    #                 L    d_model heads kv   d_ff   vocab
    "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
    "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
    "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
    "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
    "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
    "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 0, 151936),
    "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 0, 163840),
}


def test_all_ten_archs_present():
    assert set(ARCH_NAMES) == set(SPEC)


@pytest.mark.parametrize("name", list(SPEC))
def test_config_matches_assignment(name):
    cfg = get_arch(name)
    L, d, h, kv, ff, v = SPEC[name]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v


def test_moe_specs():
    q = get_arch("qwen3-moe-30b-a3b")
    assert (q.num_experts, q.top_k, q.moe_d_ff) == (128, 8, 768)
    m = get_arch("moonshot-v1-16b-a3b")
    assert (m.num_experts, m.top_k, m.moe_d_ff) == (64, 6, 1408)


def test_special_flags():
    assert get_arch("qwen2.5-3b").qkv_bias
    assert get_arch("hymba-1.5b").ssm_state == 16
    assert get_arch("hymba-1.5b").block_pattern == "hymba"
    assert get_arch("xlstm-1.3b").block_pattern == "xlstm"
    assert get_arch("seamless-m4t-large-v2").encdec
    assert get_arch("pixtral-12b").num_patches > 0
    # long-context capability per assignment (sub-quadratic only)
    long_ok = {n for n in ARCH_NAMES if get_arch(n).supports_long_context}
    assert long_ok == {"xlstm-1.3b", "hymba-1.5b"}


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768 and SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].seq_len == 32768 and SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288 and SHAPES["long_500k"].global_batch == 1
