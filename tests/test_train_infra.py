"""Training substrate: optimizer/WSD, checkpointing (atomic/async/elastic),
fault tolerance (rollback, failure injection, stragglers), gradient
compression (error feedback + convergence), trainer end-to-end."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced_config
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import compress_grads, init_state
from repro.train.fault import (
    FaultConfig,
    FaultTolerantRunner,
    StragglerMonitor,
    WorkerFailure,
)
from repro.train.optimizer import OptConfig, adamw_init, adamw_update, wsd_schedule
from repro.train.trainer import TrainConfig, Trainer, synthetic_batch


# ---------------------------------------------------------------------- #
# optimizer
# ---------------------------------------------------------------------- #
def test_wsd_schedule_phases():
    cfg = OptConfig(peak_lr=1.0, warmup_steps=10, stable_steps=20, decay_steps=10,
                    min_lr_frac=0.1)
    lrs = [float(wsd_schedule(jnp.asarray(s), cfg)) for s in range(45)]
    assert lrs[0] == 0.0 and lrs[5] == pytest.approx(0.5)
    assert lrs[15] == pytest.approx(1.0) and lrs[29] == pytest.approx(1.0)
    assert lrs[35] < 1.0 and lrs[44] == pytest.approx(0.1, abs=1e-3)


def test_adamw_reduces_quadratic():
    key = jax.random.PRNGKey(0)
    w_true = jax.random.normal(key, (8,))
    params = {"w": jnp.zeros(8)}
    opt = adamw_init(params)
    cfg = OptConfig(peak_lr=0.1, warmup_steps=5, stable_steps=200, decay_steps=5,
                    weight_decay=0.0)

    def loss(p):
        return jnp.sum((p["w"] - w_true) ** 2)

    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, cfg)
    assert float(loss(params)) < 1e-2


# ---------------------------------------------------------------------- #
# checkpointing
# ---------------------------------------------------------------------- #
def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 6)), "b": {"c": jnp.arange(5.0)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(3, t)
    restored, step = mgr.restore(t)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t["a"]))


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]
    r, s = mgr.restore(_tree())
    assert s == 4
    np.testing.assert_array_equal(np.asarray(r["a"]), np.asarray(_tree(4)["a"]))


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore under different shardings (elastic restart path)."""
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(1, t)
    dev = jax.devices()[0]
    sh = jax.tree.map(lambda _: jax.sharding.SingleDeviceSharding(dev), t)
    restored, _ = mgr.restore(t, shardings=sh)
    assert restored["a"].sharding == jax.sharding.SingleDeviceSharding(dev)


# ---------------------------------------------------------------------- #
# fault tolerance
# ---------------------------------------------------------------------- #
def test_runner_rolls_back_on_nan(tmp_path):
    injected = {"done": False}

    def step(state, batch):
        # inject a NaN exactly once at step 7
        if int(state["s"]) == 7 and not injected["done"]:
            injected["done"] = True
            return state, jnp.asarray(float("nan"))
        return {"s": state["s"] + 1}, jnp.asarray(1.0)

    mgr = CheckpointManager(str(tmp_path))
    runner = FaultTolerantRunner(step, mgr, FaultConfig(checkpoint_every=5))
    state, step_n = runner.run({"s": jnp.asarray(0)}, lambda s: None, 10)
    assert step_n == 10 and runner.restarts == 1
    assert int(state["s"]) == 10


def test_runner_survives_worker_failure(tmp_path):
    fail_at = {"left": 2}

    def step(state, batch):
        if int(state["s"]) == 4 and fail_at["left"] > 0:
            fail_at["left"] -= 1
            raise WorkerFailure("node-17 heartbeat lost")
        return {"s": state["s"] + 1}, jnp.asarray(0.5)

    mgr = CheckpointManager(str(tmp_path))
    runner = FaultTolerantRunner(step, mgr, FaultConfig(checkpoint_every=2))
    state, n = runner.run({"s": jnp.asarray(0)}, lambda s: None, 8)
    assert n == 8 and runner.restarts == 2


def test_runner_gives_up_after_max_restarts(tmp_path):
    def step(state, batch):
        raise WorkerFailure("flapping node")

    mgr = CheckpointManager(str(tmp_path))
    runner = FaultTolerantRunner(step, mgr, FaultConfig(max_restarts=2))
    with pytest.raises(RuntimeError, match="max_restarts"):
        runner.run({"s": jnp.asarray(0)}, lambda s: None, 5)


def test_straggler_monitor():
    mon = StragglerMonitor(4, FaultConfig(straggler_factor=2.0, ema=0.5))
    for _ in range(10):
        for w, dt in enumerate([0.1, 0.1, 0.1, 0.5]):
            mon.record(w, dt)
    assert mon.stragglers() == [3]


# ---------------------------------------------------------------------- #
# compression
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("method", ["int8", "topk"])
def test_compression_error_feedback_converges(method):
    key = jax.random.PRNGKey(1)
    w_true = jax.random.normal(key, (32,))
    params = {"w": jnp.zeros(32)}
    opt = adamw_init(params)
    ocfg = OptConfig(peak_lr=0.05, warmup_steps=5, stable_steps=400, decay_steps=5,
                     weight_decay=0.0)
    cstate = init_state(params)

    def loss(p):
        return jnp.sum((p["w"] - w_true) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        g, cstate, wire = compress_grads(g, cstate, method=method, topk_frac=0.25)
        params, opt, _ = adamw_update(g, opt, params, ocfg)
    assert float(loss(params)) < 5e-2, method


def test_int8_wire_reduction():
    g = {"w": jnp.ones((1000,))}
    _, _, wire = compress_grads(g, init_state(g), method="int8")
    assert wire == 1000  # 1 byte per element vs 4 for fp32


# ---------------------------------------------------------------------- #
# trainer end-to-end (loss must go down on learnable synthetic data)
# ---------------------------------------------------------------------- #
def test_trainer_loss_decreases():
    cfg = reduced_config(get_arch("llama3.2-1b"))
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=32, d_ff=64,
                              num_heads=2, num_kv_heads=2, head_dim=16)
    t = Trainer(cfg, TrainConfig(steps=60, batch=8, seq_len=32, log_every=10),
                OptConfig(peak_lr=3e-3, warmup_steps=10, stable_steps=60, decay_steps=10))
    out = t.train()
    assert out["losses"][-1] < out["losses"][0] - 0.5, out["losses"]


def test_trainer_with_checkpointing(tmp_path):
    cfg = reduced_config(get_arch("llama3.2-1b"))
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=32, d_ff=64,
                              num_heads=2, num_kv_heads=2, head_dim=16)
    t = Trainer(cfg, TrainConfig(steps=20, batch=4, seq_len=16,
                                 checkpoint_dir=str(tmp_path), checkpoint_every=10))
    out = t.train()
    assert out["steps"] == 20
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.latest_step() == 20


def test_trainer_microbatch_equivalence():
    """Gradient accumulation must not change the loss trajectory (much)."""
    cfg = reduced_config(get_arch("llama3.2-1b"))
    cfg = dataclasses.replace(cfg, num_layers=1, d_model=32, d_ff=64,
                              num_heads=2, num_kv_heads=2, head_dim=16)
    t1 = Trainer(cfg, TrainConfig(steps=10, batch=8, seq_len=16, microbatches=1))
    t2 = Trainer(cfg, TrainConfig(steps=10, batch=8, seq_len=16, microbatches=4))
    o1, o2 = t1.train(), t2.train()
    assert abs(o1["losses"][-1] - o2["losses"][-1]) < 0.15


def test_synthetic_batch_deterministic():
    cfg = reduced_config(get_arch("llama3.2-1b"))
    tcfg = TrainConfig(batch=4, seq_len=16)
    b1 = synthetic_batch(cfg, tcfg, 7)
    b2 = synthetic_batch(cfg, tcfg, 7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
