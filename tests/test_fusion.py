"""Batch-window fusion (ISSUE 9): the fused≡serial bitwise matrix, exact
fusion counters, versioned snapshot reads across fused windows, and the
hysteresis no-retrace guarantee.

The contract under test: merging runs of consecutive batches with
pairwise-disjoint plan footprints into ONE packed plan / ONE device
dispatch is *bitwise* invisible — embeddings, per-layer state, and every
frontend snapshot read match the unfused serial loop on every backend,
with async staging on or off — while the dispatch count drops by exactly
``fused_batches - fusion_windows``.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.core import ExecutionPolicy, make_model
from repro.core.affected import (
    BucketHysteresis,
    FusionConfig,
    FusionWindow,
    build_plan,
    pack_plan,
)
from repro.graph.csr import CSRGraph
from repro.graph.streaming import UpdateBatch
from repro.serve import EngineConfig, ServingFrontend, StagingConfig, create_engine

L_DIMS = [8, 8]  # two layers, d=8


# ---------------------------------------------------------------------- #
# deterministic stream construction: far-apart regions fuse, clustered
# regions force serial fallback
# ---------------------------------------------------------------------- #
def _ring_graph(n: int) -> CSRGraph:
    """Ring lattice (in-edges from i+1, i+2): footprints of updates in
    regions ≥ ~10 rows apart are provably disjoint at L=2."""
    src = np.concatenate([(np.arange(n) + 1) % n, (np.arange(n) + 2) % n])
    dst = np.concatenate([np.arange(n), np.arange(n)]).astype(np.int64)
    return CSRGraph.from_edges(n, src.astype(np.int64), dst)


def _region_batch(n, base, rng, d=8, feats=True):
    """One insert + optional feature update confined to rows [base, base+8)."""
    ins_s = np.array([(base + 1) % n], np.int64)
    ins_d = np.array([(base + 5) % n], np.int64)
    fv = np.array([(base + 7) % n], np.int64) if feats else None
    return UpdateBatch(
        ins_src=ins_s, ins_dst=ins_d,
        del_src=np.array([], np.int64), del_dst=np.array([], np.int64),
        feat_vertices=fv,
        feat_values=(rng.standard_normal((1, d)).astype(np.float32)
                     if feats else None))


def _mixed_stream(n=600, seed=0):
    """20 batches: a fusable run (far-apart regions), a forced-overlap run
    (all batches hammer one hub region), then a fusable run again."""
    rng = np.random.default_rng(seed)
    batches = []
    for i in range(8):  # fusable: regions 60 rows apart
        batches.append(_region_batch(n, (i * 60) % n, rng))
    for i in range(4):  # forced overlap: everyone hammers rows ~[15, 27)
        batches.append(_region_batch(n, 15 + i, rng))
    for i in range(8):  # fusable again (offset to fresh regions)
        batches.append(_region_batch(n, (i * 60 + 30) % n, rng))
    return batches


def _fusable_stream(n=600, seed=0, num=8):
    rng = np.random.default_rng(seed)
    return [_region_batch(n, (i * 45) % n, rng) for i in range(num)]


def _engine(kind, model, g, x, params, fused, async_staging=True, **kw):
    shards = {"num_shards": jax.device_count()} if "sharded" in kind else {}
    return create_engine(kind, EngineConfig(
        model=model, graph=g, x=x, params=params,
        staging=StagingConfig(async_enabled=async_staging),
        fusion=FusionConfig(window=4) if fused else None, **shards, **kw))


def _state_of(eng):
    emb = np.array(np.asarray(eng.embeddings))
    try:
        hs = [np.array(np.asarray(h)) for h in eng.h]
    except AttributeError:  # device backend facade exposes h differently
        hs = []
    return emb, hs


# ---------------------------------------------------------------------- #
# the acceptance matrix: fused ≡ serial, bitwise, everywhere
# ---------------------------------------------------------------------- #
_CELLS = [(k, a, m)
          for k in ("device", "offload", "sharded", "sharded_offload",
                    "chunked")
          # async staging exists only on the host-resident pair; other
          # substrates ignore the flag, so one cell each suffices
          for a in ((False, True) if "offload" in k else (True,))
          for m in ("gcn", "gat")]


@pytest.mark.parametrize("kind,async_staging,name", _CELLS)
def test_fused_bitwise_equals_serial_matrix(kind, async_staging, name):
    """20-batch mixed stream (forced-fusable + forced-overlapping
    segments): the fused orchestrator must produce bitwise-identical
    embeddings AND per-layer host state, fuse the independent runs, and
    fall back serially on the overlapping ones."""
    n = 600
    g = _ring_graph(n)
    x = np.random.default_rng(3).standard_normal((n, 8)).astype(np.float32)
    model = make_model(name)
    params = model.init_layers(jax.random.PRNGKey(0), L_DIMS)
    batches = _mixed_stream(n, seed=7)
    runs = {}
    for fused in (False, True):
        eng = _engine(kind, model, g, x, params, fused,
                      async_staging=async_staging)
        ss = eng._orch.apply_stream(batches)
        runs[fused] = (_state_of(eng), ss)
    (emb_s, hs_s), ss_s = runs[False]
    (emb_f, hs_f), ss_f = runs[True]
    np.testing.assert_array_equal(emb_s, emb_f)
    for h0, h1 in zip(hs_s, hs_f):
        np.testing.assert_array_equal(h0, h1)
    # the serial loop never fuses; the fused loop must actually fuse the
    # independent runs and fall back on the clustered one
    assert (ss_s.fusion_windows, ss_s.fused_batches) == (0, 0)
    assert ss_f.fusion_windows >= 4  # two fusable runs of 8, window=4
    assert ss_f.fused_batches >= 16
    assert ss_f.fusion_fallbacks > 0  # the clustered segment broke up
    assert len(ss_f.batches) == len(batches)


# ---------------------------------------------------------------------- #
# counter exactness: the greedy reference predicts the loop's counters
# ---------------------------------------------------------------------- #
def _reference_counters(model, g, batches, window, L=2):
    """Independent greedy simulation of the lookahead loop over serially
    built plans: returns (windows, fused, fallbacks, dispatches)."""
    fw = FusionWindow(FusionConfig(window=window))
    pend = []
    g_cur = g
    for b in batches:
        g_new = g_cur.apply_updates(b.ins_src, b.ins_dst, b.del_src,
                                    b.del_dst, b.ins_weights, b.ins_etypes)
        plan = build_plan(model, g_cur, g_new, b, L)
        pend.append(FusionWindow.footprint(plan, b))
        g_cur = g_new
    windows = fused = fallbacks = dispatches = 0
    i = 0
    while i < len(pend):
        k = fw.select_prefix(pend[i:i + window])
        if k >= 2:
            windows += 1
            fused += k
            dispatches += 1
            i += k
        else:
            if len(pend) - i >= 2:
                fallbacks += 1
            dispatches += 1
            i += 1
    return windows, fused, fallbacks, dispatches


def test_fusion_counters_exact_against_greedy_reference():
    n = 600
    g = _ring_graph(n)
    x = np.random.default_rng(1).standard_normal((n, 8)).astype(np.float32)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), L_DIMS)
    batches = _mixed_stream(n, seed=11)
    exp_w, exp_f, exp_fb, exp_disp = _reference_counters(model, g, batches, 4)
    eng = _engine("device", model, g, x, params, fused=True)
    ss = eng._orch.apply_stream(batches)
    assert ss.fusion_windows == exp_w
    assert ss.fused_batches == exp_f
    assert ss.fusion_fallbacks == exp_fb
    # dispatch-count identity: every fused window saves (k - 1) dispatches
    assert (len(batches) - (ss.fused_batches - ss.fusion_windows)
            == exp_disp)
    # per-constituent flags: each batch reports the width of the dispatch
    # it rode in, and the window's dispatch time sits on its first member
    widths = [b.fused_window for b in ss.batches]
    assert sum(1 for w in widths if w == 1) == len(batches) - exp_f
    assert sum(1.0 / w for w in widths) == pytest.approx(exp_disp)
    j = 0
    while j < len(widths):
        if widths[j] > 1:
            k = widths[j]
            assert widths[j:j + k] == [k] * k
            assert all(ss.batches[j + m].exec_time_s == 0.0
                       for m in range(1, k))
            j += k
        else:
            j += 1


def test_fully_fusable_stream_exact_counters():
    """8 far-apart batches, window 4 → exactly two 4-wide windows."""
    n = 600
    g = _ring_graph(n)
    x = np.random.default_rng(2).standard_normal((n, 8)).astype(np.float32)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), L_DIMS)
    eng = _engine("offload", model, g, x, params, fused=True)
    ss = eng._orch.apply_stream(_fusable_stream(n, seed=4, num=8))
    assert (ss.fusion_windows, ss.fused_batches, ss.fusion_fallbacks) \
        == (2, 8, 0)
    assert [b.fused_window for b in ss.batches] == [4] * 8


def test_fusion_never_spans_refresh_boundary():
    """refresh_every=3 with window=4: every window is capped at the
    refresh cadence, so no fused constituent crosses a state rebuild —
    and the result stays bitwise equal to the serial refreshing run."""
    n = 600
    g = _ring_graph(n)
    x = np.random.default_rng(5).standard_normal((n, 8)).astype(np.float32)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), L_DIMS)
    batches = _fusable_stream(n, seed=9, num=9)
    runs = {}
    for fused in (False, True):
        eng = _engine("device", model, g, x, params, fused, refresh_every=3)
        ss = eng._orch.apply_stream(batches)
        runs[fused] = (np.array(np.asarray(eng.embeddings)), ss)
    np.testing.assert_array_equal(runs[False][0], runs[True][0])
    ss = runs[True][1]
    assert all(b.fused_window <= 3 for b in ss.batches)
    assert ss.fused_batches == 9  # 3-wide windows aligned to the cadence
    assert ss.fusion_windows == 3


def test_config_off_switches_are_inert():
    """window=1 / enabled=False → the serial loop, counters all zero."""
    n = 300
    g = _ring_graph(n)
    x = np.random.default_rng(6).standard_normal((n, 8)).astype(np.float32)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), L_DIMS)
    batches = _fusable_stream(n, seed=3, num=4)
    ref = None
    for fusion in (None, FusionConfig(window=1),
                   FusionConfig(window=4, enabled=False)):
        eng = create_engine("device", EngineConfig(
            model=model, graph=g, x=x, params=params, fusion=fusion))
        ss = eng._orch.apply_stream(batches)
        assert (ss.fusion_windows, ss.fused_batches,
                ss.fusion_fallbacks) == (0, 0, 0)
        emb = np.array(np.asarray(eng.embeddings))
        if ref is None:
            ref = emb
        else:
            np.testing.assert_array_equal(ref, emb)
    with pytest.raises(ValueError, match="window"):
        FusionConfig(window=0)


def test_fusion_disabled_under_per_batch_force_schedule():
    """A per-batch force_mode schedule is indexed by logical batch; the
    orchestrator must take the serial loop (and still satisfy it)."""
    n = 300
    g = _ring_graph(n)
    x = np.random.default_rng(8).standard_normal((n, 8)).astype(np.float32)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), L_DIMS)
    batches = _fusable_stream(n, seed=2, num=4)
    pol = ExecutionPolicy(force_mode=["incremental"] * 4)
    eng = create_engine("device", EngineConfig(
        model=model, graph=g, x=x, params=params, policy=pol,
        fusion=FusionConfig(window=4)))
    ss = eng._orch.apply_stream(batches)
    assert (ss.fusion_windows, ss.fused_batches) == (0, 0)
    with pytest.raises(ValueError, match="force_mode"):
        pol2 = ExecutionPolicy(force_mode=["incremental"])
        g2 = g.apply_updates(batches[0].ins_src, batches[0].ins_dst,
                             batches[0].del_src, batches[0].del_dst)
        pol2.decide_window(build_plan(model, g, g2, batches[0], 2))


# ---------------------------------------------------------------------- #
# frontend: one version per logical batch, snapshot reads stay bitwise
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("kind", ["device", "offload"])
def test_frontend_snapshot_reads_across_fused_windows(kind):
    """Every retained version remains bitwise-readable through fused
    windows: the frontend records one undo record per *logical* batch
    with pre-images captured against the pre-window state."""
    n = 600
    g = _ring_graph(n)
    x = np.random.default_rng(4).standard_normal((n, 8)).astype(np.float32)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), L_DIMS)
    batches = _mixed_stream(n, seed=13)
    rows = np.arange(0, n, 13)

    # serial per-version references
    ref_eng = _engine(kind, model, g, x, params, fused=False)
    refs = [np.array(ref_eng.snapshot_rows(rows))]
    for b in batches:
        ref_eng.apply_batch(b)
        refs.append(np.array(ref_eng.snapshot_rows(rows)))

    fr = ServingFrontend(_engine(kind, model, g, x, params, fused=True),
                         max_pending_reads=512,
                         max_versions=len(batches) + 1)
    ss = fr.run_stream(batches)
    assert fr.version == len(batches)
    assert ss.fusion_windows >= 4 and ss.fused_batches >= 16
    for v in range(len(batches) + 1):
        np.testing.assert_array_equal(fr.read(rows, version=v), refs[v])


def test_frontend_fused_respects_refresh_floor():
    """Across a refresh the fused frontend drops undo history exactly like
    the serial one: floors match, retained reads match, stale pins raise."""
    from repro.serve import StaleVersionError

    n = 600
    g = _ring_graph(n)
    x = np.random.default_rng(9).standard_normal((n, 8)).astype(np.float32)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), L_DIMS)
    batches = _fusable_stream(n, seed=1, num=8)
    rows = np.arange(0, n, 17)
    frs = {}
    for fused in (False, True):
        fr = ServingFrontend(
            _engine("offload", model, g, x, params, fused, refresh_every=4),
            max_versions=len(batches) + 1)
        fr.run_stream(batches)
        frs[fused] = fr
    assert frs[True].min_version == frs[False].min_version == 8
    np.testing.assert_array_equal(frs[True].read(rows, version=8),
                                  frs[False].read(rows, version=8))
    with pytest.raises(StaleVersionError):
        frs[True].read(rows, version=7)


# ---------------------------------------------------------------------- #
# hysteresis: fused/serial shape alternation must not retrace mid-stream
# ---------------------------------------------------------------------- #
def test_fused_shapes_flow_through_shared_hysteresis():
    """Packing merged plans and single plans through one BucketHysteresis:
    caps never shrink, and once the fused high-water mark is set, single
    plans re-use already-seen layouts instead of oscillating."""
    n = 600
    g = _ring_graph(n)
    model = make_model("gcn")
    batches = _fusable_stream(n, seed=6, num=12)
    plans = []
    g_cur = g
    for b in batches:
        g_new = g_cur.apply_updates(b.ins_src, b.ins_dst, b.del_src,
                                    b.del_dst, b.ins_weights, b.ins_etypes)
        plans.append((build_plan(model, g_cur, g_new, b, 2), b))
        g_cur = g_new
    hwm = BucketHysteresis()
    layouts = []
    prev_caps = None

    def pack(plan, batch):
        nonlocal prev_caps
        packed = pack_plan(plan, batch.feat_vertices, batch.feat_values,
                           hwm=hwm)
        if prev_caps is not None:
            for caps, prev in zip(packed.layout.caps, prev_caps):
                assert all(c >= p for c, p in zip(caps, prev)), "cap shrank"
        prev_caps = packed.layout.caps
        layouts.append(packed.layout)

    # alternate: fused window of 4, then two singles, twice over
    for lo in (0, 6):
        quad = plans[lo:lo + 4]
        merged_plan, merged_batch = FusionWindow.merge(
            [p for p, _ in quad], [b for _, b in quad])
        pack(merged_plan, merged_batch)
        for p, b in plans[lo + 4:lo + 6]:
            pack(p, b)
    # second round introduces NO new layouts: the first fused window set
    # the high-water mark for both shapes (no fused↔serial oscillation)
    assert set(layouts[3:]) <= set(layouts[:3])


def test_fused_stream_hwm_stabilizes_no_retrace():
    """Engine-level no-retrace: over a periodic fusable stream the device
    backend's capacity high-water marks stop growing after the first
    period — every later dispatch reuses an existing packed layout (and
    therefore an existing trace)."""
    n = 600
    g = _ring_graph(n)
    x = np.random.default_rng(12).standard_normal((n, 8)).astype(np.float32)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), L_DIMS)
    rng = np.random.default_rng(21)
    stream = []
    for rep in range(4):  # same shapes every period, fresh regions
        stream += [_region_batch(n, (i * 60 + rep * 7) % n, rng)
                   for i in range(4)]
    eng = _engine("device", model, g, x, params, fused=True)
    orch = eng._orch
    orch.apply_stream(stream[:4])
    caps_after_warmup = eng._backend.hwm.snapshot()
    ss = orch.apply_stream(stream[4:])
    assert ss.fused_batches == 12  # every later window fused
    assert eng._backend.hwm.snapshot() == caps_after_warmup, \
        "capacity HWM grew mid-stream → a retrace happened"
