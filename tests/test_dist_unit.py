"""Single-device unit coverage for `repro.dist`: `tree_shardings` over a
real `init_model` Param tree, `ashard` identity behaviour outside an
`activation_sharding` context, and the ZeRO-1 optimizer-state layout.

Runs on the one real CPU device (a 1×1 mesh) — the multi-device paths live
in `tests/test_dist.py` subprocesses."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs import get_arch, reduced_config
from repro.dist.ctx import activation_sharding, ashard
from repro.dist.sharding import (
    ShardingConfig,
    batch_specs,
    cache_specs,
    opt_state_specs,
    tree_shardings,
)
from repro.models import init_cache, init_model


def _tiny_cfg():
    return dataclasses.replace(
        reduced_config(get_arch("llama3.2-1b")),
        num_layers=2, d_model=32, d_ff=64, num_heads=4, num_kv_heads=2,
        head_dim=8, vocab_size=128,
    )


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def _specs(sharding_tree):
    return [s.spec for s in jax.tree.leaves(sharding_tree)]


def test_tree_shardings_covers_every_param_leaf():
    cfg = _tiny_cfg()
    params, axes = init_model(jax.random.PRNGKey(0), cfg)
    mesh = _mesh11()
    sh = tree_shardings(axes, mesh, ShardingConfig(fsdp=True), shapes_tree=params)
    leaves = jax.tree.leaves(sh)
    assert leaves, "empty sharding tree"
    assert all(isinstance(s, NamedSharding) for s in leaves)
    # structure matches the param tree exactly
    assert jax.tree.structure(sh) == jax.tree.structure(params)
    # every spec has the rank of its param
    for s, p in zip(leaves, jax.tree.leaves(params)):
        assert len(s.spec) == p.ndim, (s.spec, p.shape)


def test_fsdp_toggle_differs_only_on_dp_axis():
    cfg = _tiny_cfg()
    params, axes = init_model(jax.random.PRNGKey(0), cfg)
    mesh = _mesh11()
    tp_only = _specs(tree_shardings(axes, mesh, ShardingConfig(fsdp=False), shapes_tree=params))
    fsdp = _specs(tree_shardings(axes, mesh, ShardingConfig(fsdp=True), shapes_tree=params))
    assert tp_only != fsdp  # fsdp actually shards something extra
    for spec_tp, spec_fsdp in zip(tp_only, fsdp):
        for entry_tp, entry_fsdp in zip(spec_tp, spec_fsdp):
            if entry_tp != entry_fsdp:
                # the only allowed difference: an embed dim picking up "data"
                assert entry_tp is None and entry_fsdp == "data", (spec_tp, spec_fsdp)


def test_ashard_is_identity_outside_context():
    x = jnp.arange(12.0).reshape(3, 4)
    assert ashard(x, "dp", "tp") is x
    assert ashard(x, None, None) is x


def test_ashard_constrains_inside_context():
    mesh = _mesh11()
    x = jnp.ones((4, 8))
    with activation_sharding(mesh, ShardingConfig()):
        y = jax.jit(lambda t: ashard(t, "dp", "tp") * 2.0)(x)
    np.testing.assert_allclose(np.asarray(y), 2.0 * np.asarray(x))
    # context popped cleanly — identity again
    assert ashard(x, "dp", "tp") is x


def test_opt_state_specs_zero1_matches_fsdp_layout():
    cfg = _tiny_cfg()
    params, axes = init_model(jax.random.PRNGKey(0), cfg)
    mesh = _mesh11()
    # serving-style TP-only params, but moments still take the FSDP layout
    moments = opt_state_specs(axes, mesh, ShardingConfig(fsdp=False), shapes_tree=params)
    fsdp = tree_shardings(axes, mesh, ShardingConfig(fsdp=True), shapes_tree=params)
    assert _specs(moments) == _specs(fsdp)


def test_batch_and_cache_specs_ranks():
    cfg = _tiny_cfg()
    mesh = _mesh11()
    shcfg = ShardingConfig(fsdp=False)
    batch = {"tokens": jnp.zeros((8, 16), jnp.int32), "labels": jnp.zeros((8, 16), jnp.int32)}
    for name, spec in batch_specs(batch, mesh, shcfg).items():
        assert len(spec) == batch[name].ndim
    cache = init_cache(cfg, 8, 32)
    cspecs = cache_specs(cache, mesh, shcfg)
    for leaf, spec in zip(jax.tree.leaves(cache), jax.tree.leaves(cspecs)):
        assert len(spec) == leaf.ndim, (leaf.shape, spec)
