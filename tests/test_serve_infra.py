"""Serving substrate: offloaded engine equivalence + transfer accounting,
chunked scheduler with shard-embedding reuse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RTECEngine, full_forward, make_model
from repro.graph import make_graph, make_stream
from repro.graph.generators import random_features
from repro.serve.offload import OffloadedRTECEngine
from repro.serve.scheduler import ChunkedLayerScheduler

TOL = 2e-4


@pytest.mark.parametrize("name", ["gcn", "sage", "gat", "rgcn"])
def test_offloaded_engine_matches_full(name):
    kw = {"num_relations": 3} if name == "rgcn" else {}
    model = make_model(name, **kw)
    g = make_graph("uniform", 150, avg_degree=5, seed=3, weighted=True, num_etypes=3)
    x, _ = random_features(150, 12, seed=1)
    wl = make_stream(g, num_batches=3, batch_edges=15, delete_frac=0.4,
                     feature_dim=12, feature_frac=0.02, seed=5)
    params = model.init_layers(jax.random.PRNGKey(0), [12, 8, 8])
    eng = OffloadedRTECEngine(model, params, wl.base, x)
    g_cur = wl.base
    x_cur = np.array(x)
    for b in wl.batches:
        eng.apply_batch(b)
        g_cur = g_cur.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                                    b.ins_weights, b.ins_etypes)
        if b.feat_vertices is not None:
            x_cur[b.feat_vertices] = b.feat_values
    ref = full_forward(model, params, jnp.asarray(x_cur), g_cur)
    assert float(np.abs(eng.embeddings - np.asarray(ref[-1].h)).max()) < TOL


def test_offload_transfers_scale_with_affected_not_graph():
    """The point of §V-B: transferred rows ≈ affected set, not |V|."""
    model = make_model("sage")
    g = make_graph("powerlaw", 2000, avg_degree=8, seed=0)
    x, _ = random_features(2000, 16, seed=0)
    wl = make_stream(g, num_batches=1, batch_edges=5, seed=1)
    params = model.init_layers(jax.random.PRNGKey(0), [16, 16, 16])
    eng = OffloadedRTECEngine(model, params, wl.base, x)
    eng.apply_batch(wl.batches[0])
    assert eng.transfers.rows_up < 2000, eng.transfers  # ≪ 2 layers × |V|


def test_offload_matches_inmemory_engine():
    model = make_model("gcn")
    g = make_graph("uniform", 120, avg_degree=5, seed=2)
    x, _ = random_features(120, 8, seed=2)
    wl = make_stream(g, num_batches=2, batch_edges=10, seed=3)
    params = model.init_layers(jax.random.PRNGKey(1), [8, 8, 8])
    e1 = RTECEngine(model, params, wl.base, jnp.asarray(x))
    e2 = OffloadedRTECEngine(model, params, wl.base, x)
    for b in wl.batches:
        e1.apply_batch(b)
        e2.apply_batch(b)
    np.testing.assert_allclose(np.asarray(e1.embeddings), e2.embeddings, atol=1e-5)


# ---------------------------------------------------------------------- #
# chunked scheduler
# ---------------------------------------------------------------------- #
def test_chunked_scheduler_matches_unchunked():
    model = make_model("sage")
    g = make_graph("powerlaw", 300, avg_degree=8, seed=1)
    x, _ = random_features(300, 8, seed=1)
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    ref = full_forward(model, params, jnp.asarray(x), g)[0]
    sched = ChunkedLayerScheduler(model, chunk_size=64)
    rows = np.arange(300, dtype=np.int64)
    a, nct, h = sched.run_layer(params[0], g, x, rows, g.in_degree().astype(np.float32))
    np.testing.assert_allclose(h, np.asarray(ref.h), atol=1e-4)
    np.testing.assert_allclose(a, np.asarray(ref.a), atol=1e-4)
    assert sched.stats.chunks == (300 + 63) // 64


def test_chunk_reuse_reduces_transfers():
    model = make_model("sage")
    g = make_graph("dense", 400, avg_degree=40, seed=2)
    x, _ = random_features(400, 8, seed=2)
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])
    rows = np.arange(400, dtype=np.int64)
    deg = g.in_degree().astype(np.float32)
    with_reuse = ChunkedLayerScheduler(model, chunk_size=64, reuse=True)
    no_reuse = ChunkedLayerScheduler(model, chunk_size=64, reuse=False)
    h1 = with_reuse.run_layer(params[0], g, x, rows, deg)[2]
    h2 = no_reuse.run_layer(params[0], g, x, rows, deg)[2]
    np.testing.assert_allclose(h1, h2, atol=1e-5)
    assert with_reuse.stats.rows_transferred < no_reuse.stats.rows_transferred
    assert with_reuse.stats.reuse_frac > 0.1
