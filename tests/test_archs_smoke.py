"""Per-assigned-architecture smoke tests (reduced configs, CPU):
forward/train step shape + NaN asserts, plus serve-path consistency —
prefill+decode logits must match the full forward at the same positions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_arch, reduced_config
from repro.models import decode_step, forward, init_model, loss_fn, prefill

B, S = 2, 32


def _batch(cfg, rng, s=S):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s))),
    }
    if cfg.encdec:
        batch["frames"] = jnp.asarray(rng.normal(size=(B, s, cfg.d_frontend)).astype(np.float32))
    if cfg.num_patches:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, cfg.d_frontend)).astype(np.float32)
        )
    return batch


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_and_train_step(name, rng):
    cfg = reduced_config(get_arch(name))
    params, axes = init_model(jax.random.PRNGKey(0), cfg)
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple)
    )
    batch = _batch(cfg, rng)
    logits, aux = forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    # one SGD step through the loss must produce finite grads for every leaf
    loss, metrics = loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.all(jnp.isfinite(g))), f"{name}: non-finite grad at {path}"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_matches_forward(name, rng):
    """Teacher-forced decode must reproduce the full forward logits.

    MoE archs run with dropless capacity here: capacity-based token dropping
    is context-dependent by design (GShard semantics), so train-time
    forward and decode only agree exactly when nothing overflows."""
    import dataclasses

    cfg = reduced_config(get_arch(name))
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts) / cfg.top_k)
    if cfg.block_pattern == "hymba":
        # decode rings ALL layers (DESIGN.md §5); exact consistency holds for
        # the pure-SWA mix — the dedicated ring test covers the semantics
        cfg = dataclasses.replace(cfg, full_attn_layers=())
    params, _ = init_model(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg, rng)
    tokens = batch["tokens"]
    full_logits, _ = forward(params, cfg, batch)

    n_steps = 4
    prompt = {**batch, "tokens": tokens[:, : S - n_steps]}
    s_max = S + (cfg.num_patches or 0)  # vlm caches cover patch positions too
    logits, cache = prefill(params, cfg, prompt, s_max=s_max, cache_dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32),
        np.asarray(full_logits[:, S - n_steps - 1], np.float32),
        atol=2e-2, rtol=2e-2,
    )
    for i in range(n_steps):
        tok = tokens[:, S - n_steps + i : S - n_steps + i + 1]
        logits, cache = decode_step(params, cfg, tok, cache)
        if S - n_steps + i < S - 1 or True:
            np.testing.assert_allclose(
                np.asarray(logits[:, 0], np.float32),
                np.asarray(full_logits[:, S - n_steps + i], np.float32),
                atol=2e-2, rtol=2e-2,
                err_msg=f"{name} step {i}",
            )


def test_hymba_ring_cache_matches_window_attention():
    """Long decode with ring cache == forward with sliding-window mask."""
    cfg = reduced_config(get_arch("hymba-1.5b"))
    import dataclasses
    cfg = dataclasses.replace(cfg, full_attn_layers=())  # pure SWA for exactness
    params, _ = init_model(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(3)
    s = 48  # > window (16) → ring wraps
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s)))
    full_logits, _ = forward(params, cfg, {"tokens": tokens})

    n_steps = 8
    logits, cache = prefill(params, cfg, {"tokens": tokens[:, : s - n_steps]},
                            s_max=s, cache_dtype=jnp.float32)
    assert cache.k.shape[3] == cfg.window  # ring buffer, not full length
    for i in range(n_steps):
        tok = tokens[:, s - n_steps + i : s - n_steps + i + 1]
        logits, cache = decode_step(params, cfg, tok, cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, s - n_steps + i], np.float32),
            atol=2e-2, rtol=2e-2, err_msg=f"ring step {i}",
        )


@pytest.mark.parametrize("name", ["xlstm-1.3b", "hymba-1.5b"])
def test_long_context_archs_state_bounded(name):
    """Sub-quadratic archs: decode state must not grow with context length."""
    cfg = reduced_config(get_arch(name))
    assert cfg.supports_long_context
    from repro.models import init_cache

    c_small = init_cache(cfg, 1, 64)
    c_large = init_cache(cfg, 1, 4096)
    small = sum(np.prod(x.shape) for x in jax.tree.leaves(c_small))
    large = sum(np.prod(x.shape) for x in jax.tree.leaves(c_large))
    if name == "xlstm-1.3b":
        assert small == large  # pure state, no KV at all
    else:
        assert large <= small * (cfg.window / 16)  # bounded by ring size


def test_param_count_sanity():
    """Full-size analytic param counts are in the advertised ballpark."""
    counts = {
        "qwen2.5-3b": (2.5e9, 4.2e9),
        "llama3.2-1b": (1.0e9, 1.9e9),
        "pixtral-12b": (10e9, 14e9),
        "qwen3-moe-30b-a3b": (26e9, 34e9),
        # the assigned 48L/64e/1408ff spec computes to ~28B total (the
        # production Moonlight-16B uses 27 layers; we implement the brief)
        "moonshot-v1-16b-a3b": (24e9, 32e9),
        "hymba-1.5b": (1.0e9, 2.2e9),
        "xlstm-1.3b": (1.0e9, 1.9e9),
    }
    for name, (lo, hi) in counts.items():
        n = get_arch(name).param_count()
        assert lo < n < hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params():
    cfg = get_arch("qwen3-moe-30b-a3b")
    active = cfg.active_param_count()
    assert 2e9 < active < 5e9, active  # "a3b" ≈ 3B active
