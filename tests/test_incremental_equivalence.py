"""THE central invariant (paper Theorem 1): incremental RTEC output ==
full-neighbor recomputation from scratch, for every model, over random
insert/delete/feature-update streams.

Property-based via hypothesis over graph topology, stream composition, and
model choice; plus deterministic long-stream drift tests.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property-based tests need hypothesis (pip install -e .[dev])"
)
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import ALL_MODELS, RTECEngine, full_forward, make_model
from repro.graph import make_graph, make_stream
from repro.graph.csr import CSRGraph
from repro.graph.generators import random_features
from repro.graph.streaming import UpdateBatch

TOL = 2e-4


def _mk(name):
    kw = {"num_relations": 3} if name in ("rgcn", "rgat") else {}
    return make_model(name, **kw)


def _run_stream(model, params, wl, x, store_h=True):
    eng = RTECEngine(model, params, wl.base, jnp.asarray(x), store_h=store_h)
    g_cur = wl.base
    x_cur = np.array(x)
    for b in wl.batches:
        eng.apply_batch(b)
        g_cur = g_cur.apply_updates(
            b.ins_src, b.ins_dst, b.del_src, b.del_dst, b.ins_weights, b.ins_etypes
        )
        if b.feat_vertices is not None:
            x_cur[b.feat_vertices] = b.feat_values
    ref = full_forward(model, params, jnp.asarray(x_cur), g_cur)
    return eng, ref, g_cur, x_cur


@pytest.mark.parametrize("name", ALL_MODELS)
def test_stream_equivalence(name):
    g = make_graph("uniform", 120, avg_degree=5, seed=3, weighted=True, num_etypes=3)
    x, _ = random_features(120, 10, seed=1)
    wl = make_stream(g, num_batches=4, batch_edges=12, delete_frac=0.4,
                     feature_dim=10, feature_frac=0.02, seed=5)
    model = _mk(name)
    params = model.init_layers(jax.random.PRNGKey(0), [10, 8, 8])
    eng, ref, _, _ = _run_stream(model, params, wl, x)
    err = float(jnp.abs(eng.embeddings - ref[-1].h).max())
    assert err < TOL, f"{name}: {err}"
    # intermediate states must match too (a, nct per layer)
    for l in range(2):
        assert float(jnp.abs(eng.a[l] - ref[l].a).max()) < TOL
        assert float(jnp.abs(eng.nct[l] - ref[l].nct).max()) < TOL


@pytest.mark.parametrize("name", ["gcn", "gat", "sage"])
def test_three_layer_equivalence(name):
    g = make_graph("powerlaw", 100, avg_degree=6, seed=7)
    x, _ = random_features(100, 8, seed=2)
    wl = make_stream(g, num_batches=3, batch_edges=10, delete_frac=0.3, seed=8)
    model = _mk(name)
    params = model.init_layers(jax.random.PRNGKey(1), [8, 8, 8, 8])
    eng, ref, _, _ = _run_stream(model, params, wl, x)
    assert float(jnp.abs(eng.embeddings - ref[-1].h).max()) < TOL


@pytest.mark.parametrize("store_h", [True, False])
def test_storage_optimization_equivalence(store_h):
    """Recomputation-based storage optimization (§V-B) must not change results."""
    g = make_graph("uniform", 100, avg_degree=5, seed=0)
    x, _ = random_features(100, 8, seed=0)
    wl = make_stream(g, num_batches=3, batch_edges=10, seed=1)
    model = _mk("sage")
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8, 8])
    eng, ref, _, _ = _run_stream(model, params, wl, x, store_h=store_h)
    assert float(jnp.abs(eng.embeddings - ref[-1].h).max()) < TOL


def test_long_stream_drift():
    """Paper reports MSE < 1e-4 between Inc and Full; check fp drift stays
    bounded over a 60-batch stream."""
    g = make_graph("powerlaw", 150, avg_degree=6, seed=0)
    x, _ = random_features(150, 8, seed=0)
    wl = make_stream(g, num_batches=60, batch_edges=8, delete_frac=0.4, seed=3)
    model = _mk("gat")
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8, 8])
    eng, ref, _, _ = _run_stream(model, params, wl, x)
    mse = float(jnp.mean((eng.embeddings - ref[-1].h) ** 2))
    assert mse < 1e-6


def test_empty_batch_noop():
    g = make_graph("uniform", 50, avg_degree=4, seed=0)
    x, _ = random_features(50, 6, seed=0)
    model = _mk("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), [6, 6, 6])
    eng = RTECEngine(model, params, g, jnp.asarray(x))
    before = np.array(eng.embeddings)
    empty = UpdateBatch(
        ins_src=np.zeros(0, np.int64), ins_dst=np.zeros(0, np.int64),
        del_src=np.zeros(0, np.int64), del_dst=np.zeros(0, np.int64),
        ins_weights=np.zeros(0, np.float32), ins_etypes=np.zeros(0, np.int32),
    )
    stats = eng.apply_batch(empty)
    assert stats.edges_processed == 0
    np.testing.assert_allclose(np.array(eng.embeddings), before, atol=1e-6)


def test_drain_vertex_to_zero_degree():
    """All in-edges of a vertex deleted → embedding equals the from-scratch
    value (the catastrophic-cancellation guard, DESIGN.md §4)."""
    src = np.array([0, 1, 3])
    dst = np.array([2, 2, 4])
    g = CSRGraph.from_edges(5, src, dst)
    x, _ = random_features(5, 6, seed=0)
    for name in ["gat", "sage", "gcn", "rgat"]:
        model = _mk(name)
        params = model.init_layers(jax.random.PRNGKey(0), [6, 6, 6])
        eng = RTECEngine(model, params, g, jnp.asarray(x))
        b = UpdateBatch(
            ins_src=np.zeros(0, np.int64), ins_dst=np.zeros(0, np.int64),
            del_src=np.array([0, 1]), del_dst=np.array([2, 2]),
            ins_weights=np.zeros(0, np.float32), ins_etypes=np.zeros(0, np.int32),
        )
        eng.apply_batch(b)
        g2 = g.apply_updates(np.zeros(0, np.int64), np.zeros(0, np.int64),
                             np.array([0, 1]), np.array([2, 2]))
        ref = full_forward(model, params, jnp.asarray(x), g2)
        err = float(jnp.abs(eng.embeddings - ref[-1].h).max())
        assert err < TOL, f"{name}: {err}"


# ---------------------------------------------------------------------- #
# hypothesis property tests
# ---------------------------------------------------------------------- #
@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
@given(
    seed=st.integers(0, 2**16),
    n=st.integers(20, 80),
    avg_deg=st.integers(2, 8),
    model_name=st.sampled_from(["gcn", "sage", "gin", "gat", "pinsage", "agnn"]),
    delete_frac=st.floats(0.0, 0.8),
    kind=st.sampled_from(["uniform", "powerlaw"]),
)
def test_property_incremental_equals_full(seed, n, avg_deg, model_name, delete_frac, kind):
    g = make_graph(kind, n, avg_degree=avg_deg, seed=seed, weighted=True)
    if g.num_edges < 4:
        return
    x, _ = random_features(n, 6, seed=seed)
    wl = make_stream(g, num_batches=2, batch_edges=max(2, g.num_edges // 20),
                     delete_frac=delete_frac, seed=seed + 1)
    model = _mk(model_name)
    params = model.init_layers(jax.random.PRNGKey(seed % 97), [6, 6, 6])
    eng, ref, _, _ = _run_stream(model, params, wl, x)
    err = float(jnp.abs(eng.embeddings - ref[-1].h).max())
    assert err < 5e-4, f"{model_name} n={n} seed={seed}: {err}"


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**16), fdim=st.integers(4, 12))
def test_property_feature_updates(seed, fdim):
    g = make_graph("uniform", 60, avg_degree=4, seed=seed)
    if g.num_edges < 4:
        return
    x, _ = random_features(60, fdim, seed=seed)
    wl = make_stream(g, num_batches=2, batch_edges=4, delete_frac=0.2,
                     feature_dim=fdim, feature_frac=0.05, seed=seed)
    model = _mk("gat")
    params = model.init_layers(jax.random.PRNGKey(seed % 89), [fdim, 8, 8])
    eng, ref, _, _ = _run_stream(model, params, wl, x)
    assert float(jnp.abs(eng.embeddings - ref[-1].h).max()) < 5e-4
