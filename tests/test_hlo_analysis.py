"""HLO roofline analyzer: flops/trip-count/collective parsing validated
against analytic counts on small lowered programs (subprocess: needs >1
device for SPMD collectives)."""
import subprocess
import sys
import textwrap
from pathlib import Path

from repro.launch.hlo_analysis import (
    _shape_dims,
    _shapes_bytes,
    analyze_hlo,
)


def test_shape_parsing():
    assert _shapes_bytes("f32[16,256]{1,0}") == 16 * 256 * 4
    assert _shapes_bytes("bf16[8]{0}") == 16
    assert _shapes_bytes("(f32[4,4]{1,0}, s32[2]{0})") == 64 + 8
    assert _shape_dims("f32[16,256]{1,0}") == ("f32", [16, 256])
    assert _shapes_bytes("pred[]") == 1


def test_wire_factors_on_synthetic_hlo():
    hlo = textwrap.dedent("""
    ENTRY %main.1 (p0: f32[64,64]) -> f32[64,64] {
      %p0 = f32[64,64]{1,0} parameter(0)
      %ag = f32[64,64]{1,0} all-gather(%p0), replica_groups=[4,4]<=[16]
      %ar = f32[64,64]{1,0} all-reduce(%ag), replica_groups=[2,8]<=[16]
      ROOT %out = f32[64,64]{1,0} add(%ar, %ag)
    }
    """)
    st = analyze_hlo(hlo, total_devices=16)
    b = 64 * 64 * 4
    expect = b * (3 / 4) + b * 2 * (7 / 8)
    assert abs(st.collective_bytes - expect) < 1e-6
    assert st.collective_counts == {"all-gather": 1, "all-reduce": 1}


def test_while_trip_count_scaling():
    hlo = textwrap.dedent("""
    %body.1 (p: f32[8,8]) -> f32[8,8] {
      %p = f32[8,8]{1,0} parameter(0)
      ROOT %d = f32[8,8]{1,0} dot(%p, %p), lhs_contracting_dims={1}, rhs_contracting_dims={0}
    }
    %cond.1 (p: f32[8,8]) -> pred[] {
      %p = f32[8,8]{1,0} parameter(0)
      ROOT %c = pred[] constant(false)
    }
    ENTRY %main.2 (p0: f32[8,8]) -> f32[8,8] {
      %p0 = f32[8,8]{1,0} parameter(0)
      ROOT %w = f32[8,8]{1,0} while(%p0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
    }
    """)
    st = analyze_hlo(hlo, default_trip_count=1)
    assert st.flops == 5 * 2 * 8 * 8 * 8  # 5 trips × 2MNK


_SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import analyze_hlo

mesh = jax.make_mesh((2, 4), ("data", "model"))

def f(ws, x):
    def body(x, w):
        return jax.nn.relu(x @ w), None
    x, _ = jax.lax.scan(body, x, ws)
    return x.sum()

g = jax.jit(jax.grad(f), in_shardings=(
    NamedSharding(mesh, P(None, "data", "model")), NamedSharding(mesh, P("data", None))))
L, B, D = 4, 32, 64
comp = g.lower(jax.ShapeDtypeStruct((L, D, D), jnp.float32),
               jax.ShapeDtypeStruct((B, D), jnp.float32)).compile()
st = analyze_hlo(comp.as_text(), default_trip_count=L, total_devices=8)
# analytic: fwd L×2BDD; bwd ≈ 2×fwd (dx + dw per layer) → 3× total, /8 devices
analytic = 3 * L * 2 * B * D * D / 8
ratio = st.flops / analytic
assert 0.6 < ratio < 1.7, (st.flops, analytic, ratio)
assert st.collective_bytes > 0
print("ratio ok", ratio)
"""


def test_scan_flops_match_analytic_subprocess():
    out = subprocess.run([sys.executable, "-c", _SUB], capture_output=True,
                         text=True, cwd=Path(__file__).resolve().parents[1],
                         timeout=600)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"
