"""Unified decoder-only LM covering the assigned architecture families:

  * dense GQA transformers (qwen2.5 / granite / llama3.2 / minicpm)
  * MoE transformers (qwen3-moe, moonshot) — GShard-style EP MoE blocks
  * hybrid attention+SSM (hymba) — parallel SWA-attention + Mamba-2/SSD
    heads per layer (global-attn layers configured via `full_attn_layers`;
    decode uses a ring-buffer window cache, DESIGN.md §5)
  * xLSTM — groups of (1 sLSTM + k−1 mLSTM) blocks, chunkwise-parallel
    training form and O(1)-state decode

Layers are stacked and scanned (`lax.scan`) so the 512-device dry-run HLO
stays compact; per-layer heterogeneity (hymba window mix) rides along as a
scanned int32 array.  Params are `Param(value, logical_axes)` pairs — see
`repro.dist.sharding` for the mesh mapping.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.nn import param as pm
from repro.nn.attention import (
    KVCache,
    attention_apply,
    attention_core,
    init_attention,
)
from repro.nn.layers import rms_norm, softmax_xent, swiglu
from repro.nn.moe import init_moe, moe_apply
from repro.nn.ssm import (
    MLSTMState,
    SLSTMState,
    causal_conv,
    mlstm_chunked,
    mlstm_init_state,
    mlstm_step,
    slstm_init_state,
    slstm_seq,
    slstm_step,
    ssd_chunked,
    ssd_step,
)

FULL_WINDOW = 1 << 30


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


# ====================================================================== #
# init
# ====================================================================== #
def _init_mlp(key, layers, d, f, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wg": pm.stacked_dense(k1, layers, (d, f), ("embed", "mlp"), dtype),
        "wi": pm.stacked_dense(k2, layers, (d, f), ("embed", "mlp"), dtype),
        "wo": pm.stacked_dense(k3, layers, (f, d), ("mlp", "embed"), dtype),
    }


def _init_ssd_branch(key, layers, d, cfg: ArchConfig, dtype):
    """Mamba-2/SSD branch (hymba)."""
    di = cfg.ssm_expand * d
    h = cfg.ssm_heads
    n = cfg.ssm_state
    ks = jax.random.split(key, 7)
    return {
        "w_in": pm.stacked_dense(ks[0], layers, (d, 2 * di), ("embed", "mlp"), dtype),
        "conv_w": pm.Param(
            jax.random.normal(ks[1], (layers, cfg.conv_width, di), dtype) * 0.2,
            ("layers", None, "mlp"),
        ),
        "w_bc": pm.stacked_dense(ks[2], layers, (di, 2 * h * n), ("mlp", "heads"), dtype),
        "w_dt": pm.stacked_dense(ks[3], layers, (di, h), ("mlp", None), dtype),
        "a_log": pm.stacked_zeros(layers, (h,), (None,), jnp.float32),
        "dt_bias": pm.stacked_zeros(layers, (h,), (None,), jnp.float32),
        "d_skip": pm.stacked_ones(layers, (h,), (None,), jnp.float32),
        "w_out": pm.stacked_dense(ks[6], layers, (di, d), ("mlp", "embed"), dtype),
        "out_norm": pm.stacked_ones(layers, (di,), (None,), dtype),
    }


def _init_mlstm_blocks(key, groups, per, d, heads, conv_width, dtype):
    ks = jax.random.split(key, 8)
    shp = lambda *s: (groups, per, *s)

    def sd(k, s, axes, fan):
        std = 1.0 / (fan**0.5)
        return pm.Param(jax.random.normal(k, shp(*s), dtype) * std, ("layers", "stack", *axes))

    return {
        "ln": pm.Param(jnp.ones(shp(d), dtype), ("layers", "stack", None)),
        "w_up": sd(ks[0], (d, 2 * d), ("embed", "mlp"), d),
        "conv_w": pm.Param(jax.random.normal(ks[1], shp(conv_width, d), dtype) * 0.2,
                           ("layers", "stack", None, "mlp")),
        "wq": sd(ks[2], (d, d), ("embed", "heads"), d),
        "wk": sd(ks[3], (d, d), ("embed", "heads"), d),
        "wv": sd(ks[4], (d, d), ("embed", "heads"), d),
        "w_gates": sd(ks[5], (d, 2 * heads), ("embed", None), d),
        "b_gates": pm.Param(jnp.zeros(shp(2 * heads), jnp.float32), ("layers", "stack", None)),
        "w_down": sd(ks[6], (d, d), ("heads", "embed"), d),
        "out_norm": pm.Param(jnp.ones(shp(d), dtype), ("layers", "stack", None)),
    }


def _init_slstm_blocks(key, groups, d, heads, dtype):
    ks = jax.random.split(key, 6)

    def sd(k, s, axes, fan):
        std = 1.0 / (fan**0.5)
        return pm.Param(jax.random.normal(k, (groups, *s), dtype) * std, ("layers", *axes))

    return {
        "ln": pm.Param(jnp.ones((groups, d), dtype), ("layers", None)),
        "wz": sd(ks[0], (d, d), ("embed", "heads"), d),
        "wif": sd(ks[1], (d, 2 * d), ("embed", "heads"), d),
        "wo_gate": sd(ks[2], (d, d), ("embed", "heads"), d),
        "w_down": sd(ks[3], (d, d), ("heads", "embed"), d),
    }


def init_lm(key: jax.Array, cfg: ArchConfig):
    """Returns (params, logical_axes) trees."""
    dtype = _dtype(cfg.param_dtype)
    d = cfg.d_model
    keys = jax.random.split(key, 12)
    tree: Dict[str, Any] = {
        "embed": pm.Param(
            jax.random.normal(keys[0], (cfg.vocab_size, d), dtype) * 0.02,
            ("vocab", "embed"),
        ),
        "final_norm": pm.ones((d,), (None,), dtype),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = pm.dense(keys[1], (d, cfg.vocab_size), ("embed", "vocab"), dtype)
    if cfg.num_patches:
        tree["patch_proj"] = pm.dense(keys[2], (cfg.d_frontend, d), (None, "embed"), dtype)

    L = cfg.num_layers
    if cfg.block_pattern == "attn":
        blocks = {
            "ln1": pm.stacked_ones(L, (d,), (None,), dtype),
            "ln2": pm.stacked_ones(L, (d,), (None,), dtype),
            "attn": init_attention(
                keys[3], L, d, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim,
                qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm, dtype=dtype,
            ),
        }
        if cfg.is_moe:
            blocks["moe"] = init_moe(
                keys[4], L, d, cfg.moe_d_ff, cfg.num_experts, dtype,
                num_shared=cfg.num_shared_experts, shared_d_ff=cfg.moe_d_ff,
            )
        else:
            blocks["mlp"] = _init_mlp(keys[4], L, d, cfg.d_ff, dtype)
        tree["blocks"] = blocks
    elif cfg.block_pattern == "hymba":
        blocks = {
            "ln1": pm.stacked_ones(L, (d,), (None,), dtype),
            "ln2": pm.stacked_ones(L, (d,), (None,), dtype),
            "attn": init_attention(
                keys[3], L, d, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim,
                dtype=dtype,
            ),
            "ssd": _init_ssd_branch(keys[4], L, d, cfg, dtype),
            "mlp": _init_mlp(keys[5], L, d, cfg.d_ff, dtype),
        }
        tree["blocks"] = blocks
    elif cfg.block_pattern == "xlstm":
        per = cfg.slstm_every or L
        assert L % per == 0, "xlstm layers must divide into sLSTM-led groups"
        groups = L // per
        tree["slstm_blocks"] = _init_slstm_blocks(keys[3], groups, d, cfg.num_heads, dtype)
        tree["mlstm_blocks"] = _init_mlstm_blocks(
            keys[4], groups, per - 1, d, cfg.num_heads, cfg.conv_width, dtype
        )
    else:
        raise ValueError(cfg.block_pattern)
    return pm.unzip(tree)


def window_schedule(cfg: ArchConfig) -> np.ndarray:
    """Per-layer attention window (FULL_WINDOW = unmasked)."""
    if cfg.window == 0:
        return np.full(cfg.num_layers, FULL_WINDOW, np.int32)
    w = np.full(cfg.num_layers, cfg.window, np.int32)
    for l in cfg.full_attn_layers:
        w[l] = FULL_WINDOW
    return w


# ====================================================================== #
# block bodies
# ====================================================================== #
def _attn_block(cfg: ArchConfig, p, x, window_t, cache: Optional[KVCache], index):
    h = rms_norm(x, p["ln1"])
    out, new_cache = attention_apply(
        p["attn"], h,
        n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
        rope_theta=cfg.rope_theta, causal=True, window=window_t,
        cache=cache, cache_index=index,
    )
    x = x + out
    h2 = rms_norm(x, p["ln2"])
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe:
        mo, aux = moe_apply(p["moe"], h2, top_k=cfg.top_k, capacity_factor=cfg.capacity_factor)
        x = x + mo
    else:
        x = x + swiglu(h2, p["mlp"]["wg"], p["mlp"]["wi"], p["mlp"]["wo"])
    return x, new_cache, aux


def _ssd_branch(cfg: ArchConfig, p, h, ssm_state, conv_carry, decoding: bool):
    """Mamba-2/SSD branch. h: [B,S,D] (S=1 for decode)."""
    di = cfg.ssm_expand * cfg.d_model
    nh, ns = cfg.ssm_heads, cfg.ssm_state
    dh = di // nh
    xz = h @ p["w_in"]
    xr, z = jnp.split(xz, 2, axis=-1)
    xr, conv_carry = causal_conv(xr, p["conv_w"], conv_carry)
    xr = jax.nn.silu(xr)
    bc = xr @ p["w_bc"]
    bmat, cmat = jnp.split(bc, 2, axis=-1)  # [B,S,H*ns] each
    b, s, _ = h.shape
    k = bmat.reshape(b, s, nh, ns)
    q = cmat.reshape(b, s, nh, ns)
    v = xr.reshape(b, s, nh, dh)
    dt = jax.nn.softplus(xr @ p["w_dt"] + p["dt_bias"])  # [B,S,H]
    la = -dt * jnp.exp(p["a_log"])  # log decay ≤ 0
    if decoding:
        ssm_state, y = ssd_step(ssm_state, q[:, 0], k[:, 0], v[:, 0], la[:, 0])
        y = y[:, None]
    else:
        y, ssm_state = ssd_chunked(q, k, v, la, s0=ssm_state, chunk=min(cfg.chunk, s))
    y = y + (p["d_skip"][None, None, :, None] * v).astype(y.dtype)
    y = y.reshape(b, s, di).astype(h.dtype)
    y = rms_norm(y, p["out_norm"]) * jax.nn.silu(z)
    return (y @ p["w_out"]).astype(h.dtype), ssm_state, conv_carry


def _hymba_block(cfg: ArchConfig, p, x, window_t, cache, ssm_state, conv_carry, index):
    """Parallel attention + SSD heads, averaged (hymba)."""
    h = rms_norm(x, p["ln1"])
    attn_out, new_cache = attention_apply(
        p["attn"], h,
        n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
        rope_theta=cfg.rope_theta, causal=True, window=window_t,
        cache=cache, cache_index=index,
    )
    ssd_out, ssm_state, conv_carry = _ssd_branch(
        cfg, p["ssd"], h, ssm_state, conv_carry, decoding=(h.shape[1] == 1)
    )
    x = x + 0.5 * (attn_out + ssd_out)
    h2 = rms_norm(x, p["ln2"])
    x = x + swiglu(h2, p["mlp"]["wg"], p["mlp"]["wi"], p["mlp"]["wo"])
    return x, new_cache, ssm_state, conv_carry


def _mlstm_block(cfg: ArchConfig, p, x, state: MLSTMState, conv_carry, decoding: bool):
    d = cfg.d_model
    nh = cfg.num_heads
    dh = d // nh
    b, s, _ = x.shape
    h = rms_norm(x, p["ln"])
    up = h @ p["w_up"]
    xm, zg = jnp.split(up, 2, axis=-1)
    xc, conv_carry = causal_conv(xm, p["conv_w"], conv_carry)
    xc = jax.nn.silu(xc)
    q = (xc @ p["wq"]).reshape(b, s, nh, dh)
    k = (xc @ p["wk"]).reshape(b, s, nh, dh) / (dh**0.5)
    v = (xm @ p["wv"]).reshape(b, s, nh, dh)
    gates = (h @ p["w_gates"]).astype(jnp.float32) + p["b_gates"]
    lf_raw, li = jnp.split(gates, 2, axis=-1)  # [B,S,H]
    lf = jax.nn.log_sigmoid(lf_raw)
    if decoding:
        state, y = mlstm_step(state, q[:, 0], k[:, 0], v[:, 0], lf[:, 0], li[:, 0])
        y = y[:, None]
    else:
        y, state = mlstm_chunked(q, k, v, lf, li, st=state, chunk=min(cfg.chunk, s))
    y = y.reshape(b, s, d).astype(x.dtype)
    y = rms_norm(y, p["out_norm"]) * jax.nn.silu(zg)
    return x + y @ p["w_down"], state, conv_carry


def _slstm_block(cfg: ArchConfig, p, x, state: SLSTMState, decoding: bool):
    d = cfg.d_model
    nh = cfg.num_heads
    dh = d // nh
    b, s, _ = x.shape
    h = rms_norm(x, p["ln"])
    z = jnp.tanh(h @ p["wz"]).reshape(b, s, nh, dh)
    gif = (h @ p["wif"]).astype(jnp.float32).reshape(b, s, nh, 2 * dh)
    li, lf_raw = jnp.split(gif, 2, axis=-1)
    lf = jax.nn.log_sigmoid(lf_raw)
    o = jax.nn.sigmoid(h @ p["wo_gate"]).reshape(b, s, nh, dh)
    if decoding:
        state, y = slstm_step(state, z[:, 0].astype(jnp.float32), lf[:, 0], li[:, 0],
                              o[:, 0].astype(jnp.float32))
        y = y[:, None]
    else:
        y, state = slstm_seq(z, lf, li, o)
    y = y.reshape(b, s, d).astype(x.dtype)
    return x + y @ p["w_down"], state


# ====================================================================== #
# caches
# ====================================================================== #
class LMCache(NamedTuple):
    """Stacked-per-layer decode state for attn/hymba patterns."""

    k: Optional[jax.Array]  # [L, B, Hkv, S_cache, dh]
    v: Optional[jax.Array]
    ssm: Optional[jax.Array]  # [L, B, H_ssm, ns, dh_ssm]
    conv: Optional[jax.Array]  # [L, B, kw-1, di]
    index: jax.Array  # scalar int32 — next position to write


class XLSTMCache(NamedTuple):
    s_c: jax.Array  # [G, B, H, dh]
    s_n: jax.Array
    s_m: jax.Array
    m_c: jax.Array  # [G, P-1, B, H, dh, dh]
    m_n: jax.Array  # [G, P-1, B, H, dh]
    m_m: jax.Array  # [G, P-1, B, H]
    conv: jax.Array  # [G, P-1, B, kw-1, D]
    index: jax.Array


def cache_len(cfg: ArchConfig, s_max: int) -> int:
    """Per-layer KV length: ring buffer of `window` for pure-SWA layer mixes
    (hymba long-context serving), else the full context."""
    if cfg.block_pattern == "hymba" and cfg.window and s_max > cfg.window:
        return cfg.window
    return s_max


def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    L, d = cfg.num_layers, cfg.d_model
    if cfg.block_pattern == "xlstm":
        per = cfg.slstm_every or L
        g = L // per
        nh = cfg.num_heads
        dh = d // nh
        return XLSTMCache(
            s_c=jnp.zeros((g, batch, nh, dh), jnp.float32),
            s_n=jnp.zeros((g, batch, nh, dh), jnp.float32),
            s_m=jnp.full((g, batch, nh, dh), -1e30, jnp.float32),
            m_c=jnp.zeros((g, per - 1, batch, nh, dh, dh), jnp.float32),
            m_n=jnp.zeros((g, per - 1, batch, nh, dh), jnp.float32),
            m_m=jnp.full((g, per - 1, batch, nh), -1e30, jnp.float32),
            conv=jnp.zeros((g, per - 1, batch, cfg.conv_width - 1, d), dtype),
            index=jnp.zeros((), jnp.int32),
        )
    sc = cache_len(cfg, s_max)
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    k = jnp.zeros((L, batch, hkv, sc, dh), dtype)
    v = jnp.zeros((L, batch, hkv, sc, dh), dtype)
    ssm = conv = None
    if cfg.block_pattern == "hymba":
        di = cfg.ssm_expand * d
        ssm = jnp.zeros((L, batch, cfg.ssm_heads, cfg.ssm_state, di // cfg.ssm_heads), jnp.float32)
        conv = jnp.zeros((L, batch, cfg.conv_width - 1, di), dtype)
    return LMCache(k=k, v=v, ssm=ssm, conv=conv, index=jnp.zeros((), jnp.int32))


# ====================================================================== #
# embedding / logits
# ====================================================================== #
def _embed(params, cfg: ArchConfig, tokens, patches=None):
    cdt = _dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    if patches is not None:
        px = (patches.astype(cdt) @ params["patch_proj"].astype(cdt))
        x = jnp.concatenate([px, x], axis=1)
    return x


def _logits(params, cfg: ArchConfig, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head.astype(x.dtype)


def _window_static(cfg: ArchConfig):
    sched = window_schedule(cfg)
    if len(set(sched.tolist())) == 1:
        w = int(sched[0])
        return None if w >= FULL_WINDOW else w
    return sched  # heterogeneous → traced per-layer array


# ====================================================================== #
# training / full forward
# ====================================================================== #
def forward(params, cfg: ArchConfig, tokens, patches=None):
    """Full forward (no cache). Returns (logits [B,S_text,V], aux)."""
    x = _embed(params, cfg, tokens, patches)
    n_patch = 0 if patches is None else patches.shape[1]

    if cfg.block_pattern == "xlstm":
        per = cfg.slstm_every or cfg.num_layers
        b = x.shape[0]
        nh = cfg.num_heads
        dh = cfg.d_model // nh

        def inner(xc2, pslice):
            xc2, _, _ = _mlstm_block(
                cfg, pslice, xc2, mlstm_init_state(b, nh, dh, dh), None, False
            )
            return xc2, None

        # remat ONLY the mLSTM blocks: rematerializing the sLSTM step loop
        # recomputes full-sequence gate tensors inside every scan iteration —
        # a ~500 TB/device HBM blowup (EXPERIMENTS.md §Perf xlstm iter 2)
        inner_ck = jax.checkpoint(inner) if cfg.remat else inner

        def group_body(xc, xs):
            ps, pms = xs
            xc, _ = _slstm_block(cfg, ps, xc, slstm_init_state(b, nh, dh), decoding=False)
            xc, _ = jax.lax.scan(inner_ck, xc, pms)
            return xc, jnp.zeros((), jnp.float32)

        x, _ = jax.lax.scan(group_body, x, (params["slstm_blocks"], params["mlstm_blocks"]))
        aux = jnp.zeros((), jnp.float32)
    else:
        wstat = _window_static(cfg)
        blocks = params["blocks"]
        if cfg.block_pattern == "attn":
            def body(xc, xs):
                if isinstance(wstat, np.ndarray):
                    p, w = xs
                else:
                    p, w = xs[0], wstat
                xc, _, aux = _attn_block(cfg, p, xc, w, None, 0)
                return xc, aux
        else:  # hymba
            b = x.shape[0]
            di = cfg.ssm_expand * cfg.d_model

            def body(xc, xs):
                if isinstance(wstat, np.ndarray):
                    p, w = xs
                else:
                    p, w = xs[0], wstat
                ssm0 = jnp.zeros((b, cfg.ssm_heads, cfg.ssm_state, di // cfg.ssm_heads), jnp.float32)
                xc, _, _, _ = _hymba_block(cfg, p, xc, w, None, ssm0, None, 0)
                return xc, jnp.zeros((), jnp.float32)

        xs = (blocks, jnp.asarray(window_schedule(cfg))) if isinstance(wstat, np.ndarray) else (blocks,)
        body_ck = jax.checkpoint(body) if cfg.remat else body
        x, auxs = jax.lax.scan(body_ck, x, xs)
        aux = auxs.mean()

    x = rms_norm(x, params["final_norm"])
    if n_patch:
        x = x[:, n_patch:]
    return _logits(params, cfg, x), aux


def lm_loss(params, cfg: ArchConfig, batch: Dict[str, jax.Array]):
    """Next-token CE (+ MoE aux). batch: tokens [B,S], labels [B,S], patches?"""
    logits, aux = forward(params, cfg, batch["tokens"], batch.get("patches"))
    loss = softmax_xent(logits, batch["labels"])
    return loss + 0.01 * aux, {"ce": loss, "aux": aux}


# ====================================================================== #
# serving: prefill + decode
# ====================================================================== #
def prefill(params, cfg: ArchConfig, tokens, s_max: int, patches=None,
            cache_dtype=jnp.bfloat16):
    """Populate a decode cache from a prompt; returns (last-token logits,
    cache).  tokens occupy positions [0, S); cache.index = S."""
    from repro.nn.attention import attention_prefill_kv

    x = _embed(params, cfg, tokens, patches)
    b, s_tot, _ = x.shape
    cache = init_cache(cfg, b, s_max, cache_dtype)

    if cfg.block_pattern == "xlstm":
        nh = cfg.num_heads
        dh = cfg.d_model // nh

        def group_body(xc, xs):
            ps, pms = xs
            xc, s_st = _slstm_block(cfg, ps, xc, slstm_init_state(b, nh, dh), decoding=False)

            def inner(xc2, pslice):
                st0 = mlstm_init_state(b, nh, dh, dh)
                cc0 = jnp.zeros((b, cfg.conv_width - 1, cfg.d_model), xc2.dtype)
                xc2, m_st, cc = _mlstm_block(cfg, pslice, xc2, st0, cc0, False)
                return xc2, (m_st.c, m_st.n, m_st.m, cc)

            xc, ys = jax.lax.scan(inner, xc, pms)
            return xc, (s_st.c, s_st.n, s_st.m, *ys)

        x, outs = jax.lax.scan(group_body, x, (params["slstm_blocks"], params["mlstm_blocks"]))
        sc, sn, sm, mc, mn, mm, conv = outs
        cache = XLSTMCache(s_c=sc, s_n=sn, s_m=sm, m_c=mc, m_n=mn, m_m=mm,
                           conv=conv.astype(cache_dtype), index=jnp.asarray(s_tot, jnp.int32))
    else:
        sc_len = cache_len(cfg, s_max)
        wstat = _window_static(cfg)
        di = cfg.ssm_expand * cfg.d_model

        def body(xc, xs):
            if isinstance(wstat, np.ndarray):
                p, w = xs
            else:
                p, w = xs[0], wstat
            h = rms_norm(xc, p["ln1"])
            if cfg.block_pattern == "hymba":
                attn_out, kf, vf = attention_prefill_kv(
                    p["attn"], h, n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
                    head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                    causal=True, window=w,
                )
                ssm0 = jnp.zeros((b, cfg.ssm_heads, cfg.ssm_state, di // cfg.ssm_heads), jnp.float32)
                cc0 = jnp.zeros((b, cfg.conv_width - 1, di), xc.dtype)
                ssd_out, ssm_st, cc = _ssd_branch(cfg, p["ssd"], h, ssm0, cc0, False)
                xc = xc + 0.5 * (attn_out + ssd_out)
                xc = xc + swiglu(rms_norm(xc, p["ln2"]), p["mlp"]["wg"], p["mlp"]["wi"], p["mlp"]["wo"])
                # ring fill: slot j holds the latest position p ≡ j (mod W),
                # p < s_tot; slots never written stay masked at decode
                s_here = kf.shape[2]
                slot_pos = (s_here - 1) - jnp.mod(
                    s_here - 1 - jnp.arange(sc_len), sc_len
                )
                slot_pos = jnp.clip(slot_pos, 0, s_here - 1)
                ck = jnp.take(kf, slot_pos, axis=2).astype(cache_dtype)
                cv = jnp.take(vf, slot_pos, axis=2).astype(cache_dtype)
                return xc, (ck, cv, ssm_st, cc.astype(cache_dtype), jnp.zeros((), jnp.float32))
            else:
                attn_out, kf, vf = attention_prefill_kv(
                    p["attn"], h, n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
                    head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                    causal=True, window=w,
                )
                xc = xc + attn_out
                h2 = rms_norm(xc, p["ln2"])
                aux = jnp.zeros((), jnp.float32)
                if cfg.is_moe:
                    mo, aux = moe_apply(p["moe"], h2, top_k=cfg.top_k,
                                        capacity_factor=cfg.capacity_factor)
                    xc = xc + mo
                else:
                    xc = xc + swiglu(h2, p["mlp"]["wg"], p["mlp"]["wi"], p["mlp"]["wo"])
                pad = sc_len - kf.shape[2]
                kf = jnp.pad(kf, ((0, 0), (0, 0), (0, pad), (0, 0)))
                vf = jnp.pad(vf, ((0, 0), (0, 0), (0, pad), (0, 0)))
                return xc, (kf.astype(cache_dtype), vf.astype(cache_dtype), aux)

        xs = (params["blocks"], jnp.asarray(window_schedule(cfg))) if isinstance(wstat, np.ndarray) else (params["blocks"],)
        x, ys = jax.lax.scan(body, x, xs)
        if cfg.block_pattern == "hymba":
            ck, cv, ssm, conv, _ = ys
            cache = LMCache(k=ck, v=cv, ssm=ssm, conv=conv,
                            index=jnp.asarray(s_tot, jnp.int32))
        else:
            ck, cv, _ = ys
            cache = LMCache(k=ck, v=cv, ssm=None, conv=None,
                            index=jnp.asarray(s_tot, jnp.int32))

    x = rms_norm(x, params["final_norm"])
    logits = _logits(params, cfg, x[:, -1:])
    return logits, cache


def decode_step(params, cfg: ArchConfig, token, cache):
    """One decode step. token [B, 1] int32. Returns (logits [B,1,V], cache)."""
    from repro.nn.attention import ring_decode_attention

    x = _embed(params, cfg, token)
    b = x.shape[0]
    index = cache.index

    if cfg.block_pattern == "xlstm":
        def group_body(xc, xs):
            ps, pms, sc, sn, sm, mc, mn, mm, conv = xs
            xc, s_st = _slstm_block(cfg, ps, xc, SLSTMState(sc, sn, sm), decoding=True)

            def inner(xc2, inner_xs):
                pslice, c_, n_, m_, cc_ = inner_xs
                xc2, m_st, cc = _mlstm_block(cfg, pslice, xc2, MLSTMState(c_, n_, m_),
                                             cc_.astype(xc2.dtype), True)
                return xc2, (m_st.c, m_st.n, m_st.m, cc)

            xc, ys = jax.lax.scan(inner, xc, (pms, mc, mn, mm, conv))
            return xc, (s_st.c, s_st.n, s_st.m, *ys)

        x, outs = jax.lax.scan(
            group_body, x,
            (params["slstm_blocks"], params["mlstm_blocks"],
             cache.s_c, cache.s_n, cache.s_m, cache.m_c, cache.m_n, cache.m_m, cache.conv),
        )
        sc, sn, sm, mc, mn, mm, conv = outs
        new_cache = XLSTMCache(s_c=sc, s_n=sn, s_m=sm, m_c=mc, m_n=mn, m_m=mm,
                               conv=conv.astype(cache.conv.dtype), index=index + 1)
    else:
        wsched = jnp.asarray(window_schedule(cfg))
        ring = cfg.block_pattern == "hymba" and cache.k.shape[3] < FULL_WINDOW and cfg.window and cache.k.shape[3] == cfg.window

        if cfg.block_pattern == "hymba":
            def body(xc, xs):
                p, w, ck, cv, ssm, conv = xs
                h = rms_norm(xc, p["ln1"])
                attn_out, ck, cv = ring_decode_attention(
                    p["attn"], h, ck, cv, index,
                    n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
                    head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                )
                ssd_out, ssm, conv_new = _ssd_branch(cfg, p["ssd"], h, ssm,
                                                     conv.astype(xc.dtype), True)
                xc = xc + 0.5 * (attn_out + ssd_out)
                xc = xc + swiglu(rms_norm(xc, p["ln2"]), p["mlp"]["wg"], p["mlp"]["wi"], p["mlp"]["wo"])
                return xc, (ck, cv, ssm, conv_new.astype(conv.dtype))

            x, ys = jax.lax.scan(body, x, (params["blocks"], wsched, cache.k, cache.v,
                                           cache.ssm, cache.conv))
            ck, cv, ssm, conv = ys
            new_cache = LMCache(k=ck, v=cv, ssm=ssm, conv=conv, index=index + 1)
        else:
            def body(xc, xs):
                p, ck, cv = xs
                h = rms_norm(xc, p["ln1"])
                out, kv = attention_apply(
                    p["attn"], h, n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
                    head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                    causal=True, window=None, cache=KVCache(ck, cv), cache_index=index,
                )
                xc = xc + out
                h2 = rms_norm(xc, p["ln2"])
                if cfg.is_moe:
                    mo, _ = moe_apply(p["moe"], h2, top_k=cfg.top_k,
                                      capacity_factor=cfg.capacity_factor)
                    xc = xc + mo
                else:
                    xc = xc + swiglu(h2, p["mlp"]["wg"], p["mlp"]["wi"], p["mlp"]["wo"])
                return xc, (kv.k, kv.v)

            x, ys = jax.lax.scan(body, x, (params["blocks"], cache.k, cache.v))
            ck, cv = ys
            new_cache = LMCache(k=ck, v=cv, ssm=None, conv=None, index=index + 1)

    x = rms_norm(x, params["final_norm"])
    return _logits(params, cfg, x), new_cache
