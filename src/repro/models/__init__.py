"""Model zoo API: unified init / loss / prefill / decode per architecture."""
from __future__ import annotations

from typing import Any, Dict

import jax

from repro.configs.base import ArchConfig
from repro.models import encdec as _encdec
from repro.models import lm as _lm


def init_model(key: jax.Array, cfg: ArchConfig):
    """Returns (params, logical_axes)."""
    if cfg.encdec:
        return _encdec.init_encdec(key, cfg)
    return _lm.init_lm(key, cfg)


def loss_fn(params, cfg: ArchConfig, batch: Dict[str, Any]):
    if cfg.encdec:
        return _encdec.encdec_loss(params, cfg, batch)
    return _lm.lm_loss(params, cfg, batch)


def forward(params, cfg: ArchConfig, batch: Dict[str, Any]):
    if cfg.encdec:
        return _encdec.forward(params, cfg, batch["frames"], batch["tokens"])
    return _lm.forward(params, cfg, batch["tokens"], batch.get("patches"))


def prefill(params, cfg: ArchConfig, batch: Dict[str, Any], s_max: int, cache_dtype=None):
    import jax.numpy as jnp

    cache_dtype = cache_dtype or jnp.bfloat16
    if cfg.encdec:
        return _encdec.prefill(params, cfg, batch["frames"], batch["tokens"], s_max,
                               cache_dtype=cache_dtype)
    return _lm.prefill(params, cfg, batch["tokens"], s_max,
                       patches=batch.get("patches"), cache_dtype=cache_dtype)


def decode_step(params, cfg: ArchConfig, token, cache):
    if cfg.encdec:
        return _encdec.decode_step(params, cfg, token, cache)
    return _lm.decode_step(params, cfg, token, cache)


def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=None):
    import jax.numpy as jnp

    return _lm.init_cache(cfg, batch, s_max, dtype or jnp.bfloat16)
