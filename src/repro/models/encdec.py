"""Encoder-decoder transformer (seamless-m4t backbone).

The audio frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, S_src, d_frontend]; the encoder is a
bidirectional transformer over projected frames, the decoder a causal
transformer with per-layer cross-attention to the encoder memory.  Serving
keeps the encoder memory's cross-K/V precomputed in the cache.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.lm import _dtype, _init_mlp, _logits
from repro.nn import param as pm
from repro.nn.attention import (
    KVCache,
    attention_apply,
    attention_prefill_kv,
    cross_attention_apply,
    cross_memory,
    init_attention,
    init_cross_attention,
)
from repro.nn.layers import rms_norm, softmax_xent, swiglu


class EncDecCache(NamedTuple):
    k: jax.Array  # [L, B, Hkv, S_max, dh] decoder self-attn
    v: jax.Array
    mem_k: jax.Array  # [L, B, H, S_src, dh] cross-attn memory
    mem_v: jax.Array
    index: jax.Array


def init_encdec(key: jax.Array, cfg: ArchConfig):
    dtype = _dtype(cfg.param_dtype)
    d = cfg.d_model
    keys = jax.random.split(key, 10)
    hd = cfg.resolved_head_dim
    tree: Dict[str, Any] = {
        "embed": pm.Param(
            jax.random.normal(keys[0], (cfg.vocab_size, d), dtype) * 0.02,
            ("vocab", "embed"),
        ),
        "lm_head": pm.dense(keys[1], (d, cfg.vocab_size), ("embed", "vocab"), dtype),
        "frame_proj": pm.dense(keys[2], (cfg.d_frontend, d), (None, "embed"), dtype),
        "final_norm": pm.ones((d,), (None,), dtype),
        "enc_final_norm": pm.ones((d,), (None,), dtype),
        "enc_blocks": {
            "ln1": pm.stacked_ones(cfg.enc_layers, (d,), (None,), dtype),
            "ln2": pm.stacked_ones(cfg.enc_layers, (d,), (None,), dtype),
            "attn": init_attention(keys[3], cfg.enc_layers, d, cfg.num_heads,
                                   cfg.num_kv_heads, hd, dtype=dtype),
            "mlp": _init_mlp(keys[4], cfg.enc_layers, d, cfg.d_ff, dtype),
        },
        "dec_blocks": {
            "ln1": pm.stacked_ones(cfg.num_layers, (d,), (None,), dtype),
            "ln_x": pm.stacked_ones(cfg.num_layers, (d,), (None,), dtype),
            "ln2": pm.stacked_ones(cfg.num_layers, (d,), (None,), dtype),
            "attn": init_attention(keys[5], cfg.num_layers, d, cfg.num_heads,
                                   cfg.num_kv_heads, hd, dtype=dtype),
            "xattn": init_cross_attention(keys[6], cfg.num_layers, d, d,
                                          cfg.num_heads, hd, dtype=dtype),
            "mlp": _init_mlp(keys[7], cfg.num_layers, d, cfg.d_ff, dtype),
        },
    }
    return pm.unzip(tree)


def encode(params, cfg: ArchConfig, frames):
    """frames [B, S_src, d_frontend] → encoder memory [B, S_src, D]."""
    cdt = _dtype(cfg.compute_dtype)
    x = frames.astype(cdt) @ params["frame_proj"].astype(cdt)

    def body(xc, p):
        h = rms_norm(xc, p["ln1"])
        out, _ = attention_apply(
            p["attn"], h, n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta, causal=False,
        )
        xc = xc + out
        xc = xc + swiglu(rms_norm(xc, p["ln2"]), p["mlp"]["wg"], p["mlp"]["wi"], p["mlp"]["wo"])
        return xc, None

    body_ck = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_ck, x, params["enc_blocks"])
    return rms_norm(x, params["enc_final_norm"])


def forward(params, cfg: ArchConfig, frames, tokens):
    """Training forward: (logits [B, S_dec, V], aux=0)."""
    memory = encode(params, cfg, frames)
    cdt = _dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    hd = cfg.resolved_head_dim

    def body(xc, p):
        h = rms_norm(xc, p["ln1"])
        out, _ = attention_apply(
            p["attn"], h, n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
            head_dim=hd, rope_theta=cfg.rope_theta, causal=True,
        )
        xc = xc + out
        hx = rms_norm(xc, p["ln_x"])
        mem_kv = cross_memory(p["xattn"], memory, cfg.num_heads, hd)
        xc = xc + cross_attention_apply(p["xattn"], hx, mem_kv, n_heads=cfg.num_heads, head_dim=hd)
        xc = xc + swiglu(rms_norm(xc, p["ln2"]), p["mlp"]["wg"], p["mlp"]["wi"], p["mlp"]["wo"])
        return xc, None

    body_ck = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_ck, x, params["dec_blocks"])
    x = rms_norm(x, params["final_norm"])
    return _logits(params, cfg, x), jnp.zeros((), jnp.float32)


def encdec_loss(params, cfg: ArchConfig, batch):
    logits, _ = forward(params, cfg, batch["frames"], batch["tokens"])
    loss = softmax_xent(logits, batch["labels"])
    return loss, {"ce": loss}


def prefill(params, cfg: ArchConfig, frames, tokens, s_max: int, cache_dtype=jnp.bfloat16):
    """Encode source + prefill decoder positions [0, S_dec)."""
    memory = encode(params, cfg, frames)
    cdt = _dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    b, s_dec, _ = x.shape
    hd = cfg.resolved_head_dim

    def body(xc, p):
        h = rms_norm(xc, p["ln1"])
        out, kf, vf = attention_prefill_kv(
            p["attn"], h, n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
            head_dim=hd, rope_theta=cfg.rope_theta, causal=True,
        )
        xc = xc + out
        hx = rms_norm(xc, p["ln_x"])
        mk, mv = cross_memory(p["xattn"], memory, cfg.num_heads, hd)
        xc = xc + cross_attention_apply(p["xattn"], hx, (mk, mv), n_heads=cfg.num_heads, head_dim=hd)
        xc = xc + swiglu(rms_norm(xc, p["ln2"]), p["mlp"]["wg"], p["mlp"]["wi"], p["mlp"]["wo"])
        pad = s_max - kf.shape[2]
        kf = jnp.pad(kf, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return xc, (kf.astype(cache_dtype), vf.astype(cache_dtype),
                    mk.astype(cache_dtype), mv.astype(cache_dtype))

    x, ys = jax.lax.scan(body, x, params["dec_blocks"])
    ck, cv, mk, mv = ys
    x = rms_norm(x, params["final_norm"])
    logits = _logits(params, cfg, x[:, -1:])
    cache = EncDecCache(k=ck, v=cv, mem_k=mk, mem_v=mv,
                        index=jnp.asarray(s_dec, jnp.int32))
    return logits, cache


def decode_step(params, cfg: ArchConfig, token, cache: EncDecCache):
    cdt = _dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], token, axis=0).astype(cdt)
    hd = cfg.resolved_head_dim
    index = cache.index

    def body(xc, xs):
        p, ck, cv, mk, mv = xs
        h = rms_norm(xc, p["ln1"])
        out, kv = attention_apply(
            p["attn"], h, n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
            head_dim=hd, rope_theta=cfg.rope_theta, causal=True,
            cache=KVCache(ck, cv), cache_index=index,
        )
        xc = xc + out
        hx = rms_norm(xc, p["ln_x"])
        xc = xc + cross_attention_apply(p["xattn"], hx, (mk, mv),
                                        n_heads=cfg.num_heads, head_dim=hd)
        xc = xc + swiglu(rms_norm(xc, p["ln2"]), p["mlp"]["wg"], p["mlp"]["wi"], p["mlp"]["wo"])
        return xc, (kv.k, kv.v)

    x, ys = jax.lax.scan(body, x, (params["dec_blocks"], cache.k, cache.v,
                                   cache.mem_k, cache.mem_v))
    ck, cv = ys
    x = rms_norm(x, params["final_norm"])
    return _logits(params, cfg, x), cache._replace(k=ck, v=cv, index=index + 1)
