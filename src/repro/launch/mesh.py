"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod = 16×16 v5e ("data", "model");
multi-pod = 2 pods × 16×16 ("pod", "data", "model") — the "pod" axis maps
to the cross-pod DCN/ICI links.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} — run under "
            f'XLA_FLAGS="--xla_force_host_platform_device_count={n}" (dryrun.py sets this)'
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])
