"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod = 16×16 v5e ("data", "model");
multi-pod = 2 pods × 16×16 ("pod", "data", "model") — the "pod" axis maps
to the cross-pod DCN/ICI links.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False, pipeline_stages: int = 0):
    """Single pod 16×16 ("data", "model"); multi-pod 2×16×16 ("pod", ...).

    ``pipeline_stages > 1`` carves a leading "stage" axis out of the data
    axis (16 must stay divisible) for `repro.dist.pipeline.pipeline_apply`.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if pipeline_stages > 1:
        data_idx = len(shape) - 2
        if shape[data_idx] % pipeline_stages:
            raise ValueError(
                f"pipeline_stages={pipeline_stages} must divide data axis {shape[data_idx]}"
            )
        shape = (*shape[:data_idx], pipeline_stages,
                 shape[data_idx] // pipeline_stages, shape[-1])
        axes = (*axes[:data_idx], "stage", "data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} — run under "
            f'XLA_FLAGS="--xla_force_host_platform_device_count={n}" (dryrun.py sets this)'
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])
