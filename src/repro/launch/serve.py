"""Serving driver: batched prefill + decode loop for any architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_arch, reduced_config
from repro.models import decode_step, init_model, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced or len(jax.devices()) == 1:
        cfg = reduced_config(cfg)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    b, s = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))}
    if cfg.encdec:
        batch["frames"] = jnp.asarray(rng.normal(size=(b, s, cfg.d_frontend)).astype(np.float32))
    if cfg.num_patches:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_patches, cfg.d_frontend)).astype(np.float32))

    s_max = s + args.gen + (cfg.num_patches or 0)
    t0 = time.perf_counter()
    logits, cache = prefill(params, cfg, batch, s_max=s_max)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    step = jax.jit(lambda p, c, t: decode_step(p, cfg, t, c))
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} prefill={t_prefill*1e3:.1f}ms "
          f"decode={t_decode/args.gen*1e3:.2f}ms/tok "
          f"throughput={b*args.gen/t_decode:.1f}tok/s")
    print("sample:", np.asarray(gen[0])[:16])


if __name__ == "__main__":
    main()
