"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 200 [--multi-pod] [--reduced] [--checkpoint-dir ckpt/]

On real hardware the mesh comes from the runtime; on this container use
--reduced (CPU-scale config, single device) — the same code path the
dry-run compiles for the production mesh.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_NAMES, get_arch, reduced_config
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale reduced config (default on 1 device)")
    ap.add_argument("--checkpoint-dir", type=str, default=None)
    ap.add_argument("--compression", choices=["int8", "topk"], default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced or len(jax.devices()) == 1:
        cfg = reduced_config(cfg)
    tcfg = TrainConfig(
        steps=args.steps, batch=args.batch, seq_len=args.seq_len,
        checkpoint_dir=args.checkpoint_dir, compression=args.compression,
        microbatches=args.microbatches,
    )
    t = Trainer(cfg, tcfg, OptConfig(peak_lr=3e-3, warmup_steps=10,
                                     stable_steps=args.steps, decay_steps=10))
    out = t.train()
    print(out)


if __name__ == "__main__":
    main()
