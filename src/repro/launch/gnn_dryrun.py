import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Distributed GNN RTEC dry-run — the paper-representative cells.

Lowers + compiles, on the production mesh(es):

  * ``gnn_full_layer``  — one full-neighbor embedding-computation layer over
    a billion-edge graph (V=2^26, E=2^30, D=128): vertices sharded over
    "data", features over "model", edges sharded over "data" (the paper's
    RTEC-Full baseline at pod scale);
  * ``gnn_rtec_inc``    — one incremental RTEC layer (Alg. 1) over an
    affected subgraph of 2^22 signed edge records / 2^20 touched vertices —
    the paper's contribution as it would run per update batch.

Roofline terms recorded like the LM cells (experiments/dryrun/<mode>/gnn_*).
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.full import full_layer
from repro.core.incremental import incremental_layer
from repro.core.models import GCN
from repro.launch.dryrun import HBM_BW, ICI_BW, OUT_DIR, PEAK_FLOPS
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh

V = 1 << 26  # 67M vertices (ogbn-paper/Friendster scale per pod-pair)
E = 1 << 30  # 1B edges
D = 128
E_AFF = 1 << 22  # affected-edge records per batch
V_AFF = 1 << 20  # touched rows
F_CAP = 1 << 16  # constrained full-recompute rows
FE_CAP = 1 << 20


def _gcn_params():
    model = GCN()
    p = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0), D, D))
    return model, p


def full_layer_cell(mesh):
    model, pst = _gcn_params()

    def step(p, h, src, dst, ew, et, deg):
        mask = jnp.ones(src.shape[0], dtype=bool)
        st = full_layer(model, p, h, src, dst, ew, et, mask, deg, V)
        return st.a, st.nct, st.h

    vsh = NamedSharding(mesh, P("data", "model"))
    esh = NamedSharding(mesh, P("data"))
    rep = NamedSharding(mesh, P())
    psh = jax.tree.map(lambda _: rep, pst)
    jitted = jax.jit(step, in_shardings=(psh, vsh, esh, esh, esh, esh,
                                         NamedSharding(mesh, P("data"))))
    structs = (
        pst,
        jax.ShapeDtypeStruct((V, D), jnp.float32),
        jax.ShapeDtypeStruct((E,), jnp.int32),
        jax.ShapeDtypeStruct((E,), jnp.int32),
        jax.ShapeDtypeStruct((E,), jnp.float32),
        jax.ShapeDtypeStruct((E,), jnp.int32),
        jax.ShapeDtypeStruct((V,), jnp.float32),
    )
    return jitted.lower(*structs)


def rtec_inc_cell(mesh):
    model, pst = _gcn_params()

    def step(p, h_old, h_new, deg_old, deg_new, a, nct, h_cur,
             e_src, e_dst, e_rowidx, e_sign, e_use_new, e_w, e_t, e_mask,
             touch_rows, touch_mask, f_rows, f_mask, f_src, f_rowidx,
             f_w, f_t, f_emask, out_rows, out_mask):
        return incremental_layer(
            model, p, h_old, h_new, deg_old, deg_new, a, nct, h_cur,
            e_src, e_dst, e_rowidx, e_sign, e_use_new, e_w, e_t, e_mask,
            touch_rows, touch_mask, f_rows, f_mask, f_src, f_rowidx,
            f_w, f_t, f_emask, out_rows, out_mask,
        )

    vsh = NamedSharding(mesh, P("data", "model"))
    vec = NamedSharding(mesh, P("data"))
    rep = NamedSharding(mesh, P())
    psh = jax.tree.map(lambda _: rep, pst)
    i32 = jnp.int32
    f32 = jnp.float32
    # scratch row lives at index V; pad to V+16 so dim 0 stays 16-divisible
    structs = dict(
        h_old=jax.ShapeDtypeStruct((V + 16, D), f32),
        h_new=jax.ShapeDtypeStruct((V + 16, D), f32),
        deg_old=jax.ShapeDtypeStruct((V + 16,), f32),
        deg_new=jax.ShapeDtypeStruct((V + 16,), f32),
        a=jax.ShapeDtypeStruct((V, D), f32),
        nct=jax.ShapeDtypeStruct((V, 1), f32),
        h_cur=jax.ShapeDtypeStruct((V, D), f32),
        e_src=jax.ShapeDtypeStruct((E_AFF,), i32),
        e_dst=jax.ShapeDtypeStruct((E_AFF,), i32),
        e_rowidx=jax.ShapeDtypeStruct((E_AFF,), i32),
        e_sign=jax.ShapeDtypeStruct((E_AFF,), f32),
        e_use_new=jax.ShapeDtypeStruct((E_AFF,), jnp.bool_),
        e_w=jax.ShapeDtypeStruct((E_AFF,), f32),
        e_t=jax.ShapeDtypeStruct((E_AFF,), i32),
        e_mask=jax.ShapeDtypeStruct((E_AFF,), jnp.bool_),
        touch_rows=jax.ShapeDtypeStruct((V_AFF,), i32),
        touch_mask=jax.ShapeDtypeStruct((V_AFF,), jnp.bool_),
        f_rows=jax.ShapeDtypeStruct((F_CAP,), i32),
        f_mask=jax.ShapeDtypeStruct((F_CAP,), jnp.bool_),
        f_src=jax.ShapeDtypeStruct((FE_CAP,), i32),
        f_rowidx=jax.ShapeDtypeStruct((FE_CAP,), i32),
        f_w=jax.ShapeDtypeStruct((FE_CAP,), f32),
        f_t=jax.ShapeDtypeStruct((FE_CAP,), i32),
        f_emask=jax.ShapeDtypeStruct((FE_CAP,), jnp.bool_),
        out_rows=jax.ShapeDtypeStruct((V_AFF,), i32),
        out_mask=jax.ShapeDtypeStruct((V_AFF,), jnp.bool_),
    )
    shardings = dict(
        h_old=vsh, h_new=vsh, deg_old=vec, deg_new=vec, a=vsh,
        nct=NamedSharding(mesh, P("data", None)), h_cur=vsh,
        e_src=vec, e_dst=vec, e_rowidx=vec, e_sign=vec, e_use_new=vec,
        e_w=vec, e_t=vec, e_mask=vec,
        touch_rows=vec, touch_mask=vec, f_rows=vec, f_mask=vec,
        f_src=vec, f_rowidx=vec, f_w=vec, f_t=vec, f_emask=vec,
        out_rows=vec, out_mask=vec,
    )
    names = list(structs)
    jitted = jax.jit(
        lambda p, *args: step(p, *args),
        in_shardings=(psh, *[shardings[k] for k in names]),
    )
    return jitted.lower(pst, *[structs[k] for k in names])


def rtec_inc_compact_cell(mesh):
    """Beyond-naive formulation (EXPERIMENTS.md §Perf GNN iter 2): the host
    planner ships only the COMPACT affected rows (what NeutronRT's zero-copy
    reads do), so no collective ever touches the full [V, D] tables.  The
    compact kernel is the exact same `incremental_layer` (index remapping —
    see repro/serve/offload.py)."""
    model, pst = _gcn_params()
    RH = E_AFF  # compact h rows upper bound (unique endpoints of records)
    RS = V_AFF  # compact state rows

    def step(p, h_old, h_new, deg_old, deg_new, a, nct, h_cur,
             e_src, e_dst, e_rowidx, e_sign, e_use_new, e_w, e_t, e_mask,
             touch_rows, touch_mask, f_rows, f_mask, f_src, f_rowidx,
             f_w, f_t, f_emask, out_rows, out_mask, f_rows_h, out_rows_h):
        return incremental_layer(
            model, p, h_old, h_new, deg_old, deg_new, a, nct, h_cur,
            e_src, e_dst, e_rowidx, e_sign, e_use_new, e_w, e_t, e_mask,
            touch_rows, touch_mask, f_rows, f_mask, f_src, f_rowidx,
            f_w, f_t, f_emask, out_rows, out_mask,
            f_rows_h=f_rows_h, out_rows_h=out_rows_h,
        )

    vsh = NamedSharding(mesh, P("data", "model"))
    vec = NamedSharding(mesh, P("data"))
    rep = NamedSharding(mesh, P())
    psh = jax.tree.map(lambda _: rep, pst)
    i32, f32, b1 = jnp.int32, jnp.float32, jnp.bool_
    # halo embeddings ship in bf16 (GNN iter 3: halves gather wire bytes);
    # aggregation state stays fp32 so ms_cbn⁻¹ round-trips keep precision
    structs = dict(
        h_old=jax.ShapeDtypeStruct((RH + 16, D), jnp.bfloat16),
        h_new=jax.ShapeDtypeStruct((RH + 16, D), jnp.bfloat16),
        deg_old=jax.ShapeDtypeStruct((RH + 16,), f32),
        deg_new=jax.ShapeDtypeStruct((RH + 16,), f32),
        a=jax.ShapeDtypeStruct((RS, D), f32),
        nct=jax.ShapeDtypeStruct((RS, 1), f32),
        h_cur=jax.ShapeDtypeStruct((RS, D), f32),
        e_src=jax.ShapeDtypeStruct((E_AFF,), i32),
        e_dst=jax.ShapeDtypeStruct((E_AFF,), i32),
        e_rowidx=jax.ShapeDtypeStruct((E_AFF,), i32),
        e_sign=jax.ShapeDtypeStruct((E_AFF,), f32),
        e_use_new=jax.ShapeDtypeStruct((E_AFF,), b1),
        e_w=jax.ShapeDtypeStruct((E_AFF,), f32),
        e_t=jax.ShapeDtypeStruct((E_AFF,), i32),
        e_mask=jax.ShapeDtypeStruct((E_AFF,), b1),
        touch_rows=jax.ShapeDtypeStruct((V_AFF,), i32),
        touch_mask=jax.ShapeDtypeStruct((V_AFF,), b1),
        f_rows=jax.ShapeDtypeStruct((F_CAP,), i32),
        f_mask=jax.ShapeDtypeStruct((F_CAP,), b1),
        f_src=jax.ShapeDtypeStruct((FE_CAP,), i32),
        f_rowidx=jax.ShapeDtypeStruct((FE_CAP,), i32),
        f_w=jax.ShapeDtypeStruct((FE_CAP,), f32),
        f_t=jax.ShapeDtypeStruct((FE_CAP,), i32),
        f_emask=jax.ShapeDtypeStruct((FE_CAP,), b1),
        out_rows=jax.ShapeDtypeStruct((V_AFF,), i32),
        out_mask=jax.ShapeDtypeStruct((V_AFF,), b1),
        f_rows_h=jax.ShapeDtypeStruct((F_CAP,), i32),
        out_rows_h=jax.ShapeDtypeStruct((V_AFF,), i32),
    )
    shardings = {k: vec for k in structs}
    for k in ("h_old", "h_new", "a", "h_cur"):
        shardings[k] = vsh
    shardings["nct"] = NamedSharding(mesh, P("data", None))
    names = list(structs)
    jitted = jax.jit(
        lambda p, *args: step(p, *args),
        in_shardings=(psh, *[shardings[k] for k in names]),
    )
    return jitted.lower(pst, *[structs[k] for k in names])


_CELLS = {
    "gnn_full_layer": full_layer_cell,
    "gnn_rtec_inc": rtec_inc_cell,
    "gnn_rtec_inc_compact": rtec_inc_compact_cell,
}


def run_cell(name: str, multi_pod: bool) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    lowered = _CELLS[name](mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    stats = analyze_hlo(compiled.as_text(), default_trip_count=1, total_devices=n_chips)
    compute_s = stats.flops / PEAK_FLOPS
    memory_s = stats.hbm_bytes / HBM_BW
    collective_s = stats.collective_bytes / ICI_BW
    dom = max([("compute", compute_s), ("memory", memory_s), ("collective", collective_s)],
              key=lambda kv: kv[1])[0]
    return {
        "arch": name,
        "shape": f"V{V}_E{E if name == 'gnn_full_layer' else E_AFF}_D{D}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "peak_est_gb": round((mem.argument_size_in_bytes + mem.output_size_in_bytes
                                  - mem.alias_size_in_bytes + mem.temp_size_in_bytes) / 1e9, 3),
        },
        "hlo_per_device": {
            "flops": stats.flops,
            "hbm_bytes_raw": stats.hbm_bytes,
            "collective_wire_bytes": stats.collective_bytes,
            "collective_counts": stats.collective_counts,
        },
        "roofline": {
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dom,
            "bound_s": max(compute_s, memory_s, collective_s),
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="opt")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out_dir = OUT_DIR / args.mode
    out_dir.mkdir(parents=True, exist_ok=True)
    for name in ("gnn_rtec_inc", "gnn_full_layer", "gnn_rtec_inc_compact"):
        for mp in (False, True):
            tag = f"{name}__{'pod2' if mp else 'pod1'}"
            path = out_dir / f"{tag}.json"
            if path.exists() and not args.force:
                print(f"[skip cached] {tag}")
                continue
            print(f"[run ] {tag}", flush=True)
            try:
                res = run_cell(name, mp)
                path.write_text(json.dumps(res, indent=2))
                r = res["roofline"]
                print(f"[done] {tag}: compile={res['compile_s']}s "
                      f"mem={res['memory_analysis']['peak_est_gb']}GB "
                      f"c={r['compute_s']:.2e} m={r['memory_s']:.2e} "
                      f"n={r['collective_s']:.2e} dom={r['dominant']}", flush=True)
            except Exception as e:  # noqa
                path.with_suffix(".err").write_text(traceback.format_exc())
                print(f"[FAIL] {tag}: {e}")


if __name__ == "__main__":
    main()
