"""Post-SPMD HLO text analyzer for the roofline (DESIGN.md §10).

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so a
scanned L-layer model under-reports by ~L×.  This analyzer parses the
optimized HLO text and computes, per device:

  * **flops** — 2·M·N·K per ``dot`` (+ convolutions), recursively through
    called computations, multiplying ``while`` bodies by their
    ``known_trip_count`` (fallback: caller-supplied default);
  * **hbm_bytes** — Σ (result + operand bytes) over *top-level* instructions
    of executed computations, NOT descending into fusion bodies — fusion
    internals never round-trip HBM, so instruction boundaries model HBM
    traffic the way XLA's buffer assignment does;
  * **collective wire bytes** — per collective op, bytes on the wire per
    chip using ring-algorithm factors over the op's replica group size g:
      all-gather (g−1)/g·result, all-reduce 2(g−1)/g·result,
      reduce-scatter (g−1)·result, all-to-all (g−1)/g·result,
      collective-permute 1·result.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[\\"]*:?\s*[{\\"]*n[\\"]*:?[\\"]*(\d+)')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


def _shapes_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(x) for x in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instruction:
    name: str
    rest: str  # everything after '='
    result_bytes: int


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    param_types: Dict[str, str]


def _gte_or_param(name: str, comps: Dict[str, "Computation"]) -> bool:
    """True if instruction `name` is a get-tuple-element / parameter anywhere
    (i.e. a loop-carried or argument buffer, alias-eligible)."""
    cache = getattr(_gte_or_param, "_cache", None)
    if cache is None or cache[0] is not comps:
        kinds = {}
        for c in comps.values():
            for k in c.param_types:
                kinds[k] = True
            for ins in c.instructions:
                op_ = _opcode(ins.rest)
                kinds[ins.name] = op_ in ("get-tuple-element", "parameter")
        _gte_or_param._cache = (comps, kinds)
        cache = _gte_or_param._cache
    return cache[1].get(name, False)


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_START_RE.match(line)
            if m and stripped.endswith("{"):
                params = {}
                for pm in re.finditer(r"%?([\w\.\-]+):\s*([\w\[\],\{\} ]+)", m.group(2)):
                    params[pm.group(1)] = pm.group(2)
                cur = Computation(m.group(1), [], params)
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if im:
            name, rest = im.groups()
            cur.instructions.append(Instruction(name, rest, _shapes_bytes(rest.split(" ", 1)[0] if "(" not in rest.split(" ")[0] else rest)))
    return comps


def _result_type(rest: str) -> str:
    # rest looks like: "f32[16,256]{1,0} dot(%a, %b), ..." or "(f32[..], ...) tuple(...)"
    m = re.match(r"(\([^)]*\)|[\w\[\],]+(?:\{[\d,]*\})?)", rest)
    return m.group(1) if m else ""


def _opcode(rest: str) -> str:
    t = _result_type(rest)
    tail = rest[len(t):].strip()
    m = re.match(r"([\w\-]+)", tail)
    return m.group(1) if m else ""


def _operands(rest: str) -> List[str]:
    m = re.search(r"\(([^)]*)\)", rest[rest.index(" ") :] if " " in rest else rest)
    if not m:
        return []
    # operand tokens are "%name" (old text format) or "f32[8,8]{1,0} %name"
    # (xla ≥ 0.4.36 prints inline operand types); the type strings contain
    # commas, so pull the %-prefixed names instead of splitting on ","
    return re.findall(r"%([\w\.\-]+)", m.group(1))


@dataclasses.dataclass
class HLOStats:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_counts: Dict[str, int]
    per_collective_bytes: Dict[str, float]
    # HBM bytes excluding attention-matrix-shaped buffers ([.., Sq, Skv]).
    # The CPU lowering materializes softmax(QKᵀ) in HBM; the TPU deployment
    # streams it through VMEM via the Pallas flash kernel, so the adjusted
    # number models the deployed memory traffic (DESIGN.md §10).
    hbm_bytes_flash_adjusted: float = 0.0
    attn_matrix_bytes: float = 0.0


def _dot_flops(rest: str, symtab: Dict[str, str]) -> float:
    rt = _shape_dims(_result_type(rest))
    if rt is None:
        return 0.0
    _, rdims = rt
    ops = _operands(rest)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
    if not ops or m is None:
        return 0.0
    lhs_type = symtab.get(ops[0])
    if lhs_type is None:
        return 0.0
    lt = _shape_dims(lhs_type)
    if lt is None:
        return 0.0
    _, ldims = lt
    k = 1
    if m.group(1):
        for i in m.group(1).split(","):
            idx = int(i)
            if idx < len(ldims):
                k *= ldims[idx]
    n = 1
    for d in rdims:
        n *= d
    return 2.0 * n * k


def _group_size(rest: str, total_devices: int) -> int:
    m = _GROUPS_RE.search(rest)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_OLD_RE.search(rest)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return total_devices


_WIRE_FACTOR = {
    "all-gather": lambda g: (g - 1) / g,
    "all-reduce": lambda g: 2 * (g - 1) / g,
    "reduce-scatter": lambda g: float(g - 1),
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}


def analyze_hlo(
    hlo: str,
    default_trip_count: int = 1,
    total_devices: int = 1,
    attn_seq_hint: Optional[int] = None,
) -> HLOStats:
    comps = parse_computations(hlo)
    # global symbol table: instruction name -> result type string
    symtab: Dict[str, str] = {}
    for c in comps.values():
        for k, v in c.param_types.items():
            symtab[k] = v
        for ins in c.instructions:
            symtab[ins.name] = _result_type(ins.rest)

    entry = None
    for name, c in comps.items():
        if re.match(r"main", name) or name.endswith("_spmd") and entry is None:
            entry = name
    # prefer a computation literally containing 'main'
    mains = [n for n in comps if "main" in n]
    if mains:
        entry = max(mains, key=lambda n: len(comps[n].instructions))
    if entry is None:  # fallback: biggest computation
        entry = max(comps, key=lambda n: len(comps[n].instructions))

    fusion_names = set()
    for c in comps.values():
        for ins in c.instructions:
            if _opcode(ins.rest) == "fusion":
                m = _CALL_RE.search(ins.rest)
                if m:
                    fusion_names.add(m.group(1))

    # effective read bytes per (fusion computation, param index): if every
    # direct user of a parameter is a slice/gather, the fusion only touches
    # the sliced window — critical for scanned stacked weights, where the
    # full [L, ...] tensor is an operand of a per-layer fusion.
    def _param_read_bytes(comp_name: str) -> Dict[int, float]:
        c = comps.get(comp_name)
        if c is None:
            return {}
        out: Dict[int, float] = {}
        params: Dict[str, int] = {}
        for ins in c.instructions:
            if _opcode(ins.rest) == "parameter":
                m = re.search(r"parameter\((\d+)\)", ins.rest)
                if m:
                    params[ins.name] = int(m.group(1))
        for pname, pidx in params.items():
            users = [i for i in c.instructions if pname in _operands(i.rest)]
            if users and all(
                _opcode(u.rest) in ("dynamic-slice", "gather", "slice") for u in users
            ):
                out[pidx] = sum(_shapes_bytes(_result_type(u.rest)) for u in users)
        return out

    param_reads: Dict[str, Dict[int, float]] = {}

    memo_flops: Dict[str, float] = {}
    memo_bytes: Dict[str, float] = {}
    memo_attn: Dict[str, float] = {}
    memo_coll: Dict[str, Tuple[float, Dict[str, int], Dict[str, float]]] = {}

    def _is_attn_matrix(type_str: str) -> bool:
        """[.., Sq≥128, Skv==hint] — only attention score/prob tensors have a
        trailing dim equal to the (kv-)sequence length at these shapes."""
        if attn_seq_hint is None:
            return False
        sd = _shape_dims(type_str)
        if sd is None:
            return False
        _, dims = sd
        return len(dims) >= 3 and dims[-1] == attn_seq_hint and dims[-2] >= 128

    def comp_flops(name: str) -> float:
        """Recursive flops including fusion bodies and while trips."""
        if name in memo_flops:
            return memo_flops[name]
        memo_flops[name] = 0.0  # cycle guard
        total = 0.0
        c = comps.get(name)
        if c is None:
            return 0.0
        for ins in c.instructions:
            op = _opcode(ins.rest)
            if op == "dot" or op.startswith("convolution"):
                total += _dot_flops(ins.rest, symtab)
            if op == "while":
                wm = _WHILE_RE.search(ins.rest)
                tm = _TRIP_RE.search(ins.rest)
                trips = int(tm.group(1)) if tm else default_trip_count
                if wm:
                    total += trips * comp_flops(wm.group(2)) + comp_flops(wm.group(1))
            else:
                for cm in _CALL_RE.finditer(ins.rest):
                    total += comp_flops(cm.group(1))
        memo_flops[name] = total
        return total

    def comp_bytes(name: str) -> Tuple[float, float]:
        """HBM traffic: top-level instruction boundaries only (no fusion
        internals), while bodies × trips.  Returns (total, attn_matrix_part)."""
        if name in memo_bytes:
            return memo_bytes[name]
        memo_bytes[name] = (0.0, 0.0)
        total = 0.0
        attn = 0.0
        c = comps.get(name)
        if c is None:
            return 0.0, 0.0
        for ins in c.instructions:
            op = _opcode(ins.rest)
            if op in ("tuple", "get-tuple-element", "parameter", "constant", "bitcast"):
                continue
            if op == "while":
                wm = _WHILE_RE.search(ins.rest)
                tm = _TRIP_RE.search(ins.rest)
                trips = int(tm.group(1)) if tm else default_trip_count
                if wm:
                    tb, ab = comp_bytes(wm.group(2))
                    cb, cab = comp_bytes(wm.group(1))
                    total += trips * tb + cb
                    attn += trips * ab + cab
                continue
            if op in ("call", "conditional"):
                for cm in _CALL_RE.finditer(ins.rest):
                    tb, ab = comp_bytes(cm.group(1))
                    total += tb
                    attn += ab
                continue
            rb = _shapes_bytes(_result_type(ins.rest))
            if op in ("dynamic-slice", "gather"):
                # reads only the sliced window, not the whole operand
                total += 2 * rb
                continue
            if op in ("dynamic-update-slice", "scatter"):
                # in-place window write: traffic ≈ 2 × update operand
                ops_ = _operands(ins.rest)
                ub = _shapes_bytes(symtab.get(ops_[1], "")) if len(ops_) > 1 else rb
                total += 2 * ub
                continue
            eff = {}
            if op == "fusion":
                cm = _CALL_RE.search(ins.rest)
                if cm:
                    callee = cm.group(1)
                    if callee not in param_reads:
                        param_reads[callee] = _param_read_bytes(callee)
                    eff = param_reads[callee]
            this_attn = 0.0
            if _is_attn_matrix(_result_type(ins.rest)):
                this_attn += rb
            ob = 0.0
            aliased = False
            for i, o in enumerate(_operands(ins.rest)):
                b = eff.get(i, _shapes_bytes(symtab.get(o, "")))
                # loop-carried buffer updated in place (DUS-style fusion whose
                # result size equals a carried-state operand): XLA's buffer
                # assignment aliases it — the write is windowed, not full-size
                if (
                    not aliased
                    and b == rb
                    and rb > 1 << 20
                    and symtab.get(o) is not None
                    and _gte_or_param(o, comps)
                ):
                    aliased = True
                    continue  # neither read nor rewritten wholesale
                ob += b
                if i not in eff and _is_attn_matrix(symtab.get(o, "")):
                    this_attn += b
            total += (0.0 if aliased else rb) + ob
            attn += this_attn
        memo_bytes[name] = (total, attn)
        return memo_bytes[name]

    def comp_coll(name: str):
        if name in memo_coll:
            return memo_coll[name]
        memo_coll[name] = (0.0, {}, {})
        total = 0.0
        counts: Dict[str, int] = {}
        per: Dict[str, float] = {}
        c = comps.get(name)
        if c is None:
            return 0.0, {}, {}
        for ins in c.instructions:
            op = _opcode(ins.rest)
            if op in COLLECTIVE_OPS or (op.endswith("-start") and op[:-6] in COLLECTIVE_OPS):
                base = op[:-6] if op.endswith("-start") else op
                g = _group_size(ins.rest, total_devices)
                rb = _shapes_bytes(_result_type(ins.rest))
                wire = rb * _WIRE_FACTOR[base](g)
                total += wire
                counts[base] = counts.get(base, 0) + 1
                per[base] = per.get(base, 0.0) + wire
            elif op == "while":
                wm = _WHILE_RE.search(ins.rest)
                tm = _TRIP_RE.search(ins.rest)
                trips = int(tm.group(1)) if tm else default_trip_count
                if wm:
                    t2, c2, p2 = comp_coll(wm.group(2))
                    total += trips * t2
                    for k, v in c2.items():
                        counts[k] = counts.get(k, 0) + trips * v
                    for k, v in p2.items():
                        per[k] = per.get(k, 0.0) + trips * v
            else:
                for cm in _CALL_RE.finditer(ins.rest):
                    if cm.group(1) in fusion_names:
                        continue
                    t2, c2, p2 = comp_coll(cm.group(1))
                    total += t2
                    for k, v in c2.items():
                        counts[k] = counts.get(k, 0) + v
                    for k, v in p2.items():
                        per[k] = per.get(k, 0.0) + v
        memo_coll[name] = (total, counts, per)
        return memo_coll[name]

    coll_total, coll_counts, coll_per = comp_coll(entry)
    total_b, attn_b = comp_bytes(entry)
    return HLOStats(
        flops=comp_flops(entry),
        hbm_bytes=total_b,
        collective_bytes=coll_total,
        collective_counts=coll_counts,
        per_collective_bytes=coll_per,
        hbm_bytes_flash_adjusted=total_b - attn_b,
        attn_matrix_bytes=attn_b,
    )
