"""Step builders + input specs for training/serving under a mesh.

These are shared by the multi-pod dry-run (`launch/dryrun.py`), the real
drivers (`launch/train.py` / `launch/serve.py`) and the benchmarks: one
definition of `train_step` / `prefill_step` / `serve_step` per architecture,
with shardings derived from the logical-axis rules.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.dist.sharding import (
    ShardingConfig,
    auto_spec,
    batch_specs,
    cache_specs,
    opt_state_specs,
    tree_shardings,
)
from repro.models import decode_step, init_model, loss_fn, prefill
from repro.models.encdec import EncDecCache
from repro.train.optimizer import OptConfig, OptState, adamw_init, adamw_update


# ---------------------------------------------------------------------- #
# abstract inputs (ShapeDtypeStruct only — never allocates)
# ---------------------------------------------------------------------- #
def batch_struct(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    out = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.encdec:
        out["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_frontend), jnp.bfloat16)
    if cfg.num_patches:
        out["patches"] = jax.ShapeDtypeStruct((b, cfg.num_patches, cfg.d_frontend), jnp.bfloat16)
    return out


def serve_cache_struct(cfg: ArchConfig, b: int, s_max: int):
    """Abstract decode-cache pytree (via eval_shape; no allocation)."""
    if cfg.encdec:
        hd = cfg.resolved_head_dim

        def mk():
            return EncDecCache(
                k=jnp.zeros((cfg.num_layers, b, cfg.num_kv_heads, s_max, hd), jnp.bfloat16),
                v=jnp.zeros((cfg.num_layers, b, cfg.num_kv_heads, s_max, hd), jnp.bfloat16),
                mem_k=jnp.zeros((cfg.num_layers, b, cfg.num_heads, s_max, hd), jnp.bfloat16),
                mem_v=jnp.zeros((cfg.num_layers, b, cfg.num_heads, s_max, hd), jnp.bfloat16),
                index=jnp.zeros((), jnp.int32),
            )

        return jax.eval_shape(mk)
    from repro.models import init_cache

    return jax.eval_shape(lambda: init_cache(cfg, b, s_max))


def params_struct(cfg: ArchConfig) -> Tuple[Any, Any]:
    """(param ShapeDtypeStruct tree, logical-axes tree) with NO allocation:
    shapes come from `eval_shape` over the full config; the axes tree (static
    python strings, which eval_shape cannot return) comes from an eager init
    of the structurally-identical reduced config."""
    from repro.configs import reduced_config

    shapes = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg)[0])
    _, axes = init_model(jax.random.PRNGKey(0), reduced_config(cfg))
    return shapes, axes


# ---------------------------------------------------------------------- #
# step functions
# ---------------------------------------------------------------------- #
def make_train_step(cfg: ArchConfig, opt_cfg: OptConfig):
    def train_step(params, opt_state: OptState, batch):
        def lf(p):
            return loss_fn(p, cfg, batch)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        new_params, new_state, lr = adamw_update(grads, opt_state, params, opt_cfg)
        return new_params, new_state, {"loss": loss, "lr": lr, **metrics}

    return train_step


def make_prefill_step(cfg: ArchConfig, s_max: int):
    def prefill_step(params, batch):
        return prefill(params, cfg, batch, s_max)

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, cache, token):
        return decode_step(params, cfg, token, cache)

    return serve_step


# ---------------------------------------------------------------------- #
# sharding assembly
# ---------------------------------------------------------------------- #
def shardings_for_cell(
    cfg: ArchConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    fsdp_train: bool = True,
):
    """Returns dict with everything dryrun/train/serve need:
    param/opt/batch/cache shardings + abstract inputs."""
    multi = "pod" in mesh.axis_names
    dp_axes = ("pod", "data") if multi else ("data",)
    # training shards params over data (FSDP); serving keeps TP-only params
    shcfg_train = ShardingConfig(fsdp=fsdp_train, dp_axes=dp_axes)
    shcfg_serve = ShardingConfig(fsdp=False, dp_axes=dp_axes)
    shcfg = shcfg_train if shape.kind == "train" else shcfg_serve

    pstruct, axes = params_struct(cfg)
    psharding = tree_shardings(axes, mesh, shcfg, shapes_tree=pstruct)

    out: Dict[str, Any] = {
        "shcfg": shcfg,
        "params_struct": pstruct,
        "params_sharding": psharding,
    }
    bstruct = batch_struct(cfg, shape)
    bspec = batch_specs(bstruct, mesh, shcfg)
    out["batch_struct"] = bstruct
    out["batch_sharding"] = {k: NamedSharding(mesh, s) for k, s in bspec.items()}

    if shape.kind == "train":
        ostruct = jax.eval_shape(lambda: adamw_init(pstruct))
        # ZeRO: moments always take the dp-sharded (FSDP) layout, even when
        # the params themselves are TP-only (opt_state_specs docstring)
        msharding = opt_state_specs(axes, mesh, shcfg, shapes_tree=pstruct)
        osharding = OptState(
            m=msharding, v=msharding, count=NamedSharding(mesh, P())
        )
        out["opt_struct"] = ostruct
        out["opt_sharding"] = osharding
    else:
        s_max = shape.seq_len + (cfg.num_patches or 0)
        cstruct = serve_cache_struct(cfg, shape.global_batch, s_max)
        cspecs = cache_specs(cstruct, mesh, shcfg, batch=shape.global_batch)
        out["cache_struct"] = cstruct
        out["cache_sharding"] = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                                             is_leaf=lambda x: isinstance(x, P))
        out["token_struct"] = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        tspec = auto_spec((shape.global_batch, 1), mesh, shcfg, batch_dim=0)
        out["token_sharding"] = NamedSharding(mesh, tspec)
        out["s_max"] = s_max
    return out
