import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell and extract the roofline terms (DESIGN.md §8, EXPERIMENTS.md
§Dry-run).

MUST be the process entry point — the XLA_FLAGS line above runs before any
other import (jax locks the device count at first init).  Results are
persisted per cell under experiments/dryrun/<cell>.json so the sweep is
resumable.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, SHAPES, get_arch
from repro.dist.ctx import activation_sharding
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    make_prefill_step,
    make_serve_step,
    make_train_step,
    shardings_for_cell,
)
from repro.train.optimizer import OptConfig

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
ICI_BW = 50e9  # per link


def input_specs(arch: str, shape_name: str = "train_4k"):
    """ShapeDtypeStruct stand-ins for every model input of the given cell —
    weak-type-correct, shardable, no device allocation.  Training shapes
    return the {tokens, labels, frames?, patches?} batch; decode shapes also
    return the abstract cache pytree."""
    from repro.launch.steps import batch_struct, serve_cache_struct

    cfg = production_cfg(arch)
    shape = SHAPES[shape_name]
    out = dict(batch_struct(cfg, shape))
    if shape.kind == "decode":
        out["cache"] = serve_cache_struct(
            cfg, shape.global_batch, shape.seq_len + (cfg.num_patches or 0)
        )
        out["token"] = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return out


def cell_skipped(arch: str, shape_name: str) -> str:
    cfg = get_arch(arch)
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return "pure full-attention arch — long_500k needs sub-quadratic attention (DESIGN.md §5)"
    return ""


def production_cfg(arch: str):
    return dataclasses.replace(get_arch(arch), param_dtype="bfloat16")


def run_cell(arch: str, shape_name: str, multi_pod: bool, mode: str = "opt") -> dict:
    # mode: 'baseline' = XLA propagation only; 'opt' = explicit activation
    # sharding constraints (ashard) — the main §Perf lever.
    import contextlib

    cfg = production_cfg(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    sh = shardings_for_cell(cfg, shape, mesh)
    ctx = (
        activation_sharding(mesh, sh["shcfg"])
        if mode == "opt"
        else contextlib.nullcontext()
    )

    t0 = time.time()
    with ctx:
        if shape.kind == "train":
            step = make_train_step(cfg, OptConfig())
            jitted = jax.jit(
                step,
                in_shardings=(sh["params_sharding"], sh["opt_sharding"], sh["batch_sharding"]),
            )
            lowered = jitted.lower(sh["params_struct"], sh["opt_struct"], sh["batch_struct"])
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, sh["s_max"])
            bstruct = dict(sh["batch_struct"])
            bstruct.pop("labels")
            bsh = dict(sh["batch_sharding"])
            bsh.pop("labels")
            jitted = jax.jit(step, in_shardings=(sh["params_sharding"], bsh))
            lowered = jitted.lower(sh["params_struct"], bstruct)
        else:  # decode
            step = make_serve_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(sh["params_sharding"], sh["cache_sharding"], sh["token_sharding"]),
            )
            lowered = jitted.lower(sh["params_struct"], sh["cache_struct"], sh["token_struct"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # train/prefill: attention matrices stream through VMEM on TPU (flash
    # kernel) — exclude them from the HBM term (they exist only in the CPU
    # lowering).  decode keeps the raw number (it uses the XLA path on TPU).
    hint = shape.seq_len if shape.kind in ("train", "prefill") else None
    stats = analyze_hlo(hlo, default_trip_count=cfg.num_layers,
                        total_devices=n_chips, attn_seq_hint=hint)

    compute_s = stats.flops / PEAK_FLOPS
    hbm_eff = stats.hbm_bytes_flash_adjusted if hint else stats.hbm_bytes
    memory_s = hbm_eff / HBM_BW
    collective_s = stats.collective_bytes / ICI_BW
    dominant = max(
        [("compute", compute_s), ("memory", memory_s), ("collective", collective_s)],
        key=lambda kv: kv[1],
    )[0]

    n = cfg.param_count()
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * n_active * tokens
    hlo_flops_total = stats.flops * n_chips

    result = {
        "arch": arch,
        "shape": shape_name,
        "mode": mode,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "peak_est_gb": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 - mem.alias_size_in_bytes + mem.temp_size_in_bytes) / 1e9, 3),
        },
        "xla_cost_analysis": {
            "flops_per_device_unscaled": cost.get("flops", 0.0),
            "bytes_per_device_unscaled": cost.get("bytes accessed", 0.0),
        },
        "hlo_per_device": {
            "flops": stats.flops,
            "hbm_bytes_raw": stats.hbm_bytes,
            "hbm_bytes_flash_adjusted": stats.hbm_bytes_flash_adjusted,
            "attn_matrix_bytes_excluded": stats.attn_matrix_bytes,
            "collective_wire_bytes": stats.collective_bytes,
            "collective_counts": stats.collective_counts,
            "per_collective_bytes": stats.per_collective_bytes,
        },
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant,
            "bound_s": max(compute_s, memory_s, collective_s),
        },
        "model_flops": {
            "params": n,
            "active_params": n_active,
            "model_flops": model_flops,
            "hlo_flops_total": hlo_flops_total,
            "useful_fraction": model_flops / hlo_flops_total if hlo_flops_total else 0.0,
        },
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--mode", choices=["baseline", "opt"], default="baseline")
    args = ap.parse_args()

    out_dir = OUT_DIR / args.mode
    out_dir.mkdir(parents=True, exist_ok=True)
    cells = []
    if args.all:
        for a in ARCH_NAMES:
            for s in SHAPES:
                for mp in (False, True):
                    cells.append((a, s, mp))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells.append((args.arch, args.shape, args.multi_pod))

    for arch, shape_name, mp in cells:
        tag = f"{arch}__{shape_name}__{'pod2' if mp else 'pod1'}"
        out_path = out_dir / f"{tag}.json"
        if out_path.exists() and not args.force:
            print(f"[skip cached] {tag}")
            continue
        skip = cell_skipped(arch, shape_name)
        if skip:
            out_path.write_text(json.dumps({"arch": arch, "shape": shape_name,
                                            "mesh": "2x16x16" if mp else "16x16",
                                            "skipped": skip}, indent=2))
            print(f"[skip] {tag}: {skip}")
            continue
        print(f"[run ] {tag} ...", flush=True)
        try:
            res = run_cell(arch, shape_name, mp, mode=args.mode)
            out_path.write_text(json.dumps(res, indent=2))
            r = res["roofline"]
            print(
                f"[done] {tag}: lower={res['lower_s']}s compile={res['compile_s']}s "
                f"mem={res['memory_analysis']['peak_est_gb']}GB "
                f"compute={r['compute_s']:.2e}s memory={r['memory_s']:.2e}s "
                f"coll={r['collective_s']:.2e}s dominant={r['dominant']}",
                flush=True,
            )
        except Exception as e:  # noqa
            out_path.with_suffix(".err").write_text(traceback.format_exc())
            print(f"[FAIL] {tag}: {e}")


if __name__ == "__main__":
    main()
