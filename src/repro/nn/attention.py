"""GQA attention with RoPE, KV cache, sliding window, optional QK-norm.

Dispatch policy (DESIGN.md §7): training/prefill shapes (static q_offset=0,
static window) route to the Pallas flash kernel on TPU; decode shapes
(traced cache index) and traced per-layer windows (hymba's scanned layer mix)
use the XLA einsum path — decode attention is HBM-bandwidth-bound, where the
kernel adds nothing over XLA's fused gather+dot.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.dist.ctx import ashard
from repro.kernels import ops as kops
from repro.nn import param as pm
from repro.nn.layers import apply_rope, rms_norm, rope_freqs


class KVCache(NamedTuple):
    k: jax.Array  # [B, Hkv, S_max, Dh]
    v: jax.Array  # [B, Hkv, S_max, Dh]


def init_attention(
    key,
    layers: int,
    d_model: int,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    qkv_bias: bool = False,
    qk_norm: bool = False,
    dtype=jnp.float32,
) -> Dict[str, pm.Param]:
    ks = jax.random.split(key, 4)
    p = {
        "wq": pm.stacked_dense(ks[0], layers, (d_model, n_heads * head_dim), ("embed", "heads"), dtype),
        "wk": pm.stacked_dense(ks[1], layers, (d_model, n_kv * head_dim), ("embed", "heads"), dtype),
        "wv": pm.stacked_dense(ks[2], layers, (d_model, n_kv * head_dim), ("embed", "heads"), dtype),
        "wo": pm.stacked_dense(ks[3], layers, (n_heads * head_dim, d_model), ("heads", "embed"), dtype),
    }
    if qkv_bias:
        p["bq"] = pm.stacked_zeros(layers, (n_heads * head_dim,), ("heads",), dtype)
        p["bk"] = pm.stacked_zeros(layers, (n_kv * head_dim,), ("heads",), dtype)
        p["bv"] = pm.stacked_zeros(layers, (n_kv * head_dim,), ("heads",), dtype)
    if qk_norm:
        p["q_norm"] = pm.stacked_ones(layers, (head_dim,), (None,), dtype)
        p["k_norm"] = pm.stacked_ones(layers, (head_dim,), (None,), dtype)
    return p


def attention_core(
    q: jax.Array,  # [B, Hq, Sq, Dh]
    k: jax.Array,  # [B, Hkv, Sk, Dh]
    v: jax.Array,
    causal: bool,
    window: Union[None, int, jax.Array],
    q_offset: Union[int, jax.Array],
) -> jax.Array:
    static = isinstance(window, (int, type(None))) and isinstance(q_offset, int)
    if static and q.shape[2] > 1:
        return kops.flash_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)
    # XLA path (decode / traced window).  Grouped-GQA einsum — NOT
    # jnp.repeat: repeating KV heads materializes a g-times-larger tensor and
    # breaks the cache's position sharding, forcing XLA SPMD into an
    # involuntary full rematerialization (all-gather of the whole cache;
    # EXPERIMENTS.md Perf decode iteration).
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    sk = k.shape[2]
    # keep K/V in cache dtype (bf16): MXU consumes bf16 natively; accumulate
    # in f32 via preferred_element_type (§Perf decode iter 2).
    qf = q.reshape(b, hkv, g, sq, d).astype(k.dtype)

    def _attend(q_chunk, off):
        # q_chunk [b, hkv, g, qc, d]; off = absolute position of row 0
        qc = q_chunk.shape[3]
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", q_chunk, k,
                            preferred_element_type=jnp.float32) / jnp.sqrt(d)
        qpos = off + jnp.arange(qc)[:, None]
        kpos = jnp.arange(sk)[None, :]
        m = jnp.ones((qc, sk), bool)
        if causal:
            m &= kpos <= qpos
        if window is not None:
            m &= kpos > qpos - window
        logits = jnp.where(m[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", probs.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return out

    CHUNK = 2048
    if sq > CHUNK and sq % CHUNK == 0 and isinstance(q_offset, int):
        # long prefill: chunk the query dim so the probs buffer is
        # [.., CHUNK, Sk] instead of [.., Sq, Sk] (bounds HBM when the
        # Pallas flash path is unavailable, e.g. the CPU-lowered dry-run)
        nb = sq // CHUNK
        qb = jnp.moveaxis(qf.reshape(b, hkv, g, nb, CHUNK, d), 3, 0)
        offs = q_offset + CHUNK * jnp.arange(nb)
        outs = jax.lax.map(lambda args: _attend(*args), (qb, offs))
        out = jnp.moveaxis(outs, 0, 3).reshape(b, hkv, g, sq, d)
    else:
        out = _attend(qf, q_offset)
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def attention_apply(
    p: Dict[str, jax.Array],  # per-layer slice (no leading L dim)
    x: jax.Array,  # [B, S, D]
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float = 1e6,
    causal: bool = True,
    window: Union[None, int, jax.Array] = None,
    cache: Optional[KVCache] = None,
    cache_index: Union[int, jax.Array] = 0,
    use_rope: bool = True,
) -> Tuple[jax.Array, Optional[KVCache]]:
    """Self-attention over x.  If `cache` is given:
      * S == cache length → prefill: fills cache positions [0, S)
      * S == 1            → decode: writes position `cache_index`, attends to
                            the full cache with q_offset = cache_index.
    """
    b, s, d_model = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = ashard(q.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3), "dp", "tp")
    k = ashard(k.reshape(b, s, n_kv, head_dim).transpose(0, 2, 1, 3), "dp", "tp")
    v = ashard(v.reshape(b, s, n_kv, head_dim).transpose(0, 2, 1, 3), "dp", "tp")
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if use_rope:
        if isinstance(cache_index, int) and cache_index == 0:
            positions = jnp.arange(s)
        else:
            positions = cache_index + jnp.arange(s)
        angles = rope_freqs(head_dim, rope_theta, positions)
        q = ashard(apply_rope(q, angles), "dp", "tp")
        k = ashard(apply_rope(k, angles), "dp", "tp")

    new_cache = None
    if cache is not None:
        if s == 1:  # decode
            idx = cache_index if not isinstance(cache_index, int) else jnp.asarray(cache_index)
            ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, 0, idx, 0))
            cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, 0, idx, 0))
            new_cache = KVCache(ck, cv)
            out = attention_core(q, ck, cv, causal=causal, window=window, q_offset=idx)
        else:  # prefill
            ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0))
            new_cache = KVCache(ck, cv)
            out = attention_core(q, k, v, causal=causal, window=window, q_offset=0)
    else:
        out = attention_core(q, k, v, causal=causal, window=window,
                             q_offset=0 if isinstance(cache_index, int) else cache_index)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, n_heads * head_dim)
    return out @ p["wo"], new_cache


def init_cross_attention(key, layers, d_model, d_enc, n_heads, head_dim, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {
        "wq": pm.stacked_dense(ks[0], layers, (d_model, n_heads * head_dim), ("embed", "heads"), dtype),
        "wk": pm.stacked_dense(ks[1], layers, (d_enc, n_heads * head_dim), ("embed", "heads"), dtype),
        "wv": pm.stacked_dense(ks[2], layers, (d_enc, n_heads * head_dim), ("embed", "heads"), dtype),
        "wo": pm.stacked_dense(ks[3], layers, (n_heads * head_dim, d_model), ("heads", "embed"), dtype),
    }


def cross_attention_apply(
    p: Dict[str, jax.Array],
    x: jax.Array,  # [B, Sq, D]
    memory_kv: Tuple[jax.Array, jax.Array],  # precomputed ([B,H,Sk,dh], [B,H,Sk,dh])
    *,
    n_heads: int,
    head_dim: int,
) -> jax.Array:
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)
    k, v = memory_kv
    out = attention_core(q, k, v, causal=False, window=None, q_offset=0)
    return out.transpose(0, 2, 1, 3).reshape(b, s, n_heads * head_dim) @ p["wo"]


def cross_memory(p: Dict[str, jax.Array], enc: jax.Array, n_heads: int, head_dim: int):
    """Precompute encoder-side K/V for cross attention (once per request)."""
    b, sk, _ = enc.shape
    k = (enc @ p["wk"]).reshape(b, sk, n_heads, head_dim).transpose(0, 2, 1, 3)
    v = (enc @ p["wv"]).reshape(b, sk, n_heads, head_dim).transpose(0, 2, 1, 3)
    return k, v


def attention_prefill_kv(
    p: Dict[str, jax.Array],
    x: jax.Array,  # [B, S, D]
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float = 1e6,
    causal: bool = True,
    window=None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Prefill that also returns the (rope-applied) full-length K/V so the
    caller can populate dense or ring caches (DESIGN.md §5)."""
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = ashard(q.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3), "dp", "tp")
    k = ashard(k.reshape(b, s, n_kv, head_dim).transpose(0, 2, 1, 3), "dp", "tp")
    v = ashard(v.reshape(b, s, n_kv, head_dim).transpose(0, 2, 1, 3), "dp", "tp")
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    angles = rope_freqs(head_dim, rope_theta, jnp.arange(s))
    q = ashard(apply_rope(q, angles), "dp", "tp")
    k = ashard(apply_rope(k, angles), "dp", "tp")
    out = attention_core(q, k, v, causal=causal, window=window, q_offset=0)
    out = ashard(out, "dp", "tp")
    out = out.transpose(0, 2, 1, 3).reshape(b, s, n_heads * head_dim)
    return out @ p["wo"], k, v


def ring_decode_attention(
    p: Dict[str, jax.Array],
    x: jax.Array,  # [B, 1, D]
    ck: jax.Array,  # [B, Hkv, W, dh] ring cache (rope-applied keys)
    cv: jax.Array,
    index,  # traced scalar: absolute position being generated
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float = 1e6,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sliding-window decode against a ring-buffer KV cache.  Slot s of the
    ring holds absolute position p(s) = index - ((index - s) mod W); slots
    with p(s) < 0 are masked (not yet written)."""
    b, _, _ = x.shape
    w = ck.shape[2]
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, 1, n_heads, head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, 1, n_kv, head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, 1, n_kv, head_dim).transpose(0, 2, 1, 3)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    angles = rope_freqs(head_dim, rope_theta, index + jnp.arange(1))
    q = apply_rope(q, angles)
    k = apply_rope(k, angles)
    slot = jnp.mod(index, w)
    ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, slot, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, slot, 0))
    # absolute position per slot
    s_idx = jnp.arange(w)
    pos = index - jnp.mod(index - s_idx, w)
    mask = pos >= 0
    g = n_heads // n_kv
    qf = q.reshape(b, n_kv, g, 1, head_dim).astype(jnp.float32)
    kf = ck.astype(jnp.float32)
    vf = cv.astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) / jnp.sqrt(head_dim)
    logits = jnp.where(mask[None, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)
    out = out.reshape(b, n_heads, 1, head_dim).astype(x.dtype)
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, n_heads * head_dim)
    return out @ p["wo"], ck, cv
