"""Functional NN building blocks: norms, MLPs, RoPE, embeddings."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma + beta


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    from repro.dist.ctx import ashard

    g = ashard(x @ w_gate, "dp", None, "tp")
    u = ashard(x @ w_up, "dp", None, "tp")
    return (jax.nn.silu(g) * u) @ w_down


def rope_freqs(head_dim: int, theta: float, positions: jax.Array) -> jax.Array:
    """[*, head_dim/2] complex rotation angles for the given positions."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    return positions[..., None].astype(jnp.float32) * inv  # [*, hd/2]


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: [B, H, S, D]; angles: [S, D/2] or [B, S, D/2]."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if angles.ndim == 2:
        cos = jnp.cos(angles)[None, None]
        sin = jnp.sin(angles)[None, None]
    else:
        cos = jnp.cos(angles)[:, None]
        sin = jnp.sin(angles)[:, None]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def embed_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    return jnp.take(table, ids, axis=0)


def softmax_xent(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross entropy; logits [.., V] fp32-stabilized."""
    lg = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
