"""Parameter trees with paired logical sharding axes.

Every parameter is created as ``Param(value, axes)`` where ``axes`` is a
tuple of logical axis names (one per dim, ``None`` = replicated).  Model
init builds one tree; :func:`unzip` splits it into the value tree (for
compute) and the axes tree (for the sharding rule system in
``repro.dist.sharding``) — the MaxText-style "logical axis" pattern.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Param(NamedTuple):
    value: Any
    axes: Tuple[Optional[str], ...]


def is_param(x) -> bool:
    return isinstance(x, Param)


def unzip(tree):
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def dense(key, shape, axes, dtype=jnp.float32, scale: float = 1.0) -> Param:
    fan_in = shape[0] if len(shape) > 1 else max(shape[0], 1)
    std = scale / (fan_in**0.5)
    return Param(jax.random.normal(key, shape, dtype) * jnp.asarray(std, dtype), axes)


def stacked_dense(key, layers: int, shape, axes, dtype=jnp.float32, scale: float = 1.0) -> Param:
    """[layers, *shape] for lax.scan over layers; leading axis logical name 'layers'."""
    fan_in = shape[0] if len(shape) > 1 else max(shape[0], 1)
    std = scale / (fan_in**0.5)
    v = jax.random.normal(key, (layers, *shape), dtype) * jnp.asarray(std, dtype)
    return Param(v, ("layers", *axes))


def zeros(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(shape, dtype), axes)


def ones(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(shape, dtype), axes)


def stacked_zeros(layers: int, shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros((layers, *shape), dtype), ("layers", *axes))


def stacked_ones(layers: int, shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.ones((layers, *shape), dtype), ("layers", *axes))
