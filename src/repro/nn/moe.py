"""Mixture-of-Experts layer (GShard-style capacity dispatch, EP-shardable).

Top-k routing with per-expert capacity: tokens are gathered into an
[E, C, D] dispatch tensor (index-based gather, not one-hot — the one-hot
dispatch tensor is O(N·E·C) and never materializable at LM scale), expert
SwiGLU MLPs run as a batched einsum sharded over the expert axis (EP on the
mesh 'model' axis), and results scatter-add back weighted by the normalized
top-k gates.  Overflow tokens beyond capacity_factor are dropped (classic
GShard; the §Perf log discusses the dropless alternative).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.nn import param as pm


def init_moe(
    key,
    layers: int,
    d_model: int,
    d_ff: int,
    num_experts: int,
    dtype=jnp.float32,
    num_shared: int = 0,
    shared_d_ff: int = 0,
) -> Dict[str, pm.Param]:
    ks = jax.random.split(key, 5)
    std = 1.0 / (d_model**0.5)
    stdf = 1.0 / (d_ff**0.5)
    p = {
        "router": pm.stacked_dense(ks[0], layers, (d_model, num_experts), ("embed", None), jnp.float32),
        "wi": pm.Param(
            jax.random.normal(ks[1], (layers, num_experts, d_model, d_ff), dtype) * std,
            ("layers", "experts", "embed", "mlp"),
        ),
        "wg": pm.Param(
            jax.random.normal(ks[2], (layers, num_experts, d_model, d_ff), dtype) * std,
            ("layers", "experts", "embed", "mlp"),
        ),
        "wo": pm.Param(
            jax.random.normal(ks[3], (layers, num_experts, d_ff, d_model), dtype) * stdf,
            ("layers", "experts", "mlp", "embed"),
        ),
    }
    if num_shared:
        ks2 = jax.random.split(ks[4], 3)
        p["shared_wi"] = pm.stacked_dense(ks2[0], layers, (d_model, shared_d_ff), ("embed", "mlp"), dtype)
        p["shared_wg"] = pm.stacked_dense(ks2[1], layers, (d_model, shared_d_ff), ("embed", "mlp"), dtype)
        p["shared_wo"] = pm.stacked_dense(ks2[2], layers, (shared_d_ff, d_model), ("mlp", "embed"), dtype)
    return p


def moe_apply(
    p: Dict[str, jax.Array],  # per-layer slice
    x: jax.Array,  # [B, S, D]  (B doubles as the GShard dispatch group)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output [B,S,D], aux load-balancing loss).

    Grouped dispatch: capacity is per (group=batch-row, expert), so the
    gather stays local to the group's data shard and the expert einsum is
    sharded over the expert axis — under GSPMD this propagates to
    (data × model)-local compute with one all-reduce at combine, never a
    global token gather."""
    b, s, d = x.shape
    e = p["router"].shape[1]

    logits = x.astype(jnp.float32) @ p["router"]  # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # [B, S, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux loss (Switch): e * Σ_e fraction_tokens_e * mean_prob_e
    top1 = gate_idx[..., 0]
    frac = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(frac * probs.mean((0, 1)))

    cap = min(int(capacity_factor * top_k * s / e) + 1, s)
    # per-group routed score matrix [B, S, E] (0 where not routed)
    routed = jnp.zeros((b, s, e), jnp.float32)
    bidx = jnp.arange(b)[:, None, None]
    sidx = jnp.arange(s)[None, :, None]
    routed = routed.at[bidx, sidx, gate_idx].set(gate_vals)
    # per (group, expert): top-C tokens within the group
    sel_score, sel_idx = jax.lax.top_k(routed.transpose(0, 2, 1), cap)  # [B, E, C]
    valid = sel_score > 0.0

    from repro.dist.ctx import ashard

    xs = jnp.take_along_axis(
        x[:, None, :, :], sel_idx[..., None], axis=2
    )  # [B, E, C, D] — group-local gather
    xs = ashard(xs * valid[..., None].astype(xs.dtype), "dp", "tp")
    g = jnp.einsum("becd,edf->becf", xs, p["wg"])
    u = jnp.einsum("becd,edf->becf", xs, p["wi"])
    y = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, p["wo"])  # [B, E, C, D]
    y = ashard(y * sel_score[..., None].astype(y.dtype), "dp", "tp")

    # combine: scatter back within the group, summing across experts
    flat_idx = jnp.where(valid, sel_idx, s)  # [B, E, C]
    out = jax.vmap(
        lambda yy, ii: jax.ops.segment_sum(
            yy.reshape(-1, d), ii.reshape(-1), num_segments=s + 1
        )[:s]
    )(y, flat_idx)  # [B, S, D]

    if "shared_wi" in p:
        gsh = x @ p["shared_wg"]
        ush = x @ p["shared_wi"]
        out = out + (jax.nn.silu(gsh) * ush) @ p["shared_wo"]
    return out.astype(x.dtype), aux
