"""NN substrate for the LM model zoo: functional layers with paired
logical-axis metadata for GSPMD sharding."""
