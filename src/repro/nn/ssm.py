"""Recurrent sequence mixers: Mamba-2-style SSD and xLSTM (mLSTM/sLSTM).

TPU adaptation (DESIGN.md §3): selective scans are realized in *chunkwise
parallel* form — within a chunk the recurrence becomes masked-decay matmuls
(MXU work), across chunks a `lax.scan` carries the matrix state.  This is
the SSD duality (Mamba-2) and the standard chunked mLSTM formulation; the
per-step sequential forms are kept as oracles (`*_seq`) and as the O(1)
decode steps (`*_step`).

Shapes: q/k [B, S, H, dk], v [B, S, H, dv], log-decay la [B, S, H] (≤ 0),
optional log input gate li [B, S, H] (mLSTM).  State [B, H, dk, dv].
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp



# ====================================================================== #
# SSD (scalar-decay linear recurrence): S_t = a_t S_{t-1} + k_tᵀ v_t
#                                       y_t = q_t S_t
# ====================================================================== #
def ssd_seq(q, k, v, la, s0=None):
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    state = jnp.zeros((b, h, dk, dv), jnp.float32) if s0 is None else s0

    def step(state, inp):
        qt, kt, vt, lat = inp  # [B,H,dk] [B,H,dk] [B,H,dv] [B,H]
        a = jnp.exp(lat)[..., None, None]
        state = a * state + kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", qt, state)
        return state, y

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), la.transpose(1, 0, 2))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state  # [B,S,H,dv]


def ssd_chunked(q, k, v, la, s0=None, chunk: int = 128):
    """Chunkwise-parallel SSD. Returns (y [B,S,H,dv], final state).

    Non-multiple lengths are padded with identity steps (k=v=0, decay=1):
    they contribute nothing and leave the carried state untouched."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    pad = (-s) % chunk
    if pad:
        zq = jnp.zeros((b, pad, h, dk), q.dtype)
        q = jnp.concatenate([q, zq], 1)
        k = jnp.concatenate([k, zq], 1)
        v = jnp.concatenate([v, jnp.zeros((b, pad, h, dv), v.dtype)], 1)
        la = jnp.concatenate([la, jnp.zeros((b, pad, h), la.dtype)], 1)
        y, st = ssd_chunked(q, k, v, la, s0=s0, chunk=chunk)
        return y[:, :s], st
    nc = s // chunk
    qf = q.reshape(b, nc, chunk, h, dk).astype(jnp.float32)
    kf = k.reshape(b, nc, chunk, h, dk).astype(jnp.float32)
    vf = v.reshape(b, nc, chunk, h, dv).astype(jnp.float32)
    laf = la.reshape(b, nc, chunk, h).astype(jnp.float32)
    state0 = jnp.zeros((b, h, dk, dv), jnp.float32) if s0 is None else s0

    def chunk_step(state, inp):
        qc, kc, vc, lac = inp  # [B,c,H,*]
        cum = jnp.cumsum(lac, axis=1)  # [B,c,H]
        total = cum[:, -1]  # [B,H]
        # intra-chunk: L[t,s] = exp(cum_t - cum_s) for s<=t
        rel = cum[:, :, None, :] - cum[:, None, :, :]  # [B,t,s,H]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        L = jnp.where(tri[None, :, :, None], jnp.exp(rel), 0.0)
        scores = jnp.einsum("bthk,bshk->btsh", qc, kc) * L
        y_intra = jnp.einsum("btsh,bshv->bthv", scores, vc)
        # inter-chunk: y += exp(cum_t) q_t S_prev
        qdec = qc * jnp.exp(cum)[..., None]
        y_inter = jnp.einsum("bthk,bhkv->bthv", qdec, state)
        # state update: S = exp(total) S + Σ_s exp(total - cum_s) k_s v_sᵀ
        w = jnp.exp(total[:, None] - cum)  # [B,c,H]
        kw = kf_scale = kc * w[..., None]
        s_new = jnp.exp(total)[..., None, None] * state + jnp.einsum(
            "bshk,bshv->bhkv", kw, vc
        )
        return s_new, y_intra + y_inter

    xs = (qf.transpose(1, 0, 2, 3, 4), kf.transpose(1, 0, 2, 3, 4),
          vf.transpose(1, 0, 2, 3, 4), laf.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(chunk_step, state0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dv)
    return y.astype(v.dtype), state


def ssd_step(state, qt, kt, vt, lat):
    """Single decode step. state [B,H,dk,dv]; qt/kt [B,H,dk], vt [B,H,dv]."""
    a = jnp.exp(lat.astype(jnp.float32))[..., None, None]
    state = a * state + kt.astype(jnp.float32)[..., :, None] * vt.astype(jnp.float32)[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", qt.astype(jnp.float32), state)
    return state, y.astype(vt.dtype)


# ====================================================================== #
# mLSTM (xLSTM): matrix memory + normalizer + exp input gate, stabilized
#   C_t = f_t C_{t-1} + i_t k_tᵀ v_t ;  n_t = f_t n_{t-1} + i_t k_t
#   h_t = (q_t C_t) / max(|q_t n_t|, 1)
# with log-space gates lf = logsigmoid(f̂), li = î and running max
# stabilizer m (chunk-granular in the chunked form; DESIGN.md §4).
# ====================================================================== #
class MLSTMState(NamedTuple):
    c: jax.Array  # [B, H, dk, dv]
    n: jax.Array  # [B, H, dk]
    m: jax.Array  # [B, H]


def mlstm_init_state(b, h, dk, dv):
    return MLSTMState(
        c=jnp.zeros((b, h, dk, dv), jnp.float32),
        n=jnp.zeros((b, h, dk), jnp.float32),
        m=jnp.full((b, h), -1e30, jnp.float32),
    )


def mlstm_seq(q, k, v, lf, li, st: Optional[MLSTMState] = None):
    """Per-step oracle (stabilized exactly as the xLSTM paper)."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    st = st or mlstm_init_state(b, h, dk, dv)

    def step(st, inp):
        qt, kt, vt, lft, lit = inp
        m_new = jnp.maximum(st.m + lft, lit)
        fdec = jnp.exp(st.m + lft - m_new)
        iexp = jnp.exp(lit - m_new)
        c = fdec[..., None, None] * st.c + iexp[..., None, None] * (
            kt[..., :, None] * vt[..., None, :]
        )
        n = fdec[..., None] * st.n + iexp[..., None] * kt
        num = jnp.einsum("bhk,bhkv->bhv", qt, c)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", qt, n))
        h_t = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return MLSTMState(c, n, m_new), h_t

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (q, k, v)) + tuple(
        a.transpose(1, 0, 2) for a in (lf, li)
    )
    st, ys = jax.lax.scan(step, st, xs)
    return ys.transpose(1, 0, 2, 3), st


def mlstm_chunked(q, k, v, lf, li, st: Optional[MLSTMState] = None, chunk: int = 128):
    """Chunkwise mLSTM with per-step-exact stabilizer computed via cummax.

    Non-multiple lengths padded with identity steps (decay 1, gate −∞)."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    pad = (-s) % chunk
    if pad:
        zq = jnp.zeros((b, pad, h, dk), q.dtype)
        q = jnp.concatenate([q, zq], 1)
        k = jnp.concatenate([k, zq], 1)
        v = jnp.concatenate([v, jnp.zeros((b, pad, h, dv), v.dtype)], 1)
        lf = jnp.concatenate([lf, jnp.zeros((b, pad, h), lf.dtype)], 1)
        li = jnp.concatenate([li, jnp.full((b, pad, h), -1e30, li.dtype)], 1)
        y, stf = mlstm_chunked(q, k, v, lf, li, st=st, chunk=chunk)
        return y[:, :s], stf
    nc = s // chunk
    st = st or mlstm_init_state(b, h, dk, dv)
    qf = q.reshape(b, nc, chunk, h, dk).astype(jnp.float32)
    kf = k.reshape(b, nc, chunk, h, dk).astype(jnp.float32)
    vf = v.reshape(b, nc, chunk, h, dv).astype(jnp.float32)
    lff = lf.reshape(b, nc, chunk, h).astype(jnp.float32)
    lif = li.reshape(b, nc, chunk, h).astype(jnp.float32)

    def chunk_step(carry, inp):
        c_st, n_st, m_st = carry
        qc, kc, vc, lfc, lic = inp
        cum = jnp.cumsum(lfc, axis=1)  # Σ_{r≤t} lf_r   [B,c,H]
        total = cum[:, -1]
        # per-step stabilizer: m_t = cum_t + max(m_0, cummax_s≤t(li_s - cum_s))
        z = lic - cum
        zmax = jax.lax.cummax(z, axis=1)
        m_t = cum + jnp.maximum(m_st[:, None], zmax)  # [B,c,H]
        # intra contributions: D[t,s] = exp(cum_t - cum_s + li_s - m_t), s≤t
        rel = cum[:, :, None, :] - cum[:, None, :, :] + lic[:, None, :, :] - m_t[:, :, None, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        D = jnp.where(tri[None, :, :, None], jnp.exp(rel), 0.0)
        scores = jnp.einsum("bthk,bshk->btsh", qc, kc) * D
        num_intra = jnp.einsum("btsh,bshv->bthv", scores, vc)
        # denominator uses k (not qk): n contribution = Σ_s D[t,s] k_s
        n_intra = jnp.einsum("btsh,bshk->bthk", D, kc)
        # inter: decay of old state to step t: exp(cum_t + m_0 - m_t)
        dec = jnp.exp(cum + m_st[:, None] - m_t)  # [B,c,H]
        num_inter = jnp.einsum("bthk,bhkv->bthv", qc * dec[..., None], c_st)
        n_t = n_intra + dec[..., None] * n_st[:, None]
        num = num_intra + num_inter
        den = jnp.abs(jnp.einsum("bthk,bthk->bth", qc, n_t))
        y = num / jnp.maximum(den, jnp.exp(-m_t))[..., None]
        # carry update (end of chunk, stabilized at m_end)
        m_end = m_t[:, -1]
        w = jnp.exp(total[:, None] - cum + lic - m_end[:, None])  # [B,c,H]
        c_new = jnp.exp(total + m_st - m_end)[..., None, None] * c_st + jnp.einsum(
            "bshk,bshv->bhkv", kc * w[..., None], vc
        )
        n_new = jnp.exp(total + m_st - m_end)[..., None] * n_st + jnp.einsum(
            "bsh,bshk->bhk", w, kc
        )
        return (c_new, n_new, m_end), y

    xs = (qf.transpose(1, 0, 2, 3, 4), kf.transpose(1, 0, 2, 3, 4),
          vf.transpose(1, 0, 2, 3, 4), lff.transpose(1, 0, 2, 3),
          lif.transpose(1, 0, 2, 3))
    (c, n, m), ys = jax.lax.scan(chunk_step, (st.c, st.n, st.m), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dv)
    return y.astype(v.dtype), MLSTMState(c, n, m)


def mlstm_step(st: MLSTMState, qt, kt, vt, lft, lit):
    qt, kt, vt = (a.astype(jnp.float32) for a in (qt, kt, vt))
    m_new = jnp.maximum(st.m + lft, lit)
    fdec = jnp.exp(st.m + lft - m_new)
    iexp = jnp.exp(lit - m_new)
    c = fdec[..., None, None] * st.c + iexp[..., None, None] * (kt[..., :, None] * vt[..., None, :])
    n = fdec[..., None] * st.n + iexp[..., None] * kt
    num = jnp.einsum("bhk,bhkv->bhv", qt, c)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", qt, n))
    h_t = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return MLSTMState(c, n, m_new), h_t


# ====================================================================== #
# sLSTM (xLSTM): scalar memory per head-dim, sequential by nature
# ====================================================================== #
class SLSTMState(NamedTuple):
    c: jax.Array  # [B, H, dh]
    n: jax.Array  # [B, H, dh]
    m: jax.Array  # [B, H, dh]


def slstm_init_state(b, h, dh):
    return SLSTMState(
        c=jnp.zeros((b, h, dh), jnp.float32),
        n=jnp.zeros((b, h, dh), jnp.float32),
        m=jnp.full((b, h, dh), -1e30, jnp.float32),
    )


def slstm_step(st: SLSTMState, zt, lft, lit, ot):
    """z: cell input [B,H,dh]; lf/li: log gates [B,H,dh]; o: output gate."""
    m_new = jnp.maximum(st.m + lft, lit)
    fdec = jnp.exp(st.m + lft - m_new)
    iexp = jnp.exp(lit - m_new)
    c = fdec * st.c + iexp * zt
    n = fdec * st.n + iexp
    h = ot * c / jnp.maximum(n, jnp.exp(-m_new))
    return SLSTMState(c, n, m_new), h


def slstm_seq(z, lf, li, o, st: Optional[SLSTMState] = None, unroll: int = 8):
    """Sequential sLSTM.  `unroll` keeps the (c, n, m) state in registers
    across unrolled steps instead of round-tripping HBM every step — the
    dominant cost of a scalar recurrence on TPU (EXPERIMENTS.md §Perf)."""
    b, s, h, dh = z.shape
    st = st or slstm_init_state(b, h, dh)

    def step(st, inp):
        return slstm_step(st, *inp)

    xs = tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32) for a in (z, lf, li, o))
    st, ys = jax.lax.scan(step, st, xs, unroll=min(unroll, s))
    return ys.transpose(1, 0, 2, 3).astype(z.dtype), st


# ====================================================================== #
# causal depthwise conv (width kw) with carry for decode
# ====================================================================== #
def causal_conv(x: jax.Array, w: jax.Array, carry: Optional[jax.Array] = None):
    """x [B,S,D], w [kw, D] depthwise. Returns (y [B,S,D], new carry [B,kw-1,D])."""
    kw = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], kw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    ys = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(kw))
    return ys, xp[:, -(kw - 1) :]
