"""minicpm-2b [dense] — llama-like, MHA, tied embeddings; trained with the
WSD schedule (provided by repro.train.optimizer.wsd_schedule)
[arXiv:2404.06395]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122753, rope_theta=1e4, tie_embeddings=True,
)
