"""seamless-m4t-large-v2 [audio] — enc-dec backbone; audio frontend stubbed
(precomputed frame embeddings) [arXiv:2308.11596]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    encdec=True, enc_layers=24, d_frontend=160, rope_theta=1e4,
)
