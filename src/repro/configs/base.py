"""Architecture + shape configuration schema for the model zoo."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | ssm | audio | vlm | hybrid | moe
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // num_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    # block mix
    block_pattern: str = "attn"  # attn | xlstm | hymba
    window: int = 0  # 0 = full attention; >0 sliding-window size
    full_attn_layers: Tuple[int, ...] = ()  # hybrid: layers with full attn
    # moe
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    # ssm / hybrid
    ssm_state: int = 16
    ssm_expand: int = 2
    ssm_heads: int = 0
    conv_width: int = 4
    slstm_every: int = 0  # xlstm: one sLSTM per group of this size (0 = none)
    chunk: int = 128  # recurrent chunk length
    # enc-dec (audio)
    encdec: bool = False
    enc_layers: int = 0
    d_frontend: int = 0  # stub frontend feature dim (audio frames / patches)
    num_patches: int = 0  # vlm: prepended patch embeddings
    # capabilities
    supports_long_context: bool = False
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # structure
    use_scan: bool = True
    remat: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.block_pattern == "attn" or self.block_pattern == "hymba":
            att = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d
            per_layer += att
        if self.block_pattern == "hymba":
            di = self.ssm_expand * d
            per_layer += 2 * d * di + di * d + di * (2 * self.ssm_state) + self.ssm_heads * 2
        if self.block_pattern == "xlstm":
            per_layer += 2 * d * d + 3 * d * d + 2 * d * self.num_heads + d * d
        if self.is_moe:
            per_layer += self.num_experts * 3 * d * self.moe_d_ff + d * self.num_experts
            if self.num_shared_experts:
                per_layer += 3 * d * self.moe_d_ff * self.num_shared_experts
        elif self.d_ff:
            per_layer += 3 * d * self.d_ff
        total = emb + self.num_layers * per_layer
        if self.encdec:
            enc_att = 4 * d * d
            total += self.enc_layers * (enc_att + 3 * d * self.d_ff)
            total += self.num_layers * 4 * d * d  # cross attention
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        inactive = self.num_layers * (self.num_experts - self.top_k) * 3 * d * self.moe_d_ff
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
