"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (one sLSTM leading each group of
8), chunkwise-parallel training form [arXiv:2405.04517]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern="xlstm", slstm_every=8, conv_width=4, chunk=128,
    supports_long_context=True,
)
