"""hymba-1.5b [hybrid] — parallel attention + Mamba(-2/SSD) heads per layer,
sliding window with 3 global-attention layers, ssm_state=16
[arXiv:2411.13676].  Decode uses a ring-buffer window cache for ALL layers
(global layers degrade to windowed during decode — DESIGN.md §5)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    head_dim=64, d_ff=5504, vocab_size=32001,
    block_pattern="hymba", window=1024, full_attn_layers=(0, 15, 31),
    ssm_state=16, ssm_expand=2, ssm_heads=25, conv_width=4, chunk=128,
    supports_long_context=True, rope_theta=1e4,
)
