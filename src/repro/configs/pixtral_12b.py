"""pixtral-12b [vlm] — pixtral-ViT frontend stubbed (precomputed patch
embeddings prepended); mistral-nemo-like decoder [hf:mistralai/Pixtral-12B]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    head_dim=160, d_ff=14336, vocab_size=131072, rope_theta=1e6,
    num_patches=256, d_frontend=1024,
)
