"""Architecture registry: one module per assigned architecture, plus the
paper-native GNN streaming configs (repro.configs.gnn)."""
from repro.configs.base import SHAPES, ArchConfig, ShapeConfig

_ARCH_MODULES = {
    "qwen2.5-3b": "qwen2_5_3b",
    "granite-3-2b": "granite_3_2b",
    "llama3.2-1b": "llama3_2_1b",
    "minicpm-2b": "minicpm_2b",
    "xlstm-1.3b": "xlstm_1_3b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "pixtral-12b": "pixtral_12b",
    "hymba-1.5b": "hymba_1_5b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
}

ARCH_NAMES = list(_ARCH_MODULES)


def get_arch(name: str) -> ArchConfig:
    import importlib

    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def reduced_config(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    import dataclasses

    kw = dict(
        num_layers=4 if cfg.block_pattern != "xlstm" else (cfg.slstm_every or 4),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
        chunk=16,
    )
    if cfg.is_moe:
        kw.update(num_experts=8, top_k=2, moe_d_ff=32, d_ff=0)
    if cfg.block_pattern == "hymba":
        kw.update(ssm_heads=4, ssm_expand=2, ssm_state=4, window=16,
                  full_attn_layers=(0,), d_ff=128)
    if cfg.block_pattern == "xlstm":
        kw.update(slstm_every=4, d_ff=0)
    if cfg.encdec:
        kw.update(enc_layers=2, d_frontend=24)
    if cfg.num_patches:
        kw.update(num_patches=8, d_frontend=24)
    return dataclasses.replace(cfg, **kw)


__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "ARCH_NAMES", "get_arch", "reduced_config"]
