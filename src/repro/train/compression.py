"""Gradient compression for the DP all-reduce (beyond-paper, DESIGN.md §6).

Two compressors, both with **error feedback** (the residual of the lossy
round is added back before the next compression — required for convergence,
Karimireddy et al. 2019):

  * int8 per-tensor symmetric quantization (4× wire reduction vs fp32);
  * top-k magnitude sparsification (k as a fraction of elements).

`CompressedState` carries the feedback residuals as a pytree mirroring the
grads.  `compress_grads` returns the decompressed-after-compression grads —
i.e. exactly what the receiving side of the all-reduce would apply — so the
optimizer sees the lossy gradient and tests can assert convergence.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressedState(NamedTuple):
    residual: Any


def init_state(grads_template) -> CompressedState:
    return CompressedState(
        residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_template)
    )


def _int8_roundtrip(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, g - deq


def _topk_roundtrip(g: jax.Array, frac: float) -> Tuple[jax.Array, jax.Array]:
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
    kept = kept.reshape(g.shape)
    return kept, g - kept


def compress_grads(
    grads, state: CompressedState, method: str = "int8", topk_frac: float = 0.05
):
    """Returns (lossy grads as applied, new state, wire_bytes_estimate)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        if method == "int8":
            deq, res = _int8_roundtrip(gf)
            wire = gf.size  # 1 byte/elem
        elif method == "topk":
            deq, res = _topk_roundtrip(gf, topk_frac)
            wire = int(gf.size * topk_frac) * 8  # value + index
        else:
            raise ValueError(method)
        return deq.astype(g.dtype), res, wire

    out = jax.tree.map(one, grads, state.residual)
    lossy = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    wire = sum(t[2] for t in jax.tree.leaves(out, is_leaf=lambda x: isinstance(x, tuple)))
    return lossy, CompressedState(residual=res), wire
