"""Fault tolerance for long-running training (beyond-paper, DESIGN.md §6).

`FaultTolerantRunner` wraps a step function with:

  * periodic async checkpoints (atomic, keep-K);
  * divergence detection — NaN/Inf loss rolls back to the last checkpoint
    (with the data cursor restored, so the bad batch is re-drawn);
  * simulated node-failure injection (`WorkerFailure`) → restart-from-
    checkpoint, optionally onto a *smaller mesh* (elastic restore re-shards
    every leaf; see CheckpointManager.restore);
  * straggler mitigation — per-step wall-time EMA per (simulated) worker;
    workers slower than `straggler_factor`× the median are reported and the
    data-assignment callback lets the caller rebalance shards, mirroring
    backup-worker scheduling at cluster scale.

Everything is testable on one CPU process; the cluster integration points
(worker registry, heartbeats) are the two callbacks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional

import numpy as np

from repro.train.checkpoint import CheckpointManager


class WorkerFailure(RuntimeError):
    """Raised by the environment when a (simulated) worker dies."""


@dataclasses.dataclass
class FaultConfig:
    checkpoint_every: int = 50
    keep: int = 3
    max_restarts: int = 5
    straggler_factor: float = 2.0
    ema: float = 0.9


class StragglerMonitor:
    """Tracks per-worker step-time EMAs and flags outliers."""

    def __init__(self, num_workers: int, cfg: FaultConfig):
        self.cfg = cfg
        self.ema = np.zeros(num_workers)
        self.seen = np.zeros(num_workers, dtype=bool)

    def record(self, worker: int, dt: float) -> None:
        if not self.seen[worker]:
            self.ema[worker] = dt
            self.seen[worker] = True
        else:
            self.ema[worker] = self.cfg.ema * self.ema[worker] + (1 - self.cfg.ema) * dt

    def stragglers(self) -> List[int]:
        if not self.seen.any():
            return []
        med = float(np.median(self.ema[self.seen]))
        return [
            int(i)
            for i in np.nonzero(self.seen & (self.ema > self.cfg.straggler_factor * med))[0]
        ]


class FaultTolerantRunner:
    def __init__(
        self,
        step_fn: Callable,  # (state, batch) -> (state, loss)
        ckpt: CheckpointManager,
        cfg: FaultConfig = FaultConfig(),
        on_restart: Optional[Callable[[int], None]] = None,
    ):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.cfg = cfg
        self.on_restart = on_restart
        self.restarts = 0
        self.events: List[str] = []

    def run(self, state, data_fn: Callable[[int], Any], num_steps: int, shardings=None):
        """data_fn(step) must be deterministic in step (replay on rollback)."""
        step = 0
        self.ckpt.save(step, state, blocking=True)
        while step < num_steps:
            try:
                batch = data_fn(step)
                state2, loss = self.step_fn(state, batch)
                loss_v = float(loss)
                if not np.isfinite(loss_v):
                    raise FloatingPointError(f"non-finite loss at step {step}: {loss_v}")
                state = state2
                step += 1
                if step % self.cfg.checkpoint_every == 0:
                    self.ckpt.save(step, state, blocking=False)
            except (WorkerFailure, FloatingPointError) as e:
                self.restarts += 1
                self.events.append(f"step {step}: {type(e).__name__}: {e}")
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.cfg.max_restarts}; events={self.events}"
                    ) from e
                self.ckpt.wait()
                state, step = self.ckpt.restore(state, shardings=shardings)
                if self.on_restart:
                    self.on_restart(step)
        self.ckpt.wait()
        return state, step
