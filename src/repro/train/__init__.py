"""Training substrate: optimizer (AdamW + WSD), trainer loop, checkpointing,
fault tolerance, gradient compression."""
