"""End-to-end trainer: synthetic LM data pipeline, microbatch gradient
accumulation, AdamW+WSD, fault-tolerant runner, optional gradient
compression.  CPU-scale by default (examples/tests); the same code path
drives the production mesh through `launch/train.py`."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import init_model, loss_fn
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import compress_grads, init_state
from repro.train.fault import FaultConfig, FaultTolerantRunner
from repro.train.optimizer import OptConfig, adamw_init, adamw_update


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    batch: int = 8
    seq_len: int = 64
    microbatches: int = 1  # gradient accumulation
    seed: int = 0
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    compression: Optional[str] = None  # None | int8 | topk
    log_every: int = 10


def synthetic_batch(cfg: ArchConfig, tcfg: TrainConfig, step: int) -> Dict[str, Any]:
    """Deterministic-in-step synthetic LM data (replayable on rollback).
    A learnable structure: next token = (token * 31 + position) % vocab_eff."""
    rng = np.random.default_rng(tcfg.seed + step)
    vocab_eff = min(cfg.vocab_size, 97)
    b, s = tcfg.batch, tcfg.seq_len
    first = rng.integers(0, vocab_eff, (b, 1))
    toks = [first]
    for i in range(s - 1):
        toks.append((toks[-1] * 31 + i) % vocab_eff)
    tokens = np.concatenate(toks, axis=1).astype(np.int32)
    labels = np.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1).astype(np.int32)
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    if cfg.encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_frontend)).astype(np.float32)
        )
    if cfg.num_patches:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_patches, cfg.d_frontend)).astype(np.float32)
        )
    return batch


class Trainer:
    def __init__(self, cfg: ArchConfig, tcfg: TrainConfig, opt_cfg: OptConfig = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or OptConfig(warmup_steps=10, stable_steps=tcfg.steps, decay_steps=10)
        params, axes = init_model(jax.random.PRNGKey(tcfg.seed), cfg)
        self.axes = axes
        opt = adamw_init(params)
        comp = init_state(params) if tcfg.compression else None
        self.state = {"params": params, "opt": opt, "comp": comp, "step": jnp.zeros((), jnp.int32)}
        self._jit_step = jax.jit(self._step)
        self.history: list = []

    # ------------------------------------------------------------------ #
    def _grads(self, params, batch):
        def lf(p):
            return loss_fn(p, self.cfg, batch)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        return loss, grads

    def _step(self, state, batch):
        params, opt = state["params"], state["opt"]
        mb = self.tcfg.microbatches
        if mb > 1:
            def one(i, carry):
                loss_acc, gacc = carry
                sub = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // mb), x.shape[0] // mb, 0
                    ),
                    batch,
                )
                loss, grads = self._grads(params, sub)
                return loss_acc + loss / mb, jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / mb, gacc, grads
                )

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            loss, grads = jax.lax.fori_loop(0, mb, one, (jnp.zeros(()), zero))
        else:
            loss, grads = self._grads(params, batch)

        comp = state["comp"]
        if comp is not None:
            grads, comp, _ = compress_grads(grads, comp, method=self.tcfg.compression)
        new_params, new_opt, lr = adamw_update(grads, opt, params, self.opt_cfg)
        new_state = {"params": new_params, "opt": new_opt, "comp": comp,
                     "step": state["step"] + 1}
        return new_state, loss

    # ------------------------------------------------------------------ #
    def train(self) -> Dict[str, Any]:
        if self.tcfg.checkpoint_dir:
            ckpt = CheckpointManager(self.tcfg.checkpoint_dir, keep=3)
            runner = FaultTolerantRunner(
                self._jit_step, ckpt,
                FaultConfig(checkpoint_every=self.tcfg.checkpoint_every),
            )
            self.state, step = runner.run(
                self.state, lambda s: synthetic_batch(self.cfg, self.tcfg, s),
                self.tcfg.steps,
            )
            return {"steps": step, "restarts": runner.restarts}
        losses = []
        t0 = time.perf_counter()
        for s in range(self.tcfg.steps):
            batch = synthetic_batch(self.cfg, self.tcfg, s)
            self.state, loss = self._jit_step(self.state, batch)
            if s % self.tcfg.log_every == 0 or s == self.tcfg.steps - 1:
                losses.append(float(loss))
        return {
            "losses": losses,
            "steps": self.tcfg.steps,
            "wall_s": time.perf_counter() - t0,
        }
