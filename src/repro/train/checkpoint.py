"""Sharded, atomic, async checkpointing with elastic restore.

* **atomic** — writes land in `step_K.tmp/` and are renamed to `step_K/`
  only when complete, so a killed writer never corrupts the latest state;
* **async** — `save(..., blocking=False)` hands the host copy to a writer
  thread (double-buffered; at most one in flight);
* **elastic** — `restore(..., shardings=...)` re-device_puts every leaf under
  NEW shardings, so a job restarted on a different mesh (e.g. 256 → 128
  chips after losing a pod slice) resumes without conversion tooling;
* keep-last-K garbage collection.

Leaves are stored as one ``.npy`` per flattened tree path plus a JSON
manifest; restore targets a template pytree (structure + dtypes).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        # snapshot to host memory synchronously (cheap); write async
        flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        if self._thread is not None:
            self._thread.join()  # at most one async write in flight
            self._thread = None
        if blocking:
            self._write(step, flat)
        else:
            self._thread = threading.Thread(target=self._write, args=(step, flat))
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat) -> None:
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        manifest = {}
        for i, (k, v) in enumerate(flat.items()):
            fname = f"leaf_{i}.npy"
            np.save(tmp / fname, v)
            manifest[k] = fname
        (tmp / "manifest.json").write_text(
            json.dumps({"step": step, "leaves": manifest})
        )
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ------------------------------------------------------------------ #
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None, shardings: Any = None):
        """Load into `template`'s structure; optionally re-shard every leaf
        onto `shardings` (same structure) — the elastic-restart path."""
        step = step if step is not None else self.latest_step()
        assert step is not None, "no checkpoint found"
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())["leaves"]
        flat_t = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        shard_leaves = (
            jax.tree_util.tree_flatten(
                shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
            )[0]
            if shardings is not None
            else [None] * len(flat_t[0])
        )
        for (path, tleaf), sh in zip(flat_t[0], shard_leaves):
            key = jax.tree_util.keystr(path)
            arr = np.load(d / manifest[key])
            arr = arr.astype(tleaf.dtype) if hasattr(tleaf, "dtype") else arr
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(flat_t[1], leaves), step
