"""AdamW with the WSD (warmup–stable–decay) schedule (MiniCPM
[arXiv:2404.06395]) — functional, pytree-shaped, so optimizer state inherits
the parameter sharding specs (ZeRO-1/3 per `repro.dist.sharding`)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # WSD schedule
    warmup_steps: int = 100
    stable_steps: int = 1000
    decay_steps: int = 100
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def wsd_schedule(step: jax.Array, cfg: OptConfig) -> jax.Array:
    """Warmup → stable plateau → sqrt-style decay (MiniCPM §4)."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    decay_pos = (s - cfg.warmup_steps - cfg.stable_steps) / jnp.maximum(cfg.decay_steps, 1)
    decay = 1.0 - (1.0 - cfg.min_lr_frac) * jnp.clip(decay_pos, 0.0, 1.0)
    lr = jnp.where(
        s < cfg.warmup_steps,
        warm,
        jnp.where(s < cfg.warmup_steps + cfg.stable_steps, 1.0, decay),
    )
    return cfg.peak_lr * lr


def adamw_init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=zeros, v=jax.tree.map(jnp.copy, zeros), count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    grads, state: OptState, params, cfg: OptConfig
) -> Tuple[Any, OptState, jax.Array]:
    """Returns (new_params, new_state, lr). Grad clip by global norm."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    lr = wsd_schedule(count, cfg)
    c1 = 1.0 - cfg.b1**count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2**count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        step = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(m=new_m, v=new_v, count=count), lr
