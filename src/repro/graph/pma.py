"""Packed-Memory-Array (PMA) backed dynamic CSR (host side).

NeutronRT (paper §V-A) stores the evolving graph in a PMA-based CSR: all
vertex in-neighborhoods live in one packed array with adaptively balanced
gaps so edge insertions are O(log² n) amortized without rebuilding the CSR.

This is a faithful-but-compact PMA: the packed array is divided into leaf
segments of size ``seg``; density bounds (lo, hi) per level of an implicit
binary tree over segments trigger local rebalancing (redistribute the
occupied slots uniformly over a window).  Per-vertex neighborhood extents are
tracked with (start, end) offsets into the packed array; each neighborhood is
kept sorted so membership tests are O(log d).

The PMA is the *mutable* store; ``snapshot()`` exports an immutable
``CSRGraph`` for the device-facing engine.  Weights and edge types ride along
in parallel packed arrays.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.graph.csr import CSRGraph

_EMPTY = np.int64(-1)


class PMAGraph:
    def __init__(self, n: int, capacity: int = 1024, seg: int = 64):
        self.n = n
        self.seg = seg
        capacity = max(seg, 1 << int(np.ceil(np.log2(max(capacity, seg)))))
        self._alloc(capacity)
        # per-vertex extent [start, end) in the packed array; end-start = degree
        self.vstart = np.zeros(n, dtype=np.int64)
        self.vend = np.zeros(n, dtype=np.int64)
        self.num_edges = 0
        self._layout_empty()

    # ------------------------------------------------------------------ #
    def _alloc(self, capacity: int) -> None:
        self.capacity = capacity
        self.nbr = np.full(capacity, _EMPTY, dtype=np.int64)
        self.wgt = np.zeros(capacity, dtype=np.float32)
        self.ety = np.zeros(capacity, dtype=np.int32)

    def _layout_empty(self) -> None:
        # spread empty vertices uniformly across the capacity
        pos = np.linspace(0, self.capacity, self.n + 1).astype(np.int64)
        self.vstart[:] = pos[:-1]
        self.vend[:] = pos[:-1]

    # ------------------------------------------------------------------ #
    # public API
    # ------------------------------------------------------------------ #
    def in_degree(self, v: Optional[int] = None):
        if v is None:
            return (self.vend - self.vstart).copy()
        return int(self.vend[v] - self.vstart[v])

    def neighbors(self, v: int) -> np.ndarray:
        return self.nbr[self.vstart[v] : self.vend[v]]

    def has_edge(self, u: int, v: int) -> bool:
        nb = self.neighbors(v)
        i = np.searchsorted(nb, u)
        return bool(i < nb.shape[0] and nb[i] == u)

    def insert_edge(self, u: int, v: int, w: float = 1.0, t: int = 0) -> None:
        """Insert directed edge (u, v) into v's sorted in-neighborhood."""
        if self.has_edge(u, v):
            raise ValueError(f"edge ({u},{v}) already present")
        if self.num_edges + self.n >= self.capacity:  # global density too high
            self._grow()
        s, e = self.vstart[v], self.vend[v]
        i = s + np.searchsorted(self.nbr[s:e], u)
        if e >= self.capacity or (v + 1 < self.n and e >= self.vstart[v + 1]) or self.nbr[e] != _EMPTY:
            self._make_gap_after(v)
            s, e = self.vstart[v], self.vend[v]
            i = s + np.searchsorted(self.nbr[s:e], u)
        # shift [i, e) right by one (gap guaranteed at e)
        self.nbr[i + 1 : e + 1] = self.nbr[i:e]
        self.wgt[i + 1 : e + 1] = self.wgt[i:e]
        self.ety[i + 1 : e + 1] = self.ety[i:e]
        self.nbr[i] = u
        self.wgt[i] = w
        self.ety[i] = t
        self.vend[v] = e + 1
        self.num_edges += 1

    def delete_edge(self, u: int, v: int) -> None:
        s, e = self.vstart[v], self.vend[v]
        i = s + np.searchsorted(self.nbr[s:e], u)
        if i >= e or self.nbr[i] != u:
            raise ValueError(f"edge ({u},{v}) not present")
        self.nbr[i : e - 1] = self.nbr[i + 1 : e]
        self.wgt[i : e - 1] = self.wgt[i + 1 : e]
        self.ety[i : e - 1] = self.ety[i + 1 : e]
        self.nbr[e - 1] = _EMPTY
        self.vend[v] = e - 1
        self.num_edges -= 1

    def snapshot(self) -> CSRGraph:
        deg = self.vend - self.vstart
        total = int(deg.sum())
        src = np.empty(total, dtype=np.int64)
        wgt = np.empty(total, dtype=np.float32)
        ety = np.empty(total, dtype=np.int32)
        indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(deg, out=indptr[1:])
        for v in range(self.n):
            lo, hi = indptr[v], indptr[v + 1]
            src[lo:hi] = self.nbr[self.vstart[v] : self.vend[v]]
            wgt[lo:hi] = self.wgt[self.vstart[v] : self.vend[v]]
            ety[lo:hi] = self.ety[self.vstart[v] : self.vend[v]]
        dst = np.repeat(np.arange(self.n, dtype=np.int64), deg)
        return CSRGraph.from_edges(self.n, src, dst, wgt, ety)

    # ------------------------------------------------------------------ #
    # internals: growth & gap rebalancing
    # ------------------------------------------------------------------ #
    def _grow(self) -> None:
        old = (self.nbr, self.wgt, self.ety, self.vstart.copy(), self.vend.copy())
        self._alloc(self.capacity * 2)
        self._redistribute(*old)

    def _redistribute(self, nbr, wgt, ety, vstart, vend) -> None:
        deg = vend - vstart
        total = int(deg.sum())
        # uniform gaps: allot each vertex deg + proportional slack
        slack = self.capacity - total
        extra = np.full(self.n, slack // self.n, dtype=np.int64)
        extra[: slack % self.n] += 1
        news = np.zeros(self.n, dtype=np.int64)
        np.cumsum((deg + extra)[:-1], out=news[1:])
        for v in range(self.n):
            d = int(deg[v])
            self.nbr[news[v] : news[v] + d] = nbr[vstart[v] : vend[v]]
            self.wgt[news[v] : news[v] + d] = wgt[vstart[v] : vend[v]]
            self.ety[news[v] : news[v] + d] = ety[vstart[v] : vend[v]]
        self.vstart[:] = news
        self.vend[:] = news + deg

    def _make_gap_after(self, v: int) -> None:
        """Local PMA rebalance: widen the window around v until a slot frees up
        after v's extent, then redistribute the window's neighborhoods."""
        lo_v, hi_v = v, v
        win = max(2, self.seg // 8)
        while True:
            lo_v = max(0, v - win)
            hi_v = min(self.n - 1, v + win)
            lo = self.vstart[lo_v]
            hi = self.vend[hi_v] if hi_v + 1 >= self.n else self.vstart[hi_v + 1]
            used = int(sum(self.vend[x] - self.vstart[x] for x in range(lo_v, hi_v + 1)))
            space = int(hi - lo)
            if space >= used + (hi_v - lo_v + 1) or (lo_v == 0 and hi_v == self.n - 1):
                break
            win *= 2
        if space < used + (hi_v - lo_v + 1):
            self._grow()
            return
        # redistribute window uniformly
        vs = slice(lo_v, hi_v + 1)
        deg = self.vend[vs] - self.vstart[vs]
        buf_n = np.concatenate([self.nbr[self.vstart[x] : self.vend[x]] for x in range(lo_v, hi_v + 1)])
        buf_w = np.concatenate([self.wgt[self.vstart[x] : self.vend[x]] for x in range(lo_v, hi_v + 1)])
        buf_t = np.concatenate([self.ety[self.vstart[x] : self.vend[x]] for x in range(lo_v, hi_v + 1)])
        self.nbr[lo:hi] = _EMPTY
        k = hi_v - lo_v + 1
        slack = space - int(deg.sum())
        extra = np.full(k, slack // k, dtype=np.int64)
        extra[: slack % k] += 1
        pos = lo
        off = 0
        for j in range(k):
            d = int(deg[j])
            self.nbr[pos : pos + d] = buf_n[off : off + d]
            self.wgt[pos : pos + d] = buf_w[off : off + d]
            self.ety[pos : pos + d] = buf_t[off : off + d]
            self.vstart[lo_v + j] = pos
            self.vend[lo_v + j] = pos + d
            pos += d + int(extra[j])
            off += d
