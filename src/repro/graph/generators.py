"""Synthetic graph generators (host side).

Real-graph stand-ins for the paper's datasets (Table III): power-law graphs
(Barabási–Albert style preferential attachment → Twitter/Friendster/Products
analogue), uniform random graphs (Erdős–Rényi), and high-average-degree dense
community graphs (Reddit analogue).  Undirected workloads are materialized as
two directed edges.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.graph.csr import CSRGraph


def _dedup(src: np.ndarray, dst: np.ndarray, n: int) -> Tuple[np.ndarray, np.ndarray]:
    key = dst.astype(np.int64) * n + src.astype(np.int64)
    _, idx = np.unique(key, return_index=True)
    idx.sort()
    return src[idx], dst[idx]


def barabasi_albert(
    n: int,
    m: int = 4,
    seed: int = 0,
    undirected: bool = True,
) -> CSRGraph:
    """Preferential-attachment power-law graph with ~m edges per new vertex."""
    rng = np.random.default_rng(seed)
    targets = list(range(m))
    repeated: list[int] = list(range(m))
    src_l: list[int] = []
    dst_l: list[int] = []
    for v in range(m, n):
        chosen = rng.choice(repeated, size=m, replace=True)
        chosen = np.unique(chosen)
        for t in chosen:
            src_l.append(v)
            dst_l.append(int(t))
        repeated.extend(chosen.tolist())
        repeated.extend([v] * len(chosen))
    src = np.array(src_l, dtype=np.int64)
    dst = np.array(dst_l, dtype=np.int64)
    if undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    mask = src != dst
    src, dst = _dedup(src[mask], dst[mask], n)
    return CSRGraph.from_edges(n, src, dst)


def erdos_renyi(n: int, avg_degree: float = 8.0, seed: int = 0, undirected: bool = False) -> CSRGraph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree) // (2 if undirected else 1)
    src = rng.integers(0, n, size=m, dtype=np.int64)
    dst = rng.integers(0, n, size=m, dtype=np.int64)
    if undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    mask = src != dst
    src, dst = _dedup(src[mask], dst[mask], n)
    return CSRGraph.from_edges(n, src, dst)


def make_graph(
    kind: str,
    n: int,
    avg_degree: float = 8.0,
    seed: int = 0,
    num_etypes: int = 1,
    weighted: bool = False,
) -> CSRGraph:
    """Unified entry: kind in {powerlaw, uniform, dense}."""
    if kind == "powerlaw":
        g = barabasi_albert(n, m=max(1, int(avg_degree) // 2), seed=seed)
    elif kind == "uniform":
        g = erdos_renyi(n, avg_degree=avg_degree, seed=seed)
    elif kind == "dense":
        g = erdos_renyi(n, avg_degree=max(avg_degree, 32.0), seed=seed)
    else:
        raise ValueError(f"unknown graph kind {kind!r}")
    rng = np.random.default_rng(seed + 1)
    src, dst, w, t = g.edges_by_dst()
    if weighted:
        w = rng.uniform(0.5, 1.5, size=src.shape[0]).astype(np.float32)
    if num_etypes > 1:
        t = rng.integers(0, num_etypes, size=src.shape[0]).astype(np.int32)
    return CSRGraph.from_edges(n, src, dst, w, t)


def random_features(
    n: int, d: int, num_labels: int = 0, seed: int = 0
) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    rng = np.random.default_rng(seed)
    x = rng.normal(0.0, 1.0, size=(n, d)).astype(np.float32)
    y = rng.integers(0, num_labels, size=(n,)).astype(np.int32) if num_labels else None
    return x, y
