"""Static CSR graph snapshot (host side, numpy).

Directed multigraph-free graph with both in- and out-adjacency, optional
per-edge weights (PinSAGE alpha) and edge types (RGCN/RGAT).  GNN aggregation
in this codebase is over *in*-neighborhoods: destination v aggregates
messages from sources u for every directed edge (u, v).

The device-facing form is an edge list sorted by destination (``dst_sorted``)
plus a destination indptr — that is the layout the Pallas ``segment_spmm``
kernel and the pure-JAX reference both consume.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    """Immutable snapshot of a directed graph.

    Attributes:
      n: number of vertices.
      in_indptr/in_indices: CSR over destinations; ``in_indices[in_indptr[v]:
        in_indptr[v+1]]`` are the sources of v's in-edges.
      out_indptr/out_indices: CSR over sources (mirror).
      in_weights / in_etypes: aligned with ``in_indices``.
    """

    n: int
    in_indptr: np.ndarray
    in_indices: np.ndarray
    out_indptr: np.ndarray
    out_indices: np.ndarray
    in_weights: np.ndarray
    in_etypes: np.ndarray
    out_weights: np.ndarray
    out_etypes: np.ndarray

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    @staticmethod
    def from_edges(
        n: int,
        src: np.ndarray,
        dst: np.ndarray,
        weights: Optional[np.ndarray] = None,
        etypes: Optional[np.ndarray] = None,
    ) -> "CSRGraph":
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if src.size:
            assert src.min() >= 0 and src.max() < n, "src out of range"
            assert dst.min() >= 0 and dst.max() < n, "dst out of range"
        if weights is None:
            weights = np.ones(src.shape[0], dtype=np.float32)
        if etypes is None:
            etypes = np.zeros(src.shape[0], dtype=np.int32)
        # sort by (dst, src) for the in-CSR; stable canonical order
        order = np.lexsort((src, dst))
        s, d = src[order], dst[order]
        w, t = weights[order], etypes[order]
        key = d * n + s
        if key.size and np.any(np.diff(key) == 0):
            raise ValueError("duplicate edges are not supported")
        in_indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(in_indptr, d + 1, 1)
        in_indptr = np.cumsum(in_indptr)
        # out-CSR mirror
        order_o = np.lexsort((d, s))
        out_indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(out_indptr, s[order_o] + 1, 1)
        out_indptr = np.cumsum(out_indptr)
        return CSRGraph(
            n=n,
            in_indptr=in_indptr,
            in_indices=s,
            out_indptr=out_indptr,
            out_indices=d[order_o],
            in_weights=w.astype(np.float32),
            in_etypes=t.astype(np.int32),
            out_weights=w[order_o].astype(np.float32),
            out_etypes=t[order_o].astype(np.int32),
        )

    # ------------------------------------------------------------------ #
    # basic queries
    # ------------------------------------------------------------------ #
    @property
    def num_edges(self) -> int:
        return int(self.in_indices.shape[0])

    def in_degree(self) -> np.ndarray:
        return np.diff(self.in_indptr).astype(np.int64)

    def out_degree(self) -> np.ndarray:
        return np.diff(self.out_indptr).astype(np.int64)

    def in_neighbors(self, v: int) -> np.ndarray:
        return self.in_indices[self.in_indptr[v] : self.in_indptr[v + 1]]

    def out_neighbors(self, v: int) -> np.ndarray:
        return self.out_indices[self.out_indptr[v] : self.out_indptr[v + 1]]

    def out_edge_data(self, v: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        lo, hi = self.out_indptr[v], self.out_indptr[v + 1]
        return self.out_indices[lo:hi], self.out_weights[lo:hi], self.out_etypes[lo:hi]

    def in_edge_data(self, v: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        lo, hi = self.in_indptr[v], self.in_indptr[v + 1]
        return self.in_indices[lo:hi], self.in_weights[lo:hi], self.in_etypes[lo:hi]

    def has_edge(self, u: int, v: int) -> bool:
        nbrs = self.in_neighbors(v)
        i = np.searchsorted(nbrs, u)
        return bool(i < nbrs.shape[0] and nbrs[i] == u)

    # ------------------------------------------------------------------ #
    # device-facing layout
    # ------------------------------------------------------------------ #
    def edges_by_dst(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, weight, etype) arrays sorted by (dst, src)."""
        dst = np.repeat(np.arange(self.n, dtype=np.int64), np.diff(self.in_indptr))
        return self.in_indices.copy(), dst, self.in_weights.copy(), self.in_etypes.copy()

    # ------------------------------------------------------------------ #
    # functional mutation (returns new snapshot)
    # ------------------------------------------------------------------ #
    def apply_updates(
        self,
        ins_src: np.ndarray,
        ins_dst: np.ndarray,
        del_src: np.ndarray,
        del_dst: np.ndarray,
        ins_weights: Optional[np.ndarray] = None,
        ins_etypes: Optional[np.ndarray] = None,
    ) -> "CSRGraph":
        src, dst, w, t = self.edges_by_dst()
        if del_src.size:
            key = dst * self.n + src
            dkey = np.asarray(del_dst, np.int64) * self.n + np.asarray(del_src, np.int64)
            keep = ~np.isin(key, dkey)
            missing = np.isin(dkey, key, invert=True)
            if missing.any():
                raise ValueError(f"deleting {int(missing.sum())} non-existent edge(s)")
            src, dst, w, t = src[keep], dst[keep], w[keep], t[keep]
        if ins_src.size:
            iw = (
                np.ones(len(ins_src), np.float32)
                if ins_weights is None
                else np.asarray(ins_weights, np.float32)
            )
            it = (
                np.zeros(len(ins_src), np.int32)
                if ins_etypes is None
                else np.asarray(ins_etypes, np.int32)
            )
            src = np.concatenate([src, np.asarray(ins_src, np.int64)])
            dst = np.concatenate([dst, np.asarray(ins_dst, np.int64)])
            w = np.concatenate([w, iw])
            t = np.concatenate([t, it])
        return CSRGraph.from_edges(self.n, src, dst, w, t)
