"""Update-stream workloads: hybrid edge insertion/deletion batches.

Follows the paper's evaluation protocol (§VI): take a base graph, reserve the
most recent fraction of edges as the stream, split into batches; hybrid
workloads mix insertions of reserved edges with deletions of existing ones.
Batch sizes are expressed as a fraction of |E| (0.01% small / 0.001% large by
default in the paper).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np

from repro.graph.csr import CSRGraph


@dataclasses.dataclass
class EdgeUpdate:
    src: int
    dst: int
    insert: bool  # False = delete
    weight: float = 1.0
    etype: int = 0


@dataclasses.dataclass
class UpdateBatch:
    """One batch of structural updates (plus optional feature updates)."""

    ins_src: np.ndarray
    ins_dst: np.ndarray
    del_src: np.ndarray
    del_dst: np.ndarray
    ins_weights: Optional[np.ndarray] = None
    ins_etypes: Optional[np.ndarray] = None
    feat_vertices: Optional[np.ndarray] = None  # vertices whose features change
    feat_values: Optional[np.ndarray] = None  # [len(feat_vertices), d]

    @property
    def num_updates(self) -> int:
        return int(self.ins_src.size + self.del_src.size)

    def updated_vertices(self) -> np.ndarray:
        parts = [self.ins_src, self.ins_dst, self.del_src, self.del_dst]
        if self.feat_vertices is not None:
            parts.append(self.feat_vertices)
        return np.unique(np.concatenate([np.asarray(p, np.int64) for p in parts]))


@dataclasses.dataclass
class StreamWorkload:
    base: CSRGraph
    batches: List[UpdateBatch]

    def __iter__(self) -> Iterator[UpdateBatch]:
        return iter(self.batches)


def make_stream(
    graph: CSRGraph,
    num_batches: int = 10,
    batch_edges: Optional[int] = None,
    batch_frac: float = 1e-4,
    delete_frac: float = 0.3,
    feature_dim: int = 0,
    feature_frac: float = 0.0,
    seed: int = 0,
) -> StreamWorkload:
    """Split the 'most recent' edges off `graph` into an insertion stream and
    mix in deletions of base edges.

    Returns a StreamWorkload whose .base is the trimmed graph; applying all
    batches in order never inserts a duplicate or deletes a missing edge.
    """
    rng = np.random.default_rng(seed)
    src, dst, w, t = graph.edges_by_dst()
    E = src.shape[0]
    if batch_edges is None:
        batch_edges = max(1, int(E * batch_frac))
    n_ins_total = int(num_batches * batch_edges * (1.0 - delete_frac) + 0.5)
    n_ins_total = min(n_ins_total, E // 2)
    # reserve a random subset as "future" insertions
    perm = rng.permutation(E)
    ins_pool = perm[:n_ins_total]
    keep = np.ones(E, dtype=bool)
    keep[ins_pool] = False
    base = CSRGraph.from_edges(graph.n, src[keep], dst[keep], w[keep], t[keep])

    # live edge set for deletions (start from base edges)
    live_src = src[keep].tolist()
    live_dst = dst[keep].tolist()
    live_set = set(zip(live_src, live_dst))

    batches: List[UpdateBatch] = []
    ins_cursor = 0
    for _ in range(num_batches):
        n_del = int(batch_edges * delete_frac)
        n_ins = batch_edges - n_del
        isrc: list[int] = []
        idst: list[int] = []
        iw: list[float] = []
        it: list[int] = []
        while n_ins > 0 and ins_cursor < ins_pool.shape[0]:
            e = ins_pool[ins_cursor]
            ins_cursor += 1
            pair = (int(src[e]), int(dst[e]))
            if pair in live_set:
                continue
            live_set.add(pair)
            isrc.append(pair[0])
            idst.append(pair[1])
            iw.append(float(w[e]))
            it.append(int(t[e]))
            n_ins -= 1
        dsrc: list[int] = []
        ddst: list[int] = []
        live_list = list(live_set)
        if n_del > 0 and live_list:
            picks = rng.choice(len(live_list), size=min(n_del, len(live_list)), replace=False)
            for p in picks:
                pair = live_list[p]
                if pair in live_set and (pair[0], pair[1]) not in zip(isrc, idst):
                    live_set.discard(pair)
                    dsrc.append(pair[0])
                    ddst.append(pair[1])
        fv = fx = None
        if feature_dim and feature_frac > 0:
            k = max(1, int(graph.n * feature_frac))
            fv = rng.choice(graph.n, size=k, replace=False).astype(np.int64)
            fx = rng.normal(0, 1, size=(k, feature_dim)).astype(np.float32)
        batches.append(
            UpdateBatch(
                ins_src=np.array(isrc, np.int64),
                ins_dst=np.array(idst, np.int64),
                del_src=np.array(dsrc, np.int64),
                del_dst=np.array(ddst, np.int64),
                ins_weights=np.array(iw, np.float32),
                ins_etypes=np.array(it, np.int32),
                feat_vertices=fv,
                feat_values=fx,
            )
        )
    return StreamWorkload(base=base, batches=batches)
