"""Update-stream workloads: hybrid edge insertion/deletion batches.

Follows the paper's evaluation protocol (§VI): take a base graph, reserve the
most recent fraction of edges as the stream, split into batches; hybrid
workloads mix insertions of reserved edges with deletions of existing ones.
Batch sizes are expressed as a fraction of |E| (0.01% small / 0.001% large by
default in the paper).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np

from repro.graph.csr import CSRGraph


@dataclasses.dataclass
class EdgeUpdate:
    src: int
    dst: int
    insert: bool  # False = delete
    weight: float = 1.0
    etype: int = 0


@dataclasses.dataclass
class UpdateBatch:
    """One batch of structural updates (plus optional feature updates)."""

    ins_src: np.ndarray
    ins_dst: np.ndarray
    del_src: np.ndarray
    del_dst: np.ndarray
    ins_weights: Optional[np.ndarray] = None
    ins_etypes: Optional[np.ndarray] = None
    feat_vertices: Optional[np.ndarray] = None  # vertices whose features change
    feat_values: Optional[np.ndarray] = None  # [len(feat_vertices), d]

    @property
    def num_updates(self) -> int:
        return int(self.ins_src.size + self.del_src.size)

    def updated_vertices(self) -> np.ndarray:
        parts = [self.ins_src, self.ins_dst, self.del_src, self.del_dst]
        if self.feat_vertices is not None:
            parts.append(self.feat_vertices)
        return np.unique(np.concatenate([np.asarray(p, np.int64) for p in parts]))


@dataclasses.dataclass
class StreamWorkload:
    base: CSRGraph
    batches: List[UpdateBatch]

    def __iter__(self) -> Iterator[UpdateBatch]:
        return iter(self.batches)


#: regimes `make_adversarial_stream` generates (the ISSUE-7 CI matrix)
ADVERSARIAL_REGIMES = ("hub_burst", "delete_heavy", "feature_churn")


def _ring_edges(n: int, k: int):
    """Ring-lattice in-edges: (i+j) % n → i for j in 1..k (in-degree k)."""
    src, dst = [], []
    for j in range(1, k + 1):
        for i in range(n):
            src.append((i + j) % n)
            dst.append(i)
    return src, dst


def make_adversarial_stream(
    regime: str,
    n: int = 256,
    num_batches: int = 6,
    feature_dim: int = 8,
    seed: int = 0,
) -> StreamWorkload:
    """Synthetic adversarial streams where a fixed execution mode loses.

    Each regime is a deterministic construction (the RNG only draws feature
    values): graph structure, batch composition, and therefore the Alg.-4
    plan counters the execution policy scores are identical run to run —
    which is what lets CI gate the per-batch mode decisions *exactly*.

    * ``hub_burst`` — quiet long-range inserts, periodically interrupted by
      bursts of insertions into a few hubs whose out-fan covers the whole
      graph.  A hub's in-degree change invalidates every contribution it
      sources (GCN-style degree normalization), so the burst's affected
      frontier is ≈ V at layer 2 (InkStream's affected-area blow-up): the
      signed incremental step costs more than a dense pass and the policy
      must flip to full recompute, then back to incremental on the next
      quiet batch.
    * ``delete_heavy`` — light insert batches alternating with batches that
      delete one whole ring layer (one in-edge of *every* vertex): every
      row is degree-changed → constrained, the chunked subset degenerates
      into the full graph, and full recompute wins on weight.
    * ``feature_churn`` — a dense bipartite cluster (48 leaves drawing
      almost all in-edges from 32 churn sources) whose sources' features
      all change at once: nearly every in-contribution of every affected
      row is re-signed (2 records per edge), so chunked-subset recompute
      (1 edge per in-edge, ×chunked_weight) beats the incremental step,
      while sparse-churn batches stay incremental.

    The live-edge invariant of :func:`make_stream` holds: applying all
    batches in order never inserts a duplicate or deletes a missing edge.
    """
    if regime not in ADVERSARIAL_REGIMES:
        raise ValueError(f"unknown adversarial regime {regime!r}; "
                         f"expected one of {ADVERSARIAL_REGIMES}")
    if n < 64:
        raise ValueError("adversarial streams need n >= 64")
    rng = np.random.default_rng(seed)

    def _feat(verts: list) -> tuple:
        fv = np.asarray(verts, np.int64)
        fx = rng.normal(0, 1, size=(fv.size, feature_dim)).astype(np.float32)
        return fv, fx

    def _batch(ins=None, dels=None, feats=None) -> UpdateBatch:
        isrc, idst = (np.array(ins[0], np.int64), np.array(ins[1], np.int64)) \
            if ins else (np.zeros(0, np.int64), np.zeros(0, np.int64))
        dsrc, ddst = (np.array(dels[0], np.int64), np.array(dels[1], np.int64)) \
            if dels else (np.zeros(0, np.int64), np.zeros(0, np.int64))
        fv, fx = _feat(feats) if feats else (None, None)
        return UpdateBatch(
            ins_src=isrc, ins_dst=idst, del_src=dsrc, del_dst=ddst,
            ins_weights=np.ones(isrc.size, np.float32),
            ins_etypes=np.zeros(isrc.size, np.int32),
            feat_vertices=fv, feat_values=fx,
        )

    batches: List[UpdateBatch] = []

    if regime == "hub_burst":
        hubs = list(range(4))
        src, dst = _ring_edges(n, 2)
        # hub out-fan: (almost) every non-hub vertex hears every hub; the
        # top two vertices are skipped — their ring in-edges wrap to hub ids
        for h in hubs:
            for v in range(8, n - 2):
                src.append(h)
                dst.append(v)
        base = _from_lists(n, src, dst)
        quiet_cursor = 8
        for b in range(num_batches):
            if b % 3 == 1:  # burst: 8 fresh feeders per hub, every hub
                feeders = range(8 + b * 8, 16 + b * 8)
                ins = ([f for f in feeders for _ in hubs],
                       [h for _ in feeders for h in hubs])
                batches.append(_batch(ins=ins))
            else:  # quiet: 3 long-range inserts between low-degree vertices
                pairs = [(quiet_cursor + i, (quiet_cursor + i + 5) % n)
                         for i in range(3)]
                quiet_cursor += 3
                batches.append(_batch(ins=([p[0] for p in pairs],
                                           [p[1] for p in pairs])))
        return StreamWorkload(base=base, batches=batches)

    if regime == "delete_heavy":
        k = 4  # ring in-degree; heavy batches delete one whole layer each
        src, dst = _ring_edges(n, k)
        base = _from_lists(n, src, dst)
        layer = 2  # layer 1 is never deleted (keeps the graph connected)
        ins_cursor = 0
        for b in range(num_batches):
            if b % 2 == 1 and layer <= k:  # heavy: one in-edge of every row
                dels = ([(i + layer) % n for i in range(n)], list(range(n)))
                layer += 1
                batches.append(_batch(dels=dels))
            else:  # light: 4 fresh medium-range inserts
                pairs = [((ins_cursor + i) % n,
                          (ins_cursor + i + k + 3 + b) % n)
                         for i in range(4)]
                ins_cursor += 4
                batches.append(_batch(ins=([p[0] for p in pairs],
                                           [p[1] for p in pairs])))
        return StreamWorkload(base=base, batches=batches)

    # feature_churn: ring (in-degree 4) + dense bipartite cluster
    # sources→leaves — leaves draw fan/(fan+4) of their in-edges from the
    # churn sources, so a cluster-wide churn re-signs nearly every
    # contribution of every affected row
    n_src, n_leaf, fan = 32, 48, 24
    sources = list(range(n_src))
    leaves = list(range(n_src, n_src + n_leaf))
    src, dst = _ring_edges(n, 4)
    for t_i, t in enumerate(leaves):  # each leaf hears `fan` of the sources
        for j in range(fan):
            src.append((t_i * 7 + j) % n_src)
            dst.append(t)
    base = _from_lists(n, src, dst)
    quiet_lo = n_src + n_leaf + 8
    quiet_span = n - quiet_lo
    for b in range(num_batches):
        if b % 2 == 1:  # churn: every cluster source's features change
            batches.append(_batch(feats=sources))
        else:  # sparse churn: 6 well-separated ring-only vertices, so no
            # affected row hears more than one churned source (c/d = 1/4)
            batches.append(_batch(
                feats=[quiet_lo + (b * 30 + i * 5) % quiet_span
                       for i in range(6)]))
    return StreamWorkload(base=base, batches=batches)


def _from_lists(n: int, src: list, dst: list) -> CSRGraph:
    s = np.asarray(src, np.int64)
    d = np.asarray(dst, np.int64)
    return CSRGraph.from_edges(n, s, d, np.ones(s.size, np.float32),
                               np.zeros(s.size, np.int32))


def make_stream(
    graph: CSRGraph,
    num_batches: int = 10,
    batch_edges: Optional[int] = None,
    batch_frac: float = 1e-4,
    delete_frac: float = 0.3,
    feature_dim: int = 0,
    feature_frac: float = 0.0,
    seed: int = 0,
) -> StreamWorkload:
    """Split the 'most recent' edges off `graph` into an insertion stream and
    mix in deletions of base edges.

    Returns a StreamWorkload whose .base is the trimmed graph; applying all
    batches in order never inserts a duplicate or deletes a missing edge.
    """
    rng = np.random.default_rng(seed)
    src, dst, w, t = graph.edges_by_dst()
    E = src.shape[0]
    if batch_edges is None:
        batch_edges = max(1, int(E * batch_frac))
    n_ins_total = int(num_batches * batch_edges * (1.0 - delete_frac) + 0.5)
    n_ins_total = min(n_ins_total, E // 2)
    # reserve a random subset as "future" insertions
    perm = rng.permutation(E)
    ins_pool = perm[:n_ins_total]
    keep = np.ones(E, dtype=bool)
    keep[ins_pool] = False
    base = CSRGraph.from_edges(graph.n, src[keep], dst[keep], w[keep], t[keep])

    # live edge set for deletions (start from base edges)
    live_src = src[keep].tolist()
    live_dst = dst[keep].tolist()
    live_set = set(zip(live_src, live_dst))

    batches: List[UpdateBatch] = []
    ins_cursor = 0
    for _ in range(num_batches):
        n_del = int(batch_edges * delete_frac)
        n_ins = batch_edges - n_del
        isrc: list[int] = []
        idst: list[int] = []
        iw: list[float] = []
        it: list[int] = []
        while n_ins > 0 and ins_cursor < ins_pool.shape[0]:
            e = ins_pool[ins_cursor]
            ins_cursor += 1
            pair = (int(src[e]), int(dst[e]))
            if pair in live_set:
                continue
            live_set.add(pair)
            isrc.append(pair[0])
            idst.append(pair[1])
            iw.append(float(w[e]))
            it.append(int(t[e]))
            n_ins -= 1
        dsrc: list[int] = []
        ddst: list[int] = []
        live_list = list(live_set)
        if n_del > 0 and live_list:
            picks = rng.choice(len(live_list), size=min(n_del, len(live_list)), replace=False)
            for p in picks:
                pair = live_list[p]
                if pair in live_set and (pair[0], pair[1]) not in zip(isrc, idst):
                    live_set.discard(pair)
                    dsrc.append(pair[0])
                    ddst.append(pair[1])
        fv = fx = None
        if feature_dim and feature_frac > 0:
            k = max(1, int(graph.n * feature_frac))
            fv = rng.choice(graph.n, size=k, replace=False).astype(np.int64)
            fx = rng.normal(0, 1, size=(k, feature_dim)).astype(np.float32)
        batches.append(
            UpdateBatch(
                ins_src=np.array(isrc, np.int64),
                ins_dst=np.array(idst, np.int64),
                del_src=np.array(dsrc, np.int64),
                del_dst=np.array(ddst, np.int64),
                ins_weights=np.array(iw, np.float32),
                ins_etypes=np.array(it, np.int32),
                feat_vertices=fv,
                feat_values=fx,
            )
        )
    return StreamWorkload(base=base, batches=batches)
