"""Streaming-graph substrate: static CSR snapshots, PMA-backed dynamic CSR,
synthetic generators, and update-stream workloads."""

from repro.graph.csr import CSRGraph
from repro.graph.generators import barabasi_albert, erdos_renyi, make_graph
from repro.graph.pma import PMAGraph
from repro.graph.streaming import (
    ADVERSARIAL_REGIMES,
    EdgeUpdate,
    StreamWorkload,
    UpdateBatch,
    make_adversarial_stream,
    make_stream,
)

__all__ = [
    "CSRGraph",
    "PMAGraph",
    "EdgeUpdate",
    "UpdateBatch",
    "StreamWorkload",
    "make_stream",
    "make_adversarial_stream",
    "ADVERSARIAL_REGIMES",
    "barabasi_albert",
    "erdos_renyi",
    "make_graph",
]
