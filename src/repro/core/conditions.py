"""Theorem-1 applicability-condition prober (the paper's LLM+SMT verifier,
re-realized as a randomized numerical certifier — DESIGN.md §3).

For a candidate decoupled model it certifies, over randomized inputs:

  (C1) nbr_ctx associativity — holds by construction here (signed-sum form),
       so we instead check that ctx contributions are well-defined/finite;
  (C2) aggregate associativity — sum, by construction; checked for
       permutation invariance and splits;
  (C3) distributivity of ms_cbn over aggregate:
       ms_cbn(z, x + y) == ms_cbn(z, x) + ms_cbn(z, y);
  (C4) invertibility: ms_cbn_inv(z, ms_cbn(z, x)) == x;
  (C5) destination independence of ms_local (unless the model declares
       ``dest_dependent``, which routes it to constrained processing).

``certify`` is used as a registration gate: the engine refuses models whose
declared flags contradict the probes (e.g. an undeclared destination
dependence would silently corrupt reuse — exactly the failure mode the
paper's SMT check guards against).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.operators import GNNModel


@dataclasses.dataclass
class ConditionReport:
    distributive: bool
    invertible: bool
    aggregate_assoc: bool
    dest_independent: bool
    struct_independent: bool
    max_err: Dict[str, float]

    @property
    def incrementalizable(self) -> bool:
        return self.distributive and self.invertible and self.aggregate_assoc


def certify(
    model: GNNModel,
    d_in: int = 8,
    d_out: int = 8,
    trials: int = 8,
    seed: int = 0,
    tol: float = 1e-4,
) -> ConditionReport:
    key = jax.random.PRNGKey(seed)
    kp, key = jax.random.split(key)
    p = model.init_params(kp, d_in, d_out)
    agg = model.agg_dim(d_in, d_out)
    ctxd = model.ctx_dim(d_in, d_out)
    errs = {"distributive": 0.0, "invertible": 0.0, "agg_assoc": 0.0, "dest": 0.0, "struct": 0.0}

    dest_indep = True
    struct_indep = True
    for t in range(trials):
        key, k1, k2, k3, k4, k5 = jax.random.split(key, 6)
        # positive context (counts / attention sums are positive by nature)
        z = jax.random.uniform(k1, (4, ctxd), minval=0.5, maxval=5.0)
        x = jax.random.normal(k2, (4, agg))
        y = jax.random.normal(k3, (4, agg))
        # C3 distributivity
        lhs = model.ms_cbn(p, z, x + y)
        rhs = model.ms_cbn(p, z, x) + model.ms_cbn(p, z, y)
        errs["distributive"] = max(errs["distributive"], float(jnp.abs(lhs - rhs).max()))
        # C4 invertibility
        back = model.ms_cbn_inv(p, z, model.ms_cbn(p, z, x))
        errs["invertible"] = max(errs["invertible"], float(jnp.abs(back - x).max()))
        # C2 aggregate associativity: sum over permuted splits
        xs = jax.random.normal(k4, (6, agg))
        s1 = xs.sum(0)
        perm = jax.random.permutation(k5, 6)
        s2 = xs[perm[:3]].sum(0) + xs[perm[3:]].sum(0)
        errs["agg_assoc"] = max(errs["agg_assoc"], float(jnp.abs(s1 - s2).max()))
        # C5 destination / structural independence of ms_local
        key, ka, kb, kc = jax.random.split(key, 4)
        hu = jax.random.normal(ka, (4, d_in))
        hv1 = jax.random.normal(kb, (4, d_in))
        hv2 = jax.random.normal(kc, (4, d_in))
        su = jnp.abs(jax.random.normal(ka, (4,))) * 3
        sv = jnp.abs(jax.random.normal(kb, (4,))) * 3
        ew = jnp.ones((4,))
        et = jnp.zeros((4,), jnp.int32)
        m1 = model.ms_local(p, hu, hv1, su, sv, ew, et)
        m2 = model.ms_local(p, hu, hv2, su, sv, ew, et)
        d_err = float(jnp.abs(m1 - m2).max())
        errs["dest"] = max(errs["dest"], d_err)
        if d_err > tol:
            dest_indep = False
        m3 = model.ms_local(p, hu, hv1, su + 1.0, sv, ew, et)
        s_err = float(jnp.abs(m1 - m3).max())
        errs["struct"] = max(errs["struct"], s_err)
        if s_err > tol:
            struct_indep = False

    return ConditionReport(
        distributive=errs["distributive"] < tol,
        invertible=errs["invertible"] < tol,
        aggregate_assoc=errs["agg_assoc"] < tol,
        dest_independent=dest_indep,
        struct_independent=struct_indep,
        max_err=errs,
    )


def validate_registration(model: GNNModel, **kw) -> ConditionReport:
    """Raise if the model's declared flags contradict the numeric probes."""
    rep = certify(model, **kw)
    if not rep.incrementalizable:
        raise ValueError(
            f"model {model.name!r} fails Theorem-1 conditions: {rep.max_err}"
        )
    if not rep.dest_independent and not model.dest_dependent:
        raise ValueError(
            f"model {model.name!r} has destination-dependent ms_local but does "
            f"not declare dest_dependent — unsafe for incremental reuse"
        )
    if not rep.struct_independent and not model.src_struct_dependent:
        raise ValueError(
            f"model {model.name!r} reads source structure in ms_local but does "
            f"not declare src_struct_dependent"
        )
    return rep
