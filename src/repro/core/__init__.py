"""Core incremental-RTEC framework — the paper's contribution in JAX."""

from repro.core.backend import (
    DeviceBackend,
    OffloadBackend,
    ShardBackend,
    ShardedOffloadBackend,
    StateBackend,
    StreamOrchestrator,
)
from repro.core.baselines import RTECUER, MTECPeriod, RTECFull, RTECSample
from repro.core.conditions import certify, validate_registration
from repro.core.engine import BatchStats, RTECEngine, StreamStats
from repro.core.full import LayerState, full_forward
from repro.core.models import ALL_MODELS, make_model
from repro.core.odec import odec_query
from repro.core.operators import GNNModel
from repro.core.policy import (
    MODES,
    ExecutionPolicy,
    PlanCostEstimate,
    PolicyDecision,
    estimate_plan_cost,
    make_policy,
)
from repro.core.sharded_engine import ShardedRTECEngine

__all__ = [
    "GNNModel",
    "make_model",
    "ALL_MODELS",
    "RTECEngine",
    "ShardedRTECEngine",
    "BatchStats",
    "StreamStats",
    "StateBackend",
    "StreamOrchestrator",
    "DeviceBackend",
    "OffloadBackend",
    "ShardBackend",
    "ShardedOffloadBackend",
    "full_forward",
    "LayerState",
    "RTECFull",
    "RTECSample",
    "RTECUER",
    "MTECPeriod",
    "odec_query",
    "certify",
    "validate_registration",
    "MODES",
    "ExecutionPolicy",
    "PlanCostEstimate",
    "PolicyDecision",
    "estimate_plan_cost",
    "make_policy",
]
