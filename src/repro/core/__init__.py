"""Core incremental-RTEC framework — the paper's contribution in JAX."""

from repro.core.operators import GNNModel
from repro.core.models import make_model, ALL_MODELS
from repro.core.engine import RTECEngine, BatchStats
from repro.core.full import full_forward, LayerState
from repro.core.baselines import RTECFull, RTECSample, RTECUER, MTECPeriod
from repro.core.odec import odec_query
from repro.core.conditions import certify, validate_registration

__all__ = [
    "GNNModel",
    "make_model",
    "ALL_MODELS",
    "RTECEngine",
    "BatchStats",
    "full_forward",
    "LayerState",
    "RTECFull",
    "RTECSample",
    "RTECUER",
    "MTECPeriod",
    "odec_query",
    "certify",
    "validate_registration",
]
