"""Residency-backend architecture: one orchestrator, five state substrates.

The paper's §V GPU-CPU co-processing story has a single control flow —
plan each update batch on the host (Alg. 4), pack it into a transfer
format, ship it, execute the reordered incremental workflow (Alg. 1), and
overlap batch-t+1 planning with batch-t execution — but the *residency* of
the historical state (which memory tier holds h/a/nct, and how rows reach
the compute) is a deployment decision.  This module separates the two:

                          ┌──────────────────────────┐
     UpdateBatch stream → │    StreamOrchestrator    │  plan/pack/hysteresis,
                          │  plan(t+1) on host while │  honest StreamStats
                          │  the device executes (t) │  timing, refresh cadence
                          └────────────┬─────────────┘
                                       │  StateBackend protocol
                                       │  (plan / dispatch / flush / sync)
        ┌──────────────────┬───────────┴──────┬─────────────────────┐
  DeviceBackend      OffloadBackend     ShardBackend      ShardedOffloadBackend
  state in HBM,      state host-        state row-sharded  per-shard host row
  one fused donated  resident; compact  [S, rows_per+1,·]  blocks; per layer a
  L-layer step per   affected rows      blocks; one psum   compact [halo|local]
  batch (PackedPlan) staged per layer   of frontier rows   workspace staged per
                     (paper §V-B)       per layer          shard (HBM footprint
                                                           O(affected), not O(V))

All four backends execute the *same* layer implementation
(:func:`repro.core.incremental._layer_body`) and are fed by the same Alg.-4
planner (:func:`repro.core.affected.build_plan`) through one packing layer
(:mod:`repro.core.affected`'s ``PackedPlan``/``ShardedPlan``/remap tables).
The public engine classes (``RTECEngine``, ``OffloadedRTECEngine``,
``ShardedRTECEngine``, ``ShardedOffloadRTECEngine``) are thin facades over
``StreamOrchestrator`` + one backend — no engine owns a plan/overlap loop.

Protocol contract (what ``StreamOrchestrator`` relies on):

* ``plan(g_old, g_new, batch)`` is host-only and **value-independent** (it
  may read graph structure and batch indices, never state values), so it can
  run while the devices still execute the previous batch;
* ``dispatch(prep)`` is as asynchronous as the substrate allows; any work it
  must defer to keep the next plan off the critical path is completed by
  ``flush()`` (a no-op for fully-async device substrates);
* ``flush()`` + ``jax.block_until_ready(sync_arrays())`` is a full barrier:
  after it, ``embeddings`` reflects every dispatched batch.

A fifth substrate, :class:`ChunkedBackend`, executes batches by chunked
constrained re-computation through the §V-C scheduler (host-resident state,
device residency bounded by ``chunk_size``) — the fallback when a batch's
affected subgraph exceeds what the staging substrates can hold at once.

Serving (ISSUE 6): every substrate additionally implements the Serving API
(``snapshot_rows`` / ``changed_rows``, documented on :class:`StateBackend`),
which :class:`repro.serve.frontend.ServingFrontend` uses to answer
embedding reads pinned to historical versions bitwise-consistently while
updates continue to stream.  Construct any of the five through
:func:`repro.serve.create_engine`.
"""
from __future__ import annotations

import abc
import dataclasses
import threading
import time
import warnings
from functools import partial
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.affected import (
    BatchPlan,
    BucketHysteresis,
    FusionConfig,
    FusionWindow,
    HybridLayerPlan,
    LayerPlan,
    PackedPlan,
    ShardedPlan,
    build_packed_plan,
    build_plan,
    final_write_rows,
    hybrid_plan,
    pack_plan,
    remap_compact,
    shard_plan,
    shard_rows,
)
from repro.core.full import full_forward
from repro.core.incremental import (
    fused_stream_step,
    hybrid_layer_step_fn,
    incremental_layer,
    sharded_step_fn,
    with_scratch,
)
from repro.core.operators import GNNModel, Params
from repro.core.policy import ExecutionPolicy, PlanCostEstimate
from repro.graph.csr import CSRGraph
from repro.graph.streaming import UpdateBatch
from repro.serve.hotcache import CacheStats, HotRowCache
from repro.serve.staging import HostStagingPipeline, StagingStats, StagingTicket


# ====================================================================== #
# Stats (shared by every engine facade)
# ====================================================================== #
@dataclasses.dataclass
class BatchStats:
    inc_edges: int
    full_edges: int
    out_vertices: int
    plan_time_s: float
    exec_time_s: float
    graph_time_s: float
    #: execution shape the batch ran as (ISSUE 7): "incremental" (the
    #: backend's native dispatch), "chunked" (orchestrator-level §V-C
    #: subset recompute) or "full" (refresh over the post-batch graph).
    #: Always "incremental" without an ExecutionPolicy.
    mode: str = "incremental"
    #: the policy cost model's raw edge-work for the chosen mode — the
    #: deterministic quantity the adversarial CI gate compares against the
    #: best fixed mode.  0 when no policy is attached.
    est_edges: int = 0
    #: the chosen mode's *weighted* cost (``PolicyDecision.costs[mode]``) —
    #: the decision surface itself.  Plans are mode-independent, so the
    #: adaptive policy's stream total is ≤ every fixed mode's by
    #: construction; the CI wall-clock-free "policy matches the best fixed
    #: mode" gate compares these.  0.0 when no policy is attached.
    est_cost: float = 0.0
    #: batch-window fusion (ISSUE 9): how many logical batches shared this
    #: batch's device dispatch.  1 = dispatched alone (the serial path);
    #: k ≥ 2 on every constituent of a fused window (the window's one
    #: dispatch time is charged to its first constituent, the others
    #: report ``exec_time_s == 0``).
    fused_window: int = 1

    @property
    def edges_processed(self) -> int:
        return self.inc_edges + self.full_edges


@dataclasses.dataclass
class StreamStats:
    """Aggregate result of a pipelined ``apply_stream`` run.

    ``wall_s`` is honest end-to-end time including the final flush + device
    sync; per-batch ``exec_time_s`` entries are dispatch-only (execution
    overlaps the next batch's planning, so per-batch completion is
    unobservable without breaking the pipeline).

    Per-phase overlap accounting (ISSUE 5): ``prefetch_hits`` counts the
    batches whose Alg.-4 plan completed with **no intervening backend
    barrier** (verified via ``StateBackend.barrier_epoch``, so a substrate
    that silently flushes per batch scores 0) — ``len(batches) - 1`` for a
    healthy pipeline, deterministic, CI-gated; ``staged_bytes`` is the byte
    volume moved through the backend's :class:`HostStagingPipeline`
    (deterministic, CI-gated ceiling); ``sync_wait_s`` is caller time
    blocked on host staging (gather waits + drain barriers) and
    ``compute_s`` is caller time blocked on the device (D2H waits) —
    timing telemetry, never gated.  All four stay zero for backends
    without a staging pipeline.

    Read-side serving fields (ISSUE 6): populated only by
    :class:`repro.serve.frontend.ServingFrontend` — ``reads_served`` /
    ``reads_rejected`` / ``staleness_batches`` are deterministic counters
    (CI-gated exactly in the smoke bench); ``read_p50_s`` / ``read_p99_s``
    are submit→serve latency percentiles (telemetry, never gated).  All
    default to zero so pre-serving baselines and gates keep passing.

    Device hot-row cache counters (ISSUE 8): ``cache_hit_rows`` /
    ``cache_miss_rows`` / ``cache_evictions`` mirror the backend's
    :class:`repro.serve.hotcache.CacheStats` over the stream —
    deterministic (admission and eviction are value-independent plan-time
    decisions), CI-gated exactly on the hub_burst smoke cell.  All three
    stay zero for backends without a cache (or with ``enabled=False``).

    Halo-exchange counters (ISSUE 10): ``comms_halo_rows_sent`` /
    ``comms_halo_bytes`` mirror the sharded backends'
    :class:`CommsStats` over the stream — plan-derived and deterministic
    (under ``halo="ppermute"`` they count per-consumer deliveries; under
    ``"psum"`` the global-frontier broadcast volume, the ceiling the CI
    gate compares against).  Both stay zero for unsharded backends.

    ``StreamStats`` is the single result type for *every* entry point
    (``apply_stream``, the serving front-end, the bench cells);
    :meth:`as_dict` is the normalized scalar view the benchmark emitters
    consume instead of ad-hoc attribute plucking."""

    batches: List[BatchStats]
    wall_s: float
    plan_s: float  # total host planning time (hidden behind device exec)
    staged_bytes: int = 0
    prefetch_hits: int = 0
    sync_wait_s: float = 0.0
    compute_s: float = 0.0
    # read-side serving metrics (repro.serve.frontend)
    reads_served: int = 0
    reads_rejected: int = 0
    read_p50_s: float = 0.0
    read_p99_s: float = 0.0
    staleness_batches: int = 0
    # device hot-row cache counters (repro.serve.hotcache)
    cache_hit_rows: int = 0
    cache_miss_rows: int = 0
    cache_evictions: int = 0
    # batch-window fusion counters (ISSUE 9): deterministic — which batches
    # fuse depends only on the update stream's plan footprints
    fusion_windows: int = 0
    fused_batches: int = 0
    fusion_fallbacks: int = 0
    # halo-exchange counters (ISSUE 10): plan-derived, deterministic
    comms_halo_rows_sent: int = 0
    comms_halo_bytes: int = 0

    @property
    def mean_batch_s(self) -> float:
        return self.wall_s / max(1, len(self.batches))

    def as_dict(self) -> dict:
        """Normalized scalar view: every entry point reports through these
        keys (benchmarks/common.py ``emit_stream_stats`` renders them).

        THE documented key namespace — benchmarks and
        ``benchmarks/check_regression.py`` consume only these names
        (pinned by ``STREAM_STAT_KEYS`` and tests/test_hotcache.py, so a
        rename can never silently drop a CI gate):

        ==========================  =========================================
        key                         meaning (D = deterministic, CI-gateable)
        ==========================  =========================================
        n_batches                   batches in the stream (D)
        wall_s                      honest end-to-end wall, incl. final sync
        plan_s                      host planning time (hidden behind exec)
        mean_batch_s                wall_s / n_batches
        inc_edges                   signed incremental records executed (D)
        full_edges                  constrained full-recompute edges (D)
        out_vertices                rows written, summed over layers (D)
        staged_bytes                bytes through HostStagingPipeline (D)
        prefetch_hits               plans built with no backend barrier (D)
        sync_wait_s                 caller time blocked on host staging
        compute_s                   caller time blocked on the device
        reads_served                frontend reads answered (D)
        reads_rejected              frontend reads shed by admission (D)
        read_p50_s / read_p99_s     read latency percentiles (telemetry)
        staleness_batches           versions behind head at serve time (D)
        cache_hit_rows              rows served from device cache slots (D)
        cache_miss_rows             rows staged from host (D)
        cache_evictions             cache capacity evictions (D)
        fusion_windows              fused multi-batch dispatches (D)
        fused_batches               batches absorbed into fused windows (D)
        fusion_fallbacks            windows broken up by overlap/policy (D)
        comms_halo_rows_sent        halo rows moved between shards (D)
        comms_halo_bytes            halo bytes moved between shards (D)
        policy_incremental_batches  batches decided incremental (D)
        policy_chunked_batches      batches decided chunked-subset (D)
        policy_full_batches         batches decided full recompute (D)
        policy_edges                cost model's raw edge-work estimate (D)
        policy_cost                 chosen-mode weighted cost total (D)
        ==========================  =========================================
        """
        return {
            "n_batches": len(self.batches),
            "wall_s": self.wall_s,
            "plan_s": self.plan_s,
            "mean_batch_s": self.mean_batch_s,
            "inc_edges": sum(b.inc_edges for b in self.batches),
            "full_edges": sum(b.full_edges for b in self.batches),
            "out_vertices": sum(b.out_vertices for b in self.batches),
            "staged_bytes": self.staged_bytes,
            "prefetch_hits": self.prefetch_hits,
            "sync_wait_s": self.sync_wait_s,
            "compute_s": self.compute_s,
            "reads_served": self.reads_served,
            "reads_rejected": self.reads_rejected,
            "read_p50_s": self.read_p50_s,
            "read_p99_s": self.read_p99_s,
            "staleness_batches": self.staleness_batches,
            "cache_hit_rows": self.cache_hit_rows,
            "cache_miss_rows": self.cache_miss_rows,
            "cache_evictions": self.cache_evictions,
            # batch-window fusion counters (ISSUE 9): deterministic, gated
            # exactly on the high-rate smoke cell.  All three stay zero
            # without a FusionConfig (or with window=1/enabled=False).
            "fusion_windows": self.fusion_windows,
            "fused_batches": self.fused_batches,
            "fusion_fallbacks": self.fusion_fallbacks,
            # halo-exchange counters (ISSUE 10): plan-derived (never read
            # from device), deterministic, gated exactly on the 8-shard
            # smoke cell.  Zero for unsharded backends.
            "comms_halo_rows_sent": self.comms_halo_rows_sent,
            "comms_halo_bytes": self.comms_halo_bytes,
            # adaptive-execution-policy accounting (ISSUE 7): per-mode
            # decision counts and the cost model's raw edge-work, both
            # deterministic (CI-gated exactly in the adversarial suite).
            # Without a policy every batch is "incremental" and
            # policy_edges stays 0.
            "policy_incremental_batches": self._mode_count("incremental"),
            "policy_chunked_batches": self._mode_count("chunked"),
            "policy_full_batches": self._mode_count("full"),
            "policy_edges": sum(b.est_edges for b in self.batches),
            "policy_cost": sum(b.est_cost for b in self.batches),
        }

    def _mode_count(self, mode: str) -> int:
        return sum(1 for b in self.batches if b.mode == mode)


#: the complete documented ``StreamStats.as_dict`` key namespace (see the
#: table in :meth:`StreamStats.as_dict`) — consumers assert against this
#: instead of hard-coding strings, so a rename fails loudly
STREAM_STAT_KEYS: Tuple[str, ...] = tuple(
    StreamStats([], 0.0, 0.0).as_dict().keys()
)


@dataclasses.dataclass(frozen=True)
class CommsStats:
    """Cumulative halo-exchange volume of a sharded backend (ISSUE 10).

    Plan-derived — computed from the value-independent per-consumer
    delivery sets, never measured off the device — so the counters are
    bit-stable and CI-gateable.  ``halo_rows_sent`` counts (row, consumer)
    deliveries: under ``halo="ppermute"`` each halo row is counted once
    per shard that actually gathers it; under ``"psum"`` once per shard
    on the mesh (the broadcast ceiling).  ``halo_bytes`` weights each
    delivery by the rows' staged payload (old+new views where both
    cross)."""

    halo_rows_sent: int = 0
    halo_bytes: int = 0


def _resolve_backend_comms(comms, use_pallas_delta: Optional[bool],
                           name: str):
    """Canonicalize a sharded backend's comms knobs: the typed
    :class:`~repro.dist.sharding.CommsConfig` is the documented surface;
    the loose ``use_pallas_delta=`` kwarg survives as a deprecated alias
    that folds into it (None — the default — means "not passed")."""
    from repro.dist.sharding import CommsConfig

    if use_pallas_delta is not None:
        warnings.warn(
            f"{name}(use_pallas_delta=...) is a deprecated alias; pass "
            f"comms=CommsConfig(use_pallas_delta=...) (or create the "
            f"engine with create_engine and EngineConfig.comms) instead",
            DeprecationWarning, stacklevel=3)
        if comms is None:
            return CommsConfig(use_pallas_delta=use_pallas_delta)
        return dataclasses.replace(comms, use_pallas_delta=use_pallas_delta)
    return comms if comms is not None else CommsConfig()


# ====================================================================== #
# StateBackend protocol
# ====================================================================== #
class StateBackend(abc.ABC):
    """Execution substrate under a :class:`StreamOrchestrator`.

    A backend owns the residency of the per-layer historical state
    (h, a, nct) and knows how to (1) turn a batch into a substrate-specific
    prepared plan (host-only, value-independent), (2) dispatch that plan,
    and (3) surface the state back (``embeddings``/``sync_arrays``).  The
    returned prep object must expose ``n_inc_edges``/``n_full_edges``/
    ``n_out_rows`` counters for :class:`BatchStats` accounting."""

    model: GNNModel
    L: int

    @property
    def overlap_capable(self) -> bool:
        """Whether ``apply_stream``'s plan/execute overlap is supported."""
        return True

    @abc.abstractmethod
    def plan(self, g_old: CSRGraph, g_new: CSRGraph, batch: UpdateBatch) -> Any:
        """Host-only, value-independent planning (may overlap execution)."""

    @abc.abstractmethod
    def dispatch(self, prep: Any) -> None:
        """Execute a prepared plan (as asynchronously as the substrate allows)."""

    #: bumped by every ``flush()``: the orchestrator uses it to verify a
    #: batch's plan really was built with no intervening backend barrier
    #: (the ``prefetch_hits`` counter would otherwise be tautological)
    barrier_epoch: int = 0

    def flush(self) -> None:
        """Complete any work ``dispatch`` deferred (a barrier: bump the
        epoch even when there is nothing to complete)."""
        self.barrier_epoch += 1

    def staging_snapshot(self) -> Optional[StagingStats]:
        """Snapshot of the backend's host-staging counters (None when the
        substrate has no :class:`HostStagingPipeline`)."""
        return None

    def cache_snapshot(self) -> Optional[CacheStats]:
        """Snapshot of the backend's device hot-row-cache counters (None
        when the substrate has no :class:`repro.serve.hotcache.HotRowCache`
        attached)."""
        return None

    def comms_snapshot(self) -> Optional[CommsStats]:
        """Snapshot of the backend's halo-exchange counters (None for
        unsharded substrates — no inter-shard traffic exists)."""
        return None

    # ------------------------------------------------------------------ #
    # Serving API (ISSUE 6): versioned snapshot reads.
    #
    # The version/consistency contract the serving front-end
    # (:class:`repro.serve.frontend.ServingFrontend`) builds on:
    #
    # * a **version** is one flushed batch — after ``flush()`` +
    #   ``block_until_ready(sync_arrays())`` the substrate's state *is* the
    #   post-batch-v state, bitwise;
    # * ``snapshot_rows(rows)`` is a consistent host gather of final-layer
    #   embedding rows at such a boundary.  It must not inject work into a
    #   live staging pipeline: the host-resident substrates flush first
    #   (a no-op at a boundary — the worker queue is already drained), so
    #   reads never contend with the async worker's pristine-gather
    #   schedule;
    # * ``changed_rows(prep)`` names, *before dispatch*, every final-layer
    #   row the prepared plan may write.  Snapshotting exactly these rows
    #   pre-dispatch yields a per-version undo record, which is how the
    #   front-end answers a read pinned to version v bitwise-equal to the
    #   post-batch-v state after later batches have run.
    # ------------------------------------------------------------------ #
    def snapshot_rows(self, rows: np.ndarray) -> np.ndarray:
        """Host gather of final-layer embedding rows (consistent at a
        version boundary).  Substrates override with an O(len(rows)) path;
        this fallback materializes the full embedding table."""
        return np.asarray(self.embeddings)[np.asarray(rows, np.int64)]

    def changed_rows(self, prep: Any) -> np.ndarray:
        """Global ids of final-layer rows ``dispatch(prep)`` may write
        (value-independent: derived from the plan, usable pre-dispatch)."""
        raise NotImplementedError(
            f"{type(self).__name__} does not expose plan write sets; "
            "versioned serving reads are unsupported on this substrate")

    # ------------------------------------------------------------------ #
    # Policy-execution primitives (ISSUE 7): the orchestrator-level
    # ExecutionPolicy runs chunked-subset and full-recompute batches on
    # *any* substrate through three generic state operations.  The caller
    # (StreamOrchestrator) flushes first, so implementations may assume no
    # deferred write-back is in flight.
    # ------------------------------------------------------------------ #
    @property
    def host_params(self) -> List[Params]:
        """Per-layer params as host-usable values (mesh backends override:
        their ``params`` are device-replicated)."""
        return self.params

    def chunk_scheduler(self):
        """The substrate's own §V-C scheduler, if it has one (ChunkedBackend)
        — lets the policy path share its reuse/transfer counters.  None →
        the orchestrator lazily creates a generic one."""
        return None

    def apply_feature_updates(self, rows: np.ndarray, vals: np.ndarray) -> None:
        """Persist a batch's layer-0 feature updates into the state."""
        raise NotImplementedError(
            f"{type(self).__name__} does not implement the policy "
            "execution primitives")

    def layer_input_host(self, l: int) -> np.ndarray:
        """Layer ``l``'s input embeddings (h^l) as a host ``[n, d]`` array
        (no scratch row) — what the chunked scheduler recomputes from."""
        raise NotImplementedError(
            f"{type(self).__name__} does not implement the policy "
            "execution primitives")

    def scatter_layer_rows(self, l: int, rows: np.ndarray, a_rows: np.ndarray,
                           nct_rows: np.ndarray, h_rows: np.ndarray) -> None:
        """Write one layer's recomputed (a, nct, h^{l+1}) rows back into the
        substrate's state at global ``rows``."""
        raise NotImplementedError(
            f"{type(self).__name__} does not implement the policy "
            "execution primitives")

    @abc.abstractmethod
    def sync_arrays(self) -> list:
        """Arrays to ``jax.block_until_ready`` at timed boundaries."""

    @abc.abstractmethod
    def refresh(self, graph: CSRGraph) -> None:
        """Full recomputation over ``graph`` and the *current* features."""

    @property
    @abc.abstractmethod
    def embeddings(self):
        """Final-layer embeddings for all n vertices."""

    @abc.abstractmethod
    def state_bytes(self) -> int:
        """Bytes of persistent cached state (all tiers)."""


# ====================================================================== #
# Policy execution payloads (ISSUE 7): when an ExecutionPolicy routes a
# batch away from the substrate's native incremental dispatch, the
# orchestrator carries one of these instead of a backend prep.  They expose
# the same n_inc_edges / n_full_edges / n_out_rows counters BatchStats reads.
# ====================================================================== #
@dataclasses.dataclass
class _PolicyChunkedPrep:
    """Chunked-subset recompute payload: the policy chose ``"chunked"``, so
    the orchestrator drives the §V-C scheduler over the plan's live out rows
    through the backend's policy-execution primitives (any substrate)."""

    plan: BatchPlan
    batch: UpdateBatch
    g_new: CSRGraph
    rows_per_layer: List[np.ndarray]  # live out_rows per layer (global ids)
    est: PlanCostEstimate

    @property
    def n_inc_edges(self) -> int:
        return 0  # no signed delta records execute in this mode

    @property
    def n_full_edges(self) -> int:
        return self.est.chunked_edges

    @property
    def n_out_rows(self) -> int:
        return int(sum(r.shape[0] for r in self.rows_per_layer))


@dataclasses.dataclass
class _PolicyFullPrep:
    """Full-recompute payload: the policy chose ``"full"`` — the batch runs
    as ``backend.refresh`` over the post-batch graph (after the feature
    scatter), exactly the refresh-cadence path."""

    batch: UpdateBatch
    g_new: CSRGraph
    est: PlanCostEstimate

    @property
    def n_inc_edges(self) -> int:
        return 0

    @property
    def n_full_edges(self) -> int:
        return self.est.full_edges

    @property
    def n_out_rows(self) -> int:
        return self.est.n * self.est.L


@dataclasses.dataclass
class _PendingPlan:
    """One planned-but-not-dispatched batch in the fusion lookahead window
    (ISSUE 9).  Everything here is host-only and value-independent (graph
    snapshots, the Alg.-4 plan, its footprint), so the window may run
    arbitrarily far ahead of device execution."""

    batch: UpdateBatch
    g_old: CSRGraph
    g_new: CSRGraph
    plan: BatchPlan
    fp: np.ndarray  # sorted unique row footprint (FusionWindow.footprint)


# ====================================================================== #
# StreamOrchestrator — the single plan/pack/overlap loop
# ====================================================================== #
class StreamOrchestrator:
    """Drives one :class:`StateBackend` over an update stream.

    Owns the evolving graph snapshot, the refresh cadence, and the paper's
    §V co-processing schedule: ``apply_stream`` dispatches batch t and then
    runs host planning of batch t+1 while the substrate executes, syncing
    only at the end of the stream (and around refreshes).  ``apply_batch``
    keeps the per-batch API with honest timing (``block=True`` syncs at the
    timed boundary so ``exec_time_s`` measures completion, not dispatch)."""

    def __init__(self, backend: StateBackend, graph: CSRGraph,
                 refresh_every: int = 0,
                 policy: Optional[ExecutionPolicy] = None,
                 fusion: Optional[FusionConfig] = None):
        self.backend = backend
        self.graph = graph
        self.refresh_every = refresh_every
        self.policy = policy
        # batch-window fusion (ISSUE 9): inert unless a FusionConfig with
        # window >= 2 is attached — None keeps every entry point on the
        # serial per-batch loop, byte-identical to pre-fusion behavior
        if fusion is not None and (not fusion.enabled or fusion.window < 2):
            fusion = None
        self.fusion = fusion
        # cumulative fusion counters (deterministic; StreamStats reports
        # per-stream deltas of these)
        self.fusion_windows = 0
        self.fused_batches = 0
        self.fusion_fallbacks = 0
        self._batches_seen = 0
        self._chunk_sched = None  # lazy generic §V-C scheduler (policy path)

    # ------------------------------------------------------------------ #
    def refresh(self) -> None:
        """Full recomputation (drift reset / MTEC-style refresh)."""
        self.backend.refresh(self.graph)

    def _apply_graph(self, batch: UpdateBatch) -> CSRGraph:
        return self.graph.apply_updates(
            batch.ins_src, batch.ins_dst, batch.del_src, batch.del_dst,
            batch.ins_weights, batch.ins_etypes,
        )

    def _after_batch(self, sync_before_refresh: bool = False) -> None:
        self._batches_seen += 1
        if self.refresh_every and self._batches_seen % self.refresh_every == 0:
            self.backend.flush()
            if sync_before_refresh:
                jax.block_until_ready(self.backend.sync_arrays())
            self.refresh()

    # ------------------------------------------------------------------ #
    # policy routing (ISSUE 7): per batch, score the three execution
    # shapes on the Alg.-4 plan and dispatch the winner.  Without a
    # policy every batch takes the pre-policy incremental path unchanged.
    # ------------------------------------------------------------------ #
    def _prepare(self, g_new: CSRGraph, batch: UpdateBatch,
                 base: Optional[BatchPlan] = None):
        """Plan one batch → ``(mode, payload, decision)``.

        Host-only and value-independent (the decision reads plan counters
        and degree tables, never state values), so it keeps the §V overlap
        contract: ``apply_stream`` runs it behind the previous batch's
        device execution.  ``base`` short-circuits the Alg.-4 build when
        the caller already planned the batch (the fusion lookahead's serial
        fallback) — ``build_plan`` is deterministic, so reusing the
        lookahead's plan is bitwise-identical to rebuilding it."""
        if self.policy is None:
            if base is not None:
                return ("incremental",
                        self.backend.plan(self.graph, g_new, batch,
                                          base_plan=base), None)
            return "incremental", self.backend.plan(self.graph, g_new, batch), None
        if base is None:
            base = build_plan(self.backend.model, self.graph, g_new, batch,
                              self.backend.L)
        decision = self.policy.decide(base)
        if decision.mode == "incremental":
            prep = self.backend.plan(self.graph, g_new, batch, base_plan=base)
            return "incremental", prep, decision
        if decision.mode == "chunked":
            rows = [np.unique(lp.out_rows[lp.out_mask].astype(np.int64))
                    for lp in base.layers]
            return "chunked", _PolicyChunkedPrep(
                plan=base, batch=batch, g_new=g_new, rows_per_layer=rows,
                est=decision.estimate), decision
        return "full", _PolicyFullPrep(batch=batch, g_new=g_new,
                                       est=decision.estimate), decision

    def _dispatch_mode(self, mode: str, prep: Any) -> None:
        if mode == "incremental":
            self.backend.dispatch(prep)
        elif mode == "chunked":
            self._execute_chunked(prep)
        else:
            self._execute_full(prep)

    def _chunk_scheduler(self):
        sched = self.backend.chunk_scheduler()
        if sched is not None:
            return sched  # ChunkedBackend: share its reuse/transfer counters
        if self._chunk_sched is None:
            # deferred import: repro.serve.scheduler pulls repro.core.full
            # while this module is itself mid-import under repro.core
            from repro.serve.scheduler import ChunkedLayerScheduler

            self._chunk_sched = ChunkedLayerScheduler(self.backend.model)
        return self._chunk_sched

    def _apply_features(self, batch: UpdateBatch) -> None:
        if batch.feat_vertices is not None and batch.feat_vertices.size:
            self.backend.apply_feature_updates(
                np.asarray(batch.feat_vertices, np.int64),
                np.asarray(batch.feat_values, np.float32))

    def _execute_chunked(self, prep: _PolicyChunkedPrep) -> None:
        """Chunked-subset recompute on any substrate: per layer, recompute
        the plan's live out rows from the post-batch graph through the §V-C
        scheduler and scatter them back.  Layer ``l`` reads ``h[l]`` after
        the previous layer's scatter (and the feature scatter for layer 0),
        so the recompute sees exactly the incremental path's layer inputs —
        the same schedule as :meth:`ChunkedBackend.dispatch`."""
        self.backend.flush()  # primitives assume no in-flight write-back
        self._apply_features(prep.batch)
        sched = self._chunk_scheduler()
        params = self.backend.host_params
        deg = prep.plan.deg_new[:-1]  # [n] new-graph degrees (drop scratch)
        for l in range(self.backend.L):
            rows = prep.rows_per_layer[l]
            if not rows.size:
                continue
            h_prev = self.backend.layer_input_host(l)
            a_r, nct_r, h_r = sched.run_layer(params[l], prep.g_new,
                                              h_prev, rows, deg)
            self.backend.scatter_layer_rows(l, rows, a_r, nct_r, h_r)

    def _execute_full(self, prep: _PolicyFullPrep) -> None:
        """Full recompute over the post-batch graph — the refresh-cadence
        path, with the batch's feature updates applied first so ``refresh``
        (which recomputes from the *current* h[0]) sees them."""
        self.backend.flush()
        self._apply_features(prep.batch)
        self.backend.refresh(prep.g_new)

    def write_set(self, prep: Any) -> np.ndarray:
        """Serving write set of one prepared batch payload, whatever mode
        the policy chose (the frontend's undo-log hook goes through here;
        full-recompute payloads never reach it — the frontend resets).
        Inside a fused window the hook receives each constituent's raw
        :class:`BatchPlan` (the per-logical-batch write sets the undo log
        needs), handled here directly."""
        if isinstance(prep, BatchPlan):
            return final_write_rows(prep)
        if isinstance(prep, _PolicyChunkedPrep):
            return prep.rows_per_layer[-1]
        return self.backend.changed_rows(prep)

    # ------------------------------------------------------------------ #
    # per-batch API (honest timing: block=True syncs at the boundary)
    # ------------------------------------------------------------------ #
    def apply_batch(self, batch: UpdateBatch, block: bool = True,
                    on_plan=None) -> BatchStats:
        t0 = time.perf_counter()
        g_new = self._apply_graph(batch)
        t1 = time.perf_counter()
        mode, prep, decision = self._prepare(g_new, batch)
        t2 = time.perf_counter()
        if on_plan is not None and mode != "full":
            # serving hook (repro.serve.frontend): runs between plan and
            # dispatch, while the substrate still holds the *pre-batch*
            # state — the front-end snapshots the plan's write set here to
            # build its per-version undo log.  Skipped for full-recompute
            # batches: their pre-images degenerate into a whole-state copy,
            # so the front-end resets its history instead (BatchStats.mode
            # tells it to).
            on_plan(prep)
        self._dispatch_mode(mode, prep)
        if block:
            self.backend.flush()
            jax.block_until_ready(self.backend.sync_arrays())
        t3 = time.perf_counter()
        self.graph = g_new
        if decision is not None:
            # online cost-weight calibration (ISSUE 9): a no-op unless the
            # policy was built with calibrate=True.  block=False feeds the
            # dispatch-only time (the overlap pipeline cannot observe
            # per-batch completion without breaking itself).
            self.policy.observe(decision, t3 - t2)
        self._after_batch()
        return BatchStats(
            inc_edges=prep.n_inc_edges,
            full_edges=prep.n_full_edges,
            out_vertices=prep.n_out_rows,
            plan_time_s=t2 - t1,
            exec_time_s=t3 - t2,
            graph_time_s=t1 - t0,
            mode=mode,
            est_edges=decision.est_edges if decision is not None else 0,
            est_cost=decision.costs[mode] if decision is not None else 0.0,
        )

    # ------------------------------------------------------------------ #
    # pipelined stream API: plan t+1 on host while the substrate runs t
    # ------------------------------------------------------------------ #
    def apply_stream(self, batches: Sequence[UpdateBatch]) -> StreamStats:
        """Double-buffered batch application (paper §V co-processing).

        Batch t is dispatched; Alg.-4 planning of batch t+1 (host numpy)
        then runs while the substrate executes.  The only full barrier is
        the end of the stream (and around refreshes)."""
        assert self.backend.overlap_capable, "apply_stream requires the fused engine"
        batches = list(batches)
        if not batches:
            return StreamStats([], 0.0, 0.0)
        if self._fusion_active():
            return self._apply_stream_fused(batches)
        t_start = time.perf_counter()
        stats: List[BatchStats] = []
        plan_total = 0.0
        prefetch_hits = 0  # batches whose plan was built behind execution
        staging0 = self.backend.staging_snapshot()
        cache0 = self.backend.cache_snapshot()
        comms0 = self.backend.comms_snapshot()

        tp = time.perf_counter()
        g_new = self._apply_graph(batches[0])
        mode, prep, decision = self._prepare(g_new, batches[0])
        plan_total += time.perf_counter() - tp

        for i in range(len(batches)):
            epoch0 = self.backend.barrier_epoch
            td = time.perf_counter()
            # async for incremental; chunked/full execute synchronously
            # (they flush first), which honestly costs this batch its
            # prefetch hit — the flush bumps barrier_epoch
            self._dispatch_mode(mode, prep)
            dispatch_s = time.perf_counter() - td
            self.graph = g_new
            stats.append(
                BatchStats(
                    inc_edges=prep.n_inc_edges,
                    full_edges=prep.n_full_edges,
                    out_vertices=prep.n_out_rows,
                    plan_time_s=0.0,
                    exec_time_s=dispatch_s,  # dispatch-only; see StreamStats
                    graph_time_s=0.0,
                    mode=mode,
                    est_edges=decision.est_edges if decision is not None else 0,
                    est_cost=(decision.costs[mode]
                              if decision is not None else 0.0),
                )
            )
            if decision is not None:
                # dispatch-time calibration proxy (a no-op unless the
                # policy was built with calibrate=True): per-batch
                # completion is unobservable inside the overlap pipeline
                self.policy.observe(decision, dispatch_s)
            if i + 1 < len(batches):
                tp = time.perf_counter()  # overlapped with device execution
                nxt = self._apply_graph(batches[i + 1])
                mode, prep, decision = self._prepare(nxt, batches[i + 1])
                g_new = nxt
                plan_total += time.perf_counter() - tp
                # a real prefetch hit only if no backend barrier (flush)
                # fired between dispatch(i) and the completed plan(i+1):
                # a substrate that regresses to synchronous staging (e.g.
                # the async_staging=False escape hatch, which flushes in
                # dispatch) scores 0 here — this is what the CI exact gate
                # pins at batches-1
                if self.backend.barrier_epoch == epoch0:
                    prefetch_hits += 1
            self._after_batch(sync_before_refresh=True)
        self.backend.flush()
        jax.block_until_ready(self.backend.sync_arrays())
        ss = StreamStats(stats, time.perf_counter() - t_start, plan_total,
                         prefetch_hits=prefetch_hits)
        if staging0 is not None:
            s1 = self.backend.staging_snapshot()
            ss.staged_bytes = s1.staged_bytes - staging0.staged_bytes
            ss.sync_wait_s = ((s1.wait_gather_s + s1.drain_wait_s)
                              - (staging0.wait_gather_s + staging0.drain_wait_s))
            ss.compute_s = s1.wait_device_s - staging0.wait_device_s
        if cache0 is not None:
            c1 = self.backend.cache_snapshot()
            ss.cache_hit_rows = c1.hit_rows - cache0.hit_rows
            ss.cache_miss_rows = c1.miss_rows - cache0.miss_rows
            ss.cache_evictions = c1.evictions - cache0.evictions
        if comms0 is not None:
            m1 = self.backend.comms_snapshot()
            ss.comms_halo_rows_sent = m1.halo_rows_sent - comms0.halo_rows_sent
            ss.comms_halo_bytes = m1.halo_bytes - comms0.halo_bytes
        return ss

    # ------------------------------------------------------------------ #
    # batch-window fusion (ISSUE 9): buffer up to fusion.window pending
    # batches, fuse the maximal independent prefix into ONE packed plan /
    # ONE device dispatch, fall back to serial on overlap.  Bitwise-equal
    # to the serial loop on every backend (the disjoint-footprint proof
    # lives on repro.core.affected.FusionWindow).
    # ------------------------------------------------------------------ #
    def _fusion_active(self) -> bool:
        """Fusion runs only when configured AND the policy allows it: a
        per-batch ``force_mode`` schedule is indexed by logical batch, so
        fusing under one would desynchronize the schedule — those streams
        take the serial loop unchanged."""
        if self.fusion is None:
            return False
        if self.policy is not None and self.policy.force_mode is not None \
                and not isinstance(self.policy.force_mode, str):
            return False
        return True

    def _refresh_limit(self) -> int:
        """Batches until the next refresh boundary (windows must not span
        one: refresh recomputes state, so constituents after the boundary
        would fuse against pre-refresh values)."""
        if not self.refresh_every:
            return 1 << 30
        return self.refresh_every - self._batches_seen % self.refresh_every

    def _plan_pending(self, g_old: CSRGraph, batch: UpdateBatch) -> _PendingPlan:
        g_new = g_old.apply_updates(
            batch.ins_src, batch.ins_dst, batch.del_src, batch.del_dst,
            batch.ins_weights, batch.ins_etypes)
        plan = build_plan(self.backend.model, g_old, g_new, batch,
                          self.backend.L)
        return _PendingPlan(batch=batch, g_old=g_old, g_new=g_new, plan=plan,
                            fp=FusionWindow.footprint(plan, batch))

    def _decide_window(self, merged_plan: BatchPlan):
        """Policy check for a fused window (None → no policy → fuse)."""
        if self.policy is None:
            return None, "incremental"
        decision = self.policy.decide_window(merged_plan)
        return decision, decision.mode

    def _fused_stats(self, group: List[_PendingPlan], dispatch_s: float,
                     decision) -> List[BatchStats]:
        """Per-constituent BatchStats of one fused dispatch: plan counters
        stay per *logical* batch (each constituent reports its own plan's
        edge/row work — the sums equal the merged plan's), the window's one
        dispatch time and policy estimate are charged to the first."""
        k = len(group)
        out = []
        for j, p in enumerate(group):
            out.append(BatchStats(
                inc_edges=p.plan.total_inc_edges(),
                full_edges=p.plan.total_full_edges(),
                out_vertices=p.plan.total_vertices(),
                plan_time_s=0.0,
                exec_time_s=dispatch_s if j == 0 else 0.0,
                graph_time_s=0.0,
                mode="incremental",
                est_edges=(decision.est_edges
                           if decision is not None and j == 0 else 0),
                est_cost=(decision.costs["incremental"]
                          if decision is not None and j == 0 else 0.0),
                fused_window=k,
            ))
        return out

    def _apply_stream_fused(self, batches: List[UpdateBatch]) -> StreamStats:
        """The fused variant of :meth:`apply_stream`: same overlap schedule
        (host planning of *future* batches runs behind the device execution
        of the dispatch just issued), but each dispatch covers the maximal
        independent prefix of the lookahead window."""
        fw = FusionWindow(self.fusion)
        t_start = time.perf_counter()
        stats: List[BatchStats] = []
        plan_total = 0.0
        prefetch_hits = 0
        fusion0 = (self.fusion_windows, self.fused_batches,
                   self.fusion_fallbacks)
        staging0 = self.backend.staging_snapshot()
        cache0 = self.backend.cache_snapshot()
        comms0 = self.backend.comms_snapshot()

        pending: List[_PendingPlan] = []
        nxt = 0  # next batch index to plan
        g_plan = self.graph  # graph snapshot after every *planned* batch

        def top_up() -> int:
            """Fill the lookahead window (host-only; overlaps execution)."""
            nonlocal nxt, g_plan, plan_total
            planned = 0
            while len(pending) < self.fusion.window and nxt < len(batches):
                tp = time.perf_counter()
                pending.append(self._plan_pending(g_plan, batches[nxt]))
                g_plan = pending[-1].g_new
                nxt += 1
                plan_total += time.perf_counter() - tp
                planned += 1
            return planned

        top_up()
        while pending:
            limit = min(len(pending), self._refresh_limit())
            k = fw.select_prefix([p.fp for p in pending[:limit]])
            decision, mode = None, "incremental"
            if k >= 2:
                tp = time.perf_counter()
                merged_plan, merged_batch = FusionWindow.merge(
                    [p.plan for p in pending[:k]],
                    [p.batch for p in pending[:k]])
                decision, mode = self._decide_window(merged_plan)
                if mode == "incremental":
                    prep = self.backend.plan(
                        pending[0].g_old, pending[k - 1].g_new, merged_batch,
                        base_plan=merged_plan)
                    plan_total += time.perf_counter() - tp
                    group = pending[:k]
                    del pending[:k]
                    epoch0 = self.backend.barrier_epoch
                    td = time.perf_counter()
                    self.backend.dispatch(prep)
                    dispatch_s = time.perf_counter() - td
                    self.graph = group[-1].g_new
                    self.fusion_windows += 1
                    self.fused_batches += k
                    stats.extend(self._fused_stats(group, dispatch_s,
                                                   decision))
                    if decision is not None:
                        self.policy.observe(decision, dispatch_s)
                    planned = top_up()  # overlapped with fused execution
                    if self.backend.barrier_epoch == epoch0:
                        prefetch_hits += planned
                    for _ in range(k):
                        self._after_batch(sync_before_refresh=True)
                    continue
                # the policy priced the fused unit off the incremental
                # path: break the window up, re-decide per batch below
                plan_total += time.perf_counter() - tp
                self.fusion_fallbacks += 1
            elif limit >= 2:
                self.fusion_fallbacks += 1  # head pair overlaps
            # serial dispatch of the window head (plan reused, not rebuilt)
            p = pending.pop(0)
            tp = time.perf_counter()
            mode, prep, decision = self._prepare(p.g_new, p.batch,
                                                 base=p.plan)
            plan_total += time.perf_counter() - tp
            epoch0 = self.backend.barrier_epoch
            td = time.perf_counter()
            self._dispatch_mode(mode, prep)
            dispatch_s = time.perf_counter() - td
            self.graph = p.g_new
            stats.append(BatchStats(
                inc_edges=prep.n_inc_edges,
                full_edges=prep.n_full_edges,
                out_vertices=prep.n_out_rows,
                plan_time_s=0.0,
                exec_time_s=dispatch_s,
                graph_time_s=0.0,
                mode=mode,
                est_edges=decision.est_edges if decision is not None else 0,
                est_cost=(decision.costs[mode]
                          if decision is not None else 0.0),
            ))
            if decision is not None:
                self.policy.observe(decision, dispatch_s)
            planned = top_up()
            if self.backend.barrier_epoch == epoch0:
                prefetch_hits += planned
            self._after_batch(sync_before_refresh=True)

        self.backend.flush()
        jax.block_until_ready(self.backend.sync_arrays())
        ss = StreamStats(stats, time.perf_counter() - t_start, plan_total,
                         prefetch_hits=prefetch_hits)
        ss.fusion_windows = self.fusion_windows - fusion0[0]
        ss.fused_batches = self.fused_batches - fusion0[1]
        ss.fusion_fallbacks = self.fusion_fallbacks - fusion0[2]
        if staging0 is not None:
            s1 = self.backend.staging_snapshot()
            ss.staged_bytes = s1.staged_bytes - staging0.staged_bytes
            ss.sync_wait_s = ((s1.wait_gather_s + s1.drain_wait_s)
                              - (staging0.wait_gather_s + staging0.drain_wait_s))
            ss.compute_s = s1.wait_device_s - staging0.wait_device_s
        if cache0 is not None:
            c1 = self.backend.cache_snapshot()
            ss.cache_hit_rows = c1.hit_rows - cache0.hit_rows
            ss.cache_miss_rows = c1.miss_rows - cache0.miss_rows
            ss.cache_evictions = c1.evictions - cache0.evictions
        if comms0 is not None:
            m1 = self.backend.comms_snapshot()
            ss.comms_halo_rows_sent = m1.halo_rows_sent - comms0.halo_rows_sent
            ss.comms_halo_bytes = m1.halo_bytes - comms0.halo_bytes
        return ss

    def apply_window(self, batches: Sequence[UpdateBatch],
                     on_plan=None) -> List[BatchStats]:
        """Blocking fused application of a *prefix* of ``batches``.

        The serving front-end's fused write path: plans batches one at a
        time from the current graph, stops at the first footprint overlap /
        window cap / refresh boundary, dispatches the accumulated prefix as
        one fused step (or one serial batch when the prefix is length 1),
        and blocks until the state reflects it.  Returns one
        :class:`BatchStats` per batch consumed (``len(result)`` tells the
        caller how far the stream advanced).

        ``on_plan`` runs once per *constituent* batch — in stream order,
        before dispatch, with the constituent's own :class:`BatchPlan` —
        while the substrate still holds the strictly pre-window state.
        Disjoint write sets make the pre-window values on batch j's write
        set identical to the post-batch-(j-1) values there, so the
        front-end's per-version pre-images stay exact (skipped for
        full-recompute fallbacks, matching :meth:`apply_batch`)."""
        batches = list(batches)
        if not batches:
            return []
        fw = FusionWindow(self.fusion) if self._fusion_active() \
            else FusionWindow(FusionConfig(window=1))
        limit = min(len(batches), fw.config.window, self._refresh_limit())
        t0 = time.perf_counter()
        group = [self._plan_pending(self.graph, batches[0])]
        while len(group) < limit:
            p = self._plan_pending(group[-1].g_new, batches[len(group)])
            if not all(fw.disjoint(p.fp, q.fp) for q in group):
                break  # one wasted (deterministic, value-independent) plan
            group.append(p)
        k = len(group)
        decision, mode = None, "incremental"
        if k >= 2:
            merged_plan, merged_batch = FusionWindow.merge(
                [p.plan for p in group], [p.batch for p in group])
            decision, mode = self._decide_window(merged_plan)
            if mode != "incremental":
                self.fusion_fallbacks += 1
                group, k = group[:1], 1
        elif limit >= 2 and len(batches) >= 2:
            self.fusion_fallbacks += 1
        t1 = time.perf_counter()
        if k >= 2:
            prep = self.backend.plan(group[0].g_old, group[-1].g_new,
                                     merged_batch, base_plan=merged_plan)
            if on_plan is not None:
                for p in group:
                    on_plan(p.plan)
            td = time.perf_counter()
            self.backend.dispatch(prep)
            self.backend.flush()
            jax.block_until_ready(self.backend.sync_arrays())
            dispatch_s = time.perf_counter() - td
            self.graph = group[-1].g_new
            self.fusion_windows += 1
            self.fused_batches += k
            out = self._fused_stats(group, dispatch_s, decision)
            out[0].plan_time_s = t1 - t0
            if decision is not None:
                self.policy.observe(decision, dispatch_s)
            for _ in range(k):
                self._after_batch(sync_before_refresh=True)
            return out
        p = group[0]
        mode, prep, decision = self._prepare(p.g_new, p.batch, base=p.plan)
        if on_plan is not None and mode != "full":
            on_plan(prep)
        td = time.perf_counter()
        self._dispatch_mode(mode, prep)
        self.backend.flush()
        jax.block_until_ready(self.backend.sync_arrays())
        dispatch_s = time.perf_counter() - td
        self.graph = p.g_new
        if decision is not None:
            self.policy.observe(decision, dispatch_s)
        self._after_batch(sync_before_refresh=True)
        return [BatchStats(
            inc_edges=prep.n_inc_edges,
            full_edges=prep.n_full_edges,
            out_vertices=prep.n_out_rows,
            plan_time_s=t1 - t0,
            exec_time_s=dispatch_s,
            graph_time_s=0.0,
            mode=mode,
            est_edges=decision.est_edges if decision is not None else 0,
            est_cost=decision.costs[mode] if decision is not None else 0.0,
        )]


# ====================================================================== #
# DeviceBackend — fused donated in-HBM state (the PR-2 pipelined path)
# ====================================================================== #
@dataclasses.dataclass
class _UnfusedPrep:
    """Per-layer seed execution path's prepared plan (equivalence reference)."""

    plan: BatchPlan
    batch: UpdateBatch

    @property
    def n_inc_edges(self) -> int:
        return self.plan.total_inc_edges()

    @property
    def n_full_edges(self) -> int:
        return self.plan.total_full_edges()

    @property
    def n_out_rows(self) -> int:
        return self.plan.total_vertices()


class DeviceBackend(StateBackend):
    """All state device-resident as scratch-extended ``[N+1, ·]`` arrays;
    each batch runs as one fused, donated L-layer step over a packed plan
    (:func:`repro.core.incremental.fused_stream_step`).  ``fused=False``
    preserves the seed per-layer dispatch as the unfused reference."""

    def __init__(
        self,
        model: GNNModel,
        params: Sequence[Params],
        graph: CSRGraph,
        x: jax.Array,
        store_h: bool = True,
        fused: bool = True,
        use_pallas_delta: bool = False,
    ):
        self.model = model
        self.params = list(params)
        self.L = len(self.params)
        self.store_h = store_h
        self.fused = fused
        self.use_pallas_delta = use_pallas_delta
        # high-water-mark capacity buckets: shrinking batches reuse the
        # previous PackedLayout instead of retracing the fused step
        self.hwm = BucketHysteresis()
        self._upd = jax.jit(model.update)
        self._init_state(graph, jnp.asarray(x))

    @property
    def overlap_capable(self) -> bool:
        return self.fused

    # ------------------------------------------------------------------ #
    # state: scratch-extended [N+1, ·] device arrays (index n = scratch)
    # ------------------------------------------------------------------ #
    def _init_state(self, graph: CSRGraph, x: Optional[jax.Array] = None) -> None:
        if x is None:
            x = self.x
        states = full_forward(self.model, self.params, x, graph)
        self._h: List[Optional[jax.Array]] = [with_scratch(x)] + [
            with_scratch(s.h) for s in states
        ]
        self._a: List[jax.Array] = [with_scratch(s.a) for s in states]
        self._nct: List[jax.Array] = [with_scratch(s.nct) for s in states]
        if not self.store_h:
            self._drop_h()

    def refresh(self, graph: CSRGraph) -> None:
        self._init_state(graph)

    def _drop_h(self) -> None:
        self._h = [self._h[0]] + [None] * self.L

    @property
    def x(self) -> jax.Array:
        return self._h[0][:-1]

    @property
    def h(self) -> List[Optional[jax.Array]]:
        """Seed-compatible view: per-layer embeddings without scratch rows."""
        return [None if v is None else v[:-1] for v in self._h]

    @h.setter
    def h(self, vals: Sequence[Optional[jax.Array]]) -> None:
        self._h = [None if v is None else with_scratch(v) for v in vals]

    @property
    def a(self) -> List[jax.Array]:
        return [v[:-1] for v in self._a]

    @a.setter
    def a(self, vals: Sequence[jax.Array]) -> None:
        self._a = [with_scratch(v) for v in vals]

    @property
    def nct(self) -> List[jax.Array]:
        return [v[:-1] for v in self._nct]

    @nct.setter
    def nct(self, vals: Sequence[jax.Array]) -> None:
        self._nct = [with_scratch(v) for v in vals]

    def reconstruct_h(self) -> List[jax.Array]:
        """Recomputation-based storage optimization (paper §V-B): rebuild
        h^l = update(h^{l-1}, a^l) from the cached aggregation states."""
        h = [self.x]
        for l in range(self.L):
            h.append(self._upd(self.params[l], h[l], self._a[l][:-1]))
        return h

    @property
    def embeddings(self) -> jax.Array:
        if self._h[-1] is None:
            return self.reconstruct_h()[-1]
        return self._h[-1][:-1]

    def state_bytes(self) -> int:
        def nb(arr: jax.Array) -> int:
            return (arr.shape[0] - 1) * int(np.prod(arr.shape[1:] or (1,))) * arr.dtype.itemsize

        total = sum(nb(a) for a in self._a) + sum(nb(c) for c in self._nct)
        if self.store_h:
            total += sum(nb(h) for h in self._h[1:] if h is not None)
        total += nb(self._h[0])
        return total

    def sync_arrays(self) -> list:
        return [v for v in (*self._h, *self._a, *self._nct) if v is not None]

    # ------------------------------------------------------------------ #
    # Serving API: O(len(rows)) device gather + D2H (never O(V))
    # ------------------------------------------------------------------ #
    def snapshot_rows(self, rows: np.ndarray) -> np.ndarray:
        idx = jnp.asarray(np.asarray(rows, np.int64), jnp.int32)
        h = self._h[-1]
        if h is None:  # store_h=False: rebuild from the cached a states
            return np.asarray(jnp.take(self.reconstruct_h()[-1], idx, axis=0))
        return np.asarray(jnp.take(h[:-1], idx, axis=0))

    def changed_rows(self, prep) -> np.ndarray:
        if isinstance(prep, _UnfusedPrep):
            from repro.core.affected import final_write_rows

            return final_write_rows(prep.plan)
        return prep.out_rows_final

    # ------------------------------------------------------------------ #
    # policy-execution primitives: scatters on the scratch-extended device
    # arrays (global rows < n, so the scratch row is never written)
    # ------------------------------------------------------------------ #
    def apply_feature_updates(self, rows: np.ndarray, vals: np.ndarray) -> None:
        idx = jnp.asarray(np.asarray(rows, np.int64), jnp.int32)
        self._h[0] = self._h[0].at[idx].set(
            jnp.asarray(vals, self._h[0].dtype))

    def layer_input_host(self, l: int) -> np.ndarray:
        h = self._h[l]
        if h is None:  # store_h=False: rebuild from the cached a states
            return np.asarray(self.reconstruct_h()[l])
        return np.asarray(h[:-1])

    def scatter_layer_rows(self, l: int, rows: np.ndarray, a_rows: np.ndarray,
                           nct_rows: np.ndarray, h_rows: np.ndarray) -> None:
        idx = jnp.asarray(np.asarray(rows, np.int64), jnp.int32)
        self._a[l] = self._a[l].at[idx].set(jnp.asarray(a_rows))
        self._nct[l] = self._nct[l].at[idx].set(jnp.asarray(nct_rows))
        if self._h[l + 1] is not None:  # store_h=False reconstructs instead
            self._h[l + 1] = self._h[l + 1].at[idx].set(jnp.asarray(h_rows))

    # ------------------------------------------------------------------ #
    def plan(self, g_old: CSRGraph, g_new: CSRGraph, batch: UpdateBatch,
             base_plan: Optional[BatchPlan] = None):
        if self.fused:
            if base_plan is not None:  # policy path: Alg. 4 already ran
                return pack_plan(base_plan, batch.feat_vertices,
                                 batch.feat_values,
                                 pallas=self.use_pallas_delta, hwm=self.hwm)
            return build_packed_plan(
                self.model, g_old, g_new, batch, self.L,
                pallas=self.use_pallas_delta, hwm=self.hwm,
            )
        plan = (base_plan if base_plan is not None
                else build_plan(self.model, g_old, g_new, batch, self.L))
        return _UnfusedPrep(plan, batch)

    def dispatch(self, prep) -> None:
        if isinstance(prep, _UnfusedPrep):
            self._execute_unfused(prep.plan, prep.batch)
        else:
            self._dispatch_packed(prep)

    # ------------------------------------------------------------------ #
    def _dispatch_packed(self, packed: PackedPlan) -> None:
        """One device_put for the whole plan, one fused-step dispatch."""
        if not self.store_h and self._h[1] is None:
            h = self.reconstruct_h()
            self._h = [self._h[0]] + [with_scratch(v) for v in h[1:]]
        idx, flt, msk, feat_vals, pallas = jax.device_put(
            (packed.idx, packed.flt, packed.msk, packed.feat_vals, packed.pallas)
        )
        with warnings.catch_warnings():
            # donation is a TPU/GPU aliasing optimization; CPU jit ignores it
            # with a UserWarning per compile — suppress it here (scoped) so
            # the CPU hot path stays quiet without touching global filters
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable"
            )
            hs, as_, ncts = fused_stream_step(
                self.model, packed.layout, tuple(self.params),
                tuple(self._h), tuple(self._a), tuple(self._nct),
                idx, flt, msk, feat_vals, pallas,
            )
        self._h = list(hs)
        self._a = list(as_)
        self._nct = list(ncts)
        if not self.store_h:
            self._drop_h()

    # ------------------------------------------------------------------ #
    # unfused seed path (per-layer dispatch) — equivalence reference
    # ------------------------------------------------------------------ #
    def _execute_unfused(self, plan: BatchPlan, batch: UpdateBatch) -> None:
        deg_old = jnp.asarray(plan.deg_old)
        deg_new = jnp.asarray(plan.deg_new)

        if not self.store_h:
            self.h = self.reconstruct_h()

        # layer-0 feature updates
        h0_old = self.h[0]
        if batch.feat_vertices is not None and batch.feat_vertices.size:
            h0_new = h0_old.at[jnp.asarray(batch.feat_vertices)].set(
                jnp.asarray(batch.feat_values, h0_old.dtype)
            )
        else:
            h0_new = h0_old

        h_old = [h0_old] + list(self.h[1:])
        h_new: List[jax.Array] = [h0_new]
        a_new: List[jax.Array] = []
        nct_new: List[jax.Array] = []

        for l, lp in enumerate(plan.layers):
            an, nn, hn = incremental_layer(
                self.model,
                self.params[l],
                with_scratch(h_old[l]),
                with_scratch(h_new[l]),
                deg_old,
                deg_new,
                self.a[l],
                self.nct[l],
                h_old[l + 1],
                jnp.asarray(lp.e_src),
                jnp.asarray(lp.e_dst),
                jnp.asarray(lp.e_rowidx),
                jnp.asarray(lp.e_sign),
                jnp.asarray(lp.e_use_new),
                jnp.asarray(lp.e_w),
                jnp.asarray(lp.e_t),
                jnp.asarray(lp.e_mask),
                jnp.asarray(lp.touch_rows),
                jnp.asarray(lp.touch_mask),
                jnp.asarray(lp.f_rows),
                jnp.asarray(lp.f_mask),
                jnp.asarray(lp.f_src),
                jnp.asarray(lp.f_rowidx),
                jnp.asarray(lp.f_w),
                jnp.asarray(lp.f_t),
                jnp.asarray(lp.f_emask),
                jnp.asarray(lp.out_rows),
                jnp.asarray(lp.out_mask),
            )
            a_new.append(an)
            nct_new.append(nn)
            h_new.append(hn)

        self.h = h_new
        self.a = a_new
        self.nct = nct_new
        if not self.store_h:
            self._drop_h()


# ====================================================================== #
# OffloadBackend — host-resident state, compact per-layer staging (§V-B)
# ====================================================================== #
@dataclasses.dataclass
class TransferStats:
    rows_up: int = 0
    rows_down: int = 0
    bytes_up: int = 0
    bytes_down: int = 0

    @property
    def total_rows(self) -> int:
        """H2D+D2H row volume — deterministic (no timing noise), so the CI
        perf gate can bound it tightly (benchmarks/check_regression.py)."""
        return self.rows_up + self.rows_down


_remap = remap_compact  # global vertex ids → compact positions (affected.py)


def _override_rows(dst_vals: np.ndarray, dst_rows: np.ndarray,
                   src_rows: np.ndarray, src_vals: np.ndarray) -> None:
    """dst_vals[i] ← src_vals[j] where dst_rows[i] == src_rows[j] (vectorized)."""
    if not src_rows.size or not dst_rows.size:
        return
    order = np.argsort(src_rows)
    pos = np.searchsorted(src_rows[order], dst_rows)
    pos = np.clip(pos, 0, src_rows.size - 1)
    hit = src_rows[order][pos] == dst_rows
    dst_vals[hit] = src_vals[order][pos[hit]]


@dataclasses.dataclass
class _CacheLayerOps:
    """Plan-time device hot-row-cache schedule for one layer (ISSUE 8).

    Built by the host-resident backends' ``_plan_cache`` next to the
    transfer tables (value-independent, so it keeps the plan/execute
    overlap contract) and consumed by their cached gather/exec paths at
    dispatch.  All ``*_pos`` arrays are positions in the layer's device
    workspace — ``[nh]``/``[ns]`` compact space for the flat offload,
    flat ``[S·cap]`` stacked space for the hybrid; ``h_miss_src``/
    ``s_miss_src`` are the global row ids the staging worker still
    gathers (the cold misses); ``patch_src`` / ``*_wb_pos`` index the
    previous / current layer's compact device outputs."""

    # h^{l-1} gather space ("h", l): hits read device slots, misses stage
    h_hit_pos: np.ndarray
    h_hit_slots: np.ndarray
    h_miss_pos: np.ndarray
    h_miss_src: np.ndarray
    h_admit_midx: np.ndarray  # miss-buffer rows to install into fresh slots
    h_admit_slots: np.ndarray
    # device-side new-view patch (previous layer's still-resident outputs)
    patch_pos: np.ndarray
    patch_src: np.ndarray
    # state gather space ("s", l): a/nct/h_cur rows
    s_hit_pos: np.ndarray
    s_hit_slots: np.ndarray
    s_miss_pos: np.ndarray
    s_miss_src: np.ndarray
    # in-place slot refresh from this layer's kernel outputs
    s_wb_pos: np.ndarray
    s_wb_slots: np.ndarray
    hnext_wb_pos: np.ndarray
    hnext_wb_slots: np.ndarray


def _patch_positions(dst_keys: np.ndarray, src_rows: np.ndarray):
    """Workspace positions (and source indices) of the new-view patch —
    the same match :func:`_override_rows` performs on the host path, so
    the cached device patch is position-for-position identical."""
    idx = np.full(dst_keys.shape[0], -1, np.int64)
    _override_rows(idx, np.asarray(dst_keys, np.int64), src_rows,
                   np.arange(src_rows.shape[0], dtype=np.int64))
    pos = np.flatnonzero(idx >= 0).astype(np.int64)
    return pos, idx[pos]


def _cache_assemble(n_rows: int, dim: int, miss_pos: np.ndarray, miss_vals,
                    hit_pos: np.ndarray, hit_vals):
    """Device workspace assembly: scatter the staged cold misses and the
    cached hot rows into a zeroed ``[n_rows, dim]`` array.  Hit and miss
    positions partition the live rows (dead stacked-hybrid positions stay
    0.0, matching the host gather's zeroing), so the result is bitwise
    identical to the staged workspace it replaces."""
    out = jnp.zeros((n_rows, dim), jnp.float32)
    if miss_pos.size:
        out = out.at[miss_pos].set(miss_vals)
    if hit_pos.size:
        out = out.at[hit_pos].set(hit_vals)
    return out


@dataclasses.dataclass
class _LayerTransfer:
    """Plan-time (value-independent) compact transfer tables for one layer."""

    need_h: np.ndarray  # global ids of h^{l-1} rows the device needs
    srows: np.ndarray  # global ids of state rows updated (= out_rows live)
    e_src: np.ndarray  # remapped into need_h space
    e_dst: np.ndarray
    f_src: np.ndarray
    touch_rows_s: np.ndarray  # remapped into srows space
    f_rows_s: np.ndarray
    out_rows_s: np.ndarray
    f_rows_h: np.ndarray  # remapped into need_h space
    out_rows_h: np.ndarray
    deg_old_rows: np.ndarray  # [nh+1] compact degree tables (scratch slot)
    deg_new_rows: np.ndarray


@dataclasses.dataclass
class _OffloadPrep:
    """Host-side output of the planning phase for one batch."""

    plan: BatchPlan
    batch: UpdateBatch
    transfers: List[_LayerTransfer]
    cache_ops: Optional[List[_CacheLayerOps]] = None

    @property
    def n_inc_edges(self) -> int:
        return self.plan.total_inc_edges()

    @property
    def n_full_edges(self) -> int:
        return self.plan.total_full_edges()

    @property
    def n_out_rows(self) -> int:
        return self.plan.total_vertices()


class _DeferredWritebackMixin:
    """Deferred final-layer write-back + staging barrier shared by the
    host-resident backends.  ``dispatch`` leaves the last layer's (device →
    host) write-back pending — a :class:`StagingTicket` in async-staging
    mode (the worker performs the D2H and the scatter), the raw payload in
    sync mode — and ``flush`` completes it and **drains the staging
    worker**, re-raising any worker exception on the caller thread.  The
    orchestrator's next plan (and, async, even the next batch's gathers,
    queued behind the write-back) runs while the device still executes the
    final layer."""

    _pending = None
    _staging: Optional[HostStagingPipeline] = None
    _cache: Optional[HotRowCache] = None

    def flush(self) -> None:
        self.barrier_epoch += 1
        pending, self._pending = self._pending, None
        if pending is not None:
            if isinstance(pending, StagingTicket):
                pending.wait()
            else:
                self._final_writeback(pending)
        if self._staging is not None:
            self._staging.drain()

    def staging_snapshot(self) -> Optional[StagingStats]:
        return self._staging.stats.snapshot()

    def cache_snapshot(self) -> Optional[CacheStats]:
        return None if self._cache is None else self._cache.stats.snapshot()

    @property
    def async_staging(self) -> bool:
        return self._staging.async_mode

    def _cache_layer_ops(self, l: int, n: int, rows_h: np.ndarray,
                         rows_s: np.ndarray, prev_rows: np.ndarray,
                         deg: np.ndarray):
        """Shared per-layer cache planning for the host-resident
        substrates: the read splits for the ``("h", l)`` / ``("s", l)``
        spaces and the write-back slot refresh for ``("s", l)`` and
        ``("h", l+1)``.  ``prev_rows`` (the rows the batch wrote earlier —
        layer l-1's scatter set, or the feature vertices for l=0) are
        excluded from hits *and* staged-value admission: their cached
        slots were just refreshed with post-write values, while layer l's
        old view needs the pristine pre-batch rows (see the coherence
        notes in repro.serve.hotcache)."""
        cache = self._cache
        h_split = cache.plan_reads(("h", l), n, rows_h, deg[rows_h],
                                   exclude_rows=prev_rows)
        s_split = cache.plan_reads(("s", l), n, rows_s, deg[rows_s],
                                   admit=False)
        s_wb = cache.plan_writeback(("s", l), n, rows_s, deg[rows_s])
        if l + 1 < self.L:
            hn_wb = cache.plan_writeback(("h", l + 1), n, rows_s, deg[rows_s])
        else:  # h^L is never re-read through the cache
            hn_wb = (np.zeros(0, np.int64), np.zeros(0, np.int32))
        return h_split, s_split, s_wb, hn_wb

    def _gather_state_rows(self, arr: np.ndarray, rows: np.ndarray) -> np.ndarray:
        """Gather global state rows (flat host arrays; the sharded hybrid
        overrides with its per-shard block gather)."""
        return arr[rows]

    def _prewarm_cache(self, graph: CSRGraph) -> None:
        """Seed every cache row space from the base graph's top-degree rows
        before batch 0 (``CacheConfig.prewarm_rows``, ISSUE 9).

        Runs at construction time, after the initial full forward: the
        gathered values are the pristine base state, so the coherence
        invariant holds trivially.  Degree ties admit the smallest row id
        (stable argsort), keeping the seeded slot table — and every
        downstream hit/miss/eviction counter — deterministic."""
        cache = self._cache
        if cache is None or not cache.config.prewarm_rows:
            return
        k = min(int(cache.config.prewarm_rows), graph.n)
        deg = graph.in_degree().astype(np.int64)
        top = np.argsort(-deg, kind="stable")[:k].astype(np.int64)
        degs = deg[top].astype(np.float32)
        for l in range(self.L):
            cache.prewarm(("h", l), graph.n, top, degs,
                          {"h": self._gather_state_rows(self.h[l], top)})
            cache.prewarm(("s", l), graph.n, top, degs, {
                "a": self._gather_state_rows(self.a[l], top),
                "nct": self._gather_state_rows(self.nct[l], top),
                "h": self._gather_state_rows(self.h[l + 1], top),
            })

    def _cache_invalidate_feats(self, batch: UpdateBatch) -> np.ndarray:
        """Plan-time, value-independent invalidation for a batch's feature
        scatter (it rewrites h[0] rows outside the kernel write-back path);
        returns the feature rows as layer 0's exclusion set."""
        if batch.feat_vertices is not None and np.asarray(batch.feat_vertices).size:
            rows = np.asarray(batch.feat_vertices, np.int64)
            self._cache.invalidate(("h", 0), rows)
            return rows
        return np.zeros(0, np.int64)

    def _defer_final(self, payload) -> None:
        """Queue the final layer's write-back: on the worker (async) or as
        a raw pending payload completed inline at ``flush`` (sync)."""
        pipe = self._staging
        nb = (0 if payload is None or payload[-1] is None
              else sum(int(o.nbytes) for o in payload[-1]))
        if pipe.async_mode:
            self._pending = pipe.submit_writeback(
                partial(self._final_writeback, payload), nbytes=nb, tag="final")
        else:
            pipe.stats.staged_bytes += nb
            self._pending = payload


class OffloadBackend(_DeferredWritebackMixin, StateBackend):
    """NeutronRT-style out-of-memory embedding management (paper §V-B).

    The per-layer state (h, a, nct) lives as **host numpy**; per batch only
    the compact row sets the plan touches transfer to the device, the same
    `incremental_layer` kernel runs over compact arrays (the kernel is
    index-based, so a compact view with remapped indices is exactly
    equivalent), and all write-backs are grouped.  Host staging runs
    through a :class:`~repro.serve.staging.HostStagingPipeline`: pristine
    per-layer gathers prefetch on a background worker while the device
    computes the previous layer, write-back scatters retire there too, and
    the final layer's write-back (D2H included) is deferred entirely to
    the worker (``flush`` is the barrier) so batch-t+1 planning — and its
    gathers — overlap the device's execution of batch t's last layer.
    ``async_staging=False`` runs the identical staging jobs inline
    (bitwise-identical output; tests/test_staging.py)."""

    def __init__(self, model: GNNModel, params: Sequence[Params],
                 graph: CSRGraph, x: np.ndarray, async_staging: bool = True,
                 cache: Optional[HotRowCache] = None, staging_depth: int = 2):
        self.model = model
        self.params = list(params)
        self.L = len(self.params)
        self.x = np.asarray(x, np.float32)
        self.transfers = TransferStats()
        self._cache = cache
        self._staging = HostStagingPipeline(self.L, depth=staging_depth,
                                            async_mode=async_staging,
                                            name="offload")
        states = full_forward(model, params, jnp.asarray(self.x), graph)
        self.h: List[np.ndarray] = [self.x.copy()] + [np.array(s.h) for s in states]
        self.a: List[np.ndarray] = [np.array(s.a) for s in states]
        self.nct: List[np.ndarray] = [np.array(s.nct) for s in states]
        self._prewarm_cache(graph)

    @property
    def embeddings(self) -> np.ndarray:
        self.flush()
        return self.h[-1]

    def state_bytes(self) -> int:
        return (sum(a.nbytes for a in self.a) + sum(c.nbytes for c in self.nct)
                + sum(h.nbytes for h in self.h))

    def sync_arrays(self) -> list:
        return []  # flush() is the real barrier; state is host numpy

    def refresh(self, graph: CSRGraph) -> None:
        self.flush()
        states = full_forward(self.model, self.params, jnp.asarray(self.h[0]),
                              graph)
        self.h = [self.h[0]] + [np.array(s.h) for s in states]
        self.a = [np.array(s.a) for s in states]
        self.nct = [np.array(s.nct) for s in states]
        if self._cache is not None:  # every cached row may now be stale
            self._cache.invalidate_all()

    # ------------------------------------------------------------------ #
    # Serving API: host-numpy gather; flush() first so a deferred final
    # write-back can never be missed (a no-op at a version boundary — the
    # staging worker's queue is already drained, so reads never block it)
    # ------------------------------------------------------------------ #
    def snapshot_rows(self, rows: np.ndarray) -> np.ndarray:
        self.flush()
        return self.h[-1][np.asarray(rows, np.int64)]

    def changed_rows(self, prep: "_OffloadPrep") -> np.ndarray:
        return np.unique(prep.transfers[-1].srows)

    # ------------------------------------------------------------------ #
    # policy-execution primitives: direct host-numpy scatters (the
    # orchestrator flushes first, so no deferred write-back is in flight)
    # ------------------------------------------------------------------ #
    def apply_feature_updates(self, rows: np.ndarray, vals: np.ndarray) -> None:
        rows = np.asarray(rows, np.int64)
        self.h[0][rows] = np.asarray(vals, np.float32)
        if self._cache is not None:
            self._cache.invalidate(("h", 0), rows)

    def layer_input_host(self, l: int) -> np.ndarray:
        return self.h[l]

    def scatter_layer_rows(self, l: int, rows: np.ndarray, a_rows: np.ndarray,
                           nct_rows: np.ndarray, h_rows: np.ndarray) -> None:
        self.a[l][rows] = a_rows
        self.nct[l][rows] = nct_rows
        self.h[l + 1][rows] = h_rows
        if self._cache is not None:  # value-independent: keyed by rows only
            self._cache.invalidate(("s", l), rows)
            self._cache.invalidate(("h", l + 1), rows)

    # ------------------------------------------------------------------ #
    # planning phase (host only, value-independent)
    # ------------------------------------------------------------------ #
    def plan(self, g_old: CSRGraph, g_new: CSRGraph, batch: UpdateBatch,
             base_plan: Optional[BatchPlan] = None) -> _OffloadPrep:
        plan = (base_plan if base_plan is not None
                else build_plan(self.model, g_old, g_new, batch, self.L))
        n = g_old.n
        prev_rows = (
            np.asarray(batch.feat_vertices, np.int64)
            if batch.feat_vertices is not None and batch.feat_vertices.size
            else np.zeros(0, np.int64)
        )
        transfers: List[_LayerTransfer] = []
        for lp in plan.layers:
            need_h = np.unique(np.concatenate([
                lp.e_src[lp.e_mask].astype(np.int64),
                lp.e_dst[lp.e_mask].astype(np.int64),
                lp.f_src[lp.f_emask].astype(np.int64),
                lp.f_rows[lp.f_mask].astype(np.int64),
                lp.out_rows[lp.out_mask].astype(np.int64),
                prev_rows,
            ]))
            srows = lp.out_rows[lp.out_mask].astype(np.int64)
            nh, ns = need_h.shape[0], srows.shape[0]
            transfers.append(_LayerTransfer(
                need_h=need_h,
                srows=srows,
                e_src=_remap(lp.e_src, need_h, nh, n),
                e_dst=_remap(lp.e_dst, need_h, nh, n),
                f_src=_remap(lp.f_src, need_h, nh, n),
                touch_rows_s=_remap(lp.touch_rows, srows, ns, n),
                f_rows_s=_remap(lp.f_rows, srows, ns, n),
                out_rows_s=_remap(lp.out_rows, srows, ns, n),
                f_rows_h=_remap(lp.f_rows, need_h, nh, n),
                out_rows_h=_remap(lp.out_rows, need_h, nh, n),
                deg_old_rows=np.concatenate(
                    [plan.deg_old[need_h], [0.0]]).astype(np.float32),
                deg_new_rows=np.concatenate(
                    [plan.deg_new[need_h], [0.0]]).astype(np.float32),
            ))
            prev_rows = srows
        cache_ops = (self._plan_cache(plan, batch, transfers)
                     if self._cache is not None else None)
        return _OffloadPrep(plan=plan, batch=batch, transfers=transfers,
                            cache_ops=cache_ops)

    def _plan_cache(self, plan: BatchPlan, batch: UpdateBatch,
                    transfers: List[_LayerTransfer]) -> List[_CacheLayerOps]:
        """Plan-time residency split for every layer (host only,
        value-independent — it touches slot metadata and degree tables,
        never row values).  Runs after dispatch(t-1) returned, so all of
        batch t-1's cache-store updates are already recorded."""
        cache = self._cache
        n = plan.deg_old.shape[0] - 1  # deg tables carry a scratch slot
        deg = plan.deg_new
        cache.decay_tick()
        prev_rows = self._cache_invalidate_feats(batch)
        ops: List[_CacheLayerOps] = []
        for l, tr in enumerate(transfers):
            h_split, s_split, s_wb, hn_wb = self._cache_layer_ops(
                l, n, tr.need_h, tr.srows, prev_rows, deg)
            patch_pos, patch_src = _patch_positions(tr.need_h, prev_rows)
            ops.append(_CacheLayerOps(
                h_hit_pos=h_split.hit_pos, h_hit_slots=h_split.hit_slots,
                h_miss_pos=h_split.miss_pos, h_miss_src=h_split.miss_rows,
                h_admit_midx=h_split.admit_midx,
                h_admit_slots=h_split.admit_slots,
                patch_pos=patch_pos, patch_src=patch_src,
                s_hit_pos=s_split.hit_pos, s_hit_slots=s_split.hit_slots,
                s_miss_pos=s_split.miss_pos, s_miss_src=s_split.miss_rows,
                s_wb_pos=s_wb[0], s_wb_slots=s_wb[1],
                hnext_wb_pos=hn_wb[0], hnext_wb_slots=hn_wb[1]))
            prev_rows = tr.srows
        return ops

    # ------------------------------------------------------------------ #
    def dispatch(self, prep: _OffloadPrep) -> None:
        """Run all layers through the staging pipeline (see
        :mod:`repro.serve.staging` for the schedule).  Pristine gathers for
        every layer enqueue up front — the in-order worker runs them after
        any still-in-flight write-back of the previous batch and before
        this batch's own write-backs, so each layer's staged ``h_old`` view
        is exactly the pre-batch state and the ``h_new`` view is the same
        rows patched with the previous layer's freshly computed outputs.
        While the device computes layer *l*, the worker gathers layer *l+1*
        and retires layer *l-1*'s scatter; the final layer's grouped
        write-back (the paper's "group all updated embeddings and write
        them back in parallel") defers entirely to the worker so the
        orchestrator's next plan overlaps the device's last-layer
        execution."""
        pipe = self._staging
        if not pipe.async_mode:
            self.flush()  # inline staging jobs read host state directly
        pipe.begin_batch()
        batch = prep.batch

        # layer-0 "previous layer outputs" = the batch's feature updates
        if batch.feat_vertices is not None and batch.feat_vertices.size:
            prev_rows = np.asarray(batch.feat_vertices, np.int64)
            prev_new = np.asarray(batch.feat_values, np.float32)
        else:
            prev_rows = np.zeros(0, np.int64)
            prev_new = np.zeros((0, self.h[0].shape[1]), np.float32)

        ops = prep.cache_ops
        tickets = [
            pipe.submit_gather(partial(self._gather_layer, l, tr,
                                       pipe.buffers(l),
                                       None if ops is None else ops[l]),
                               tag=l)
            for l, tr in enumerate(prep.transfers)
        ]
        if prev_rows.size:
            # persist the feature update into h[0]; the in-order queue puts
            # it after gather(0)'s pristine read and before the next batch
            pipe.submit_writeback(
                partial(self._scatter_feats, prev_rows, prev_new),
                nbytes=int(prev_new.nbytes), tag="feat")

        # cached path: the previous layer's outputs stay device-resident so
        # the new-view patch happens on device instead of via staged h_new
        prev_dev = jnp.asarray(prev_new) if prev_rows.size else None
        final = None
        for l, (lp, tr) in enumerate(zip(prep.plan.layers, prep.transfers)):
            staged = pipe.wait_gather(tickets[l])
            if ops is None:
                outs = self._layer_exec(l, lp, tr, staged, prev_rows, prev_new)
            else:
                outs = self._layer_exec_cached(l, lp, tr, staged, ops[l],
                                               prev_dev)
                prev_dev = None if outs is None else outs[2]
            if l + 1 < self.L:
                if outs is None:  # empty layer: nothing written back
                    prev_rows = tr.srows
                    prev_new = np.zeros((0, self.h[l + 1].shape[1]), np.float32)
                else:
                    a_np, nct_np, h_np = pipe.wait_device(outs)
                    pipe.submit_writeback(
                        partial(self._writeback_host, l, tr.srows,
                                a_np, nct_np, h_np),
                        nbytes=int(a_np.nbytes + nct_np.nbytes + h_np.nbytes),
                        tag=l)
                    prev_rows, prev_new = tr.srows, h_np
            else:
                final = (l, tr.srows, outs)
        self._defer_final(final)

    def _scatter_feats(self, rows: np.ndarray, vals: np.ndarray) -> None:
        self.h[0][rows] = vals

    def _gather_layer(self, l: int, tr: _LayerTransfer, bufs,
                      cops: Optional[_CacheLayerOps] = None):
        """Staging-worker job: pristine gather of layer ``l``'s compact
        rows into the double-buffered staging set (``h_new`` starts as a
        copy of ``h_old``; the caller patches it before H2D).  With the
        hot-row cache enabled, only the plan's cold misses stage — hits
        are served from device slots at exec and no ``h_new`` view stages
        at all (the new-view patch happens on device)."""
        need_h, srows = tr.need_h, tr.srows
        nh, ns = need_h.shape[0], srows.shape[0]
        if nh == 0 and ns == 0:
            return None
        if cops is not None:
            nh_m, ns_m = cops.h_miss_src.shape[0], cops.s_miss_src.shape[0]
            h_old = bufs.take("h_old", nh_m, self.h[l].shape[1:])
            np.take(self.h[l], cops.h_miss_src, axis=0, out=h_old)
            a_rows = bufs.take("a", ns_m, self.a[l].shape[1:])
            np.take(self.a[l], cops.s_miss_src, axis=0, out=a_rows)
            nct_rows = bufs.take("nct", ns_m, self.nct[l].shape[1:])
            np.take(self.nct[l], cops.s_miss_src, axis=0, out=nct_rows)
            h_cur = bufs.take("h_cur", ns_m, self.h[l + 1].shape[1:])
            np.take(self.h[l + 1], cops.s_miss_src, axis=0, out=h_cur)
            return {"h_old": h_old, "a": a_rows, "nct": nct_rows,
                    "h_cur": h_cur}
        h_old = bufs.take("h_old", nh, self.h[l].shape[1:])
        np.take(self.h[l], need_h, axis=0, out=h_old)
        h_new = bufs.take("h_new", nh, self.h[l].shape[1:])
        np.copyto(h_new, h_old)
        a_rows = bufs.take("a", ns, self.a[l].shape[1:])
        np.take(self.a[l], srows, axis=0, out=a_rows)
        nct_rows = bufs.take("nct", ns, self.nct[l].shape[1:])
        np.take(self.nct[l], srows, axis=0, out=nct_rows)
        h_cur = bufs.take("h_cur", ns, self.h[l + 1].shape[1:])
        np.take(self.h[l + 1], srows, axis=0, out=h_cur)
        return {"h_old": h_old, "h_new": h_new, "a": a_rows,
                "nct": nct_rows, "h_cur": h_cur}

    def _layer_exec(self, l: int, lp: LayerPlan, tr: _LayerTransfer, staged,
                    prev_rows: np.ndarray, prev_new: np.ndarray):
        """Patch the staged new-view rows with the previous layer's fresh
        outputs, ship the layer in ONE device_put, dispatch the kernel."""
        if staged is None:
            return None
        need_h, srows = tr.need_h, tr.srows
        nh, ns = need_h.shape[0], srows.shape[0]
        h_old_rows, h_new_rows = staged["h_old"], staged["h_new"]
        _override_rows(h_new_rows, need_h, prev_rows, prev_new)
        a_rows, nct_rows, h_cur_rows = staged["a"], staged["nct"], staged["h_cur"]

        self.transfers.rows_up += 2 * nh + 3 * ns
        self.transfers.bytes_up += (2 * h_new_rows.nbytes + a_rows.nbytes
                                    + nct_rows.nbytes + h_cur_rows.nbytes)

        # one batched H2D transfer for the whole layer (packed-plan analogue)
        dev = jax.device_put((
            h_old_rows, h_new_rows, tr.deg_old_rows, tr.deg_new_rows,
            a_rows, nct_rows, h_cur_rows,
            tr.e_src, tr.e_dst, lp.e_rowidx, lp.e_sign, lp.e_use_new,
            lp.e_w, lp.e_t, lp.e_mask,
            tr.touch_rows_s, lp.touch_mask,
            tr.f_rows_s, lp.f_mask, tr.f_src, lp.f_rowidx, lp.f_w,
            lp.f_t, lp.f_emask,
            tr.out_rows_s, lp.out_mask, tr.f_rows_h, tr.out_rows_h,
        ))
        (h_old_d, h_new_d, deg_old_d, deg_new_d, a_d, nct_d, h_cur_d,
         e_src, e_dst, e_rowidx, e_sign, e_use_new, e_w, e_t, e_mask,
         touch_rows_s, touch_mask, f_rows_s, f_mask, f_src, f_rowidx, f_w,
         f_t, f_emask, out_rows_s, out_mask, f_rows_h, out_rows_h) = dev

        return incremental_layer(
            self.model, self.params[l],
            with_scratch(h_old_d), with_scratch(h_new_d),
            deg_old_d, deg_new_d, a_d, nct_d, h_cur_d,
            e_src, e_dst, e_rowidx, e_sign, e_use_new, e_w, e_t, e_mask,
            touch_rows_s, touch_mask,
            f_rows_s, f_mask, f_src, f_rowidx, f_w, f_t, f_emask,
            out_rows_s, out_mask,
            f_rows_h=f_rows_h, out_rows_h=out_rows_h,
        )

    def _layer_exec_cached(self, l: int, lp: LayerPlan, tr: _LayerTransfer,
                           staged, cops: _CacheLayerOps, prev_dev):
        """Cached variant of :meth:`_layer_exec`: assemble the device
        workspaces from staged cold misses + cached hot slots, patch the
        new view on device from the previous layer's still-resident
        outputs, run the identical kernel, then refresh written slots in
        place from the kernel outputs (bitwise-equal to the uncached path
        — hits/misses partition the rows, and the float32 D2H→H2D
        round-trip the uncached patch takes is value-preserving)."""
        if staged is None:
            return None
        cache = self._cache
        nh, ns = tr.need_h.shape[0], tr.srows.shape[0]
        h_old_m, a_m, nct_m, h_cur_m = (staged["h_old"], staged["a"],
                                        staged["nct"], staged["h_cur"])
        self.transfers.rows_up += h_old_m.shape[0] + 3 * a_m.shape[0]
        self.transfers.bytes_up += (h_old_m.nbytes + a_m.nbytes
                                    + nct_m.nbytes + h_cur_m.nbytes)

        dev = jax.device_put((
            h_old_m, a_m, nct_m, h_cur_m,
            tr.deg_old_rows, tr.deg_new_rows,
            tr.e_src, tr.e_dst, lp.e_rowidx, lp.e_sign, lp.e_use_new,
            lp.e_w, lp.e_t, lp.e_mask,
            tr.touch_rows_s, lp.touch_mask,
            tr.f_rows_s, lp.f_mask, tr.f_src, lp.f_rowidx, lp.f_w,
            lp.f_t, lp.f_emask,
            tr.out_rows_s, lp.out_mask, tr.f_rows_h, tr.out_rows_h,
        ))
        (h_old_md, a_md, nct_md, h_cur_md, deg_old_d, deg_new_d,
         e_src, e_dst, e_rowidx, e_sign, e_use_new, e_w, e_t, e_mask,
         touch_rows_s, touch_mask, f_rows_s, f_mask, f_src, f_rowidx, f_w,
         f_t, f_emask, out_rows_s, out_mask, f_rows_h, out_rows_h) = dev

        d_in = self.h[l].shape[1]
        h_old_d = _cache_assemble(
            nh, d_in, cops.h_miss_pos, h_old_md, cops.h_hit_pos,
            cache.store(("h", l), "h", (d_in,))[cops.h_hit_slots]
            if cops.h_hit_pos.size else None)
        # install freshly admitted rows from the staged pristine values
        if cops.h_admit_midx.size:
            cache.update_store(("h", l), "h", cops.h_admit_slots,
                               h_old_md[cops.h_admit_midx])
        if cops.patch_pos.size:
            h_new_d = h_old_d.at[cops.patch_pos].set(prev_dev[cops.patch_src])
        else:
            h_new_d = h_old_d

        da, dn, dc = (self.a[l].shape[1], self.nct[l].shape[1],
                      self.h[l + 1].shape[1])
        s_key = ("s", l)
        a_d = _cache_assemble(
            ns, da, cops.s_miss_pos, a_md, cops.s_hit_pos,
            cache.store(s_key, "a", (da,))[cops.s_hit_slots]
            if cops.s_hit_pos.size else None)
        nct_d = _cache_assemble(
            ns, dn, cops.s_miss_pos, nct_md, cops.s_hit_pos,
            cache.store(s_key, "nct", (dn,))[cops.s_hit_slots]
            if cops.s_hit_pos.size else None)
        h_cur_d = _cache_assemble(
            ns, dc, cops.s_miss_pos, h_cur_md, cops.s_hit_pos,
            cache.store(s_key, "h", (dc,))[cops.s_hit_slots]
            if cops.s_hit_pos.size else None)

        outs = incremental_layer(
            self.model, self.params[l],
            with_scratch(h_old_d), with_scratch(h_new_d),
            deg_old_d, deg_new_d, a_d, nct_d, h_cur_d,
            e_src, e_dst, e_rowidx, e_sign, e_use_new, e_w, e_t, e_mask,
            touch_rows_s, touch_mask,
            f_rows_s, f_mask, f_src, f_rowidx, f_w, f_t, f_emask,
            out_rows_s, out_mask,
            f_rows_h=f_rows_h, out_rows_h=out_rows_h,
        )
        # in-place slot refresh from the kernel outputs: hot written rows
        # skip the D2H→host→H2D re-staging round-trip on the next batch
        if cops.s_wb_pos.size:
            cache.update_store(s_key, "a", cops.s_wb_slots,
                               outs[0][cops.s_wb_pos])
            cache.update_store(s_key, "nct", cops.s_wb_slots,
                               outs[1][cops.s_wb_pos])
            cache.update_store(s_key, "h", cops.s_wb_slots,
                               outs[2][cops.s_wb_pos])
        if cops.hnext_wb_pos.size:
            cache.update_store(("h", l + 1), "h", cops.hnext_wb_slots,
                               outs[2][cops.hnext_wb_pos])
        return outs

    def _writeback_host(self, l: int, srows: np.ndarray, a_new: np.ndarray,
                        nct_new: np.ndarray, h_new: np.ndarray) -> None:
        """Grouped host scatter of one layer's written-back rows (runs on
        the staging worker in async mode)."""
        self.a[l][srows] = a_new
        self.nct[l][srows] = nct_new
        self.h[l + 1][srows] = h_new
        self.transfers.rows_down += 3 * srows.shape[0]
        self.transfers.bytes_down += int(a_new.nbytes + nct_new.nbytes + h_new.nbytes)

    def _final_writeback(self, payload) -> None:
        """Final layer's D2H + scatter — runs on the staging worker (async)
        or at ``flush`` (sync escape hatch)."""
        if payload is None:
            return
        l, srows, outs = payload
        if outs is None:
            return
        a_new, nct_new, h_new = (np.asarray(o) for o in outs)
        self._writeback_host(l, srows, a_new, nct_new, h_new)


# ====================================================================== #
# ShardBackend — row-sharded device state over the repro.dist mesh
# ====================================================================== #
class _StreamMeshMixin:
    """Shared 1-D stream-mesh setup for the two row-sharded backends:
    resolves (mesh, axis, S, rows_per) and the state/plan/replicated
    NamedShardings from one ``ShardingConfig``."""

    def _init_stream_mesh(self, graph: CSRGraph, mesh, num_shards, shcfg) -> None:
        from repro.dist.sharding import ShardingConfig, stream_mesh, stream_state_specs

        self.shcfg = shcfg or ShardingConfig()
        self.mesh = mesh if mesh is not None else stream_mesh(num_shards, self.shcfg)
        self.axis = tuple(self.mesh.axis_names)[0]
        self.S = int(self.mesh.shape[self.axis])
        self.rows_per = shard_rows(graph.n, self.S)
        specs = stream_state_specs(self.mesh, self.shcfg)
        self._state_sh = specs["state"]
        self._plan_sh = specs["plan"]
        self._rep_sh = specs["replicated"]


class ShardBackend(_StreamMeshMixin, StateBackend):
    """Scratch-extended per-layer state block row-partitioned over a 1-D
    ``repro.dist`` mesh as stacked ``[S, rows_per+1, ·]`` arrays; each
    batch's plan is partitioned per shard at plan time
    (:func:`repro.core.affected.shard_plan`) and runs as one donated,
    shard_map'd L-layer step (:func:`repro.core.incremental.sharded_step_fn`).

    The per-layer halo exchange is governed by
    :class:`~repro.dist.sharding.CommsConfig` (ISSUE 10): ``halo="psum"``
    broadcasts the global frontier (per-device bytes scale with the global
    frontier); ``"ppermute"`` — the ``"auto"`` default on any multi-shard
    mesh — runs the plan-time per-consumer rotation schedules, so each
    shard's traffic scales with its own halo.  Both modes are bitwise-equal
    (pinned by tests/test_comms.py)."""

    def __init__(
        self,
        model: GNNModel,
        params: Sequence[Params],
        graph: CSRGraph,
        x: np.ndarray,
        mesh=None,
        num_shards: Optional[int] = None,
        shcfg=None,
        comms=None,
        use_pallas_delta: Optional[bool] = None,
    ):
        self.model = model
        self.L = len(list(params))
        self.n = graph.n
        self.comms = _resolve_backend_comms(comms, use_pallas_delta,
                                            "ShardBackend")
        self.use_pallas_delta = self.comms.use_pallas_delta
        self._init_stream_mesh(graph, mesh, num_shards, shcfg)
        # "auto" collapses once per backend: the resolved mode is a static
        # trace key, so it must not flip batch to batch
        self.halo_mode = self.comms.resolve_halo(self.S)
        self._params_host = list(params)
        # step inputs must all live on the mesh: replicate params once
        self.params = jax.device_put(tuple(params), self._rep_sh)
        self._step = sharded_step_fn(model, self.mesh, self.axis)
        self.hwm = BucketHysteresis()
        self.halo_rows_total = 0
        self._comms_rows_sent = 0
        self._comms_bytes = 0
        self._x_host = np.asarray(x, np.float32)
        self._init_state(graph)

    # ------------------------------------------------------------------ #
    # state: stacked [S, rows_per+1, ·] blocks (last local row = scratch)
    # ------------------------------------------------------------------ #
    def _to_blocks(self, arr) -> jax.Array:
        flat = np.asarray(arr, np.float32)
        out = np.zeros((self.S, self.rows_per + 1) + flat.shape[1:], np.float32)
        for s in range(self.S):
            lo = s * self.rows_per
            hi = min(self.n, lo + self.rows_per)
            if hi > lo:
                out[s, : hi - lo] = flat[lo:hi]
        return jax.device_put(out, self._state_sh)

    def _from_blocks(self, blocks: jax.Array) -> np.ndarray:
        arr = np.asarray(blocks)[:, : self.rows_per]
        return arr.reshape(self.S * self.rows_per, *arr.shape[2:])[: self.n]

    def _init_state(self, graph: CSRGraph, x: Optional[np.ndarray] = None) -> None:
        if x is None:
            x = self._x_host
        states = full_forward(self.model, self._params_host,
                              jnp.asarray(x), graph)
        self._h: List[jax.Array] = [self._to_blocks(x)] + [
            self._to_blocks(s.h) for s in states
        ]
        self._a: List[jax.Array] = [self._to_blocks(s.a) for s in states]
        self._nct: List[jax.Array] = [self._to_blocks(s.nct) for s in states]

    def refresh(self, graph: CSRGraph) -> None:
        """Full recomputation (drift reset) over the current snapshot and the
        *current* features — layer-0 feature updates applied during the
        stream live in the h[0] blocks, not in the construction-time x."""
        self._init_state(graph, self._from_blocks(self._h[0]))

    @property
    def embeddings(self) -> np.ndarray:
        return self._from_blocks(self._h[-1])

    @property
    def h(self) -> List[np.ndarray]:
        return [self._from_blocks(v) for v in self._h]

    @property
    def a(self) -> List[np.ndarray]:
        return [self._from_blocks(v) for v in self._a]

    @property
    def nct(self) -> List[np.ndarray]:
        return [self._from_blocks(v) for v in self._nct]

    def state_bytes(self) -> int:
        return sum(int(np.prod(v.shape)) * 4 for v in (*self._h, *self._a, *self._nct))

    def sync_arrays(self) -> list:
        return [*self._h, *self._a, *self._nct]

    # ------------------------------------------------------------------ #
    # Serving API: one device gather over the stacked blocks — row g lives
    # at block [g // rows_per, g % rows_per] (scratch row is never read)
    # ------------------------------------------------------------------ #
    def snapshot_rows(self, rows: np.ndarray) -> np.ndarray:
        r = np.asarray(rows, np.int64)
        return np.asarray(self._h[-1][r // self.rows_per, r % self.rows_per])

    def changed_rows(self, prep: ShardedPlan) -> np.ndarray:
        return prep.out_rows_final

    # ------------------------------------------------------------------ #
    # policy-execution primitives: scatters round-trip through the host
    # (blocks → numpy → device_put with the state sharding) — a policy
    # batch is already a synchronous full/chunked pass, so the O(V) copy
    # is dominated by the recompute it accompanies
    # ------------------------------------------------------------------ #
    @property
    def host_params(self) -> List[Params]:
        return self._params_host  # .params is device-replicated on the mesh

    def _scatter_blocks(self, blocks: jax.Array, rows: np.ndarray,
                        vals: np.ndarray) -> jax.Array:
        host = np.array(blocks)  # np.asarray of a device array is read-only
        host[rows // self.rows_per, rows % self.rows_per] = vals
        return jax.device_put(host, self._state_sh)

    def apply_feature_updates(self, rows: np.ndarray, vals: np.ndarray) -> None:
        self._h[0] = self._scatter_blocks(
            self._h[0], np.asarray(rows, np.int64), np.asarray(vals, np.float32))

    def layer_input_host(self, l: int) -> np.ndarray:
        return self._from_blocks(self._h[l])

    def scatter_layer_rows(self, l: int, rows: np.ndarray, a_rows: np.ndarray,
                           nct_rows: np.ndarray, h_rows: np.ndarray) -> None:
        r = np.asarray(rows, np.int64)
        self._a[l] = self._scatter_blocks(self._a[l], r, a_rows)
        self._nct[l] = self._scatter_blocks(self._nct[l], r, nct_rows)
        self._h[l + 1] = self._scatter_blocks(self._h[l + 1], r, h_rows)

    # ------------------------------------------------------------------ #
    def plan(self, g_old: CSRGraph, g_new: CSRGraph, batch: UpdateBatch,
             base_plan: Optional[BatchPlan] = None) -> ShardedPlan:
        plan = (base_plan if base_plan is not None
                else build_plan(self.model, g_old, g_new, batch, self.L))
        return shard_plan(plan, self.S, batch.feat_vertices, batch.feat_values,
                          hwm=self.hwm, pallas=self.use_pallas_delta,
                          halo_mode=self.halo_mode,
                          pair_hysteresis=self.comms.pair_capacity_hysteresis)

    def comms_snapshot(self) -> CommsStats:
        return CommsStats(halo_rows_sent=self._comms_rows_sent,
                          halo_bytes=self._comms_bytes)

    def dispatch(self, sp: ShardedPlan) -> None:
        """One sharded device_put (each device gets only its plan slice),
        one shard_map'd fused-step dispatch."""
        idx_sh, flt_sh, msk_sh, pallas_sh, comms_sh = jax.device_put(
            (sp.idx_sh, sp.flt_sh, sp.msk_sh, sp.pallas_sh or (),
             sp.comms_sh or ()), self._plan_sh
        )
        fv = sp.feat_vals if sp.feat_vals is not None else np.zeros(
            (0, self._x_host.shape[1]), np.float32
        )
        idx_rep, msk_rep, feat_vals = jax.device_put(
            (sp.idx_rep, sp.msk_rep, fv), self._rep_sh
        )
        with warnings.catch_warnings():
            # donation is a TPU/GPU aliasing optimization; CPU jit ignores it
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable"
            )
            # plan-derived halo traffic: each delivered row carries its
            # old+new previous-layer views (the concatenated halo payload)
            for l, rows_l in enumerate(sp.comms_rows or ()):
                self._comms_rows_sent += rows_l
                self._comms_bytes += rows_l * 2 * int(self._h[l].shape[-1]) * 4
            hs, as_, ncts = self._step(
                sp.layout, self.params,
                tuple(self._h), tuple(self._a), tuple(self._nct),
                idx_sh, flt_sh, msk_sh, idx_rep, msk_rep, feat_vals, pallas_sh,
                comms_sh,
            )
        self._h = list(hs)
        self._a = list(as_)
        self._nct = list(ncts)
        self.halo_rows_total += sp.n_halo_rows


# ====================================================================== #
# ShardedOffloadBackend — the sharded offload hybrid (§V-B at mesh scale)
# ====================================================================== #
@dataclasses.dataclass
class _HybridPrep:
    """Host-side output of hybrid planning for one batch."""

    plan: BatchPlan
    batch: UpdateBatch
    layers: List[HybridLayerPlan]
    cache_ops: Optional[List[_CacheLayerOps]] = None

    @property
    def n_inc_edges(self) -> int:
        return self.plan.total_inc_edges()

    @property
    def n_full_edges(self) -> int:
        return self.plan.total_full_edges()

    @property
    def n_out_rows(self) -> int:
        return self.plan.total_vertices()


class ShardedOffloadBackend(_StreamMeshMixin, _DeferredWritebackMixin, StateBackend):
    """Row sharding × host-resident state: the full NeutronRT GPU-CPU
    co-processing story at mesh scale (ROADMAP "Sharded offload hybrid").

    Every shard keeps **only its own row block** of the per-layer state
    host-resident (stacked ``[S, rows_per, ·]`` numpy).  Per batch and
    layer, the plan is partitioned by destination-row owner (scatters stay
    owner-local) and each shard stages a compact ``[halo | local]``
    workspace to its device: the rows it needs but does not own (the halo)
    are gathered from the other shards' *host* blocks — the host is the
    exchange medium, so no device collective runs — together with its own
    affected rows.  Device residency is therefore O(per-shard affected
    subgraph), never O(V): the persistent state never touches HBM.

    Under ``CommsConfig(halo="ppermute")`` (the ``"auto"`` default on any
    multi-shard mesh) the uncached path additionally takes the
    **device-served fast path** (ISSUE 10): the rows of each layer's
    gather set that the previous layer just wrote are split out at plan
    time (``HybridLayerPlan.patch_pos``/``patch_src``) and patched on
    device from its still-resident outputs, so the staged ``h_new``
    buffer — a host-derived copy of ``h_old`` outside those rows — never
    stages at all.  Bitwise-equal to the staged path (the pristine-gather
    contract holds because halo rows are never written by the previous
    layer's owner-local scatter); pinned by tests/test_comms.py.

    The device step is one shard_map'd compact layer over the stacked
    staging buffers (:func:`repro.core.incremental.hybrid_layer_step_fn`),
    L dispatches per batch.  Host staging (the per-shard gathers and the
    write-back scatters — the dominant host cost at mesh scale) runs
    through the same :class:`~repro.serve.staging.HostStagingPipeline` as
    the flat offload backend: layer *l+1*'s gathers and layer *l-1*'s
    scatters overlap the device's compute of layer *l*, and the final
    layer's grouped write-back (D2H included) defers to the worker
    (``flush`` barrier) for plan/execute overlap.  ``async_staging=False``
    runs the identical jobs inline (bitwise-identical output)."""

    def __init__(
        self,
        model: GNNModel,
        params: Sequence[Params],
        graph: CSRGraph,
        x: np.ndarray,
        mesh=None,
        num_shards: Optional[int] = None,
        shcfg=None,
        async_staging: bool = True,
        cache: Optional[HotRowCache] = None,
        staging_depth: int = 2,
        comms=None,
    ):
        self.model = model
        self.params = list(params)
        self.L = len(self.params)
        self.n = graph.n
        self.comms = _resolve_backend_comms(comms, None,
                                            "ShardedOffloadBackend")
        self._init_stream_mesh(graph, mesh, num_shards, shcfg)
        self.halo_mode = self.comms.resolve_halo(self.S)
        self._params_dev = jax.device_put(tuple(params), self._rep_sh)
        self._step = hybrid_layer_step_fn(model, self.mesh, self.axis)
        self.hwm = BucketHysteresis()
        self.transfers = TransferStats()
        self._cache = cache
        self._staging = HostStagingPipeline(self.L, depth=staging_depth,
                                            async_mode=async_staging,
                                            name="hybrid")
        # caller (rows_up) and staging worker (rows_down) both touch the
        # per-shard accumulators — serialize the read-modify-write updates
        self._acc_lock = threading.Lock()
        # per-shard H2D+D2H row volume (the hybrid's scaling metric: each
        # shard's traffic is bounded by its own affected subgraph)
        self.per_shard_rows = np.zeros(self.S, np.int64)
        # peak bytes simultaneously staged on the mesh for one layer step —
        # the backend's entire HBM footprint (state is host-resident)
        self.peak_device_bytes = 0
        # plan-derived halo traffic (ISSUE 10): rows a shard gathers but
        # does not own, crossing through the exchange medium
        self._comms_rows_sent = 0
        self._comms_bytes = 0
        self._init_state(graph, np.asarray(x, np.float32))
        self._prewarm_cache(graph)

    # ------------------------------------------------------------------ #
    # state: host-resident per-shard row blocks [S, rows_per, ·]
    # ------------------------------------------------------------------ #
    def _gather_state_rows(self, arr: np.ndarray, rows: np.ndarray) -> np.ndarray:
        return self._gather_rows(arr, rows)

    def _to_blocks(self, arr: np.ndarray) -> np.ndarray:
        flat = np.asarray(arr, np.float32)
        out = np.zeros((self.S, self.rows_per) + flat.shape[1:], np.float32)
        for s in range(self.S):
            lo = s * self.rows_per
            hi = min(self.n, lo + self.rows_per)
            if hi > lo:
                out[s, : hi - lo] = flat[lo:hi]
        return out

    def _from_blocks(self, blocks: np.ndarray) -> np.ndarray:
        return blocks.reshape(self.S * self.rows_per, *blocks.shape[2:])[: self.n]

    def _gather_rows(self, blocks: np.ndarray, rows: np.ndarray) -> np.ndarray:
        """Gather global rows out of the per-shard host blocks."""
        return blocks[rows // self.rows_per, rows % self.rows_per]

    def _scatter_rows(self, blocks: np.ndarray, rows: np.ndarray,
                      vals: np.ndarray) -> None:
        blocks[rows // self.rows_per, rows % self.rows_per] = vals

    def _init_state(self, graph: CSRGraph, x: Optional[np.ndarray] = None) -> None:
        if x is None:
            x = self._from_blocks(self.h[0])
        states = full_forward(self.model, self.params, jnp.asarray(x), graph)
        self.h: List[np.ndarray] = [self._to_blocks(x)] + [
            self._to_blocks(np.asarray(s.h)) for s in states
        ]
        self.a: List[np.ndarray] = [self._to_blocks(np.asarray(s.a)) for s in states]
        self.nct: List[np.ndarray] = [self._to_blocks(np.asarray(s.nct)) for s in states]

    def refresh(self, graph: CSRGraph) -> None:
        self.flush()
        self._init_state(graph)
        if self._cache is not None:  # every cached row may now be stale
            self._cache.invalidate_all()

    @property
    def embeddings(self) -> np.ndarray:
        self.flush()
        return self._from_blocks(self.h[-1])

    def state_bytes(self) -> int:
        return sum(v.nbytes for v in (*self.h, *self.a, *self.nct))

    def sync_arrays(self) -> list:
        return []  # flush() is the real barrier; state is host numpy

    # ------------------------------------------------------------------ #
    # Serving API: flush() first so the worker's deferred final write-back
    # can never be missed (a no-op at a version boundary), then gather from
    # the per-shard host blocks
    # ------------------------------------------------------------------ #
    def snapshot_rows(self, rows: np.ndarray) -> np.ndarray:
        self.flush()
        return self._gather_rows(self.h[-1], np.asarray(rows, np.int64))

    def changed_rows(self, prep: _HybridPrep) -> np.ndarray:
        tr = prep.layers[-1]
        return np.unique(tr.srows[tr.srows_mask].astype(np.int64))

    # ------------------------------------------------------------------ #
    # policy-execution primitives: scatters into the per-shard host blocks
    # (the orchestrator flushes first, so the staging worker is drained)
    # ------------------------------------------------------------------ #
    def apply_feature_updates(self, rows: np.ndarray, vals: np.ndarray) -> None:
        rows = np.asarray(rows, np.int64)
        self._scatter_rows(self.h[0], rows, np.asarray(vals, np.float32))
        if self._cache is not None:
            self._cache.invalidate(("h", 0), rows)

    def layer_input_host(self, l: int) -> np.ndarray:
        return self._from_blocks(self.h[l])

    def scatter_layer_rows(self, l: int, rows: np.ndarray, a_rows: np.ndarray,
                           nct_rows: np.ndarray, h_rows: np.ndarray) -> None:
        r = np.asarray(rows, np.int64)
        self._scatter_rows(self.a[l], r, a_rows)
        self._scatter_rows(self.nct[l], r, nct_rows)
        self._scatter_rows(self.h[l + 1], r, h_rows)
        if self._cache is not None:  # value-independent: keyed by rows only
            self._cache.invalidate(("s", l), r)
            self._cache.invalidate(("h", l + 1), r)

    # ------------------------------------------------------------------ #
    # planning phase (host only, value-independent)
    # ------------------------------------------------------------------ #
    def plan(self, g_old: CSRGraph, g_new: CSRGraph, batch: UpdateBatch,
             base_plan: Optional[BatchPlan] = None) -> _HybridPrep:
        plan = (base_plan if base_plan is not None
                else build_plan(self.model, g_old, g_new, batch, self.L))
        hp = hybrid_plan(plan, self.S, hwm=self.hwm,
                         feat_vertices=batch.feat_vertices,
                         halo_mode=self.halo_mode)
        cache_ops = (self._plan_cache(plan, batch, hp.layers)
                     if self._cache is not None else None)
        return _HybridPrep(plan=plan, batch=batch, layers=hp.layers,
                           cache_ops=cache_ops)

    def comms_snapshot(self) -> CommsStats:
        return CommsStats(halo_rows_sent=self._comms_rows_sent,
                          halo_bytes=self._comms_bytes)

    def _plan_cache(self, plan: BatchPlan, batch: UpdateBatch,
                    layers: List[HybridLayerPlan]) -> List[_CacheLayerOps]:
        """Plan-time residency split over the stacked ``[S, cap]`` hybrid
        workspaces.  Cache keys are global row ids (a hot halo row is
        cached once, served to every shard that stages it); all positions
        are flattened ``[S·cap]`` indices so the cached exec scatters
        straight into the flat workspace view."""
        cache = self._cache
        n = plan.deg_old.shape[0] - 1  # deg tables carry a scratch slot
        deg = plan.deg_new
        cache.decay_tick()
        prev_rows = self._cache_invalidate_feats(batch)
        prev_live_pos: Optional[np.ndarray] = None
        ops: List[_CacheLayerOps] = []
        for l, tr in enumerate(layers):
            live_pos_h = np.flatnonzero(tr.need_mask.reshape(-1)).astype(np.int64)
            rows_h = tr.need_h.reshape(-1)[live_pos_h].astype(np.int64)
            live_pos_s = np.flatnonzero(tr.srows_mask.reshape(-1)).astype(np.int64)
            rows_s = tr.srows.reshape(-1)[live_pos_s].astype(np.int64)
            h_split, s_split, s_wb, hn_wb = self._cache_layer_ops(
                l, n, rows_h, rows_s, prev_rows, deg)
            dst_keys = np.where(tr.need_mask, tr.need_h, -1).reshape(-1)
            patch_pos, patch_src = _patch_positions(dst_keys, prev_rows)
            if l > 0:  # compose: index into srows_flat → flat ws position
                patch_src = prev_live_pos[patch_src]
            ops.append(_CacheLayerOps(
                h_hit_pos=live_pos_h[h_split.hit_pos],
                h_hit_slots=h_split.hit_slots,
                h_miss_pos=live_pos_h[h_split.miss_pos],
                h_miss_src=h_split.miss_rows,
                h_admit_midx=h_split.admit_midx,
                h_admit_slots=h_split.admit_slots,
                patch_pos=patch_pos, patch_src=patch_src,
                s_hit_pos=live_pos_s[s_split.hit_pos],
                s_hit_slots=s_split.hit_slots,
                s_miss_pos=live_pos_s[s_split.miss_pos],
                s_miss_src=s_split.miss_rows,
                s_wb_pos=live_pos_s[s_wb[0]], s_wb_slots=s_wb[1],
                hnext_wb_pos=live_pos_s[hn_wb[0]], hnext_wb_slots=hn_wb[1]))
            prev_rows, prev_live_pos = rows_s, live_pos_s
        return ops

    # ------------------------------------------------------------------ #
    def dispatch(self, prep: _HybridPrep) -> None:
        """Same staging schedule as :meth:`OffloadBackend.dispatch`, over
        per-shard stacked buffers: pristine gathers for all layers enqueue
        up front, each layer's new-view rows are patched with the previous
        layer's fresh outputs, and the write-back scatters (host blocks are
        the halo-exchange medium between layers) retire on the worker while
        the device computes the next layer."""
        pipe = self._staging
        if not pipe.async_mode:
            self.flush()  # inline staging jobs read host state directly
        pipe.begin_batch()
        batch = prep.batch

        if batch.feat_vertices is not None and batch.feat_vertices.size:
            prev_rows = np.asarray(batch.feat_vertices, np.int64)
            prev_new = np.asarray(batch.feat_values, np.float32)
        else:
            prev_rows = np.zeros(0, np.int64)
            prev_new = np.zeros((0, self.h[0].shape[2]), np.float32)

        ops = prep.cache_ops
        tickets = [
            pipe.submit_gather(partial(self._gather_layer, l, tr,
                                       pipe.buffers(l),
                                       None if ops is None else ops[l]),
                               tag=l)
            for l, tr in enumerate(prep.layers)
        ]
        if prev_rows.size:
            pipe.submit_writeback(
                partial(self._scatter_feats, prev_rows, prev_new),
                nbytes=int(prev_new.nbytes), tag="feat")

        # plan-derived halo traffic: every live need row with a remote
        # owner crosses the exchange medium once (legacy mode twice — the
        # staged h_new copy ships the same remote rows again)
        h_new_copies = 1 if self.halo_mode == "ppermute" else 2
        for l, tr in enumerate(prep.layers):
            self._comms_rows_sent += tr.n_halo_remote * h_new_copies
            self._comms_bytes += (tr.n_halo_remote
                                  * int(self.h[l].shape[2]) * 4 * h_new_copies)

        # cached / device-served paths: the previous layer's stacked
        # outputs stay resident so the new-view patch happens on device
        # (flat [S·cap] positions)
        prev_dev = jnp.asarray(prev_new) if prev_rows.size else None
        final = None
        for l, tr in enumerate(prep.layers):
            staged = pipe.wait_gather(tickets[l])
            if ops is None:
                outs = self._layer_exec(l, tr, staged, prev_rows, prev_new,
                                        prev_dev)
                if self.halo_mode == "ppermute":
                    prev_dev = outs[2].reshape(self.S * tr.ns_cap, -1)
            else:
                outs = self._layer_exec_cached(l, tr, staged, ops[l], prev_dev)
                prev_dev = outs[2].reshape(self.S * tr.ns_cap, -1)
            srows_flat = tr.srows[tr.srows_mask]
            if l + 1 < self.L:
                a_np, nct_np, h_np = pipe.wait_device(outs)
                pipe.submit_writeback(
                    partial(self._writeback_host, l, tr, srows_flat,
                            a_np, nct_np, h_np),
                    nbytes=int(a_np.nbytes + nct_np.nbytes + h_np.nbytes),
                    tag=l)
                prev_rows, prev_new = srows_flat, h_np[tr.srows_mask]
            else:
                final = (l, tr, srows_flat, outs)
        self._defer_final(final)

    def _scatter_feats(self, rows: np.ndarray, vals: np.ndarray) -> None:
        self._scatter_rows(self.h[0], rows, vals)

    def _gather_layer(self, l: int, tr: HybridLayerPlan, bufs,
                      cops: Optional[_CacheLayerOps] = None):
        """Staging-worker job: pristine per-shard gather of layer ``l``'s
        stacked ``[S, cap, ·]`` workspace rows.  Block-contiguous row
        ownership makes the flat view's index the global row id, so the
        gathers fill the double-buffered staging sets with one ``np.take``
        each.  With the hot-row cache enabled only the plan's cold misses
        stage (flat row lists; every miss is a live position, and the
        assembled workspace's dead positions are zero by construction).

        In device-served halo mode (``halo_mode != "psum"``) the host
        ``h_new`` copy is skipped entirely: the previous layer's stacked
        outputs stay device-resident and :meth:`_layer_exec` patches the
        new view from them, so the staging pipeline never ships the same
        bytes twice.  In legacy psum mode the copy is still staged, but
        keyed ``"_h_new"`` so the staging accountant counts only bytes
        actually read from host state — the copy derives byte-for-byte
        from the ``h_old`` gather in the same job (the old double-count
        inflated ``staged_bytes`` whenever a halo row was needed by two
        consecutive layers)."""
        if cops is not None:
            d_in = self.h[l].shape[2]
            nh_m, ns_m = cops.h_miss_src.shape[0], cops.s_miss_src.shape[0]
            h_old = bufs.take("h_old", nh_m, (d_in,))
            np.take(self.h[l].reshape(self.S * self.rows_per, d_in),
                    cops.h_miss_src, axis=0, out=h_old)

            def gather_miss(name, blocks):
                d = blocks.shape[2]
                rows = bufs.take(name, ns_m, (d,))
                np.take(blocks.reshape(self.S * self.rows_per, d),
                        cops.s_miss_src, axis=0, out=rows)
                return rows

            return {"h_old": h_old, "a": gather_miss("a", self.a[l]),
                    "nct": gather_miss("nct", self.nct[l]),
                    "h_cur": gather_miss("h_cur", self.h[l + 1])}
        S, nh_cap, ns_cap = self.S, tr.nh_cap, tr.ns_cap
        live_h, live_s = tr.need_mask, tr.srows_mask
        d_in = self.h[l].shape[2]

        h_old = bufs.take("h_old", S * nh_cap, (d_in,))
        np.take(self.h[l].reshape(S * self.rows_per, d_in),
                tr.need_h.reshape(-1), axis=0, out=h_old)
        h_old = h_old.reshape(S, nh_cap, d_in)
        h_old[~live_h] = 0.0
        h_new = None
        if self.halo_mode == "psum":
            h_new = bufs.take("h_new", S * nh_cap,
                              (d_in,)).reshape(S, nh_cap, d_in)
            np.copyto(h_new, h_old)

        def gather_state(name, blocks):
            d = blocks.shape[2]
            rows = bufs.take(name, S * ns_cap, (d,))
            np.take(blocks.reshape(S * self.rows_per, d),
                    tr.srows.reshape(-1), axis=0, out=rows)
            rows = rows.reshape(S, ns_cap, d)
            rows[~live_s] = 0.0
            return rows

        out = {"h_old": h_old,
               "a": gather_state("a", self.a[l]),
               "nct": gather_state("nct", self.nct[l]),
               "h_cur": gather_state("h_cur", self.h[l + 1])}
        if h_new is not None:
            out["_h_new"] = h_new
        return out

    def _layer_exec(self, l: int, tr: HybridLayerPlan, staged,
                    prev_rows: np.ndarray, prev_new: np.ndarray,
                    prev_dev=None):
        """Patch the new-view rows, ship one sharded device_put (each
        device receives only its slice), one shard_map'd compact layer
        step.

        Device-served fast path (``halo_mode != "psum"``): the staged
        dict carries no ``_h_new`` buffer.  The old view is shipped once
        and the new view is built on device by scattering the previous
        layer's resident stacked outputs into the plan-time
        ``patch_pos``/``patch_src`` positions — halo rows are pristine
        by the gather contract (the previous layer's local scatter never
        writes remote-owned rows), so the unpatched positions already
        hold the correct old=new values."""
        S, nh_cap = self.S, tr.nh_cap
        live_h, live_s = tr.need_mask, tr.srows_mask
        h_old_rows = staged["h_old"]
        a_rows, nct_rows, h_cur_rows = staged["a"], staged["nct"], staged["h_cur"]
        nh_live = live_h.sum(axis=1)
        ns_live = live_s.sum(axis=1)

        if self.halo_mode != "psum":
            with self._acc_lock:
                self.transfers.rows_up += int(nh_live.sum() + 3 * ns_live.sum())
                self.transfers.bytes_up += (h_old_rows.nbytes + a_rows.nbytes
                                            + nct_rows.nbytes + h_cur_rows.nbytes)
                self.per_shard_rows += nh_live + 3 * ns_live
            dev = jax.device_put(
                (h_old_rows, a_rows, nct_rows, h_cur_rows,
                 tr.idx_sh, tr.flt_sh, tr.msk_sh),
                self._plan_sh,
            )
            (h_old_d, a_d, nct_d, h_cur_d, idx_d, flt_d, msk_d) = dev
            d_in = h_old_rows.shape[2]
            h_old_flat = h_old_d.reshape(S * nh_cap, d_in)
            if tr.patch_pos is not None and tr.patch_pos.size and prev_dev is not None:
                h_new_flat = h_old_flat.at[tr.patch_pos].set(
                    prev_dev[tr.patch_src])
            else:
                h_new_flat = h_old_flat
            h_new_d = jax.device_put(h_new_flat.reshape(S, nh_cap, d_in),
                                     self._plan_sh)
            self.peak_device_bytes = max(
                self.peak_device_bytes,
                sum(int(d.nbytes) for d in dev) + int(h_new_d.nbytes),
            )
            return self._step(tr.layout, self._params_dev[l],
                              h_old_d, h_new_d, a_d, nct_d, h_cur_d,
                              idx_d, flt_d, msk_d)

        h_new_rows = staged["_h_new"]
        flat_new = h_new_rows.reshape(S * nh_cap, -1)
        _override_rows(flat_new, np.where(live_h, tr.need_h, -1).reshape(-1),
                       prev_rows, prev_new)
        h_new_rows = flat_new.reshape(S, nh_cap, -1)

        with self._acc_lock:
            self.transfers.rows_up += int(2 * nh_live.sum() + 3 * ns_live.sum())
            self.transfers.bytes_up += (2 * h_new_rows.nbytes + a_rows.nbytes
                                        + nct_rows.nbytes + h_cur_rows.nbytes)
            self.per_shard_rows += 2 * nh_live + 3 * ns_live

        # one sharded H2D transfer: each device receives only its slice
        dev = jax.device_put(
            (h_old_rows, h_new_rows, a_rows, nct_rows, h_cur_rows,
             tr.idx_sh, tr.flt_sh, tr.msk_sh),
            self._plan_sh,
        )
        self.peak_device_bytes = max(
            self.peak_device_bytes, sum(int(d.nbytes) for d in dev)
        )
        (h_old_d, h_new_d, a_d, nct_d, h_cur_d, idx_d, flt_d, msk_d) = dev
        return self._step(tr.layout, self._params_dev[l],
                          h_old_d, h_new_d, a_d, nct_d, h_cur_d,
                          idx_d, flt_d, msk_d)

    def _layer_exec_cached(self, l: int, tr: HybridLayerPlan, staged,
                           cops: _CacheLayerOps, prev_dev):
        """Cached variant of :meth:`_layer_exec`: assemble the flat
        ``[S·cap, ·]`` workspaces from staged cold misses + cached hot
        slots (dead positions stay 0.0, matching the host gather's
        zeroing), patch the new view on device, reshard to the stacked
        per-shard layout, run the identical step, then refresh written
        slots in place from the stacked outputs."""
        cache = self._cache
        S, nh_cap, ns_cap = self.S, tr.nh_cap, tr.ns_cap
        h_old_m, a_m, nct_m, h_cur_m = (staged["h_old"], staged["a"],
                                        staged["nct"], staged["h_cur"])
        h_miss_sh = np.bincount(cops.h_miss_pos // nh_cap, minlength=S)
        s_miss_sh = np.bincount(cops.s_miss_pos // ns_cap, minlength=S)
        with self._acc_lock:
            self.transfers.rows_up += int(h_miss_sh.sum() + 3 * s_miss_sh.sum())
            self.transfers.bytes_up += (h_old_m.nbytes + a_m.nbytes
                                        + nct_m.nbytes + h_cur_m.nbytes)
            self.per_shard_rows += h_miss_sh + 3 * s_miss_sh

        h_old_md, a_md, nct_md, h_cur_md = jax.device_put(
            (h_old_m, a_m, nct_m, h_cur_m))
        d_in = self.h[l].shape[2]
        h_old_flat = _cache_assemble(
            S * nh_cap, d_in, cops.h_miss_pos, h_old_md, cops.h_hit_pos,
            cache.store(("h", l), "h", (d_in,))[cops.h_hit_slots]
            if cops.h_hit_pos.size else None)
        if cops.h_admit_midx.size:
            cache.update_store(("h", l), "h", cops.h_admit_slots,
                               h_old_md[cops.h_admit_midx])
        if cops.patch_pos.size:
            h_new_flat = h_old_flat.at[cops.patch_pos].set(
                prev_dev[cops.patch_src])
        else:
            h_new_flat = h_old_flat

        da, dn, dc = (self.a[l].shape[2], self.nct[l].shape[2],
                      self.h[l + 1].shape[2])
        s_key = ("s", l)
        a_flat = _cache_assemble(
            S * ns_cap, da, cops.s_miss_pos, a_md, cops.s_hit_pos,
            cache.store(s_key, "a", (da,))[cops.s_hit_slots]
            if cops.s_hit_pos.size else None)
        nct_flat = _cache_assemble(
            S * ns_cap, dn, cops.s_miss_pos, nct_md, cops.s_hit_pos,
            cache.store(s_key, "nct", (dn,))[cops.s_hit_slots]
            if cops.s_hit_pos.size else None)
        h_cur_flat = _cache_assemble(
            S * ns_cap, dc, cops.s_miss_pos, h_cur_md, cops.s_hit_pos,
            cache.store(s_key, "h", (dc,))[cops.s_hit_slots]
            if cops.s_hit_pos.size else None)

        # explicit reshard to the stacked per-shard layout for shard_map
        dev = jax.device_put(
            (h_old_flat.reshape(S, nh_cap, d_in),
             h_new_flat.reshape(S, nh_cap, d_in),
             a_flat.reshape(S, ns_cap, da), nct_flat.reshape(S, ns_cap, dn),
             h_cur_flat.reshape(S, ns_cap, dc),
             tr.idx_sh, tr.flt_sh, tr.msk_sh),
            self._plan_sh,
        )
        self.peak_device_bytes = max(
            self.peak_device_bytes, sum(int(d.nbytes) for d in dev)
        )
        (h_old_d, h_new_d, a_d, nct_d, h_cur_d, idx_d, flt_d, msk_d) = dev
        outs = self._step(tr.layout, self._params_dev[l],
                          h_old_d, h_new_d, a_d, nct_d, h_cur_d,
                          idx_d, flt_d, msk_d)
        if cops.s_wb_pos.size:
            a_o = outs[0].reshape(S * ns_cap, -1)
            nct_o = outs[1].reshape(S * ns_cap, -1)
            h_o = outs[2].reshape(S * ns_cap, -1)
            cache.update_store(s_key, "a", cops.s_wb_slots,
                               a_o[cops.s_wb_pos])
            cache.update_store(s_key, "nct", cops.s_wb_slots,
                               nct_o[cops.s_wb_pos])
            cache.update_store(s_key, "h", cops.s_wb_slots,
                               h_o[cops.s_wb_pos])
        if cops.hnext_wb_pos.size:
            cache.update_store(
                ("h", l + 1), "h", cops.hnext_wb_slots,
                outs[2].reshape(S * ns_cap, -1)[cops.hnext_wb_pos])
        return outs

    def _writeback_host(self, l: int, tr: HybridLayerPlan,
                        srows_flat: np.ndarray, a_new: np.ndarray,
                        nct_new: np.ndarray, h_new: np.ndarray) -> None:
        """Grouped per-shard host scatter of one layer's written-back rows
        (runs on the staging worker in async mode) — the host blocks are
        the halo-exchange medium between layers."""
        live = tr.srows_mask
        self._scatter_rows(self.a[l], srows_flat, a_new[live])
        self._scatter_rows(self.nct[l], srows_flat, nct_new[live])
        self._scatter_rows(self.h[l + 1], srows_flat, h_new[live])
        with self._acc_lock:
            self.transfers.rows_down += 3 * int(srows_flat.shape[0])
            self.transfers.bytes_down += int(a_new[live].nbytes
                                             + nct_new[live].nbytes
                                             + h_new[live].nbytes)
            self.per_shard_rows += 3 * live.sum(axis=1)

    def _final_writeback(self, payload) -> None:
        if payload is None:
            return
        l, tr, srows_flat, outs = payload
        a_new, nct_new, h_new = (np.asarray(o) for o in outs)
        self._writeback_host(l, tr, srows_flat, a_new, nct_new, h_new)


# ====================================================================== #
# ChunkedBackend — host-resident state, chunked full-recompute execution
# ====================================================================== #
@dataclasses.dataclass
class _ChunkedPrep:
    """Prepared plan for the chunked substrate: the Alg.-4 affected sets
    plus the post-batch graph (the chunk scheduler re-reads CSR edges at
    execution time instead of baking transfer tables at plan time)."""

    plan: BatchPlan
    batch: UpdateBatch
    g_new: CSRGraph
    rows_per_layer: List[np.ndarray]  # live out_rows per layer (global ids)

    @property
    def n_inc_edges(self) -> int:
        return self.plan.total_inc_edges()

    @property
    def n_full_edges(self) -> int:
        return self.plan.total_full_edges()

    @property
    def n_out_rows(self) -> int:
        return self.plan.total_vertices()


class ChunkedBackend(StateBackend):
    """Host-resident state executed through the §V-C chunked scheduler.

    The per-layer state lives as host numpy (like :class:`OffloadBackend`)
    but each batch executes by *constrained re-computation*: per layer, the
    planner's live ``out_rows`` (⊇ touch ∪ full rows, i.e. every row whose
    a/nct/h may change) are recomputed from the post-batch graph through
    :class:`repro.serve.scheduler.ChunkedLayerScheduler` —
    destination-vertex chunks with inter-chunk shard-embedding reuse, so
    device residency is bounded by ``chunk_size`` regardless of how large a
    batch's affected subgraph grows.  This is the fallback substrate for
    affected sets too big to stage at once; output matches the incremental
    substrates to numerical tolerance (recompute vs. signed incremental
    accumulation), not bitwise — the cross-backend matrix covers it
    (tests/test_backends.py).

    Serving API: state is plain host numpy with no deferred write-back, so
    ``snapshot_rows`` is a direct gather and ``changed_rows`` is the final
    layer's planned recompute set."""

    def __init__(self, model: GNNModel, params: Sequence[Params],
                 graph: CSRGraph, x: np.ndarray, chunk_size: int = 8192,
                 chunk_reuse: bool = True):
        # deferred import: repro.serve.scheduler pulls repro.core.full
        # while this module is itself mid-import under repro.core.__init__
        from repro.serve.scheduler import ChunkedLayerScheduler

        self.model = model
        self.params = list(params)
        self.L = len(self.params)
        self.x = np.asarray(x, np.float32)
        self.scheduler = ChunkedLayerScheduler(model, chunk_size=chunk_size,
                                               reuse=chunk_reuse)
        states = full_forward(model, params, jnp.asarray(self.x), graph)
        self.h: List[np.ndarray] = [self.x.copy()] + [np.array(s.h) for s in states]
        self.a: List[np.ndarray] = [np.array(s.a) for s in states]
        self.nct: List[np.ndarray] = [np.array(s.nct) for s in states]

    @property
    def embeddings(self) -> np.ndarray:
        return self.h[-1]

    def state_bytes(self) -> int:
        return (sum(a.nbytes for a in self.a) + sum(c.nbytes for c in self.nct)
                + sum(h.nbytes for h in self.h))

    def sync_arrays(self) -> list:
        return []  # dispatch is synchronous; state is host numpy

    def refresh(self, graph: CSRGraph) -> None:
        states = full_forward(self.model, self.params, jnp.asarray(self.h[0]),
                              graph)
        self.h = [self.h[0]] + [np.array(s.h) for s in states]
        self.a = [np.array(s.a) for s in states]
        self.nct = [np.array(s.nct) for s in states]

    # ------------------------------------------------------------------ #
    # Serving API
    # ------------------------------------------------------------------ #
    def snapshot_rows(self, rows: np.ndarray) -> np.ndarray:
        return self.h[-1][np.asarray(rows, np.int64)]

    def changed_rows(self, prep: "_ChunkedPrep") -> np.ndarray:
        return prep.rows_per_layer[-1]

    # ------------------------------------------------------------------ #
    # policy-execution primitives: this substrate's native dispatch *is*
    # the chunked mode — the policy path shares its scheduler (and its
    # reuse/transfer counters), making policy-chosen chunked batches
    # bitwise-identical to native ones
    # ------------------------------------------------------------------ #
    def chunk_scheduler(self):
        return self.scheduler

    def apply_feature_updates(self, rows: np.ndarray, vals: np.ndarray) -> None:
        self.h[0][np.asarray(rows, np.int64)] = np.asarray(vals, np.float32)

    def layer_input_host(self, l: int) -> np.ndarray:
        return self.h[l]

    def scatter_layer_rows(self, l: int, rows: np.ndarray, a_rows: np.ndarray,
                           nct_rows: np.ndarray, h_rows: np.ndarray) -> None:
        self.a[l][rows] = a_rows
        self.nct[l][rows] = nct_rows
        self.h[l + 1][rows] = h_rows

    # ------------------------------------------------------------------ #
    def plan(self, g_old: CSRGraph, g_new: CSRGraph, batch: UpdateBatch,
             base_plan: Optional[BatchPlan] = None) -> _ChunkedPrep:
        plan = (base_plan if base_plan is not None
                else build_plan(self.model, g_old, g_new, batch, self.L))
        rows = [np.unique(lp.out_rows[lp.out_mask].astype(np.int64))
                for lp in plan.layers]
        return _ChunkedPrep(plan=plan, batch=batch, g_new=g_new,
                            rows_per_layer=rows)

    def dispatch(self, prep: _ChunkedPrep) -> None:
        """Layer-by-layer chunked recompute of the affected rows.  Layer
        ``l`` reads ``h[l]`` *after* the previous layer's write-back (and
        the batch's feature scatter for layer 0), so the recompute sees
        exactly the incremental substrates' layer inputs."""
        batch = prep.batch
        if batch.feat_vertices is not None and batch.feat_vertices.size:
            self.h[0][np.asarray(batch.feat_vertices, np.int64)] = np.asarray(
                batch.feat_values, np.float32)
        deg = prep.plan.deg_new[:-1]  # [n] new-graph degrees (drop scratch)
        for l in range(self.L):
            rows = prep.rows_per_layer[l]
            if not rows.size:
                continue
            a_r, nct_r, h_r = self.scheduler.run_layer(
                self.params[l], prep.g_new, self.h[l], rows, deg)
            self.a[l][rows] = a_r
            self.nct[l][rows] = nct_r
            self.h[l + 1][rows] = h_r
