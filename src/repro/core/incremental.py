"""Device-side reordered incremental RTEC layer — paper Alg. 1, batched.

One call updates a whole layer's state for one update batch:

  1. recompute local messages for affected edges (old side / new side chosen
     per record) and scatter the *signed* context deltas into the touched
     rows (Alg. 1 lines 1–3);
  2. strip the old neighborhood context from the cached aggregation state of
     the touched rows with ``ms_cbn⁻¹``, add the signed message deltas, and
     re-apply the new context with ``ms_cbn`` (lines 4–6);
  3. full-neighborhood recompute for constrained destination-affected rows
     (paper §IV-C), overwriting their (a, nct);
  4. vertex-wise ``update`` on every row whose output changes (line 7).

All arrays are padded (see :mod:`repro.core.affected`).  State arrays are
extended with one scratch row at index ``n``; padded indices point there, so
padding can never alias a live vertex regardless of scatter ordering.  The
function is pure and jitted once per shape bucket.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.full import edge_messages, subset_layer
from repro.core.operators import GNNModel, Params


def with_scratch(x: jax.Array) -> jax.Array:
    """Append one zero scratch row (index n) to a [N, ...] array."""
    return jnp.concatenate([x, jnp.zeros((1,) + x.shape[1:], x.dtype)], axis=0)


@partial(jax.jit, static_argnums=(0,))
def incremental_layer(
    model: GNNModel,
    p: Params,
    # previous-layer embeddings (old and new views), WITH scratch row [N+1,·]
    h_prev_old: jax.Array,
    h_prev_new: jax.Array,
    deg_old: jax.Array,  # [N+1]
    deg_new: jax.Array,  # [N+1]
    # cached layer state (no scratch row)
    a: jax.Array,  # [N, agg]
    nct: jax.Array,  # [N, C]
    h_cur_old: jax.Array,  # [N, d_out]
    # incremental records
    e_src: jax.Array,
    e_dst: jax.Array,
    e_rowidx: jax.Array,
    e_sign: jax.Array,
    e_use_new: jax.Array,
    e_w: jax.Array,
    e_t: jax.Array,
    e_mask: jax.Array,
    touch_rows: jax.Array,
    touch_mask: jax.Array,
    # constrained full path
    f_rows: jax.Array,
    f_mask: jax.Array,
    f_src: jax.Array,
    f_rowidx: jax.Array,
    f_w: jax.Array,
    f_t: jax.Array,
    f_emask: jax.Array,
    # output rows
    out_rows: jax.Array,
    out_mask: jax.Array,
    # h-space views of f_rows/out_rows: identical to the state-space arrays
    # in the in-memory engine, but differ under the compact offloaded engine
    # where h^{l-1} rows and state rows have separate compactions (§V-B)
    f_rows_h: Optional[jax.Array] = None,
    out_rows_h: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (a_new [N,agg], nct_new [N,C], h_cur_new [N,d_out])."""
    if f_rows_h is None:
        f_rows_h = f_rows
    if out_rows_h is None:
        out_rows_h = out_rows
    n = a.shape[0]
    r_cap = touch_rows.shape[0]
    f_cap = f_rows.shape[0]

    a_ext = with_scratch(a)
    nct_ext = with_scratch(nct)
    h_ext = with_scratch(h_cur_old)

    # ---------------- step 1: signed delta messages (Alg.1 l.1-3) -------
    use = e_use_new[:, None]
    h_u = jnp.where(use, h_prev_new[e_src], h_prev_old[e_src])
    if model.dest_dependent:
        h_v = jnp.where(use, h_prev_new[e_dst], h_prev_old[e_dst])
    else:
        # Theorem 1 requires ms_local independent of the destination for
        # unconstrained models — skip the h[dst] halo gather entirely
        # (≈2× less collective traffic at pod scale; EXPERIMENTS.md §Perf)
        h_v = jnp.zeros((e_src.shape[0], h_prev_new.shape[1]), h_prev_new.dtype)
    s_u = jnp.where(e_use_new, deg_new[e_src], deg_old[e_src])
    s_v = jnp.where(e_use_new, deg_new[e_dst], deg_old[e_dst])
    ctx, raw = edge_messages(model, p, h_u, h_v, s_u, s_v, e_w, e_t)
    scale = (e_sign * e_mask.astype(raw.dtype))[:, None]
    ctx = ctx * scale
    raw = raw * scale

    # compact scatter into touched-row space (O(affected), not O(V))
    d_nct = jax.ops.segment_sum(ctx, e_rowidx, num_segments=r_cap + 1)[:r_cap]
    d_s = jax.ops.segment_sum(raw, e_rowidx, num_segments=r_cap + 1)[:r_cap]

    # ---------------- step 2: cbn⁻¹ → delta-agg → cbn (Alg.1 l.4-6) -----
    nct_old_rows = nct_ext[touch_rows]
    a_rows = a_ext[touch_rows]
    nct_new_rows = nct_old_rows + d_nct
    s_rows = model.ms_cbn_inv(p, nct_old_rows, a_rows) + d_s
    a_new_rows = model.ms_cbn(p, nct_new_rows, s_rows)
    # padded rows in touch_rows all point at the scratch slot n
    a_ext = a_ext.at[touch_rows].set(a_new_rows)
    nct_ext = nct_ext.at[touch_rows].set(nct_new_rows)

    # ---------------- step 3: constrained full recompute (§IV-C) --------
    if f_rows.shape[0] > 0:
        fa, fnct, _ = subset_layer(
            model,
            p,
            h_prev_new,
            f_rows_h,
            f_mask,
            f_src,
            f_rowidx,
            f_w,
            f_t,
            f_emask,
            deg_new,
            f_cap,
        )
        a_ext = a_ext.at[f_rows].set(fa)
        nct_ext = nct_ext.at[f_rows].set(fnct)

    # ---------------- step 4: vertex-wise update (Alg.1 l.7) ------------
    h_prev_rows = h_prev_new[out_rows_h]
    h_rows = model.update(p, h_prev_rows, a_ext[out_rows])
    h_ext = h_ext.at[out_rows].set(h_rows)
    return a_ext[:n], nct_ext[:n], h_ext[:n]
