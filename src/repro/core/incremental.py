"""Device-side reordered incremental RTEC — paper Alg. 1, batched + fused.

Two entry points share one layer body (:func:`_layer_body`):

* :func:`incremental_layer` — the seed per-layer function (one jit dispatch
  per layer, state shipped without scratch rows).  Kept for the offloaded
  engine, ODEC and the dry-run cost model, and as the unfused reference the
  equivalence tests compare the pipelined engine against.
* :func:`fused_stream_step` — the pipelined engine's single L-layer step:
  consumes one :class:`~repro.core.affected.PackedPlan` (three contiguous
  buffers, sliced per field at trace time via the static offset table),
  threads ``(h, a, nct)`` through all layers, and **donates** the state
  arguments so on TPU the cached state updates in place — O(affected) HBM
  traffic instead of an O(V) copy in and out per layer.

The layer body per layer:

  1. recompute local messages for affected edges (old side / new side chosen
     per record) and scatter the *signed* context deltas into the touched
     rows (Alg. 1 lines 1–3);
  2. strip the old neighborhood context from the cached aggregation state of
     the touched rows with ``ms_cbn⁻¹``, add the signed message deltas, and
     re-apply the new context with ``ms_cbn`` (lines 4–6);
  3. full-neighborhood recompute for constrained destination-affected rows
     (paper §IV-C), overwriting their (a, nct);
  4. vertex-wise ``update`` on every row whose output changes (line 7).

All arrays are padded (see :mod:`repro.core.affected`).  State arrays carry
one scratch row at index ``n``; padded indices point there, so padding can
never alias a live vertex regardless of scatter ordering.  The fused step
re-zeroes the scratch row after each layer so the persistent state stays
inert across batches.  Step 1's scatter optionally routes through the Pallas
``delta_agg`` kernel (host-planned block-CSR schedule shipped with the
packed plan; XLA ``segment_sum`` is the fallback).
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.affected import (
    HybridLayerLayout,
    PackedLayout,
    ShardedLayout,
    hybrid_layout_slices,
    layout_slices,
    sharded_layout_slices,
)
from repro.core.full import edge_messages, subset_layer
from repro.core.operators import GNNModel, Params
from repro.dist.sharding import rotation_perm


def with_scratch(x: jax.Array) -> jax.Array:
    """Append one zero scratch row (index n) to a [N, ...] array."""
    return jnp.concatenate([x, jnp.zeros((1,) + x.shape[1:], x.dtype)], axis=0)


def _pallas_delta_scatter(
    ctx: jax.Array,  # [Ecap, C] signed, mask-scaled
    raw: jax.Array,  # [Ecap, agg]
    sched: Tuple[jax.Array, jax.Array, jax.Array],  # (perm, dloc, block_rows)
    r_cap: int,
) -> Tuple[jax.Array, jax.Array]:
    """Step-1 scatter via the Pallas ``delta_agg`` kernel (one fused
    [ctx | raw] scatter); schedule was planned host-side in pack_plan."""
    from repro.kernels.delta_agg import DELTA_BD, DELTA_BE, DELTA_TV, delta_agg

    perm, dloc, brows = sched
    c = ctx.shape[1]
    msg = jnp.concatenate([ctx, raw], axis=1)
    safe = jnp.maximum(perm, 0)
    m = msg[safe] * (perm >= 0).astype(msg.dtype)[:, None]  # block layout
    d = m.shape[1]
    dpad = -(-d // DELTA_BD) * DELTA_BD
    if dpad != d:
        m = jnp.pad(m, ((0, 0), (0, dpad - d)))
    state = jnp.zeros((r_cap, dpad), m.dtype)  # r_cap is pow2 ≥ 16 → tv-aligned
    out = delta_agg(
        m, dloc, brows, state, tv=DELTA_TV, be=DELTA_BE, bd=DELTA_BD,
        interpret=jax.default_backend() != "tpu",
    )
    return out[:, :c], out[:, c:d]


def _layer_body(
    model: GNNModel,
    p: Params,
    # previous-layer embeddings (old and new views), WITH scratch row [N+1,·]
    h_prev_old: jax.Array,
    h_prev_new: jax.Array,
    deg_old: jax.Array,  # [N+1]
    deg_new: jax.Array,  # [N+1]
    # cached layer state, WITH scratch row [N+1,·]
    a_ext: jax.Array,
    nct_ext: jax.Array,
    h_ext: jax.Array,
    # incremental records
    e_src: jax.Array,
    e_dst: jax.Array,
    e_rowidx: jax.Array,
    e_sign: jax.Array,
    e_use_new: jax.Array,
    e_w: jax.Array,
    e_t: jax.Array,
    e_mask: jax.Array,
    touch_rows: jax.Array,
    touch_mask: jax.Array,
    # constrained full path
    f_rows: jax.Array,
    f_mask: jax.Array,
    f_src: jax.Array,
    f_rowidx: jax.Array,
    f_w: jax.Array,
    f_t: jax.Array,
    f_emask: jax.Array,
    # output rows
    out_rows: jax.Array,
    out_mask: jax.Array,
    f_rows_h: Optional[jax.Array] = None,
    out_rows_h: Optional[jax.Array] = None,
    pallas_delta: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One layer over scratch-extended state; returns extended arrays."""
    if f_rows_h is None:
        f_rows_h = f_rows
    if out_rows_h is None:
        out_rows_h = out_rows
    r_cap = touch_rows.shape[0]
    f_cap = f_rows.shape[0]

    # ---------------- step 1: signed delta messages (Alg.1 l.1-3) -------
    use = e_use_new[:, None]
    h_u = jnp.where(use, h_prev_new[e_src], h_prev_old[e_src])
    if model.dest_dependent:
        h_v = jnp.where(use, h_prev_new[e_dst], h_prev_old[e_dst])
    else:
        # Theorem 1 requires ms_local independent of the destination for
        # unconstrained models — skip the h[dst] halo gather entirely
        # (≈2× less collective traffic at pod scale; EXPERIMENTS.md §Perf)
        h_v = jnp.zeros((e_src.shape[0], h_prev_new.shape[1]), h_prev_new.dtype)
    s_u = jnp.where(e_use_new, deg_new[e_src], deg_old[e_src])
    s_v = jnp.where(e_use_new, deg_new[e_dst], deg_old[e_dst])
    ctx, raw = edge_messages(model, p, h_u, h_v, s_u, s_v, e_w, e_t)
    scale = (e_sign * e_mask.astype(raw.dtype))[:, None]
    ctx = ctx * scale
    raw = raw * scale

    # compact scatter into touched-row space (O(affected), not O(V))
    if pallas_delta is not None:
        d_nct, d_s = _pallas_delta_scatter(ctx, raw, pallas_delta, r_cap)
    else:
        d_nct = jax.ops.segment_sum(ctx, e_rowidx, num_segments=r_cap + 1)[:r_cap]
        d_s = jax.ops.segment_sum(raw, e_rowidx, num_segments=r_cap + 1)[:r_cap]

    # ---------------- step 2: cbn⁻¹ → delta-agg → cbn (Alg.1 l.4-6) -----
    nct_old_rows = nct_ext[touch_rows]
    a_rows = a_ext[touch_rows]
    nct_new_rows = nct_old_rows + d_nct
    s_rows = model.ms_cbn_inv(p, nct_old_rows, a_rows) + d_s
    a_new_rows = model.ms_cbn(p, nct_new_rows, s_rows)
    # padded rows in touch_rows all point at the scratch slot n
    a_ext = a_ext.at[touch_rows].set(a_new_rows)
    nct_ext = nct_ext.at[touch_rows].set(nct_new_rows)

    # ---------------- step 3: constrained full recompute (§IV-C) --------
    if f_rows.shape[0] > 0:
        fa, fnct, _ = subset_layer(
            model,
            p,
            h_prev_new,
            f_rows_h,
            f_mask,
            f_src,
            f_rowidx,
            f_w,
            f_t,
            f_emask,
            deg_new,
            f_cap,
        )
        a_ext = a_ext.at[f_rows].set(fa)
        nct_ext = nct_ext.at[f_rows].set(fnct)

    # ---------------- step 4: vertex-wise update (Alg.1 l.7) ------------
    h_prev_rows = h_prev_new[out_rows_h]
    h_rows = model.update(p, h_prev_rows, a_ext[out_rows])
    h_ext = h_ext.at[out_rows].set(h_rows)
    return a_ext, nct_ext, h_ext


@partial(jax.jit, static_argnums=(0,))
def incremental_layer(
    model: GNNModel,
    p: Params,
    h_prev_old: jax.Array,  # WITH scratch row [N+1,·]
    h_prev_new: jax.Array,
    deg_old: jax.Array,  # [N+1]
    deg_new: jax.Array,  # [N+1]
    # cached layer state (no scratch row)
    a: jax.Array,  # [N, agg]
    nct: jax.Array,  # [N, C]
    h_cur_old: jax.Array,  # [N, d_out]
    e_src: jax.Array,
    e_dst: jax.Array,
    e_rowidx: jax.Array,
    e_sign: jax.Array,
    e_use_new: jax.Array,
    e_w: jax.Array,
    e_t: jax.Array,
    e_mask: jax.Array,
    touch_rows: jax.Array,
    touch_mask: jax.Array,
    f_rows: jax.Array,
    f_mask: jax.Array,
    f_src: jax.Array,
    f_rowidx: jax.Array,
    f_w: jax.Array,
    f_t: jax.Array,
    f_emask: jax.Array,
    out_rows: jax.Array,
    out_mask: jax.Array,
    # h-space views of f_rows/out_rows: identical to the state-space arrays
    # in the in-memory engine, but differ under the compact offloaded engine
    # where h^{l-1} rows and state rows have separate compactions (§V-B)
    f_rows_h: Optional[jax.Array] = None,
    out_rows_h: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Seed per-layer API: returns (a_new [N,agg], nct_new [N,C], h_cur_new)."""
    n = a.shape[0]
    a_ext, nct_ext, h_ext = _layer_body(
        model, p, h_prev_old, h_prev_new, deg_old, deg_new,
        with_scratch(a), with_scratch(nct), with_scratch(h_cur_old),
        e_src, e_dst, e_rowidx, e_sign, e_use_new, e_w, e_t, e_mask,
        touch_rows, touch_mask,
        f_rows, f_mask, f_src, f_rowidx, f_w, f_t, f_emask,
        out_rows, out_mask,
        f_rows_h=f_rows_h, out_rows_h=out_rows_h,
    )
    return a_ext[:n], nct_ext[:n], h_ext[:n]


@partial(jax.jit, static_argnums=(0, 1), donate_argnums=(3, 4, 5))
def fused_stream_step(
    model: GNNModel,
    layout: PackedLayout,
    params: Tuple[Params, ...],
    h_exts: Tuple[jax.Array, ...],  # L+1 arrays [N+1,·] — donated
    a_exts: Tuple[jax.Array, ...],  # L arrays [N+1,·] — donated
    nct_exts: Tuple[jax.Array, ...],  # L arrays [N+1,·] — donated
    idx: jax.Array,  # int32 packed buffer
    flt: jax.Array,  # float32 packed buffer (leads with deg_old/deg_new)
    msk: jax.Array,  # bool packed buffer
    feat_vals: Optional[jax.Array],  # [feat_cap, d0] when layout.feat_cap
    pallas: Optional[Tuple[Tuple[jax.Array, jax.Array, jax.Array], ...]],
) -> Tuple[Tuple[jax.Array, ...], Tuple[jax.Array, ...], Tuple[jax.Array, ...]]:
    """One fused L-layer incremental step over a packed plan.

    Returns (h_exts', a_exts', nct_exts') — the next batch's cached state,
    scratch rows re-zeroed.  One trace per PackedLayout; one dispatch per
    batch."""
    n = layout.n
    idx_sl, flt_sl, msk_sl, _ = layout_slices(layout)
    deg_old = flt[: n + 1]
    deg_new = flt[n + 1 : 2 * (n + 1)]

    h0_old = h_exts[0]
    if layout.feat_cap:
        frows = idx[: layout.feat_cap]
        fmask = msk[: layout.feat_cap]
        vals = jnp.where(fmask[:, None], feat_vals.astype(h0_old.dtype), h0_old[frows])
        h0_new = h0_old.at[frows].set(vals)  # pads → scratch, masked to no-op
    else:
        h0_new = h0_old

    h_prev_old, h_prev_new = h0_old, h0_new
    hs = [h0_new]
    as_, ncts = [], []
    for l in range(len(layout.caps)):
        gi = {name: idx[s] for name, s in idx_sl[l].items()}
        gf = {name: flt[s] for name, s in flt_sl[l].items()}
        gm = {name: msk[s] for name, s in msk_sl[l].items()}
        an, nn, hn = _layer_body(
            model, params[l], h_prev_old, h_prev_new, deg_old, deg_new,
            a_exts[l], nct_exts[l], h_exts[l + 1],
            gi["e_src"], gi["e_dst"], gi["e_rowidx"], gf["e_sign"],
            gm["e_use_new"], gf["e_w"], gi["e_t"], gm["e_mask"],
            gi["touch_rows"], gm["touch_mask"],
            gi["f_rows"], gm["f_mask"], gi["f_src"], gi["f_rowidx"],
            gf["f_w"], gi["f_t"], gm["f_emask"],
            gi["out_rows"], gm["out_mask"],
            pallas_delta=None if pallas is None else pallas[l],
        )
        # re-zero the scratch row: padded scatters may have written NaN-prone
        # values (e.g. ms_cbn_inv(0, 0)) and the state persists across batches
        an = an.at[n].set(0.0)
        nn = nn.at[n].set(0.0)
        hn = hn.at[n].set(0.0)
        as_.append(an)
        ncts.append(nn)
        hs.append(hn)
        h_prev_old = h_exts[l + 1]
        h_prev_new = hn
    return tuple(hs), tuple(as_), tuple(ncts)


# ====================================================================== #
# Sharded fused step — the multi-device analogue of fused_stream_step
# ====================================================================== #
@lru_cache(maxsize=None)
def sharded_step_fn(model: GNNModel, mesh, axis: str):
    """Build (and cache per (model, mesh)) the jitted shard_map'd L-layer
    step over row-sharded state.

    State lives as stacked ``[S, rows_per + 1, ·]`` blocks (one scratch row
    per shard, donated).  Per layer each shard

      1. materializes its ``[halo_cap, 2·d]`` frontier buffer — under
         ``halo_mode="psum"`` by serving its slice of the replicated
         frontier row list out of its local previous-layer block and
         ``lax.psum``-ing (per-device bytes scale with the *global*
         frontier); under ``halo_mode="ppermute"`` by ``S−1`` rotation
         rounds of ``lax.ppermute`` over the plan-time per-consumer
         send/recv schedules (``ShardedPlan.comms_sh``), so each shard
         sends/receives only the halo rows its consumers actually gather —
         bitwise-equal to the psum path because psum over the one-hot
         ownership partition is a select-broadcast of the owner's exact
         bytes, and positions a shard never gathers may stay zero;
      2. concatenates ``[halo | local]`` into the workspace the plan's
         remapped indices address and runs the unmodified
         :func:`_layer_body` — all scatters are owner-local by construction
         (destination rows are never remote);
      3. re-zeroes its local scratch row.

    One trace per :class:`~repro.core.affected.ShardedLayout`; plan-side
    capacity hysteresis keeps the layout count bounded over a stream."""

    @partial(jax.jit, static_argnums=(0,), donate_argnums=(2, 3, 4))
    def step(
        slayout: ShardedLayout,
        params: Tuple[Params, ...],
        h_blocks: Tuple[jax.Array, ...],  # L+1 arrays [S, rows_per+1, ·]
        a_blocks: Tuple[jax.Array, ...],  # L arrays [S, rows_per+1, ·]
        nct_blocks: Tuple[jax.Array, ...],  # L arrays [S, rows_per+1, ·]
        idx_sh: jax.Array,  # int32  [S, idx_len]
        flt_sh: jax.Array,  # float32 [S, flt_len]
        msk_sh: jax.Array,  # bool   [S, msk_len]
        idx_rep: jax.Array,  # int32 [rep_len] replicated
        msk_rep: jax.Array,  # bool  [feat_cap] replicated
        feat_vals: jax.Array,  # [feat_cap, d0] replicated ([0, d0] if unused)
        pallas_sh=(),  # per-layer stacked (perm, dloc, brows) triples, or ()
        comms_sh=(),  # per-layer (send_pos, recv_pos) [S, S-1, pair_cap], or ()
    ):
        idx_sl, flt_sl, msk_sl, halo_sl, _ = sharded_layout_slices(slayout)
        rows_per = slayout.rows_per
        S = slayout.n_shards
        use_pallas = slayout.pallas_ecaps is not None
        use_ppermute = slayout.halo_mode == "ppermute"

        def local(prm, h_bl, a_bl, nct_bl, idx_s, flt_s, msk_s, idx_r, msk_r,
                  fvals, pal, comms):
            h_bl = [h[0] for h in h_bl]  # shard-local views [rows_per+1, ·]
            a_bl = [a[0] for a in a_bl]
            nct_bl = [c[0] for c in nct_bl]
            idx_s, flt_s, msk_s = idx_s[0], flt_s[0], msk_s[0]
            pal = tuple(tuple(x[0] for x in tr) for tr in pal)
            comms = tuple((sp_[0], rp_[0]) for sp_, rp_ in comms)
            lo = lax.axis_index(axis) * rows_per

            h0_old = h_bl[0]
            if slayout.feat_cap:
                fr = idx_r[: slayout.feat_cap]
                fm = msk_r & (fr >= lo) & (fr < lo + rows_per)
                li = jnp.where(fm, fr - lo, rows_per)  # not owned → scratch
                vals = jnp.where(fm[:, None], fvals.astype(h0_old.dtype), h0_old[li])
                h0_new = h0_old.at[li].set(vals)
            else:
                h0_new = h0_old

            h_prev_old, h_prev_new = h0_old, h0_new
            hs = [h0_new]
            as_, ncts = [], []
            for l in range(len(slayout.caps)):
                # ---- halo exchange: frontier source rows only ----
                d_prev = h_prev_old.shape[1]
                halo_cap = slayout.caps[l][5]
                if use_ppermute and S > 1:
                    # per-consumer rotation rounds: round k moves pair
                    # (owner j → consumer (j+k) mod S); send pads gather
                    # the scratch row, recv pads land in the dump row
                    # (index halo_cap, sliced off).  Positions no consumer
                    # receives stay zero — this shard never gathers them.
                    send_pos, recv_pos = comms[l]
                    buf = jnp.zeros((halo_cap + 1, 2 * d_prev),
                                    h_prev_old.dtype)
                    for k in range(1, S):
                        perm = rotation_perm(S, k)
                        sp_ = send_pos[k - 1]
                        cat = jnp.concatenate(
                            [h_prev_old[sp_], h_prev_new[sp_]], axis=1)
                        rec = lax.ppermute(cat, axis, perm)
                        buf = buf.at[recv_pos[k - 1]].set(rec)
                    halo = buf[:halo_cap]
                else:
                    halo_rows = idx_r[halo_sl[l]]  # global ids, pad → -1
                    own = (halo_rows >= lo) & (halo_rows < lo + rows_per)
                    pos = jnp.where(own, halo_rows - lo, rows_per)
                    cat = jnp.concatenate(
                        [h_prev_old[pos], h_prev_new[pos]], axis=1)
                    halo = lax.psum(jnp.where(own[:, None], cat, 0.0), axis)
                ws_old = jnp.concatenate([halo[:, :d_prev], h_prev_old], axis=0)
                ws_new = jnp.concatenate([halo[:, d_prev:], h_prev_new], axis=0)

                gi = {k: idx_s[s] for k, s in idx_sl[l].items()}
                gf = {k: flt_s[s] for k, s in flt_sl[l].items()}
                gm = {k: msk_s[s] for k, s in msk_sl[l].items()}
                an, nn, hn = _layer_body(
                    model, prm[l], ws_old, ws_new, gf["deg_old"], gf["deg_new"],
                    a_bl[l], nct_bl[l], h_bl[l + 1],
                    gi["e_src"], gi["e_dst"], gi["e_rowidx"], gf["e_sign"],
                    gm["e_use_new"], gf["e_w"], gi["e_t"], gm["e_mask"],
                    gi["touch_rows"], gm["touch_mask"],
                    gi["f_rows"], gm["f_mask"], gi["f_src"], gi["f_rowidx"],
                    gf["f_w"], gi["f_t"], gm["f_emask"],
                    gi["out_rows"], gm["out_mask"],
                    f_rows_h=gi["f_rows_h"], out_rows_h=gi["out_rows_h"],
                    pallas_delta=pal[l] if use_pallas else None,
                )
                an = an.at[rows_per].set(0.0)  # re-zero local scratch row
                nn = nn.at[rows_per].set(0.0)
                hn = hn.at[rows_per].set(0.0)
                as_.append(an)
                ncts.append(nn)
                hs.append(hn)
                h_prev_old = h_bl[l + 1]
                h_prev_new = hn
            return (
                tuple(h[None] for h in hs),
                tuple(a[None] for a in as_),
                tuple(c[None] for c in ncts),
            )

        sh = P(axis)  # leading shard dim
        rep = P()
        fn = shard_map(
            local,
            mesh=mesh,
            in_specs=(rep, sh, sh, sh, sh, sh, sh, rep, rep, rep, sh, sh),
            out_specs=(sh, sh, sh),
            check_rep=False,
        )
        return fn(params, h_blocks, a_blocks, nct_blocks, idx_sh, flt_sh, msk_sh,
                  idx_rep, msk_rep, feat_vals, pallas_sh, comms_sh)

    return step


# ====================================================================== #
# Hybrid compact layer step — the sharded-offload backend's device kernel
# ====================================================================== #
@lru_cache(maxsize=None)
def hybrid_layer_step_fn(model: GNNModel, mesh, axis: str):
    """Build (and cache per (model, mesh)) the jitted shard_map'd *compact*
    layer step for the sharded-offload hybrid.

    Every input is a stacked ``[S, cap, ·]`` staging buffer: each shard's
    slice holds only the compact ``[halo | local]`` workspace rows its plan
    touches — never the persistent state, which stays host-resident.  There
    is **no collective**: halo rows were already gathered from the owning
    shards' host blocks at staging time (since ISSUE 5 that gather runs on
    the :class:`~repro.serve.staging.HostStagingPipeline` worker, one layer
    ahead of the device), so each shard just runs the unmodified
    :func:`_layer_body` over its compact slice (one scratch row appended at
    index cap, exactly like the offloaded engine's compact views).  One
    trace per :class:`~repro.core.affected.HybridLayerLayout`.  The step is
    deliberately **not** donated: the staged buffers are double-buffered
    host views whose device copies the caller may still be shipping while
    the previous dispatch executes."""

    @partial(jax.jit, static_argnums=(0,))
    def step(
        llayout: HybridLayerLayout,
        p: Params,
        h_old_rows: jax.Array,  # [S, nh_cap, d_in] staged h^{l-1} (old view)
        h_new_rows: jax.Array,  # [S, nh_cap, d_in] staged h^{l-1} (new view)
        a_rows: jax.Array,  # [S, ns_cap, agg] staged aggregation state
        nct_rows: jax.Array,  # [S, ns_cap, C]
        h_cur_rows: jax.Array,  # [S, ns_cap, d_out]
        idx_sh: jax.Array,  # int32  [S, idx_len]
        flt_sh: jax.Array,  # float32 [S, flt_len]
        msk_sh: jax.Array,  # bool   [S, msk_len]
    ):
        idx_sl, flt_sl, msk_sl, _ = hybrid_layout_slices(llayout)
        ns_cap = llayout.caps[6]

        def local(p, h_old, h_new, a_r, nct_r, h_cur, idx_s, flt_s, msk_s):
            h_old, h_new = h_old[0], h_new[0]
            a_r, nct_r, h_cur = a_r[0], nct_r[0], h_cur[0]
            idx_s, flt_s, msk_s = idx_s[0], flt_s[0], msk_s[0]
            gi = {k: idx_s[sl] for k, sl in idx_sl.items()}
            gf = {k: flt_s[sl] for k, sl in flt_sl.items()}
            gm = {k: msk_s[sl] for k, sl in msk_sl.items()}
            an, nn, hn = _layer_body(
                model, p, with_scratch(h_old), with_scratch(h_new),
                gf["deg_old"], gf["deg_new"],
                with_scratch(a_r), with_scratch(nct_r), with_scratch(h_cur),
                gi["e_src"], gi["e_dst"], gi["e_rowidx"], gf["e_sign"],
                gm["e_use_new"], gf["e_w"], gi["e_t"], gm["e_mask"],
                gi["touch_rows"], gm["touch_mask"],
                gi["f_rows"], gm["f_mask"], gi["f_src"], gi["f_rowidx"],
                gf["f_w"], gi["f_t"], gm["f_emask"],
                gi["out_rows"], gm["out_mask"],
                f_rows_h=gi["f_rows_h"], out_rows_h=gi["out_rows_h"],
            )
            return an[None, :ns_cap], nn[None, :ns_cap], hn[None, :ns_cap]

        sh = P(axis)
        fn = shard_map(
            local,
            mesh=mesh,
            in_specs=(P(), sh, sh, sh, sh, sh, sh, sh, sh),
            out_specs=(sh, sh, sh),
            check_rep=False,
        )
        return fn(p, h_old_rows, h_new_rows, a_rows, nct_rows, h_cur_rows,
                  idx_sh, flt_sh, msk_sh)

    return step
