"""The Table-II model zoo, decoupled for incremental RTEC.

Eleven models: the paper's ten representative incrementalizable models
(MoNet, CommNet, GCN, GraphSAGE, PinSAGE, RGCN, GAT, G-GCN, A-GNN, RGAT)
plus GIN (used throughout the paper's evaluation, Fig. 4).

Conventions (documented deviations from the paper, see DESIGN.md §4):
  * Graphs are directed; ``degree`` = in-degree.  GCN normalization uses the
    self-loop convention d̃ = d + 1 so isolated sources are well-defined.
  * GAT attention sums keep raw exp() values like the paper (Alg. 3); logits
    pass through a bounded LeakyReLU so fp32 exp cannot overflow for
    unit-scale inputs; equivalence tests cover 100+ batch streams.
  * Old per-edge messages are *recomputed from the retained old embeddings*
    rather than cached per edge (O(V·D) state instead of O(E·D)), which is
    semantically identical.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.operators import GNNModel, glorot

_EPS = 1e-12
# Empty-neighborhood guard thresholds (DESIGN.md §4): when a context sum
# drains to ~0 (all in-edges deleted), x/nct would amplify the fp residue of
# ms_cbn⁻¹ by 1/eps.  Both ms_cbn and ms_cbn⁻¹ therefore clamp to exactly 0
# below the threshold — full and incremental paths share the same guard, so
# Theorem-1 equivalence is preserved bit-for-bit in the guard region.
_COUNT_THRESH = 0.5  # counts are integers: <0.5 ⟺ empty
_ATTN_THRESH = 1e-10  # attention sums are ≥ exp(-30) ≈ 9e-14 per edge


def _div_guard(x, nct, thresh):
    live = nct > thresh
    return jnp.where(live, x / jnp.where(live, nct, 1.0), 0.0)


def _mul_guard(x, nct, thresh):
    live = nct > thresh
    return jnp.where(live, x * nct, 0.0)


# ====================================================================== #
# Fully incrementalizable models
# ====================================================================== #
class GCN(GNNModel):
    """msg_local = 1/sqrt(d̃_u); nbr_ctx = count; ms_cbn = x/sqrt(ñct)."""

    name = "gcn"
    src_struct_dependent = True

    def init_params(self, key, d_in, d_out):
        kw, _ = jax.random.split(key)
        return {"W": glorot(kw, (d_in, d_out)), "b": jnp.zeros((d_out,))}

    def ms_local(self, p, h_u, h_v, s_u, s_v, ew, et):
        return 1.0 / jnp.sqrt(s_u + 1.0)

    def edge_term(self, p, mlc, z, et):
        return mlc[:, None] * z

    def ms_cbn(self, p, nct, x):
        return x / jnp.sqrt(nct[:, :1] + 1.0)

    def ms_cbn_inv(self, p, nct, x):
        return x * jnp.sqrt(nct[:, :1] + 1.0)

    def update(self, p, h_v, a_v):
        return jax.nn.relu(a_v @ p["W"] + p["b"])


class GraphSAGE(GNNModel):
    """Mean aggregation decomposed into sum / count (paper §IV-D)."""

    name = "sage"
    update_uses_h = True

    def init_params(self, key, d_in, d_out):
        k1, k2 = jax.random.split(key)
        return {
            "W_self": glorot(k1, (d_in, d_out)),
            "W_nbr": glorot(k2, (d_in, d_out)),
            "b": jnp.zeros((d_out,)),
        }

    def ms_local(self, p, h_u, h_v, s_u, s_v, ew, et):
        return jnp.ones_like(s_u)

    def edge_term(self, p, mlc, z, et):
        return mlc[:, None] * z

    def ms_cbn(self, p, nct, x):
        return _div_guard(x, nct[:, :1], _COUNT_THRESH)

    def ms_cbn_inv(self, p, nct, x):
        return _mul_guard(x, nct[:, :1], _COUNT_THRESH)

    def update(self, p, h_v, a_v):
        return jax.nn.relu(h_v @ p["W_self"] + a_v @ p["W_nbr"] + p["b"])


class GIN(GNNModel):
    """Constant message, sum aggregation, MLP update (Fig. 4)."""

    name = "gin"
    update_uses_h = True
    has_ctx = False

    def init_params(self, key, d_in, d_out):
        k1, k2 = jax.random.split(key)
        dh = max(d_in, d_out)
        return {
            "W1": glorot(k1, (d_in, dh)),
            "b1": jnp.zeros((dh,)),
            "W2": glorot(k2, (dh, d_out)),
            "b2": jnp.zeros((d_out,)),
            "eps": jnp.asarray(0.1, jnp.float32),
        }

    def ms_local(self, p, h_u, h_v, s_u, s_v, ew, et):
        return jnp.ones_like(s_u)

    def edge_term(self, p, mlc, z, et):
        return mlc[:, None] * z

    def update(self, p, h_v, a_v):
        x = (1.0 + p["eps"]) * h_v + a_v
        return jax.nn.relu(jax.nn.relu(x @ p["W1"] + p["b1"]) @ p["W2"] + p["b2"])


class CommNet(GNNModel):
    name = "commnet"
    update_uses_h = True
    has_ctx = False

    def init_params(self, key, d_in, d_out):
        k1, k2 = jax.random.split(key)
        return {"W1": glorot(k1, (d_in, d_out)), "W2": glorot(k2, (d_in, d_out))}

    def ms_local(self, p, h_u, h_v, s_u, s_v, ew, et):
        return jnp.ones_like(s_u)

    def edge_term(self, p, mlc, z, et):
        return mlc[:, None] * z

    def update(self, p, h_v, a_v):
        return jnp.tanh(h_v @ p["W1"] + a_v @ p["W2"])


class MoNet(GNNModel):
    """K Gaussian kernels over the source embedding (Table II row 1).

    edge_term lays the state out as [E, K*d_in]: kernel-weighted copies of
    h_u; update mixes them with a (K*d_in → d_out) linear layer."""

    name = "monet"
    has_ctx = False

    def __init__(self, kernels: int = 2):
        self.K = kernels

    def agg_dim(self, d_in, d_out):
        return self.K * d_in

    def init_params(self, key, d_in, d_out):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "mu": jax.random.normal(k1, (self.K, d_in)) * 0.5,
            "sigma": jnp.ones((self.K, d_in)),
            "W": glorot(k2, (self.K * d_in, d_out)),
            "b": jnp.zeros((d_out,)),
        }

    def ms_local(self, p, h_u, h_v, s_u, s_v, ew, et):
        # [E, K] gaussian kernel weights
        diff = h_u[:, None, :] - p["mu"][None, :, :]
        q = jnp.sum((diff * p["sigma"][None]) ** 2, axis=-1)
        return jnp.exp(-0.5 * q)

    def edge_term(self, p, mlc, z, et):
        # [E,K,1] * [E,1,D] → [E, K*D]
        out = mlc[:, :, None] * z[:, None, :]
        return out.reshape(z.shape[0], -1)

    def update(self, p, h_v, a_v):
        return jax.nn.relu(a_v @ p["W"] + p["b"])


class PinSAGE(GNNModel):
    """Importance-weighted (edge-weight α) message with mean decomposition."""

    name = "pinsage"
    update_uses_h = True

    def agg_dim(self, d_in, d_out):
        return d_out

    def init_params(self, key, d_in, d_out):
        k1, k2 = jax.random.split(key)
        return {
            "Q": glorot(k1, (d_in, d_out)),
            "q": jnp.zeros((d_out,)),
            "W": glorot(k2, (d_in + d_out, d_out)),
            "b": jnp.zeros((d_out,)),
        }

    def ms_local(self, p, h_u, h_v, s_u, s_v, ew, et):
        # α_uv · σ(Q h_u + q) — [E, d_out]
        return ew[:, None] * jax.nn.relu(h_u @ p["Q"] + p["q"])

    def f_nn(self, p, h_u, et):
        return jnp.ones((h_u.shape[0], 1), h_u.dtype)  # f_nn = 1 (Table II)

    def edge_term(self, p, mlc, z, et):
        return mlc * z  # z is 1

    def ms_cbn(self, p, nct, x):
        return _div_guard(x, nct[:, :1], _COUNT_THRESH)

    def ms_cbn_inv(self, p, nct, x):
        return _mul_guard(x, nct[:, :1], _COUNT_THRESH)

    def update(self, p, h_v, a_v):
        return jax.nn.relu(jnp.concatenate([h_v, a_v], axis=-1) @ p["W"] + p["b"])


class RGCN(GNNModel):
    """Relational GCN: per-relation mean, state laid out as [V, R*d_out]."""

    name = "rgcn"
    update_uses_h = True

    def __init__(self, num_relations: int = 3):
        self.R = num_relations

    def agg_dim(self, d_in, d_out):
        return self.R * d_out

    def ctx_dim(self, d_in, d_out):
        return self.R

    def init_params(self, key, d_in, d_out):
        k1, k2 = jax.random.split(key)
        return {
            "Wr": glorot(k1, (self.R, d_in, d_out)),
            "Wo": glorot(k2, (d_in, d_out)),
            "b": jnp.zeros((d_out,)),
        }

    def ms_local(self, p, h_u, h_v, s_u, s_v, ew, et):
        return jnp.ones_like(s_u)

    def ctx_contrib(self, p, mlc, et):
        # per-relation count: one-hot over relations [E, R]
        return jax.nn.one_hot(et, self.R, dtype=jnp.float32)

    def f_nn(self, p, h_u, et):
        return jnp.einsum("ed,edo->eo", h_u, p["Wr"][et])

    def edge_term(self, p, mlc, z, et):
        # route W_r h_u into its relation block: [E, R*d_out]
        oh = jax.nn.one_hot(et, self.R, dtype=z.dtype)
        return (oh[:, :, None] * z[:, None, :]).reshape(z.shape[0], -1)

    def ms_cbn(self, p, nct, x):
        v, rd = x.shape
        xr = x.reshape(v, self.R, rd // self.R)
        return _div_guard(xr, nct[:, :, None], _COUNT_THRESH).reshape(v, rd)

    def ms_cbn_inv(self, p, nct, x):
        v, rd = x.shape
        xr = x.reshape(v, self.R, rd // self.R)
        return _mul_guard(xr, nct[:, :, None], _COUNT_THRESH).reshape(v, rd)

    def update(self, p, h_v, a_v):
        d_out = p["Wo"].shape[1]
        s = a_v.reshape(a_v.shape[0], self.R, d_out).sum(axis=1)
        return jax.nn.relu(h_v @ p["Wo"] + s + p["b"])


# ====================================================================== #
# Constrained incremental models (destination-dependent messages, §IV-C)
# ====================================================================== #
class GAT(GNNModel):
    """Multi-head attention; softmax decoupled into exp / sum / normalize
    (paper Alg. 2–3).  State a_v is [V, H*dh]; nct_v the per-head attention
    sum [V, H]."""

    name = "gat"
    dest_dependent = True

    def __init__(self, heads: int = 2):
        self.H = heads

    def agg_dim(self, d_in, d_out):
        return d_out  # d_out = H * dh

    def ctx_dim(self, d_in, d_out):
        return self.H

    def init_params(self, key, d_in, d_out):
        assert d_out % self.H == 0, "d_out must be divisible by heads"
        dh = d_out // self.H
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "W": glorot(k1, (d_in, d_out)),
            "a_src": jax.random.normal(k2, (self.H, dh)) * 0.1,
            "a_dst": jax.random.normal(k3, (self.H, dh)) * 0.1,
        }

    def _logits(self, p, h_u, h_v):
        dh = p["a_src"].shape[1]
        wu = (h_u @ p["W"]).reshape(-1, self.H, dh)
        wv = (h_v @ p["W"]).reshape(-1, self.H, dh)
        lg = jnp.sum(wu * p["a_src"][None], -1) + jnp.sum(wv * p["a_dst"][None], -1)
        # bounded LeakyReLU keeps exp() in fp32 range (DESIGN.md §4)
        return jnp.clip(jax.nn.leaky_relu(lg, 0.2), -30.0, 30.0)

    def ms_local(self, p, h_u, h_v, s_u, s_v, ew, et):
        return jnp.exp(self._logits(p, h_u, h_v))  # [E, H]

    def ctx_contrib(self, p, mlc, et):
        return mlc  # attention sum

    def f_nn(self, p, h_u, et):
        return h_u @ p["W"]  # [E, H*dh]

    def edge_term(self, p, mlc, z, et):
        e = z.shape[0]
        zr = z.reshape(e, self.H, -1)
        return (mlc[:, :, None] * zr).reshape(e, -1)

    def ms_cbn(self, p, nct, x):
        v, d = x.shape
        xr = x.reshape(v, self.H, d // self.H)
        return _div_guard(xr, nct[:, :, None], _ATTN_THRESH).reshape(v, d)

    def ms_cbn_inv(self, p, nct, x):
        v, d = x.shape
        xr = x.reshape(v, self.H, d // self.H)
        return _mul_guard(xr, nct[:, :, None], _ATTN_THRESH).reshape(v, d)

    def update(self, p, h_v, a_v):
        return jax.nn.elu(a_v)


class AGNN(GNNModel):
    """Attention-free cosine-similarity propagation (Table II row A-GNN)."""

    name = "agnn"
    dest_dependent = True
    has_ctx = False

    def init_params(self, key, d_in, d_out):
        k1, _ = jax.random.split(key)
        return {"beta": jnp.asarray(1.0, jnp.float32), "W": glorot(k1, (d_in, d_out))}

    def ms_local(self, p, h_u, h_v, s_u, s_v, ew, et):
        nu = jnp.linalg.norm(h_u, axis=-1)
        nv = jnp.linalg.norm(h_v, axis=-1)
        cos = jnp.sum(h_u * h_v, -1) / jnp.maximum(nu * nv, _EPS)
        return p["beta"] * cos

    def edge_term(self, p, mlc, z, et):
        return mlc[:, None] * z

    def update(self, p, h_v, a_v):
        return jnp.tanh(a_v @ p["W"])


class GGCN(GNNModel):
    """Gated GCN: gate = σ(W1 h_u + W2 h_v) elementwise on the message."""

    name = "ggcn"
    dest_dependent = True
    has_ctx = False

    def init_params(self, key, d_in, d_out):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "W1": glorot(k1, (d_in, d_in)),
            "W2": glorot(k2, (d_in, d_in)),
            "W": glorot(k3, (d_in, d_out)),
            "b": jnp.zeros((d_out,)),
        }

    def ms_local(self, p, h_u, h_v, s_u, s_v, ew, et):
        return jax.nn.sigmoid(h_u @ p["W1"] + h_v @ p["W2"])  # [E, d_in]

    def edge_term(self, p, mlc, z, et):
        return mlc * z

    def update(self, p, h_v, a_v):
        return jnp.tanh(a_v @ p["W"] + p["b"])


class RGAT(GNNModel):
    """Relational GAT: per-relation attention; state [V, R*d_out], nct [V, R]."""

    name = "rgat"
    dest_dependent = True

    def __init__(self, num_relations: int = 3):
        self.R = num_relations

    def agg_dim(self, d_in, d_out):
        return self.R * d_out

    def ctx_dim(self, d_in, d_out):
        return self.R

    def init_params(self, key, d_in, d_out):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "Wr": glorot(k1, (self.R, d_in, d_out)),
            "a_src": jax.random.normal(k2, (self.R, d_out)) * 0.1,
            "a_dst": jax.random.normal(k3, (self.R, d_out)) * 0.1,
        }

    def ms_local(self, p, h_u, h_v, s_u, s_v, ew, et):
        wu = jnp.einsum("ed,edo->eo", h_u, p["Wr"][et])
        wv = jnp.einsum("ed,edo->eo", h_v, p["Wr"][et])
        lg = jnp.sum(wu * p["a_src"][et], -1) + jnp.sum(wv * p["a_dst"][et], -1)
        return jnp.exp(jnp.clip(jax.nn.leaky_relu(lg, 0.2), -30.0, 30.0))  # [E]

    def ctx_contrib(self, p, mlc, et):
        return jax.nn.one_hot(et, self.R, dtype=jnp.float32) * mlc[:, None]

    def f_nn(self, p, h_u, et):
        return jnp.einsum("ed,edo->eo", h_u, p["Wr"][et])

    def edge_term(self, p, mlc, z, et):
        oh = jax.nn.one_hot(et, self.R, dtype=z.dtype)
        return (oh[:, :, None] * (mlc[:, None] * z)[:, None, :]).reshape(z.shape[0], -1)

    def ms_cbn(self, p, nct, x):
        v, rd = x.shape
        xr = x.reshape(v, self.R, rd // self.R)
        return _div_guard(xr, nct[:, :, None], _ATTN_THRESH).reshape(v, rd)

    def ms_cbn_inv(self, p, nct, x):
        v, rd = x.shape
        xr = x.reshape(v, self.R, rd // self.R)
        return _mul_guard(xr, nct[:, :, None], _ATTN_THRESH).reshape(v, rd)

    def update(self, p, h_v, a_v):
        d_out = p["Wr"].shape[2]
        s = a_v.reshape(a_v.shape[0], self.R, d_out).sum(axis=1)
        return jnp.tanh(s)


# ====================================================================== #
# registry
# ====================================================================== #
def make_model(name: str, **kw) -> GNNModel:
    registry: Dict[str, type] = {
        "gcn": GCN,
        "sage": GraphSAGE,
        "gin": GIN,
        "commnet": CommNet,
        "monet": MoNet,
        "pinsage": PinSAGE,
        "rgcn": RGCN,
        "gat": GAT,
        "agnn": AGNN,
        "ggcn": GGCN,
        "rgat": RGAT,
    }
    if name not in registry:
        raise KeyError(f"unknown GNN model {name!r}; have {sorted(registry)}")
    return registry[name](**kw)


ALL_MODELS = [
    "gcn",
    "sage",
    "gin",
    "commnet",
    "monet",
    "pinsage",
    "rgcn",
    "gat",
    "agnn",
    "ggcn",
    "rgat",
]
