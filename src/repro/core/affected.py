"""Affected-subgraph construction — paper Alg. 4, host side.

Per layer, classifies work into:

  * **incremental records** — signed per-edge delta contributions
    (insert → (+, new side), delete → (−, old side), changed source /
    changed structural context → a (−, old) / (+, new) pair), consumed by
    the device-side Alg.-1 kernel; and
  * **full-recompute vertices** — for constrained (destination-dependent)
    models, vertices whose previous-layer embedding changed and that still
    have in-edges must be fully recomputed over their complete new
    in-neighborhood (paper Alg. 4 lines 5–7).  Incremental records targeting
    these vertices are suppressed to avoid double counting.

All index arrays are padded to power-of-two buckets (``next_bucket``) so the
device functions re-trace only O(log) times over a stream.  Padded gather
indices point at a scratch row (index n) and padded scatter rows at the
capacity slot, so they can never alias live data.
"""
from __future__ import annotations

import dataclasses
import math
from functools import lru_cache
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.full import next_bucket
from repro.core.operators import GNNModel
from repro.graph.csr import CSRGraph
from repro.graph.streaming import UpdateBatch


@dataclasses.dataclass
class LayerPlan:
    # --- incremental signed records (padded to e_cap) ---
    e_src: np.ndarray  # int32 [Ecap], pad → n (scratch)
    e_dst: np.ndarray  # int32 [Ecap], pad → n
    e_rowidx: np.ndarray  # int32 [Ecap] index into touch_rows, pad → r_cap
    e_sign: np.ndarray  # float32 [Ecap]
    e_use_new: np.ndarray  # bool [Ecap]
    e_w: np.ndarray  # float32
    e_t: np.ndarray  # int32
    e_mask: np.ndarray  # bool
    # --- rows whose aggregation state is updated incrementally ---
    touch_rows: np.ndarray  # int32 [Rcap], pad → n
    touch_mask: np.ndarray  # bool
    # --- constrained full-recompute path ---
    f_rows: np.ndarray  # int32 [Fcap], pad → n
    f_mask: np.ndarray
    f_src: np.ndarray  # int32 [FEcap], pad → n
    f_rowidx: np.ndarray  # int32 [FEcap] into f_rows, pad → f_cap
    f_w: np.ndarray
    f_t: np.ndarray
    f_emask: np.ndarray
    # --- rows whose h^l changes ---
    out_rows: np.ndarray  # int32 [Ocap], pad → n
    out_mask: np.ndarray
    # --- accounting (paper Figs. 2/8/11 metrics) ---
    n_inc_edges: int = 0
    n_full_edges: int = 0
    n_touch_rows: int = 0
    n_full_rows: int = 0
    n_out_rows: int = 0
    n_src_accessed: int = 0

    @property
    def shape_key(self) -> Tuple[int, ...]:
        return (
            self.e_src.shape[0],
            self.touch_rows.shape[0],
            self.f_rows.shape[0],
            self.f_src.shape[0],
            self.out_rows.shape[0],
        )


@dataclasses.dataclass
class BatchPlan:
    layers: List[LayerPlan]
    deg_old: np.ndarray  # float32 [n+1] (scratch slot appended)
    deg_new: np.ndarray
    changed0: np.ndarray  # vertices with feature updates

    def total_inc_edges(self) -> int:
        return sum(p.n_inc_edges for p in self.layers)

    def total_full_edges(self) -> int:
        return sum(p.n_full_edges for p in self.layers)

    def total_vertices(self) -> int:
        return sum(p.n_out_rows for p in self.layers)


def final_write_rows(plan: BatchPlan) -> np.ndarray:
    """Global ids of the final-layer rows a batch's execution may write.

    ``out_rows`` is the planner's "rows whose h^L changes" set, so the
    serving front-end can snapshot exactly these rows *before* dispatch and
    reconstruct any retained version bitwise (repro.serve.frontend): every
    row outside this set keeps its pre-batch value untouched."""
    lp = plan.layers[-1]
    return np.unique(lp.out_rows[lp.out_mask].astype(np.int64))


def _lookup_in_edge_data(g: CSRGraph, src: np.ndarray, dst: np.ndarray):
    """Vectorized (weight, etype) lookup for existing edges (u, v)."""
    w = np.empty(src.shape[0], np.float32)
    t = np.empty(src.shape[0], np.int32)
    for i, (u, v) in enumerate(zip(src, dst)):
        nbrs, ws, ts = g.in_edge_data(int(v))
        j = np.searchsorted(nbrs, u)
        assert j < nbrs.shape[0] and nbrs[j] == u, f"edge ({u},{v}) missing"
        w[i] = ws[j]
        t[i] = ts[j]
    return w, t


def _pad_records(
    n: int,
    src: np.ndarray,
    dst: np.ndarray,
    sign: np.ndarray,
    use_new: np.ndarray,
    w: np.ndarray,
    t: np.ndarray,
) -> Tuple[np.ndarray, ...]:
    e = src.shape[0]
    e_cap = next_bucket(e)
    rows, rowinv = np.unique(dst, return_inverse=True) if e else (np.zeros(0, np.int64), np.zeros(0, np.int64))
    r_cap = next_bucket(rows.shape[0])

    def pad(a, cap, fill, dt):
        out = np.full(cap, fill, dtype=dt)
        out[: a.shape[0]] = a
        return out

    return (
        pad(src, e_cap, n, np.int32),
        pad(dst, e_cap, n, np.int32),
        pad(rowinv, e_cap, r_cap, np.int32),
        pad(sign, e_cap, 0.0, np.float32),
        pad(use_new, e_cap, False, bool),
        pad(w, e_cap, 0.0, np.float32),
        pad(t, e_cap, 0, np.int32),
        pad(np.ones(e, bool), e_cap, False, bool),
        pad(rows, r_cap, n, np.int32),
        pad(np.ones(rows.shape[0], bool), r_cap, False, bool),
    )


def build_plan(
    model: GNNModel,
    g_old: CSRGraph,
    g_new: CSRGraph,
    batch: UpdateBatch,
    num_layers: int,
    restrict: Optional[List[set]] = None,
) -> BatchPlan:
    """Build per-layer incremental plans.

    ``restrict`` (ODEC, paper §V-D): optional per-layer vertex sets; layer
    l's work is intersected with ``restrict[l]`` (the query-induced K-hop
    cone), turning RTEC into on-demand embedding computation."""
    n = g_old.n
    deg_old = g_old.in_degree().astype(np.float32)
    deg_new = g_new.in_degree().astype(np.float32)
    deg_changed = np.nonzero(deg_old != deg_new)[0]

    ins_s = np.asarray(batch.ins_src, np.int64)
    ins_d = np.asarray(batch.ins_dst, np.int64)
    ins_w = (
        np.asarray(batch.ins_weights, np.float32)
        if batch.ins_weights is not None
        else np.ones(ins_s.shape[0], np.float32)
    )
    ins_t = (
        np.asarray(batch.ins_etypes, np.int32)
        if batch.ins_etypes is not None
        else np.zeros(ins_s.shape[0], np.int32)
    )
    del_s = np.asarray(batch.del_src, np.int64)
    del_d = np.asarray(batch.del_dst, np.int64)
    if del_s.size:
        del_w, del_t = _lookup_in_edge_data(g_old, del_s, del_d)
    else:
        del_w = np.zeros(0, np.float32)
        del_t = np.zeros(0, np.int32)
    inserted_keys = set(zip(ins_s.tolist(), ins_d.tolist()))

    changed0 = (
        np.asarray(batch.feat_vertices, np.int64)
        if batch.feat_vertices is not None
        else np.zeros(0, np.int64)
    )
    changed_h = changed0  # vertices whose h^{l-1} changed
    deg_new_int = g_new.in_degree()

    plans: List[LayerPlan] = []
    for layer_idx in range(num_layers):
        allowed = restrict[layer_idx] if restrict is not None else None
        changed_set = set(changed_h.tolist())
        # sources whose outgoing contributions changed
        c_src = set(changed_set)
        if model.src_struct_dependent:
            c_src |= set(deg_changed.tolist())
        # constrained full-recompute destinations
        if model.dest_dependent:
            v_full = np.array(
                sorted(
                    v
                    for v in changed_set
                    if deg_new_int[v] > 0 and (allowed is None or v in allowed)
                ),
                np.int64,
            )
        else:
            v_full = np.zeros(0, np.int64)
        v_full_set = set(v_full.tolist())

        # ---- incremental records ----
        rs, rd, rsign, rnew, rw, rt = [], [], [], [], [], []
        n_changed_edges = 0

        def _emit(s, d, sign, usenew, w, t):
            rs.append(s)
            rd.append(d)
            rsign.append(sign)
            rnew.append(usenew)
            rw.append(w)
            rt.append(t)

        def _allowed(d: int) -> bool:
            return allowed is None or d in allowed

        for i in range(ins_s.shape[0]):
            if int(ins_d[i]) not in v_full_set and _allowed(int(ins_d[i])):
                _emit(ins_s[i], ins_d[i], 1.0, True, ins_w[i], ins_t[i])
        for i in range(del_s.shape[0]):
            if int(del_d[i]) not in v_full_set and _allowed(int(del_d[i])):
                _emit(del_s[i], del_d[i], -1.0, False, del_w[i], del_t[i])
        for u in sorted(c_src):
            nbrs, ws, ts = g_new.out_edge_data(int(u))
            for j in range(nbrs.shape[0]):
                d = int(nbrs[j])
                if (int(u), d) in inserted_keys or d in v_full_set or not _allowed(d):
                    continue
                _emit(u, d, -1.0, False, ws[j], ts[j])
                _emit(u, d, 1.0, True, ws[j], ts[j])
                n_changed_edges += 1

        rec = _pad_records(
            n,
            np.array(rs, np.int64),
            np.array(rd, np.int64),
            np.array(rsign, np.float32),
            np.array(rnew, bool),
            np.array(rw, np.float32),
            np.array(rt, np.int32),
        )
        (e_src, e_dst, e_rowidx, e_sign, e_use_new, e_w, e_t, e_mask, touch_rows, touch_mask) = rec

        # ---- constrained full path ----
        f_srcs, f_ridx, f_ws, f_ts = [], [], [], []
        for ri, v in enumerate(v_full):
            nbrs, ws, ts = g_new.in_edge_data(int(v))
            f_srcs.extend(nbrs.tolist())
            f_ridx.extend([ri] * nbrs.shape[0])
            f_ws.extend(ws.tolist())
            f_ts.extend(ts.tolist())
        f_cap = next_bucket(v_full.shape[0])
        fe_cap = next_bucket(len(f_srcs))

        def padv(a, cap, fill, dt):
            out = np.full(cap, fill, dtype=dt)
            out[: len(a)] = a
            return out

        f_rows = padv(v_full, f_cap, n, np.int32)
        f_mask = padv(np.ones(v_full.shape[0], bool), f_cap, False, bool)
        f_src = padv(f_srcs, fe_cap, n, np.int32)
        f_rowidx = padv(f_ridx, fe_cap, f_cap, np.int32)
        f_w = padv(f_ws, fe_cap, 0.0, np.float32)
        f_t = padv(f_ts, fe_cap, 0, np.int32)
        f_emask = padv(np.ones(len(f_srcs), bool), fe_cap, False, bool)

        # ---- output rows ----
        out_set = set(touch_rows[touch_mask].tolist()) | v_full_set
        if model.update_uses_h:
            out_set |= changed_set if allowed is None else (changed_set & allowed)
        out = np.array(sorted(out_set), np.int64)
        o_cap = next_bucket(out.shape[0])
        out_rows = padv(out, o_cap, n, np.int32)
        out_mask = padv(np.ones(out.shape[0], bool), o_cap, False, bool)

        n_inc = ins_s.shape[0] + del_s.shape[0] + n_changed_edges
        srcs_accessed = len(set(rs) | set(f_srcs))
        plans.append(
            LayerPlan(
                e_src=e_src,
                e_dst=e_dst,
                e_rowidx=e_rowidx,
                e_sign=e_sign,
                e_use_new=e_use_new,
                e_w=e_w,
                e_t=e_t,
                e_mask=e_mask,
                touch_rows=touch_rows,
                touch_mask=touch_mask,
                f_rows=f_rows,
                f_mask=f_mask,
                f_src=f_src,
                f_rowidx=f_rowidx,
                f_w=f_w,
                f_t=f_t,
                f_emask=f_emask,
                out_rows=out_rows,
                out_mask=out_mask,
                n_inc_edges=n_inc,
                n_full_edges=len(f_srcs),
                n_touch_rows=int(touch_mask.sum()),
                n_full_rows=int(v_full.shape[0]),
                n_out_rows=int(out.shape[0]),
                n_src_accessed=srcs_accessed,
            )
        )
        changed_h = out

    deg_old_x = np.concatenate([deg_old, np.zeros(1, np.float32)])
    deg_new_x = np.concatenate([deg_new, np.zeros(1, np.float32)])
    return BatchPlan(layers=plans, deg_old=deg_old_x, deg_new=deg_new_x, changed0=changed0)


# ====================================================================== #
# Capacity hysteresis — high-water-mark pow-2 buckets (retrace damping)
# ====================================================================== #
class BucketHysteresis:
    """Per-field high-water-mark floors over :func:`next_bucket` capacities.

    Pow-2 bucketing alone still retraces whenever a stream's per-batch work
    oscillates across a bucket boundary (the mid-stream compile visible in
    ``BENCH_smoke`` batch 2): a large batch grows the bucket, the next small
    batch shrinks it back, and both shapes compile.  Holding every field at
    its stream-high-water bucket makes capacities monotone, so the number of
    distinct layouts over a stream is bounded by the number of *growth*
    events only.  One instance per engine (capacities are stream state, not
    plan state)."""

    def __init__(self) -> None:
        self._caps: Dict[object, int] = {}

    def bucket(self, key, size: int, minimum: int = 16) -> int:
        cap = max(next_bucket(size, minimum=minimum), self._caps.get(key, 0))
        self._caps[key] = cap
        return cap

    def snapshot(self) -> Dict[object, int]:
        """Copy of the current per-field capacity floors (tests assert the
        marks stabilize — i.e. no growth event → no retrace)."""
        return dict(self._caps)


def _cap_of(hwm: Optional[BucketHysteresis], key, size: int, minimum: int = 16) -> int:
    if hwm is None:
        return next_bucket(size, minimum=minimum)
    return hwm.bucket(key, size, minimum=minimum)


# ====================================================================== #
# Packed plans — pipelined-engine transfer format (paper §V co-processing)
# ====================================================================== #
# Per-field capacity kind within a layer's cap tuple (e, r, f, fe, o).
IDX_FIELDS: Tuple[Tuple[str, int], ...] = (
    ("e_src", 0), ("e_dst", 0), ("e_rowidx", 0), ("e_t", 0),
    ("touch_rows", 1), ("f_rows", 2), ("f_src", 3), ("f_rowidx", 3),
    ("f_t", 3), ("out_rows", 4),
)
FLT_FIELDS: Tuple[Tuple[str, int], ...] = (("e_sign", 0), ("e_w", 0), ("f_w", 3))
MSK_FIELDS: Tuple[Tuple[str, int], ...] = (
    ("e_mask", 0), ("e_use_new", 0), ("touch_mask", 1), ("f_mask", 2),
    ("f_emask", 3), ("out_mask", 4),
)


@dataclasses.dataclass(frozen=True)
class PackedLayout:
    """Static (hashable) shape descriptor of a packed plan.

    One distinct layout → one trace of the fused device step; the power-of-two
    bucketing in :func:`build_plan` keeps the number of layouts O(log) over a
    stream, exactly like the unfused per-layer functions."""

    n: int  # vertex count (scratch row index)
    feat_cap: int  # 0 → batch has no feature updates (static branch)
    caps: Tuple[Tuple[int, int, int, int, int], ...]  # per layer (e, r, f, fe, o)


@lru_cache(maxsize=None)
def layout_slices(layout: PackedLayout):
    """Static offset table: per-layer field → slice into the packed buffers.

    Returns (idx_slices, flt_slices, msk_slices, totals) where each *_slices
    is a tuple (one per layer) of name → slice dicts, and totals are the
    buffer lengths (idx_len, flt_len, msk_len)."""
    idx_off = layout.feat_cap  # [feat_rows | per-layer idx fields]
    flt_off = 2 * (layout.n + 1)  # [deg_old | deg_new | per-layer flt fields]
    msk_off = layout.feat_cap  # [feat_mask | per-layer msk fields]
    idx_sl, flt_sl, msk_sl = [], [], []
    for caps in layout.caps:
        di: Dict[str, slice] = {}
        for name, kind in IDX_FIELDS:
            di[name] = slice(idx_off, idx_off + caps[kind])
            idx_off += caps[kind]
        df: Dict[str, slice] = {}
        for name, kind in FLT_FIELDS:
            df[name] = slice(flt_off, flt_off + caps[kind])
            flt_off += caps[kind]
        dm: Dict[str, slice] = {}
        for name, kind in MSK_FIELDS:
            dm[name] = slice(msk_off, msk_off + caps[kind])
            msk_off += caps[kind]
        idx_sl.append(di)
        flt_sl.append(df)
        msk_sl.append(dm)
    return tuple(idx_sl), tuple(flt_sl), tuple(msk_sl), (idx_off, flt_off, msk_off)


@dataclasses.dataclass
class PackedPlan:
    """A whole batch's plan flattened into three contiguous host buffers.

    Shipping (idx, flt, msk[, feat_vals]) is one ``jax.device_put`` call per
    batch instead of ~24×L small per-array transfers; the static offset table
    (:func:`layout_slices`) lets the fused device step slice every field back
    out at trace time."""

    layout: PackedLayout
    idx: np.ndarray  # int32  [idx_len]
    flt: np.ndarray  # float32 [flt_len]  (leads with deg_old, deg_new)
    msk: np.ndarray  # bool   [msk_len]
    feat_vals: Optional[np.ndarray]  # float32 [feat_cap, d0] when feat_cap > 0
    # optional host-precomputed block-CSR schedules for the Pallas delta
    # scatter, one (perm, dloc, block_rows) triple per layer
    pallas: Optional[Tuple[Tuple[np.ndarray, np.ndarray, np.ndarray], ...]]
    # accounting (aggregated over layers; feeds BatchStats)
    n_inc_edges: int
    n_full_edges: int
    n_out_rows: int
    # global ids of final-layer rows this plan may write — the serving
    # front-end snapshots these before dispatch to build its per-version
    # undo log (repro.serve.frontend)
    out_rows_final: Optional[np.ndarray] = None


def _schedule_from_dstk(dstk: np.ndarray, r_cap: int, tv: int, be: int):
    """Block-CSR schedule for one record array: sort the (masked → -1)
    row keys by destination tile and compose the gather perm back into the
    *unsorted* record order.  Returns (perm, dloc, brows, e_pad)."""
    from repro.kernels.segment_spmm import prepare_block_csr

    order = np.argsort(dstk, kind="stable")  # -1 (masked) sorts first; dropped
    perm_s, dloc, brows, e_pad = prepare_block_csr(dstk[order], r_cap, tv=tv, be=be)
    perm = np.where(perm_s >= 0, order[np.clip(perm_s, 0, None)], -1).astype(np.int32)
    return perm, dloc, brows, e_pad


def _pad_schedule(perm: np.ndarray, dloc: np.ndarray, brows: np.ndarray,
                  cap: int, be: int):
    """Pad a raw schedule to a power-of-two block-count bucket — otherwise
    every batch would present new shapes to the jitted fused step and force
    a recompile.  Padding: perm/dloc = -1 (zeroed message, matches no row),
    block_rows repeats its last tile (non-decreasing, so the kernel treats
    the extra blocks as accumulating zeros into an already-visited tile)."""
    e_pad = perm.shape[0]
    if cap != e_pad:
        pad = cap - e_pad
        last = int(brows[-1]) if brows.size else 0
        perm = np.concatenate([perm, np.full(pad, -1, np.int32)])
        dloc = np.concatenate([dloc, np.full(pad, -1, np.int32)])
        brows = np.concatenate(
            [brows, np.full(cap // be - brows.shape[0], last, np.int32)]
        )
    return perm, dloc, brows


def _pallas_delta_layout(
    lp: LayerPlan,
    tv: int,
    be: int,
    hwm: Optional[BucketHysteresis] = None,
    key: object = None,
):
    """Host side of the co-processed Pallas delta scatter for one packed
    layer (single-device path)."""
    r_cap = lp.touch_rows.shape[0]
    dstk = np.where(lp.e_mask, lp.e_rowidx.astype(np.int64), -1)
    perm, dloc, brows, e_pad = _schedule_from_dstk(dstk, r_cap, tv=tv, be=be)
    cap = _cap_of(hwm, key, e_pad, minimum=be)  # pow2 ≥ be → stays a multiple of be
    return _pad_schedule(perm, dloc, brows, cap, be)


def _idx_pad_value(name: str, n: int, caps: Tuple[int, ...]) -> int:
    """Pad value a hysteresis-grown idx field must be extended with (matches
    the :func:`build_plan` padding conventions)."""
    if name == "e_rowidx":
        return caps[1]
    if name == "f_rowidx":
        return caps[2]
    if name in ("e_t", "f_t"):
        return 0
    return n


def pack_plan(
    plan: BatchPlan,
    feat_vertices: Optional[np.ndarray] = None,
    feat_values: Optional[np.ndarray] = None,
    pallas: bool = False,
    hwm: Optional[BucketHysteresis] = None,
) -> PackedPlan:
    """Flatten a :class:`BatchPlan` into the packed transfer format.

    With ``hwm`` every capacity is padded up to the stream's high-water-mark
    bucket (:class:`BucketHysteresis`), so shrinking batches reuse the
    previous layout instead of retracing the fused step mid-stream."""
    n = plan.deg_old.shape[0] - 1
    if feat_vertices is not None and np.asarray(feat_vertices).size:
        fr = np.asarray(feat_vertices, np.int64)
        fv = np.asarray(feat_values, np.float32)
        feat_cap = _cap_of(hwm, "feat", fr.shape[0])
    else:
        fr = np.zeros(0, np.int64)
        fv = None
        feat_cap = 0
    caps = tuple(
        (
            _cap_of(hwm, (l, 0), lp.e_src.shape[0]),
            _cap_of(hwm, (l, 1), lp.touch_rows.shape[0]),
            _cap_of(hwm, (l, 2), lp.f_rows.shape[0]),
            _cap_of(hwm, (l, 3), lp.f_src.shape[0]),
            _cap_of(hwm, (l, 4), lp.out_rows.shape[0]),
        )
        for l, lp in enumerate(plan.layers)
    )
    layout = PackedLayout(n=n, feat_cap=feat_cap, caps=caps)
    idx_sl, flt_sl, msk_sl, (idx_len, flt_len, msk_len) = layout_slices(layout)

    idx = np.full(idx_len, n, np.int32)  # default pad → scratch row
    flt = np.zeros(flt_len, np.float32)
    msk = np.zeros(msk_len, bool)
    flt[: n + 1] = plan.deg_old
    flt[n + 1 : 2 * (n + 1)] = plan.deg_new
    feat_vals = None
    if feat_cap:
        idx[: fr.shape[0]] = fr
        msk[: fr.shape[0]] = True
        feat_vals = np.zeros((feat_cap, fv.shape[1]), np.float32)
        feat_vals[: fv.shape[0]] = fv
    for l, lp in enumerate(plan.layers):
        for name, _ in IDX_FIELDS:
            sl, arr = idx_sl[l][name], getattr(lp, name)
            idx[sl.start : sl.start + arr.shape[0]] = arr
            if sl.start + arr.shape[0] < sl.stop:  # hysteresis-grown tail
                idx[sl.start + arr.shape[0] : sl.stop] = _idx_pad_value(
                    name, n, layout.caps[l]
                )
        for name, _ in FLT_FIELDS:
            sl, arr = flt_sl[l][name], getattr(lp, name)
            flt[sl.start : sl.start + arr.shape[0]] = arr  # tail stays 0.0
        for name, _ in MSK_FIELDS:
            sl, arr = msk_sl[l][name], getattr(lp, name)
            msk[sl.start : sl.start + arr.shape[0]] = arr  # tail stays False

    pallas_sched = None
    if pallas:
        from repro.kernels.delta_agg import DELTA_BE, DELTA_TV

        pallas_sched = tuple(
            _pallas_delta_layout(lp, DELTA_TV, DELTA_BE, hwm=hwm, key=(l, "pallas"))
            for l, lp in enumerate(plan.layers)
        )
    return PackedPlan(
        layout=layout,
        idx=idx,
        flt=flt,
        msk=msk,
        feat_vals=feat_vals,
        pallas=pallas_sched,
        n_inc_edges=plan.total_inc_edges(),
        n_full_edges=plan.total_full_edges(),
        n_out_rows=plan.total_vertices(),
        out_rows_final=final_write_rows(plan),
    )


# ====================================================================== #
# Sharded plans — row-partitioned transfer format for the multi-device
# streaming engine (paper §V co-processing scaled over the repro.dist mesh)
# ====================================================================== #
# Every global row r < n is owned by exactly one shard: owner(r) = r // rows_per
# with rows_per = ceil(n / n_shards).  All *destination* work (touched rows,
# constrained full-recompute rows, output rows — and therefore every scatter)
# is local to the owning shard; only previous-layer *source* embeddings can be
# remote.  Per layer the plan carries one replicated ``halo_rows`` list — the
# union over shards of source rows each shard needs but does not own — and
# every h-space index is remapped into the per-shard **workspace**
#
#     [ halo rows (exchanged, 0..halo_cap) | local block (rows_per + 1) ]
#
# so the device step gathers owned rows locally and remote rows from the
# exchanged halo buffer.  For unconstrained models the dest-independent
# halo-skip (EXPERIMENTS.md §Perf) already removes the h[dst] gather, and dst
# rows are owned anyway, so the collective is bounded to frontier source rows
# only.  Degree lookups ship as per-shard workspace-space tables (host knows
# all degrees at plan time), so no global [N+1] array ever reaches a device.

# Per-layer cap tuple kinds: (e, r, f, fe, o, halo, ws) with
# ws = halo + rows_per + 1 (the workspace length, scratch slot last).
SH_IDX_FIELDS: Tuple[Tuple[str, int], ...] = (
    ("e_src", 0), ("e_dst", 0), ("e_rowidx", 0), ("e_t", 0),
    ("touch_rows", 1), ("f_rows", 2), ("f_src", 3), ("f_rowidx", 3),
    ("f_t", 3), ("out_rows", 4), ("f_rows_h", 2), ("out_rows_h", 4),
)
SH_FLT_FIELDS: Tuple[Tuple[str, int], ...] = (
    ("e_sign", 0), ("e_w", 0), ("f_w", 3), ("deg_old", 6), ("deg_new", 6),
)
SH_MSK_FIELDS: Tuple[Tuple[str, int], ...] = MSK_FIELDS


def shard_rows(n: int, n_shards: int) -> int:
    """Rows per shard (block row-partition of the n live vertices)."""
    return -(-n // n_shards)


@dataclasses.dataclass(frozen=True)
class ShardedLayout:
    """Static (hashable) shape descriptor of a sharded plan — one distinct
    layout → one trace of the shard_map'd device step."""

    n: int
    n_shards: int
    rows_per: int
    feat_cap: int  # 0 → no feature updates (static branch)
    caps: Tuple[Tuple[int, int, int, int, int, int, int], ...]
    # per-layer Pallas block-CSR schedule capacity (None → XLA segment-sum)
    pallas_ecaps: Optional[Tuple[int, ...]] = None
    # halo exchange strategy: "psum" broadcasts the global frontier, or
    # "ppermute" runs the per-consumer rotation-round send/recv schedules
    # (a static trace key — each mode compiles its own fused step)
    halo_mode: str = "psum"
    # per-layer (owner, consumer)-pair capacity of the ppermute schedules
    pair_caps: Optional[Tuple[int, ...]] = None


@lru_cache(maxsize=None)
def sharded_layout_slices(layout: ShardedLayout):
    """Static offset tables for the sharded buffers.

    Returns (idx_sl, flt_sl, msk_sl, halo_sl, totals): per-layer field →
    slice dicts into one shard's row of the stacked (idx, flt, msk) buffers,
    per-layer halo-row slices into the replicated idx buffer, and the buffer
    lengths (idx_len, flt_len, msk_len, rep_len)."""
    idx_off = flt_off = msk_off = 0
    rep_off = layout.feat_cap  # idx_rep = [feat rows | per-layer halo rows]
    idx_sl, flt_sl, msk_sl, halo_sl = [], [], [], []
    for caps in layout.caps:
        di: Dict[str, slice] = {}
        for name, kind in SH_IDX_FIELDS:
            di[name] = slice(idx_off, idx_off + caps[kind])
            idx_off += caps[kind]
        df: Dict[str, slice] = {}
        for name, kind in SH_FLT_FIELDS:
            df[name] = slice(flt_off, flt_off + caps[kind])
            flt_off += caps[kind]
        dm: Dict[str, slice] = {}
        for name, kind in SH_MSK_FIELDS:
            dm[name] = slice(msk_off, msk_off + caps[kind])
            msk_off += caps[kind]
        halo_sl.append(slice(rep_off, rep_off + caps[5]))
        rep_off += caps[5]
        idx_sl.append(di)
        flt_sl.append(df)
        msk_sl.append(dm)
    return (
        tuple(idx_sl), tuple(flt_sl), tuple(msk_sl), tuple(halo_sl),
        (idx_off, flt_off, msk_off, rep_off),
    )


@dataclasses.dataclass
class ShardedPlan:
    """A batch plan partitioned per shard and packed for one sharded
    ``device_put``: stacked ``[n_shards, ·]`` buffers (each device receives
    only its slice — only the rows it touches) plus small replicated side
    tables (halo row lists, feature rows)."""

    layout: ShardedLayout
    idx_sh: np.ndarray  # int32  [S, idx_len] per-shard index fields
    flt_sh: np.ndarray  # float32 [S, flt_len] (incl. per-layer ws deg tables)
    msk_sh: np.ndarray  # bool   [S, msk_len]
    idx_rep: np.ndarray  # int32 [rep_len] replicated: feat rows | halo rows
    msk_rep: np.ndarray  # bool  [feat_cap] feature-row mask
    feat_vals: Optional[np.ndarray]  # float32 [feat_cap, d0] when feat_cap > 0
    # accounting
    n_inc_edges: int
    n_full_edges: int
    n_out_rows: int
    n_halo_rows: int  # live frontier rows exchanged, summed over layers
    # optional per-shard Pallas block-CSR schedules: one stacked
    # (perm [S, cap], dloc [S, cap], brows [S, cap//be]) triple per layer
    pallas_sh: Optional[Tuple[Tuple[np.ndarray, np.ndarray, np.ndarray], ...]] = None
    # global ids of final-layer rows this plan may write (serving undo log)
    out_rows_final: Optional[np.ndarray] = None
    # per-consumer halo schedules ("ppermute" mode): one
    # (send_pos [S, S-1, pair_cap], recv_pos [S, S-1, pair_cap]) pair per
    # layer — round k moves pair (owner o → consumer (o+k) mod S)
    comms_sh: Optional[Tuple[Tuple[np.ndarray, np.ndarray], ...]] = None
    # per-layer halo rows this plan moves between shards under its mode:
    # ppermute → Σ per-pair remote deliveries; psum → halo_rows × S (the
    # global-frontier broadcast volume the CI gate uses as the ceiling)
    comms_rows: Optional[Tuple[int, ...]] = None


def _owner_runs(owners: np.ndarray, n_shards: int) -> Tuple[np.ndarray, np.ndarray]:
    """Single-pass owner partition: one stable argsort, then contiguous-run
    boundaries.  ``order[starts[s]:starts[s+1]]`` are the indices owned by
    shard ``s``, in original record order (stable sort)."""
    order = np.argsort(owners, kind="stable")
    starts = np.searchsorted(owners[order], np.arange(n_shards + 1))
    return order, starts


def _live_owner_partition(lp: LayerPlan, rows_per: int) -> Dict[str, np.ndarray]:
    """Strip one layer plan to its live records/rows and tag each with the
    shard that owns its destination row — the common first pass of both the
    sharded (`shard_plan`) and the hybrid (`hybrid_plan`) partitioners."""
    live = lp.e_mask
    fe_live = lp.f_emask
    f_cap_old = lp.f_rows.shape[0]
    fe_rowg = lp.f_rows[np.minimum(lp.f_rowidx, f_cap_old - 1)].astype(np.int64)
    es = lp.e_src[live].astype(np.int64)
    ed = lp.e_dst[live].astype(np.int64)
    tr = lp.touch_rows[lp.touch_mask].astype(np.int64)
    f_rows = lp.f_rows[lp.f_mask].astype(np.int64)
    fs = lp.f_src[fe_live].astype(np.int64)
    fe_row = fe_rowg[fe_live]
    outr = lp.out_rows[lp.out_mask].astype(np.int64)
    return dict(
        es=es, ed=ed, d_own=ed // rows_per,
        e_sign=lp.e_sign[live], e_use_new=lp.e_use_new[live],
        e_w=lp.e_w[live], e_t=lp.e_t[live],
        tr=tr, tr_own=tr // rows_per,
        f_rows=f_rows, f_own=f_rows // rows_per,
        fs=fs, fe_row=fe_row, fe_own=fe_row // rows_per,
        f_w=lp.f_w[fe_live], f_t=lp.f_t[fe_live],
        outr=outr, o_own=outr // rows_per,
    )


def shard_plan(
    plan: BatchPlan,
    n_shards: int,
    feat_vertices: Optional[np.ndarray] = None,
    feat_values: Optional[np.ndarray] = None,
    hwm: Optional[BucketHysteresis] = None,
    pallas: bool = False,
    single_pass: bool = True,
    halo_mode: str = "psum",
    pair_hysteresis: float = 0.0,
) -> ShardedPlan:
    """Partition a :class:`BatchPlan` row-wise over ``n_shards`` and pack it
    into the sharded transfer format (see module section comment).

    ``single_pass=True`` (default) fills the stacked buffers by argsorting
    each live-record field by owner shard once and slicing contiguous runs —
    O(E log E + S·caps) host time, flat in shard count.  ``False`` keeps the
    original per-shard re-scan (O(S·E)) as the equality reference.
    ``pallas=True`` additionally emits per-shard block-CSR schedules for the
    Pallas delta scatter (one stacked triple per layer).
    ``halo_mode="ppermute"`` additionally emits the per-consumer rotation
    send/recv schedules (:func:`_sharded_comms_schedules`); the resolved
    mode lands on the layout as a static trace key.  ``pair_hysteresis``
    pads each per-pair capacity ``(1 + pair_hysteresis)×`` above its raw
    size before bucketing (burst headroom → fewer retraces)."""
    n = plan.deg_old.shape[0] - 1
    rows_per = shard_rows(n, n_shards)
    S = n_shards

    if feat_vertices is not None and np.asarray(feat_vertices).size:
        fr = np.asarray(feat_vertices, np.int64)
        fv = np.asarray(feat_values, np.float32)
        feat_cap = _cap_of(hwm, "feat", fr.shape[0])
    else:
        fr = np.zeros(0, np.int64)
        fv = None
        feat_cap = 0

    # ---- pass 1: per-layer live partitions + capacities ----
    layers = []
    caps_all = []
    halo_total = 0
    for l, lp in enumerate(plan.layers):
        art = _live_owner_partition(lp, rows_per)
        es, ed, fs = art["es"], art["ed"], art["fs"]

        # frontier rows: sources some consuming shard does not own
        halo_rows = np.unique(np.concatenate([
            es[es // rows_per != art["d_own"]],
            fs[fs // rows_per != art["fe_own"]],
        ]))
        halo_total += int(halo_rows.shape[0])
        halo_cap = _cap_of(hwm, (l, "halo"), halo_rows.shape[0])

        def per_shard_max(owners) -> int:
            return int(np.bincount(owners, minlength=S).max()) if owners.size else 0

        e_cap = _cap_of(hwm, (l, 0), per_shard_max(art["d_own"]))
        r_cap = _cap_of(hwm, (l, 1), per_shard_max(art["tr_own"]))
        f_cap = _cap_of(hwm, (l, 2), per_shard_max(art["f_own"]))
        fe_cap = _cap_of(hwm, (l, 3), per_shard_max(art["fe_own"]))
        o_cap = _cap_of(hwm, (l, 4), per_shard_max(art["o_own"]))
        ws = halo_cap + rows_per + 1
        caps_all.append((e_cap, r_cap, f_cap, fe_cap, o_cap, halo_cap, ws))
        art["halo_rows"] = halo_rows
        layers.append(art)

    layout = ShardedLayout(
        n=n, n_shards=S, rows_per=rows_per, feat_cap=feat_cap,
        caps=tuple(caps_all),
    )
    idx_sl, flt_sl, msk_sl, halo_sl, (idx_len, flt_len, msk_len, rep_len) = (
        sharded_layout_slices(layout)
    )

    # ---- pass 2: fill the stacked + replicated buffers ----
    idx_sh = np.zeros((S, idx_len), np.int32)
    flt_sh = np.zeros((S, flt_len), np.float32)
    msk_sh = np.zeros((S, msk_len), bool)
    idx_rep = np.full(rep_len, -1, np.int32)
    msk_rep = np.zeros(feat_cap, bool)
    feat_vals = None
    if feat_cap:
        idx_rep[: fr.shape[0]] = fr
        msk_rep[: fr.shape[0]] = True
        feat_vals = np.zeros((feat_cap, fv.shape[1]), np.float32)
        feat_vals[: fv.shape[0]] = fv

    fill = _fill_sharded_single_pass if single_pass else _fill_sharded_reference
    fill(plan, layout, layers, idx_sl, flt_sl, msk_sl, halo_sl,
         idx_sh, flt_sh, msk_sh, idx_rep)

    pallas_sh = None
    if pallas:
        pallas_sh, pcaps = _sharded_pallas_schedules(
            layout, idx_sl, msk_sl, idx_sh, msk_sh, hwm
        )
        layout = dataclasses.replace(layout, pallas_ecaps=pcaps)

    comms_sh = None
    if halo_mode == "ppermute":
        comms_sh, pair_caps, comms_rows = _sharded_comms_schedules(
            layout, layers, hwm, pair_hysteresis
        )
        layout = dataclasses.replace(
            layout, halo_mode="ppermute", pair_caps=pair_caps)
    else:
        # broadcast volume: every shard receives every layer's full halo
        comms_rows = tuple(
            int(art["halo_rows"].shape[0]) * S for art in layers)

    return ShardedPlan(
        layout=layout,
        idx_sh=idx_sh,
        flt_sh=flt_sh,
        msk_sh=msk_sh,
        idx_rep=idx_rep,
        msk_rep=msk_rep,
        feat_vals=feat_vals,
        n_inc_edges=plan.total_inc_edges(),
        n_full_edges=plan.total_full_edges(),
        n_out_rows=plan.total_vertices(),
        n_halo_rows=halo_total,
        pallas_sh=pallas_sh,
        out_rows_final=final_write_rows(plan),
        comms_sh=comms_sh,
        comms_rows=comms_rows,
    )


def _fill_sharded_reference(plan, layout, layers, idx_sl, flt_sl, msk_sl,
                            halo_sl, idx_sh, flt_sh, msk_sh, idx_rep) -> None:
    """Original per-shard fill: each of the S iterations re-scans the full
    live-record arrays (O(S·E)) and re-runs ``searchsorted`` per field.
    Kept verbatim as the equality reference for the single-pass fill."""
    S, rows_per, n = layout.n_shards, layout.rows_per, layout.n

    def fill_idx(s: int, sl: slice, vals: np.ndarray, pad: int) -> None:
        idx_sh[s, sl] = pad
        idx_sh[s, sl.start : sl.start + vals.shape[0]] = vals

    for l, (art, caps) in enumerate(zip(layers, layout.caps)):
        e_cap, r_cap, f_cap, fe_cap, o_cap, halo_cap, ws = caps
        ws_scratch = halo_cap + rows_per
        halo_rows = art["halo_rows"]
        idx_rep[halo_sl[l].start : halo_sl[l].start + halo_rows.shape[0]] = halo_rows

        deg_halo_old = np.zeros(halo_cap, np.float32)
        deg_halo_new = np.zeros(halo_cap, np.float32)
        deg_halo_old[: halo_rows.shape[0]] = plan.deg_old[halo_rows]
        deg_halo_new[: halo_rows.shape[0]] = plan.deg_new[halo_rows]

        for s in range(S):
            lo = s * rows_per

            def ws_of(rows: np.ndarray) -> np.ndarray:
                own = (rows >= lo) & (rows < lo + rows_per)
                hpos = np.searchsorted(halo_rows, rows)
                hpos = np.clip(hpos, 0, max(0, halo_rows.shape[0] - 1))
                return np.where(own, halo_cap + (rows - lo), hpos).astype(np.int32)

            sel = art["d_own"] == s
            ne = int(sel.sum())
            ed_s = art["ed"][sel]
            tr_s = art["tr"][art["tr_own"] == s]
            fr_s = art["f_rows"][art["f_own"] == s]
            fe_sel = art["fe_own"] == s
            fs_s = art["fs"][fe_sel]
            out_s = art["outr"][art["o_own"] == s]

            di, df, dm = idx_sl[l], flt_sl[l], msk_sl[l]
            fill_idx(s, di["e_src"], ws_of(art["es"][sel]), ws_scratch)
            fill_idx(s, di["e_dst"], ws_of(ed_s), ws_scratch)
            fill_idx(s, di["e_rowidx"],
                     np.searchsorted(tr_s, ed_s).astype(np.int32), r_cap)
            fill_idx(s, di["e_t"], art["e_t"][sel], 0)
            fill_idx(s, di["touch_rows"], (tr_s - lo).astype(np.int32), rows_per)
            fill_idx(s, di["f_rows"], (fr_s - lo).astype(np.int32), rows_per)
            fill_idx(s, di["f_src"], ws_of(fs_s), ws_scratch)
            fill_idx(s, di["f_rowidx"],
                     np.searchsorted(fr_s, art["fe_row"][fe_sel]).astype(np.int32),
                     f_cap)
            fill_idx(s, di["f_t"], art["f_t"][fe_sel], 0)
            fill_idx(s, di["out_rows"], (out_s - lo).astype(np.int32), rows_per)
            fill_idx(s, di["f_rows_h"], ws_of(fr_s), ws_scratch)
            fill_idx(s, di["out_rows_h"], ws_of(out_s), ws_scratch)

            flt_sh[s, df["e_sign"].start : df["e_sign"].start + ne] = (
                art["e_sign"][sel]
            )
            flt_sh[s, df["e_w"].start : df["e_w"].start + ne] = (
                art["e_w"][sel]
            )
            flt_sh[s, df["f_w"].start : df["f_w"].start + fs_s.shape[0]] = (
                art["f_w"][fe_sel]
            )
            li = np.arange(lo, lo + rows_per)
            dl_old = np.where(li < n, plan.deg_old[np.minimum(li, n)], 0.0)
            dl_new = np.where(li < n, plan.deg_new[np.minimum(li, n)], 0.0)
            flt_sh[s, df["deg_old"]] = np.concatenate(
                [deg_halo_old, dl_old, [0.0]]).astype(np.float32)
            flt_sh[s, df["deg_new"]] = np.concatenate(
                [deg_halo_new, dl_new, [0.0]]).astype(np.float32)

            nr, nf, nfe, no = (tr_s.shape[0], fr_s.shape[0],
                               fs_s.shape[0], out_s.shape[0])
            msk_sh[s, dm["e_mask"].start : dm["e_mask"].start + ne] = True
            msk_sh[s, dm["e_use_new"].start : dm["e_use_new"].start + ne] = (
                art["e_use_new"][sel]
            )
            msk_sh[s, dm["touch_mask"].start : dm["touch_mask"].start + nr] = True
            msk_sh[s, dm["f_mask"].start : dm["f_mask"].start + nf] = True
            msk_sh[s, dm["f_emask"].start : dm["f_emask"].start + nfe] = True
            msk_sh[s, dm["out_mask"].start : dm["out_mask"].start + no] = True


def _fill_sharded_single_pass(plan, layout, layers, idx_sl, flt_sl, msk_sl,
                              halo_sl, idx_sh, flt_sh, msk_sh, idx_rep) -> None:
    """Single-pass fill (ROADMAP): every owner partition is one stable
    argsort + contiguous-run slicing (:func:`_owner_runs`), and every
    ``searchsorted`` runs once per field over the *full* array instead of
    once per shard — host plan time stays flat in shard count, so planning
    keeps hiding behind device execution at S=64+.  Produces buffers
    bit-identical to :func:`_fill_sharded_reference` (asserted in
    tests/test_sharded_engine.py)."""
    S, rows_per, n = layout.n_shards, layout.rows_per, layout.n

    def fill_idx(s: int, sl: slice, vals: np.ndarray, pad: int) -> None:
        idx_sh[s, sl] = pad
        idx_sh[s, sl.start : sl.start + vals.shape[0]] = vals

    for l, (art, caps) in enumerate(zip(layers, layout.caps)):
        e_cap, r_cap, f_cap, fe_cap, o_cap, halo_cap, ws = caps
        ws_scratch = halo_cap + rows_per
        halo_rows = art["halo_rows"]
        idx_rep[halo_sl[l].start : halo_sl[l].start + halo_rows.shape[0]] = halo_rows

        deg_halo_old = np.zeros(halo_cap, np.float32)
        deg_halo_new = np.zeros(halo_cap, np.float32)
        deg_halo_old[: halo_rows.shape[0]] = plan.deg_old[halo_rows]
        deg_halo_new[: halo_rows.shape[0]] = plan.deg_new[halo_rows]

        # ---- once per layer: owner runs + global lookups ----
        e_ord, e_st = _owner_runs(art["d_own"], S)
        fe_ord, fe_st = _owner_runs(art["fe_own"], S)
        # tr / f_rows / outr are sorted, so owner runs are already contiguous
        tr_st = np.searchsorted(art["tr_own"], np.arange(S + 1))
        f_st = np.searchsorted(art["f_own"], np.arange(S + 1))
        o_st = np.searchsorted(art["o_own"], np.arange(S + 1))

        # h-space fields: owned rows use a local offset, remote rows the
        # halo slot — resolved per shard below from these global tables
        def ws_split(rows: np.ndarray):
            hpos = np.searchsorted(halo_rows, rows)
            hpos = np.clip(hpos, 0, max(0, halo_rows.shape[0] - 1)).astype(np.int64)
            return hpos, rows // rows_per

        es_h, es_own = ws_split(art["es"])
        fs_h, fs_own = ws_split(art["fs"])
        e_row_g = np.searchsorted(art["tr"], art["ed"])
        fe_row_g = np.searchsorted(art["f_rows"], art["fe_row"])

        for s in range(S):
            lo = s * rows_per
            esel = e_ord[e_st[s] : e_st[s + 1]]
            fesel = fe_ord[fe_st[s] : fe_st[s + 1]]
            ne, nfe = esel.shape[0], fesel.shape[0]
            ed_s = art["ed"][esel]
            tr_s = art["tr"][tr_st[s] : tr_st[s + 1]]
            fr_s = art["f_rows"][f_st[s] : f_st[s + 1]]
            fs_s = art["fs"][fesel]
            out_s = art["outr"][o_st[s] : o_st[s + 1]]

            def ws_of(rows, hpos, own):
                return np.where(own == s, halo_cap + (rows - lo), hpos).astype(
                    np.int32)

            di, df, dm = idx_sl[l], flt_sl[l], msk_sl[l]
            fill_idx(s, di["e_src"],
                     ws_of(art["es"][esel], es_h[esel], es_own[esel]), ws_scratch)
            # destination rows are owner-local by construction
            fill_idx(s, di["e_dst"], (halo_cap + ed_s - lo).astype(np.int32),
                     ws_scratch)
            fill_idx(s, di["e_rowidx"],
                     (e_row_g[esel] - tr_st[s]).astype(np.int32), r_cap)
            fill_idx(s, di["e_t"], art["e_t"][esel], 0)
            fill_idx(s, di["touch_rows"], (tr_s - lo).astype(np.int32), rows_per)
            fill_idx(s, di["f_rows"], (fr_s - lo).astype(np.int32), rows_per)
            fill_idx(s, di["f_src"],
                     ws_of(fs_s, fs_h[fesel], fs_own[fesel]), ws_scratch)
            fill_idx(s, di["f_rowidx"],
                     (fe_row_g[fesel] - f_st[s]).astype(np.int32), f_cap)
            fill_idx(s, di["f_t"], art["f_t"][fesel], 0)
            fill_idx(s, di["out_rows"], (out_s - lo).astype(np.int32), rows_per)
            fill_idx(s, di["f_rows_h"], (halo_cap + fr_s - lo).astype(np.int32),
                     ws_scratch)
            fill_idx(s, di["out_rows_h"], (halo_cap + out_s - lo).astype(np.int32),
                     ws_scratch)

            flt_sh[s, df["e_sign"].start : df["e_sign"].start + ne] = (
                art["e_sign"][esel]
            )
            flt_sh[s, df["e_w"].start : df["e_w"].start + ne] = art["e_w"][esel]
            flt_sh[s, df["f_w"].start : df["f_w"].start + nfe] = art["f_w"][fesel]
            li = np.arange(lo, lo + rows_per)
            dl_old = np.where(li < n, plan.deg_old[np.minimum(li, n)], 0.0)
            dl_new = np.where(li < n, plan.deg_new[np.minimum(li, n)], 0.0)
            flt_sh[s, df["deg_old"]] = np.concatenate(
                [deg_halo_old, dl_old, [0.0]]).astype(np.float32)
            flt_sh[s, df["deg_new"]] = np.concatenate(
                [deg_halo_new, dl_new, [0.0]]).astype(np.float32)

            nr, nf, no = tr_s.shape[0], fr_s.shape[0], out_s.shape[0]
            msk_sh[s, dm["e_mask"].start : dm["e_mask"].start + ne] = True
            msk_sh[s, dm["e_use_new"].start : dm["e_use_new"].start + ne] = (
                art["e_use_new"][esel]
            )
            msk_sh[s, dm["touch_mask"].start : dm["touch_mask"].start + nr] = True
            msk_sh[s, dm["f_mask"].start : dm["f_mask"].start + nf] = True
            msk_sh[s, dm["f_emask"].start : dm["f_emask"].start + nfe] = True
            msk_sh[s, dm["out_mask"].start : dm["out_mask"].start + no] = True


def _sharded_pallas_schedules(layout, idx_sl, msk_sl, idx_sh, msk_sh,
                              hwm: Optional[BucketHysteresis]):
    """Per-shard block-CSR schedules for the Pallas delta scatter, one
    stacked (perm, dloc, brows) triple per layer.  All shards of a layer
    share one (hysteresis-held) capacity so the stacked arrays ship under
    the plan sharding like every other per-shard buffer."""
    from repro.kernels.delta_agg import DELTA_BE, DELTA_TV

    S = layout.n_shards
    out = []
    pcaps = []
    for l, caps in enumerate(layout.caps):
        r_cap = caps[1]
        raw = []
        for s in range(S):
            rowidx = idx_sh[s, idx_sl[l]["e_rowidx"]].astype(np.int64)
            emask = msk_sh[s, msk_sl[l]["e_mask"]]
            dstk = np.where(emask, rowidx, -1)
            raw.append(_schedule_from_dstk(dstk, r_cap, tv=DELTA_TV, be=DELTA_BE))
        cap = _cap_of(hwm, (l, "pallas"), max(r[3] for r in raw),
                      minimum=DELTA_BE)
        padded = [_pad_schedule(p, d, b, cap, DELTA_BE) for p, d, b, _ in raw]
        out.append(tuple(
            np.stack([pd[k] for pd in padded]) for k in range(3)
        ))
        pcaps.append(cap)
    return tuple(out), tuple(pcaps)


def _remote_deliveries(art: Dict[str, np.ndarray], rows_per: int,
                       n: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Unique (owner, consumer, row) halo deliveries of one layer: every
    source row some consuming shard gathers but does not own, deduplicated
    per consumer — the value-independent ground truth both the ppermute
    schedules and the coverage tests are built from."""
    es, fs = art["es"], art["fs"]
    re_m = es // rows_per != art["d_own"]
    rf_m = fs // rows_per != art["fe_own"]
    src = np.concatenate([es[re_m], fs[rf_m]])
    cons = np.concatenate([art["d_own"][re_m], art["fe_own"][rf_m]])
    key = np.unique(cons.astype(np.int64) * (n + 1) + src)
    cons_u, src_u = key // (n + 1), key % (n + 1)
    return src_u // rows_per, cons_u, src_u


def _sharded_comms_schedules(layout, layers, hwm: Optional[BucketHysteresis],
                             pair_hysteresis: float):
    """Per-consumer rotation send/recv schedules for the ppermute halo
    exchange, one (send_pos, recv_pos) pair of ``[S, S-1, pair_cap]`` int32
    tables per layer.

    Round ``k`` (1-based) permutes shard ``j → (j+k) mod S``, so the pair
    (owner o → consumer c) rides round ``(c - o) mod S``: ``send_pos[o,
    k-1]`` holds the owner-local positions (pad → ``rows_per``, the block's
    scratch row) and ``recv_pos[c, k-1]`` the consumer's halo-slot
    positions (pad → ``halo_cap``, the recv buffer's dump row).  All shards
    and rounds of a layer share one hysteresis-held pair capacity so the
    stacked tables ship under the plan sharding without retracing."""
    S, rows_per, n = layout.n_shards, layout.rows_per, layout.n
    K = S - 1
    out, pair_caps, rows_sent = [], [], []
    for l, art in enumerate(layers):
        halo_rows = art["halo_rows"]
        halo_cap = layout.caps[l][5]
        own_u, cons_u, src_u = _remote_deliveries(art, rows_per, n)
        rows_sent.append(int(src_u.shape[0]))

        order = np.lexsort((src_u, cons_u, own_u))
        own_u, cons_u, src_u = own_u[order], cons_u[order], src_u[order]
        pair_key = own_u * S + cons_u
        starts = np.concatenate([
            [0], np.flatnonzero(np.diff(pair_key)) + 1, [pair_key.size],
        ]) if pair_key.size else np.zeros(1, np.int64)
        raw_max = int(np.diff(starts).max()) if pair_key.size else 0
        cap = _cap_of(hwm, (l, "pair"),
                      int(math.ceil(raw_max * (1.0 + pair_hysteresis))))

        send = np.full((S, K, cap), rows_per, np.int32)
        recv = np.full((S, K, cap), halo_cap, np.int32)
        for a, b in zip(starts[:-1], starts[1:]):
            if b == a:
                continue
            o, c = int(own_u[a]), int(cons_u[a])
            k = (c - o) % S
            rows = src_u[a:b]
            send[o, k - 1, : b - a] = (rows - o * rows_per).astype(np.int32)
            recv[c, k - 1, : b - a] = np.searchsorted(
                halo_rows, rows).astype(np.int32)
        out.append((send, recv))
        pair_caps.append(cap)
    return tuple(out), tuple(pair_caps), tuple(rows_sent)


def build_packed_plan(
    model: GNNModel,
    g_old: CSRGraph,
    g_new: CSRGraph,
    batch: UpdateBatch,
    num_layers: int,
    pallas: bool = False,
    hwm: Optional[BucketHysteresis] = None,
) -> PackedPlan:
    """Alg.-4 planning straight into the packed transfer format."""
    plan = build_plan(model, g_old, g_new, batch, num_layers)
    return pack_plan(plan, batch.feat_vertices, batch.feat_values, pallas=pallas,
                     hwm=hwm)


# ====================================================================== #
# Hybrid plans — sharded offload transfer format: per-shard *compact*
# [halo|local] workspaces (paper §V-B at mesh scale).  Unlike ShardedPlan,
# whose per-shard workspace embeds the full local block (rows_per + 1 rows),
# the hybrid stages only the rows each shard's plan actually touches, so a
# device's footprint is O(its affected subgraph) — the persistent state
# stays host-resident in per-shard row blocks.  No device collective is
# needed: halo rows are gathered from the owning shards' *host* blocks at
# staging time (the host is the exchange medium between layers).
# ====================================================================== #
def remap_compact(indices: np.ndarray, rows: np.ndarray, n_compact: int,
                  scratch: int) -> np.ndarray:
    """Map global vertex ids → compact positions; unmatched → n_compact."""
    lut = np.full(scratch + 1, n_compact, np.int32)
    if rows.size:
        lut[rows] = np.arange(rows.shape[0], dtype=np.int32)
    return lut[np.asarray(indices, np.int64)]


def _remap_sorted(indices: np.ndarray, rows: np.ndarray, cap: int) -> np.ndarray:
    """:func:`remap_compact` for *sorted* ``rows``: O(k log k) searchsorted
    instead of an O(V) lookup-table allocation — hybrid planning calls this
    per shard per layer, so an O(V) table per call would put O(S·L·V) host
    work on the plan critical path.  Unmatched values map to ``cap``."""
    v = np.asarray(indices, np.int64)
    if rows.size == 0:
        return np.full(v.shape, cap, np.int32)
    pos = np.clip(np.searchsorted(rows, v), 0, rows.shape[0] - 1)
    return np.where(rows[pos] == v, pos, cap).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class ResidencySplit:
    """Plan-time ``[cached | miss]`` partition of one layer's needed rows —
    the residency analogue of the ``[halo | local]`` remap above, consumed
    by the device hot-row cache (repro.serve.hotcache).

    Positions index the *original* ``rows`` array; ``hit`` positions are
    served from device cache slots, ``miss`` positions from the host
    staging gather.  ``admit_midx``/``admit_slots`` (filled in by
    ``HotRowCache.plan_reads``) name the miss positions whose staged
    values should additionally be installed into fresh cache slots."""

    hit_pos: np.ndarray  # int64 positions into rows (cached)
    hit_slots: np.ndarray  # int32 device slot per hit position
    miss_pos: np.ndarray  # int64 positions into rows (staged from host)
    miss_rows: np.ndarray  # int64 global row ids, = rows[miss_pos]
    admit_midx: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))
    admit_slots: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))


def split_residency(rows: np.ndarray, slot_of: np.ndarray,
                    exclude_rows: Optional[np.ndarray] = None) -> ResidencySplit:
    """Split ``rows`` into cached hits and staged misses against a slot
    table (``slot_of[r] < 0`` → not cached).  Rows in ``exclude_rows`` are
    forced to miss even when cached — the cache uses this for rows written
    earlier in the same batch, whose cached value is mid-update (see the
    coherence notes in repro.serve.hotcache).  Pure metadata: never reads
    state values, so it is safe on the plan side of the plan/execute
    overlap."""
    rows = np.asarray(rows, np.int64)
    slots = slot_of[rows]
    hit = slots >= 0
    if exclude_rows is not None and np.asarray(exclude_rows).size:
        hit &= ~np.isin(rows, np.asarray(exclude_rows, np.int64))
    hit_pos = np.flatnonzero(hit).astype(np.int64)
    miss_pos = np.flatnonzero(~hit).astype(np.int64)
    return ResidencySplit(
        hit_pos=hit_pos,
        hit_slots=slots[hit_pos].astype(np.int32),
        miss_pos=miss_pos,
        miss_rows=rows[miss_pos],
    )


# Per-layer cap tuple: (e, r, f, fe, o, nh, ns) — nh is the compact h^{l-1}
# workspace (gather space), ns the compact state workspace (scatter space);
# both get one scratch slot at index cap when staged.  Field kinds index the
# cap that gives the field's *length*; -1 means the nh+1 degree table.
HYB_IDX_FIELDS: Tuple[Tuple[str, int], ...] = (
    ("e_src", 0), ("e_dst", 0), ("e_rowidx", 0), ("e_t", 0),
    ("touch_rows", 1), ("f_rows", 2), ("f_src", 3), ("f_rowidx", 3),
    ("f_t", 3), ("out_rows", 4), ("f_rows_h", 2), ("out_rows_h", 4),
)
HYB_FLT_FIELDS: Tuple[Tuple[str, int], ...] = (
    ("e_sign", 0), ("e_w", 0), ("f_w", 3), ("deg_old", -1), ("deg_new", -1),
)
HYB_MSK_FIELDS: Tuple[Tuple[str, int], ...] = MSK_FIELDS


@dataclasses.dataclass(frozen=True)
class HybridLayerLayout:
    """Static (hashable) shape descriptor of one hybrid layer's staging —
    one distinct layout → one trace of the shard_map'd compact layer step."""

    n: int
    n_shards: int
    caps: Tuple[int, int, int, int, int, int, int]  # (e, r, f, fe, o, nh, ns)


@lru_cache(maxsize=None)
def hybrid_layout_slices(ll: HybridLayerLayout):
    """Static offset tables into one shard's row of the stacked hybrid
    buffers; returns (idx_sl, flt_sl, msk_sl, (idx_len, flt_len, msk_len))."""
    idx_off = flt_off = msk_off = 0
    di: Dict[str, slice] = {}
    for name, kind in HYB_IDX_FIELDS:
        di[name] = slice(idx_off, idx_off + ll.caps[kind])
        idx_off += ll.caps[kind]
    df: Dict[str, slice] = {}
    for name, kind in HYB_FLT_FIELDS:
        ln = ll.caps[5] + 1 if kind == -1 else ll.caps[kind]
        df[name] = slice(flt_off, flt_off + ln)
        flt_off += ln
    dm: Dict[str, slice] = {}
    for name, kind in HYB_MSK_FIELDS:
        dm[name] = slice(msk_off, msk_off + ll.caps[kind])
        msk_off += ll.caps[kind]
    return di, df, dm, (idx_off, flt_off, msk_off)


@dataclasses.dataclass
class HybridLayerPlan:
    """One layer's per-shard compact staging tables, stacked ``[S, ·]``.

    ``need_h``/``srows`` name the *global* rows each shard stages (gather /
    scatter sets); every plan index inside ``idx_sh`` is remapped into the
    matching compact space (pad → the space's scratch slot)."""

    layout: HybridLayerLayout
    need_h: np.ndarray  # int64 [S, nh_cap] global ids (pad rows → 0, masked)
    need_mask: np.ndarray  # bool [S, nh_cap]
    srows: np.ndarray  # int64 [S, ns_cap] global ids (pad rows → 0, masked)
    srows_mask: np.ndarray  # bool [S, ns_cap]
    idx_sh: np.ndarray  # int32 [S, idx_len]
    flt_sh: np.ndarray  # float32 [S, flt_len] (incl. compact deg tables)
    msk_sh: np.ndarray  # bool [S, msk_len]
    # live need rows whose owner is another shard — the halo this layer
    # moves between shards regardless of serving path (comms counters)
    n_halo_remote: int = 0
    # device-served new-view patch (halo_mode="ppermute"): flat [S·nh_cap]
    # positions whose rows the *previous* layer just wrote, and the source
    # index into its device-resident outputs (l=0: into the batch's feature
    # rows) — these rows skip the staged h_new pipeline entirely
    patch_pos: Optional[np.ndarray] = None
    patch_src: Optional[np.ndarray] = None

    @property
    def nh_cap(self) -> int:
        return self.layout.caps[5]

    @property
    def ns_cap(self) -> int:
        return self.layout.caps[6]


@dataclasses.dataclass
class HybridPlan:
    layers: List[HybridLayerPlan]


def _match_positions(dst_keys: np.ndarray, src_rows: np.ndarray):
    """Positions of ``dst_keys`` found in ``src_rows`` plus the matching
    source indices — the same match ``_override_rows`` performs on the
    host path (``src_rows`` unique), so a device-side patch built from
    these tables is position-for-position identical."""
    if src_rows.size == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    order = np.argsort(src_rows)
    pos = np.searchsorted(src_rows[order], dst_keys)
    pos = np.clip(pos, 0, src_rows.size - 1)
    hit = src_rows[order][pos] == dst_keys
    return (np.flatnonzero(hit).astype(np.int64),
            order[pos[hit]].astype(np.int64))


def hybrid_plan(
    plan: BatchPlan,
    n_shards: int,
    hwm: Optional[BucketHysteresis] = None,
    feat_vertices: Optional[np.ndarray] = None,
    halo_mode: str = "psum",
) -> HybridPlan:
    """Partition a :class:`BatchPlan` by destination-row owner and emit the
    per-shard compact staging tables (see section comment).  All scatters
    are owner-local by construction; the gather set (``need_h``) may span
    other shards' rows — those are served from host blocks at staging time.

    ``halo_mode="ppermute"`` additionally emits the device-served new-view
    patch tables (``patch_pos``/``patch_src``): the rows of each layer's
    gather set the previous layer just wrote are split out at plan time and
    served from its still-device-resident outputs (l=0: from the batch's
    feature values), so the staged ``h_new`` buffer — and its H2D copy —
    disappears.  ``feat_vertices`` is the batch's feature-update row list
    (the l=0 patch source); only consulted in ppermute mode."""
    n = plan.deg_old.shape[0] - 1
    rows_per = shard_rows(n, n_shards)
    S = n_shards
    out_layers: List[HybridLayerPlan] = []
    device_patch = halo_mode == "ppermute"
    if feat_vertices is not None and np.asarray(feat_vertices).size:
        prev_keys = np.asarray(feat_vertices, np.int64)
    else:
        prev_keys = np.zeros(0, np.int64)
    prev_live_pos: Optional[np.ndarray] = None

    for l, lp in enumerate(plan.layers):
        art = _live_owner_partition(lp, rows_per)
        es, ed, fs = art["es"], art["ed"], art["fs"]
        tr, f_rows, outr = art["tr"], art["f_rows"], art["outr"]
        fe_row = art["fe_row"]

        e_ord, e_st = _owner_runs(art["d_own"], S)
        fe_ord, fe_st = _owner_runs(art["fe_own"], S)
        tr_st = np.searchsorted(art["tr_own"], np.arange(S + 1))
        f_st = np.searchsorted(art["f_own"], np.arange(S + 1))
        o_st = np.searchsorted(art["o_own"], np.arange(S + 1))

        # per-shard gather/scatter row sets
        need_list, srow_list = [], []
        for s in range(S):
            esel = e_ord[e_st[s] : e_st[s + 1]]
            fesel = fe_ord[fe_st[s] : fe_st[s + 1]]
            out_s = outr[o_st[s] : o_st[s + 1]]
            need_list.append(np.unique(np.concatenate([
                es[esel], ed[esel], fs[fesel],
                f_rows[f_st[s] : f_st[s + 1]], out_s,
            ])))
            srow_list.append(out_s)

        def runmax(starts) -> int:
            return int(np.diff(starts).max()) if S else 0

        e_cap = _cap_of(hwm, (l, 0), runmax(e_st))
        r_cap = _cap_of(hwm, (l, 1), runmax(tr_st))
        f_cap = _cap_of(hwm, (l, 2), runmax(f_st))
        fe_cap = _cap_of(hwm, (l, 3), runmax(fe_st))
        o_cap = _cap_of(hwm, (l, 4), runmax(o_st))
        nh_cap = _cap_of(hwm, (l, "nh"), max(v.shape[0] for v in need_list))
        ns_cap = o_cap  # srows == live out rows, so the buckets coincide
        llayout = HybridLayerLayout(
            n=n, n_shards=S,
            caps=(e_cap, r_cap, f_cap, fe_cap, o_cap, nh_cap, ns_cap),
        )
        di, df, dm, (idx_len, flt_len, msk_len) = hybrid_layout_slices(llayout)

        need_h = np.zeros((S, nh_cap), np.int64)
        need_mask = np.zeros((S, nh_cap), bool)
        srows = np.zeros((S, ns_cap), np.int64)
        srows_mask = np.zeros((S, ns_cap), bool)
        idx_sh = np.zeros((S, idx_len), np.int32)
        flt_sh = np.zeros((S, flt_len), np.float32)
        msk_sh = np.zeros((S, msk_len), bool)

        def fill_idx(s: int, sl: slice, vals: np.ndarray, pad: int) -> None:
            idx_sh[s, sl] = pad
            idx_sh[s, sl.start : sl.start + vals.shape[0]] = vals

        for s in range(S):
            esel = e_ord[e_st[s] : e_st[s + 1]]
            fesel = fe_ord[fe_st[s] : fe_st[s + 1]]
            ne, nfe = esel.shape[0], fesel.shape[0]
            need = need_list[s]
            sr = srow_list[s]
            nh, ns_ = need.shape[0], sr.shape[0]
            tr_s = tr[tr_st[s] : tr_st[s + 1]]
            fr_s = f_rows[f_st[s] : f_st[s + 1]]
            need_h[s, :nh] = need
            need_mask[s, :nh] = True
            srows[s, :ns_] = sr
            srows_mask[s, :ns_] = True

            def rmap_h(v):
                return _remap_sorted(v, need, nh_cap)

            def rmap_s(v):
                return _remap_sorted(v, sr, ns_cap)

            fill_idx(s, di["e_src"], rmap_h(es[esel]), nh_cap)
            fill_idx(s, di["e_dst"], rmap_h(ed[esel]), nh_cap)
            fill_idx(s, di["e_rowidx"],
                     np.searchsorted(tr_s, ed[esel]).astype(np.int32), r_cap)
            fill_idx(s, di["e_t"], art["e_t"][esel], 0)
            fill_idx(s, di["touch_rows"], rmap_s(tr_s), ns_cap)
            fill_idx(s, di["f_rows"], rmap_s(fr_s), ns_cap)
            fill_idx(s, di["f_src"], rmap_h(fs[fesel]), nh_cap)
            fill_idx(s, di["f_rowidx"],
                     np.searchsorted(fr_s, fe_row[fesel]).astype(np.int32), f_cap)
            fill_idx(s, di["f_t"], art["f_t"][fesel], 0)
            fill_idx(s, di["out_rows"], rmap_s(sr), ns_cap)
            fill_idx(s, di["f_rows_h"], rmap_h(fr_s), nh_cap)
            fill_idx(s, di["out_rows_h"], rmap_h(sr), nh_cap)

            flt_sh[s, df["e_sign"].start : df["e_sign"].start + ne] = (
                art["e_sign"][esel]
            )
            flt_sh[s, df["e_w"].start : df["e_w"].start + ne] = (
                art["e_w"][esel]
            )
            flt_sh[s, df["f_w"].start : df["f_w"].start + nfe] = (
                art["f_w"][fesel]
            )
            deg_o = np.zeros(nh_cap + 1, np.float32)
            deg_n = np.zeros(nh_cap + 1, np.float32)
            deg_o[:nh] = plan.deg_old[need]
            deg_n[:nh] = plan.deg_new[need]
            flt_sh[s, df["deg_old"]] = deg_o
            flt_sh[s, df["deg_new"]] = deg_n

            nr, nf, no = tr_s.shape[0], fr_s.shape[0], sr.shape[0]
            msk_sh[s, dm["e_mask"].start : dm["e_mask"].start + ne] = True
            msk_sh[s, dm["e_use_new"].start : dm["e_use_new"].start + ne] = (
                art["e_use_new"][esel]
            )
            msk_sh[s, dm["touch_mask"].start : dm["touch_mask"].start + nr] = True
            msk_sh[s, dm["f_mask"].start : dm["f_mask"].start + nf] = True
            msk_sh[s, dm["f_emask"].start : dm["f_emask"].start + nfe] = True
            msk_sh[s, dm["out_mask"].start : dm["out_mask"].start + no] = True

        n_halo_remote = sum(
            int((need_list[s] // rows_per != s).sum()) for s in range(S))

        patch_pos = patch_src = None
        if device_patch:
            dst_keys = np.where(need_mask, need_h, -1).reshape(-1)
            patch_pos, patch_src = _match_positions(dst_keys, prev_keys)
            if l > 0:  # compose: index into live srows → flat ws position
                patch_src = prev_live_pos[patch_src]
            prev_keys = srows[srows_mask].astype(np.int64)
            prev_live_pos = np.flatnonzero(
                srows_mask.reshape(-1)).astype(np.int64)

        out_layers.append(HybridLayerPlan(
            layout=llayout,
            need_h=need_h, need_mask=need_mask,
            srows=srows, srows_mask=srows_mask,
            idx_sh=idx_sh, flt_sh=flt_sh, msk_sh=msk_sh,
            n_halo_remote=n_halo_remote,
            patch_pos=patch_pos, patch_src=patch_src,
        ))

    return HybridPlan(layers=out_layers)


# ====================================================================== #
# Batch-window fusion — merge independent batch plans into one plan
# (DaCe state-fusion idiom: consecutive states with disjoint interstate
# dependencies collapse into one; here consecutive update batches with
# disjoint plan footprints collapse into one packed plan / device step)
# ====================================================================== #
@dataclasses.dataclass(frozen=True)
class FusionConfig:
    """Typed knobs for batch-window fusion (nested in
    :class:`repro.serve.api.EngineConfig` as ``fusion=``).

    ``window`` is the orchestrator's lookahead depth — up to this many
    pending batches are planned ahead and the maximal *independent prefix*
    (pairwise-disjoint :meth:`FusionWindow.footprint` sets) is merged into
    one plan and dispatched as one device step.  ``window=1`` or
    ``enabled=False`` keeps the config inert (the serial per-batch loop,
    byte-identical behavior)."""

    window: int = 4
    enabled: bool = True

    def __post_init__(self) -> None:
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")


class FusionWindow:
    """Value-independent overlap test + plan concatenation for batch fusion.

    Two batches may execute as one device step iff their plan *footprints*
    are disjoint.  The footprint of a plan is every global row id the
    batch's execution reads or writes, taken from the plan's own index
    tables (never from state values — the §V overlap contract):

    * ``e_src`` / ``f_src`` — previous-layer rows gathered (and, for
      source-degree-dependent models, rows whose normalization a degree
      change would alter);
    * ``e_dst`` / ``touch_rows`` / ``f_rows`` / ``out_rows`` — rows whose
      aggregation state or embedding is written, per layer;
    * the batch's feature-update vertices;
    * every row whose in-degree the batch changes (``deg_old != deg_new``).

    Disjointness makes the merge exact (bitwise, not approximately): each
    row's records come from exactly one constituent batch in unchanged
    relative order, every gathered row's value is unchanged by the other
    constituents (any writer would put it in that constituent's next-layer
    record sets → overlap → no fusion), and the merged degree tables
    ``(plans[0].deg_old, plans[-1].deg_new)`` agree with every
    constituent's own view on every row it touches.  The merged plan is an
    ordinary :class:`BatchPlan`, so every backend's ``plan(base_plan=...)``
    path — packed, sharded, hybrid, chunked — consumes it unchanged, and
    capacity hysteresis (:class:`BucketHysteresis`) keeps the grown fused
    shapes from retracing the per-batch layouts."""

    def __init__(self, config: Optional[FusionConfig] = None) -> None:
        self.config = config or FusionConfig()

    # ---------------------------------------------------------------- #
    # overlap test (plan time, value-independent)
    # ---------------------------------------------------------------- #
    @staticmethod
    def footprint(plan: BatchPlan, batch: UpdateBatch) -> np.ndarray:
        """Sorted unique global row ids the batch's execution touches."""
        parts = [
            np.flatnonzero(plan.deg_old[:-1] != plan.deg_new[:-1]).astype(
                np.int64)
        ]
        if batch.feat_vertices is not None:
            parts.append(np.asarray(batch.feat_vertices, np.int64))
        for lp in plan.layers:
            parts.append(lp.e_src[lp.e_mask].astype(np.int64))
            parts.append(lp.e_dst[lp.e_mask].astype(np.int64))
            parts.append(lp.touch_rows[lp.touch_mask].astype(np.int64))
            parts.append(lp.f_rows[lp.f_mask].astype(np.int64))
            parts.append(lp.f_src[lp.f_emask].astype(np.int64))
            parts.append(lp.out_rows[lp.out_mask].astype(np.int64))
        return np.unique(np.concatenate(parts))

    @staticmethod
    def disjoint(fp: np.ndarray, other: np.ndarray) -> bool:
        """True iff two footprints (sorted unique) share no row."""
        if not fp.size or not other.size:
            return True
        return not np.isin(fp, other, assume_unique=True).any()

    def select_prefix(self, footprints: List[np.ndarray]) -> int:
        """Length of the maximal independent prefix (capped at ``window``).

        Greedy left-to-right: batch j joins the window iff its footprint is
        disjoint from the union of batches 0..j-1 — execution order inside
        the window is irrelevant once that holds, but the *prefix* rule
        keeps batches FIFO (batch j never dispatches before batch i < j)."""
        limit = min(len(footprints), self.config.window)
        if limit <= 1:
            return limit
        acc = footprints[0]
        k = 1
        while k < limit and self.disjoint(footprints[k], acc):
            acc = np.union1d(acc, footprints[k])
            k += 1
        return k

    # ---------------------------------------------------------------- #
    # plan concatenation (plan time, host only)
    # ---------------------------------------------------------------- #
    @staticmethod
    def merge(plans: List[BatchPlan],
              batches: List[UpdateBatch]) -> Tuple[BatchPlan, UpdateBatch]:
        """Concatenate independent batch plans into one merged plan.

        Per layer, live incremental records concatenate in batch order
        (each touched row's records stay contiguous and ordered, so the
        device scatter-adds accumulate bitwise-identically to the serial
        per-batch dispatches) and are re-padded through the standard
        :func:`_pad_records` bucketing; constrained rows / out rows are
        re-sorted unions (disjoint, so plain sorted concatenation) with
        ``f_rowidx`` re-based into the merged row list.  The merged
        :class:`UpdateBatch` carries the concatenated edge/feature updates
        so feature scatters and cache invalidation see one logical batch."""
        assert len(plans) == len(batches) and len(plans) >= 1
        n = int(plans[0].deg_old.shape[0]) - 1
        num_layers = len(plans[0].layers)
        layers: List[LayerPlan] = []
        for l in range(num_layers):
            lps = [p.layers[l] for p in plans]
            src = np.concatenate(
                [lp.e_src[lp.e_mask] for lp in lps]).astype(np.int64)
            dst = np.concatenate(
                [lp.e_dst[lp.e_mask] for lp in lps]).astype(np.int64)
            sign = np.concatenate(
                [lp.e_sign[lp.e_mask] for lp in lps]).astype(np.float32)
            use_new = np.concatenate(
                [lp.e_use_new[lp.e_mask] for lp in lps]).astype(bool)
            w = np.concatenate(
                [lp.e_w[lp.e_mask] for lp in lps]).astype(np.float32)
            t = np.concatenate(
                [lp.e_t[lp.e_mask] for lp in lps]).astype(np.int32)
            rec = _pad_records(n, src, dst, sign, use_new, w, t)
            (e_src, e_dst, e_rowidx, e_sign, e_use_new, e_w, e_t, e_mask,
             touch_rows, touch_mask) = rec

            # constrained full path: disjoint row sets → sorted union; each
            # row's in-edge segment stays contiguous in its original order
            vf = np.sort(np.concatenate(
                [lp.f_rows[lp.f_mask] for lp in lps]).astype(np.int64))
            f_srcs = np.concatenate(
                [lp.f_src[lp.f_emask] for lp in lps]).astype(np.int64)
            row_of = np.concatenate(
                [lp.f_rows[lp.f_rowidx[lp.f_emask]] for lp in lps]
            ).astype(np.int64)
            f_ridx = np.searchsorted(vf, row_of)
            f_cap = next_bucket(vf.shape[0])
            fe_cap = next_bucket(f_srcs.shape[0])

            def padv(a, cap, fill, dt):
                out = np.full(cap, fill, dtype=dt)
                out[: len(a)] = a
                return out

            f_ws = np.concatenate([lp.f_w[lp.f_emask] for lp in lps])
            f_ts = np.concatenate([lp.f_t[lp.f_emask] for lp in lps])
            out = np.sort(np.concatenate(
                [lp.out_rows[lp.out_mask] for lp in lps]).astype(np.int64))
            o_cap = next_bucket(out.shape[0])
            layers.append(LayerPlan(
                e_src=e_src, e_dst=e_dst, e_rowidx=e_rowidx, e_sign=e_sign,
                e_use_new=e_use_new, e_w=e_w, e_t=e_t, e_mask=e_mask,
                touch_rows=touch_rows, touch_mask=touch_mask,
                f_rows=padv(vf, f_cap, n, np.int32),
                f_mask=padv(np.ones(vf.shape[0], bool), f_cap, False, bool),
                f_src=padv(f_srcs, fe_cap, n, np.int32),
                f_rowidx=padv(f_ridx, fe_cap, f_cap, np.int32),
                f_w=padv(f_ws, fe_cap, 0.0, np.float32),
                f_t=padv(f_ts, fe_cap, 0, np.int32),
                f_emask=padv(np.ones(f_srcs.shape[0], bool), fe_cap, False,
                             bool),
                out_rows=padv(out, o_cap, n, np.int32),
                out_mask=padv(np.ones(out.shape[0], bool), o_cap, False,
                              bool),
                n_inc_edges=sum(lp.n_inc_edges for lp in lps),
                n_full_edges=sum(lp.n_full_edges for lp in lps),
                n_touch_rows=int(touch_mask.sum()),
                n_full_rows=int(vf.shape[0]),
                n_out_rows=int(out.shape[0]),
                n_src_accessed=sum(lp.n_src_accessed for lp in lps),
            ))
        merged_plan = BatchPlan(
            layers=layers,
            deg_old=plans[0].deg_old,
            deg_new=plans[-1].deg_new,
            changed0=np.concatenate([p.changed0 for p in plans]),
        )
        return merged_plan, _merge_batches(batches)


def _merge_batches(batches: List[UpdateBatch]) -> UpdateBatch:
    """Concatenate independent update batches into one logical batch."""
    def cat(arrs, dt):
        return np.concatenate([np.asarray(a, dt) for a in arrs])

    ins_n = [np.asarray(b.ins_src).shape[0] for b in batches]
    ins_w = None
    if any(b.ins_weights is not None for b in batches):
        ins_w = cat([b.ins_weights if b.ins_weights is not None
                     else np.ones(k, np.float32)
                     for b, k in zip(batches, ins_n)], np.float32)
    ins_t = None
    if any(b.ins_etypes is not None for b in batches):
        ins_t = cat([b.ins_etypes if b.ins_etypes is not None
                     else np.zeros(k, np.int32)
                     for b, k in zip(batches, ins_n)], np.int32)
    feat_v = feat_x = None
    featured = [b for b in batches if b.feat_vertices is not None]
    if featured:
        feat_v = cat([b.feat_vertices for b in featured], np.int64)
        feat_x = np.concatenate(
            [np.asarray(b.feat_values, np.float32) for b in featured])
    return UpdateBatch(
        ins_src=cat([b.ins_src for b in batches], np.int64),
        ins_dst=cat([b.ins_dst for b in batches], np.int64),
        del_src=cat([b.del_src for b in batches], np.int64),
        del_dst=cat([b.del_dst for b in batches], np.int64),
        ins_weights=ins_w,
        ins_etypes=ins_t,
        feat_vertices=feat_v,
        feat_values=feat_x,
    )
