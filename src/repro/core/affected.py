"""Affected-subgraph construction — paper Alg. 4, host side.

Per layer, classifies work into:

  * **incremental records** — signed per-edge delta contributions
    (insert → (+, new side), delete → (−, old side), changed source /
    changed structural context → a (−, old) / (+, new) pair), consumed by
    the device-side Alg.-1 kernel; and
  * **full-recompute vertices** — for constrained (destination-dependent)
    models, vertices whose previous-layer embedding changed and that still
    have in-edges must be fully recomputed over their complete new
    in-neighborhood (paper Alg. 4 lines 5–7).  Incremental records targeting
    these vertices are suppressed to avoid double counting.

All index arrays are padded to power-of-two buckets (``next_bucket``) so the
device functions re-trace only O(log) times over a stream.  Padded gather
indices point at a scratch row (index n) and padded scatter rows at the
capacity slot, so they can never alias live data.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.full import next_bucket
from repro.core.operators import GNNModel
from repro.graph.csr import CSRGraph
from repro.graph.streaming import UpdateBatch


@dataclasses.dataclass
class LayerPlan:
    # --- incremental signed records (padded to e_cap) ---
    e_src: np.ndarray  # int32 [Ecap], pad → n (scratch)
    e_dst: np.ndarray  # int32 [Ecap], pad → n
    e_rowidx: np.ndarray  # int32 [Ecap] index into touch_rows, pad → r_cap
    e_sign: np.ndarray  # float32 [Ecap]
    e_use_new: np.ndarray  # bool [Ecap]
    e_w: np.ndarray  # float32
    e_t: np.ndarray  # int32
    e_mask: np.ndarray  # bool
    # --- rows whose aggregation state is updated incrementally ---
    touch_rows: np.ndarray  # int32 [Rcap], pad → n
    touch_mask: np.ndarray  # bool
    # --- constrained full-recompute path ---
    f_rows: np.ndarray  # int32 [Fcap], pad → n
    f_mask: np.ndarray
    f_src: np.ndarray  # int32 [FEcap], pad → n
    f_rowidx: np.ndarray  # int32 [FEcap] into f_rows, pad → f_cap
    f_w: np.ndarray
    f_t: np.ndarray
    f_emask: np.ndarray
    # --- rows whose h^l changes ---
    out_rows: np.ndarray  # int32 [Ocap], pad → n
    out_mask: np.ndarray
    # --- accounting (paper Figs. 2/8/11 metrics) ---
    n_inc_edges: int = 0
    n_full_edges: int = 0
    n_touch_rows: int = 0
    n_full_rows: int = 0
    n_out_rows: int = 0
    n_src_accessed: int = 0

    @property
    def shape_key(self) -> Tuple[int, ...]:
        return (
            self.e_src.shape[0],
            self.touch_rows.shape[0],
            self.f_rows.shape[0],
            self.f_src.shape[0],
            self.out_rows.shape[0],
        )


@dataclasses.dataclass
class BatchPlan:
    layers: List[LayerPlan]
    deg_old: np.ndarray  # float32 [n+1] (scratch slot appended)
    deg_new: np.ndarray
    changed0: np.ndarray  # vertices with feature updates

    def total_inc_edges(self) -> int:
        return sum(p.n_inc_edges for p in self.layers)

    def total_full_edges(self) -> int:
        return sum(p.n_full_edges for p in self.layers)

    def total_vertices(self) -> int:
        return sum(p.n_out_rows for p in self.layers)


def _lookup_in_edge_data(g: CSRGraph, src: np.ndarray, dst: np.ndarray):
    """Vectorized (weight, etype) lookup for existing edges (u, v)."""
    w = np.empty(src.shape[0], np.float32)
    t = np.empty(src.shape[0], np.int32)
    for i, (u, v) in enumerate(zip(src, dst)):
        nbrs, ws, ts = g.in_edge_data(int(v))
        j = np.searchsorted(nbrs, u)
        assert j < nbrs.shape[0] and nbrs[j] == u, f"edge ({u},{v}) missing"
        w[i] = ws[j]
        t[i] = ts[j]
    return w, t


def _pad_records(
    n: int,
    src: np.ndarray,
    dst: np.ndarray,
    sign: np.ndarray,
    use_new: np.ndarray,
    w: np.ndarray,
    t: np.ndarray,
) -> Tuple[np.ndarray, ...]:
    e = src.shape[0]
    e_cap = next_bucket(e)
    rows, rowinv = np.unique(dst, return_inverse=True) if e else (np.zeros(0, np.int64), np.zeros(0, np.int64))
    r_cap = next_bucket(rows.shape[0])

    def pad(a, cap, fill, dt):
        out = np.full(cap, fill, dtype=dt)
        out[: a.shape[0]] = a
        return out

    return (
        pad(src, e_cap, n, np.int32),
        pad(dst, e_cap, n, np.int32),
        pad(rowinv, e_cap, r_cap, np.int32),
        pad(sign, e_cap, 0.0, np.float32),
        pad(use_new, e_cap, False, bool),
        pad(w, e_cap, 0.0, np.float32),
        pad(t, e_cap, 0, np.int32),
        pad(np.ones(e, bool), e_cap, False, bool),
        pad(rows, r_cap, n, np.int32),
        pad(np.ones(rows.shape[0], bool), r_cap, False, bool),
    )


def build_plan(
    model: GNNModel,
    g_old: CSRGraph,
    g_new: CSRGraph,
    batch: UpdateBatch,
    num_layers: int,
    restrict: Optional[List[set]] = None,
) -> BatchPlan:
    """Build per-layer incremental plans.

    ``restrict`` (ODEC, paper §V-D): optional per-layer vertex sets; layer
    l's work is intersected with ``restrict[l]`` (the query-induced K-hop
    cone), turning RTEC into on-demand embedding computation."""
    n = g_old.n
    deg_old = g_old.in_degree().astype(np.float32)
    deg_new = g_new.in_degree().astype(np.float32)
    deg_changed = np.nonzero(deg_old != deg_new)[0]

    ins_s = np.asarray(batch.ins_src, np.int64)
    ins_d = np.asarray(batch.ins_dst, np.int64)
    ins_w = (
        np.asarray(batch.ins_weights, np.float32)
        if batch.ins_weights is not None
        else np.ones(ins_s.shape[0], np.float32)
    )
    ins_t = (
        np.asarray(batch.ins_etypes, np.int32)
        if batch.ins_etypes is not None
        else np.zeros(ins_s.shape[0], np.int32)
    )
    del_s = np.asarray(batch.del_src, np.int64)
    del_d = np.asarray(batch.del_dst, np.int64)
    if del_s.size:
        del_w, del_t = _lookup_in_edge_data(g_old, del_s, del_d)
    else:
        del_w = np.zeros(0, np.float32)
        del_t = np.zeros(0, np.int32)
    inserted_keys = set(zip(ins_s.tolist(), ins_d.tolist()))

    changed0 = (
        np.asarray(batch.feat_vertices, np.int64)
        if batch.feat_vertices is not None
        else np.zeros(0, np.int64)
    )
    changed_h = changed0  # vertices whose h^{l-1} changed
    deg_new_int = g_new.in_degree()

    plans: List[LayerPlan] = []
    for layer_idx in range(num_layers):
        allowed = restrict[layer_idx] if restrict is not None else None
        changed_set = set(changed_h.tolist())
        # sources whose outgoing contributions changed
        c_src = set(changed_set)
        if model.src_struct_dependent:
            c_src |= set(deg_changed.tolist())
        # constrained full-recompute destinations
        if model.dest_dependent:
            v_full = np.array(
                sorted(
                    v
                    for v in changed_set
                    if deg_new_int[v] > 0 and (allowed is None or v in allowed)
                ),
                np.int64,
            )
        else:
            v_full = np.zeros(0, np.int64)
        v_full_set = set(v_full.tolist())

        # ---- incremental records ----
        rs, rd, rsign, rnew, rw, rt = [], [], [], [], [], []
        n_changed_edges = 0

        def _emit(s, d, sign, usenew, w, t):
            rs.append(s)
            rd.append(d)
            rsign.append(sign)
            rnew.append(usenew)
            rw.append(w)
            rt.append(t)

        def _allowed(d: int) -> bool:
            return allowed is None or d in allowed

        for i in range(ins_s.shape[0]):
            if int(ins_d[i]) not in v_full_set and _allowed(int(ins_d[i])):
                _emit(ins_s[i], ins_d[i], 1.0, True, ins_w[i], ins_t[i])
        for i in range(del_s.shape[0]):
            if int(del_d[i]) not in v_full_set and _allowed(int(del_d[i])):
                _emit(del_s[i], del_d[i], -1.0, False, del_w[i], del_t[i])
        for u in sorted(c_src):
            nbrs, ws, ts = g_new.out_edge_data(int(u))
            for j in range(nbrs.shape[0]):
                d = int(nbrs[j])
                if (int(u), d) in inserted_keys or d in v_full_set or not _allowed(d):
                    continue
                _emit(u, d, -1.0, False, ws[j], ts[j])
                _emit(u, d, 1.0, True, ws[j], ts[j])
                n_changed_edges += 1

        rec = _pad_records(
            n,
            np.array(rs, np.int64),
            np.array(rd, np.int64),
            np.array(rsign, np.float32),
            np.array(rnew, bool),
            np.array(rw, np.float32),
            np.array(rt, np.int32),
        )
        (e_src, e_dst, e_rowidx, e_sign, e_use_new, e_w, e_t, e_mask, touch_rows, touch_mask) = rec

        # ---- constrained full path ----
        f_srcs, f_ridx, f_ws, f_ts = [], [], [], []
        for ri, v in enumerate(v_full):
            nbrs, ws, ts = g_new.in_edge_data(int(v))
            f_srcs.extend(nbrs.tolist())
            f_ridx.extend([ri] * nbrs.shape[0])
            f_ws.extend(ws.tolist())
            f_ts.extend(ts.tolist())
        f_cap = next_bucket(v_full.shape[0])
        fe_cap = next_bucket(len(f_srcs))

        def padv(a, cap, fill, dt):
            out = np.full(cap, fill, dtype=dt)
            out[: len(a)] = a
            return out

        f_rows = padv(v_full, f_cap, n, np.int32)
        f_mask = padv(np.ones(v_full.shape[0], bool), f_cap, False, bool)
        f_src = padv(f_srcs, fe_cap, n, np.int32)
        f_rowidx = padv(f_ridx, fe_cap, f_cap, np.int32)
        f_w = padv(f_ws, fe_cap, 0.0, np.float32)
        f_t = padv(f_ts, fe_cap, 0, np.int32)
        f_emask = padv(np.ones(len(f_srcs), bool), fe_cap, False, bool)

        # ---- output rows ----
        out_set = set(touch_rows[touch_mask].tolist()) | v_full_set
        if model.update_uses_h:
            out_set |= changed_set if allowed is None else (changed_set & allowed)
        out = np.array(sorted(out_set), np.int64)
        o_cap = next_bucket(out.shape[0])
        out_rows = padv(out, o_cap, n, np.int32)
        out_mask = padv(np.ones(out.shape[0], bool), o_cap, False, bool)

        n_inc = ins_s.shape[0] + del_s.shape[0] + n_changed_edges
        srcs_accessed = len(set(rs) | set(f_srcs))
        plans.append(
            LayerPlan(
                e_src=e_src,
                e_dst=e_dst,
                e_rowidx=e_rowidx,
                e_sign=e_sign,
                e_use_new=e_use_new,
                e_w=e_w,
                e_t=e_t,
                e_mask=e_mask,
                touch_rows=touch_rows,
                touch_mask=touch_mask,
                f_rows=f_rows,
                f_mask=f_mask,
                f_src=f_src,
                f_rowidx=f_rowidx,
                f_w=f_w,
                f_t=f_t,
                f_emask=f_emask,
                out_rows=out_rows,
                out_mask=out_mask,
                n_inc_edges=n_inc,
                n_full_edges=len(f_srcs),
                n_touch_rows=int(touch_mask.sum()),
                n_full_rows=int(v_full.shape[0]),
                n_out_rows=int(out.shape[0]),
                n_src_accessed=srcs_accessed,
            )
        )
        changed_h = out

    deg_old_x = np.concatenate([deg_old, np.zeros(1, np.float32)])
    deg_new_x = np.concatenate([deg_new, np.zeros(1, np.float32)])
    return BatchPlan(layers=plans, deg_old=deg_old_x, deg_new=deg_new_x, changed0=changed0)


# ====================================================================== #
# Packed plans — pipelined-engine transfer format (paper §V co-processing)
# ====================================================================== #
# Per-field capacity kind within a layer's cap tuple (e, r, f, fe, o).
IDX_FIELDS: Tuple[Tuple[str, int], ...] = (
    ("e_src", 0), ("e_dst", 0), ("e_rowidx", 0), ("e_t", 0),
    ("touch_rows", 1), ("f_rows", 2), ("f_src", 3), ("f_rowidx", 3),
    ("f_t", 3), ("out_rows", 4),
)
FLT_FIELDS: Tuple[Tuple[str, int], ...] = (("e_sign", 0), ("e_w", 0), ("f_w", 3))
MSK_FIELDS: Tuple[Tuple[str, int], ...] = (
    ("e_mask", 0), ("e_use_new", 0), ("touch_mask", 1), ("f_mask", 2),
    ("f_emask", 3), ("out_mask", 4),
)


@dataclasses.dataclass(frozen=True)
class PackedLayout:
    """Static (hashable) shape descriptor of a packed plan.

    One distinct layout → one trace of the fused device step; the power-of-two
    bucketing in :func:`build_plan` keeps the number of layouts O(log) over a
    stream, exactly like the unfused per-layer functions."""

    n: int  # vertex count (scratch row index)
    feat_cap: int  # 0 → batch has no feature updates (static branch)
    caps: Tuple[Tuple[int, int, int, int, int], ...]  # per layer (e, r, f, fe, o)


@lru_cache(maxsize=None)
def layout_slices(layout: PackedLayout):
    """Static offset table: per-layer field → slice into the packed buffers.

    Returns (idx_slices, flt_slices, msk_slices, totals) where each *_slices
    is a tuple (one per layer) of name → slice dicts, and totals are the
    buffer lengths (idx_len, flt_len, msk_len)."""
    idx_off = layout.feat_cap  # [feat_rows | per-layer idx fields]
    flt_off = 2 * (layout.n + 1)  # [deg_old | deg_new | per-layer flt fields]
    msk_off = layout.feat_cap  # [feat_mask | per-layer msk fields]
    idx_sl, flt_sl, msk_sl = [], [], []
    for caps in layout.caps:
        di: Dict[str, slice] = {}
        for name, kind in IDX_FIELDS:
            di[name] = slice(idx_off, idx_off + caps[kind])
            idx_off += caps[kind]
        df: Dict[str, slice] = {}
        for name, kind in FLT_FIELDS:
            df[name] = slice(flt_off, flt_off + caps[kind])
            flt_off += caps[kind]
        dm: Dict[str, slice] = {}
        for name, kind in MSK_FIELDS:
            dm[name] = slice(msk_off, msk_off + caps[kind])
            msk_off += caps[kind]
        idx_sl.append(di)
        flt_sl.append(df)
        msk_sl.append(dm)
    return tuple(idx_sl), tuple(flt_sl), tuple(msk_sl), (idx_off, flt_off, msk_off)


@dataclasses.dataclass
class PackedPlan:
    """A whole batch's plan flattened into three contiguous host buffers.

    Shipping (idx, flt, msk[, feat_vals]) is one ``jax.device_put`` call per
    batch instead of ~24×L small per-array transfers; the static offset table
    (:func:`layout_slices`) lets the fused device step slice every field back
    out at trace time."""

    layout: PackedLayout
    idx: np.ndarray  # int32  [idx_len]
    flt: np.ndarray  # float32 [flt_len]  (leads with deg_old, deg_new)
    msk: np.ndarray  # bool   [msk_len]
    feat_vals: Optional[np.ndarray]  # float32 [feat_cap, d0] when feat_cap > 0
    # optional host-precomputed block-CSR schedules for the Pallas delta
    # scatter, one (perm, dloc, block_rows) triple per layer
    pallas: Optional[Tuple[Tuple[np.ndarray, np.ndarray, np.ndarray], ...]]
    # accounting (aggregated over layers; feeds BatchStats)
    n_inc_edges: int
    n_full_edges: int
    n_out_rows: int


def _pallas_delta_layout(lp: LayerPlan, tv: int, be: int):
    """Host side of the co-processed Pallas delta scatter: sort this layer's
    incremental records by touched-row tile and emit the block-aligned CSR
    schedule (gather perm composed back into the *unsorted* record order).

    The raw schedule length depends on how records distribute over row
    tiles, so it is padded to a power-of-two block-count bucket — otherwise
    every batch would present new shapes to the jitted fused step and force
    a recompile.  Padding: perm/dloc = -1 (zeroed message, matches no row),
    block_rows repeats its last tile (non-decreasing, so the kernel treats
    the extra blocks as accumulating zeros into an already-visited tile)."""
    from repro.kernels.segment_spmm import prepare_block_csr

    r_cap = lp.touch_rows.shape[0]
    dstk = np.where(lp.e_mask, lp.e_rowidx.astype(np.int64), -1)
    order = np.argsort(dstk, kind="stable")  # -1 (masked) sorts first; dropped
    perm_s, dloc, brows, e_pad = prepare_block_csr(dstk[order], r_cap, tv=tv, be=be)
    perm = np.where(perm_s >= 0, order[np.clip(perm_s, 0, None)], -1).astype(np.int32)
    cap = next_bucket(e_pad, minimum=be)  # pow2 ≥ be → stays a multiple of be
    if cap != e_pad:
        pad = cap - e_pad
        perm = np.concatenate([perm, np.full(pad, -1, np.int32)])
        dloc = np.concatenate([dloc, np.full(pad, -1, np.int32)])
        brows = np.concatenate(
            [brows, np.full(cap // be - brows.shape[0], brows[-1], np.int32)]
        )
    return perm, dloc, brows


def pack_plan(
    plan: BatchPlan,
    feat_vertices: Optional[np.ndarray] = None,
    feat_values: Optional[np.ndarray] = None,
    pallas: bool = False,
) -> PackedPlan:
    """Flatten a :class:`BatchPlan` into the packed transfer format."""
    n = plan.deg_old.shape[0] - 1
    if feat_vertices is not None and np.asarray(feat_vertices).size:
        fr = np.asarray(feat_vertices, np.int64)
        fv = np.asarray(feat_values, np.float32)
        feat_cap = next_bucket(fr.shape[0])
    else:
        fr = np.zeros(0, np.int64)
        fv = None
        feat_cap = 0
    layout = PackedLayout(
        n=n, feat_cap=feat_cap, caps=tuple(lp.shape_key for lp in plan.layers)
    )
    idx_sl, flt_sl, msk_sl, (idx_len, flt_len, msk_len) = layout_slices(layout)

    idx = np.full(idx_len, n, np.int32)  # default pad → scratch row
    flt = np.zeros(flt_len, np.float32)
    msk = np.zeros(msk_len, bool)
    flt[: n + 1] = plan.deg_old
    flt[n + 1 : 2 * (n + 1)] = plan.deg_new
    feat_vals = None
    if feat_cap:
        idx[: fr.shape[0]] = fr
        msk[: fr.shape[0]] = True
        feat_vals = np.zeros((feat_cap, fv.shape[1]), np.float32)
        feat_vals[: fv.shape[0]] = fv
    for l, lp in enumerate(plan.layers):
        for name, _ in IDX_FIELDS:
            idx[idx_sl[l][name]] = getattr(lp, name)
        for name, _ in FLT_FIELDS:
            flt[flt_sl[l][name]] = getattr(lp, name)
        for name, _ in MSK_FIELDS:
            msk[msk_sl[l][name]] = getattr(lp, name)

    pallas_sched = None
    if pallas:
        from repro.kernels.delta_agg import DELTA_BE, DELTA_TV

        pallas_sched = tuple(
            _pallas_delta_layout(lp, DELTA_TV, DELTA_BE) for lp in plan.layers
        )
    return PackedPlan(
        layout=layout,
        idx=idx,
        flt=flt,
        msk=msk,
        feat_vals=feat_vals,
        pallas=pallas_sched,
        n_inc_edges=plan.total_inc_edges(),
        n_full_edges=plan.total_full_edges(),
        n_out_rows=plan.total_vertices(),
    )


def build_packed_plan(
    model: GNNModel,
    g_old: CSRGraph,
    g_new: CSRGraph,
    batch: UpdateBatch,
    num_layers: int,
    pallas: bool = False,
) -> PackedPlan:
    """Alg.-4 planning straight into the packed transfer format."""
    plan = build_plan(model, g_old, g_new, batch, num_layers)
    return pack_plan(plan, batch.feat_vertices, batch.feat_values, pallas=pallas)
