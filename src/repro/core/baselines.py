"""Non-incremental RTEC baselines (paper §III / §VI "Baselines").

* **RTEC-Full (FN)** — full-neighbor recomputation of the L-hop *backward*
  computation graph of every final-layer affected vertex (the paper's naive
  RTEC; 2L-hop pattern).
* **RTEC-NS{f}** — the same backward graph, but every vertex's neighborhood
  is down-sampled to fanout f (Helios-style [36]); biased to always retain
  updated edges so the change is visible at all.
* **RTEC-UER** — unaffected-embedding reuse (λGrapher [9]): recompute only
  the *forward-affected* vertices per layer, but each over its FULL
  in-neighborhood, reusing cached embeddings of unaffected vertices.
* **MTEC-Period** — periodic full recomputation every T batches; stale in
  between (industrial snapshot pipelines [25]).

All baselines share the device compute core (:func:`subset_layer`) and the
padding/bucketing discipline of the incremental engine, so the runtime
comparison isolates the algorithmic difference, mirroring the paper's
"reimplemented in NeutronRT for fairness" methodology.  Each `apply_batch`
returns the same counters as the engine (edges processed / vertices).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import BatchStats
from repro.core.full import full_forward, next_bucket, subset_layer
from repro.core.operators import GNNModel, Params
from repro.graph.csr import CSRGraph
from repro.graph.streaming import UpdateBatch


def forward_affected_sets(
    model: GNNModel, g_old: CSRGraph, g_new: CSRGraph, batch: UpdateBatch, L: int
) -> List[np.ndarray]:
    """Forward frontier: vertices whose h^l changes, per layer (conservative,
    same propagation rule as the incremental planner)."""
    deg_changed = np.nonzero(g_old.in_degree() != g_new.in_degree())[0]
    changed = set(
        np.asarray(batch.feat_vertices, np.int64).tolist()
        if batch.feat_vertices is not None
        else []
    )
    out: List[np.ndarray] = []
    upd_dsts = set(np.concatenate([batch.ins_dst, batch.del_dst]).astype(np.int64).tolist())
    for _ in range(L):
        c_src = set(changed)
        if model.src_struct_dependent:
            c_src |= set(deg_changed.tolist())
        affected = set(upd_dsts)
        for u in c_src:
            affected |= set(g_new.out_neighbors(int(u)).tolist())
        if model.update_uses_h:
            affected |= changed
        changed = affected
        out.append(np.array(sorted(affected), np.int64))
    return out


def _gather_in_edges(
    g: CSRGraph, rows: np.ndarray, fanout: int = 0, rng: Optional[np.random.Generator] = None,
    must_keep: Optional[Set[Tuple[int, int]]] = None,
):
    srcs, ridx, ws, ts = [], [], [], []
    for i, v in enumerate(rows):
        nbrs, w, t = g.in_edge_data(int(v))
        k = nbrs.shape[0]
        if fanout and k > fanout and rng is not None:
            sel = rng.choice(k, size=fanout, replace=False)
            if must_keep:
                keep_idx = [j for j in range(k) if (int(nbrs[j]), int(v)) in must_keep]
                sel = np.unique(np.concatenate([sel, np.array(keep_idx, int)])) if keep_idx else sel
            nbrs, w, t = nbrs[sel], w[sel], t[sel]
        srcs.extend(nbrs.tolist())
        ridx.extend([i] * nbrs.shape[0])
        ws.extend(w.tolist())
        ts.extend(t.tolist())
    return srcs, ridx, ws, ts


def _run_subset_layers(
    model: GNNModel,
    params: Sequence[Params],
    h_layers: List[jax.Array],
    layer_rows: List[np.ndarray],
    g: CSRGraph,
    fanout: int = 0,
    rng: Optional[np.random.Generator] = None,
    must_keep: Optional[Set[Tuple[int, int]]] = None,
) -> Tuple[List[jax.Array], int, int]:
    """Recompute h^l for layer_rows[l], reading (possibly updated) h^{l-1}.

    Returns (new h list, edges_processed, vertices_touched)."""
    n = g.n
    deg = jnp.asarray(
        np.concatenate([g.in_degree().astype(np.float32), np.zeros(1, np.float32)])
    )
    edges = 0
    verts = 0
    h_new = [h_layers[0]]
    for l, rows in enumerate(layer_rows):
        srcs, ridx, ws, ts = _gather_in_edges(g, rows, fanout, rng, must_keep)
        edges += len(srcs)
        verts += rows.shape[0]
        r_cap = next_bucket(rows.shape[0])
        e_cap = next_bucket(len(srcs))

        def pad(a, cap, fill, dt):
            out = np.full(cap, fill, dtype=dt)
            out[: len(a)] = a
            return out

        rows_p = jnp.asarray(pad(rows, r_cap, n, np.int32))
        rmask = jnp.asarray(pad(np.ones(rows.shape[0], bool), r_cap, False, bool))
        e_src = jnp.asarray(pad(srcs, e_cap, n, np.int32))
        e_ridx = jnp.asarray(pad(ridx, e_cap, r_cap, np.int32))
        e_w = jnp.asarray(pad(ws, e_cap, 0.0, np.float32))
        e_t = jnp.asarray(pad(ts, e_cap, 0, np.int32))
        e_mask = jnp.asarray(pad(np.ones(len(srcs), bool), e_cap, False, bool))

        h_prev = jnp.concatenate(
            [h_new[l], jnp.zeros((1, h_new[l].shape[1]), h_new[l].dtype)]
        )
        _, _, h_rows = _subset_jit(
            model, params[l], h_prev, rows_p, rmask, e_src, e_ridx, e_w, e_t, e_mask,
            deg, r_cap,
        )
        h_ext = jnp.concatenate(
            [h_layers[l + 1], jnp.zeros((1, h_layers[l + 1].shape[1]), h_layers[l + 1].dtype)]
        )
        h_l = h_ext.at[rows_p].set(h_rows)[:n]
        h_new.append(h_l)
    return h_new, edges, verts


from functools import partial


@partial(jax.jit, static_argnums=(0, 11))
def _subset_jit(model, p, h_prev, rows, rmask, e_src, e_ridx, e_w, e_t, e_mask, deg, r_cap):
    return subset_layer(model, p, h_prev, rows, rmask, e_src, e_ridx, e_w, e_t, e_mask, deg, r_cap)


# ====================================================================== #
@dataclasses.dataclass
class _BaseRTEC:
    model: GNNModel
    params: Sequence[Params]
    graph: CSRGraph
    x: jax.Array

    def __post_init__(self):
        self.L = len(self.params)
        states = full_forward(self.model, self.params, self.x, self.graph)
        self.h: List[jax.Array] = [jnp.asarray(self.x)] + [s.h for s in states]

    @property
    def embeddings(self) -> jax.Array:
        return self.h[-1]

    def _apply_graph(self, batch: UpdateBatch) -> CSRGraph:
        return self.graph.apply_updates(
            batch.ins_src, batch.ins_dst, batch.del_src, batch.del_dst,
            batch.ins_weights, batch.ins_etypes,
        )

    def _apply_features(self, batch: UpdateBatch) -> None:
        if batch.feat_vertices is not None and batch.feat_vertices.size:
            self.h[0] = self.h[0].at[jnp.asarray(batch.feat_vertices)].set(
                jnp.asarray(batch.feat_values, self.h[0].dtype)
            )


class RTECFull(_BaseRTEC):
    """Naive full-neighbor RTEC: recompute the backward L-hop computation
    graph of all final-layer affected vertices from scratch."""

    def apply_batch(self, batch: UpdateBatch) -> BatchStats:
        t0 = time.perf_counter()
        g_new = self._apply_graph(batch)
        t1 = time.perf_counter()
        fwd = forward_affected_sets(self.model, self.graph, g_new, batch, self.L)
        finals = fwd[-1]
        # backward closure: layer l needs in-neighbors of layer l+1 rows
        layer_rows: List[np.ndarray] = [None] * self.L  # type: ignore
        need = set(finals.tolist())
        for l in range(self.L - 1, -1, -1):
            layer_rows[l] = np.array(sorted(need), np.int64)
            nxt = set(need)
            for v in need:
                nxt |= set(g_new.in_neighbors(int(v)).tolist())
            need = nxt
        t2 = time.perf_counter()
        self._apply_features(batch)
        self.h, edges, verts = _run_subset_layers(
            self.model, self.params, self.h, layer_rows, g_new
        )
        jax.block_until_ready(self.h[-1])  # timed boundary: completion, not dispatch
        t3 = time.perf_counter()
        self.graph = g_new
        return BatchStats(
            inc_edges=0, full_edges=edges, out_vertices=verts,
            plan_time_s=t2 - t1, exec_time_s=t3 - t2, graph_time_s=t1 - t0,
        )


class RTECSample(RTECFull):
    """RTEC with neighbor sampling (fanout-limited backward graph)."""

    def __init__(self, model, params, graph, x, fanout: int = 10, seed: int = 0):
        super().__init__(model, params, graph, x)
        self.fanout = fanout
        self.rng = np.random.default_rng(seed)

    def apply_batch(self, batch: UpdateBatch) -> BatchStats:
        t0 = time.perf_counter()
        g_new = self._apply_graph(batch)
        t1 = time.perf_counter()
        fwd = forward_affected_sets(self.model, self.graph, g_new, batch, self.L)
        finals = fwd[-1]
        must_keep = set(zip(batch.ins_src.tolist(), batch.ins_dst.tolist()))
        layer_rows: List[np.ndarray] = [None] * self.L  # type: ignore
        need = set(finals.tolist())
        for l in range(self.L - 1, -1, -1):
            layer_rows[l] = np.array(sorted(need), np.int64)
            nxt = set(need)
            for v in need:
                nbrs = g_new.in_neighbors(int(v))
                if nbrs.shape[0] > self.fanout:
                    nbrs = self.rng.choice(nbrs, size=self.fanout, replace=False)
                nxt |= set(np.asarray(nbrs).tolist())
            need = nxt
        t2 = time.perf_counter()
        self._apply_features(batch)
        self.h, edges, verts = _run_subset_layers(
            self.model, self.params, self.h, layer_rows, g_new,
            fanout=self.fanout, rng=self.rng, must_keep=must_keep,
        )
        jax.block_until_ready(self.h[-1])  # timed boundary: completion, not dispatch
        t3 = time.perf_counter()
        self.graph = g_new
        return BatchStats(
            inc_edges=0, full_edges=edges, out_vertices=verts,
            plan_time_s=t2 - t1, exec_time_s=t3 - t2, graph_time_s=t1 - t0,
        )


class RTECUER(_BaseRTEC):
    """Unaffected-embedding reuse: recompute forward-affected vertices only,
    each over its full new in-neighborhood (λGrapher-style)."""

    def apply_batch(self, batch: UpdateBatch) -> BatchStats:
        t0 = time.perf_counter()
        g_new = self._apply_graph(batch)
        t1 = time.perf_counter()
        layer_rows = forward_affected_sets(self.model, self.graph, g_new, batch, self.L)
        t2 = time.perf_counter()
        self._apply_features(batch)
        self.h, edges, verts = _run_subset_layers(
            self.model, self.params, self.h, layer_rows, g_new
        )
        jax.block_until_ready(self.h[-1])  # timed boundary: completion, not dispatch
        t3 = time.perf_counter()
        self.graph = g_new
        return BatchStats(
            inc_edges=0, full_edges=edges, out_vertices=verts,
            plan_time_s=t2 - t1, exec_time_s=t3 - t2, graph_time_s=t1 - t0,
        )


class MTECPeriod(_BaseRTEC):
    """Periodic recomputation: refresh every `period` batches, stale between."""

    def __init__(self, model, params, graph, x, period: int = 10):
        super().__init__(model, params, graph, x)
        self.period = period
        self._seen = 0

    def apply_batch(self, batch: UpdateBatch) -> BatchStats:
        t0 = time.perf_counter()
        g_new = self._apply_graph(batch)
        self.graph = g_new
        self._apply_features(batch)
        self._seen += 1
        edges = 0
        verts = 0
        t1 = time.perf_counter()
        if self._seen % self.period == 0:
            states = full_forward(self.model, self.params, self.h[0], self.graph)
            self.h = [self.h[0]] + [s.h for s in states]
            edges = self.graph.num_edges * self.L
            verts = self.graph.n * self.L
        t2 = time.perf_counter()
        return BatchStats(
            inc_edges=0, full_edges=edges, out_vertices=verts,
            plan_time_s=0.0, exec_time_s=t2 - t1, graph_time_s=t1 - t0,
        )
