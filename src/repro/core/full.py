"""Full-neighbor RTEC reference (paper Eq. 5–9 / Alg. 2 generalized).

This is (a) the from-scratch oracle against which incremental RTEC is proven
equivalent, (b) the compute core of the RTEC-Full / RTEC-UER / MTEC-Period
baselines, and (c) the padded-subset layer used for the constrained-model
full-recompute path.

All functions are pure and jittable; edge arrays may be padded (mask=False
rows contribute nothing).  Scatter targets use a scratch row at index ``n``
so padded indices never alias real vertices.
"""
from __future__ import annotations

from functools import partial
from typing import List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.operators import GNNModel, Params


class LayerState(NamedTuple):
    """Cached per-layer results (the paper's 'historical results')."""

    a: jax.Array  # [N, agg_dim]  aggregated (context-applied) neighbor state
    nct: jax.Array  # [N, ctx_dim]  neighborhood context
    h: jax.Array  # [N, d_out]   layer output embedding


def edge_messages(
    model: GNNModel,
    p: Params,
    h_src: jax.Array,
    h_dst: jax.Array,
    s_src: jax.Array,
    s_dst: jax.Array,
    ew: jax.Array,
    et: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Per-edge (ctx_contrib, raw_term) under the decoupled abstraction."""
    mlc = model.ms_local(p, h_src, h_dst, s_src, s_dst, ew, et)
    ctx = model.ctx_contrib(p, mlc, et)
    z = model.f_nn(p, h_src, et)
    raw = model.edge_term(p, mlc, z, et)
    return ctx, raw


def full_layer(
    model: GNNModel,
    p: Params,
    h: jax.Array,  # [N, d_in] previous-layer embeddings
    src: jax.Array,  # [E] (padded ok; padded entries must index n)
    dst: jax.Array,  # [E]
    ew: jax.Array,
    et: jax.Array,
    mask: jax.Array,  # [E] bool
    deg: jax.Array,  # [N] float in-degrees of the *current* graph
    n: int,
) -> LayerState:
    """One full-neighbor layer over (possibly padded) edge arrays."""
    hs = h[src]
    if model.dest_dependent:
        hd = h[dst]
    else:  # Theorem-1 unconstrained: ms_local ignores h_v — skip the gather
        hd = jnp.zeros((src.shape[0], h.shape[1]), h.dtype)
    ss = deg[src]
    sd = deg[dst]
    ctx, raw = edge_messages(model, p, hs, hd, ss, sd, ew, et)
    m = mask.astype(raw.dtype)
    ctx = ctx * m[:, None]
    raw = raw * m[:, None]
    nct = jax.ops.segment_sum(ctx, dst, num_segments=n + 1)[:n]
    s = jax.ops.segment_sum(raw, dst, num_segments=n + 1)[:n]
    a = model.ms_cbn(p, nct, s)
    h_out = model.update(p, h, a)
    return LayerState(a=a, nct=nct, h=h_out)


@partial(jax.jit, static_argnums=(0, 7))
def _full_forward_jit(model, params_tuple, x, src, dst, ew, et, n, deg):
    h = x
    states = []
    mask = jnp.ones(src.shape[0], dtype=bool)
    for p in params_tuple:
        st = full_layer(model, p, h, src, dst, ew, et, mask, deg, n)
        states.append(st)
        h = st.h
    return states


def full_forward(
    model: GNNModel,
    params: Sequence[Params],
    x: jax.Array,
    graph,
) -> List[LayerState]:
    """From-scratch L-layer forward over a CSRGraph snapshot."""
    src_np, dst_np, w_np, t_np = graph.edges_by_dst()
    deg = jnp.asarray(graph.in_degree(), jnp.float32)
    src = jnp.asarray(src_np, jnp.int32)
    dst = jnp.asarray(dst_np, jnp.int32)
    ew = jnp.asarray(w_np, jnp.float32)
    et = jnp.asarray(t_np, jnp.int32)
    return _full_forward_jit(model, tuple(params), x, src, dst, ew, et, graph.n, deg)


def subset_layer(
    model: GNNModel,
    p: Params,
    h_prev: jax.Array,  # [N, d_in]   (mixed cached/new)
    rows: jax.Array,  # [R]  vertex ids to (re)compute (padded with n)
    rows_mask: jax.Array,  # [R]
    e_src: jax.Array,  # [E] sources (padded)
    e_rowidx: jax.Array,  # [E] index into rows (padded → R scratch row)
    e_w: jax.Array,
    e_t: jax.Array,
    e_mask: jax.Array,
    deg: jax.Array,  # [N+1] float degrees with scratch slot
    r_cap: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-neighbor recompute restricted to a padded vertex subset.

    Returns (a_rows [R, agg], nct_rows [R, C], h_rows [R, d_out])."""
    hs = h_prev[e_src]
    hd = h_prev[rows][e_rowidx]
    ss = deg[e_src]
    sd = deg[rows][e_rowidx]
    ctx, raw = edge_messages(model, p, hs, hd, ss, sd, e_w, e_t)
    m = e_mask.astype(raw.dtype)
    ctx = ctx * m[:, None]
    raw = raw * m[:, None]
    nct = jax.ops.segment_sum(ctx, e_rowidx, num_segments=r_cap + 1)[:r_cap]
    s = jax.ops.segment_sum(raw, e_rowidx, num_segments=r_cap + 1)[:r_cap]
    a = model.ms_cbn(p, nct, s)
    h_rows = model.update(p, h_prev[rows], a)
    return a, nct, h_rows


def pad_to(arr: np.ndarray, cap: int, fill) -> np.ndarray:
    out = np.full((cap,) + arr.shape[1:], fill, dtype=arr.dtype)
    out[: arr.shape[0]] = arr
    return out


def next_bucket(x: int, minimum: int = 16) -> int:
    """Power-of-two capacity bucketing to bound recompilation."""
    c = max(minimum, int(x))
    return 1 << int(np.ceil(np.log2(c))) if c > 0 else minimum
