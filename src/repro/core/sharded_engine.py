"""Row-sharded pipelined streaming engine (multi-device co-processing).

Scales the PR-2 pipelined engine past one device's memory: the
scratch-extended per-layer state (h, a, nct) is block row-partitioned over a
1-D ``repro.dist`` mesh as stacked ``[S, rows_per + 1, ·]`` arrays (one
scratch row per shard), each update batch is planned on the host (Alg. 4)
and **partitioned per shard at plan time**
(:func:`repro.core.affected.shard_plan`), and the reordered incremental
workflow runs as one donated, shard_map'd L-layer step per batch
(:func:`repro.core.incremental.sharded_step_fn`):

* **Per-shard transfers** — the packed plan ships as stacked ``[S, ·]``
  buffers under a ``graph_rows`` NamedSharding, so the single ``device_put``
  delivers to each device only the plan rows it touches.
* **Owner-local scatters** — records are partitioned by destination-row
  owner, so every state scatter is local; only previous-layer *source*
  embeddings cross shards.
* **Frontier-bounded collective** — one ``psum`` of the per-layer halo
  buffer (remote source rows only); the dest-independent halo-skip
  (EXPERIMENTS.md §Perf) keeps destination embeddings out of it entirely
  for unconstrained models.
* **Plan/execute overlap + hysteresis** — :meth:`apply_stream` plans (and
  partitions) batch t+1 on the host while the devices run batch t, and
  per-field high-water-mark buckets (:class:`BucketHysteresis`) keep the
  shard_map trace count bounded over the stream.

The ``apply_batch`` / ``apply_stream`` / ``embeddings`` contract matches
:class:`~repro.core.engine.RTECEngine` (same ``BatchStats``/``StreamStats``),
so benchmarks and serving code can swap engines freely.
"""
from __future__ import annotations

import time
import warnings
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.affected import (
    BucketHysteresis,
    ShardedPlan,
    build_plan,
    shard_plan,
    shard_rows,
)
from repro.core.engine import BatchStats, StreamStats
from repro.core.full import full_forward
from repro.core.incremental import sharded_step_fn
from repro.core.operators import GNNModel, Params
from repro.dist.sharding import ShardingConfig, stream_mesh, stream_state_specs
from repro.graph.csr import CSRGraph
from repro.graph.streaming import UpdateBatch


class ShardedRTECEngine:
    def __init__(
        self,
        model: GNNModel,
        params: Sequence[Params],
        graph: CSRGraph,
        x: jax.Array,
        mesh=None,
        num_shards: Optional[int] = None,
        shcfg: Optional[ShardingConfig] = None,
        refresh_every: int = 0,
    ):
        self.model = model
        self.L = len(list(params))
        self.graph = graph
        self.refresh_every = refresh_every
        self.shcfg = shcfg or ShardingConfig()
        self.mesh = mesh if mesh is not None else stream_mesh(num_shards, self.shcfg)
        self.axis = tuple(self.mesh.axis_names)[0]
        self.S = int(self.mesh.shape[self.axis])
        self.rows_per = shard_rows(graph.n, self.S)
        specs = stream_state_specs(self.mesh, self.shcfg)
        self._state_sh = specs["state"]
        self._plan_sh = specs["plan"]
        self._rep_sh = specs["replicated"]
        self._params_host = list(params)
        # step inputs must all live on the mesh: replicate params once
        self.params = jax.device_put(tuple(params), self._rep_sh)
        self._step = sharded_step_fn(model, self.mesh, self.axis)
        self._hwm = BucketHysteresis()
        self._batches_seen = 0
        self.halo_rows_total = 0
        self._x_host = np.asarray(x, np.float32)
        self._init_state()

    # ------------------------------------------------------------------ #
    # state: stacked [S, rows_per+1, ·] blocks (last local row = scratch)
    # ------------------------------------------------------------------ #
    def _to_blocks(self, arr) -> jax.Array:
        flat = np.asarray(arr, np.float32)
        out = np.zeros((self.S, self.rows_per + 1) + flat.shape[1:], np.float32)
        for s in range(self.S):
            lo = s * self.rows_per
            hi = min(self.graph.n, lo + self.rows_per)
            if hi > lo:
                out[s, : hi - lo] = flat[lo:hi]
        return jax.device_put(out, self._state_sh)

    def _from_blocks(self, blocks: jax.Array) -> np.ndarray:
        arr = np.asarray(blocks)[:, : self.rows_per]
        return arr.reshape(self.S * self.rows_per, *arr.shape[2:])[: self.graph.n]

    def _init_state(self, x: Optional[np.ndarray] = None) -> None:
        if x is None:
            x = self._x_host
        states = full_forward(self.model, self._params_host,
                              jnp.asarray(x), self.graph)
        self._h: List[jax.Array] = [self._to_blocks(x)] + [
            self._to_blocks(s.h) for s in states
        ]
        self._a: List[jax.Array] = [self._to_blocks(s.a) for s in states]
        self._nct: List[jax.Array] = [self._to_blocks(s.nct) for s in states]

    def refresh(self) -> None:
        """Full recomputation (drift reset) over the current snapshot and the
        *current* features — layer-0 feature updates applied during the
        stream live in the h[0] blocks, not in the construction-time x."""
        self._init_state(self._from_blocks(self._h[0]))

    @property
    def embeddings(self) -> np.ndarray:
        return self._from_blocks(self._h[-1])

    @property
    def h(self) -> List[np.ndarray]:
        return [self._from_blocks(v) for v in self._h]

    @property
    def a(self) -> List[np.ndarray]:
        return [self._from_blocks(v) for v in self._a]

    @property
    def nct(self) -> List[np.ndarray]:
        return [self._from_blocks(v) for v in self._nct]

    def state_bytes(self) -> int:
        return sum(int(np.prod(v.shape)) * 4 for v in (*self._h, *self._a, *self._nct))

    def _sync_arrays(self):
        return [*self._h, *self._a, *self._nct]

    # ------------------------------------------------------------------ #
    # per-batch API (same honest-timing contract as RTECEngine)
    # ------------------------------------------------------------------ #
    def apply_batch(self, batch: UpdateBatch, block: bool = True) -> BatchStats:
        t0 = time.perf_counter()
        g_new = self.graph.apply_updates(
            batch.ins_src, batch.ins_dst, batch.del_src, batch.del_dst,
            batch.ins_weights, batch.ins_etypes,
        )
        t1 = time.perf_counter()
        sp = self._shard_plan(g_new, batch)
        t2 = time.perf_counter()
        self._dispatch(sp)
        if block:
            jax.block_until_ready(self._sync_arrays())
        t3 = time.perf_counter()
        self.graph = g_new
        self._after_batch()
        return BatchStats(
            inc_edges=sp.n_inc_edges,
            full_edges=sp.n_full_edges,
            out_vertices=sp.n_out_rows,
            plan_time_s=t2 - t1,
            exec_time_s=t3 - t2,
            graph_time_s=t1 - t0,
        )

    # ------------------------------------------------------------------ #
    # pipelined stream API: plan+partition t+1 while the mesh executes t
    # ------------------------------------------------------------------ #
    def apply_stream(self, batches: Sequence[UpdateBatch]) -> StreamStats:
        batches = list(batches)
        if not batches:
            return StreamStats([], 0.0, 0.0)
        t_start = time.perf_counter()
        stats: List[BatchStats] = []
        plan_total = 0.0

        tp = time.perf_counter()
        g_new, sp = self._plan_batch(batches[0])
        plan_total += time.perf_counter() - tp

        for i in range(len(batches)):
            td = time.perf_counter()
            self._dispatch(sp)  # async: the mesh starts batch i
            dispatch_s = time.perf_counter() - td
            self.graph = g_new
            stats.append(
                BatchStats(
                    inc_edges=sp.n_inc_edges,
                    full_edges=sp.n_full_edges,
                    out_vertices=sp.n_out_rows,
                    plan_time_s=0.0,
                    exec_time_s=dispatch_s,  # dispatch-only; see StreamStats
                    graph_time_s=0.0,
                )
            )
            if i + 1 < len(batches):
                tp = time.perf_counter()  # overlapped with device execution
                g_new, sp = self._plan_batch(batches[i + 1])
                plan_total += time.perf_counter() - tp
            self._after_batch(sync_before_refresh=True)
        jax.block_until_ready(self._sync_arrays())
        return StreamStats(stats, time.perf_counter() - t_start, plan_total)

    # ------------------------------------------------------------------ #
    def _after_batch(self, sync_before_refresh: bool = False) -> None:
        self._batches_seen += 1
        if self.refresh_every and self._batches_seen % self.refresh_every == 0:
            if sync_before_refresh:
                jax.block_until_ready(self._sync_arrays())
            self.refresh()

    def _plan_batch(self, batch: UpdateBatch):
        g_new = self.graph.apply_updates(
            batch.ins_src, batch.ins_dst, batch.del_src, batch.del_dst,
            batch.ins_weights, batch.ins_etypes,
        )
        return g_new, self._shard_plan(g_new, batch)

    def _shard_plan(self, g_new: CSRGraph, batch: UpdateBatch) -> ShardedPlan:
        plan = build_plan(self.model, self.graph, g_new, batch, self.L)
        return shard_plan(plan, self.S, batch.feat_vertices, batch.feat_values,
                          hwm=self._hwm)

    def _dispatch(self, sp: ShardedPlan) -> None:
        """One sharded device_put (each device gets only its plan slice),
        one shard_map'd fused-step dispatch."""
        idx_sh, flt_sh, msk_sh = jax.device_put(
            (sp.idx_sh, sp.flt_sh, sp.msk_sh), self._plan_sh
        )
        fv = sp.feat_vals if sp.feat_vals is not None else np.zeros(
            (0, self._x_host.shape[1]), np.float32
        )
        idx_rep, msk_rep, feat_vals = jax.device_put(
            (sp.idx_rep, sp.msk_rep, fv), self._rep_sh
        )
        with warnings.catch_warnings():
            # donation is a TPU/GPU aliasing optimization; CPU jit ignores it
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable"
            )
            hs, as_, ncts = self._step(
                sp.layout, self.params,
                tuple(self._h), tuple(self._a), tuple(self._nct),
                idx_sh, flt_sh, msk_sh, idx_rep, msk_rep, feat_vals,
            )
        self._h = list(hs)
        self._a = list(as_)
        self._nct = list(ncts)
        self.halo_rows_total += sp.n_halo_rows
