"""Row-sharded pipelined streaming engine (multi-device co-processing).

Thin facade over :class:`~repro.core.backend.StreamOrchestrator` +
:class:`~repro.core.backend.ShardBackend`: the scratch-extended per-layer
state (h, a, nct) is block row-partitioned over a 1-D ``repro.dist`` mesh as
stacked ``[S, rows_per + 1, ·]`` arrays (one scratch row per shard), each
update batch is planned on the host (Alg. 4) and **partitioned per shard at
plan time** (:func:`repro.core.affected.shard_plan`), and the reordered
incremental workflow runs as one donated, shard_map'd L-layer step per batch
(:func:`repro.core.incremental.sharded_step_fn`):

* **Per-shard transfers** — the packed plan ships as stacked ``[S, ·]``
  buffers under a ``graph_rows`` NamedSharding, so the single ``device_put``
  delivers to each device only the plan rows it touches.
* **Owner-local scatters** — records are partitioned by destination-row
  owner, so every state scatter is local; only previous-layer *source*
  embeddings cross shards.
* **Collective-minimal halo exchange** — governed by the typed
  :class:`~repro.dist.sharding.CommsConfig` (ISSUE 10).  ``"ppermute"``
  (the multi-shard default under ``"auto"``) moves each halo row from its
  owner to exactly the consumers whose frontier references it, via
  plan-time ``lax.ppermute`` send/recv schedules padded to
  hysteresis-bucketed per-pair capacities; ``"psum"`` keeps the legacy
  one-collective broadcast of the per-layer halo buffer.  Both are
  bitwise-equal; the dest-independent halo-skip (EXPERIMENTS.md §Perf)
  keeps destination embeddings out of either path for unconstrained
  models, and ``StreamStats.comms_halo_rows_sent`` /
  ``comms_halo_bytes`` count the traffic.
* **Plan/execute overlap + hysteresis** — :meth:`apply_stream` plans (and
  partitions) batch t+1 on the host while the devices run batch t, and
  per-field high-water-mark buckets (:class:`BucketHysteresis`) keep the
  shard_map trace count bounded over the stream.
* **Per-shard Pallas delta scatter** — ``use_pallas_delta=True`` ships a
  per-shard block-CSR schedule with the plan and routes step 1's scatter
  through the ``delta_agg`` kernel inside each shard (XLA segment-sum is
  the fallback), exactly like the single-device engine's flag.

The ``apply_batch`` / ``apply_stream`` / ``embeddings`` contract matches
:class:`~repro.core.engine.RTECEngine` (same ``BatchStats``/``StreamStats``),
so benchmarks and serving code can swap engines freely.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.backend import (
    BatchStats,
    StreamStats,
)
from repro.core.operators import GNNModel, Params
from repro.dist.sharding import ShardingConfig
from repro.graph.csr import CSRGraph
from repro.graph.streaming import UpdateBatch


class ShardedRTECEngine:
    """Row-sharded engine facade.  Constructing it directly is a
    **deprecated alias** of ``create_engine("sharded", EngineConfig(...))``
    (:mod:`repro.serve.api`), which is the one documented entry point."""

    def __init__(
        self,
        model: GNNModel,
        params: Sequence[Params],
        graph: CSRGraph,
        x,
        mesh=None,
        num_shards: Optional[int] = None,
        shcfg: Optional[ShardingConfig] = None,
        refresh_every: int = 0,
        use_pallas_delta: bool = False,
        policy=None,
    ):
        # deferred import: repro.serve.api imports this module at load time
        from repro.dist.sharding import CommsConfig
        from repro.serve.api import EngineConfig, _alias_deprecated, create_engine

        _alias_deprecated("ShardedRTECEngine")
        # fold the loose kwarg into the typed comms config directly: the
        # alias warning above already covers the deprecation, so the
        # config path itself must stay silent
        eng = create_engine("sharded", EngineConfig(
            model=model, graph=graph, x=x, params=params, mesh=mesh,
            num_shards=num_shards, shcfg=shcfg, refresh_every=refresh_every,
            comms=CommsConfig(use_pallas_delta=use_pallas_delta),
            policy=policy))
        self._backend, self._orch = eng._backend, eng._orch

    # ------------------------------------------------------------------ #
    def apply_batch(self, batch: UpdateBatch, block: bool = True) -> BatchStats:
        return self._orch.apply_batch(batch, block=block)

    def apply_stream(self, batches: Sequence[UpdateBatch]) -> StreamStats:
        return self._orch.apply_stream(batches)

    def refresh(self) -> None:
        """Full recomputation (drift reset) over the current snapshot and the
        *current* features — layer-0 feature updates applied during the
        stream live in the h[0] blocks, not in the construction-time x."""
        self._orch.refresh()

    # ------------------------------------------------------------------ #
    # Serving API (ISSUE 6): versioned snapshot reads — see the contract
    # on repro.core.backend.StateBackend / repro.serve.frontend
    # ------------------------------------------------------------------ #
    def snapshot_rows(self, rows) -> np.ndarray:
        """Host gather of final-layer embedding rows (one device gather
        over the stacked row blocks; consistent after a blocking
        ``apply_batch``)."""
        return self._backend.snapshot_rows(rows)

    def serving_frontend(self, max_pending_reads: int = 64,
                         max_versions: int = 8):
        """A :class:`~repro.serve.frontend.ServingFrontend` over this
        engine: update-batch writes + embedding reads pinned to versions."""
        from repro.serve.frontend import ServingFrontend

        return ServingFrontend(self, max_pending_reads=max_pending_reads,
                               max_versions=max_versions)

    # ------------------------------------------------------------------ #
    @property
    def model(self) -> GNNModel:
        return self._backend.model

    @property
    def params(self):
        return self._backend.params

    @property
    def L(self) -> int:
        return self._backend.L

    @property
    def graph(self) -> CSRGraph:
        return self._orch.graph

    @graph.setter
    def graph(self, g: CSRGraph) -> None:
        self._orch.graph = g

    @property
    def mesh(self):
        return self._backend.mesh

    @property
    def axis(self) -> str:
        return self._backend.axis

    @property
    def S(self) -> int:
        return self._backend.S

    @property
    def rows_per(self) -> int:
        return self._backend.rows_per

    @property
    def halo_rows_total(self) -> int:
        return self._backend.halo_rows_total

    @property
    def _hwm(self):
        return self._backend.hwm

    # ------------------------------------------------------------------ #
    @property
    def embeddings(self) -> np.ndarray:
        return self._backend.embeddings

    @property
    def h(self) -> List[np.ndarray]:
        return self._backend.h

    @property
    def a(self) -> List[np.ndarray]:
        return self._backend.a

    @property
    def nct(self) -> List[np.ndarray]:
        return self._backend.nct

    def state_bytes(self) -> int:
        return self._backend.state_bytes()

    def _sync_arrays(self):
        return self._backend.sync_arrays()
