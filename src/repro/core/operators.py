"""Fine-grained operator decoupling for incremental RTEC (paper §IV-A).

A GNN layer is decomposed into (Eq. 5–9):

    mlc_uv = ms_local(h_u, h_v, s_u, s_v, w_uv, t_uv)        # edge-wise
    nct_v  = Σ_{u∈N(v)} ctx_contrib(mlc_uv)                  # nbr_ctx (assoc.)
    a_v    = ms_cbn(nct_v, Σ_{u∈N(v)} mlc_uv ⊙ f_nn(h_u))    # distributive
    h_v    = update(h_v, a_v)                                # vertex-wise

compared with the paper's notation, ``nbr_ctx`` is expressed as a *signed
sum* of per-edge contributions (``ctx_contrib``) — this is exactly the
associative+invertible form required by Theorem 1 conditions (1)–(2), and
covers ``count()`` (contrib = 1), GAT's attention sum (contrib = mlc) and
per-relation counts/sums.  ``ms_cbn`` must be distributive over the sum
(condition 3) and invertible in its second argument (condition 4); both are
numerically certified by :mod:`repro.core.conditions`.

``edge_term`` composes ``mlc ⊙ f_nn(h_u)`` — kept as one hook so models with
structured messages (multi-head, per-relation blocks) control the layout of
the aggregation state ``a``.

Structural inputs: ``s_u``/``s_v`` are per-vertex structural scalars (the
in-degree), needed by GCN-style normalization where the *source* degree
participates in the local message.  Models that read them must set
``src_struct_dependent`` so the planner widens the affected-edge set when
degrees change (paper §III-C: "degree normalization ... changes dynamically").

Models whose ``ms_local`` reads the destination embedding (GAT, A-GNN, G-GCN,
RGAT) must set ``dest_dependent``; the engine then falls back to
full-neighborhood recomputation for destination-affected vertices (paper
§IV-C, "constrained incremental processing").
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


class GNNModel:
    """Base class. Subclasses define the decoupled operators of Table II."""

    name: str = "base"
    dest_dependent: bool = False
    src_struct_dependent: bool = False
    update_uses_h: bool = False
    has_ctx: bool = True  # False → nbr_ctx ≡ 1 (Table II rows with nct = 1)

    # ------------------------------------------------------------------ #
    # shapes
    # ------------------------------------------------------------------ #
    def agg_dim(self, d_in: int, d_out: int) -> int:
        """Dimensionality of the aggregation state a_v for a (d_in→d_out) layer."""
        return d_in

    def ctx_dim(self, d_in: int, d_out: int) -> int:
        """Dimensionality of the neighborhood context nct_v."""
        return 1

    # ------------------------------------------------------------------ #
    # parameters
    # ------------------------------------------------------------------ #
    def init_params(self, key: jax.Array, d_in: int, d_out: int) -> Params:
        raise NotImplementedError

    # ------------------------------------------------------------------ #
    # decoupled operators — all operate on batched edge/vertex arrays
    # ------------------------------------------------------------------ #
    def ms_local(self, p: Params, h_u, h_v, s_u, s_v, ew, et):
        """Edge-wise local message. [E, ...]"""
        raise NotImplementedError

    def ctx_contrib(self, p: Params, mlc, et):
        """Per-edge contribution to nbr_ctx; summed (signed) by the engine.

        Returns [E, C].  Default: count()."""
        e = mlc.shape[0]
        return jnp.ones((e, 1), dtype=jnp.float32)

    def f_nn(self, p: Params, h_u, et):
        """Source-feature transform. [E, ...]"""
        return h_u

    def edge_term(self, p: Params, mlc, z, et):
        """mlc ⊙ f_nn(h_u) → raw per-edge aggregation contribution [E, agg_dim]."""
        raise NotImplementedError

    def ms_cbn(self, p: Params, nct, x):
        """Apply neighborhood context to (aggregated) messages. Distributive."""
        return x

    def ms_cbn_inv(self, p: Params, nct, x):
        """Inverse of ms_cbn in x (condition 4)."""
        return x

    def update(self, p: Params, h_v, a_v):
        """Vertex-wise update producing h_v^l. [V, d_out]"""
        raise NotImplementedError

    # ------------------------------------------------------------------ #
    # convenience
    # ------------------------------------------------------------------ #
    def init_layers(
        self, key: jax.Array, dims: Sequence[int]
    ) -> List[Params]:
        keys = jax.random.split(key, len(dims) - 1)
        return [
            self.init_params(k, dims[i], dims[i + 1]) for i, k in enumerate(keys)
        ]


def glorot(key, shape, scale: float = 1.0):
    fan_in, fan_out = shape[-2], shape[-1]
    s = scale * jnp.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, shape, dtype=jnp.float32) * s
