"""Adaptive execution policy: incremental vs chunked-subset vs full recompute.

The paper's incremental RTEC wins only while the affected subgraph stays
small (InkStream's affected-area blow-up, PAPERS.md): a hub burst or a
delete-heavy batch drives the monotone frontier toward V and the signed
delta-record stream costs more than just recomputing the touched rows — or
the whole graph.  All three execution shapes already exist behind the
:class:`~repro.core.backend.StateBackend` protocol; this module adds the
plan-time *choice*:

* :func:`estimate_plan_cost` — a :class:`PlanCostEstimate` derived from one
  Alg.-4 :class:`~repro.core.affected.BatchPlan` and its degree tables.
  Everything is a deterministic integer count (record counts, per-row
  new-graph in-degrees, staged rows/bytes per mode) — no state values, no
  timings — so decisions are reproducible and CI can gate them exactly.
* :class:`ExecutionPolicy` — per batch, scores the three modes with the
  estimate and a small per-mode weight model, and returns a
  :class:`PolicyDecision`.  ``force_mode`` pins the decision (a single mode
  for a whole stream, or a per-batch schedule), which is how the bitwise
  policy≡forced equivalence tests and the best-fixed-mode CI baselines are
  built.

The cost model (edge-work units, value-independent):

* ``incremental`` — the signed delta records plus the constrained-branch
  full edges the plan would execute, plus one unit per written row.  The
  smallest raw count by construction (only changed contributions are
  touched), but the most expensive *per edge*: every record is a
  random-access gather + scatter-add (``incremental_weight``).
* ``chunked`` — Σ over layers of the *new-graph in-degree* of the planned
  out rows: constrained recompute of each affected row re-aggregates its
  whole in-neighborhood through the §V-C chunked scheduler, in dense
  gathered segments (``chunked_weight``, between the two).
* ``full`` — ``L·|E(g_new)|`` plus one unit per row: a dense
  :func:`~repro.core.full.full_forward` over the post-batch graph.  Always
  an upper bound on chunked in raw edges, but the cheapest per edge
  (``full_weight``) — at frontier saturation the policy flips to it.

:class:`~repro.core.backend.StreamOrchestrator` consults the policy between
graph apply and backend planning, records the decision in
``BatchStats.mode``/``est_edges`` (aggregated into ``StreamStats``), and
executes chunked/full batches through three substrate-generic backend
primitives (``apply_feature_updates`` / ``layer_input_host`` /
``scatter_layer_rows``) so every backend supports every mode.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.affected import BatchPlan

#: execution modes, in tie-break preference order (cheapest-to-switch first)
MODES = ("incremental", "chunked", "full")

#: Default per-edge weights — the relative cost of one unit of edge-work in
#: each execution shape.  A signed delta record is a random-access gather +
#: scatter-add plus index bookkeeping (the most expensive per-edge shape);
#: the §V-C chunked scheduler re-gathers each affected row's whole
#: in-neighborhood through compact remap tables and pays per-chunk staging
#: but aggregates in dense segments; full_forward is one dense segment-sum
#: over CSR (the cheapest per edge).  The 2 : 1.5 : 1 ratio puts the
#: incremental→chunked flip where changed contributions cover ~3/4 of the
#: affected rows' in-edges, and the chunked→full flip where the affected
#: subgraph covers ~2/3 of all in-edges.
DEFAULT_INCREMENTAL_WEIGHT = 2.0
DEFAULT_CHUNKED_WEIGHT = 1.5
DEFAULT_FULL_WEIGHT = 1.0


@dataclasses.dataclass(frozen=True)
class PlanCostEstimate:
    """Deterministic per-mode cost counts for one batch plan.

    All fields derive from the Alg.-4 plan and its degree tables at plan
    time (value-independent, host-only): the estimate can be computed —
    and the mode decided — while the previous batch still executes."""

    inc_edges: int  #: signed records + constrained-branch edges (incremental)
    chunked_edges: int  #: Σ_l new-graph in-degree of the live out rows
    full_edges: int  #: L · |E(g_new)|
    affected_rows: int  #: Σ_l live out rows (rows written by inc/chunked)
    frontier_rows: int  #: final-layer live out rows (serving write set)
    n: int  #: vertices
    L: int  #: layers
    row_bytes: int  #: bytes per staged state row (h + a + nct, float32)

    def edges(self, mode: str) -> int:
        """Edge-work the mode would execute (raw counts, unweighted)."""
        return {"incremental": self.inc_edges, "chunked": self.chunked_edges,
                "full": self.full_edges}[mode]

    def staged_rows(self, mode: str) -> int:
        """State rows the mode moves between tiers (host↔device staging for
        the offload substrates; scatter volume for the resident ones)."""
        if mode == "incremental":
            # per layer: gather need_h (~affected + sources) + scatter out
            return 2 * self.affected_rows + min(self.inc_edges,
                                                self.n * self.L)
        if mode == "chunked":
            # each affected row plus its gathered in-neighborhood
            return self.affected_rows + self.chunked_edges
        return self.n * (self.L + 1)  # full: every layer state rewritten

    def staged_bytes(self, mode: str) -> int:
        return self.staged_rows(mode) * self.row_bytes

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


def estimate_plan_cost(plan: BatchPlan, row_bytes: int = 0) -> PlanCostEstimate:
    """Build a :class:`PlanCostEstimate` from one Alg.-4 plan.

    ``chunked_edges`` sums the **new-graph** in-degree over each layer's
    live out rows — the §V-C scheduler recomputes exactly these rows from
    their full post-batch in-neighborhoods.  ``full_edges`` is the dense
    L-layer pass over the same degree table."""
    inc = plan.total_inc_edges() + plan.total_full_edges()
    deg_new = plan.deg_new[:-1]  # [n] (drop the scratch slot)
    n = int(deg_new.shape[0])
    L = len(plan.layers)
    chunked = 0
    affected = 0
    frontier = 0
    for lp in plan.layers:
        rows = np.unique(lp.out_rows[lp.out_mask].astype(np.int64))
        affected += int(rows.shape[0])
        frontier = int(rows.shape[0])
        if rows.size:
            chunked += int(deg_new[rows].sum())
    full = L * int(deg_new.sum())
    return PlanCostEstimate(
        inc_edges=int(inc), chunked_edges=chunked, full_edges=full,
        affected_rows=affected, frontier_rows=frontier, n=n, L=L,
        row_bytes=int(row_bytes),
    )


@dataclasses.dataclass(frozen=True)
class PolicyDecision:
    """One batch's mode choice plus the evidence it was made on."""

    mode: str
    estimate: PlanCostEstimate
    costs: Dict[str, float]  #: weighted edge-work per mode
    forced: bool = False  #: True when ``force_mode`` pinned the choice

    @property
    def est_edges(self) -> int:
        """Raw edge-work of the chosen mode (``StreamStats`` accounting)."""
        return self.estimate.edges(self.mode)


class ExecutionPolicy:
    """Plan-time per-batch mode selection over the three execution shapes.

    Cost of each mode is its raw edge-work plus one unit per written row,
    scaled by a per-mode weight; the argmin wins, ties resolved in
    :data:`MODES` order (incremental preferred — it is the only mode that
    keeps the serving undo log and the plan/execute overlap intact).

    ``force_mode`` pins decisions instead of scoring: a mode name applies
    to every batch (the fixed-mode CI baselines), a sequence is consumed
    one entry per batch (the bitwise policy≡forced equivalence tests replay
    an adaptive run's recorded decisions through it).  Estimates are still
    computed and recorded, so forced runs report the same ``est_edges``
    accounting as adaptive ones.

    ``hysteresis`` (ISSUE 8) adds a relative switching band: after the
    first batch, the policy stays on the previously chosen mode unless the
    cheapest mode is at least ``hysteresis`` cheaper *relative to the
    previous mode's current cost* — i.e. it switches only when
    ``costs[best] < (1 - hysteresis) * costs[prev]``.  With the default
    ``0.0`` the argmin is taken every batch (pre-ISSUE-8 behavior, and the
    behavior the exact adversarial CI gates pin); a band of 0.1–0.3 damps
    mode flapping on regimes that oscillate around a cost crossover while
    still following genuine regime shifts.  Forced decisions bypass the
    band entirely and do not update its notion of "previous mode".

    ``calibrate`` (ISSUE 9) turns on online cost-weight calibration: the
    orchestrator feeds each executed batch's measured wall back through
    :meth:`observe`, which maintains a per-mode cost-per-work-unit EMA
    (``calibrate_alpha``); :meth:`effective_weights` then blends the
    static weights with the measured ratios (``calibrate_blend``,
    rescaled so the blend perturbs *ratios*, not magnitudes) and
    :meth:`costs` scores with the blend.  With the default ``False`` the
    static 2.0/1.5/1.0 model is used untouched — bit-for-bit the
    deterministic decision surface the CI gates pin; calibration is the
    opt-in hardware-adaptive variant (wall times are nondeterministic, so
    a calibrated run's decisions are not CI-gateable by construction).
    """

    def __init__(
        self,
        incremental_weight: float = DEFAULT_INCREMENTAL_WEIGHT,
        chunked_weight: float = DEFAULT_CHUNKED_WEIGHT,
        full_weight: float = DEFAULT_FULL_WEIGHT,
        force_mode: Union[None, str, Sequence[str]] = None,
        hysteresis: float = 0.0,
        calibrate: bool = False,
        calibrate_alpha: float = 0.25,
        calibrate_blend: float = 0.5,
    ):
        self.weights = {"incremental": float(incremental_weight),
                        "chunked": float(chunked_weight),
                        "full": float(full_weight)}
        if isinstance(force_mode, str):
            _check_mode(force_mode)
        elif force_mode is not None:
            force_mode = tuple(force_mode)
            for m in force_mode:
                _check_mode(m)
        if not 0.0 <= float(hysteresis) < 1.0:
            raise ValueError(
                f"hysteresis must be in [0, 1), got {hysteresis!r}")
        if not 0.0 <= float(calibrate_blend) <= 1.0:
            raise ValueError(
                f"calibrate_blend must be in [0, 1], got {calibrate_blend!r}")
        if not 0.0 < float(calibrate_alpha) <= 1.0:
            raise ValueError(
                f"calibrate_alpha must be in (0, 1], got {calibrate_alpha!r}")
        self.force_mode = force_mode
        self.hysteresis = float(hysteresis)
        self.calibrate = bool(calibrate)
        self.calibrate_alpha = float(calibrate_alpha)
        self.calibrate_blend = float(calibrate_blend)
        #: per-mode measured cost-per-work-unit EMA (None until observed)
        self._ema: Dict[str, Optional[float]] = {m: None for m in MODES}
        self._prev_mode: Optional[str] = None
        self.decisions: Dict[str, int] = {m: 0 for m in MODES}
        self.history: List[PolicyDecision] = []

    # ------------------------------------------------------------------ #
    @staticmethod
    def _units(est: PlanCostEstimate, mode: str) -> int:
        """Work units of one mode: raw edge-work + one per written row
        (the unweighted quantity the weights multiply)."""
        per_row = {"incremental": est.affected_rows,
                   "chunked": est.affected_rows,
                   "full": est.n}
        return est.edges(mode) + per_row[mode]

    def effective_weights(self) -> Dict[str, float]:
        """The decision weights actually in force.

        Static (``self.weights``, returned as-is — same dict object, so
        the uncalibrated path is bit-identical to pre-ISSUE-9) unless
        ``calibrate=True`` and at least one mode has been measured.  The
        measured EMAs are rescaled so their mean matches the static
        weights' mean over the measured modes — the blend moves the
        *ratios* toward hardware truth without inflating the absolute
        scale — and unmeasured modes keep their static weight."""
        if not self.calibrate:
            return self.weights
        measured = {m: v for m, v in self._ema.items() if v is not None}
        if not measured or sum(measured.values()) <= 0.0:
            return self.weights
        scale = (sum(self.weights[m] for m in measured)
                 / sum(measured.values()))
        b = self.calibrate_blend
        return {m: ((1.0 - b) * self.weights[m]
                    + b * measured[m] * scale) if m in measured
                else self.weights[m]
                for m in MODES}

    def observe(self, decision: PolicyDecision, wall_s: float) -> None:
        """Feed one executed batch's measured wall back into the per-mode
        cost-per-unit EMA (ISSUE 9).  A strict no-op unless
        ``calibrate=True`` — the static decision surface never moves."""
        if not self.calibrate or wall_s <= 0.0:
            return
        units = self._units(decision.estimate, decision.mode)
        if units <= 0:
            return
        cpu_ = wall_s / units
        prev = self._ema[decision.mode]
        a = self.calibrate_alpha
        self._ema[decision.mode] = (cpu_ if prev is None
                                    else (1.0 - a) * prev + a * cpu_)

    def costs(self, est: PlanCostEstimate) -> Dict[str, float]:
        """Weighted edge-work per mode (the decision surface)."""
        w = self.effective_weights()
        return {m: w[m] * self._units(est, m) for m in MODES}

    def decide(self, plan: BatchPlan) -> PolicyDecision:
        """Score one batch plan and record the decision."""
        est = estimate_plan_cost(plan)
        costs = self.costs(est)
        forced = self.force_mode is not None
        if isinstance(self.force_mode, str):
            mode = self.force_mode
        elif forced:
            i = len(self.history)
            if i >= len(self.force_mode):
                raise ValueError(
                    f"force_mode schedule exhausted after {i} batches")
            mode = self.force_mode[i]
        else:
            mode = min(MODES, key=lambda m: (costs[m], MODES.index(m)))
            # the band only engages when configured: hysteresis=0.0 must
            # reproduce the plain argmin bit-for-bit (exact-tie tie-breaks
            # included) — the adversarial CI gates pin those decisions
            if self.hysteresis > 0.0:
                prev = self._prev_mode
                if (prev is not None and mode != prev
                        and not costs[mode]
                        < (1.0 - self.hysteresis) * costs[prev]):
                    mode = prev  # inside the band: hold the previous mode
            self._prev_mode = mode
        decision = PolicyDecision(mode=mode, estimate=est, costs=costs,
                                  forced=forced)
        self.decisions[mode] += 1
        self.history.append(decision)
        return decision

    def decide_window(self, plan: BatchPlan) -> PolicyDecision:
        """Score a fused window's merged plan as ONE unit (ISSUE 9).

        Same surface as :meth:`decide` — the merged plan's counters *are*
        the window's total work, so one scoring prices the whole window —
        but bookkeeping differs: the decision is recorded (``decisions`` /
        ``history`` / the hysteresis band's previous mode) only when the
        window is **accepted** (``mode == "incremental"``, the only mode a
        fused dispatch exists for).  A declined window falls back to the
        serial loop, where ``decide`` re-scores each constituent batch
        individually — recording the declined window too would double-count
        it.  Per-batch ``force_mode`` schedules are indexed by logical
        batch and cannot price a window; the orchestrator disables fusion
        for them before ever calling this."""
        est = estimate_plan_cost(plan)
        costs = self.costs(est)
        forced = self.force_mode is not None
        if isinstance(self.force_mode, str):
            mode = self.force_mode
        elif forced:
            raise ValueError(
                "per-batch force_mode schedules cannot score a fused "
                "window; disable fusion for scheduled runs")
        else:
            mode = min(MODES, key=lambda m: (costs[m], MODES.index(m)))
            if self.hysteresis > 0.0:
                prev = self._prev_mode
                if (prev is not None and mode != prev
                        and not costs[mode]
                        < (1.0 - self.hysteresis) * costs[prev]):
                    mode = prev
        decision = PolicyDecision(mode=mode, estimate=est, costs=costs,
                                  forced=forced)
        if mode == "incremental":
            if not forced:
                self._prev_mode = mode
            self.decisions[mode] += 1
            self.history.append(decision)
        return decision


def _check_mode(mode: str) -> None:
    if mode not in MODES:
        raise ValueError(f"unknown execution mode {mode!r}; "
                         f"expected one of {MODES}")


def make_policy(spec: Union[None, str, ExecutionPolicy],
                chunked_weight: float = DEFAULT_CHUNKED_WEIGHT,
                hysteresis: float = 0.0,
                calibrate: bool = False,
                ) -> Optional[ExecutionPolicy]:
    """Resolve an :class:`~repro.serve.api.EngineConfig` policy knob.

    ``None`` → no policy (the pre-policy incremental-only orchestrator
    path, byte-identical behavior); ``"adaptive"`` → cost-model scoring
    with the given switching ``hysteresis`` band and optional online
    weight calibration (``calibrate``, ISSUE 9); a mode name → that mode forced on
    every batch; an :class:`ExecutionPolicy` instance passes through
    unchanged (``chunked_weight``/``hysteresis``/``calibrate`` ignored)."""
    if spec is None or isinstance(spec, ExecutionPolicy):
        return spec
    if spec == "adaptive":
        return ExecutionPolicy(chunked_weight=chunked_weight,
                               hysteresis=hysteresis, calibrate=calibrate)
    _check_mode(spec)
    return ExecutionPolicy(chunked_weight=chunked_weight, force_mode=spec)
