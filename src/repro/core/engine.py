"""Pipelined streaming incremental-RTEC engine (host/device co-processing).

Thin facade over the residency-backend architecture
(:mod:`repro.core.backend`): a :class:`~repro.core.backend.StreamOrchestrator`
owns the plan/pack/overlap loop (batch-t+1 host planning overlapped with
batch-t device execution, honest :class:`StreamStats` timing, refresh
cadence) and a :class:`~repro.core.backend.DeviceBackend` owns the state —
scratch-extended ``[N+1, ·]`` device arrays updated by one fused, donated
L-layer step per batch (:func:`repro.core.incremental.fused_stream_step`):

* **Packed plans** — all per-layer index/mask/weight arrays ship as three
  contiguous buffers in a single ``jax.device_put`` per batch instead of
  ~24×L small transfers (paper §V co-processing).
* **Donated state** — ``(h, a, nct)`` thread through all layers inside one
  jit with ``donate_argnums``, so on TPU the cached state updates in place:
  O(affected) HBM traffic, no O(V) copy in/out per layer.
* **Plan/execute overlap** — :meth:`apply_stream` dispatches batch t and
  then runs host planning of batch t+1 (numpy) while the device executes;
  the only sync point is the end of the stream.  :meth:`apply_batch` keeps
  the per-batch API and, by default, blocks at the timed boundary so
  ``BatchStats.exec_time_s`` measures completion, not dispatch.  The
  returned :class:`StreamStats` carries the overlap accounting (ISSUE 5):
  ``prefetch_hits`` counts plans built behind execution (structurally
  ``batches - 1``); the host-staging fields (``staged_bytes``,
  ``sync_wait_s`` vs ``compute_s``) stay zero here — the device backend
  has no host staging pipeline; see :mod:`repro.serve.offload` for the
  substrates that populate them.

Also implements the paper's recomputation-based storage optimization
(§V-B): with ``store_h=False`` the engine caches only ``a``/``nct`` and
recomputes ``h^l = update(h^{l-1}, a^l)`` on the fly, trading ~1% compute
for ~33% state memory.  ``fused=False`` preserves the seed per-layer
execution path as the unfused reference for equivalence tests.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.backend import (  # noqa: F401  (BatchStats/StreamStats re-export)
    BatchStats,
    StreamStats,
)
from repro.core.operators import GNNModel, Params
from repro.graph.csr import CSRGraph
from repro.graph.streaming import UpdateBatch


class RTECEngine:
    """Device-resident engine facade.  Constructing it directly is a
    **deprecated alias** of ``create_engine("device", EngineConfig(...))``
    (:mod:`repro.serve.api`), which is the one documented entry point."""

    def __init__(
        self,
        model: GNNModel,
        params: Sequence[Params],
        graph: CSRGraph,
        x: jax.Array,
        store_h: bool = True,
        refresh_every: int = 0,
        fused: bool = True,
        use_pallas_delta: bool = False,
        policy=None,
    ):
        # deferred import: repro.serve.api imports this module at load time
        from repro.dist.sharding import CommsConfig
        from repro.serve.api import EngineConfig, _alias_deprecated, create_engine

        _alias_deprecated("RTECEngine")
        # fold the loose kwarg into the typed comms config directly: the
        # alias warning above already covers the deprecation, so the
        # config path itself must stay silent
        eng = create_engine("device", EngineConfig(
            model=model, graph=graph, x=jnp.asarray(x), params=params,
            store_h=store_h, refresh_every=refresh_every, fused=fused,
            comms=CommsConfig(use_pallas_delta=use_pallas_delta),
            policy=policy))
        self._backend, self._orch = eng._backend, eng._orch

    # ------------------------------------------------------------------ #
    # public API: delegates to orchestrator (control) + backend (state)
    # ------------------------------------------------------------------ #
    def apply_batch(self, batch: UpdateBatch, block: bool = True) -> BatchStats:
        return self._orch.apply_batch(batch, block=block)

    def apply_stream(self, batches: Sequence[UpdateBatch]) -> StreamStats:
        return self._orch.apply_stream(batches)

    def refresh(self) -> None:
        """Full recomputation (drift reset / MTEC-style refresh)."""
        self._orch.refresh()

    # ------------------------------------------------------------------ #
    # Serving API (ISSUE 6): versioned snapshot reads — see the contract
    # on repro.core.backend.StateBackend / repro.serve.frontend
    # ------------------------------------------------------------------ #
    def snapshot_rows(self, rows) -> "np.ndarray":  # noqa: F821
        """Host gather of final-layer embedding rows (consistent after a
        blocking ``apply_batch``)."""
        return self._backend.snapshot_rows(rows)

    def serving_frontend(self, max_pending_reads: int = 64,
                         max_versions: int = 8):
        """A :class:`~repro.serve.frontend.ServingFrontend` over this
        engine: update-batch writes + embedding reads pinned to versions."""
        from repro.serve.frontend import ServingFrontend

        return ServingFrontend(self, max_pending_reads=max_pending_reads,
                               max_versions=max_versions)

    # ------------------------------------------------------------------ #
    @property
    def model(self) -> GNNModel:
        return self._backend.model

    @property
    def params(self) -> List[Params]:
        return self._backend.params

    @property
    def L(self) -> int:
        return self._backend.L

    @property
    def graph(self) -> CSRGraph:
        return self._orch.graph

    @graph.setter
    def graph(self, g: CSRGraph) -> None:
        self._orch.graph = g

    @property
    def refresh_every(self) -> int:
        return self._orch.refresh_every

    @property
    def store_h(self) -> bool:
        return self._backend.store_h

    @property
    def fused(self) -> bool:
        return self._backend.fused

    @property
    def use_pallas_delta(self) -> bool:
        return self._backend.use_pallas_delta

    @property
    def _hwm(self):
        return self._backend.hwm

    # ------------------------------------------------------------------ #
    # state views (seed-compatible: no scratch rows)
    # ------------------------------------------------------------------ #
    @property
    def x(self) -> jax.Array:
        return self._backend.x

    @property
    def h(self) -> List[Optional[jax.Array]]:
        return self._backend.h

    @h.setter
    def h(self, vals: Sequence[Optional[jax.Array]]) -> None:
        self._backend.h = vals

    @property
    def a(self) -> List[jax.Array]:
        return self._backend.a

    @a.setter
    def a(self, vals: Sequence[jax.Array]) -> None:
        self._backend.a = vals

    @property
    def nct(self) -> List[jax.Array]:
        return self._backend.nct

    @nct.setter
    def nct(self, vals: Sequence[jax.Array]) -> None:
        self._backend.nct = vals

    @property
    def embeddings(self) -> jax.Array:
        return self._backend.embeddings

    def _reconstruct_h(self) -> List[jax.Array]:
        return self._backend.reconstruct_h()

    def state_bytes(self) -> int:
        return self._backend.state_bytes()

    def staging_stats(self):
        """Host-staging counters — None for the device backend (state is
        HBM-resident; there is no host staging pipeline to account)."""
        return self._backend.staging_snapshot()

    def _sync_arrays(self):
        return self._backend.sync_arrays()
