"""Streaming incremental-RTEC engine (single host/device orchestration).

Holds the evolving graph snapshot and the per-layer historical results
(h, a, nct), plans each update batch on the host (Alg. 4) and executes the
reordered incremental workflow (Alg. 1) on device.  Functional double
buffering: the previous batch's state stays alive while the new one is
built, which is exactly the `h_old` the delta computation needs.

Also implements the paper's recomputation-based storage optimization
(§V-B): with ``store_h=False`` the engine caches only ``a``/``nct`` and
recomputes ``h^l = update(h^{l-1}, a^l)`` on the fly, trading ~1% compute
for ~33% state memory.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.affected import BatchPlan, build_plan
from repro.core.full import full_forward
from repro.core.incremental import incremental_layer, with_scratch
from repro.core.operators import GNNModel, Params
from repro.graph.csr import CSRGraph
from repro.graph.streaming import UpdateBatch


@dataclasses.dataclass
class BatchStats:
    inc_edges: int
    full_edges: int
    out_vertices: int
    plan_time_s: float
    exec_time_s: float
    graph_time_s: float

    @property
    def edges_processed(self) -> int:
        return self.inc_edges + self.full_edges


class RTECEngine:
    def __init__(
        self,
        model: GNNModel,
        params: Sequence[Params],
        graph: CSRGraph,
        x: jax.Array,
        store_h: bool = True,
        refresh_every: int = 0,
    ):
        self.model = model
        self.params = list(params)
        self.L = len(self.params)
        self.graph = graph
        self.store_h = store_h
        self.refresh_every = refresh_every
        self._batches_seen = 0
        self.x = jnp.asarray(x)
        self._upd = jax.jit(model.update)
        self._init_state()

    # ------------------------------------------------------------------ #
    def _init_state(self) -> None:
        states = full_forward(self.model, self.params, self.x, self.graph)
        self.h: List[Optional[jax.Array]] = [self.x] + [s.h for s in states]
        self.a: List[jax.Array] = [s.a for s in states]
        self.nct: List[jax.Array] = [s.nct for s in states]
        if not self.store_h:
            self._drop_h()

    def refresh(self) -> None:
        """Full recomputation (drift reset / MTEC-style refresh)."""
        self._init_state()

    def _drop_h(self) -> None:
        self.h = [self.h[0]] + [None] * self.L

    def _reconstruct_h(self) -> List[jax.Array]:
        """Recomputation-based storage optimization (paper §V-B): rebuild
        h^l = update(h^{l-1}, a^l) from the cached aggregation states."""
        h = [self.h[0]]
        for l in range(self.L):
            h.append(self._upd(self.params[l], h[l], self.a[l]))
        return h

    @property
    def embeddings(self) -> jax.Array:
        if self.h[-1] is None:
            return self._reconstruct_h()[-1]
        return self.h[-1]

    def state_bytes(self) -> int:
        total = sum(int(np.prod(a.shape)) * a.dtype.itemsize for a in self.a)
        total += sum(int(np.prod(c.shape)) * c.dtype.itemsize for c in self.nct)
        if self.store_h:
            total += sum(int(np.prod(h.shape)) * h.dtype.itemsize for h in self.h[1:])
        total += int(np.prod(self.x.shape)) * self.x.dtype.itemsize
        return total

    # ------------------------------------------------------------------ #
    def apply_batch(self, batch: UpdateBatch) -> BatchStats:
        t0 = time.perf_counter()
        g_new = self.graph.apply_updates(
            batch.ins_src, batch.ins_dst, batch.del_src, batch.del_dst,
            batch.ins_weights, batch.ins_etypes,
        )
        t1 = time.perf_counter()
        plan = build_plan(self.model, self.graph, g_new, batch, self.L)
        t2 = time.perf_counter()
        self._execute(plan, batch)
        t3 = time.perf_counter()
        self.graph = g_new
        self._batches_seen += 1
        if self.refresh_every and self._batches_seen % self.refresh_every == 0:
            self.refresh()
        return BatchStats(
            inc_edges=plan.total_inc_edges(),
            full_edges=plan.total_full_edges(),
            out_vertices=plan.total_vertices(),
            plan_time_s=t2 - t1,
            exec_time_s=t3 - t2,
            graph_time_s=t1 - t0,
        )

    # ------------------------------------------------------------------ #
    def _execute(self, plan: BatchPlan, batch: UpdateBatch) -> None:
        deg_old = jnp.asarray(plan.deg_old)
        deg_new = jnp.asarray(plan.deg_new)

        if not self.store_h:
            self.h = self._reconstruct_h()

        # layer-0 feature updates
        h0_old = self.h[0]
        if batch.feat_vertices is not None and batch.feat_vertices.size:
            h0_new = h0_old.at[jnp.asarray(batch.feat_vertices)].set(
                jnp.asarray(batch.feat_values, h0_old.dtype)
            )
        else:
            h0_new = h0_old

        h_old = [h0_old] + list(self.h[1:])
        h_new: List[jax.Array] = [h0_new]
        a_new: List[jax.Array] = []
        nct_new: List[jax.Array] = []

        for l, lp in enumerate(plan.layers):
            an, nn, hn = incremental_layer(
                self.model,
                self.params[l],
                with_scratch(h_old[l]),
                with_scratch(h_new[l]),
                deg_old,
                deg_new,
                self.a[l],
                self.nct[l],
                h_old[l + 1],
                jnp.asarray(lp.e_src),
                jnp.asarray(lp.e_dst),
                jnp.asarray(lp.e_rowidx),
                jnp.asarray(lp.e_sign),
                jnp.asarray(lp.e_use_new),
                jnp.asarray(lp.e_w),
                jnp.asarray(lp.e_t),
                jnp.asarray(lp.e_mask),
                jnp.asarray(lp.touch_rows),
                jnp.asarray(lp.touch_mask),
                jnp.asarray(lp.f_rows),
                jnp.asarray(lp.f_mask),
                jnp.asarray(lp.f_src),
                jnp.asarray(lp.f_rowidx),
                jnp.asarray(lp.f_w),
                jnp.asarray(lp.f_t),
                jnp.asarray(lp.f_emask),
                jnp.asarray(lp.out_rows),
                jnp.asarray(lp.out_mask),
            )
            a_new.append(an)
            nct_new.append(nn)
            h_new.append(hn)

        self.h = h_new
        self.a = a_new
        self.nct = nct_new
        self.x = h_new[0]
        if not self.store_h:
            self._drop_h()
