"""Pipelined streaming incremental-RTEC engine (host/device co-processing).

Holds the evolving graph snapshot and the per-layer historical results
(h, a, nct) as scratch-extended device arrays, plans each update batch on
the host (Alg. 4) into a packed transfer format, and executes the reordered
incremental workflow (Alg. 1) on device as **one fused, donated L-layer
step** per batch (:func:`repro.core.incremental.fused_stream_step`):

* **Packed plans** — all per-layer index/mask/weight arrays ship as three
  contiguous buffers in a single ``jax.device_put`` per batch instead of
  ~24×L small transfers (paper §V co-processing).
* **Donated state** — ``(h, a, nct)`` thread through all layers inside one
  jit with ``donate_argnums``, so on TPU the cached state updates in place:
  O(affected) HBM traffic, no O(V) copy in/out per layer.
* **Plan/execute overlap** — :meth:`apply_stream` dispatches batch t and
  then runs host planning of batch t+1 (numpy) while the device executes;
  the only sync point is the end of the stream.  :meth:`apply_batch` keeps
  the per-batch API and, by default, blocks at the timed boundary so
  ``BatchStats.exec_time_s`` measures completion, not dispatch.

Also implements the paper's recomputation-based storage optimization
(§V-B): with ``store_h=False`` the engine caches only ``a``/``nct`` and
recomputes ``h^l = update(h^{l-1}, a^l)`` on the fly, trading ~1% compute
for ~33% state memory.  ``fused=False`` preserves the seed per-layer
execution path as the unfused reference for equivalence tests.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.affected import (
    BatchPlan,
    BucketHysteresis,
    PackedPlan,
    build_packed_plan,
    build_plan,
)
from repro.core.full import full_forward
from repro.core.incremental import fused_stream_step, incremental_layer, with_scratch
from repro.core.operators import GNNModel, Params
from repro.graph.csr import CSRGraph
from repro.graph.streaming import UpdateBatch


@dataclasses.dataclass
class BatchStats:
    inc_edges: int
    full_edges: int
    out_vertices: int
    plan_time_s: float
    exec_time_s: float
    graph_time_s: float

    @property
    def edges_processed(self) -> int:
        return self.inc_edges + self.full_edges


@dataclasses.dataclass
class StreamStats:
    """Aggregate result of a pipelined :meth:`RTECEngine.apply_stream` run.

    ``wall_s`` is honest end-to-end time including the final device sync;
    per-batch ``exec_time_s`` entries are dispatch-only (execution overlaps
    the next batch's planning, so per-batch completion is unobservable
    without breaking the pipeline)."""

    batches: List[BatchStats]
    wall_s: float
    plan_s: float  # total host planning time (hidden behind device exec)

    @property
    def mean_batch_s(self) -> float:
        return self.wall_s / max(1, len(self.batches))


class RTECEngine:
    def __init__(
        self,
        model: GNNModel,
        params: Sequence[Params],
        graph: CSRGraph,
        x: jax.Array,
        store_h: bool = True,
        refresh_every: int = 0,
        fused: bool = True,
        use_pallas_delta: bool = False,
    ):
        self.model = model
        self.params = list(params)
        self.L = len(self.params)
        self.graph = graph
        self.store_h = store_h
        self.refresh_every = refresh_every
        self.fused = fused
        self.use_pallas_delta = use_pallas_delta
        # high-water-mark capacity buckets: shrinking batches reuse the
        # previous PackedLayout instead of retracing the fused step
        self._hwm = BucketHysteresis()
        self._batches_seen = 0
        self._upd = jax.jit(model.update)
        self._init_state(jnp.asarray(x))

    # ------------------------------------------------------------------ #
    # state: scratch-extended [N+1, ·] device arrays (index n = scratch)
    # ------------------------------------------------------------------ #
    def _init_state(self, x: Optional[jax.Array] = None) -> None:
        if x is None:
            x = self.x
        states = full_forward(self.model, self.params, x, self.graph)
        self._h: List[Optional[jax.Array]] = [with_scratch(x)] + [
            with_scratch(s.h) for s in states
        ]
        self._a: List[jax.Array] = [with_scratch(s.a) for s in states]
        self._nct: List[jax.Array] = [with_scratch(s.nct) for s in states]
        if not self.store_h:
            self._drop_h()

    def refresh(self) -> None:
        """Full recomputation (drift reset / MTEC-style refresh)."""
        self._init_state()

    def _drop_h(self) -> None:
        self._h = [self._h[0]] + [None] * self.L

    @property
    def x(self) -> jax.Array:
        return self._h[0][:-1]

    @property
    def h(self) -> List[Optional[jax.Array]]:
        """Seed-compatible view: per-layer embeddings without scratch rows."""
        return [None if v is None else v[:-1] for v in self._h]

    @h.setter
    def h(self, vals: Sequence[Optional[jax.Array]]) -> None:
        self._h = [None if v is None else with_scratch(v) for v in vals]

    @property
    def a(self) -> List[jax.Array]:
        return [v[:-1] for v in self._a]

    @a.setter
    def a(self, vals: Sequence[jax.Array]) -> None:
        self._a = [with_scratch(v) for v in vals]

    @property
    def nct(self) -> List[jax.Array]:
        return [v[:-1] for v in self._nct]

    @nct.setter
    def nct(self, vals: Sequence[jax.Array]) -> None:
        self._nct = [with_scratch(v) for v in vals]

    def _reconstruct_h(self) -> List[jax.Array]:
        """Recomputation-based storage optimization (paper §V-B): rebuild
        h^l = update(h^{l-1}, a^l) from the cached aggregation states."""
        h = [self.x]
        for l in range(self.L):
            h.append(self._upd(self.params[l], h[l], self._a[l][:-1]))
        return h

    @property
    def embeddings(self) -> jax.Array:
        if self._h[-1] is None:
            return self._reconstruct_h()[-1]
        return self._h[-1][:-1]

    def state_bytes(self) -> int:
        def nb(arr: jax.Array) -> int:
            return (arr.shape[0] - 1) * int(np.prod(arr.shape[1:] or (1,))) * arr.dtype.itemsize

        total = sum(nb(a) for a in self._a) + sum(nb(c) for c in self._nct)
        if self.store_h:
            total += sum(nb(h) for h in self._h[1:] if h is not None)
        total += nb(self._h[0])
        return total

    def _sync_arrays(self):
        return [v for v in (*self._h, *self._a, *self._nct) if v is not None]

    # ------------------------------------------------------------------ #
    # per-batch API (honest timing: block=True syncs at the boundary)
    # ------------------------------------------------------------------ #
    def apply_batch(self, batch: UpdateBatch, block: bool = True) -> BatchStats:
        t0 = time.perf_counter()
        g_new = self.graph.apply_updates(
            batch.ins_src, batch.ins_dst, batch.del_src, batch.del_dst,
            batch.ins_weights, batch.ins_etypes,
        )
        t1 = time.perf_counter()
        if self.fused:
            packed = build_packed_plan(
                self.model, self.graph, g_new, batch, self.L,
                pallas=self.use_pallas_delta, hwm=self._hwm,
            )
            t2 = time.perf_counter()
            self._dispatch_packed(packed)
            counters = (packed.n_inc_edges, packed.n_full_edges, packed.n_out_rows)
        else:
            plan = build_plan(self.model, self.graph, g_new, batch, self.L)
            t2 = time.perf_counter()
            self._execute_unfused(plan, batch)
            counters = (plan.total_inc_edges(), plan.total_full_edges(), plan.total_vertices())
        if block:
            jax.block_until_ready(self._sync_arrays())
        t3 = time.perf_counter()
        self.graph = g_new
        self._batches_seen += 1
        if self.refresh_every and self._batches_seen % self.refresh_every == 0:
            self.refresh()
        return BatchStats(
            inc_edges=counters[0],
            full_edges=counters[1],
            out_vertices=counters[2],
            plan_time_s=t2 - t1,
            exec_time_s=t3 - t2,
            graph_time_s=t1 - t0,
        )

    # ------------------------------------------------------------------ #
    # pipelined stream API: plan t+1 on host while the device executes t
    # ------------------------------------------------------------------ #
    def apply_stream(self, batches: Sequence[UpdateBatch]) -> StreamStats:
        """Double-buffered batch application (paper §V co-processing).

        Batch t's fused step is dispatched asynchronously; Alg.-4 planning of
        batch t+1 (host numpy) then runs while the device executes.  The only
        device sync is at the end of the stream (and around refreshes)."""
        assert self.fused, "apply_stream requires the fused engine"
        batches = list(batches)
        if not batches:
            return StreamStats([], 0.0, 0.0)
        t_start = time.perf_counter()
        stats: List[BatchStats] = []
        plan_total = 0.0

        tp = time.perf_counter()
        g_new, packed = self._plan_batch(batches[0])
        plan_total += time.perf_counter() - tp

        for i in range(len(batches)):
            td = time.perf_counter()
            self._dispatch_packed(packed)  # async: device starts batch i
            dispatch_s = time.perf_counter() - td
            self.graph = g_new
            self._batches_seen += 1
            stats.append(
                BatchStats(
                    inc_edges=packed.n_inc_edges,
                    full_edges=packed.n_full_edges,
                    out_vertices=packed.n_out_rows,
                    plan_time_s=0.0,
                    exec_time_s=dispatch_s,  # dispatch-only; see StreamStats
                    graph_time_s=0.0,
                )
            )
            if i + 1 < len(batches):
                tp = time.perf_counter()  # overlapped with device execution
                g_new, packed = self._plan_batch(batches[i + 1])
                plan_total += time.perf_counter() - tp
            if self.refresh_every and self._batches_seen % self.refresh_every == 0:
                jax.block_until_ready(self._sync_arrays())
                self.refresh()
        jax.block_until_ready(self._sync_arrays())
        return StreamStats(stats, time.perf_counter() - t_start, plan_total)

    def _plan_batch(self, batch: UpdateBatch):
        g_new = self.graph.apply_updates(
            batch.ins_src, batch.ins_dst, batch.del_src, batch.del_dst,
            batch.ins_weights, batch.ins_etypes,
        )
        packed = build_packed_plan(
            self.model, self.graph, g_new, batch, self.L,
            pallas=self.use_pallas_delta, hwm=self._hwm,
        )
        return g_new, packed

    # ------------------------------------------------------------------ #
    def _dispatch_packed(self, packed: PackedPlan) -> None:
        """One device_put for the whole plan, one fused-step dispatch."""
        if not self.store_h and self._h[1] is None:
            h = self._reconstruct_h()
            self._h = [self._h[0]] + [with_scratch(v) for v in h[1:]]
        idx, flt, msk, feat_vals, pallas = jax.device_put(
            (packed.idx, packed.flt, packed.msk, packed.feat_vals, packed.pallas)
        )
        with warnings.catch_warnings():
            # donation is a TPU/GPU aliasing optimization; CPU jit ignores it
            # with a UserWarning per compile — suppress it here (scoped) so
            # the CPU hot path stays quiet without touching global filters
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable"
            )
            hs, as_, ncts = fused_stream_step(
                self.model, packed.layout, tuple(self.params),
                tuple(self._h), tuple(self._a), tuple(self._nct),
                idx, flt, msk, feat_vals, pallas,
            )
        self._h = list(hs)
        self._a = list(as_)
        self._nct = list(ncts)
        if not self.store_h:
            self._drop_h()

    # ------------------------------------------------------------------ #
    # unfused seed path (per-layer dispatch) — equivalence reference
    # ------------------------------------------------------------------ #
    def _execute_unfused(self, plan: BatchPlan, batch: UpdateBatch) -> None:
        deg_old = jnp.asarray(plan.deg_old)
        deg_new = jnp.asarray(plan.deg_new)

        if not self.store_h:
            self.h = self._reconstruct_h()

        # layer-0 feature updates
        h0_old = self.h[0]
        if batch.feat_vertices is not None and batch.feat_vertices.size:
            h0_new = h0_old.at[jnp.asarray(batch.feat_vertices)].set(
                jnp.asarray(batch.feat_values, h0_old.dtype)
            )
        else:
            h0_new = h0_old

        h_old = [h0_old] + list(self.h[1:])
        h_new: List[jax.Array] = [h0_new]
        a_new: List[jax.Array] = []
        nct_new: List[jax.Array] = []

        for l, lp in enumerate(plan.layers):
            an, nn, hn = incremental_layer(
                self.model,
                self.params[l],
                with_scratch(h_old[l]),
                with_scratch(h_new[l]),
                deg_old,
                deg_new,
                self.a[l],
                self.nct[l],
                h_old[l + 1],
                jnp.asarray(lp.e_src),
                jnp.asarray(lp.e_dst),
                jnp.asarray(lp.e_rowidx),
                jnp.asarray(lp.e_sign),
                jnp.asarray(lp.e_use_new),
                jnp.asarray(lp.e_w),
                jnp.asarray(lp.e_t),
                jnp.asarray(lp.e_mask),
                jnp.asarray(lp.touch_rows),
                jnp.asarray(lp.touch_mask),
                jnp.asarray(lp.f_rows),
                jnp.asarray(lp.f_mask),
                jnp.asarray(lp.f_src),
                jnp.asarray(lp.f_rowidx),
                jnp.asarray(lp.f_w),
                jnp.asarray(lp.f_t),
                jnp.asarray(lp.f_emask),
                jnp.asarray(lp.out_rows),
                jnp.asarray(lp.out_mask),
            )
            a_new.append(an)
            nct_new.append(nn)
            h_new.append(hn)

        self.h = h_new
        self.a = a_new
        self.nct = nct_new
        if not self.store_h:
            self._drop_h()
