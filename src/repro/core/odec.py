"""On-Demand Embedding Computation (ODEC) — paper §V-D.

ODEC serves online queries for a small vertex set Q: the computation graph
is the *intersection* of the affected subgraph with the query-induced
K-hop-backward cone.  ``odec_query`` computes the post-batch embeddings of Q
without committing engine state (the serving deployment pattern: queries are
answered immediately from the restricted cone while the full batch commit
happens asynchronously via ``engine.apply_batch``; see DESIGN.md).

When Q covers all affected vertices, ODEC reduces to plain incremental RTEC
(paper Fig. 12.d "ALL").
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.affected import build_plan
from repro.core.engine import BatchStats, RTECEngine
from repro.core.incremental import incremental_layer, with_scratch
from repro.graph.csr import CSRGraph
from repro.graph.streaming import UpdateBatch


def query_cone(g: CSRGraph, query: np.ndarray, num_layers: int) -> List[set]:
    """Per-layer allowed-vertex sets: layer L = Q, layer l−1 = layer l ∪
    in-neighbors(layer l)."""
    need = set(np.asarray(query, np.int64).tolist())
    cones: List[set] = [None] * num_layers  # type: ignore
    for l in range(num_layers - 1, -1, -1):
        cones[l] = set(need)
        nxt = set(need)
        for v in need:
            nxt |= set(g.in_neighbors(int(v)).tolist())
        need = nxt
    return cones


def odec_query(
    engine: RTECEngine, batch: UpdateBatch, query: np.ndarray
) -> Tuple[jnp.ndarray, BatchStats]:
    """Answer embeddings for ``query`` reflecting ``batch``, via the
    affected-subgraph ∩ query-cone restricted incremental computation."""
    t0 = time.perf_counter()
    g_new = engine.graph.apply_updates(
        batch.ins_src, batch.ins_dst, batch.del_src, batch.del_dst,
        batch.ins_weights, batch.ins_etypes,
    )
    cones = query_cone(g_new, query, engine.L)
    plan = build_plan(engine.model, engine.graph, g_new, batch, engine.L, restrict=cones)
    t1 = time.perf_counter()

    deg_old = jnp.asarray(plan.deg_old)
    deg_new = jnp.asarray(plan.deg_new)
    h = engine.h if engine.store_h else engine._reconstruct_h()
    h0_old = h[0]
    if batch.feat_vertices is not None and batch.feat_vertices.size:
        h0_new = h0_old.at[jnp.asarray(batch.feat_vertices)].set(
            jnp.asarray(batch.feat_values, h0_old.dtype)
        )
    else:
        h0_new = h0_old

    h_new = [h0_new]
    for l, lp in enumerate(plan.layers):
        _, _, hn = incremental_layer(
            engine.model,
            engine.params[l],
            with_scratch(h[l]),
            with_scratch(h_new[l]),
            deg_old,
            deg_new,
            engine.a[l],
            engine.nct[l],
            h[l + 1],
            jnp.asarray(lp.e_src), jnp.asarray(lp.e_dst), jnp.asarray(lp.e_rowidx),
            jnp.asarray(lp.e_sign), jnp.asarray(lp.e_use_new), jnp.asarray(lp.e_w),
            jnp.asarray(lp.e_t), jnp.asarray(lp.e_mask),
            jnp.asarray(lp.touch_rows), jnp.asarray(lp.touch_mask),
            jnp.asarray(lp.f_rows), jnp.asarray(lp.f_mask), jnp.asarray(lp.f_src),
            jnp.asarray(lp.f_rowidx), jnp.asarray(lp.f_w), jnp.asarray(lp.f_t),
            jnp.asarray(lp.f_emask),
            jnp.asarray(lp.out_rows), jnp.asarray(lp.out_mask),
        )
        h_new.append(hn)
    t2 = time.perf_counter()
    stats = BatchStats(
        inc_edges=plan.total_inc_edges(),
        full_edges=plan.total_full_edges(),
        out_vertices=plan.total_vertices(),
        plan_time_s=t1 - t0,
        exec_time_s=t2 - t1,
        graph_time_s=0.0,
    )
    return h_new[-1][jnp.asarray(query)], stats
