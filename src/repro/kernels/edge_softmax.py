"""GAT edge-softmax normalization (Pallas TPU).

Phase 2 of the decoupled softmax (paper Alg. 2 line 5): given raw exp-scores
per edge and the per-destination attention sums (phase 1 = `segment_spmm`),
produce normalized scores.  The per-edge gather of its destination's sum is
realized as the *transpose* one-hot MXU matmul:

    sums_per_edge[BE, H] = onehotᵀ[BE, TV] @ sums_tile[TV, H]

so the irregular gather again becomes systolic-array work, and the division
fuses into the same kernel pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(block_rows_ref, dloc_ref, scores_ref, sums_ref, out_ref):
    dloc = dloc_ref[...].reshape(-1)  # [BE]
    tv = sums_ref.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (dloc.shape[0], tv), 1)
    onehot_t = (cols == dloc[:, None]).astype(jnp.float32)  # [BE, TV]
    sums_tile = sums_ref[...].astype(jnp.float32)  # [TV, H]
    denom = jnp.dot(onehot_t, sums_tile, preferred_element_type=jnp.float32)
    scores = scores_ref[...].astype(jnp.float32)
    live = denom > 1e-10
    out = jnp.where(live, scores / jnp.where(live, denom, 1.0), 0.0)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tv", "be", "bh", "interpret"))
def edge_softmax_normalize(
    scores: jax.Array,  # [E_pad, H_pad] raw exp-scores, block-aligned layout
    dst_local: jax.Array,  # [E_pad] int32 (-1 padding)
    block_rows: jax.Array,  # [NB] int32
    sums: jax.Array,  # [rows_pad, H_pad] per-destination attention sums
    tv: int = 8,
    be: int = 512,
    bh: int = 128,
    interpret: bool = False,
) -> jax.Array:
    e_pad, h = scores.shape
    nb = e_pad // be
    nh = h // bh
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nh, nb),
        in_specs=[
            pl.BlockSpec((be, 1), lambda j, i, br: (i, 0)),
            pl.BlockSpec((be, bh), lambda j, i, br: (i, j)),
            pl.BlockSpec((tv, bh), lambda j, i, br: (br[i], j)),
        ],
        out_specs=pl.BlockSpec((be, bh), lambda j, i, br: (i, j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(scores.shape, scores.dtype),
        interpret=interpret,
        name="edge_softmax_normalize",
    )(block_rows, dst_local[:, None], scores, sums)
