"""Jit'd public wrappers around the Pallas kernels.

Each op (a) prepares the block-aligned layout on the host, (b) dispatches to
the Pallas kernel on TPU (or ``interpret=True`` when forced), and (c) falls
back to the pure-jnp oracle on CPU by default — interpret-mode Pallas is a
correctness tool, not a fast path, so production CPU execution uses XLA.

Set ``repro.kernels.ops.FORCE_PALLAS_INTERPRET = True`` (tests do) to route
through the kernels in interpret mode.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as kref
from repro.kernels.delta_agg import delta_agg as _delta_agg_kernel
from repro.kernels.edge_softmax import edge_softmax_normalize as _esm_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.segment_spmm import prepare_block_csr, segment_spmm as _spmm_kernel

FORCE_PALLAS_INTERPRET = False


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_kernels() -> bool:
    return _on_tpu() or FORCE_PALLAS_INTERPRET


def _interpret() -> bool:
    return not _on_tpu()


def _pad_dim(x: jax.Array, mult: int, axis: int = 1) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _permute_messages(messages: jax.Array, perm: np.ndarray) -> jax.Array:
    """Gather messages into block layout; perm -1 → zero row."""
    safe = jnp.asarray(np.where(perm >= 0, perm, 0), jnp.int32)
    gathered = messages[safe]
    return gathered * jnp.asarray(perm >= 0, messages.dtype)[:, None]


def segment_sum_edges(
    messages: jax.Array,  # [E, D] (dst-sorted, -1-padded tail allowed)
    dst: np.ndarray,  # [E] int (host, sorted; -1 padding)
    num_rows: int,
    tv: int = 8,
    be: int = 512,
    bd: int = 128,
) -> jax.Array:
    """out[v] = Σ_{dst[e]=v} messages[e] — the aggregation hot spot."""
    if not _use_kernels():
        return kref.segment_spmm_ref(messages, jnp.asarray(dst, jnp.int32), num_rows)
    perm, dloc, brows, _ = prepare_block_csr(dst, num_rows, tv, be)
    msg = _permute_messages(messages, perm)
    msg = _pad_dim(msg, bd)
    out = _spmm_kernel(
        msg,
        jnp.asarray(dloc),
        jnp.asarray(brows),
        num_rows,
        tv=tv,
        be=be,
        bd=bd,
        interpret=_interpret(),
    )
    # zero-fill row tiles never visited by an edge block (DESIGN.md §7)
    rows_pad = out.shape[0]
    visited = np.zeros(rows_pad // tv, bool)
    visited[np.unique(brows)] = True
    vmask = jnp.asarray(np.repeat(visited, tv))
    out = jnp.where(vmask[:, None], out, 0.0)
    return out[:num_rows, : messages.shape[1]]


def delta_agg_update(
    state: jax.Array,  # [V, D]
    messages: jax.Array,  # [E, D] signed deltas (dst-sorted)
    dst: np.ndarray,  # [E] int (host, sorted; -1 padding)
    tv: int = 8,
    be: int = 512,
    bd: int = 128,
) -> jax.Array:
    """state[dst[e]] += messages[e], touching only affected row tiles."""
    if not _use_kernels():
        return kref.delta_agg_ref(state, messages, jnp.asarray(dst, jnp.int32))
    num_rows, d = state.shape
    perm, dloc, brows, _ = prepare_block_csr(dst, num_rows, tv, be)
    msg = _permute_messages(messages, perm)
    msg = _pad_dim(msg, bd)
    state_p = _pad_dim(_pad_dim(state, bd, axis=1), tv, axis=0)
    out = _delta_agg_kernel(
        msg,
        jnp.asarray(dloc),
        jnp.asarray(brows),
        state_p,
        tv=tv,
        be=be,
        bd=bd,
        interpret=_interpret(),
    )
    return out[:num_rows, :d]


def edge_softmax(
    scores: jax.Array,  # [E, H] raw exp-scores (dst-sorted)
    dst: np.ndarray,  # [E] int (host, sorted; -1 padding)
    num_rows: int,
    tv: int = 8,
    be: int = 512,
    bh: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (normalized scores [E, H], attention sums [num_rows, H])."""
    if not _use_kernels():
        return kref.edge_softmax_ref(scores, jnp.asarray(dst, jnp.int32), num_rows)
    h = scores.shape[1]
    perm, dloc, brows, _ = prepare_block_csr(dst, num_rows, tv, be)
    sc = _permute_messages(scores, perm)
    sc = _pad_dim(sc, bh)
    sums_p = _spmm_kernel(
        sc, jnp.asarray(dloc), jnp.asarray(brows), num_rows,
        tv=tv, be=be, bd=bh, interpret=_interpret(),
    )
    rows_pad = sums_p.shape[0]
    visited = np.zeros(rows_pad // tv, bool)
    visited[np.unique(brows)] = True
    vmask = jnp.asarray(np.repeat(visited, tv))
    sums_p = jnp.where(vmask[:, None], sums_p, 0.0)
    normed = _esm_kernel(
        sc, jnp.asarray(dloc), jnp.asarray(brows), sums_p,
        tv=tv, be=be, bh=bh, interpret=_interpret(),
    )
    # un-permute back to the caller's edge order
    e = scores.shape[0]
    out = jnp.zeros((e, h), scores.dtype)
    live = perm >= 0
    out = out.at[jnp.asarray(perm[live])].set(normed[np.nonzero(live)[0], :h])
    return out, sums_p[:num_rows, :h]


def flash_attention(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Sk, D]
    v: jax.Array,  # [B, Hkv, Sk, D]
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    bq: int = 512,
    bk: int = 512,
) -> jax.Array:
    """GQA flash attention; broadcasts kv heads to q heads for the kernel."""
    if not _use_kernels():
        return kref.flash_attention_ref(q, k, v, causal=causal, window=window, q_offset=q_offset)
    g = q.shape[1] // k.shape[1]
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    return _flash_kernel(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        bq=bq, bk=bk, interpret=_interpret(),
    )
