"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

Each function is the semantic ground truth used by tests
(`assert_allclose` across shape/dtype sweeps) and by the CPU fallback in
:mod:`repro.kernels.ops`.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def segment_spmm_ref(messages: jax.Array, dst: jax.Array, num_rows: int) -> jax.Array:
    """Sum messages[e] into out[dst[e]]; dst may contain -1 (padding → dropped).

    messages: [E, D] float; dst: [E] int32; returns [num_rows, D].
    """
    valid = dst >= 0
    seg = jnp.where(valid, dst, num_rows)
    out = jax.ops.segment_sum(
        messages * valid[:, None].astype(messages.dtype), seg, num_segments=num_rows + 1
    )
    return out[:num_rows].astype(messages.dtype)


def delta_agg_ref(
    state: jax.Array, messages: jax.Array, dst: jax.Array
) -> jax.Array:
    """state[dst[e]] += messages[e] (signed deltas; -1 padding dropped)."""
    delta = segment_spmm_ref(messages, dst, state.shape[0])
    return state + delta.astype(state.dtype)


def edge_softmax_ref(
    scores: jax.Array, dst: jax.Array, num_rows: int
) -> Tuple[jax.Array, jax.Array]:
    """GAT edge softmax over raw exp-scores grouped by destination.

    scores: [E, H] raw exp(logits) (the paper keeps raw exp sums — Alg. 3);
    returns (normalized [E, H], per-row sums [num_rows, H])."""
    sums = segment_spmm_ref(scores, dst, num_rows)
    safe = jnp.where(dst >= 0, dst, 0)
    denom = sums[safe]
    out = jnp.where(denom > 1e-10, scores / jnp.where(denom > 1e-10, denom, 1.0), 0.0)
    return out.astype(scores.dtype), sums


def flash_attention_ref(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, Hkv, Sk, D]
    v: jax.Array,  # [B, Hkv, Sk, D]
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    """Reference attention with GQA head-group broadcast, causal masking and
    optional sliding window.  q_offset: absolute position of q[...,0,:]
    (decode: q_offset = kv_len - q_len)."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    sk = k.shape[2]
    g = h // hkv
    # grouped-GQA einsum (no kv-head repeat — preserves KV sharding; §Perf)
    # native-dtype operands + f32 accumulation: casting bf16 K/V to f32
    # materializes full-cache copies (measured 38 GB/device at 32k prefill)
    qf = q.reshape(b, hkv, g, sq, d).astype(k.dtype)
    kf = k
    vf = v

    def _attend(q_chunk, off):
        qc = q_chunk.shape[3]
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", q_chunk, kf,
                            preferred_element_type=jnp.float32) / jnp.sqrt(d)
        qpos = off + jnp.arange(qc)[:, None]
        kpos = jnp.arange(sk)[None, :]
        m = jnp.ones((qc, sk), bool)
        if causal:
            m &= kpos <= qpos
        if window is not None:
            m &= kpos > qpos - window
        logits = jnp.where(m[None, None, None], logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1)
        probs = jnp.where(jnp.isnan(probs), 0.0, probs)
        return jnp.einsum("bhgqk,bhkd->bhgqd", probs.astype(vf.dtype), vf,
                          preferred_element_type=jnp.float32)

    CHUNK = 2048
    if sq > CHUNK and sq % CHUNK == 0:
        # long prefill: bound the probs buffer to [.., CHUNK, Sk] — the
        # XLA-side stand-in for the flash kernel's VMEM streaming
        nb = sq // CHUNK
        qb = jnp.moveaxis(qf.reshape(b, hkv, g, nb, CHUNK, d), 3, 0)
        offs = q_offset + CHUNK * jnp.arange(nb)
        outs = jax.lax.map(lambda args: _attend(*args), (qb, offs))
        out = jnp.moveaxis(outs, 0, 3).reshape(b, hkv, g, sq, d)
    else:
        out = _attend(qf, q_offset)
    return out.reshape(b, h, sq, d).astype(q.dtype)
