"""In-place signed delta aggregation (Pallas TPU, aliased state update).

The incremental hot path of Alg. 1 line 5: ``a[dst[e]] += sign·msg[e]`` over
the affected-edge records, *in place* on the cached aggregation state.  Uses
the same block-aligned one-hot-MXU schedule as :mod:`segment_spmm`, plus
``input_output_aliases`` so the state tensor is updated without a second
HBM copy — the TPU equivalent of NeutronRT's in-place GPU scatter.

Only state tiles named in ``block_rows`` are touched; all other rows pass
through untouched via the aliased buffer (this is what makes the update
O(affected) in HBM traffic instead of O(V)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Tile sizes shared by the fused-step delta scatter: the host planner
# (affected.pack_plan) emits the block-CSR schedule with these, and the
# device step (incremental.fused_stream_step) calls the kernel with the
# same — they must agree or the BlockSpecs read the wrong tiles.
DELTA_TV = 8  # state rows per tile
DELTA_BE = 128  # records per edge block (streams are small; 512 overpads)
DELTA_BD = 128  # feature lanes per block (Mosaic f32 tiling needs lane dim ≥128)


def _kernel(block_rows_ref, dloc_ref, msg_ref, state_ref, out_ref):
    i = pl.program_id(1)
    first = jnp.logical_or(i == 0, block_rows_ref[i] != block_rows_ref[jnp.maximum(i - 1, 0)])

    @pl.when(first)
    def _():
        out_ref[...] = state_ref[...]

    dloc = dloc_ref[...].reshape(-1)
    rows = jax.lax.broadcasted_iota(jnp.int32, (out_ref.shape[0], dloc.shape[0]), 0)
    onehot = (rows == dloc[None, :]).astype(jnp.float32)
    msg = msg_ref[...].astype(jnp.float32)
    out_ref[...] += jnp.dot(onehot, msg, preferred_element_type=jnp.float32).astype(
        out_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("tv", "be", "bd", "interpret"))
def delta_agg(
    messages: jax.Array,  # [E_pad, D] signed, block-aligned layout
    dst_local: jax.Array,  # [E_pad] int32 (-1 padding)
    block_rows: jax.Array,  # [NB] int32 (non-decreasing)
    state: jax.Array,  # [rows_pad, D] — updated in place (donated)
    tv: int = 8,
    be: int = 512,
    bd: int = 128,
    interpret: bool = False,
) -> jax.Array:
    e_pad, d = messages.shape
    nb = e_pad // be
    nd = d // bd
    assert state.shape[0] % tv == 0 and state.shape[1] == d

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nd, nb),
        in_specs=[
            pl.BlockSpec((be, 1), lambda j, i, br: (i, 0)),
            pl.BlockSpec((be, bd), lambda j, i, br: (i, j)),
            pl.BlockSpec((tv, bd), lambda j, i, br: (br[i], j)),  # state (read)
        ],
        out_specs=pl.BlockSpec((tv, bd), lambda j, i, br: (br[i], j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(state.shape, state.dtype),
        input_output_aliases={3: 0},  # alias state → out (after scalar operand)
        interpret=interpret,
        name="delta_agg",
    )(block_rows, dst_local[:, None], messages, state)
