"""Block-aligned CSR segment-sum as one-hot MXU matmuls (Pallas TPU).

The TPU-native realization of the paper's scatter-add aggregation hot spot
(DESIGN.md §3, §7).  TPUs have no efficient random scatter; instead, edges
are pre-sorted by destination and padded so that each destination *row tile*
(TV rows) owns an integer number of *edge blocks* (BE edges).  Within a
block the segment-sum becomes

    out_tile[TV, BD] += onehot[TV, BE] @ messages[BE, BD]

an MXU matmul with `onehot[r, e] = (dst_local[e] == r)` — systolic-array
work instead of serial scatters.

Data-dependent output indexing uses `PrefetchScalarGridSpec`: the host
precomputes ``block_rows[i]`` = row-tile index of edge block i (sorted ⇒
non-decreasing), which drives the output BlockSpec.  The grid is ordered
(feature_tiles, edge_blocks) so revisits of an output tile are *consecutive*
— the Pallas accumulation contract — with `pl.when(first-visit)` zeroing.

v5e sizing: BE=512 edges × BD=128 lanes of f32 messages = 256 KiB input
block; TV=8 sublanes × 128 lanes out = 4 KiB; onehot materialized at
[8, 512] = 16 KiB.  Three buffers double-buffered ≈ 0.6 MiB of the 128 MiB
VMEM — leaves room for the wider-D variants the engine uses (BD up to 512).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# default tile sizes (see header); overridable for tests/sweeps
TV = 8  # destination rows per tile (sublane dim)
BE = 512  # edges per block
BD = 128  # feature lanes per block


def prepare_block_csr(
    dst: np.ndarray, num_rows: int, tv: int = TV, be: int = BE
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Host-side block alignment (the 'block-aligned CSR' layout).

    Given dst ids sorted ascending (pad entries = -1 allowed at the end),
    emits (perm, dst_local, block_rows, e_pad):
      perm       [E_pad] gather indices into the edge array (-1 → padding)
      dst_local  [E_pad] destination row *within its tile* (-1 → padding)
      block_rows [E_pad/be] row-tile index per edge block (non-decreasing)
    """
    dst = np.asarray(dst, np.int64)
    valid = dst >= 0
    dstv = dst[valid]
    idxv = np.nonzero(valid)[0]
    assert np.all(np.diff(dstv) >= 0), "dst must be sorted ascending"
    tiles = dstv // tv
    perm_parts = []
    dloc_parts = []
    block_rows = []
    for t in np.unique(tiles):
        sel = idxv[tiles == t]
        cnt = sel.shape[0]
        pad = (-cnt) % be
        perm_parts.append(np.concatenate([sel, np.full(pad, -1, np.int64)]))
        dl = np.concatenate([dstv[tiles == t] - t * tv, np.full(pad, -1, np.int64)])
        dloc_parts.append(dl)
        block_rows.extend([int(t)] * ((cnt + pad) // be))
    if not perm_parts:  # empty input
        perm = np.full(be, -1, np.int64)
        dloc = np.full(be, -1, np.int64)
        block_rows = [0]
    else:
        perm = np.concatenate(perm_parts)
        dloc = np.concatenate(dloc_parts)
    return (
        perm.astype(np.int32),
        dloc.astype(np.int32),
        np.asarray(block_rows, np.int32),
        perm.shape[0],
    )


def _kernel(block_rows_ref, dloc_ref, msg_ref, out_ref):
    j, i = pl.program_id(0), pl.program_id(1)
    first = jnp.logical_or(i == 0, block_rows_ref[i] != block_rows_ref[jnp.maximum(i - 1, 0)])

    @pl.when(first)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    dloc = dloc_ref[...].reshape(-1)  # [BE]
    rows = jax.lax.broadcasted_iota(jnp.int32, (out_ref.shape[0], dloc.shape[0]), 0)
    onehot = (rows == dloc[None, :]).astype(jnp.float32)
    msg = msg_ref[...].astype(jnp.float32)
    out_ref[...] += jnp.dot(onehot, msg, preferred_element_type=jnp.float32).astype(
        out_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("num_rows", "tv", "be", "bd", "interpret"))
def segment_spmm(
    messages: jax.Array,  # [E_pad, D] already permuted to block layout
    dst_local: jax.Array,  # [E_pad] int32 (-1 padding)
    block_rows: jax.Array,  # [NB] int32
    num_rows: int,
    tv: int = TV,
    be: int = BE,
    bd: int = BD,
    interpret: bool = False,
) -> jax.Array:
    """Segment-sum of block-aligned messages. Returns [num_rows_padded, D]
    where num_rows_padded = ceil(num_rows/tv)*tv; caller slices [:num_rows]."""
    e_pad, d = messages.shape
    assert e_pad % be == 0, (e_pad, be)
    assert d % bd == 0, (d, bd)
    nb = e_pad // be
    nd = d // bd
    rows_pad = ((num_rows + tv - 1) // tv) * tv

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nd, nb),
        in_specs=[
            pl.BlockSpec((be, 1), lambda j, i, br: (i, 0)),  # dst_local
            pl.BlockSpec((be, bd), lambda j, i, br: (i, j)),  # messages
        ],
        out_specs=pl.BlockSpec((tv, bd), lambda j, i, br: (br[i], j)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows_pad, d), messages.dtype),
        interpret=interpret,
        name="segment_spmm",
    )(block_rows, dst_local[:, None], messages)
    return out
