"""Blockwise streaming-softmax attention (Pallas TPU).

Used by the LM model zoo (training / prefill paths) — causal and
sliding-window variants with fp32 streaming-softmax state in VMEM scratch.

Schedule: grid = (B*H, Q_blocks, K_blocks), K fastest.  Per (b, q) the
running (max m, denom l, accumulator acc) live in VMEM scratch and are
finalized on the last K block.  Masked K blocks are computed-and-masked
(correctness first; the §Perf log covers skipping them via a banded grid).

v5e sizing: BQ=BK=512, D=128 → q/k/v blocks 3×256 KiB, acc 256 KiB fp32,
all ≪ VMEM.  MXU dims (512×128 @ 128×512) are lane/sublane aligned.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, causal, window, q_offset, bq, bk, num_kb):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # [BQ, D]
    k = k_ref[0].astype(jnp.float32)  # [BK, D]
    v = v_ref[0].astype(jnp.float32)  # [BK, D]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [BQ, BK]

    qb = pl.program_id(1)
    qpos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # [BQ, 1]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur

    @pl.when(kb == num_kb - 1)
    def _():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, H, Sk, D]  (kv heads pre-broadcast by wrapper)
    v: jax.Array,  # [B, H, Sk, D]
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    bq: int = 512,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    sk = k.shape[2]
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nk = sq // bq, sk // bk
    bh = b * h
    qf = q.reshape(bh, sq, d)
    kf = k.reshape(bh, sk, d)
    vf = v.reshape(bh, sk, d)
    kernel = functools.partial(
        _kernel,
        scale=1.0 / (d**0.5),
        causal=causal,
        window=window,
        q_offset=q_offset,
        bq=bq,
        bk=bk,
        num_kb=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bhi, qi, ki: (bhi, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bhi, qi, ki: (bhi, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bhi, qi, ki: (bhi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bhi, qi, ki: (bhi, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
        name="flash_attention",
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
