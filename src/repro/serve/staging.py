"""Asynchronous double-buffered host↔device staging (paper §V co-processing).

The host-resident backends (:class:`~repro.core.backend.OffloadBackend`,
:class:`~repro.core.backend.ShardedOffloadBackend`) move three kinds of
payload per layer: a compact host **gather** of the rows the plan touches,
the **H2D** staging copy, and the **D2H write-back** of the updated rows.
Before this module all three ran serially on the dispatch thread, so host
staging sat on the critical path of every layer (the `offload_stream_wall`
smoke cell measured staging dominating the offload batch).  The
:class:`HostStagingPipeline` moves the host-memory halves onto one
background worker so they overlap the device's compute:

                 batch t                               batch t+1
  caller   put/exec L0 ─ d2h L0 ─ put/exec L1 ─ d2h L1 ─ ... plan(t+1) ...
  worker  [G0][G1][G2]···[WB0 scatter]······[WB1 scatter][WBfinal][G0']···
  device  ───[compute L0]───────[compute L1]───────[compute L2]──[L0']──

  G l   = pristine host gather of layer l's staging buffers (submitted for
          every layer at dispatch start, value-independent — see below)
  WB l  = host scatter of layer l's D2H'd outputs into the resident state
  d2h l = the caller's only block: device completion + copy-out of layer l

while the device computes layer *l*, the worker is gathering layer *l+1*
(prefetch) and scattering layer *l-1*'s write-back — the overlap the
ROADMAP "Async offload prefetch" item asks for.  The final layer's
write-back (D2H **and** scatter) runs entirely on the worker, so the
orchestrator's batch-t+1 planning and even batch-t+1's gathers (queued
behind it) proceed while the device finishes batch t.

Why pristine gathers can all be submitted up front: within a batch, layer
*l*'s staging reads ``h[l]`` (written only by write-back *l-1*), ``a[l]``/
``nct[l]``/``h[l+1]`` (written only by write-back *l*).  Gathering the
**pre-batch** state therefore yields exactly the *old* view ``h_old``; the
*new* view is the same rows patched with the previous layer's freshly
computed outputs (values the caller holds anyway after its D2H).  The
single in-order worker queue makes "pristine" precise: all of batch t's
gathers are enqueued before any of batch t's write-backs, and batch t+1's
gathers are enqueued after batch t's final write-back.

Mechanics:

* **two staging buffer sets per layer** — grow-only host buffers (pinned
  allocations on a real GPU host; plain page-aligned numpy on CPU/TPU CI),
  alternated per batch (``begin_batch``) so a set being consumed by batch
  t's H2D is never the set batch t+1's gathers fill;
* **depth-2 request queue** — at most two staging jobs in flight gives the
  one-ahead prefetch the schedule needs while bounding host memory and
  providing back-pressure;
* **explicit phases** — ``submit_gather`` / ``wait_gather`` (caller blocks
  for staged buffers), ``wait_device`` (caller blocks for D2H; this is the
  device-compute window), ``submit_writeback``, and ``drain`` (full
  barrier: queue empty, worker idle, worker exceptions re-raised on the
  caller thread — the backends' ``flush()`` calls it);
* **sync escape hatch** — ``async_mode=False`` executes every submitted
  job inline on the caller thread.  Both modes run byte-identical numpy
  work, so the async path is bitwise-identical to the sync path
  (tests/test_staging.py gates this over 20-batch gcn+gat streams).

Deterministic counters (``StagingStats.staged_bytes``, job counts) feed
the CI overlap gate (`benchmarks/check_regression.py`); the timing
counters (``wait_gather_s``/``wait_device_s``/``work_*``) are telemetry
for `StreamStats.sync_wait_s` vs `compute_s` and are never gated.

Hot-row cache coexistence (ISSUE 8): with the device hot-row cache
(:mod:`repro.serve.hotcache`) enabled, the backends submit **miss-only
gather jobs** — the same pristine-gather contract over the plan's cold
miss row lists instead of the full per-layer row sets.  Nothing here
changes: the staged payload (and therefore ``staged_bytes``) simply
shrinks by the cached fraction, which is exactly the reduction the CI
cache gate measures.

Serving coexistence (ISSUE 6): the pipeline's pristine-gather contract —
worker jobs read host state in submission order, so a layer's staged view
is exactly the pre-batch state — also protects snapshot reads.  The
serving front-end (`repro.serve.frontend`) only gathers at version
boundaries, i.e. after the owning backend's ``flush()`` has ``drain()``-ed
the queue (``idle`` is then True), so a snapshot can never observe a
half-retired write-back nor inject host work under a live gather.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
import weakref
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class StagingConfig:
    """Typed knobs for the host staging pipeline (nested in
    :class:`repro.serve.api.EngineConfig` as ``staging=``).

    ``async_enabled`` selects the background worker (False = the inline
    bitwise-identical escape hatch); ``depth`` bounds the in-flight job
    queue (2 = the double-buffered one-ahead prefetch the module
    docstring's schedule needs; larger values deepen the prefetch window
    at the cost of host staging memory)."""

    async_enabled: bool = True
    depth: int = 2

    def __post_init__(self) -> None:
        if self.depth < 1:
            raise ValueError(f"staging depth must be >= 1, got {self.depth}")


@dataclasses.dataclass
class StagingStats:
    """Pipeline counters.  ``staged_bytes``/job counts are deterministic
    functions of the plan (CI-gateable); the ``*_s`` fields are wall-clock
    telemetry."""

    staged_bytes: int = 0  # gather payload + write-back payload, in bytes
    gather_jobs: int = 0
    writeback_jobs: int = 0
    wait_gather_s: float = 0.0  # caller blocked waiting for staged buffers
    wait_device_s: float = 0.0  # caller blocked in D2H (device compute window)
    drain_wait_s: float = 0.0  # caller blocked in drain() barriers
    work_gather_s: float = 0.0  # worker (or inline) time executing gathers
    work_writeback_s: float = 0.0

    def snapshot(self) -> "StagingStats":
        return dataclasses.replace(self)


class StagingTicket:
    """Completion handle for one submitted staging job."""

    __slots__ = ("_event", "result", "error")

    def __init__(self) -> None:
        self._event = threading.Event()
        self.result: Any = None
        self.error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self) -> Any:
        self._event.wait()
        if self.error is not None:
            raise RuntimeError("host staging job failed") from self.error
        return self.result


class StagingBuffers:
    """One grow-only named staging buffer set (half of a layer's pair).

    Buffers are keyed by ``(name, trailing shape, dtype)`` and grow only
    along axis 0, so ``take`` always returns a C-contiguous view that
    ``np.take(..., out=)`` can fill without an intermediate allocation —
    the "pinned buffer" reuse a GPU host needs for async H2D."""

    def __init__(self) -> None:
        self._bufs: Dict[Tuple, np.ndarray] = {}

    def take(self, name: str, rows: int, trailing: Tuple[int, ...],
             dtype=np.float32) -> np.ndarray:
        key = (name, trailing, np.dtype(dtype).str)
        buf = self._bufs.get(key)
        if buf is None or buf.shape[0] < rows:
            cap = max(rows, 2 * buf.shape[0] if buf is not None else rows)
            buf = np.empty((cap,) + trailing, dtype)
            self._bufs[key] = buf
        return buf[:rows]

    def nbytes(self) -> int:
        return sum(b.nbytes for b in self._bufs.values())


class HostStagingPipeline:
    """Background host-staging worker: depth-``depth`` in-order job queue,
    two :class:`StagingBuffers` sets per layer, exception capture with
    re-raise at ``drain()``.  See the module docstring for the schedule."""

    def __init__(self, num_layers: int, depth: int = 2,
                 async_mode: bool = True, name: str = "staging") -> None:
        self.num_layers = num_layers
        self.async_mode = async_mode
        self.stats = StagingStats()
        # test seams: called inside the worker before each job body runs
        # (fault injection / artificial gather slowdown — test_staging.py)
        self.gather_hook: Optional[Callable[[Any], None]] = None
        self.writeback_hook: Optional[Callable[[Any], None]] = None
        self._buffers = [(StagingBuffers(), StagingBuffers())
                         for _ in range(num_layers)]
        self._parity = 0
        self._failure: Optional[BaseException] = None
        self._q: Optional[queue.Queue] = None
        if async_mode:
            self._q = queue.Queue(maxsize=depth)
            # the worker holds only a weakref to the pipeline (plus the
            # queue), so a dropped engine does not leak its pipeline,
            # staging buffers, or worker thread: once the queue drains,
            # the pipeline becomes collectable and __del__ stops the
            # worker via the sentinel
            self._worker = threading.Thread(
                target=_worker_loop, args=(weakref.ref(self), self._q),
                name=f"{name}-worker", daemon=True)
            self._worker.start()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass  # interpreter shutdown: the daemon worker dies anyway

    # ------------------------------------------------------------------ #
    # buffer management
    # ------------------------------------------------------------------ #
    def begin_batch(self) -> None:
        """Flip the double buffers: this batch's gathers fill the set the
        previous batch was *not* staging from."""
        self._parity ^= 1

    def buffers(self, layer: int) -> StagingBuffers:
        """The staging buffer set for ``layer`` in the current parity."""
        return self._buffers[layer][self._parity]

    def buffer_bytes(self) -> int:
        return sum(s.nbytes() for pair in self._buffers for s in pair)

    # ------------------------------------------------------------------ #
    # phases
    # ------------------------------------------------------------------ #
    def submit_gather(self, fn: Callable[[], Any], tag: Any = None) -> StagingTicket:
        """Enqueue a host gather producing staged buffers (a dict/tuple of
        arrays); value-independent of any in-flight write-back by the
        in-order-queue contract."""
        self.stats.gather_jobs += 1
        return self._submit(fn, "gather", tag)

    def wait_gather(self, ticket: StagingTicket) -> Any:
        """Block until a gather's staged buffers are ready (re-raising a
        worker failure here, on the caller thread)."""
        t0 = time.perf_counter()
        out = ticket.wait()
        self.stats.wait_gather_s += time.perf_counter() - t0
        if out is not None:
            self.stats.staged_bytes += sum(
                int(a.nbytes) for a in _iter_arrays(out))
        return out

    def wait_device(self, outs) -> Tuple[np.ndarray, ...]:
        """D2H: block until the device materializes ``outs`` and copy them
        out.  This wait *is* the device-compute window the worker's gathers
        and write-backs hide behind."""
        t0 = time.perf_counter()
        host = tuple(np.asarray(o) for o in outs)
        self.stats.wait_device_s += time.perf_counter() - t0
        return host

    def submit_writeback(self, fn: Callable[[], Any], nbytes: int = 0,
                         tag: Any = None) -> StagingTicket:
        """Enqueue a host scatter of written-back rows (the arrays are
        already host-side, or the job performs its own D2H for the deferred
        final layer)."""
        self.stats.writeback_jobs += 1
        self.stats.staged_bytes += int(nbytes)
        return self._submit(fn, "writeback", tag)

    @property
    def idle(self) -> bool:
        """True when no submitted job is queued or running (always True in
        sync mode) — the state a version-boundary snapshot read relies on."""
        return self._q is None or self._q.unfinished_tasks == 0

    def drain(self) -> None:
        """Full barrier: every submitted job has executed and any worker
        exception is re-raised here, on the caller thread."""
        if self._q is not None:
            t0 = time.perf_counter()
            self._q.join()
            self.stats.drain_wait_s += time.perf_counter() - t0
        if self._failure is not None:
            err, self._failure = self._failure, None
            raise RuntimeError("host staging worker failed") from err

    def close(self) -> None:
        """Stop the worker.  Called by ``__del__`` when the owning backend
        is dropped; safe to call explicitly and idempotent."""
        if self._q is not None:
            q, self._q = self._q, None
            q.put(None)

    # ------------------------------------------------------------------ #
    # execution
    # ------------------------------------------------------------------ #
    def _submit(self, fn, kind: str, tag) -> StagingTicket:
        ticket = StagingTicket()
        if self._q is None:  # sync escape hatch: identical work, inline
            t0 = time.perf_counter()
            try:
                self._exec(ticket, fn, kind, tag)
            finally:
                self._account_work(kind, time.perf_counter() - t0)
            if ticket.error is not None:
                self._failure = None  # propagated right here instead
                raise RuntimeError("host staging job failed") from ticket.error
            return ticket
        self._q.put((ticket, fn, kind, tag))
        return ticket

    def _exec(self, ticket: StagingTicket, fn, kind: str, tag) -> None:
        try:
            hook = self.gather_hook if kind == "gather" else self.writeback_hook
            if hook is not None:
                hook(tag)
            ticket.result = fn()
        except BaseException as e:  # surfaced by wait()/drain(), never lost
            ticket.error = e
            if self._failure is None:
                self._failure = e
        finally:
            ticket._event.set()

    def _account_work(self, kind: str, dt: float) -> None:
        if kind == "gather":
            self.stats.work_gather_s += dt
        else:
            self.stats.work_writeback_s += dt

def _worker_loop(pipe_ref: "weakref.ref[HostStagingPipeline]",
                 q: queue.Queue) -> None:
    """Module-level worker body: holds the queue strongly but the pipeline
    only weakly, so the thread never pins a dropped engine's buffers."""
    while True:
        job = q.get()
        if job is None:
            q.task_done()
            return
        ticket, fn, kind, tag = job
        pipe = pipe_ref()
        if pipe is None:  # owner collected mid-queue: nobody can wait on us
            ticket._event.set()
            q.task_done()
            return
        t0 = time.perf_counter()
        try:
            pipe._exec(ticket, fn, kind, tag)
        finally:
            pipe._account_work(kind, time.perf_counter() - t0)
            q.task_done()
            del pipe  # drop the strong ref before blocking on q.get()


def _iter_arrays(obj):
    """Yield the staged ndarrays of a gather payload for byte accounting.

    Dict entries whose key starts with ``"_"`` are *derived* buffers —
    copies a gather job builds from bytes it already staged (e.g. the
    hybrid backend's host ``_h_new`` view, a byte-for-byte copy of the
    ``h_old`` gather).  Counting them would double-charge
    ``staged_bytes`` for every row staged twice across consecutive
    layers, so they are skipped."""
    if isinstance(obj, np.ndarray):
        yield obj
    elif isinstance(obj, dict):
        for k, v in obj.items():
            if isinstance(k, str) and k.startswith("_"):
                continue
            yield from _iter_arrays(v)
    elif isinstance(obj, (tuple, list)):
        for v in obj:
            yield from _iter_arrays(v)
