"""Degree-aware device-resident hot-row cache for the host-resident backends.

The paper's §V co-processing argument is that communication-optimized
scheduling — not just overlap — keeps the device busy when the embedding
tables live in host memory, and ``table5_degree.py`` measures exactly the
degree skew that makes a small hot set absorb most row traffic.  This
module pins that hot set on the device so the
:class:`~repro.serve.staging.HostStagingPipeline` gathers only cold
misses per layer:

::

    plan (host, value-independent)           dispatch (device)
    ─────────────────────────────            ────────────────────────────
    need_h ──┬── [cached] ── slot ids ─────▶ store[slots] ──┐ scatter
             └── [miss]   ── gather rows ──▶ H2D (staged) ──┤   ▼
                                                       workspace [nh, d]
    srows  ──┬── [cached] ── slot ids ─────▶ store[slots] ──┐ scatter
             └── [miss]   ── gather rows ──▶ H2D (staged) ──┤   ▼
                                                       a/nct/h_cur [ns, ·]
                                             kernel outs ──▶ store.at[wb
                                             (in-place slot update; host
                                              write-back unchanged)

    admission  = frequency × (1 + degree), from the plan's degree tables
    eviction   = deterministic lowest-priority victim (ties: smallest row)
    invalidate = value-independent, driven by the plan's write sets
                 (feature updates, policy chunked scatters, full refresh)

Coherence invariant (what the tests pin): *a cached slot always holds
exactly the host-state value of its row as of the last completed batch.*
It is maintained without ever reading state values at plan time:

* The split of each layer's needed rows into ``[cached | miss]`` is
  computed at **plan time** (:func:`repro.core.affected.split_residency`,
  next to the ``[halo | local]`` remap) from slot metadata only, so it
  keeps the §V overlap contract — plan(t+1) may run while batch t still
  executes, because all metadata mutation happens in ``plan`` and all
  device data movement in ``dispatch``, and the orchestrator serializes
  plan(t+1) after dispatch(t).
* Rows written *earlier in the same batch* (the previous layer's write
  set / the batch's feature vertices) are excluded from hits and from
  staged-value admission: their pre-batch staged value would go stale
  within the batch.  Their cached slots are instead updated **in place on
  device from the kernel outputs** at write-back — hot rows therefore
  skip the per-batch D2H→host→H2D re-staging round-trip entirely (the
  host write-back itself is unchanged: host state stays authoritative
  for snapshot reads, the serving undo log, and the hybrid's halo
  exchange).
* Writes that do not flow through the incremental write-back (feature
  scatters, the policy's chunked ``scatter_layer_rows``, full refresh)
  **invalidate** instead — value-independent, driven by the same row
  sets ``changed_rows`` reports, so the serving front-end's snapshot/undo
  contract and the staging worker's pristine-gather contract both hold
  with the cache enabled.

Row spaces: one per (kind, layer) — ``("h", l)`` caches rows of
``h[l]`` (the layer-``l`` gather view), ``("s", l)`` caches the
``(a[l], nct[l], h[l+1])`` row triple (the layer-``l`` state view).
Keys are **global row ids** for both host-resident substrates; under the
sharded hybrid a hot halo row is therefore cached once and served to
every shard that needs it (the store is one un-sharded device array — a
per-shard slab split is future work, noted in ROADMAP).

Everything here is deterministic: admission order, eviction victims and
the hit/miss/eviction counters (surfaced as
``StreamStats.cache_hit_rows`` / ``cache_miss_rows`` /
``cache_evictions``) depend only on the update stream, so CI gates them
exactly (``benchmarks/check_regression.py --suite smoke|sharded``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.affected import ResidencySplit, split_residency

#: admission priority models ``CacheConfig.admission`` accepts
ADMISSION_POLICIES = ("freq_degree", "freq")


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Typed knobs for the device hot-row cache (nested in
    :class:`repro.serve.api.EngineConfig` as ``cache=``).

    ``capacity_rows`` is the slot count *per row space* (2 spaces per
    layer); ``admission`` picks the priority model (``"freq_degree"`` —
    touch frequency × (1 + plan degree), the paper-motivated default — or
    ``"freq"`` — pure touch frequency); ``enabled=False`` keeps the
    config inert (identical to passing no cache at all).

    ``prewarm_rows`` (ISSUE 9) seeds every row space from the top-degree
    rows of the base graph *before batch 0* instead of learning the hot
    set during the first batches — the degree skew the paper's §V argument
    rests on makes the static top of the degree distribution a strong
    prior for the streamed hot set.  ``decay`` (ISSUE 9) is the per-batch
    LFU aging factor: each batch every space's frequency counters are
    multiplied by ``1 - decay`` at plan time, so a drifting hot set
    (feature_churn regime) can evict stale hubs.  Both default off
    (``0`` / ``0.0`` — behavior bit-for-bit identical to ISSUE 8)."""

    capacity_rows: int = 256
    admission: str = "freq_degree"
    enabled: bool = True
    prewarm_rows: int = 0
    decay: float = 0.0

    def __post_init__(self) -> None:
        if self.capacity_rows <= 0:
            raise ValueError(f"capacity_rows must be positive, got "
                             f"{self.capacity_rows}")
        if self.admission not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy {self.admission!r}; "
                             f"expected one of {ADMISSION_POLICIES}")
        if self.prewarm_rows < 0:
            raise ValueError(f"prewarm_rows must be >= 0, got "
                             f"{self.prewarm_rows}")
        if not 0.0 <= self.decay < 1.0:
            raise ValueError(f"decay must be in [0, 1), got {self.decay!r}")


@dataclasses.dataclass
class CacheStats:
    """Deterministic cache counters (documented subset surfaced through
    ``StreamStats.as_dict``; see the table there)."""

    hit_rows: int = 0  #: rows served from device slots instead of staging
    miss_rows: int = 0  #: rows staged from host (cold or excluded)
    evictions: int = 0  #: capacity evictions (invalidations counted apart)
    admitted_rows: int = 0
    invalidated_rows: int = 0

    def snapshot(self) -> "CacheStats":
        return dataclasses.replace(self)


class _Space:
    """Slot metadata for one cached row space (host-side, value-free)."""

    __slots__ = ("slot_of", "row_of", "freq", "degw", "free", "stores")

    def __init__(self, n_keys: int, capacity: int) -> None:
        self.slot_of = np.full(n_keys, -1, np.int32)
        self.row_of = np.full(capacity, -1, np.int64)
        # float64 so LFU decay (CacheConfig.decay) can age counters in
        # place; undecayed counters are small integers, exact in float64,
        # so decay=0.0 keeps every priority bit-identical to the old int64
        self.freq = np.zeros(n_keys, np.float64)
        self.degw = np.zeros(n_keys, np.float32)
        # grow-only slot table: pop() always yields the smallest free slot
        self.free = list(range(capacity - 1, -1, -1))
        self.stores: Dict[str, object] = {}  # name -> jax.Array [cap, ·]


class HotRowCache:
    """Pinned device hot-row cache: host-side slot metadata (this class)
    plus grow-only per-space device stores the owning backend scatters
    into.  All admission/eviction/split decisions happen at plan time and
    are value-independent; the backend performs the corresponding device
    data movement at dispatch in the same order (see the module
    docstring's coherence invariant)."""

    def __init__(self, config: Optional[CacheConfig] = None) -> None:
        self.config = config or CacheConfig()
        self.capacity = int(self.config.capacity_rows)
        self.stats = CacheStats()
        self._spaces: Dict[Tuple[str, int], _Space] = {}

    # ------------------------------------------------------------------ #
    # metadata (plan time, host only)
    # ------------------------------------------------------------------ #
    def _space(self, key: Tuple[str, int], n_keys: int) -> _Space:
        sp = self._spaces.get(key)
        if sp is None:
            sp = self._spaces[key] = _Space(n_keys, self.capacity)
        return sp

    def _priority(self, sp: _Space, rows: np.ndarray) -> np.ndarray:
        if self.config.admission == "freq":
            return sp.freq[rows].astype(np.float64)
        return sp.freq[rows] * (1.0 + sp.degw[rows].astype(np.float64))

    def _touch(self, sp: _Space, rows: np.ndarray, deg: np.ndarray) -> None:
        np.add.at(sp.freq, rows, 1)
        sp.degw[rows] = np.asarray(deg, np.float32)

    def decay_tick(self) -> None:
        """Age every space's frequency counters by ``1 - decay`` (ISSUE 9
        LFU decay; the owning backend calls this once per batch at plan
        time).  With the default ``decay=0.0`` this returns immediately
        and every counter — and therefore every admission/eviction
        decision — is bit-for-bit the undecayed behavior."""
        d = self.config.decay
        if d <= 0.0:
            return
        f = 1.0 - d
        for sp in self._spaces.values():
            sp.freq *= f

    def _admit(self, sp: _Space, cand_rows: np.ndarray) -> np.ndarray:
        """Deterministically admit candidate rows (unique, uncached).

        Free slots fill first (highest priority first, ties to the
        smallest row); once full, a candidate evicts the lowest-priority
        cached victim only if strictly hotter (victim ties break to the
        smallest row).  Returns the admitted rows (slot assignment is in
        ``slot_of``)."""
        if not cand_rows.size:
            return cand_rows
        prio = self._priority(sp, cand_rows)
        order = np.lexsort((cand_rows, -prio))
        admitted = []
        for i in order:
            row = int(cand_rows[i])
            if sp.free:
                slot = sp.free.pop()
            else:
                occ = sp.row_of  # all slots occupied once free is empty
                vprio = self._priority(sp, occ)
                v = int(np.lexsort((occ, vprio))[0])
                if not prio[i] > vprio[v]:
                    # candidates are sorted by descending priority and the
                    # victim pool only gets hotter on eviction, so no later
                    # candidate can succeed either
                    break
                slot = v
                sp.slot_of[occ[v]] = -1
                self.stats.evictions += 1
            sp.slot_of[row] = slot
            sp.row_of[slot] = row
            admitted.append(row)
            self.stats.admitted_rows += 1
        return np.asarray(admitted, np.int64)

    def plan_reads(self, key: Tuple[str, int], n_keys: int, rows: np.ndarray,
                   deg: np.ndarray, exclude_rows: Optional[np.ndarray] = None,
                   admit: bool = True) -> ResidencySplit:
        """Plan-time ``[cached | miss]`` split of one layer's needed rows.

        Bumps the touch frequency, splits against the slot table
        (excluding rows written earlier in this batch — see module
        docstring), and optionally admits the hottest *non-excluded*
        misses so dispatch can fill their slots from the staged (pristine,
        pre-batch) values.  Returns the split with admission indices into
        its miss list."""
        sp = self._space(key, n_keys)
        self._touch(sp, rows, deg)
        split = split_residency(rows, sp.slot_of, exclude_rows=exclude_rows)
        self.stats.hit_rows += int(split.hit_pos.size)
        self.stats.miss_rows += int(split.miss_pos.size)
        if admit and split.miss_rows.size:
            cand, first = np.unique(split.miss_rows, return_index=True)
            if exclude_rows is not None and exclude_rows.size:
                keep = ~np.isin(cand, exclude_rows)
                cand, first = cand[keep], first[keep]
            got = self._admit(sp, cand)
            if got.size:
                sel = np.isin(cand, got)
                midx = np.sort(first[sel]).astype(np.int64)
                split = dataclasses.replace(
                    split,
                    admit_midx=midx,
                    admit_slots=sp.slot_of[split.miss_rows[midx]].astype(
                        np.int32),
                )
        return split

    def plan_writeback(self, key: Tuple[str, int], n_keys: int,
                       rows: np.ndarray, deg: np.ndarray,
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """Plan the in-place device slot updates for one layer's written
        rows: already-cached rows refresh their slots from the kernel
        outputs, and the hottest uncached written rows are admitted (their
        fresh values are free — they are already on device).  Returns
        ``(positions into rows, slots)``."""
        sp = self._space(key, n_keys)
        self._touch(sp, rows, deg)
        uncached = rows[sp.slot_of[rows] < 0]
        if uncached.size:
            self._admit(sp, np.unique(uncached))
        pos = np.flatnonzero(sp.slot_of[rows] >= 0).astype(np.int64)
        return pos, sp.slot_of[rows[pos]].astype(np.int32)

    def prewarm(self, key: Tuple[str, int], n_keys: int, rows: np.ndarray,
                deg: np.ndarray, values: Dict[str, np.ndarray]) -> None:
        """Seed one row space before batch 0 (``CacheConfig.prewarm_rows``).

        ``rows``/``deg`` are the base graph's top-degree rows (unique, any
        order) with their degrees; ``values`` maps store names to arrays
        aligned with ``rows`` holding those rows' *current* state, which
        the owning backend gathers once at construction time.  Runs the
        ordinary touch → admit pipeline, so prewarmed slots are
        indistinguishable from learned ones (same priorities, same
        deterministic eviction order), then fills the admitted slots'
        device stores so batch 0 already hits."""
        rows = np.asarray(rows, np.int64)
        if not rows.size:
            return
        sp = self._space(key, n_keys)
        self._touch(sp, rows, deg)
        got = self._admit(sp, np.unique(rows))
        if not got.size:
            return
        pos_of = {int(r): i for i, r in enumerate(rows)}
        pos = np.array([pos_of[int(r)] for r in got], np.int64)
        slots = sp.slot_of[got].astype(np.int32)
        for name, vals in values.items():
            self.update_store(key, name,
                              slots, np.asarray(vals, np.float32)[pos])

    def invalidate(self, key: Tuple[str, int], rows: np.ndarray) -> None:
        """Value-independent invalidation of cached rows (feature scatters
        and the policy's chunked host scatters route here)."""
        sp = self._spaces.get(key)
        if sp is None or not np.asarray(rows).size:
            return
        rows = np.asarray(rows, np.int64)
        slots = sp.slot_of[rows]
        slots = np.unique(slots[slots >= 0])
        if not slots.size:
            return
        sp.row_of[slots] = -1
        sp.slot_of[rows] = -1
        # keep pop() = smallest-free deterministic after arbitrary frees
        sp.free = sorted(set(sp.free) | set(int(s) for s in slots),
                         reverse=True)
        self.stats.invalidated_rows += int(slots.size)

    def invalidate_all(self) -> None:
        """Full invalidation (refresh / policy-forced full recompute: the
        whole state is rewritten host-side)."""
        n = sum(int((sp.row_of >= 0).sum()) for sp in self._spaces.values())
        self.stats.invalidated_rows += n
        self._spaces.clear()

    # ------------------------------------------------------------------ #
    # device stores (dispatch time)
    # ------------------------------------------------------------------ #
    def store(self, key: Tuple[str, int], name: str, trailing: Tuple[int, ...]):
        """The device slot store for (space, tensor) — lazily allocated
        ``[capacity, ·]`` zeros on first use (grow-only: capacity is
        fixed, rows recycle through the deterministic eviction order)."""
        import jax.numpy as jnp

        sp = self._spaces[key]
        st = sp.stores.get(name)
        if st is None:
            st = sp.stores[name] = jnp.zeros(
                (self.capacity,) + tuple(trailing), jnp.float32)
        return st

    def update_store(self, key: Tuple[str, int], name: str,
                     slots: np.ndarray, values) -> None:
        """Scatter fresh row values into their slots (device-side, eager —
        the in-place write-back update of the module docstring)."""
        st = self.store(key, name, values.shape[1:])
        self._spaces[key].stores[name] = st.at[np.asarray(slots)].set(values)

    def state_bytes(self) -> int:
        """Device bytes pinned by all slot stores (telemetry)."""
        return sum(int(st.nbytes) for sp in self._spaces.values()
                   for st in sp.stores.values())
