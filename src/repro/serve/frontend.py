"""Online serving front-end: versioned snapshot reads over a streaming engine.

The paper makes RTEC cheap enough to run *at serve time*; this module is the
deployment shape that exploits it.  A :class:`ServingFrontend` multiplexes
the two traffic classes a real deployment sees over one
:class:`~repro.core.backend.StreamOrchestrator` + :class:`StateBackend`:

* **writes** — structural/feature :class:`UpdateBatch` streams, applied one
  flushed batch at a time;
* **reads** — "give me fresh embeddings for these vertices" queries,
  micro-batched between update batches and answered from versioned,
  consistent snapshot views.

Serving API — the version/consistency contract
----------------------------------------------

* The frontend maintains a monotone ``version`` counter: version 0 is the
  construction-time state and each flushed update batch bumps it by one.
  Every batch is applied with ``block=True`` (``flush()`` +
  ``block_until_ready(sync_arrays())``), so a version is always a full
  barrier — the substrate's state *is* the post-batch state, bitwise.
* A read is **pinned** to a version at submit time (defaulting to the
  then-current version).  When served, its rows are **bitwise-equal** to
  the post-batch state at the pinned version, no matter how many batches
  have run since: between plan and dispatch of every batch the frontend
  snapshots the plan's final-layer write set
  (``StateBackend.changed_rows`` → ``snapshot_rows``) as a per-version
  *undo record*; a read pinned at v gathers current rows and overrides
  them with undo pre-images walking versions C→v+1.  Rows outside every
  write set are untouched by construction, so the reconstruction is exact.
* Undo history is bounded (``max_versions``).  A pin that falls below the
  retained floor is rejected with :class:`StaleVersionError`; a full
  pending-read queue evicts the oldest-pinned reads with
  :class:`ReadRejectedError` (admission control — the reads most likely to
  be unservably stale go first).
* An orchestrator ``refresh`` (drift reset) recomputes state from scratch
  — bitwise reconstruction across it is impossible, so the undo history is
  cleared and the floor jumps to the refresh version.
* Snapshot reads never inject work into a live staging pipeline: they run
  at version boundaries, where the host-resident substrates' worker queues
  are already drained (see ``StateBackend.snapshot_rows``).

Read-side telemetry (``reads_served``, ``reads_rejected``, submit→serve
latency p50/p99, cumulative staleness in batches) reports through the same
:class:`StreamStats` every other entry point returns.

The frontend is deliberately single-threaded and deterministic: reads are
admitted any time, but service happens at micro-batch points (before each
update batch and at ``drain``), which is what makes the bitwise interleaving
tests and the CI-gated exact counters possible.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.core.backend import (
    BatchStats,
    StreamOrchestrator,
    StreamStats,
    _override_rows,
)
from repro.graph.streaming import UpdateBatch


class ReadRejectedError(RuntimeError):
    """Read evicted by admission control (pending-read queue full)."""


class StaleVersionError(ReadRejectedError):
    """Read pinned below the retained undo-history floor."""


@dataclasses.dataclass
class ReadTicket:
    """One embedding-read query: global vertex ids pinned to a version."""

    rows: np.ndarray  # int64 global vertex ids (as submitted)
    version: int  # pinned version
    submitted_s: float
    result: Optional[np.ndarray] = None  # [len(rows), d] once served
    error: Optional[Exception] = None
    served_version: Optional[int] = None  # frontend version at service time

    @property
    def done(self) -> bool:
        return self.result is not None or self.error is not None

    @property
    def staleness(self) -> int:
        """Batches applied between the pin and service (0 = fresh)."""
        return (self.served_version - self.version
                if self.served_version is not None else 0)

    def value(self) -> np.ndarray:
        """The embedding rows at the pinned version (raises if rejected)."""
        if self.error is not None:
            raise self.error
        assert self.result is not None, "read not served yet"
        return self.result


@dataclasses.dataclass
class _UndoRecord:
    """Pre-images of the rows batch ``version`` wrote: applying this record
    on top of post-batch-``version`` state yields post-batch-``version-1``
    state, bitwise."""

    version: int
    rows: np.ndarray  # sorted unique int64
    vals: np.ndarray  # [len(rows), d] pre-batch values


class ServingFrontend:
    """Multiplexes update-batch writes and versioned embedding reads over
    any :class:`StateBackend` (see the module docstring for the contract).

    Parameters
    ----------
    engine:
        A :class:`StreamOrchestrator`, or any engine facade exposing
        ``_orch`` (``RTECEngine``/``OffloadedRTECEngine``/... and everything
        :func:`repro.serve.create_engine` returns).
    max_pending_reads:
        Admission-control bound on queued (unserved) reads; exceeding it
        evicts the oldest-pinned reads with :class:`ReadRejectedError`.
    max_versions:
        Retained undo-history depth — how many versions back a read may pin.
    """

    def __init__(self, engine, max_pending_reads: int = 64,
                 max_versions: int = 8):
        orch = engine if isinstance(engine, StreamOrchestrator) else engine._orch
        if max_pending_reads < 1:
            raise ValueError("max_pending_reads must be >= 1")
        if max_versions < 0:
            raise ValueError("max_versions must be >= 0")
        self._orch = orch
        self.max_pending_reads = max_pending_reads
        self.max_versions = max_versions
        self.version = 0
        self._floor = 0  # oldest version still bitwise-reconstructible
        self._undo: List[_UndoRecord] = []  # ascending by .version
        self._pending: List[ReadTicket] = []
        self._batch_stats: List[BatchStats] = []
        self._latencies: List[float] = []
        self._wall_s = 0.0
        self._plan_s = 0.0
        self.reads_served = 0
        self.reads_rejected = 0
        self.staleness_batches = 0
        # fusion counter baseline (ISSUE 9): stats() reports the deltas this
        # frontend's writes produced, not the orchestrator's lifetime totals
        self._fusion0 = (orch.fusion_windows, orch.fused_batches,
                         orch.fusion_fallbacks)

    # ------------------------------------------------------------------ #
    # read path
    # ------------------------------------------------------------------ #
    @property
    def min_version(self) -> int:
        """Oldest version a read may pin (the undo-history floor)."""
        return self._floor

    def submit_read(self, rows: Sequence[int],
                    version: Optional[int] = None) -> ReadTicket:
        """Enqueue an embedding read pinned to ``version`` (default: the
        current version).  Service happens at the next micro-batch point
        (:meth:`serve_reads`, called by :meth:`apply_batch`/:meth:`drain`).

        Raises :class:`StaleVersionError` immediately for pins below the
        retained floor; pins above the current version queue until the
        stream reaches them."""
        pin = self.version if version is None else int(version)
        if pin < self._floor:
            self.reads_rejected += 1
            raise StaleVersionError(
                f"read pinned at version {pin} but undo history floor is "
                f"{self._floor} (max_versions={self.max_versions})")
        t = ReadTicket(rows=np.asarray(rows, np.int64), version=pin,
                       submitted_s=time.perf_counter())
        self._pending.append(t)
        # admission control: evict the oldest-pinned reads first — they
        # are the ones most likely to fall below the floor anyway
        while len(self._pending) > self.max_pending_reads:
            evict = min(self._pending, key=lambda p: (p.version,
                                                      p.submitted_s))
            self._pending.remove(evict)
            evict.error = ReadRejectedError(
                f"read queue full (max_pending_reads="
                f"{self.max_pending_reads}); oldest-pinned read (version "
                f"{evict.version}) evicted")
            self.reads_rejected += 1
        return t

    def read(self, rows: Sequence[int],
             version: Optional[int] = None) -> np.ndarray:
        """Synchronous convenience wrapper: submit + serve immediately."""
        t = self.submit_read(rows, version=version)
        self.serve_reads()
        return t.value()

    def _reconstruct(self, rows: np.ndarray, pin: int) -> np.ndarray:
        """Rows at version ``pin``: gather current values, then walk the
        undo records C→pin+1 overriding any row they wrote."""
        vals = np.array(self._orch.backend.snapshot_rows(rows))
        for rec in reversed(self._undo):
            if rec.version <= pin:
                break
            _override_rows(vals, rows, rec.rows, rec.vals)
        return vals

    def serve_reads(self) -> int:
        """Serve every pending read pinned at or below the current version
        (micro-batched: one snapshot per distinct pinned version).  Returns
        the number of reads served."""
        due = [t for t in self._pending if t.version <= self.version]
        if not due:
            return 0
        served = 0
        for pin in sorted({t.version for t in due}):
            group = [t for t in due if t.version == pin]
            if pin < self._floor:  # floor moved while queued
                for t in group:
                    self._pending.remove(t)
                    t.error = StaleVersionError(
                        f"read pinned at version {pin} fell below the undo "
                        f"history floor {self._floor} while queued")
                    self.reads_rejected += 1
                continue
            # one gather for the union of the group's rows, scattered back
            union = np.unique(np.concatenate([t.rows for t in group]))
            union_vals = self._reconstruct(union, pin)
            now = time.perf_counter()
            for t in group:
                self._pending.remove(t)
                t.result = union_vals[np.searchsorted(union, t.rows)]
                t.served_version = self.version
                self._latencies.append(now - t.submitted_s)
                self.staleness_batches += t.staleness
                served += 1
        self.reads_served += served
        return served

    # ------------------------------------------------------------------ #
    # write path
    # ------------------------------------------------------------------ #
    def apply_batch(self, batch: UpdateBatch) -> BatchStats:
        """Serve due reads, then apply one update batch as a full version
        boundary (the undo pre-images are captured between the batch's plan
        and dispatch via the orchestrator's ``on_plan`` hook)."""
        self.serve_reads()
        t0 = time.perf_counter()
        captured: List[_UndoRecord] = []

        def on_plan(prep) -> None:
            # write_set resolves the plan's final-layer rows whatever
            # execution mode the orchestrator's policy chose; the hook is
            # never invoked for full-recompute batches (their pre-images
            # would be a whole-state copy) — those reset the history below
            rows = np.asarray(self._orch.write_set(prep), np.int64)
            captured.append(_UndoRecord(
                version=self.version + 1, rows=rows,
                vals=np.array(self._orch.backend.snapshot_rows(rows))))

        bs = self._orch.apply_batch(batch, block=True, on_plan=on_plan)
        self.version += 1
        orch = self._orch
        refreshed = (orch.refresh_every
                     and orch._batches_seen % orch.refresh_every == 0)
        if refreshed or bs.mode == "full":
            # a refresh — cadence-driven or policy-chosen full recompute —
            # rebuilt state from scratch: older versions are no longer
            # bitwise-reconstructible — drop the undo history
            self._undo.clear()
            self._floor = self.version
        else:
            self._undo.extend(captured)
            while len(self._undo) > self.max_versions:
                self._undo.pop(0)
                self._floor += 1
        self._wall_s += time.perf_counter() - t0
        self._plan_s += bs.plan_time_s
        self._batch_stats.append(bs)
        return bs

    def apply_window(self, batches: Sequence[UpdateBatch]) -> List[BatchStats]:
        """Serve due reads, then apply a fused *prefix* of ``batches``
        through :meth:`StreamOrchestrator.apply_window` (ISSUE 9): the
        orchestrator merges the maximal independent prefix into one device
        dispatch; the frontend still records **one version per logical
        batch**.  Pre-images are captured per constituent — in stream
        order, against the strictly pre-window state — which is exact
        because fused windows have pairwise-disjoint write sets (a row
        batch j writes is untouched by batches 0..j-1, so its pre-window
        value equals its post-batch-(j-1) value).  Returns the consumed
        batches' stats; ``len(result)`` tells the caller how far the
        stream advanced.  Falls back to plain serial single-batch behavior
        (bitwise, version-for-version) when fusion is off or the head
        batches overlap."""
        batches = list(batches)
        if not batches:
            return []
        self.serve_reads()
        t0 = time.perf_counter()
        captured: List[_UndoRecord] = []

        def on_plan(plan) -> None:
            # called once per constituent, before dispatch: version numbers
            # are assigned in stream order on top of the current version
            rows = np.asarray(self._orch.write_set(plan), np.int64)
            captured.append(_UndoRecord(
                version=self.version + 1 + len(captured), rows=rows,
                vals=np.array(self._orch.backend.snapshot_rows(rows))))

        out = self._orch.apply_window(batches, on_plan=on_plan)
        orch = self._orch
        ci = 0  # next captured pre-image (full-recompute batches skip one)
        for j, bs in enumerate(out):
            self.version += 1
            # _batches_seen already advanced by len(out); reconstruct this
            # constituent's post-batch count for the refresh-cadence check.
            # Fused windows never span a refresh boundary (the orchestrator
            # caps the window at it), so only the last constituent can land
            # on the cadence.
            seen = orch._batches_seen - (len(out) - 1 - j)
            refreshed = (orch.refresh_every
                         and seen % orch.refresh_every == 0)
            if refreshed or bs.mode == "full":
                self._undo.clear()
                self._floor = self.version
                if bs.mode != "full":
                    ci += 1  # captured, then invalidated by the refresh
            else:
                self._undo.append(captured[ci])
                ci += 1
                while len(self._undo) > self.max_versions:
                    self._undo.pop(0)
                    self._floor += 1
        self._wall_s += time.perf_counter() - t0
        self._plan_s += sum(bs.plan_time_s for bs in out)
        self._batch_stats.extend(out)
        return out

    def run_stream(self, batches: Sequence[UpdateBatch]) -> StreamStats:
        """Apply a whole update stream, serving reads between batches and
        draining the queue at the end.  When the engine was built with
        :class:`~repro.core.affected.FusionConfig`, consecutive independent
        batches are fused into shared device dispatches (ISSUE 9) — the
        version/consistency contract is unchanged: one version per logical
        batch, snapshot reads bitwise-equal to the serial path."""
        batches = list(batches)
        i = 0
        while i < len(batches):
            if self._orch._fusion_active():
                i += len(self.apply_window(batches[i:]))
            else:
                self.apply_batch(batches[i])
                i += 1
        self.drain()
        return self.stats()

    def drain(self) -> int:
        """Serve everything still pending (end-of-stream barrier)."""
        return self.serve_reads()

    # ------------------------------------------------------------------ #
    def stats(self) -> StreamStats:
        """The run so far as the repo's single result type."""
        lat = np.asarray(self._latencies, np.float64)
        orch = self._orch
        return StreamStats(
            batches=list(self._batch_stats),
            wall_s=self._wall_s,
            plan_s=self._plan_s,
            reads_served=self.reads_served,
            reads_rejected=self.reads_rejected,
            read_p50_s=float(np.percentile(lat, 50)) if lat.size else 0.0,
            read_p99_s=float(np.percentile(lat, 99)) if lat.size else 0.0,
            staleness_batches=self.staleness_batches,
            fusion_windows=orch.fusion_windows - self._fusion0[0],
            fused_batches=orch.fused_batches - self._fusion0[1],
            fusion_fallbacks=orch.fusion_fallbacks - self._fusion0[2],
        )
