"""THE public engine API: one config, one factory, five backends.

Five PRs of engine growth left four parallel constructor surfaces
(``RTECEngine``, ``OffloadedRTECEngine``, ``ShardedRTECEngine``,
``ShardedOffloadRTECEngine``) that every caller had to know individually.
This module redesigns that surface once, InkStream-style (one event-driven
interface over many models):

* :class:`EngineConfig` — a single dataclass naming every construction
  knob any backend understands (model/params/graph/features, the device
  flags, the typed :class:`~repro.serve.staging.StagingConfig` /
  :class:`~repro.serve.hotcache.CacheConfig` /
  :class:`~repro.dist.sharding.CommsConfig` sub-configs, the mesh/shard
  knobs, the chunk knobs, the execution-policy spec).  Knobs a backend
  does not consume are simply ignored by it, so one config can drive a
  backend sweep.  Loose knobs that predate the typed sub-configs
  (``use_pallas_delta``) survive as deprecated aliases that fold into
  them with a warning.
* :func:`create_engine` — ``create_engine(backend, config)`` for
  ``backend`` in :data:`BACKENDS`.  **This is the only documented
  constructor**: it owns the canonical backend + orchestrator assembly
  (including the ISSUE-8 device hot-row cache wiring), and the legacy
  ``*RTECEngine`` constructors are deprecated aliases that route through
  it — calling one emits :class:`DeprecationWarning` and produces an
  engine bitwise-equal to the factory path (pinned per backend by
  tests/test_hotcache.py).
* :class:`ChunkedRTECEngine` — facade for the §V-C chunked substrate
  (:class:`~repro.core.backend.ChunkedBackend`), constructible as
  ``backend="chunked"`` and covered by the cross-backend matrix.

:func:`serving_frontend` / :meth:`ServingFrontend <repro.serve.frontend.ServingFrontend>`
attaches the read/write serving layer to whatever the factory returns.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.affected import FusionConfig
from repro.core.backend import (
    BatchStats,
    ChunkedBackend,
    DeviceBackend,
    OffloadBackend,
    ShardBackend,
    ShardedOffloadBackend,
    StreamOrchestrator,
    StreamStats,
)
from repro.core.engine import RTECEngine
from repro.core.operators import GNNModel, Params
from repro.core.policy import DEFAULT_CHUNKED_WEIGHT, make_policy
from repro.core.sharded_engine import ShardedRTECEngine
from repro.dist.sharding import CommsConfig
from repro.graph.csr import CSRGraph
from repro.graph.streaming import UpdateBatch
from repro.serve.hotcache import CacheConfig, HotRowCache
from repro.serve.offload import OffloadedRTECEngine, ShardedOffloadRTECEngine
from repro.serve.staging import StagingConfig

#: every backend name `create_engine` accepts
BACKENDS: Tuple[str, ...] = (
    "device", "offload", "sharded", "sharded_offload", "chunked",
)


@dataclasses.dataclass
class EngineConfig:
    """Construction knobs for every streaming-engine backend.

    Required: ``model``, ``graph``, ``x``, and either ``params`` or
    ``dims`` (+ ``seed``) to initialize them.  Backend-specific knobs are
    ignored by backends that do not consume them (e.g. ``num_shards`` by
    ``backend="device"``, ``cache`` by everything that is not
    host-resident), so one config can drive a backend sweep."""

    model: GNNModel
    graph: CSRGraph
    x: np.ndarray
    params: Optional[Sequence[Params]] = None
    #: layer dims for parameter init when ``params`` is None, e.g. [16, 16]
    dims: Optional[Sequence[int]] = None
    seed: int = 0
    # shared orchestrator knob
    refresh_every: int = 0
    # device backend
    store_h: bool = True
    fused: bool = True
    #: deprecated — use ``comms=CommsConfig(use_pallas_delta=True)``.
    #: Kept as a routing alias: ``resolved_comms()`` folds it in (with a
    #: DeprecationWarning when set) so old configs stay bitwise-equal.
    use_pallas_delta: bool = False
    #: typed communication config (ISSUE 10): halo-exchange mode for the
    #: sharded backends ("psum" | "ppermute" | "auto"), the per-pair
    #: capacity hysteresis for the ppermute schedules, and the Pallas
    #: delta-aggregation kernel toggle folded in from the old loose knob.
    comms: Optional[CommsConfig] = None
    # host-resident backends: staging pipeline + device hot-row cache.
    # `staging=None` resolves to StagingConfig(async_enabled=async_staging)
    # (the legacy flag keeps working); an explicit StagingConfig wins.
    # `cache=None` (or CacheConfig(enabled=False)) runs uncached.
    async_staging: bool = True
    staging: Optional[StagingConfig] = None
    cache: Optional[CacheConfig] = None
    # mesh backends
    mesh: Optional[object] = None
    num_shards: Optional[int] = None
    shcfg: Optional[object] = None
    # chunked backend
    chunk_size: int = 8192
    chunk_reuse: bool = True
    # adaptive execution policy (ISSUE 7): None → every batch takes the
    # substrate's native incremental path (pre-policy behavior);
    # "adaptive" → per-batch cost-model selection over
    # incremental/chunked/full; a mode name forces that mode on every
    # batch; an ExecutionPolicy instance passes through as-is (shared
    # across engines built from this config — pass a spec string to give
    # each engine its own decision state)
    policy: object = None
    policy_chunked_weight: float = DEFAULT_CHUNKED_WEIGHT
    #: relative hysteresis band for policy mode switches (ISSUE 8): stay
    #: on the previous mode unless the best mode beats it by this margin
    policy_hysteresis: float = 0.0
    #: online cost-weight calibration (ISSUE 9): blend measured per-mode
    #: cost-per-unit EMAs into the static 2.0/1.5/1.0 weights.  Only
    #: meaningful with ``policy="adaptive"``; the static model stays the
    #: deterministic CI gate (default off).
    policy_calibrate: bool = False
    #: batch-window fusion (ISSUE 9): merge runs of consecutive batches
    #: whose plans have disjoint affected frontiers/write sets into one
    #: packed plan and one fused device step.  ``None`` (or
    #: ``FusionConfig(enabled=False)`` / ``window < 2``) keeps the serial
    #: per-batch loop, bit for bit.
    fusion: Optional[FusionConfig] = None

    def resolved_policy(self):
        return make_policy(self.policy,
                           chunked_weight=self.policy_chunked_weight,
                           hysteresis=self.policy_hysteresis,
                           calibrate=self.policy_calibrate)

    def resolved_staging(self) -> StagingConfig:
        if self.staging is not None:
            return self.staging
        return StagingConfig(async_enabled=self.async_staging)

    def resolved_cache(self) -> Optional[HotRowCache]:
        """A fresh :class:`HotRowCache` per engine (slot state is engine
        state), or None when caching is off."""
        if self.cache is None or not self.cache.enabled:
            return None
        return HotRowCache(self.cache)

    def resolved_comms(self) -> CommsConfig:
        """The typed :class:`~repro.dist.sharding.CommsConfig` this config
        resolves to.  An explicit ``comms`` wins; otherwise the legacy
        ``use_pallas_delta`` flag is folded into a default config (with a
        DeprecationWarning only when it was actually set — untouched
        configs stay warning-free)."""
        if self.comms is not None:
            return self.comms
        if self.use_pallas_delta:
            warnings.warn(
                "EngineConfig(use_pallas_delta=...) is deprecated; pass "
                "comms=CommsConfig(use_pallas_delta=True) instead",
                DeprecationWarning, stacklevel=3)
        return CommsConfig(use_pallas_delta=self.use_pallas_delta)

    def resolved_params(self) -> Sequence[Params]:
        if self.params is not None:
            return self.params
        if self.dims is None:
            raise ValueError("EngineConfig needs params or dims")
        return self.model.init_layers(jax.random.PRNGKey(self.seed),
                                      list(self.dims))


def _alias_deprecated(name: str) -> None:
    """Every legacy ``*RTECEngine`` constructor funnels through here."""
    warnings.warn(
        f"{name}(...) is a deprecated alias; construct engines with "
        f"repro.serve.create_engine(backend, EngineConfig(...)) instead",
        DeprecationWarning, stacklevel=3)


def _shell(cls, backend, orch):
    """Assemble a facade around an already-built backend + orchestrator
    without re-running the deprecated alias ``__init__``."""
    eng = object.__new__(cls)
    eng._backend = backend
    eng._orch = orch
    return eng


class ChunkedRTECEngine:
    """Facade for the chunked-recompute substrate
    (:class:`~repro.core.backend.ChunkedBackend`): host-resident state,
    per-batch execution through the §V-C
    :class:`~repro.serve.scheduler.ChunkedLayerScheduler` so device
    residency is bounded by ``chunk_size``.  Output matches the incremental
    engines to numerical tolerance (recompute vs. incremental
    accumulation)."""

    def __init__(self, model: GNNModel, params: Sequence[Params],
                 graph: CSRGraph, x: np.ndarray, chunk_size: int = 8192,
                 chunk_reuse: bool = True, refresh_every: int = 0,
                 policy=None):
        # deprecated alias (kept for back-compat): route through the factory
        _alias_deprecated("ChunkedRTECEngine")
        eng = create_engine("chunked", EngineConfig(
            model=model, graph=graph, x=x, params=params,
            chunk_size=chunk_size, chunk_reuse=chunk_reuse,
            refresh_every=refresh_every, policy=policy))
        self._backend, self._orch = eng._backend, eng._orch

    def apply_batch(self, batch: UpdateBatch, block: bool = True) -> BatchStats:
        return self._orch.apply_batch(batch, block=block)

    def apply_stream(self, batches: Sequence[UpdateBatch]) -> StreamStats:
        return self._orch.apply_stream(batches)

    def refresh(self) -> None:
        self._orch.refresh()

    def snapshot_rows(self, rows) -> np.ndarray:
        """Host gather of final-layer embedding rows (consistent after a
        blocking ``apply_batch``)."""
        return self._backend.snapshot_rows(rows)

    def serving_frontend(self, max_pending_reads: int = 64,
                         max_versions: int = 8):
        """A :class:`~repro.serve.frontend.ServingFrontend` over this
        engine: update-batch writes + embedding reads pinned to versions."""
        return serving_frontend(self, max_pending_reads=max_pending_reads,
                                max_versions=max_versions)

    @property
    def model(self) -> GNNModel:
        return self._backend.model

    @property
    def params(self):
        return self._backend.params

    @property
    def L(self) -> int:
        return self._backend.L

    @property
    def graph(self) -> CSRGraph:
        return self._orch.graph

    @graph.setter
    def graph(self, g: CSRGraph) -> None:
        self._orch.graph = g

    @property
    def chunk_stats(self):
        """Chunk/transfer/reuse counters (ChunkStats; benchmarks/fig10)."""
        return self._backend.scheduler.stats

    @property
    def x(self) -> np.ndarray:
        return self._backend.x

    @property
    def h(self):
        return self._backend.h

    @property
    def a(self):
        return self._backend.a

    @property
    def nct(self):
        return self._backend.nct

    @property
    def embeddings(self) -> np.ndarray:
        return self._backend.embeddings

    def state_bytes(self) -> int:
        return self._backend.state_bytes()

    def _sync_arrays(self):
        return self._backend.sync_arrays()


def create_engine(backend: str, config: EngineConfig):
    """Construct a streaming engine for ``backend`` from one config.

    ``backend`` ∈ :data:`BACKENDS`.  This is the canonical (and only
    documented) construction path: it builds the
    :class:`~repro.core.backend.StateBackend` substrate — threading the
    staging pipeline and device hot-row cache knobs through to the
    host-resident ones — wraps it in a
    :class:`~repro.core.backend.StreamOrchestrator`, and returns the
    matching facade.  The legacy ``*RTECEngine`` constructors are
    deprecated aliases of this function (bitwise-equal by construction)."""
    params = config.resolved_params()
    policy = config.resolved_policy()
    staging = config.resolved_staging()
    comms = config.resolved_comms()
    if backend == "device":
        sb = DeviceBackend(
            config.model, params, config.graph, jnp.asarray(config.x),
            store_h=config.store_h, fused=config.fused,
            use_pallas_delta=comms.use_pallas_delta,
        )
        cls = RTECEngine
    elif backend == "offload":
        sb = OffloadBackend(
            config.model, params, config.graph, config.x,
            async_staging=staging.async_enabled,
            cache=config.resolved_cache(), staging_depth=staging.depth,
        )
        cls = OffloadedRTECEngine
    elif backend == "sharded":
        sb = ShardBackend(
            config.model, params, config.graph, config.x, mesh=config.mesh,
            num_shards=config.num_shards, shcfg=config.shcfg,
            comms=comms,
        )
        cls = ShardedRTECEngine
    elif backend == "sharded_offload":
        sb = ShardedOffloadBackend(
            config.model, params, config.graph, config.x, mesh=config.mesh,
            num_shards=config.num_shards, shcfg=config.shcfg,
            async_staging=staging.async_enabled,
            cache=config.resolved_cache(), staging_depth=staging.depth,
            comms=comms,
        )
        cls = ShardedOffloadRTECEngine
    elif backend == "chunked":
        sb = ChunkedBackend(
            config.model, params, config.graph, config.x,
            chunk_size=config.chunk_size, chunk_reuse=config.chunk_reuse,
        )
        cls = ChunkedRTECEngine
    else:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {BACKENDS}")
    orch = StreamOrchestrator(sb, config.graph,
                              refresh_every=config.refresh_every,
                              policy=policy, fusion=config.fusion)
    return _shell(cls, sb, orch)


def serving_frontend(engine, max_pending_reads: int = 64,
                     max_versions: int = 8):
    """Attach a :class:`~repro.serve.frontend.ServingFrontend` to an engine
    (anything :func:`create_engine` returns, or a raw orchestrator)."""
    from repro.serve.frontend import ServingFrontend

    return ServingFrontend(engine, max_pending_reads=max_pending_reads,
                           max_versions=max_versions)
