"""Unified public engine API: one config, one factory, five backends.

Five PRs of engine growth left four parallel constructor surfaces
(``RTECEngine``, ``OffloadedRTECEngine``, ``ShardedRTECEngine``,
``ShardedOffloadRTECEngine``) that every caller had to know individually.
This module redesigns that surface once, InkStream-style (one event-driven
interface over many models):

* :class:`EngineConfig` — a single dataclass naming every construction
  knob any backend understands (model/params/graph/features, the device
  flags, the async-staging flag, the mesh/shard knobs, the chunk knobs).
  Knobs a backend does not consume are simply ignored by it, so one config
  can drive a backend sweep.
* :func:`create_engine` — ``create_engine(backend, config)`` for
  ``backend`` in :data:`BACKENDS`.  The factory calls the *same*
  constructors as direct instantiation — no extra wrapping — so factory
  construction is bitwise-equal to the legacy path (pinned by
  tests/test_frontend.py).
* :class:`ChunkedRTECEngine` — public facade for the §V-C chunked
  substrate (:class:`~repro.core.backend.ChunkedBackend`), previously dead
  code behind ``repro.serve.scheduler``; now constructible as
  ``backend="chunked"`` and covered by the cross-backend matrix.

The legacy engine classes remain as thin back-compat facades; this factory
is the recommended entry point, and
:func:`serving_frontend` / :meth:`ServingFrontend <repro.serve.frontend.ServingFrontend>`
attaches the read/write serving layer to whatever it returns.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.backend import (
    BatchStats,
    ChunkedBackend,
    StreamOrchestrator,
    StreamStats,
)
from repro.core.engine import RTECEngine
from repro.core.operators import GNNModel, Params
from repro.core.policy import DEFAULT_CHUNKED_WEIGHT, make_policy
from repro.core.sharded_engine import ShardedRTECEngine
from repro.graph.csr import CSRGraph
from repro.graph.streaming import UpdateBatch
from repro.serve.offload import OffloadedRTECEngine, ShardedOffloadRTECEngine

#: every backend name `create_engine` accepts
BACKENDS: Tuple[str, ...] = (
    "device", "offload", "sharded", "sharded_offload", "chunked",
)


@dataclasses.dataclass
class EngineConfig:
    """Construction knobs for every streaming-engine backend.

    Required: ``model``, ``graph``, ``x``, and either ``params`` or
    ``dims`` (+ ``seed``) to initialize them.  Backend-specific knobs are
    ignored by backends that do not consume them (e.g. ``num_shards`` by
    ``backend="device"``), so one config can drive a backend sweep."""

    model: GNNModel
    graph: CSRGraph
    x: np.ndarray
    params: Optional[Sequence[Params]] = None
    #: layer dims for parameter init when ``params`` is None, e.g. [16, 16]
    dims: Optional[Sequence[int]] = None
    seed: int = 0
    # shared orchestrator knob
    refresh_every: int = 0
    # device backend
    store_h: bool = True
    fused: bool = True
    use_pallas_delta: bool = False
    # host-resident backends
    async_staging: bool = True
    # mesh backends
    mesh: Optional[object] = None
    num_shards: Optional[int] = None
    shcfg: Optional[object] = None
    # chunked backend
    chunk_size: int = 8192
    chunk_reuse: bool = True
    # adaptive execution policy (ISSUE 7): None → every batch takes the
    # substrate's native incremental path (pre-policy behavior);
    # "adaptive" → per-batch cost-model selection over
    # incremental/chunked/full; a mode name forces that mode on every
    # batch; an ExecutionPolicy instance passes through as-is (shared
    # across engines built from this config — pass a spec string to give
    # each engine its own decision state)
    policy: object = None
    policy_chunked_weight: float = DEFAULT_CHUNKED_WEIGHT

    def resolved_policy(self):
        return make_policy(self.policy,
                           chunked_weight=self.policy_chunked_weight)

    def resolved_params(self) -> Sequence[Params]:
        if self.params is not None:
            return self.params
        if self.dims is None:
            raise ValueError("EngineConfig needs params or dims")
        return self.model.init_layers(jax.random.PRNGKey(self.seed),
                                      list(self.dims))


class ChunkedRTECEngine:
    """Facade for the chunked-recompute substrate
    (:class:`~repro.core.backend.ChunkedBackend`): host-resident state,
    per-batch execution through the §V-C
    :class:`~repro.serve.scheduler.ChunkedLayerScheduler` so device
    residency is bounded by ``chunk_size``.  Output matches the incremental
    engines to numerical tolerance (recompute vs. incremental
    accumulation)."""

    def __init__(self, model: GNNModel, params: Sequence[Params],
                 graph: CSRGraph, x: np.ndarray, chunk_size: int = 8192,
                 chunk_reuse: bool = True, refresh_every: int = 0,
                 policy=None):
        self._backend = ChunkedBackend(model, params, graph, x,
                                       chunk_size=chunk_size,
                                       chunk_reuse=chunk_reuse)
        self._orch = StreamOrchestrator(self._backend, graph,
                                        refresh_every=refresh_every,
                                        policy=policy)

    def apply_batch(self, batch: UpdateBatch, block: bool = True) -> BatchStats:
        return self._orch.apply_batch(batch, block=block)

    def apply_stream(self, batches: Sequence[UpdateBatch]) -> StreamStats:
        return self._orch.apply_stream(batches)

    def refresh(self) -> None:
        self._orch.refresh()

    def snapshot_rows(self, rows) -> np.ndarray:
        """Host gather of final-layer embedding rows (consistent after a
        blocking ``apply_batch``)."""
        return self._backend.snapshot_rows(rows)

    def serving_frontend(self, max_pending_reads: int = 64,
                         max_versions: int = 8):
        """A :class:`~repro.serve.frontend.ServingFrontend` over this
        engine: update-batch writes + embedding reads pinned to versions."""
        return serving_frontend(self, max_pending_reads=max_pending_reads,
                                max_versions=max_versions)

    @property
    def model(self) -> GNNModel:
        return self._backend.model

    @property
    def params(self):
        return self._backend.params

    @property
    def L(self) -> int:
        return self._backend.L

    @property
    def graph(self) -> CSRGraph:
        return self._orch.graph

    @graph.setter
    def graph(self, g: CSRGraph) -> None:
        self._orch.graph = g

    @property
    def chunk_stats(self):
        """Chunk/transfer/reuse counters (ChunkStats; benchmarks/fig10)."""
        return self._backend.scheduler.stats

    @property
    def x(self) -> np.ndarray:
        return self._backend.x

    @property
    def h(self):
        return self._backend.h

    @property
    def a(self):
        return self._backend.a

    @property
    def nct(self):
        return self._backend.nct

    @property
    def embeddings(self) -> np.ndarray:
        return self._backend.embeddings

    def state_bytes(self) -> int:
        return self._backend.state_bytes()

    def _sync_arrays(self):
        return self._backend.sync_arrays()


def create_engine(backend: str, config: EngineConfig):
    """Construct a streaming engine for ``backend`` from one config.

    ``backend`` ∈ :data:`BACKENDS`.  Calls the same constructors as direct
    instantiation, so the result is bitwise-equal to the legacy path."""
    params = config.resolved_params()
    policy = config.resolved_policy()
    if backend == "device":
        return RTECEngine(
            config.model, params, config.graph, config.x,
            store_h=config.store_h, refresh_every=config.refresh_every,
            fused=config.fused, use_pallas_delta=config.use_pallas_delta,
            policy=policy,
        )
    if backend == "offload":
        return OffloadedRTECEngine(
            config.model, params, config.graph, config.x,
            async_staging=config.async_staging, policy=policy,
        )
    if backend == "sharded":
        return ShardedRTECEngine(
            config.model, params, config.graph, config.x, mesh=config.mesh,
            num_shards=config.num_shards, shcfg=config.shcfg,
            refresh_every=config.refresh_every,
            use_pallas_delta=config.use_pallas_delta,
            policy=policy,
        )
    if backend == "sharded_offload":
        return ShardedOffloadRTECEngine(
            config.model, params, config.graph, config.x, mesh=config.mesh,
            num_shards=config.num_shards, shcfg=config.shcfg,
            refresh_every=config.refresh_every,
            async_staging=config.async_staging,
            policy=policy,
        )
    if backend == "chunked":
        return ChunkedRTECEngine(
            config.model, params, config.graph, config.x,
            chunk_size=config.chunk_size, chunk_reuse=config.chunk_reuse,
            refresh_every=config.refresh_every, policy=policy,
        )
    raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")


def serving_frontend(engine, max_pending_reads: int = 64,
                     max_versions: int = 8):
    """Attach a :class:`~repro.serve.frontend.ServingFrontend` to an engine
    (anything :func:`create_engine` returns, or a raw orchestrator)."""
    from repro.serve.frontend import ServingFrontend

    return ServingFrontend(engine, max_pending_reads=max_pending_reads,
                           max_versions=max_versions)
