"""Out-of-memory embedding management (paper §V-B) — TPU/JAX realization.

NeutronRT offloads intermediate embeddings to CPU memory and reads sparse
rows with GPU-directed zero-copy.  The JAX equivalent keeps the per-layer
state (h, a, nct) as **host numpy** and, per update batch, transfers only the
*compact row sets the plan touches* to the device, runs the same
`incremental_layer` kernel over compact arrays (the kernel is index-based,
so a compact view with remapped indices is exactly equivalent), and groups
all write-backs (the paper's "group all updated embeddings and write them
back in parallel").  Transfer accounting mirrors the paper's access-volume
metrics.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.affected import LayerPlan, build_plan
from repro.core.engine import BatchStats
from repro.core.full import full_forward
from repro.core.incremental import incremental_layer, with_scratch
from repro.core.operators import GNNModel, Params
from repro.graph.csr import CSRGraph
from repro.graph.streaming import UpdateBatch


@dataclasses.dataclass
class TransferStats:
    rows_up: int = 0
    rows_down: int = 0
    bytes_up: int = 0
    bytes_down: int = 0


def _remap(indices: np.ndarray, rows: np.ndarray, n_compact: int, scratch: int) -> np.ndarray:
    """Map global vertex ids → compact positions; scratch id → n_compact."""
    lut = np.full(scratch + 1, n_compact, np.int32)
    if rows.size:
        lut[rows] = np.arange(rows.shape[0], dtype=np.int32)
    return lut[np.asarray(indices, np.int64)]


def _override_rows(dst_vals: np.ndarray, dst_rows: np.ndarray,
                   src_rows: np.ndarray, src_vals: np.ndarray) -> None:
    """dst_vals[i] ← src_vals[j] where dst_rows[i] == src_rows[j] (vectorized)."""
    if not src_rows.size or not dst_rows.size:
        return
    order = np.argsort(src_rows)
    pos = np.searchsorted(src_rows[order], dst_rows)
    pos = np.clip(pos, 0, src_rows.size - 1)
    hit = src_rows[order][pos] == dst_rows
    dst_vals[hit] = src_vals[order][pos[hit]]


class OffloadedRTECEngine:
    """Incremental RTEC with host-resident state (CPU-offload engine)."""

    def __init__(self, model: GNNModel, params: Sequence[Params], graph: CSRGraph,
                 x: np.ndarray):
        self.model = model
        self.params = list(params)
        self.L = len(params)
        self.graph = graph
        self.x = np.asarray(x, np.float32)
        self.transfers = TransferStats()
        states = full_forward(model, params, jnp.asarray(self.x), graph)
        self.h: List[np.ndarray] = [self.x.copy()] + [np.array(s.h) for s in states]
        self.a: List[np.ndarray] = [np.array(s.a) for s in states]
        self.nct: List[np.ndarray] = [np.array(s.nct) for s in states]

    @property
    def embeddings(self) -> np.ndarray:
        return self.h[-1]

    def state_bytes(self) -> int:
        return (sum(a.nbytes for a in self.a) + sum(c.nbytes for c in self.nct)
                + sum(h.nbytes for h in self.h))

    # ------------------------------------------------------------------ #
    def apply_batch(self, batch: UpdateBatch) -> BatchStats:
        t0 = time.perf_counter()
        g_new = self.graph.apply_updates(
            batch.ins_src, batch.ins_dst, batch.del_src, batch.del_dst,
            batch.ins_weights, batch.ins_etypes,
        )
        t1 = time.perf_counter()
        plan = build_plan(self.model, self.graph, g_new, batch, self.L)
        t2 = time.perf_counter()

        n = self.graph.n
        deg_old_np = plan.deg_old
        deg_new_np = plan.deg_new

        # layer-0 feature updates: keep old values for the delta pass
        if batch.feat_vertices is not None and batch.feat_vertices.size:
            prev_rows = np.asarray(batch.feat_vertices, np.int64)
            prev_old = self.h[0][prev_rows].copy()
            self.h[0][prev_rows] = batch.feat_values
        else:
            prev_rows = np.zeros(0, np.int64)
            prev_old = np.zeros((0, self.h[0].shape[1]), np.float32)

        for l, lp in enumerate(plan.layers):
            prev_rows, prev_old = self._layer(
                l, lp, deg_old_np, deg_new_np, prev_rows, prev_old, n
            )
        self.graph = g_new
        t3 = time.perf_counter()
        return BatchStats(
            inc_edges=plan.total_inc_edges(), full_edges=plan.total_full_edges(),
            out_vertices=plan.total_vertices(), plan_time_s=t2 - t1,
            exec_time_s=t3 - t2, graph_time_s=t1 - t0,
        )

    # ------------------------------------------------------------------ #
    def _layer(self, l: int, lp: LayerPlan, deg_old_np, deg_new_np,
               prev_rows: np.ndarray, prev_old: np.ndarray, n: int):
        need_h = np.unique(np.concatenate([
            lp.e_src[lp.e_mask].astype(np.int64),
            lp.e_dst[lp.e_mask].astype(np.int64),
            lp.f_src[lp.f_emask].astype(np.int64),
            lp.f_rows[lp.f_mask].astype(np.int64),
            lp.out_rows[lp.out_mask].astype(np.int64),
            prev_rows,
        ]))
        srows = lp.out_rows[lp.out_mask].astype(np.int64)  # = touch ∪ full ∪ carried
        nh, ns = need_h.shape[0], srows.shape[0]
        out_old = self.h[l + 1][srows].copy() if ns else np.zeros((0, self.h[l + 1].shape[1]), np.float32)
        if nh == 0 and ns == 0:
            return srows, out_old

        h_prev = self.h[l]
        h_new_rows = h_prev[need_h]  # host already holds the NEW h^{l-1}
        h_old_rows = h_new_rows.copy()
        _override_rows(h_old_rows, need_h, prev_rows, prev_old)

        a_rows = self.a[l][srows]
        nct_rows = self.nct[l][srows]
        h_cur_rows = self.h[l + 1][srows]

        self.transfers.rows_up += 2 * nh + 3 * ns
        self.transfers.bytes_up += 2 * h_new_rows.nbytes + a_rows.nbytes + nct_rows.nbytes + h_cur_rows.nbytes

        e_src = _remap(lp.e_src, need_h, nh, n)
        e_dst = _remap(lp.e_dst, need_h, nh, n)
        f_src = _remap(lp.f_src, need_h, nh, n)
        touch_rows_s = _remap(lp.touch_rows, srows, ns, n)
        f_rows_s = _remap(lp.f_rows, srows, ns, n)
        out_rows_s = _remap(lp.out_rows, srows, ns, n)
        f_rows_h = _remap(lp.f_rows, need_h, nh, n)
        out_rows_h = _remap(lp.out_rows, need_h, nh, n)

        deg_old_rows = np.concatenate([deg_old_np[need_h], [0.0]]).astype(np.float32)
        deg_new_rows = np.concatenate([deg_new_np[need_h], [0.0]]).astype(np.float32)

        a_new, nct_new, h_new = incremental_layer(
            self.model, self.params[l],
            with_scratch(jnp.asarray(h_old_rows)), with_scratch(jnp.asarray(h_new_rows)),
            jnp.asarray(deg_old_rows), jnp.asarray(deg_new_rows),
            jnp.asarray(a_rows), jnp.asarray(nct_rows), jnp.asarray(h_cur_rows),
            jnp.asarray(e_src), jnp.asarray(e_dst), jnp.asarray(lp.e_rowidx),
            jnp.asarray(lp.e_sign), jnp.asarray(lp.e_use_new), jnp.asarray(lp.e_w),
            jnp.asarray(lp.e_t), jnp.asarray(lp.e_mask),
            jnp.asarray(touch_rows_s), jnp.asarray(lp.touch_mask),
            jnp.asarray(f_rows_s), jnp.asarray(lp.f_mask),
            jnp.asarray(f_src), jnp.asarray(lp.f_rowidx), jnp.asarray(lp.f_w),
            jnp.asarray(lp.f_t), jnp.asarray(lp.f_emask),
            jnp.asarray(out_rows_s), jnp.asarray(lp.out_mask),
            f_rows_h=jnp.asarray(f_rows_h), out_rows_h=jnp.asarray(out_rows_h),
        )

        # grouped parallel write-back
        self.a[l][srows] = np.asarray(a_new)
        self.nct[l][srows] = np.asarray(nct_new)
        self.h[l + 1][srows] = np.asarray(h_new)
        self.transfers.rows_down += 3 * ns
        self.transfers.bytes_down += int(np.asarray(a_new).nbytes + np.asarray(nct_new).nbytes + np.asarray(h_new).nbytes)
        return srows, out_old
