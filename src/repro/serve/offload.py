"""Out-of-memory embedding management (paper §V-B) — TPU/JAX realization.

NeutronRT offloads intermediate embeddings to CPU memory and reads sparse
rows with GPU-directed zero-copy.  The JAX equivalent keeps the per-layer
state (h, a, nct) as **host numpy** and, per update batch, transfers only the
*compact row sets the plan touches* to the device, runs the same
`incremental_layer` kernel over compact arrays (the kernel is index-based,
so a compact view with remapped indices is exactly equivalent), and groups
all write-backs (the paper's "group all updated embeddings and write them
back in parallel").  Transfer accounting mirrors the paper's access-volume
metrics.

This engine reuses the pipelined in-memory engine's machinery:

* **Packed per-layer transfer** — every layer's compact arrays ship in one
  ``jax.device_put`` call (a single batched transfer) instead of ~27
  individual ``jnp.asarray`` H2D round trips.
* **Plan-time remap tables** — all index remapping is value-independent, so
  it is precomputed from the plan for every layer up front (off the exec
  critical path).
* **Plan/execute overlap** — :meth:`apply_stream` defers the final layer's
  grouped write-back so Alg.-4 planning of batch t+1 runs on the host while
  the device still executes batch t's last layer.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.affected import BatchPlan, LayerPlan, build_plan
from repro.core.engine import BatchStats
from repro.core.full import full_forward
from repro.core.incremental import incremental_layer, with_scratch
from repro.core.operators import GNNModel, Params
from repro.graph.csr import CSRGraph
from repro.graph.streaming import UpdateBatch


@dataclasses.dataclass
class TransferStats:
    rows_up: int = 0
    rows_down: int = 0
    bytes_up: int = 0
    bytes_down: int = 0

    @property
    def total_rows(self) -> int:
        """H2D+D2H row volume — deterministic (no timing noise), so the CI
        perf gate can bound it tightly (benchmarks/check_regression.py)."""
        return self.rows_up + self.rows_down


def _remap(indices: np.ndarray, rows: np.ndarray, n_compact: int, scratch: int) -> np.ndarray:
    """Map global vertex ids → compact positions; scratch id → n_compact."""
    lut = np.full(scratch + 1, n_compact, np.int32)
    if rows.size:
        lut[rows] = np.arange(rows.shape[0], dtype=np.int32)
    return lut[np.asarray(indices, np.int64)]


def _override_rows(dst_vals: np.ndarray, dst_rows: np.ndarray,
                   src_rows: np.ndarray, src_vals: np.ndarray) -> None:
    """dst_vals[i] ← src_vals[j] where dst_rows[i] == src_rows[j] (vectorized)."""
    if not src_rows.size or not dst_rows.size:
        return
    order = np.argsort(src_rows)
    pos = np.searchsorted(src_rows[order], dst_rows)
    pos = np.clip(pos, 0, src_rows.size - 1)
    hit = src_rows[order][pos] == dst_rows
    dst_vals[hit] = src_vals[order][pos[hit]]


@dataclasses.dataclass
class _LayerTransfer:
    """Plan-time (value-independent) compact transfer tables for one layer."""

    need_h: np.ndarray  # global ids of h^{l-1} rows the device needs
    srows: np.ndarray  # global ids of state rows updated (= out_rows live)
    e_src: np.ndarray  # remapped into need_h space
    e_dst: np.ndarray
    f_src: np.ndarray
    touch_rows_s: np.ndarray  # remapped into srows space
    f_rows_s: np.ndarray
    out_rows_s: np.ndarray
    f_rows_h: np.ndarray  # remapped into need_h space
    out_rows_h: np.ndarray
    deg_old_rows: np.ndarray  # [nh+1] compact degree tables (scratch slot)
    deg_new_rows: np.ndarray


@dataclasses.dataclass
class _Prepared:
    """Host-side output of the planning phase for one batch."""

    g_new: CSRGraph
    plan: BatchPlan
    transfers: List[_LayerTransfer]
    plan_time_s: float
    graph_time_s: float


class OffloadedRTECEngine:
    """Incremental RTEC with host-resident state (CPU-offload engine)."""

    def __init__(self, model: GNNModel, params: Sequence[Params], graph: CSRGraph,
                 x: np.ndarray):
        self.model = model
        self.params = list(params)
        self.L = len(params)
        self.graph = graph
        self.x = np.asarray(x, np.float32)
        self.transfers = TransferStats()
        states = full_forward(model, params, jnp.asarray(self.x), graph)
        self.h: List[np.ndarray] = [self.x.copy()] + [np.array(s.h) for s in states]
        self.a: List[np.ndarray] = [np.array(s.a) for s in states]
        self.nct: List[np.ndarray] = [np.array(s.nct) for s in states]

    @property
    def embeddings(self) -> np.ndarray:
        return self.h[-1]

    def state_bytes(self) -> int:
        return (sum(a.nbytes for a in self.a) + sum(c.nbytes for c in self.nct)
                + sum(h.nbytes for h in self.h))

    # ------------------------------------------------------------------ #
    # planning phase (host only, value-independent)
    # ------------------------------------------------------------------ #
    def _prepare(self, batch: UpdateBatch) -> _Prepared:
        t0 = time.perf_counter()
        g_new = self.graph.apply_updates(
            batch.ins_src, batch.ins_dst, batch.del_src, batch.del_dst,
            batch.ins_weights, batch.ins_etypes,
        )
        t1 = time.perf_counter()
        plan = build_plan(self.model, self.graph, g_new, batch, self.L)
        n = self.graph.n
        prev_rows = (
            np.asarray(batch.feat_vertices, np.int64)
            if batch.feat_vertices is not None and batch.feat_vertices.size
            else np.zeros(0, np.int64)
        )
        transfers: List[_LayerTransfer] = []
        for lp in plan.layers:
            need_h = np.unique(np.concatenate([
                lp.e_src[lp.e_mask].astype(np.int64),
                lp.e_dst[lp.e_mask].astype(np.int64),
                lp.f_src[lp.f_emask].astype(np.int64),
                lp.f_rows[lp.f_mask].astype(np.int64),
                lp.out_rows[lp.out_mask].astype(np.int64),
                prev_rows,
            ]))
            srows = lp.out_rows[lp.out_mask].astype(np.int64)
            nh, ns = need_h.shape[0], srows.shape[0]
            transfers.append(_LayerTransfer(
                need_h=need_h,
                srows=srows,
                e_src=_remap(lp.e_src, need_h, nh, n),
                e_dst=_remap(lp.e_dst, need_h, nh, n),
                f_src=_remap(lp.f_src, need_h, nh, n),
                touch_rows_s=_remap(lp.touch_rows, srows, ns, n),
                f_rows_s=_remap(lp.f_rows, srows, ns, n),
                out_rows_s=_remap(lp.out_rows, srows, ns, n),
                f_rows_h=_remap(lp.f_rows, need_h, nh, n),
                out_rows_h=_remap(lp.out_rows, need_h, nh, n),
                deg_old_rows=np.concatenate(
                    [plan.deg_old[need_h], [0.0]]).astype(np.float32),
                deg_new_rows=np.concatenate(
                    [plan.deg_new[need_h], [0.0]]).astype(np.float32),
            ))
            prev_rows = srows
        t2 = time.perf_counter()
        return _Prepared(g_new=g_new, plan=plan, transfers=transfers,
                         plan_time_s=t2 - t1, graph_time_s=t1 - t0)

    # ------------------------------------------------------------------ #
    def apply_batch(self, batch: UpdateBatch) -> BatchStats:
        prep = self._prepare(batch)
        t0 = time.perf_counter()
        pending = self._execute(prep, batch)
        self._writeback(pending)
        t1 = time.perf_counter()
        return BatchStats(
            inc_edges=prep.plan.total_inc_edges(),
            full_edges=prep.plan.total_full_edges(),
            out_vertices=prep.plan.total_vertices(),
            plan_time_s=prep.plan_time_s,
            exec_time_s=t1 - t0,
            graph_time_s=prep.graph_time_s,
        )

    def apply_stream(self, batches: Sequence[UpdateBatch]) -> List[BatchStats]:
        """Plan/execute overlap for the offload path: batch t's final layer
        executes on device while batch t+1's plan + remap tables build on
        the host; the deferred grouped write-back is the sync point."""
        batches = list(batches)
        out: List[BatchStats] = []
        if not batches:
            return out
        prep = self._prepare(batches[0])
        for i, b in enumerate(batches):
            t0 = time.perf_counter()
            pending = self._execute(prep, b)
            t1 = time.perf_counter()
            next_prep = self._prepare(batches[i + 1]) if i + 1 < len(batches) else None
            t2 = time.perf_counter()
            self._writeback(pending)  # sync point: device → host
            t3 = time.perf_counter()
            out.append(BatchStats(
                inc_edges=prep.plan.total_inc_edges(),
                full_edges=prep.plan.total_full_edges(),
                out_vertices=prep.plan.total_vertices(),
                plan_time_s=prep.plan_time_s,
                # exclude [t1, t2]: that is batch t+1's planning (reported in
                # its own plan_time_s), overlapped with device execution here
                exec_time_s=(t1 - t0) + (t3 - t2),
                graph_time_s=prep.graph_time_s,
            ))
            prep = next_prep
        return out

    # ------------------------------------------------------------------ #
    def _execute(self, prep: _Prepared, batch: UpdateBatch):
        """Run all layers; returns the final layer's pending write-back."""
        # layer-0 feature updates: keep old values for the delta pass
        if batch.feat_vertices is not None and batch.feat_vertices.size:
            prev_rows = np.asarray(batch.feat_vertices, np.int64)
            prev_old = self.h[0][prev_rows].copy()
            self.h[0][prev_rows] = batch.feat_values
        else:
            prev_rows = np.zeros(0, np.int64)
            prev_old = np.zeros((0, self.h[0].shape[1]), np.float32)

        pending = None
        for l, (lp, tr) in enumerate(zip(prep.plan.layers, prep.transfers)):
            if pending is not None:
                prev_rows, prev_old = self._writeback(pending)
            pending = self._layer_dispatch(l, lp, tr, prev_rows, prev_old)
        self.graph = prep.g_new
        return pending

    def _layer_dispatch(self, l: int, lp: LayerPlan, tr: _LayerTransfer,
                        prev_rows: np.ndarray, prev_old: np.ndarray):
        """Gather compact host rows, ship them in ONE device_put, dispatch."""
        need_h, srows = tr.need_h, tr.srows
        nh, ns = need_h.shape[0], srows.shape[0]
        out_old = (self.h[l + 1][srows].copy() if ns
                   else np.zeros((0, self.h[l + 1].shape[1]), np.float32))
        if nh == 0 and ns == 0:
            return (l, srows, out_old, None)

        h_new_rows = self.h[l][need_h]  # host already holds the NEW h^{l-1}
        h_old_rows = h_new_rows.copy()
        _override_rows(h_old_rows, need_h, prev_rows, prev_old)

        a_rows = self.a[l][srows]
        nct_rows = self.nct[l][srows]
        h_cur_rows = self.h[l + 1][srows]

        self.transfers.rows_up += 2 * nh + 3 * ns
        self.transfers.bytes_up += (2 * h_new_rows.nbytes + a_rows.nbytes
                                    + nct_rows.nbytes + h_cur_rows.nbytes)

        # one batched H2D transfer for the whole layer (packed-plan analogue)
        dev = jax.device_put((
            h_old_rows, h_new_rows, tr.deg_old_rows, tr.deg_new_rows,
            a_rows, nct_rows, h_cur_rows,
            tr.e_src, tr.e_dst, lp.e_rowidx, lp.e_sign, lp.e_use_new,
            lp.e_w, lp.e_t, lp.e_mask,
            tr.touch_rows_s, lp.touch_mask,
            tr.f_rows_s, lp.f_mask, tr.f_src, lp.f_rowidx, lp.f_w,
            lp.f_t, lp.f_emask,
            tr.out_rows_s, lp.out_mask, tr.f_rows_h, tr.out_rows_h,
        ))
        (h_old_d, h_new_d, deg_old_d, deg_new_d, a_d, nct_d, h_cur_d,
         e_src, e_dst, e_rowidx, e_sign, e_use_new, e_w, e_t, e_mask,
         touch_rows_s, touch_mask, f_rows_s, f_mask, f_src, f_rowidx, f_w,
         f_t, f_emask, out_rows_s, out_mask, f_rows_h, out_rows_h) = dev

        outs = incremental_layer(
            self.model, self.params[l],
            with_scratch(h_old_d), with_scratch(h_new_d),
            deg_old_d, deg_new_d, a_d, nct_d, h_cur_d,
            e_src, e_dst, e_rowidx, e_sign, e_use_new, e_w, e_t, e_mask,
            touch_rows_s, touch_mask,
            f_rows_s, f_mask, f_src, f_rowidx, f_w, f_t, f_emask,
            out_rows_s, out_mask,
            f_rows_h=f_rows_h, out_rows_h=out_rows_h,
        )
        return (l, srows, out_old, outs)

    def _writeback(self, pending) -> Tuple[np.ndarray, np.ndarray]:
        """Grouped parallel write-back (device sync point); returns the
        (rows, old values) pair the next layer's delta pass needs."""
        l, srows, out_old, outs = pending
        if outs is None:
            return srows, out_old
        a_new, nct_new, h_new = (np.asarray(o) for o in outs)
        self.a[l][srows] = a_new
        self.nct[l][srows] = nct_new
        self.h[l + 1][srows] = h_new
        self.transfers.rows_down += 3 * srows.shape[0]
        self.transfers.bytes_down += int(a_new.nbytes + nct_new.nbytes + h_new.nbytes)
        return srows, out_old
