"""Out-of-memory embedding management (paper §V-B) — TPU/JAX realization.

Thin facades over the residency-backend architecture
(:mod:`repro.core.backend`):

* :class:`OffloadedRTECEngine` — :class:`~repro.core.backend.OffloadBackend`
  under a :class:`~repro.core.backend.StreamOrchestrator`.  NeutronRT
  offloads intermediate embeddings to CPU memory and reads sparse rows with
  GPU-directed zero-copy; the JAX equivalent keeps the per-layer state
  (h, a, nct) as **host numpy** and, per update batch, transfers only the
  *compact row sets the plan touches* to the device, runs the same
  ``incremental_layer`` kernel over compact arrays, and groups all
  write-backs.  Transfer accounting mirrors the paper's access-volume
  metrics.  ``apply_stream`` returns the same :class:`StreamStats` as the
  other engines (wall_s / plan_s), with batch-t+1 planning overlapped with
  the device's execution of batch t's final layer (deferred write-back).

* :class:`ShardedOffloadRTECEngine` — the **sharded offload hybrid**
  (:class:`~repro.core.backend.ShardedOffloadBackend`): row sharding × host
  residency.  Each shard keeps only its own row block host-resident and
  stages a compact per-layer ``[halo | local]`` workspace to its device, so
  HBM footprint scales with the per-shard affected subgraph rather than V —
  the full NeutronRT GPU-CPU co-processing story at mesh scale.  Under the
  typed :class:`~repro.dist.sharding.CommsConfig` (ISSUE 10, multi-shard
  default ``halo="auto"`` → ``"ppermute"``) the new-view workspace is
  served from the previous layer's device-resident outputs instead of a
  second host-staged copy, halving the halo bytes that cross the staging
  pipeline (``StreamStats.comms_halo_rows_sent`` / ``comms_halo_bytes``).

Both engines stage host↔device traffic through an asynchronous
double-buffered :class:`~repro.serve.staging.HostStagingPipeline` (ISSUE
5): layer *l+1*'s host gathers and layer *l-1*'s write-back scatters run
on a background worker while the device computes layer *l*, on top of the
orchestrator's batch-level plan/execute overlap.  ``async_staging=False``
falls back to inline staging with bitwise-identical output; the overlap
is observable via ``StreamStats.staged_bytes`` / ``prefetch_hits`` /
``sync_wait_s`` vs ``compute_s`` (the deterministic counters are CI-gated
by benchmarks/check_regression.py).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.backend import (  # noqa: F401  (TransferStats re-export)
    BatchStats,
    StreamStats,
    TransferStats,
)
from repro.core.operators import GNNModel, Params
from repro.graph.csr import CSRGraph
from repro.graph.streaming import UpdateBatch


class _OffloadFacadeMixin:
    """Shared delegation for the two host-resident engines."""

    def apply_batch(self, batch: UpdateBatch, block: bool = True) -> BatchStats:
        return self._orch.apply_batch(batch, block=block)

    def apply_stream(self, batches: Sequence[UpdateBatch]) -> StreamStats:
        """Plan/execute overlap for the offload path: batch t's final layer
        executes on device while batch t+1's plan + staging tables build on
        the host; the deferred grouped write-back is the sync point."""
        return self._orch.apply_stream(batches)

    def refresh(self) -> None:
        self._orch.refresh()

    # ------------------------------------------------------------------ #
    # Serving API (ISSUE 6): versioned snapshot reads — see the contract
    # on repro.core.backend.StateBackend / repro.serve.frontend.  Snapshot
    # gathers flush the deferred final write-back first (a no-op at a
    # version boundary), so they never contend with the staging worker.
    # ------------------------------------------------------------------ #
    def snapshot_rows(self, rows) -> np.ndarray:
        """Host gather of final-layer embedding rows (consistent after a
        blocking ``apply_batch``)."""
        return self._backend.snapshot_rows(rows)

    def serving_frontend(self, max_pending_reads: int = 64,
                         max_versions: int = 8):
        """A :class:`~repro.serve.frontend.ServingFrontend` over this
        engine: update-batch writes + embedding reads pinned to versions."""
        from repro.serve.frontend import ServingFrontend

        return ServingFrontend(self, max_pending_reads=max_pending_reads,
                               max_versions=max_versions)

    @property
    def model(self) -> GNNModel:
        return self._backend.model

    @property
    def params(self) -> List[Params]:
        return self._backend.params

    @property
    def L(self) -> int:
        return self._backend.L

    @property
    def graph(self) -> CSRGraph:
        return self._orch.graph

    @graph.setter
    def graph(self, g: CSRGraph) -> None:
        self._orch.graph = g

    @property
    def transfers(self) -> TransferStats:
        return self._backend.transfers

    @property
    def staging(self):
        """The backend's :class:`~repro.serve.staging.HostStagingPipeline`."""
        return self._backend._staging

    @property
    def async_staging(self) -> bool:
        return self._backend.async_staging

    def staging_stats(self):
        """Snapshot of the host-staging counters (StagingStats)."""
        return self._backend.staging_snapshot()

    @property
    def embeddings(self) -> np.ndarray:
        return self._backend.embeddings

    def state_bytes(self) -> int:
        return self._backend.state_bytes()

    def _sync_arrays(self):
        self._backend.flush()
        return self._backend.sync_arrays()


class OffloadedRTECEngine(_OffloadFacadeMixin):
    """Incremental RTEC with host-resident state (CPU-offload engine).
    Constructing it directly is a **deprecated alias** of
    ``create_engine("offload", EngineConfig(...))`` (:mod:`repro.serve.api`),
    which is the one documented entry point (and the only surface exposing
    the staging/cache sub-configs)."""

    def __init__(self, model: GNNModel, params: Sequence[Params], graph: CSRGraph,
                 x: np.ndarray, async_staging: bool = True, policy=None):
        # deferred import: repro.serve.api imports this module at load time
        from repro.serve.api import EngineConfig, _alias_deprecated, create_engine

        _alias_deprecated("OffloadedRTECEngine")
        eng = create_engine("offload", EngineConfig(
            model=model, graph=graph, x=x, params=params,
            async_staging=async_staging, policy=policy))
        self._backend, self._orch = eng._backend, eng._orch

    @property
    def x(self) -> np.ndarray:
        return self._backend.x

    # state views flush the deferred final-layer write-back first, so they
    # can never disagree with `embeddings` mid-pipeline (block=False)
    @property
    def h(self) -> List[np.ndarray]:
        self._backend.flush()
        return self._backend.h

    @property
    def a(self) -> List[np.ndarray]:
        self._backend.flush()
        return self._backend.a

    @property
    def nct(self) -> List[np.ndarray]:
        self._backend.flush()
        return self._backend.nct


class ShardedOffloadRTECEngine(_OffloadFacadeMixin):
    """Incremental RTEC with per-shard host-resident row blocks and compact
    per-layer device staging (the sharded offload hybrid)."""

    def __init__(self, model: GNNModel, params: Sequence[Params], graph: CSRGraph,
                 x: np.ndarray, mesh=None, num_shards: Optional[int] = None,
                 shcfg=None, refresh_every: int = 0, async_staging: bool = True,
                 policy=None):
        # deferred import: repro.serve.api imports this module at load time
        from repro.serve.api import EngineConfig, _alias_deprecated, create_engine

        _alias_deprecated("ShardedOffloadRTECEngine")
        eng = create_engine("sharded_offload", EngineConfig(
            model=model, graph=graph, x=x, params=params, mesh=mesh,
            num_shards=num_shards, shcfg=shcfg, refresh_every=refresh_every,
            async_staging=async_staging, policy=policy))
        self._backend, self._orch = eng._backend, eng._orch

    @property
    def S(self) -> int:
        return self._backend.S

    @property
    def rows_per(self) -> int:
        return self._backend.rows_per

    @property
    def mesh(self):
        return self._backend.mesh

    @property
    def per_shard_rows(self) -> np.ndarray:
        """Per-shard H2D+D2H row volume (deterministic; CI-gated)."""
        return self._backend.per_shard_rows

    @property
    def peak_device_bytes(self) -> int:
        """Largest one-layer staging footprint seen on the mesh — the
        backend's entire HBM residency (state stays host-side)."""
        return self._backend.peak_device_bytes

    @property
    def h(self) -> List[np.ndarray]:
        self._backend.flush()
        return [self._backend._from_blocks(v) for v in self._backend.h]

    @property
    def a(self) -> List[np.ndarray]:
        self._backend.flush()
        return [self._backend._from_blocks(v) for v in self._backend.a]

    @property
    def nct(self) -> List[np.ndarray]:
        self._backend.flush()
        return [self._backend._from_blocks(v) for v in self._backend.nct]
