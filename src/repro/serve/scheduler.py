"""Chunked task scheduling with inter-chunk shard-embedding reuse (§V-C).

When a layer's computation graph exceeds device memory, NeutronRT splits it
into destination-vertex chunks and caches neighborhood intersections between
chunks in a device staging buffer so shared source embeddings transfer once.

`ChunkedLayerScheduler` executes a (full or subset) layer over host-resident
embeddings in chunks: per chunk it gathers only the source rows NOT already
staged from the previous chunk (precomputed intersections — the paper's
mechanism), runs the compact `subset_layer`, and writes results back.
Transfer accounting exposes the reuse win (benchmarks/fig10).

Chunk execution is pipelined like the streaming engine's plan/execute
overlap: each chunk's host tables (CSR gather, remap LUT, padding, the
fresh-row split against the staging set) ship in **one** ``jax.device_put``,
the compact kernel is dispatched asynchronously, and the *next* chunk's host
tables are prepared before this chunk's results are pulled back — so host
prep runs while the device computes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.full import next_bucket, subset_layer
from repro.core.operators import GNNModel, Params
from repro.graph.csr import CSRGraph


@dataclasses.dataclass
class ChunkStats:
    chunks: int = 0
    rows_transferred: int = 0
    rows_reused: int = 0
    edges_processed: int = 0

    @property
    def reuse_frac(self) -> float:
        tot = self.rows_transferred + self.rows_reused
        return self.rows_reused / tot if tot else 0.0


@partial(jax.jit, static_argnums=(0, 11))
def _subset_jit(model, p, h_prev, rows, rmask, e_src, e_ridx, e_w, e_t, e_mask, deg, r_cap):
    return subset_layer(model, p, h_prev, rows, rmask, e_src, e_ridx, e_w, e_t, e_mask, deg, r_cap)


@dataclasses.dataclass
class _ChunkPayload:
    """Host-prepared transfer tables for one chunk (value gathers included)."""

    chunk: np.ndarray
    allrows: np.ndarray  # rows resident on device after this chunk (sorted)
    shared_pos: np.ndarray  # positions of reused rows in the previous staging
    order: np.ndarray  # sort permutation merging [shared | fresh] → allrows
    h_fresh: np.ndarray  # host-gathered h rows not already staged
    n_shared: int
    n_edges: int
    # compact padded kernel inputs
    rows_c: np.ndarray
    rmask: np.ndarray
    e_src: np.ndarray
    e_ridx: np.ndarray
    e_w: np.ndarray
    e_t: np.ndarray
    e_mask: np.ndarray
    deg_c: np.ndarray
    r_cap: int


class ChunkedLayerScheduler:
    def __init__(self, model: GNNModel, chunk_size: int = 8192, reuse: bool = True):
        self.model = model
        self.chunk_size = chunk_size
        self.reuse = reuse
        self.stats = ChunkStats()

    # ------------------------------------------------------------------ #
    def _host_payload(
        self,
        chunk: np.ndarray,
        g: CSRGraph,
        h_prev_host: np.ndarray,
        deg: np.ndarray,
        staged_rows: np.ndarray,
    ) -> _ChunkPayload:
        """All host work for one chunk: CSR gather, staging intersection,
        remap, padding, and the fresh-row value gather."""
        n = g.n
        srcs, ridx, ws, ts = [], [], [], []
        for i, v in enumerate(chunk):
            nb, w, t = g.in_edge_data(int(v))
            srcs.extend(nb.tolist())
            ridx.extend([i] * nb.shape[0])
            ws.extend(w.tolist())
            ts.extend(t.tolist())
        need = np.unique(np.concatenate([np.asarray(srcs, np.int64), chunk]))
        if self.reuse and staged_rows.size:
            shared = np.intersect1d(need, staged_rows, assume_unique=True)
            fresh = np.setdiff1d(need, staged_rows, assume_unique=True)
        else:
            shared = np.zeros(0, np.int64)
            fresh = need
        if shared.size:
            shared_pos = np.searchsorted(staged_rows, shared)
            order = np.argsort(np.concatenate([shared, fresh]))
            allrows = np.concatenate([shared, fresh])[order]
        else:
            shared_pos = np.zeros(0, np.int64)
            order = np.arange(need.shape[0])
            allrows = need

        lut = np.full(n + 1, allrows.shape[0], np.int32)
        lut[allrows] = np.arange(allrows.shape[0], dtype=np.int32)
        r_cap = next_bucket(chunk.shape[0])
        e_cap = next_bucket(len(srcs))

        def pad(a, cap, fill, dt):
            out = np.full(cap, fill, dtype=dt)
            out[: len(a)] = a
            return out

        return _ChunkPayload(
            chunk=chunk,
            allrows=allrows,
            shared_pos=shared_pos,
            order=order,
            h_fresh=h_prev_host[fresh],
            n_shared=int(shared.size),
            n_edges=len(srcs),
            rows_c=pad(lut[chunk], r_cap, allrows.shape[0], np.int32),
            rmask=pad(np.ones(chunk.shape[0], bool), r_cap, False, bool),
            e_src=pad(lut[np.asarray(srcs, np.int64)] if srcs else [], e_cap,
                      allrows.shape[0], np.int32),
            e_ridx=pad(ridx, e_cap, r_cap, np.int32),
            e_w=pad(ws, e_cap, 0.0, np.float32),
            e_t=pad(ts, e_cap, 0, np.int32),
            e_mask=pad(np.ones(len(srcs), bool), e_cap, False, bool),
            deg_c=np.concatenate([deg[allrows].astype(np.float32),
                                  np.zeros(1, np.float32)]),
            r_cap=r_cap,
        )

    # ------------------------------------------------------------------ #
    def run_layer(
        self,
        p: Params,
        g: CSRGraph,
        h_prev_host: np.ndarray,  # [N, d_in] host
        rows: np.ndarray,  # destination rows to compute
        deg: np.ndarray,  # [N] float
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Returns (a_rows, nct_rows, h_rows) for `rows`, chunked + pipelined."""
        outs_a, outs_n, outs_h = [], [], []
        chunks = [rows[c0: c0 + self.chunk_size]
                  for c0 in range(0, rows.shape[0], self.chunk_size)]
        staged_vals: Optional[jnp.ndarray] = None  # [len(staged), d] on device

        payload = (self._host_payload(chunks[0], g, h_prev_host, deg,
                                      np.zeros(0, np.int64)) if chunks else None)
        for ci in range(len(chunks)):
            pl = payload
            self.stats.edges_processed += pl.n_edges
            self.stats.rows_reused += pl.n_shared
            self.stats.rows_transferred += pl.allrows.shape[0] - pl.n_shared

            # one batched H2D transfer per chunk
            dev = jax.device_put((
                pl.h_fresh, pl.shared_pos, pl.order, pl.rows_c, pl.rmask,
                pl.e_src, pl.e_ridx, pl.e_w, pl.e_t, pl.e_mask, pl.deg_c,
            ))
            (h_fresh_d, shared_pos_d, order_d, rows_c, rmask,
             e_src, e_ridx, e_w, e_t, e_mask, deg_c) = dev
            if pl.n_shared and staged_vals is not None:
                dev_shared = staged_vals[shared_pos_d]
                buf = jnp.concatenate([dev_shared, h_fresh_d], axis=0)[order_d]
            else:
                buf = h_fresh_d
            staged_vals = buf

            h_dev = jnp.concatenate([buf, jnp.zeros((1, buf.shape[1]), buf.dtype)])
            a_c, nct_c, h_c = _subset_jit(
                self.model, p, h_dev, rows_c, rmask, e_src, e_ridx, e_w, e_t,
                e_mask, deg_c, pl.r_cap,
            )
            # prefetch: next chunk's host tables build while device computes
            if ci + 1 < len(chunks):
                payload = self._host_payload(chunks[ci + 1], g, h_prev_host,
                                             deg, pl.allrows)
            k = pl.chunk.shape[0]
            outs_a.append(np.asarray(a_c)[:k])  # sync point
            outs_n.append(np.asarray(nct_c)[:k])
            outs_h.append(np.asarray(h_c)[:k])
            self.stats.chunks += 1

        return (
            np.concatenate(outs_a) if outs_a else np.zeros((0, 1), np.float32),
            np.concatenate(outs_n) if outs_n else np.zeros((0, 1), np.float32),
            np.concatenate(outs_h) if outs_h else np.zeros((0, 1), np.float32),
        )
