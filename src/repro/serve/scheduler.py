"""Chunked task scheduling with inter-chunk shard-embedding reuse (§V-C).

When a layer's computation graph exceeds device memory, NeutronRT splits it
into destination-vertex chunks and caches neighborhood intersections between
chunks in a device staging buffer so shared source embeddings transfer once.

`ChunkedLayerScheduler` executes a (full or subset) layer over host-resident
embeddings in chunks: per chunk it gathers only the source rows NOT already
staged from the previous chunk (precomputed intersections — the paper's
mechanism), runs the compact `subset_layer`, and writes results back.
Transfer accounting exposes the reuse win (benchmarks/fig10).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.full import next_bucket, subset_layer
from repro.core.operators import GNNModel, Params
from repro.graph.csr import CSRGraph


@dataclasses.dataclass
class ChunkStats:
    chunks: int = 0
    rows_transferred: int = 0
    rows_reused: int = 0
    edges_processed: int = 0

    @property
    def reuse_frac(self) -> float:
        tot = self.rows_transferred + self.rows_reused
        return self.rows_reused / tot if tot else 0.0


from functools import partial


@partial(jax.jit, static_argnums=(0, 11))
def _subset_jit(model, p, h_prev, rows, rmask, e_src, e_ridx, e_w, e_t, e_mask, deg, r_cap):
    return subset_layer(model, p, h_prev, rows, rmask, e_src, e_ridx, e_w, e_t, e_mask, deg, r_cap)


class ChunkedLayerScheduler:
    def __init__(self, model: GNNModel, chunk_size: int = 8192, reuse: bool = True):
        self.model = model
        self.chunk_size = chunk_size
        self.reuse = reuse
        self.stats = ChunkStats()

    def run_layer(
        self,
        p: Params,
        g: CSRGraph,
        h_prev_host: np.ndarray,  # [N, d_in] host
        rows: np.ndarray,  # destination rows to compute
        deg: np.ndarray,  # [N] float
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Returns (a_rows, nct_rows, h_rows) for `rows`, chunked."""
        n = g.n
        outs_a, outs_n, outs_h = [], [], []
        staged_rows = np.zeros(0, np.int64)  # rows resident on device
        staged_vals: jnp.ndarray = None  # [len(staged), d]
        deg_x = jnp.asarray(np.concatenate([deg.astype(np.float32), [0.0]]))

        for c0 in range(0, rows.shape[0], self.chunk_size):
            chunk = rows[c0 : c0 + self.chunk_size]
            srcs, ridx, ws, ts = [], [], [], []
            for i, v in enumerate(chunk):
                nb, w, t = g.in_edge_data(int(v))
                srcs.extend(nb.tolist())
                ridx.extend([i] * nb.shape[0])
                ws.extend(w.tolist())
                ts.extend(t.tolist())
            self.stats.edges_processed += len(srcs)
            # rows needed on device for this chunk
            need = np.unique(np.concatenate([np.asarray(srcs, np.int64), chunk]))
            if self.reuse and staged_rows.size:
                shared = np.intersect1d(need, staged_rows, assume_unique=True)
                fresh = np.setdiff1d(need, staged_rows, assume_unique=True)
            else:
                shared = np.zeros(0, np.int64)
                fresh = need
            self.stats.rows_reused += shared.size
            self.stats.rows_transferred += fresh.size
            # assemble device buffer: shared rows reused from staging
            if shared.size and staged_vals is not None:
                pos = np.searchsorted(staged_rows, shared)
                dev_shared = staged_vals[jnp.asarray(pos)]
                dev_fresh = jnp.asarray(h_prev_host[fresh])
                order = np.argsort(np.concatenate([shared, fresh]))
                allrows = np.concatenate([shared, fresh])[order]
                dev = jnp.concatenate([dev_shared, dev_fresh], axis=0)[jnp.asarray(order)]
            else:
                allrows = need
                dev = jnp.asarray(h_prev_host[need])
            staged_rows, staged_vals = allrows, dev

            # remap into compact space
            lut = np.full(n + 1, allrows.shape[0], np.int32)
            lut[allrows] = np.arange(allrows.shape[0], dtype=np.int32)
            r_cap = next_bucket(chunk.shape[0])
            e_cap = next_bucket(len(srcs))

            def pad(a, cap, fill, dt):
                out = np.full(cap, fill, dtype=dt)
                out[: len(a)] = a
                return out

            rows_c = pad(lut[chunk], r_cap, allrows.shape[0], np.int32)
            rmask = pad(np.ones(chunk.shape[0], bool), r_cap, False, bool)
            e_src = pad(lut[np.asarray(srcs, np.int64)] if srcs else [], e_cap, allrows.shape[0], np.int32)
            e_ridx = pad(ridx, e_cap, r_cap, np.int32)
            e_w = pad(ws, e_cap, 0.0, np.float32)
            e_t = pad(ts, e_cap, 0, np.int32)
            e_mask = pad(np.ones(len(srcs), bool), e_cap, False, bool)
            # compact degree table aligned with the staged rows
            deg_c = jnp.concatenate([deg_x[jnp.asarray(allrows)], jnp.zeros(1)])

            h_dev = jnp.concatenate([dev, jnp.zeros((1, dev.shape[1]), dev.dtype)])
            a_c, nct_c, h_c = _subset_jit(
                self.model, p, h_dev, jnp.asarray(rows_c), jnp.asarray(rmask),
                jnp.asarray(e_src), jnp.asarray(e_ridx), jnp.asarray(e_w),
                jnp.asarray(e_t), jnp.asarray(e_mask), deg_c, r_cap,
            )
            k = chunk.shape[0]
            outs_a.append(np.asarray(a_c)[:k])
            outs_n.append(np.asarray(nct_c)[:k])
            outs_h.append(np.asarray(h_c)[:k])
            self.stats.chunks += 1

        return (
            np.concatenate(outs_a) if outs_a else np.zeros((0, 1), np.float32),
            np.concatenate(outs_n) if outs_n else np.zeros((0, 1), np.float32),
            np.concatenate(outs_h) if outs_h else np.zeros((0, 1), np.float32),
        )
