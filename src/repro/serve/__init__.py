"""Serving: the unified engine factory (`create_engine`), the online
read/write serving front-end with versioned snapshot reads
(`ServingFrontend`), host-offloaded embedding stores, chunked task
scheduling with shard-embedding reuse, LM decode loop.

Exports resolve lazily (PEP 562): ``repro.core.backend`` imports
``repro.serve.staging`` at module load, so an eager ``from .api import …``
here would close an import cycle through the partially-initialized core
package.
"""
from __future__ import annotations

_API = ("create_engine", "EngineConfig", "BACKENDS", "ChunkedRTECEngine",
        "serving_frontend", "FusionConfig")
_FRONTEND = ("ServingFrontend", "ReadTicket", "ReadRejectedError",
             "StaleVersionError")
_CACHE = ("CacheConfig", "CacheStats", "HotRowCache")
_STAGING = ("StagingConfig",)

__all__ = list(_API + _FRONTEND + _CACHE + _STAGING)


def __getattr__(name: str):
    if name in _API:
        from repro.serve import api

        return getattr(api, name)
    if name in _FRONTEND:
        from repro.serve import frontend

        return getattr(frontend, name)
    if name in _CACHE:
        from repro.serve import hotcache

        return getattr(hotcache, name)
    if name in _STAGING:
        from repro.serve import staging

        return getattr(staging, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
