"""Serving: host-offloaded embedding store, chunked task scheduling with
shard-embedding reuse, LM decode loop."""
