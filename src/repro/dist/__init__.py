"""`repro.dist` — sharding, activation constraints, pipeline parallelism.

The distribution layer has three parts:

- `repro.dist.sharding` — logical-axis rules.  Params carry logical axis
  names (`nn/param.py`); `ShardingConfig.rules()` maps them to mesh axes,
  `tree_shardings` turns a whole param tree into `NamedSharding`s, and
  `auto_spec`/`batch_specs`/`cache_specs` cover inputs and decode caches.
- `repro.dist.ctx` — activation constraints.  Wrap execution in
  `activation_sharding(mesh, shcfg)` and every `ashard(x, "dp", "tp")`
  call inside the model becomes a `with_sharding_constraint`; outside the
  context `ashard` is an identity, so single-device runs are untouched.
- `repro.dist.pipeline` — `pipeline_apply`, microbatched GPipe-style
  pipelining over a mesh "stage" axis, with `sequential_reference` as the
  single-device oracle.

Usage::

    import jax
    from repro.configs import get_arch
    from repro.configs.base import ShapeConfig
    from repro.dist import activation_sharding
    from repro.launch.steps import make_train_step, shardings_for_cell

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    sh = shardings_for_cell(cfg, ShapeConfig("tiny", 16, 8, "train"), mesh)
    with activation_sharding(mesh, sh["shcfg"]):
        step = jax.jit(make_train_step(cfg, opt_cfg),
                       in_shardings=(sh["params_sharding"],
                                     sh["opt_sharding"],
                                     sh["batch_sharding"]))
        params, opt, metrics = step(params, opt, batch)

The context only matters at trace time, and it is NOT part of jit's cache
key: re-entering it for later calls of an already-traced function is
unnecessary but harmless, while first-tracing a step *outside* the context
caches the unconstrained program for good (see `repro.dist.ctx`).  Enter
the context before the first call, as above.
"""
from repro.dist.ctx import activation_sharding, ashard
from repro.dist.pipeline import pipeline_apply, sequential_reference
from repro.dist.sharding import (
    ShardingConfig,
    auto_spec,
    batch_specs,
    cache_specs,
    opt_state_specs,
    spec_for_axes,
    stream_mesh,
    stream_state_specs,
    tree_shardings,
)

__all__ = [
    "ShardingConfig",
    "activation_sharding",
    "ashard",
    "auto_spec",
    "batch_specs",
    "cache_specs",
    "opt_state_specs",
    "pipeline_apply",
    "sequential_reference",
    "spec_for_axes",
    "stream_mesh",
    "stream_state_specs",
    "tree_shardings",
]
