"""Logical-axis sharding rules (MaxText pattern).

Every parameter carries a tuple of *logical* axis names (``nn/param.py``);
this module owns the single mapping from logical names to physical mesh
axes.  The mapping depends only on :class:`ShardingConfig` — training wants
FSDP (shard the replicated ``embed`` dim over the data axes, ZeRO-3 style),
serving wants TP-only params so decode never all-gathers weights.

Key invariant: a mesh axis may appear at most once in a
:class:`~jax.sharding.PartitionSpec`; :func:`spec_for_axes` resolves
conflicts first-dim-wins.  All shape-aware entry points
(:func:`auto_spec`, :func:`tree_shardings`, :func:`cache_specs`) drop any
assignment whose dim is not divisible by the mesh axes it would occupy, so
tiny test configs and production configs share one rule table.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# A rule maps a logical axis name to one mesh axis, a tuple of mesh axes
# (e.g. FSDP over ("pod", "data")), or None (replicated).
MeshAxes = Union[None, str, Tuple[str, ...]]
Rules = Dict[str, MeshAxes]


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    """How logical axes map onto the physical mesh.

    ``fsdp``    — shard the ``embed`` dim of every weight over ``dp_axes``
                  (ZeRO-3: params, grads and optimizer state all sharded).
                  With ``fsdp=False`` params are TP-only (serving layout);
                  optimizer state can still be dp-sharded via
                  :func:`opt_state_specs` (ZeRO-1).
    ``dp_axes`` — mesh axes that jointly form the data-parallel group
                  (("data",) single pod, ("pod", "data") multi-pod).
    ``tp_axis`` — the tensor-parallel mesh axis.
    """

    fsdp: bool = True
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"

    def rules(self) -> Rules:
        dp = tuple(self.dp_axes)
        return {
            # weight matrices: contracting/output dims over TP
            "vocab": self.tp_axis,
            "heads": self.tp_axis,
            "mlp": self.tp_axis,
            # FSDP shards the embed dim over the data axes; otherwise the
            # embed dim stays replicated (pure-TP serving layout)
            "embed": dp if self.fsdp else None,
            # scan-stacked leading dims are never sharded
            "layers": None,
            "stack": None,
            # experts are local to each TP group (no expert-parallel axis yet)
            "experts": None,
            # streaming-graph state: vertex rows block-partitioned over the
            # data axes (the sharded RTEC engine's [S, rows_per+1, ·] blocks)
            "graph_rows": dp,
        }


#: halo exchange strategies for the row-sharded streaming backends
_HALO_MODES = ("psum", "ppermute", "auto")


@dataclasses.dataclass(frozen=True)
class CommsConfig:
    """Halo-exchange strategy for the row-sharded streaming backends.

    ``halo`` — how each layer's frontier halo moves between shards:

    * ``"psum"``     — the legacy global broadcast: every shard contributes
      its owned halo rows to one ``lax.psum``, so per-device bytes scale
      with the *global* frontier.
    * ``"ppermute"`` — plan-time per-consumer partitioning: rotation-round
      ``lax.ppermute`` schedules deliver each halo row only to the shards
      that reference it, so traffic scales with each shard's own halo.
      On the hybrid host-resident backend this also enables the
      device-served fast path (co-hosted halo rows skip host staging).
    * ``"auto"``     — resolved once at backend construction: ``ppermute``
      when the mesh has more than one shard, else ``psum`` (single-shard
      meshes have no remote halo, so the schedules would be empty).

    ``pair_capacity_hysteresis`` — extra headroom multiplier applied to the
    per-(owner, consumer) pair capacities before hysteresis bucketing, e.g.
    ``0.5`` pads each pair table 1.5× above its high-water mark so bursty
    streams retrace less often.  ``0.0`` (default) buckets the raw sizes.

    ``use_pallas_delta`` — route the sharded delta-scatter through the
    Pallas kernels (folded in from the old loose ``use_pallas_delta=``
    constructor kwarg; the kwarg survives as a deprecated alias).
    """

    halo: str = "auto"
    pair_capacity_hysteresis: float = 0.0
    use_pallas_delta: bool = False

    def __post_init__(self):
        if self.halo not in _HALO_MODES:
            raise ValueError(
                f"CommsConfig.halo must be one of {_HALO_MODES}, "
                f"got {self.halo!r}")
        if self.pair_capacity_hysteresis < 0:
            raise ValueError(
                "CommsConfig.pair_capacity_hysteresis must be >= 0, "
                f"got {self.pair_capacity_hysteresis!r}")

    def resolve_halo(self, num_shards: int) -> str:
        """Collapse ``"auto"`` for a concrete mesh size (done once at
        backend construction so the resolved mode is a static trace key)."""
        if self.halo != "auto":
            return self.halo
        return "ppermute" if num_shards > 1 else "psum"


def rotation_perm(num_shards: int, k: int = 1) -> List[Tuple[int, int]]:
    """(source, destination) pairs for a rotate-by-``k`` ``lax.ppermute``.

    One full exchange over ``S`` shards is ``S - 1`` rotation rounds
    (``k = 1 .. S-1``); the pair owner→consumer ``(o, c)`` rides round
    ``(c - o) mod S``.  The GPipe pipeline (:mod:`repro.dist.pipeline`)
    is the ``k = 1`` special case, the per-consumer halo exchange
    (:func:`repro.core.affected.shard_plan` with ``halo="ppermute"``)
    uses all ``S - 1`` rounds."""
    return [(j, (j + k) % num_shards) for j in range(num_shards)]


def _as_tuple(v: MeshAxes) -> Tuple[str, ...]:
    if v is None:
        return ()
    if isinstance(v, str):
        return (v,)
    return tuple(v)


def _entry(axes: Tuple[str, ...]):
    """Collapse a mesh-axes tuple into a PartitionSpec entry."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def spec_for_axes(axes: Sequence[Optional[str]], rules: Rules) -> P:
    """Logical axes tuple -> PartitionSpec under ``rules``.

    Unknown logical names are replicated; a mesh axis already consumed by an
    earlier dim is dropped (first-dim-wins), never duplicated.
    """
    used: set = set()
    entries = []
    for ax in axes:
        mesh_axes = _as_tuple(rules.get(ax)) if ax is not None else ()
        if mesh_axes and not any(m in used for m in mesh_axes):
            used.update(mesh_axes)
            entries.append(_entry(mesh_axes))
        else:
            entries.append(None)
    return P(*entries)


def _axis_sizes(mesh) -> Dict[str, int]:
    """{mesh axis -> size}; works for jax.sharding.Mesh and test doubles
    exposing only ``axis_names`` + ``devices``."""
    return dict(zip(tuple(mesh.axis_names), np.shape(mesh.devices)))


def _prod_size(axes: Tuple[str, ...], sizes: Dict[str, int]) -> int:
    return math.prod(sizes[a] for a in axes)


def _drop_indivisible(spec: P, shape: Sequence[int], sizes: Dict[str, int]) -> P:
    """Replicate any dim whose size is not divisible by its assigned axes."""
    entries = []
    for dim, entry in zip(shape, tuple(spec)):
        axes = _as_tuple(entry)
        if axes and dim % _prod_size(axes, sizes) != 0:
            entry = None
        entries.append(entry)
    return P(*entries)


def auto_spec(shape: Sequence[int], mesh, shcfg: ShardingConfig, batch_dim: int = 0) -> P:
    """Divisibility-aware spec for an *input* array (batches, tokens).

    The dp axes land on ``batch_dim`` when its size divides the dp group;
    otherwise they move to the first other divisible dim (so odd benchmark
    batch sizes still get some parallelism).  The tp axis then takes the
    rightmost remaining divisible dim.  Anything left is replicated.
    """
    sizes = _axis_sizes(mesh)
    dp = tuple(a for a in shcfg.dp_axes if a in sizes)
    entries: list = [None] * len(shape)

    if dp:
        dp_size = _prod_size(dp, sizes)
        dp_dim = None
        if shape[batch_dim] % dp_size == 0:
            dp_dim = batch_dim
        else:
            for i, d in enumerate(shape):
                if i != batch_dim and d % dp_size == 0:
                    dp_dim = i
                    break
        if dp_dim is not None:
            entries[dp_dim] = _entry(dp)

    if shcfg.tp_axis in sizes:
        tp_size = sizes[shcfg.tp_axis]
        for i in range(len(shape) - 1, -1, -1):
            if entries[i] is None and shape[i] % tp_size == 0:
                entries[i] = shcfg.tp_axis
                break
    return P(*entries)


def _is_axes_leaf(x: Any) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)


def tree_shardings(
    axes_tree,
    mesh,
    shcfg: ShardingConfig,
    shapes_tree=None,
) -> Any:
    """Map a logical-axes tree (from ``nn.param.unzip``) to NamedShardings.

    With ``shapes_tree`` (matching tree of arrays / ShapeDtypeStructs) every
    spec is additionally divisibility-checked against the actual dims — the
    reduced test configs rely on this to fall back to replication.
    """
    rules = shcfg.rules()
    sizes = _axis_sizes(mesh)

    def one(axes, shaped=None):
        spec = spec_for_axes(axes, rules)
        if shaped is not None:
            spec = _drop_indivisible(spec, np.shape(shaped), sizes)
        return NamedSharding(mesh, spec)

    if shapes_tree is None:
        return jax.tree.map(one, axes_tree, is_leaf=_is_axes_leaf)
    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=_is_axes_leaf)


def batch_specs(batch_struct: Dict[str, Any], mesh, shcfg: ShardingConfig,
                batch_dim: int = 0) -> Dict[str, P]:
    """Per-input PartitionSpecs for a {name: array-like} batch dict."""
    return {k: auto_spec(np.shape(v), mesh, shcfg, batch_dim=batch_dim)
            for k, v in batch_struct.items()}


def cache_specs(cache_struct, mesh, shcfg: ShardingConfig, batch: Optional[int] = None):
    """PartitionSpec tree for a decode-cache pytree.

    Cache leaves are stacked state buffers with the batch dim somewhere
    after the leading scan dims — ``[L, B, heads, ...]`` for KV caches,
    ``[G, P-1, B, ...]`` for xLSTM group state.  With ``batch`` given, the
    dp axes land on the first dim (past dim 0) whose size equals it;
    without it, dim 1 is assumed (the KV-cache layout).  The tp axis only
    ever takes the dim immediately after the batch (the heads dim) —
    sharding the ring-buffer sequence dim would turn every decode-step
    ``dynamic_update_slice`` at a traced index into a collective.
    Scalars (the ring index) and short leaves replicate.
    """
    sizes = _axis_sizes(mesh)
    dp = tuple(a for a in shcfg.dp_axes if a in sizes)
    dp_size = _prod_size(dp, sizes) if dp else 0
    tp = shcfg.tp_axis if shcfg.tp_axis in sizes else None

    def one(leaf):
        shape = np.shape(leaf)
        if len(shape) < 3:
            return P(*([None] * len(shape)))
        b_dim = 1
        if batch is not None:
            b_dim = next((i for i in range(1, len(shape)) if shape[i] == batch), 1)
        entries: list = [None] * len(shape)
        if dp and shape[b_dim] % dp_size == 0:
            entries[b_dim] = _entry(dp)
        h_dim = b_dim + 1
        if tp and h_dim < len(shape) - 1 and shape[h_dim] % sizes[tp] == 0:
            entries[h_dim] = tp
        return P(*entries)

    return jax.tree.map(one, cache_struct)


def stream_mesh(
    num_shards: Optional[int] = None,
    shcfg: Optional[ShardingConfig] = None,
):
    """1-D mesh for the row-sharded streaming engine.

    Uses the first data-parallel axis name from ``shcfg`` (so the engine's
    specs come straight out of :func:`spec_for_axes` under the standard rule
    table) over the first ``num_shards`` local devices (default: all)."""
    shcfg = shcfg or ShardingConfig()
    n = num_shards or jax.device_count()
    if n > jax.device_count():
        raise ValueError(
            f"num_shards={n} exceeds the {jax.device_count()} available "
            "devices (force host devices via XLA_FLAGS before jax imports)"
        )
    devs = np.array(jax.devices()[:n])
    return jax.sharding.Mesh(devs, (shcfg.dp_axes[0],))


def stream_state_specs(mesh, shcfg: Optional[ShardingConfig] = None) -> Dict[str, NamedSharding]:
    """NamedShardings for the sharded streaming backends' buffers
    (``repro.core.backend``: `ShardBackend` and `ShardedOffloadBackend`).

    ``state``: stacked ``[S, rows_per+1, d]`` embedding/aggregate blocks —
    ``graph_rows`` on the leading shard dim (`ShardBackend` persistent
    state).  ``plan``: stacked ``[S, ·]`` per-shard buffers — packed plan
    rows, Pallas schedules, and the hybrid backend's transient compact
    ``[halo|local]`` staging (each device receives only its slice).
    ``replicated``: halo row lists, degree-free side tables, params."""
    shcfg = shcfg or ShardingConfig()
    sizes = _axis_sizes(mesh)
    rules = dict(shcfg.rules())
    # stream_mesh is 1-D over dp_axes[0]; a multi-pod config's full dp tuple
    # would name axes this mesh doesn't have
    rules["graph_rows"] = (
        tuple(a for a in _as_tuple(rules["graph_rows"]) if a in sizes) or None
    )
    return {
        "state": NamedSharding(mesh, spec_for_axes(("graph_rows", None, None), rules)),
        "plan": NamedSharding(mesh, spec_for_axes(("graph_rows", None), rules)),
        "replicated": NamedSharding(mesh, P()),
    }


def opt_state_specs(axes_tree, mesh, shcfg: ShardingConfig, shapes_tree=None):
    """ZeRO-1/3 optimizer-moment shardings (`train/optimizer.py`).

    AdamW's ``m``/``v`` are pytree-shaped copies of the params, so they take
    the *FSDP* layout even when the params themselves are TP-only
    (``fsdp=False``): that is exactly ZeRO-1 (replicated params, dp-sharded
    optimizer state).  With ``fsdp=True`` params and moments share one
    layout — ZeRO-3.
    """
    zcfg = shcfg if shcfg.fsdp else dataclasses.replace(shcfg, fsdp=True)
    return tree_shardings(axes_tree, mesh, zcfg, shapes_tree=shapes_tree)
