"""Activation-sharding context: `activation_sharding` + `ashard`.

Model code annotates activations with *logical* activation axes ("dp" =
batch-like, "tp" = head/feature-like, None = replicated) instead of mesh
names, so the same forward pass runs unmodified on one device, one pod or
multiple pods.  `ashard` is a no-op unless the caller opened an
`activation_sharding(mesh, shcfg)` context — single-device tests and the
eager paths never pay for it.

Entering the context is cheap and purely thread-local; it composes with
`jax.jit` because `ashard` resolves the (mesh, config) pair at *trace*
time, baking a `with_sharding_constraint` into the jaxpr.

CAVEAT — the context is NOT part of jit's cache key.  A function traced
*outside* the context caches the unconstrained program, and a later call
inside the context with the same avals silently reuses it (and vice
versa).  Always enter `activation_sharding` before the first call of a
jitted step you want constrained, or jit a fresh function per context —
`launch/dryrun.py` and `tests/test_dist.py` both follow this pattern.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import ShardingConfig, _as_tuple, _axis_sizes, _entry, _prod_size

_state = threading.local()


def _stack():
    if not hasattr(_state, "stack"):
        _state.stack = []
    return _state.stack


def current_mesh_and_config() -> Optional[Tuple[object, ShardingConfig]]:
    """The innermost active (mesh, ShardingConfig), or None."""
    stack = _stack()
    return stack[-1] if stack else None


@contextlib.contextmanager
def activation_sharding(mesh, shcfg: ShardingConfig):
    """Activate `ashard` constraints for `mesh` under `shcfg`'s rules.

    Usage (see `launch/dryrun.py` / `tests/test_dist.py`)::

        sh = shardings_for_cell(cfg, shape, mesh)
        with activation_sharding(mesh, sh["shcfg"]):
            jitted = jax.jit(step, in_shardings=...)
            out = jitted(...)
    """
    _stack().append((mesh, shcfg))
    try:
        yield
    finally:
        _stack().pop()


def _activation_spec(shape, logical_axes, mesh, shcfg: ShardingConfig) -> P:
    """Map ("dp"|"tp"|None, ...) onto mesh axes, divisibility-checked.

    `logical_axes` may be shorter than the rank; trailing dims replicate.
    A mesh axis is used at most once (first dim wins), and any dim not
    divisible by its axes falls back to replicated — so the same annotation
    is valid for 4-head test models and 128-head production models.
    """
    sizes = _axis_sizes(mesh)
    lookup = {
        "dp": tuple(a for a in shcfg.dp_axes if a in sizes),
        "tp": (shcfg.tp_axis,) if shcfg.tp_axis in sizes else (),
    }
    used: set = set()
    entries = []
    for i, dim in enumerate(shape):
        ax = logical_axes[i] if i < len(logical_axes) else None
        mesh_axes = lookup.get(ax, ()) if ax is not None else ()
        mesh_axes = _as_tuple(mesh_axes)
        if (
            mesh_axes
            and not any(m in used for m in mesh_axes)
            and dim % _prod_size(mesh_axes, sizes) == 0
        ):
            used.update(mesh_axes)
            entries.append(_entry(mesh_axes))
        else:
            entries.append(None)
    return P(*entries)


def ashard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Constrain activation `x` to the logical axes, or pass through.

    Outside an `activation_sharding` context this returns `x` unchanged
    (identity, no tracing cost), which keeps every single-device code path
    byte-identical to the unsharded program.
    """
    ctx = current_mesh_and_config()
    if ctx is None:
        return x
    mesh, shcfg = ctx
    spec = _activation_spec(np.shape(x), logical_axes, mesh, shcfg)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
