"""Microbatched pipeline parallelism over a mesh "stage" axis.

`pipeline_apply` runs a layer-stacked block function as a GPipe-style
pipeline inside one `shard_map`: each device row along the stage axis owns
one slice of the stacked params, microbatches stream through, and
`lax.ppermute` moves activations stage -> stage+1 each tick.  The schedule
is the classic (num_micro + num_stages - 1)-tick fill/drain loop; numerics
are bit-comparable to `sequential_reference` because every microbatch sees
the identical op sequence, just on a different device per step.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import rotation_perm


def sequential_reference(block: Callable[[Any, jax.Array], jax.Array],
                         params, x: jax.Array) -> jax.Array:
    """Single-device reference: apply the S stacked stages in order.

    `params` is a pytree whose leaves all carry a leading stage dim S;
    stage s runs `block(params[s], x)`.
    """
    num_stages = jax.tree.leaves(params)[0].shape[0]
    for s in range(num_stages):
        stage_params = jax.tree.map(lambda a: a[s], params)  # noqa: B023
        x = block(stage_params, x)
    return x


def pipeline_apply(
    block: Callable[[Any, jax.Array], jax.Array],
    params,
    x: jax.Array,
    mesh,
    stage_axis: str = "stage",
    num_micro: int = 4,
) -> jax.Array:
    """Pipeline-parallel `sequential_reference` over `mesh`'s stage axis.

    The leading dim of every param leaf is split across `stage_axis`
    (stage s's params live on device row s); the batch dim of `x` is split
    into `num_micro` microbatches that stream through the stages.  Any
    other mesh axes (e.g. "model") see replicated data — compose tensor
    parallelism inside `block` via `ashard` if wanted.
    """
    num_stages = int(mesh.shape[stage_axis])
    batch = x.shape[0]
    if batch % num_micro != 0:
        raise ValueError(f"batch {batch} not divisible by num_micro={num_micro}")
    stage_dim = jax.tree.leaves(params)[0].shape[0]
    if stage_dim != num_stages:
        raise ValueError(
            f"params leading dim {stage_dim} != mesh '{stage_axis}' size {num_stages}"
        )
    micro = batch // num_micro
    xs = x.reshape(num_micro, micro, *x.shape[1:])

    param_specs = jax.tree.map(
        lambda a: P(stage_axis, *([None] * (a.ndim - 1))), params
    )

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(param_specs, P()),
        out_specs=P(),
        check_rep=False,
    )
    def run(local_params, xs_all):
        idx = lax.axis_index(stage_axis)
        stage_params = jax.tree.map(lambda a: a[0], local_params)
        fwd = rotation_perm(num_stages)  # stage -> stage+1 each tick

        def tick(t, carry):
            state, out_buf = carry
            # stage 0 injects microbatch t (clamped; ticks past the fill
            # phase recompute a stale microbatch whose output is never kept)
            mb = xs_all[jnp.minimum(t, num_micro - 1)]
            inp = jnp.where(idx == 0, mb, state)
            y = block(stage_params, inp)
            # the last stage finished microbatch m = t - (num_stages - 1)
            m = t - (num_stages - 1)
            keep = jnp.logical_and(idx == num_stages - 1, m >= 0)
            slot = jnp.clip(m, 0, num_micro - 1)
            out_buf = out_buf.at[slot].set(jnp.where(keep, y, out_buf[slot]))
            state = lax.ppermute(y, stage_axis, fwd)
            return state, out_buf

        ticks = num_micro + num_stages - 1
        _, out_buf = lax.fori_loop(
            0, ticks, tick, (jnp.zeros_like(xs_all[0]), jnp.zeros_like(xs_all))
        )
        # only the last stage holds real outputs; psum broadcasts them
        mask = (idx == num_stages - 1).astype(out_buf.dtype)
        return lax.psum(out_buf * mask, stage_axis)

    out = run(params, xs)
    return out.reshape(batch, *x.shape[1:])
