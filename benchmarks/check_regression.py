"""Blocking CI perf-regression gate over the bench-smoke artifact.

Usage (what .github/workflows/ci.yml runs after ``benchmarks.run --smoke``):

    python -m benchmarks.check_regression \
        --current BENCH_smoke.json --baseline BENCH_baseline.json

Fails (exit 1) when the pipelined engine's headline metric
``fig7/smoke/gcn/inc_speedup_vs_full``

* drops below the absolute floor (default 1.2x — the paper's claim is a
  *speedup*, so losing to full recompute is always a regression), or
* regresses more than ``--tolerance`` (default 20%) relative to the
  committed ``BENCH_baseline.json``.

The baseline file is committed; refresh it deliberately (rerun
``python -m benchmarks.run --smoke`` and copy the artifact) when a PR
legitimately shifts the perf envelope.
"""
from __future__ import annotations

import argparse
import json
import sys

METRIC = "fig7/smoke/gcn/inc_speedup_vs_full"


def read_speedup(path: str, metric: str = METRIC) -> float:
    """Extract the speedup ('1.53x' derived column) from a smoke artifact."""
    with open(path) as f:
        data = json.load(f)
    for row in data.get("rows", []):
        name, _, derived = row.split(",", 2)
        if name == metric:
            if not derived.endswith("x"):
                raise ValueError(f"{path}: metric {metric!r} has no speedup column: {row!r}")
            return float(derived[:-1])
    raise KeyError(f"{path}: metric {metric!r} not found")


def check(current: float, baseline: float | None, floor: float, tolerance: float):
    """Returns a list of failure messages (empty → gate passes)."""
    failures = []
    if current < floor:
        failures.append(
            f"{METRIC} = {current:.2f}x is below the absolute floor {floor:.2f}x"
        )
    if baseline is not None:
        min_ok = baseline * (1.0 - tolerance)
        if current < min_ok:
            failures.append(
                f"{METRIC} = {current:.2f}x regressed >{tolerance:.0%} vs "
                f"baseline {baseline:.2f}x (min allowed {min_ok:.2f}x)"
            )
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="BENCH_smoke.json")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--floor", type=float, default=1.2,
                    help="absolute minimum inc_speedup_vs_full (default 1.2)")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="max fractional regression vs baseline (default 0.2)")
    args = ap.parse_args()

    current = read_speedup(args.current)
    try:
        baseline = read_speedup(args.baseline)
    except FileNotFoundError:
        print(f"note: no baseline at {args.baseline}; checking absolute floor only")
        baseline = None

    failures = check(current, baseline, args.floor, args.tolerance)
    base_str = f"{baseline:.2f}x" if baseline is not None else "n/a"
    print(f"perf gate: current={current:.2f}x baseline={base_str} "
          f"floor={args.floor:.2f}x tolerance={args.tolerance:.0%}")
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    if not failures:
        print("perf gate passed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
