"""Blocking CI perf-regression gate over the bench-smoke artifact.

Usage (what .github/workflows/ci.yml runs after ``benchmarks.run --smoke``):

    python -m benchmarks.check_regression \
        --current BENCH_smoke.json --baseline BENCH_baseline.json

The gate watches a small **metric matrix** (``SPECS``), not a single cell:

* ``fig7/smoke/gcn/inc_speedup_vs_full`` — the headline unconstrained-path
  speedup (the paper's claim is a *speedup*, so losing to full recompute is
  always a regression: absolute floor 1.2x);
* ``fig7/smoke/gat/inc_speedup_vs_full`` — the constrained
  (destination-dependent) path, which exercises the §IV-C full-recompute
  branch the gcn cell never touches;
* ``fig7/smoke/gcn/offload_transfer_rows`` — the offload engine's H2D+D2H
  row volume, a *deterministic* count (no timing noise): growth means the
  compact row sets or remap tables regressed;
* ``fig7/smoke/gcn/frontend_reads_served`` / ``_staleness_batches`` — the
  serving front-end's deterministic read counters from its fixed
  interleaving schedule (ISSUE 6), gated exactly; the read-latency rows
  stay non-blocking telemetry.
* ``fig7/smoke/gcn/cache_staged_bytes`` + ``cache_hit_rows`` /
  ``cache_miss_rows`` / ``cache_evictions`` — the hot-row cache set
  (ISSUE 8): the staged-bytes row carries the uncached/cached reduction
  ratio on the deterministic hub_burst cell (floor 1.43x, i.e. the
  ≥30% reduction acceptance bound with margin) and the counters are
  exact (``CACHE_EXPECTED``, shared with the emitting cell; the sharded
  suite gates the hybrid's ``hybrid_cache_*`` mirror rows).

Every gated cell now reports through ``StreamStats.as_dict()`` (the single
result type) via ``benchmarks.common.emit_stream_stats``.

Speedup metrics fail when they drop below their absolute ``floor`` or
regress more than ``tolerance`` vs the committed baseline; volume metrics
fail when they *exceed* their ``ceiling`` or grow more than ``tolerance``;
``exact`` metrics (the overlap counters, which are deterministic) must
equal the expectation the emitting cell embeds in their derived column
(``expect_<v>``) and the committed baseline value bit-for-bit.  The
baseline file is committed; refresh it deliberately (rerun
``python -m benchmarks.run --smoke`` and copy the artifact) when a PR
legitimately shifts the perf envelope.

Exit codes are distinct so CI can retry *noise* without masking a metric
that was never emitted (the noise-retry bug, ISSUE 5):

* ``0`` — all gated metrics pass;
* ``1`` — a metric regressed (timing metrics may be runner noise: CI
  gives the whole gate one fresh measurement before failing the build);
* ``2`` — a gated metric is **missing** from the current artifact (or the
  artifact is unreadable).  Never retried: the emitting cell is broken.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import List, Optional, Sequence, Tuple

METRIC = "fig7/smoke/gcn/inc_speedup_vs_full"

EXIT_OK = 0
EXIT_REGRESSION = 1
EXIT_MISSING = 2


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    name: str
    kind: str  # "speedup": derived '<v>x' column, higher is better;
    #            "volume": value column, lower is better;
    #            "exact": deterministic counter — must equal the
    #            'expect_<v>' derived column and the baseline exactly
    floor: Optional[float] = None  # speedup: absolute minimum
    ceiling: Optional[float] = None  # volume: absolute maximum
    tolerance: float = 0.2  # max fractional regression vs baseline


SPECS = (
    MetricSpec(name=METRIC, kind="speedup", floor=1.2, tolerance=0.20),
    MetricSpec(name="fig7/smoke/gat/inc_speedup_vs_full", kind="speedup",
               floor=1.1, tolerance=0.25),
    # deterministic offload metrics: row volume must never grow
    # (tolerance 0 — "unchanged" is the contract; shrinking is a win), and
    # the overlap counters must hit their structural expectations exactly
    MetricSpec(name="fig7/smoke/gcn/offload_transfer_rows", kind="volume",
               ceiling=20000.0, tolerance=0.0),
    MetricSpec(name="fig7/smoke/gcn/offload_prefetch_hits", kind="exact"),
    # measured 145560B on the smoke stream; the ceiling leaves ~35%
    # headroom for planner drift while catching an O(V)-staging regression
    # (full-state staging would be ~10x) — 5% creep tolerance vs baseline
    MetricSpec(name="fig7/smoke/gcn/offload_staged_bytes", kind="volume",
               ceiling=200_000.0, tolerance=0.05),
    # serving front-end read counters (ISSUE 6): the smoke cell's read
    # schedule is deterministic (one fresh + one two-back pinned read per
    # batch once version ≥ 2 → 10 served, cumulative staleness 8), so both
    # counters gate BLOCKING and exactly; the companion read_p99 latency
    # row is telemetry and never gated
    MetricSpec(name="fig7/smoke/gcn/frontend_reads_served", kind="exact"),
    MetricSpec(name="fig7/smoke/gcn/frontend_staleness_batches",
               kind="exact"),
    # device hot-row cache (ISSUE 8): the hub_burst cell runs the offload
    # engine cached vs uncached on the same deterministic stream.  The
    # staged-bytes row is gated as a *ratio* (uncached/cached ≥ 1.43x —
    # the acceptance's ≥30% reduction), and the hit/miss/eviction counters
    # gate exactly (tolerance 0): residency is a pure function of the
    # plans, so any drift is a cache or planner change, never noise.
    MetricSpec(name="fig7/smoke/gcn/cache_staged_bytes", kind="speedup",
               floor=1.43, tolerance=0.10),
    MetricSpec(name="fig7/smoke/gcn/cache_hit_rows", kind="exact"),
    MetricSpec(name="fig7/smoke/gcn/cache_miss_rows", kind="exact"),
    MetricSpec(name="fig7/smoke/gcn/cache_evictions", kind="exact"),
    # batch-window fusion (ISSUE 9): the high-rate small-batch cell's
    # stream is structurally fusable (region-disjoint updates on a ring
    # lattice), so the window/absorbed-batch counters and the resulting
    # dispatch count — n_batches − (fused_batches − fusion_windows) — are
    # pure functions of the plans and gate exactly (tolerance 0).  Any
    # drift means the footprint-disjointness check or the lookahead
    # window regressed; the emitting cell additionally fails the step on
    # any fused-vs-serial embedding divergence (bitwise contract).
    MetricSpec(name="fig7/smoke/gcn/fusion_windows", kind="exact"),
    MetricSpec(name="fig7/smoke/gcn/fusion_fused_batches", kind="exact"),
    MetricSpec(name="fig7/smoke/gcn/fusion_dispatches", kind="exact"),
)

# Gated against BENCH_sharded.json by the multi-device CI job
# (``--suite sharded``): the hybrid's per-shard H2D+D2H row volume is
# deterministic, so growth means the per-shard compact staging or remap
# tables regressed toward O(V) transfers (an O(V)-per-shard regression on
# the 300-vertex smoke graph would exceed 9000 rows).  The overlap
# counters of the hybrid's apply_stream cell are gated the same way as
# the smoke suite's.
SHARDED_SPECS = (
    MetricSpec(name="fig7/sharded/gcn/hybrid_transfer_rows_per_shard",
               kind="volume", ceiling=2500.0, tolerance=0.15),
    MetricSpec(name="fig7/sharded/gcn/hybrid_prefetch_hits", kind="exact"),
    # measured 568320B (S=8, cap-padded per-shard staging buffers)
    MetricSpec(name="fig7/sharded/gcn/hybrid_staged_bytes", kind="volume",
               ceiling=750_000.0, tolerance=0.05),
    # hot-row cache on the hybrid (ISSUE 8): same contract as the smoke
    # suite's cache set — ratio-gated staged bytes, exact residency counts
    MetricSpec(name="fig7/sharded/gcn/hybrid_cache_staged_bytes",
               kind="speedup", floor=1.43, tolerance=0.10),
    MetricSpec(name="fig7/sharded/gcn/hybrid_cache_hit_rows", kind="exact"),
    MetricSpec(name="fig7/sharded/gcn/hybrid_cache_miss_rows", kind="exact"),
    MetricSpec(name="fig7/sharded/gcn/hybrid_cache_evictions", kind="exact"),
    # per-consumer halo exchange (ISSUE 10): rows-sent under ppermute is
    # the number of unique (owner, consumer, row) deliveries — a pure
    # function of the plans, gated exactly (tolerance 0).  The ceiling
    # row pins the global-frontier psum broadcast volume the exchange
    # replaced; the emitting cell (fig7_response_time._sharded_comms_cell)
    # additionally fails the CI step unless rows_sent is strictly below
    # it with bitwise-equal embeddings.
    MetricSpec(name="fig7/sharded/gcn/comms_halo_rows_sent", kind="exact"),
    MetricSpec(name="fig7/sharded/gcn/comms_psum_ceiling_rows",
               kind="exact"),
)

#: ISSUE-8 hot-row-cache expectations on the deterministic hub_burst smoke
#: stream (n=256, 6 batches, CacheConfig(capacity_rows=256)), shared by the
#: emitting cells (benchmarks/fig7_response_time.py) and the exact gates
#: above so bench and gate cannot drift apart.  Residency is a pure
#:  function of the Alg.-4 plans: hit/miss/eviction counts are bit-stable
#: run to run.  The ``sharded`` row is pinned for the CI multi-device
#: job's 8-way mesh (per-shard halo rows make the counts S-dependent).
CACHE_EXPECTED = {
    "smoke": {"hit_rows": 580, "miss_rows": 504, "evictions": 0},
    "sharded": {"hit_rows": 616, "miss_rows": 532, "evictions": 0},
}

#: ISSUE-10 per-consumer halo-exchange expectations on the deterministic
#: sharded smoke stream (powerlaw n=300, 6 batches, the CI multi-device
#: job's 8-way mesh), shared by the emitting cell
#: (fig7_response_time._sharded_comms_cell) and the exact gates above.
#: ``halo_rows_sent`` counts unique (owner, consumer, row) ppermute
#: deliveries over the stream; ``psum_ceiling_rows`` is the legacy
#: global-frontier broadcast volume (halo rows × S) the exchange
#: replaced — both are pure functions of the Alg.-4 plans.
COMMS_EXPECTED = {
    "sharded": {"halo_rows_sent": 157, "psum_ceiling_rows": 584},
}

#: ISSUE-9 batch-window-fusion expectations on the deterministic fusable
#: smoke stream (ring lattice n=600, 12 region-disjoint batches,
#: FusionConfig(window=4)), shared by the emitting cell
#: (fig7_response_time.smoke_fusion) and the exact gates above.  The
#: greedy maximal-prefix fuser packs 12 independent batches under a
#: 4-deep lookahead into 3 full windows, so the stream executes in
#: 12 − (12 − 3) = 3 device dispatches.
FUSION_EXPECTED = {"windows": 3, "fused_batches": 12, "dispatches": 3}

#: per-regime structural expectations for the adaptive policy on the
#: default adversarial streams (benchmarks/adversarial.py imports this
#: table to embed the expect_<v> columns, so the emitting cell and the
#: gate share one source of truth): exact decision counts and the raw
#: edge-work total of the adaptive run.
ADVERSARIAL_EXPECTED = {
    "hub_burst": {"incremental": 4, "chunked": 0, "full": 2,
                  "policy_edges": 3168},
    "delete_heavy": {"incremental": 3, "chunked": 0, "full": 3,
                     "policy_edges": 1608},
    "feature_churn": {"incremental": 3, "chunked": 3, "full": 0,
                      "policy_edges": 4524},
}


def _adversarial_specs(regime: str) -> Tuple[MetricSpec, ...]:
    """The ISSUE-7 policy metric set for one adversarial regime:

    * the three per-mode decision counts, gated **exactly** (BLOCKING) —
      the streams are deterministic, so any drift is a policy or planner
      change, never noise;
    * the raw edge-work ceiling (tolerance 0: deterministic volume);
    * the policy-vs-best-fixed cost ratio in the cost model's edge-work
      units — the adaptive per-batch argmin over mode-independent plans
      is ≤ every fixed mode by construction, so the deterministic ratio
      is ≥ 1.0; the 0.91 floor is the acceptance bound "within 1.1× of
      the best fixed mode";
    * the same ratio in wall time — 2-core-runner noise plus compile
      jitter at n=256 scale, so the floor is generous and the structure
      is carried by the exact counters above.
    """
    exp = ADVERSARIAL_EXPECTED[regime]
    return (
        MetricSpec(name=f"adversarial/{regime}/policy_incremental_batches",
                   kind="exact"),
        MetricSpec(name=f"adversarial/{regime}/policy_chunked_batches",
                   kind="exact"),
        MetricSpec(name=f"adversarial/{regime}/policy_full_batches",
                   kind="exact"),
        MetricSpec(name=f"adversarial/{regime}/policy_edges", kind="volume",
                   ceiling=float(exp["policy_edges"]), tolerance=0.0),
        MetricSpec(name=f"adversarial/{regime}/policy_cost_vs_best_fixed",
                   kind="speedup", floor=0.91, tolerance=0.05),
        MetricSpec(name=f"adversarial/{regime}/policy_wall_vs_best_fixed",
                   kind="speedup", floor=0.30, tolerance=0.60),
    )


SUITES = {"smoke": SPECS, "sharded": SHARDED_SPECS}
SUITES["adversarial"] = tuple(
    spec for regime in ADVERSARIAL_EXPECTED
    for spec in _adversarial_specs(regime))
for _regime in ADVERSARIAL_EXPECTED:
    SUITES[f"adversarial-{_regime}"] = _adversarial_specs(_regime)


def load_row_names(path: str) -> List[str]:
    """All row names of a bench artifact (raises ValueError on any shape
    surprise so callers can map it to the exit-2 path, not a traceback)."""
    try:
        with open(path) as f:
            data = json.load(f)
    except json.JSONDecodeError as e:
        raise ValueError(f"{path}: artifact is not valid JSON: {e}")
    rows = data.get("rows") if isinstance(data, dict) else None
    if not isinstance(rows, list):
        raise ValueError(f"{path}: artifact has no 'rows' list")
    return [str(r).split(",", 2)[0] for r in rows]


def missing_namespace_rows(current: str, baseline: str,
                           specs: Sequence[MetricSpec]) -> List[str]:
    """Baseline rows under a gated cell's namespace that the candidate
    artifact no longer emits.

    A renamed bench cell leaves the stale names in the committed baseline;
    before this check they were silently ignored (the per-spec loop only
    looks up spec names), so the rename could pass the retry path without
    anyone refreshing the baseline.  Any such row is exit-2 material —
    re-measuring cannot conjure a renamed metric."""
    try:
        base_names = load_row_names(baseline)
    except (FileNotFoundError, ValueError):
        return []  # no baseline at all → absolute bounds only, as before
    try:
        cur_names = set(load_row_names(current))
    except (FileNotFoundError, ValueError) as e:
        return [f"candidate artifact unreadable: {e}"]
    roots = tuple({spec.name.rsplit("/", 1)[0] + "/" for spec in specs})
    return [
        f"baseline row {name!r} is in a gated namespace but missing from "
        f"{current} (renamed bench cell? refresh the baseline)"
        for name in base_names
        if name.startswith(roots) and name not in cur_names
    ]


def read_row(path: str, metric: str) -> Tuple[float, str]:
    """Extract one metric row from a smoke artifact as (value, derived)."""
    with open(path) as f:
        data = json.load(f)
    for row in data.get("rows", []):
        name, value, derived = row.split(",", 2)
        if name == metric:
            return float(value), derived
    raise KeyError(f"{path}: metric {metric!r} not found")


def read_metric(path: str, metric: str, kind: str = "speedup") -> float:
    """Extract one metric from a smoke artifact: the '1.53x' derived column
    for speedups, the us_per_call value column for volumes/exact."""
    value, derived = read_row(path, metric)
    if kind == "speedup":
        if not derived.endswith("x"):
            raise ValueError(
                f"{path}: metric {metric!r} has no speedup column: "
                f"{metric},{value},{derived}"
            )
        return float(derived[:-1])
    return value


def read_speedup(path: str, metric: str = METRIC) -> float:
    return read_metric(path, metric, kind="speedup")


def check(current: float, baseline: Optional[float], floor: float,
          tolerance: float, metric: str = METRIC) -> List[str]:
    """Speedup-metric check; returns failure messages (empty → passes)."""
    failures = []
    if current < floor:
        failures.append(
            f"{metric} = {current:.2f}x is below the absolute floor {floor:.2f}x"
        )
    if baseline is not None:
        min_ok = baseline * (1.0 - tolerance)
        if current < min_ok:
            failures.append(
                f"{metric} = {current:.2f}x regressed >{tolerance:.0%} vs "
                f"baseline {baseline:.2f}x (min allowed {min_ok:.2f}x)"
            )
    return failures


def check_volume(current: float, baseline: Optional[float], ceiling: float,
                 tolerance: float, metric: str) -> List[str]:
    """Volume-metric check (lower is better)."""
    failures = []
    if current > ceiling:
        failures.append(
            f"{metric} = {current:.0f} exceeds the absolute ceiling {ceiling:.0f}"
        )
    if baseline is not None:
        max_ok = baseline * (1.0 + tolerance)
        if current > max_ok:
            failures.append(
                f"{metric} = {current:.0f} grew >{tolerance:.0%} vs "
                f"baseline {baseline:.0f} (max allowed {max_ok:.0f})"
            )
    return failures


def check_exact(current: float, derived: str, baseline: Optional[float],
                metric: str) -> List[str]:
    """Exact-counter check: the emitting cell embeds its structural
    expectation in the derived column (``expect_<v>``); the counter must
    match it and the committed baseline bit-for-bit (no tolerance —
    these are deterministic functions of the plan, not timings)."""
    failures = []
    if not derived.startswith("expect_"):
        failures.append(
            f"{metric} derived column {derived!r} carries no expect_<v> "
            "expectation (emitting cell broken)"
        )
    else:
        expect = float(derived[len("expect_"):])
        if current != expect:
            failures.append(
                f"{metric} = {current:.0f} != structural expectation "
                f"{expect:.0f} (overlap pipeline degraded)"
            )
    if baseline is not None and current != baseline:
        failures.append(
            f"{metric} = {current:.0f} != baseline {baseline:.0f} "
            "(deterministic counter changed)"
        )
    return failures


def check_spec(spec: MetricSpec, current: float, baseline: Optional[float],
               derived: str = "") -> List[str]:
    if spec.kind == "speedup":
        return check(current, baseline, spec.floor, spec.tolerance, spec.name)
    if spec.kind == "exact":
        return check_exact(current, derived, baseline, spec.name)
    return check_volume(current, baseline, spec.ceiling, spec.tolerance, spec.name)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="BENCH_smoke.json")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--suite", choices=sorted(SUITES), default="smoke",
                    help="metric matrix to gate: 'smoke' for the "
                         "single-device artifact, 'sharded' for the "
                         "multi-device BENCH_sharded.json artifact")
    args = ap.parse_args()

    failures: List[str] = []
    missing: List[str] = []
    for msg in missing_namespace_rows(args.current, args.baseline,
                                      SUITES[args.suite]):
        print(f"MISSING: {msg}", file=sys.stderr)
        missing.append(msg)
    for spec in SUITES[args.suite]:
        try:
            value, derived = read_row(args.current, spec.name)
            if spec.kind == "speedup":
                if not derived.endswith("x"):
                    raise ValueError(
                        f"{args.current}: metric {spec.name!r} has no "
                        f"speedup column: {derived!r}")
                current = float(derived[:-1])
            else:
                current = value
            if spec.kind == "exact" and not derived.startswith("expect_"):
                # the emitting cell no longer embeds its expectation —
                # that is a broken emitter, not a perf regression
                raise ValueError(
                    f"{args.current}: exact metric {spec.name!r} carries "
                    f"no expect_<v> derived column: {derived!r}")
        except (FileNotFoundError, KeyError, ValueError) as e:
            print(f"MISSING: {e}", file=sys.stderr)
            missing.append(spec.name)
            continue
        try:
            baseline = read_metric(args.baseline, spec.name, spec.kind)
        except (FileNotFoundError, KeyError, ValueError):
            print(f"note: no baseline for {spec.name}; absolute bound only")
            baseline = None
        base_str = f"{baseline:.2f}" if baseline is not None else "n/a"
        bound = {"speedup": f"floor={spec.floor:.2f}x" if spec.floor else "",
                 "volume": f"ceiling={spec.ceiling:.0f}" if spec.ceiling else "",
                 "exact": f"exact[{derived}]"}[spec.kind]
        print(f"perf gate: {spec.name} current={current:.2f} "
              f"baseline={base_str} {bound} tolerance={spec.tolerance:.0%}")
        failures += check_spec(spec, current, baseline, derived)

    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    if missing:
        print(f"MISSING METRICS (exit {EXIT_MISSING}, never retried): "
              f"{', '.join(missing)}", file=sys.stderr)
        return EXIT_MISSING
    if not failures:
        print("perf gate passed (all metrics)")
    return EXIT_REGRESSION if failures else EXIT_OK


if __name__ == "__main__":
    sys.exit(main())
