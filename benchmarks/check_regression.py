"""Blocking CI perf-regression gate over the bench-smoke artifact.

Usage (what .github/workflows/ci.yml runs after ``benchmarks.run --smoke``):

    python -m benchmarks.check_regression \
        --current BENCH_smoke.json --baseline BENCH_baseline.json

The gate watches a small **metric matrix** (``SPECS``), not a single cell:

* ``fig7/smoke/gcn/inc_speedup_vs_full`` — the headline unconstrained-path
  speedup (the paper's claim is a *speedup*, so losing to full recompute is
  always a regression: absolute floor 1.2x);
* ``fig7/smoke/gat/inc_speedup_vs_full`` — the constrained
  (destination-dependent) path, which exercises the §IV-C full-recompute
  branch the gcn cell never touches;
* ``fig7/smoke/gcn/offload_transfer_rows`` — the offload engine's H2D+D2H
  row volume, a *deterministic* count (no timing noise): growth means the
  compact row sets or remap tables regressed.

Speedup metrics fail when they drop below their absolute ``floor`` or
regress more than ``tolerance`` vs the committed baseline; volume metrics
fail when they *exceed* their ``ceiling`` or grow more than ``tolerance``.
The baseline file is committed; refresh it deliberately (rerun
``python -m benchmarks.run --smoke`` and copy the artifact) when a PR
legitimately shifts the perf envelope.  CI gives the whole gate one retry
(timing metrics are millisecond-scale ratios on shared runners).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import List, Optional

METRIC = "fig7/smoke/gcn/inc_speedup_vs_full"


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    name: str
    kind: str  # "speedup": derived '<v>x' column, higher is better;
    #            "volume": value column, lower is better
    floor: Optional[float] = None  # speedup: absolute minimum
    ceiling: Optional[float] = None  # volume: absolute maximum
    tolerance: float = 0.2  # max fractional regression vs baseline


SPECS = (
    MetricSpec(name=METRIC, kind="speedup", floor=1.2, tolerance=0.20),
    MetricSpec(name="fig7/smoke/gat/inc_speedup_vs_full", kind="speedup",
               floor=1.1, tolerance=0.25),
    MetricSpec(name="fig7/smoke/gcn/offload_transfer_rows", kind="volume",
               ceiling=20000.0, tolerance=0.10),
)

# Gated against BENCH_sharded.json by the multi-device CI job
# (``--suite sharded``): the hybrid's per-shard H2D+D2H row volume is
# deterministic, so growth means the per-shard compact staging or remap
# tables regressed toward O(V) transfers (an O(V)-per-shard regression on
# the 300-vertex smoke graph would exceed 9000 rows).
SHARDED_SPECS = (
    MetricSpec(name="fig7/sharded/gcn/hybrid_transfer_rows_per_shard",
               kind="volume", ceiling=2500.0, tolerance=0.15),
)

SUITES = {"smoke": SPECS, "sharded": SHARDED_SPECS}


def read_metric(path: str, metric: str, kind: str = "speedup") -> float:
    """Extract one metric from a smoke artifact: the '1.53x' derived column
    for speedups, the us_per_call value column for volumes."""
    with open(path) as f:
        data = json.load(f)
    for row in data.get("rows", []):
        name, value, derived = row.split(",", 2)
        if name == metric:
            if kind == "speedup":
                if not derived.endswith("x"):
                    raise ValueError(
                        f"{path}: metric {metric!r} has no speedup column: {row!r}"
                    )
                return float(derived[:-1])
            return float(value)
    raise KeyError(f"{path}: metric {metric!r} not found")


def read_speedup(path: str, metric: str = METRIC) -> float:
    return read_metric(path, metric, kind="speedup")


def check(current: float, baseline: Optional[float], floor: float,
          tolerance: float, metric: str = METRIC) -> List[str]:
    """Speedup-metric check; returns failure messages (empty → passes)."""
    failures = []
    if current < floor:
        failures.append(
            f"{metric} = {current:.2f}x is below the absolute floor {floor:.2f}x"
        )
    if baseline is not None:
        min_ok = baseline * (1.0 - tolerance)
        if current < min_ok:
            failures.append(
                f"{metric} = {current:.2f}x regressed >{tolerance:.0%} vs "
                f"baseline {baseline:.2f}x (min allowed {min_ok:.2f}x)"
            )
    return failures


def check_volume(current: float, baseline: Optional[float], ceiling: float,
                 tolerance: float, metric: str) -> List[str]:
    """Volume-metric check (lower is better)."""
    failures = []
    if current > ceiling:
        failures.append(
            f"{metric} = {current:.0f} exceeds the absolute ceiling {ceiling:.0f}"
        )
    if baseline is not None:
        max_ok = baseline * (1.0 + tolerance)
        if current > max_ok:
            failures.append(
                f"{metric} = {current:.0f} grew >{tolerance:.0%} vs "
                f"baseline {baseline:.0f} (max allowed {max_ok:.0f})"
            )
    return failures


def check_spec(spec: MetricSpec, current: float,
               baseline: Optional[float]) -> List[str]:
    if spec.kind == "speedup":
        return check(current, baseline, spec.floor, spec.tolerance, spec.name)
    return check_volume(current, baseline, spec.ceiling, spec.tolerance, spec.name)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="BENCH_smoke.json")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--suite", choices=sorted(SUITES), default="smoke",
                    help="metric matrix to gate: 'smoke' for the "
                         "single-device artifact, 'sharded' for the "
                         "multi-device BENCH_sharded.json artifact")
    args = ap.parse_args()

    failures: List[str] = []
    for spec in SUITES[args.suite]:
        current = read_metric(args.current, spec.name, spec.kind)
        try:
            baseline = read_metric(args.baseline, spec.name, spec.kind)
        except (FileNotFoundError, KeyError):
            print(f"note: no baseline for {spec.name}; absolute bound only")
            baseline = None
        base_str = f"{baseline:.2f}" if baseline is not None else "n/a"
        bound = (f"floor={spec.floor:.2f}x" if spec.kind == "speedup"
                 else f"ceiling={spec.ceiling:.0f}")
        print(f"perf gate: {spec.name} current={current:.2f} "
              f"baseline={base_str} {bound} tolerance={spec.tolerance:.0%}")
        failures += check_spec(spec, current, baseline)

    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    if not failures:
        print("perf gate passed (all metrics)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
